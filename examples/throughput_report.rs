//! Regenerate the paper's Table 1 + Figure 3: pure environment
//! simulation throughput for every executor on Atari-like and
//! MuJoCo-like tasks, plus the thread-count scaling series.
//!
//! Run: `cargo run --release --example throughput_report -- [--steps N]`
//! (`--quick` shrinks the step count for CI.)

use envpool::cli::Args;
use envpool::coordinator::throughput::run_throughput;
use envpool::metrics::table::{fmt_fps, Table};

const METHODS: &[(&str, &str)] = &[
    ("For-loop", "forloop"),
    ("Subprocess", "subprocess"),
    ("Sample-Factory", "sample-factory"),
    ("EnvPool (sync)", "envpool-sync"),
    ("EnvPool (async)", "envpool-async"),
];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps: u64 = if args.flag("quick") { 2_000 } else { args.parse_or("steps", 20_000) };
    let threads: usize = args.parse_or("num-threads", 2);
    let seed = 0u64;
    // paper's guidance: N = 2-3x threads, M = threads
    let num_envs = 3 * threads;
    let batch = threads;

    println!("# Table 1 analog — this machine ({} hw threads visible)", num_threads_visible());
    println!("# steps/cell = {steps}, threads = {threads}, N = {num_envs}, M = {batch}\n");

    let mut t = Table::new(["Method", "Atari (Pong-v5) FPS", "MuJoCo (Ant-v4) FPS"]);
    for (label, kind) in METHODS {
        let atari = run_throughput("Pong-v5", kind, num_envs, batch, threads, steps, seed)
            .map_err(|e| anyhow::anyhow!("{label}/atari: {e}"))?;
        let mujoco = run_throughput("Ant-v4", kind, num_envs, batch, threads, steps, seed)
            .map_err(|e| anyhow::anyhow!("{label}/mujoco: {e}"))?;
        t.row([label.to_string(), fmt_fps(atari), fmt_fps(mujoco)]);
    }
    // numa+async: shard the pool (the paper's DGX-A100 row; here 2 shards)
    {
        use envpool::pool::{NumaPool, PoolConfig};
        use envpool::rng::Pcg32;
        let fps = numa_fps("Pong-v5", num_envs, batch, threads, steps, seed)?;
        let fps_m = numa_fps("Ant-v4", num_envs, batch, threads, steps, seed)?;
        t.row(["EnvPool (numa+async)".to_string(), fmt_fps(fps), fmt_fps(fps_m)]);

        fn numa_fps(
            task: &str,
            num_envs: usize,
            batch: usize,
            threads: usize,
            steps: u64,
            seed: u64,
        ) -> anyhow::Result<f64> {
            let shards = 2;
            let n = num_envs.div_ceil(shards) * shards;
            let m = batch.div_ceil(shards) * shards;
            let cfg = PoolConfig::new(task)
                .num_envs(n)
                .batch_size(m)
                .num_threads(threads.max(shards))
                .seed(seed);
            let mut pool = NumaPool::make(cfg, shards).map_err(|e| anyhow::anyhow!("{e}"))?;
            pool.async_reset();
            let mut outs = pool.make_outputs();
            let spec = envpool::envs::registry::spec_for(task).map_err(|e| anyhow::anyhow!("{e}"))?;
            let mult = envpool::coordinator::throughput::frame_multiplier(task) as f64;
            let mut rng = Pcg32::new(seed, 1);
            let mut actions = Vec::new();
            let mut done_steps = 0u64;
            let t0 = std::time::Instant::now();
            while done_steps < steps {
                pool.recv_all(&mut outs);
                let mut ids = Vec::new();
                for o in &outs {
                    ids.extend_from_slice(&o.env_ids);
                }
                envpool::coordinator::throughput::random_actions(
                    &spec.action_space,
                    ids.len(),
                    &mut rng,
                    &mut actions,
                );
                pool.send(&actions, &ids).map_err(|e| anyhow::anyhow!("{e}"))?;
                done_steps += ids.len() as u64;
            }
            Ok(done_steps as f64 / t0.elapsed().as_secs_f64() * mult)
        }
    }
    println!("{}", t.render());

    // Figure 3 analog: scaling with worker threads.
    println!("\n# Figure 3 analog — FPS vs worker threads (Pong-v5)");
    let mut f = Table::new(["Threads", "Subprocess", "EnvPool (sync)", "EnvPool (async)"]);
    for w in [1usize, 2, 4] {
        let n = 3 * w;
        let sub = run_throughput("Pong-v5", "subprocess", w.max(1), w, w, steps / 2, seed)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let sync = run_throughput("Pong-v5", "envpool-sync", n, n, w, steps / 2, seed)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let asy = run_throughput("Pong-v5", "envpool-async", n, w, w, steps / 2, seed)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        f.row([w.to_string(), fmt_fps(sub), fmt_fps(sync), fmt_fps(asy)]);
    }
    println!("{}", f.render());
    Ok(())
}

fn num_threads_visible() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
