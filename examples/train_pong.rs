//! PPO on the Atari-like Pong (4×84×84 pixel observations) — the paper's
//! flagship end-to-end demo ("train Atari Pong in five minutes"). On this
//! single-core container the absolute time differs; what the run shows is
//! the full pixel pipeline composing: EnvPool frames -> AOT policy ->
//! AOT train step.
//!
//! Modes:
//!   (default)   one Pong run with N=8 (paper Table-3 hyperparameters)
//!   --parity    Fig 7 analog: same-N executor parity
//!   --sweep-n   Fig 6 analog: N ∈ {8, 16} (tuned high-throughput config)
//!   --compare   Fig 5/9 analog: subprocess vs EnvPool wall time

use envpool::cli::Args;
use envpool::config::{ExecutorKind, TrainConfig};
use envpool::coordinator::ppo;

fn base_cfg(args: &Args) -> TrainConfig {
    let mut cfg = TrainConfig {
        env_id: "Pong-v5".into(),
        executor: ExecutorKind::EnvPoolSync,
        num_envs: 8,
        batch_size: 8,
        num_threads: 2,
        total_steps: 40_960, // a few iterations by default (pixel obs are heavy)
        learning_rate: 2.5e-4,
        clip_coef: 0.1,
        ..TrainConfig::default()
    };
    cfg.num_envs = args.parse_or("num-envs", cfg.num_envs);
    cfg.batch_size = cfg.num_envs;
    cfg.total_steps = args.parse_or("total-steps", cfg.total_steps);
    cfg.seed = args.parse_or("seed", 1);
    cfg
}

fn run(cfg: &TrainConfig, label: &str) -> anyhow::Result<()> {
    let s = ppo::train(cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "{label:<14} N={:<3} wall={:>7.1}s fps={:>6.0} final={:>6.1} best={:>6.1} episodes={}",
        s.num_envs,
        s.wall_secs,
        s.env_steps as f64 / s.wall_secs,
        s.final_return,
        s.best_return,
        s.episodes
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();

    if args.flag("parity") {
        println!("# Fig 7 analog: executor parity on Pong (N=8)");
        for ex in [ExecutorKind::ForLoop, ExecutorKind::EnvPoolSync] {
            let mut cfg = base_cfg(&args);
            cfg.executor = ex;
            run(&cfg, &format!("{ex}"))?;
        }
        return Ok(());
    }
    if args.flag("compare") {
        println!("# Fig 5/9 analog: subprocess vs EnvPool on Pong, same N");
        for ex in [ExecutorKind::Subprocess, ExecutorKind::EnvPoolSync] {
            let mut cfg = base_cfg(&args);
            cfg.executor = ex;
            run(&cfg, &format!("{ex}"))?;
        }
        return Ok(());
    }
    if args.flag("sweep-n") {
        println!("# Fig 6 analog: default N=8 vs tuned N=16 Pong config");
        for n in [8usize, 16] {
            let mut cfg = base_cfg(&args);
            cfg.num_envs = n;
            cfg.batch_size = n;
            run(&cfg, &format!("n{n}"))?;
        }
        return Ok(());
    }

    let cfg = base_cfg(&args);
    println!(
        "training PPO on Pong-v5 pixels (N={}, {} steps)...",
        cfg.num_envs, cfg.total_steps
    );
    let (s, prof) = ppo::train_profiled(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{}", s.render());
    println!("{}", prof.render("pong/envpool-sync"));
    s.write_curve_csv("pong_curve.csv").map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(())
}
