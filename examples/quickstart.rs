//! Quickstart: the EnvPool API in 40 lines — make a pool, drive it with
//! random actions in both synchronous and asynchronous modes, print the
//! throughput. Mirrors the paper's Appendix A usage examples.
//!
//! Run: `cargo run --release --example quickstart`

use envpool::coordinator::throughput::random_actions;
use envpool::pool::{EnvPool, PoolConfig};
use envpool::rng::Pcg32;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // --- synchronous mode: batch_size == num_envs (gym-style step) ---
    let mut pool = EnvPool::make(
        PoolConfig::new("CartPole-v1").num_envs(8).sync().num_threads(2).seed(0),
    )?;
    let mut out = pool.make_output();
    pool.reset_into(&mut out)?;
    println!("sync: reset -> batch of {} obs of dim {}", out.len(), pool.spec().obs_dim());
    let mut rng = Pcg32::new(0, 0);
    let mut actions = Vec::new();
    let space = pool.spec().action_space.clone();
    let t0 = Instant::now();
    let steps = 20_000;
    for _ in 0..steps / 8 {
        random_actions(&space, out.len(), &mut rng, &mut actions);
        let ids = out.env_ids.clone();
        pool.step_into(&actions, &ids, &mut out)?;
    }
    println!("sync: {:.0} steps/s", steps as f64 / t0.elapsed().as_secs_f64());
    drop(pool);

    // --- asynchronous mode: recv the fastest M of N envs (paper §3.2) ---
    let mut pool = EnvPool::make(
        PoolConfig::new("CartPole-v1").num_envs(12).batch_size(8).num_threads(2).seed(0),
    )?;
    pool.async_reset();
    let t0 = Instant::now();
    let mut done_steps = 0u64;
    while done_steps < steps {
        pool.recv_into(&mut out);
        random_actions(&space, out.len(), &mut rng, &mut actions);
        let ids = out.env_ids.clone();
        pool.send(&actions, &ids)?;
        done_steps += out.len() as u64;
    }
    println!("async: {:.0} steps/s", done_steps as f64 / t0.elapsed().as_secs_f64());
    println!("quickstart OK");
    Ok(())
}
