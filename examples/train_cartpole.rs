//! END-TO-END VALIDATION (DESIGN.md §5): train PPO on CartPole through
//! the full three-layer stack — Rust EnvPool rollouts, AOT-compiled
//! JAX/Pallas policy + train-step executed via PJRT — and log the
//! learning curve. The run is recorded in EXPERIMENTS.md.
//!
//! Also reproduces the Figure-6-style N sweep with `--sweep-n`, and the
//! Figure-7-style executor parity comparison with `--parity`.
//!
//! Run: `cargo run --release --example train_cartpole -- [--total-steps N]`

use envpool::cli::Args;
use envpool::config::{ExecutorKind, TrainConfig};
use envpool::coordinator::ppo;

fn base_cfg(args: &Args) -> TrainConfig {
    let mut cfg = TrainConfig {
        env_id: "CartPole-v1".into(),
        executor: ExecutorKind::EnvPoolSync,
        num_envs: 8,
        batch_size: 8,
        num_threads: 2,
        total_steps: 250_000,
        learning_rate: 2.5e-3,
        clip_coef: 0.2,
        ..TrainConfig::default()
    };
    cfg.total_steps = args.parse_or("total-steps", cfg.total_steps);
    cfg.seed = args.parse_or("seed", 1);
    cfg
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();

    if args.flag("sweep-n") {
        // Figure 6 analog: wall time to a given return at N ∈ {1, 8, 64}.
        println!("# Figure-6 analog: N sweep on CartPole (same step budget)");
        for n in [1usize, 8, 64] {
            let mut cfg = base_cfg(&args);
            cfg.num_envs = n;
            cfg.batch_size = n;
            let s = ppo::train(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
            println!(
                "N={n:<3} wall={:>6.1}s fps={:>7.0} final_return={:>6.1} best={:>6.1}",
                s.wall_secs,
                s.env_steps as f64 / s.wall_secs,
                s.final_return,
                s.best_return
            );
        }
        return Ok(());
    }

    if args.flag("parity") {
        // Figure 7 analog: same N, EnvPool vs baselines — sample
        // efficiency must be identical (same seeds => same curves here).
        println!("# Figure-7 analog: executor parity on CartPole (N=8)");
        for ex in [ExecutorKind::ForLoop, ExecutorKind::Subprocess, ExecutorKind::EnvPoolSync] {
            let mut cfg = base_cfg(&args);
            cfg.executor = ex;
            let s = ppo::train(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
            println!(
                "{ex:<14} wall={:>6.1}s final_return={:>6.1} episodes={}",
                s.wall_secs, s.final_return, s.episodes
            );
        }
        return Ok(());
    }

    let cfg = base_cfg(&args);
    println!("training PPO on CartPole-v1 through the full stack...");
    let (s, prof) = ppo::train_profiled(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{}", s.render());
    println!("{}", prof.render("cartpole/envpool-sync"));
    s.write_curve_csv("cartpole_curve.csv").map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("learning curve -> cartpole_curve.csv");
    // learning-curve excerpt for the log
    for p in s.curve.iter().step_by((s.curve.len() / 12).max(1)) {
        println!("  steps {:>7}  t={:>6.1}s  return {:>6.1}", p.env_steps, p.wall_secs, p.mean_return);
    }
    if s.best_return > 400.0 {
        println!("SOLVED: CartPole reached return {:.0} (>400)", s.best_return);
    }
    Ok(())
}
