//! Regenerate the paper's Figure 4: time-per-category profile of PPO
//! training (environment step / inference / training / other) under the
//! three parallelization paradigms — For-loop, Subprocess, EnvPool(sync)
//! — on the Atari-like Breakout with N=8, as in CleanRL's case study.
//!
//! Run: `cargo run --release --example profile_breakdown -- [--env Breakout-v5]`

use envpool::cli::Args;
use envpool::config::{ExecutorKind, TrainConfig};
use envpool::coordinator::ppo;
use envpool::metrics::table::Table;
use envpool::metrics::timer::Category;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let env = args.get("env", "Breakout-v5").to_string();
    let total: u64 = args.parse_or("total-steps", 8 * 128 * 4); // 4 iterations

    println!("# Figure 4 analog — CleanRL-style PPO profile on {env}, N=8\n");
    let mut table = Table::new([
        "Paradigm",
        "env_step %",
        "inference %",
        "training %",
        "other %",
        "total s",
        "ms/iter env_step",
    ]);
    for ex in [ExecutorKind::ForLoop, ExecutorKind::Subprocess, ExecutorKind::EnvPoolSync] {
        let cfg = TrainConfig {
            env_id: env.clone(),
            executor: ex,
            num_envs: 8,
            batch_size: 8,
            num_threads: 2,
            total_steps: total,
            clip_coef: 0.1,
            ..TrainConfig::default()
        };
        let (s, prof) = ppo::train_profiled(&cfg).map_err(|e| anyhow::anyhow!("{ex}: {e}"))?;
        table.row([
            format!("{ex}"),
            format!("{:.1}", 100.0 * prof.fraction(Category::EnvStep)),
            format!("{:.1}", 100.0 * prof.fraction(Category::Inference)),
            format!("{:.1}", 100.0 * prof.fraction(Category::Training)),
            format!("{:.1}", 100.0 * prof.fraction(Category::Other)),
            format!("{:.1}", s.wall_secs),
            format!("{:.1}", prof.per_iter_ms(Category::EnvStep)),
        ]);
        println!("{}", prof.render(&format!("{env} / {ex}")));
    }
    println!("{}", table.render());
    println!(
        "expected shape (paper Fig 4): env_step dominates under For-loop/Subprocess;\n\
         EnvPool shrinks the env_step share while inference+training stay put."
    );
    Ok(())
}
