//! PPO on the MuJoCo-like locomotion tasks (Ant-v4 / cheetah_run) —
//! regenerates the paper's Figures 5/6/8/10 (rl_games + CleanRL MuJoCo
//! example runs) and Figures 11/12 (Acme cheetah-run comparisons) on
//! this testbed's substitute substrate.
//!
//! Modes:
//!   (default)        one Ant-v4 run with N=64 (Table-5 hyperparameters)
//!   --compare        Fig 5/10 analog: subprocess(Ray stand-in) vs EnvPool
//!   --sweep-n        Fig 6/12 analog: N ∈ {1, 8, 64} (ant) / {8,32,128} (cheetah)
//!   --parity         Fig 8 analog: same-N sample-efficiency parity
//!   --env cheetah    switch to cheetah_run (dm_control-style)
//!   --compare-dummy  Fig 11 analog: for-loop (DummyVecEnv stand-in) vs EnvPool

use envpool::cli::Args;
use envpool::config::{ExecutorKind, TrainConfig};
use envpool::coordinator::ppo;

fn base_cfg(args: &Args) -> TrainConfig {
    let cheetah = args.get("env", "ant") == "cheetah";
    let mut cfg = TrainConfig {
        env_id: if cheetah { "cheetah_run".into() } else { "Ant-v4".into() },
        executor: ExecutorKind::EnvPoolSync,
        num_envs: if cheetah { 32 } else { 64 },
        batch_size: 0, // set below
        num_threads: 2,
        total_steps: 200_000,
        learning_rate: 3e-4,
        update_epochs: 2,
        ..TrainConfig::default()
    };
    cfg.batch_size = cfg.num_envs;
    cfg.num_envs = args.parse_or("num-envs", cfg.num_envs);
    cfg.batch_size = cfg.num_envs;
    cfg.total_steps = args.parse_or("total-steps", cfg.total_steps);
    cfg.seed = args.parse_or("seed", 1);
    cfg
}

fn run(cfg: &TrainConfig, label: &str) -> anyhow::Result<()> {
    let s = ppo::train(cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "{label:<18} N={:<4} wall={:>7.1}s fps={:>7.0} final={:>8.1} best={:>8.1} episodes={}",
        s.num_envs,
        s.wall_secs,
        s.env_steps as f64 / s.wall_secs,
        s.final_return,
        s.best_return,
        s.episodes
    );
    let path = format!("{}_{}_curve.csv", cfg.env_id.replace('-', "_"), label);
    s.write_curve_csv(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cheetah = args.get("env", "ant") == "cheetah";

    if args.flag("compare") {
        println!("# Fig 5/10 analog: subprocess (Ray stand-in) vs EnvPool, same N");
        for ex in [ExecutorKind::Subprocess, ExecutorKind::EnvPoolSync] {
            let mut cfg = base_cfg(&args);
            cfg.executor = ex;
            run(&cfg, &format!("{ex}"))?;
        }
        return Ok(());
    }
    if args.flag("compare-dummy") {
        println!("# Fig 11 analog: for-loop (DummyVecEnv stand-in) vs EnvPool, N=32");
        for ex in [ExecutorKind::ForLoop, ExecutorKind::EnvPoolSync] {
            let mut cfg = base_cfg(&args);
            cfg.executor = ex;
            run(&cfg, &format!("{ex}"))?;
        }
        return Ok(());
    }
    if args.flag("sweep-n") {
        let ns: &[usize] = if cheetah { &[8, 32, 128] } else { &[1, 8, 64] };
        println!("# Fig 6/12 analog: num_envs sweep (same step budget)");
        for &n in ns {
            let mut cfg = base_cfg(&args);
            cfg.num_envs = n;
            cfg.batch_size = n;
            run(&cfg, &format!("n{n}"))?;
        }
        return Ok(());
    }
    if args.flag("parity") {
        println!("# Fig 8 analog: executor parity (sample efficiency), same N");
        for ex in [ExecutorKind::ForLoop, ExecutorKind::EnvPoolSync] {
            let mut cfg = base_cfg(&args);
            cfg.executor = ex;
            run(&cfg, &format!("{ex}"))?;
        }
        return Ok(());
    }

    let cfg = base_cfg(&args);
    println!("training PPO on {} (N={})...", cfg.env_id, cfg.num_envs);
    let (s, prof) = ppo::train_profiled(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{}", s.render());
    println!("{}", prof.render(&format!("{}/envpool-sync", cfg.env_id)));
    for p in s.curve.iter().step_by((s.curve.len() / 12).max(1)) {
        println!("  steps {:>8}  t={:>7.1}s  return {:>8.1}", p.env_steps, p.wall_secs, p.mean_return);
    }
    Ok(())
}
