"""L1 Pallas kernel: GAE reverse scan.

The scan is sequential in T but vector-wide in B: a single-program
kernel keeps the whole [T, B] delta matrix in VMEM (T=128, B<=64 f32 is
~32 KiB — far under the 16 MiB budget) and walks t backwards with
``fori_loop``. On TPU this avoids T separate HBM round-trips; on GPU the
paper-era equivalent is a per-env thread — here the vector unit covers
the batch dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rew_ref, val_ref, last_ref, done_ref, trunc_ref, adv_ref, *, gamma, lam, T):
    def body(i, adv_next):
        t = T - 1 - i
        nonterminal = 1.0 - done_ref[t, :]
        nonboundary = nonterminal * (1.0 - trunc_ref[t, :])
        v_next = jnp.where(t == T - 1, last_ref[:], val_ref[jnp.minimum(t + 1, T - 1), :])
        delta = rew_ref[t, :] + gamma * v_next * nonterminal - val_ref[t, :]
        adv = delta + gamma * lam * nonboundary * adv_next
        adv_ref[t, :] = adv
        return adv

    jax.lax.fori_loop(0, T, body, jnp.zeros_like(last_ref[:]))


def gae(rewards, values, last_value, dones, truncs, gamma: float, lam: float):
    """Pallas GAE; same contract as ``ref.gae``."""
    T, B = rewards.shape
    adv = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, lam=lam, T=T),
        out_shape=jax.ShapeDtypeStruct((T, B), rewards.dtype),
        interpret=True,
    )(rewards, values, last_value, dones, truncs)
    return adv, adv + values
