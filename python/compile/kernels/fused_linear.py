"""L1 Pallas kernel: tiled fused ``linear + bias + activation``.

TPU thinking (DESIGN.md §Hardware-Adaptation): the (B,I)·(I,O) product is
tiled into MXU-shaped blocks; each grid cell owns a (bm, bn) output tile,
accumulates over the K dimension in VMEM, and applies the bias and
nonlinearity *before* the tile leaves VMEM — one HBM round-trip per tile
instead of matmul-write + activation-read. ``BlockSpec`` expresses the
HBM↔VMEM schedule that a CUDA version would express with threadblocks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the interpret lowering emits plain HLO (correct on any
backend) and the real-TPU performance model lives in DESIGN.md §6.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act: str, n_k: int, bk: int):
    """One (bm, bn) output tile: accumulate over k strips, fuse bias+act."""

    def body(k, acc):
        x_blk = x_ref[:, pl.dslice(k * bk, bk)]
        w_blk = w_ref[pl.dslice(k * bk, bk), :]
        return acc + x_blk @ w_blk

    acc0 = jnp.zeros(o_ref.shape, o_ref.dtype)
    bias = b_ref[...]
    y = jax.lax.fori_loop(0, n_k, body, acc0) + bias[None, :]
    if act == "tanh":
        y = jnp.tanh(y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` not exceeding `target` (keeps the grid
    exact without padding logic)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _pallas_linear(x, w, b, act: str, bm: int = 64, bn: int = 128):
    """Raw fused y = act(x @ w + b) as a Pallas call (no autodiff rule).

    Block sizes (bm, bn) target the 128-lane MXU tile; they are clamped
    to divisors of the actual dims so tiny policy layers still work.
    """
    B, I = x.shape
    I2, O = w.shape
    assert I == I2 and b.shape == (O,)
    bm = _block(B, bm)
    bn = _block(O, bn)
    # K blocking: at most 128-wide strips, must divide I.
    bk = _block(I, 128)
    n_k = I // bk

    grid = (B // bm, O // bn)
    return pl.pallas_call(
        functools.partial(_kernel, act=act, n_k=n_k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((B, O), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, I), lambda i, j: (i, 0)),
            pl.BlockSpec((I, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w, b)


# --------------------------------------------------------------------------
# custom VJP: the backward pass is three more instances of the same tiled
# kernel (dx = ĝ·Wᵀ, dW = xᵀ·ĝ, with ĝ = g ⊙ act′ computed from the saved
# output), so the whole train graph stays on the L1 kernel.


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_act(x, w, b, act: str = "tanh"):
    """Fused y = act(x @ w + b) with reverse-mode support."""
    return _pallas_linear(x, w, b, act)


def _fwd(x, w, b, act):
    y = _pallas_linear(x, w, b, act)
    return y, (x, w, y)


def _bwd(act, res, g):
    x, w, y = res
    if act == "tanh":
        g = g * (1.0 - y * y)
    elif act == "relu":
        g = g * (y > 0.0).astype(g.dtype)
    zero_i = jnp.zeros((x.shape[1],), x.dtype)
    zero_o = jnp.zeros((w.shape[1],), w.dtype)
    dx = _pallas_linear(g, w.T, zero_i, "none")       # [B,O]·[O,I]
    dw = _pallas_linear(x.T, g, zero_o, "none")       # [I,B]·[B,O]
    db = g.sum(0)
    return dx, dw, db


linear_act.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(B: int, I: int, O: int, bm: int = 64, bn: int = 128) -> int:
    """Estimated VMEM bytes per grid step (DESIGN.md §6 perf model):
    x tile + w strip + out tile, f32."""
    bm = _block(B, bm)
    bn = _block(O, bn)
    return 4 * (bm * I + I * bn + bm * bn)
