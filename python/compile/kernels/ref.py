"""Pure-jnp reference oracles for the L1 kernels.

These are the ground truth the Pallas kernels are verified against
(``python/tests/test_kernels.py``) and the fast lowering path for the
CPU-only end-to-end examples.
"""

import jax.numpy as jnp
import jax


def linear_act(x, w, b, act: str):
    """y = act(x @ w + b). Activations: 'tanh' | 'relu' | 'none'."""
    y = x @ w + b
    if act == "tanh":
        return jnp.tanh(y)
    if act == "relu":
        return jax.nn.relu(y)
    if act == "none":
        return y
    raise ValueError(f"unknown activation {act!r}")


def gae(rewards, values, last_value, dones, truncs, gamma: float, lam: float):
    """Generalized Advantage Estimation (reverse scan), time-major.

    Args:
      rewards: [T, B]
      values:  [T, B]   (value of the state the action was taken in)
      last_value: [B]   (bootstrap value of the final next-state)
      dones:   [T, B]   (true termination; kills the bootstrap)
      truncs:  [T, B]   (time-limit truncation; keeps the bootstrap value
                         but stops advantage propagation across episodes)
      gamma, lam: scalars.

    Returns (advantages [T, B], returns [T, B]).
    """
    rewards, values, last_value, dones, truncs = map(
        jnp.asarray, (rewards, values, last_value, dones, truncs)
    )

    def body(carry, x):
        rew_t, val_t, done_t, trunc_t = x
        adv_next, v_next = carry
        nonterminal = 1.0 - done_t
        # at a truncation we may bootstrap the value but must not leak
        # the *advantage* of the next episode
        nonboundary = nonterminal * (1.0 - trunc_t)
        delta = rew_t + gamma * v_next * nonterminal - val_t
        adv = delta + gamma * lam * nonboundary * adv_next
        return (adv, val_t), adv

    # `reverse=True` (rather than scanning over a reversed index array +
    # reversing the stacked output) keeps explicit `reverse` ops out of
    # the lowered HLO — xla_extension 0.5.1 mis-executes that pattern
    # (EXPERIMENTS.md §Notes).
    (_, _), advs = jax.lax.scan(
        body,
        (jnp.zeros_like(last_value), last_value),
        (rewards, values, dones, truncs),
        reverse=True,
    )
    return advs, advs + values
