"""L1 Pallas kernels + pure-jnp reference oracles.

`use_pallas(True)` routes the L2 model through the Pallas kernels
(interpret=True so the lowered HLO runs on any PJRT backend); the default
jnp path is mathematically identical (verified by `python/tests/`) and
lowers to leaner HLO for the CPU-only e2e training examples. Both paths
lower into the same AOT artifact pipeline.
"""

_USE_PALLAS = False


def use_pallas(on: bool) -> None:
    """Globally select the Pallas kernel path for model building."""
    global _USE_PALLAS
    _USE_PALLAS = bool(on)


def pallas_enabled() -> bool:
    return _USE_PALLAS
