"""L2: JAX actor-critic model + PPO update, built on the L1 kernels.

Everything here is *build-time only*: `aot.py` lowers these functions to
HLO text once; the Rust coordinator executes the artifacts via PJRT with
Python nowhere on the request path.

Parameters are a flat, ordered list of arrays (the AOT calling
convention — see `param_spec`):

    [W0, b0, W1, b1, W_pi, b_pi, W_v, b_v]            (discrete)
    [W0, b0, W1, b1, W_mu, b_mu, log_std, W_v, b_v]   (continuous)

PPO follows CleanRL / the original paper (clipped surrogate, value-loss
clipping optional off, entropy bonus, global-norm clipping, Adam).
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import kernels
from .kernels import fused_linear, ref


def _linear(x, w, b, act):
    if kernels.pallas_enabled():
        return fused_linear.linear_act(x, w, b, act)
    return ref.linear_act(x, w, b, act)


# --------------------------------------------------------------------------
# parameters


def param_spec(obs_dim: int, act_dim: int, hidden: int, continuous: bool):
    """Ordered (name, shape) list defining the AOT calling convention."""
    spec = [
        ("w0", (obs_dim, hidden)),
        ("b0", (hidden,)),
        ("w1", (hidden, hidden)),
        ("b1", (hidden,)),
    ]
    if continuous:
        spec += [
            ("w_mu", (hidden, act_dim)),
            ("b_mu", (act_dim,)),
            ("log_std", (act_dim,)),
        ]
    else:
        spec += [("w_pi", (hidden, act_dim)), ("b_pi", (act_dim,))]
    spec += [("w_v", (hidden, 1)), ("b_v", (1,))]
    return spec


def _orthogonal(rng, shape, gain):
    a = rng.standard_normal(shape).astype(np.float32)
    q, r = np.linalg.qr(a if shape[0] >= shape[1] else a.T)
    q = q * np.sign(np.diag(r))
    if shape[0] < shape[1]:
        q = q.T
    return (gain * q[: shape[0], : shape[1]]).astype(np.float32)


def init_params(obs_dim, act_dim, hidden, continuous, seed=0):
    """CleanRL-style orthogonal init (gain sqrt(2); 0.01 policy head,
    1.0 value head)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in param_spec(obs_dim, act_dim, hidden, continuous):
        if name.startswith("w"):
            if name in ("w_pi", "w_mu"):
                gain = 0.01
            elif name == "w_v":
                gain = 1.0
            else:
                gain = float(np.sqrt(2.0))
            out.append(_orthogonal(rng, shape, gain))
        elif name == "log_std":
            out.append(np.zeros(shape, np.float32))
        else:
            out.append(np.zeros(shape, np.float32))
    return out


# --------------------------------------------------------------------------
# forward passes


def policy_forward(params, obs, continuous: bool):
    """Returns (dist, value[B]): dist is logits [B, A] (discrete) or
    (mu [B, A], log_std [A]) (continuous)."""
    if continuous:
        w0, b0, w1, b1, w_mu, b_mu, log_std, w_v, b_v = params
    else:
        w0, b0, w1, b1, w_pi, b_pi, w_v, b_v = params
    h = _linear(obs, w0, b0, "tanh")
    h = _linear(h, w1, b1, "tanh")
    v = (_linear(h, w_v, b_v, "none"))[:, 0]
    if continuous:
        mu = _linear(h, w_mu, b_mu, "none")
        return (mu, log_std), v
    logits = _linear(h, w_pi, b_pi, "none")
    return logits, v


def policy_outputs(params, obs, continuous: bool):
    """The AOT `policy` entry: flat tuple of arrays.

    discrete:   (logits [B, A], value [B])
    continuous: (mu [B, A], log_std_b [B, A], value [B])
    """
    dist, v = policy_forward(params, obs, continuous)
    if continuous:
        mu, log_std = dist
        return mu, jnp.broadcast_to(log_std[None, :], mu.shape), v
    return dist, v


def log_prob(dist, actions, continuous: bool):
    """Log-probability and entropy under the policy distribution."""
    if continuous:
        mu, log_std = dist
        std = jnp.exp(log_std)
        lp = -0.5 * (((actions - mu) / std) ** 2 + 2 * log_std + jnp.log(2 * jnp.pi))
        ent = (log_std + 0.5 * jnp.log(2 * jnp.pi * jnp.e)) * jnp.ones_like(mu)
        return lp.sum(-1), ent.sum(-1)
    logits = dist
    logp_all = jax.nn.log_softmax(logits)
    lp = jnp.take_along_axis(logp_all, actions.astype(jnp.int32)[:, None], axis=1)[:, 0]
    p = jnp.exp(logp_all)
    ent = -(p * logp_all).sum(-1)
    return lp, ent


# --------------------------------------------------------------------------
# PPO update (one minibatch) + Adam


def ppo_loss(params, mb, continuous, clip_coef, vf_coef, ent_coef, norm_adv=True):
    obs, actions, old_logp, adv, ret = mb
    dist, value = policy_forward(params, obs, continuous)
    logp, entropy = log_prob(dist, actions, continuous)
    logratio = logp - old_logp
    ratio = jnp.exp(logratio)
    if norm_adv:
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg1 = -adv * ratio
    pg2 = -adv * jnp.clip(ratio, 1.0 - clip_coef, 1.0 + clip_coef)
    pg_loss = jnp.maximum(pg1, pg2).mean()
    v_loss = 0.5 * ((value - ret) ** 2).mean()
    ent = entropy.mean()
    loss = pg_loss + vf_coef * v_loss - ent_coef * ent
    approx_kl = ((ratio - 1.0) - logratio).mean()
    return loss, (pg_loss, v_loss, ent, approx_kl)


def adam_init(params):
    return [jnp.zeros_like(p) for p in params], [jnp.zeros_like(p) for p in params]


def train_step(
    params,
    m,
    v,
    t,
    mb,
    lr,
    continuous,
    clip_coef=0.2,
    vf_coef=0.5,
    ent_coef=0.0,
    max_grad_norm=0.5,
    beta1=0.9,
    beta2=0.999,
    eps=1e-5,
):
    """One PPO minibatch update with global-norm clipping + Adam.

    The AOT `train` entry. `t` is the (f32 scalar) Adam step count;
    `lr` a f32 scalar so Rust can anneal it without recompiling.
    Returns (params', m', v', t', loss, pg_loss, v_loss, entropy, kl).
    """
    (loss, (pg_loss, v_loss, ent, kl)), grads = jax.value_and_grad(
        ppo_loss, has_aux=True
    )(params, mb, continuous, clip_coef, vf_coef, ent_coef)

    gnorm = jnp.sqrt(sum((g * g).sum() for g in grads))
    scale = jnp.minimum(1.0, max_grad_norm / (gnorm + 1e-8))
    grads = [g * scale for g in grads]

    t2 = t + 1.0
    bc1 = 1.0 - beta1**t2
    bc2 = 1.0 - beta2**t2
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi2 = beta1 * mi + (1 - beta1) * g
        vi2 = beta2 * vi + (1 - beta2) * g * g
        p2 = p - lr * (mi2 / bc1) / (jnp.sqrt(vi2 / bc2) + eps)
        new_params.append(p2)
        new_m.append(mi2)
        new_v.append(vi2)
    return new_params, new_m, new_v, t2, loss, pg_loss, v_loss, ent, kl


# --------------------------------------------------------------------------
# GAE entry


def gae_outputs(rewards, values, last_value, dones, truncs, gamma, lam):
    """The AOT `gae` entry: dispatches to the Pallas kernel when enabled."""
    if kernels.pallas_enabled():
        from .kernels import gae as gae_k

        return gae_k.gae(rewards, values, last_value, dones, truncs, gamma, lam)
    return ref.gae(rewards, values, last_value, dones, truncs, gamma, lam)
