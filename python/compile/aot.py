"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts + manifest.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Per training configuration three entries are lowered:

  policy_<name>.hlo.txt  (params..., obs[B,D])          -> dist + value
  train_<name>.hlo.txt   (params..., m..., v..., t, minibatch..., lr)
                                                         -> updated state
  gae_<name>.hlo.txt     (rew, val, last_val, done, trunc) -> (adv, ret)

``manifest.json`` records every shape and the parameter order so the
Rust runtime (rust/src/runtime/artifact.rs) can drive the executables
without any Python at run time. Initial parameters are exported to
``params_<name>.bin`` (raw little-endian f32, concatenated in spec
order).
"""

import argparse
import functools
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import kernels, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# training configurations (paper Appendix F hyperparameters)

CONFIGS = {}


def _cfg(name, task, obs_dim, act_dim, continuous, hidden, num_envs, num_steps,
         num_minibatches, clip=0.2, vf=0.5, ent=0.01, mgn=0.5,
         gamma=0.99, lam=0.95):
    CONFIGS[name] = dict(
        task=task, obs_dim=obs_dim, act_dim=act_dim, continuous=continuous,
        hidden=hidden, num_envs=num_envs, num_steps=num_steps,
        num_minibatches=num_minibatches, clip=clip, vf=vf, ent=ent, mgn=mgn,
        gamma=gamma, lam=lam,
    )


# CartPole quickstart/e2e (Figure 6-style N sweep: 1 / 8 / 64)
_cfg("cartpole_n1", "CartPole-v1", 4, 2, False, 64, 1, 128, 4, clip=0.2)
_cfg("cartpole_n8", "CartPole-v1", 4, 2, False, 64, 8, 128, 4, clip=0.2)
_cfg("cartpole_n64", "CartPole-v1", 4, 2, False, 64, 64, 128, 4, clip=0.2)
# Atari-like Pong: Table 3 hyperparameters (N=8), tuned variant N=16
_cfg("pong_n8", "Pong-v5", 4 * 84 * 84, 6, False, 256, 8, 128, 4, clip=0.1)
_cfg("pong_n16", "Pong-v5", 4 * 84 * 84, 6, False, 256, 16, 64, 4, clip=0.1)
# Breakout for the Figure-4 profile
_cfg("breakout_n8", "Breakout-v5", 4 * 84 * 84, 4, False, 256, 8, 128, 4, clip=0.1)
# MuJoCo-like: Table 5 hyperparameters (N=64), sweep variants
_cfg("ant_n1", "Ant-v4", 21, 8, True, 64, 1, 128, 4, ent=0.0)
_cfg("ant_n8", "Ant-v4", 21, 8, True, 64, 8, 64, 4, ent=0.0)
_cfg("ant_n64", "Ant-v4", 21, 8, True, 64, 64, 64, 4, ent=0.0)
_cfg("hopper_n8", "Hopper-v4", 11, 3, True, 64, 8, 64, 4, ent=0.0)
# dm_control cheetah run for the Acme figures (11: N=32; 12: sweep)
_cfg("cheetah_n8", "cheetah_run", 17, 6, True, 64, 8, 64, 4, ent=0.0)
_cfg("cheetah_n32", "cheetah_run", 17, 6, True, 64, 32, 64, 4, ent=0.0)
_cfg("cheetah_n128", "cheetah_run", 17, 6, True, 64, 128, 64, 4, ent=0.0)
# Pendulum: smallest continuous task, used by the runtime smoke tests
_cfg("pendulum_n4", "Pendulum-v1", 3, 1, True, 64, 4, 64, 4, ent=0.0)


def lower_config(name, cfg, out_dir, use_pallas):
    kernels.use_pallas(use_pallas)
    obs_dim, act_dim = cfg["obs_dim"], cfg["act_dim"]
    cont, hidden = cfg["continuous"], cfg["hidden"]
    N, T, nmb = cfg["num_envs"], cfg["num_steps"], cfg["num_minibatches"]
    mb = (N * T) // nmb

    spec = model.param_spec(obs_dim, act_dim, hidden, cont)
    p_shapes = [s for _, s in spec]
    f32 = jnp.float32

    def sds(shape):
        return jax.ShapeDtypeStruct(shape, f32)

    params_s = [sds(s) for s in p_shapes]

    # --- policy entry ---
    def policy_fn(*args):
        params = list(args[:-1])
        obs = args[-1]
        return model.policy_outputs(params, obs, cont)

    pol_lowered = jax.jit(policy_fn).lower(*params_s, sds((N, obs_dim)))
    pol_file = f"policy_{name}.hlo.txt"
    with open(os.path.join(out_dir, pol_file), "w") as f:
        f.write(to_hlo_text(pol_lowered))

    # --- train entry ---
    act_shape = (mb, act_dim) if cont else (mb,)

    def train_fn(*args):
        P = len(p_shapes)
        params = list(args[0:P])
        m = list(args[P:2 * P])
        v = list(args[2 * P:3 * P])
        t = args[3 * P]
        obs, actions, logp, adv, ret, lr = args[3 * P + 1:]
        out = model.train_step(
            params, m, v, t, (obs, actions, logp, adv, ret), lr, cont,
            clip_coef=cfg["clip"], vf_coef=cfg["vf"], ent_coef=cfg["ent"],
            max_grad_norm=cfg["mgn"],
        )
        new_params, new_m, new_v, t2, loss, pg, vl, ent, kl = out
        return (*new_params, *new_m, *new_v, t2, loss, pg, vl, ent, kl)

    train_args = (
        params_s + params_s + params_s
        + [sds(())]
        + [sds((mb, obs_dim)), sds(act_shape), sds((mb,)), sds((mb,)), sds((mb,)), sds(())]
    )
    # donate params/opt state buffers: they are consumed every call
    ndon = 3 * len(p_shapes) + 1
    train_lowered = jax.jit(
        train_fn, donate_argnums=tuple(range(ndon))
    ).lower(*train_args)
    train_file = f"train_{name}.hlo.txt"
    with open(os.path.join(out_dir, train_file), "w") as f:
        f.write(to_hlo_text(train_lowered))

    # --- gae entry ---
    def gae_fn(rew, val, last, done, trunc):
        return model.gae_outputs(rew, val, last, done, trunc, cfg["gamma"], cfg["lam"])

    gae_lowered = jax.jit(gae_fn).lower(
        sds((T, N)), sds((T, N)), sds((N,)), sds((T, N)), sds((T, N))
    )
    gae_file = f"gae_{name}.hlo.txt"
    with open(os.path.join(out_dir, gae_file), "w") as f:
        f.write(to_hlo_text(gae_lowered))

    # --- initial parameters ---
    params0 = model.init_params(obs_dim, act_dim, hidden, cont, seed=0)
    params_file = f"params_{name}.bin"
    with open(os.path.join(out_dir, params_file), "wb") as f:
        for p in params0:
            f.write(struct.pack(f"<{p.size}f", *np.asarray(p, np.float32).ravel()))

    return dict(
        task=cfg["task"], obs_dim=obs_dim, act_dim=act_dim,
        continuous=cont, hidden=hidden, num_envs=N, num_steps=T,
        num_minibatches=nmb, minibatch_size=mb,
        gamma=cfg["gamma"], lam=cfg["lam"],
        params=[[n, list(s)] for n, s in spec],
        files=dict(policy=pol_file, train=train_file, gae=gae_file,
                   params=params_file),
        pallas=use_pallas,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--pallas", action="store_true",
                    help="lower through the Pallas kernels (interpret=True)")
    ap.add_argument("--configs", default="",
                    help="comma-separated subset (default: all)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = [n for n in args.configs.split(",") if n] or list(CONFIGS)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"configs": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name in names:
        cfg = CONFIGS[name]
        key = f"{name}_pallas" if args.pallas else name
        print(f"lowering {key} (task={cfg['task']}, N={cfg['num_envs']})...")
        manifest["configs"][key] = lower_config(key, cfg, args.out_dir, args.pallas)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)

    # Flat `key = value` mirror for the Rust runtime (no JSON dep there).
    flat_path = os.path.join(args.out_dir, "manifest.txt")
    with open(flat_path, "w") as f:
        f.write("# generated by compile.aot — flat mirror of manifest.json\n")
        f.write(f"configs = {','.join(sorted(manifest['configs']))}\n")
        for key, e in sorted(manifest["configs"].items()):
            for field in ("task", "obs_dim", "act_dim", "hidden", "num_envs",
                          "num_steps", "num_minibatches", "minibatch_size",
                          "gamma", "lam"):
                f.write(f"{key}.{field} = {e[field]}\n")
            f.write(f"{key}.continuous = {str(e['continuous']).lower()}\n")
            params = ",".join(f"{n}:{'x'.join(map(str, s)) if s else '1'}"
                              for n, s in e["params"])
            f.write(f"{key}.params = {params}\n")
            for fk, fv in e["files"].items():
                f.write(f"{key}.files.{fk} = {fv}\n")
    print(f"wrote {manifest_path} + manifest.txt ({len(manifest['configs'])} configs)")


if __name__ == "__main__":
    main()
