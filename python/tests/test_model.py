"""L2 correctness: policy shapes, log-prob math, PPO update behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import model


def _setup(continuous, obs_dim=6, act_dim=3, hidden=32, B=16):
    params = [jnp.asarray(p) for p in model.init_params(obs_dim, act_dim, hidden, continuous, 1)]
    rng = np.random.default_rng(0)
    obs = jnp.asarray(rng.standard_normal((B, obs_dim)).astype(np.float32))
    return params, obs


def test_policy_shapes_discrete():
    params, obs = _setup(False)
    logits, v = model.policy_outputs(params, obs, False)
    assert logits.shape == (16, 3)
    assert v.shape == (16,)


def test_policy_shapes_continuous():
    params, obs = _setup(True)
    mu, log_std, v = model.policy_outputs(params, obs, True)
    assert mu.shape == (16, 3)
    assert log_std.shape == (16, 3)
    assert v.shape == (16,)


def test_discrete_logprob_sums_to_one():
    params, obs = _setup(False)
    logits, _ = model.policy_forward(params, obs, False)
    lps = []
    for a in range(3):
        lp, _ = model.log_prob(logits, jnp.full((16,), a, jnp.float32), False)
        lps.append(np.asarray(lp))
    total = np.exp(np.stack(lps)).sum(0)
    assert_allclose(total, np.ones(16), rtol=1e-5)


def test_gaussian_logprob_matches_closed_form():
    params, obs = _setup(True)
    (mu, log_std), _ = model.policy_forward(params, obs, True)
    a = mu + 0.3  # fixed offset action
    lp, _ = model.log_prob((mu, log_std), a, True)
    std = np.exp(np.asarray(log_std))
    want = (-0.5 * ((0.3 / std) ** 2) - np.asarray(log_std) - 0.5 * np.log(2 * np.pi)).sum(-1)
    want = np.broadcast_to(want, lp.shape)
    assert_allclose(np.asarray(lp), want, rtol=1e-4)


def test_entropy_increases_with_std():
    params, obs = _setup(True)
    (mu, log_std), _ = model.policy_forward(params, obs, True)
    _, ent_small = model.log_prob((mu, log_std), mu, True)
    _, ent_big = model.log_prob((mu, log_std + 1.0), mu, True)
    assert np.all(np.asarray(ent_big) > np.asarray(ent_small))


def _fake_minibatch(continuous, params, obs):
    dist, v = model.policy_forward(params, obs, continuous)
    if continuous:
        mu, log_std = dist
        actions = mu + 0.1
    else:
        actions = jnp.argmax(dist, axis=-1).astype(jnp.float32)
    logp, _ = model.log_prob(dist, actions, continuous)
    adv = jnp.asarray(np.random.default_rng(1).standard_normal(obs.shape[0]).astype(np.float32))
    ret = v + adv
    return (obs, actions, logp, adv, ret)


def test_train_step_reduces_loss_on_repeated_batch():
    for continuous in (False, True):
        params, obs = _setup(continuous)
        m, v = model.adam_init(params)
        mb = _fake_minibatch(continuous, params, obs)
        t = jnp.asarray(0.0)
        losses = []
        for _ in range(20):
            params, m, v, t, loss, *_stats = model.train_step(
                params, m, v, t, mb, jnp.asarray(3e-3), continuous
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"continuous={continuous}: {losses[0]} -> {losses[-1]}"


def test_grad_norm_clipping_bounds_update():
    params, obs = _setup(False)
    m, v = model.adam_init(params)
    # gigantic advantages force large raw grads; clipping keeps the
    # parameter delta bounded by ~lr-scale
    obs_, actions, logp, adv, ret = _fake_minibatch(False, params, obs)
    mb = (obs_, actions, logp, adv * 1e6, ret * 1e6)
    new_params, *_rest = model.train_step(params, m, v, jnp.asarray(0.0), mb,
                                          jnp.asarray(1e-3), False, max_grad_norm=0.5)
    deltas = [float(jnp.abs(p2 - p1).max()) for p1, p2 in zip(params, new_params)]
    assert max(deltas) < 0.1, f"clipped update too large: {deltas}"


def test_param_spec_ordering_stable():
    spec_d = model.param_spec(4, 2, 64, False)
    assert [n for n, _ in spec_d] == ["w0", "b0", "w1", "b1", "w_pi", "b_pi", "w_v", "b_v"]
    spec_c = model.param_spec(4, 2, 64, True)
    assert [n for n, _ in spec_c] == [
        "w0", "b0", "w1", "b1", "w_mu", "b_mu", "log_std", "w_v", "b_v",
    ]


def test_pallas_and_ref_model_agree():
    # whole-model parity: the policy through Pallas kernels equals the
    # jnp path (the guarantee that lets artifacts use either lowering)
    from compile import kernels

    params, obs = _setup(False, obs_dim=8, act_dim=4, hidden=64, B=32)
    kernels.use_pallas(False)
    logits_a, v_a = model.policy_outputs(params, obs, False)
    kernels.use_pallas(True)
    try:
        logits_b, v_b = model.policy_outputs(params, obs, False)
    finally:
        kernels.use_pallas(False)
    assert_allclose(np.asarray(logits_a), np.asarray(logits_b), rtol=2e-5, atol=2e-5)
    assert_allclose(np.asarray(v_a), np.asarray(v_b), rtol=2e-5, atol=2e-5)
