"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose against ref — the CORE
correctness signal for the kernel layer.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fused_linear, gae as gae_k, ref

SETTINGS = dict(max_examples=10, deadline=None)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 96),
    i=st.integers(1, 160),
    o=st.integers(1, 192),
    act=st.sampled_from(["tanh", "relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(b, i, o, act, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, i)).astype(np.float32)
    w = rng.standard_normal((i, o)).astype(np.float32) * 0.1
    bias = rng.standard_normal(o).astype(np.float32)
    got = fused_linear.linear_act(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), act)
    want = ref.linear_act(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), act)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_fused_linear_mxu_shaped_block():
    # The MXU-aligned case from DESIGN.md §6: blocks divide exactly.
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 512)).astype(np.float32)
    w = rng.standard_normal((512, 256)).astype(np.float32) * 0.05
    b = np.zeros(256, np.float32)
    got = fused_linear.linear_act(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), "tanh")
    want = ref.linear_act(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), "tanh")
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_vmem_footprint_under_budget():
    # the kernel working set must fit VMEM (16 MiB) at the design point
    assert fused_linear.vmem_footprint_bytes(64, 512, 256) < 16 * 2**20


@settings(**SETTINGS)
@given(
    t=st.integers(1, 64),
    b=st.integers(1, 16),
    gamma=st.floats(0.9, 0.999),
    lam=st.floats(0.8, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gae_kernel_matches_ref(t, b, gamma, lam, seed):
    rng = np.random.default_rng(seed)
    rew = rng.standard_normal((t, b)).astype(np.float32)
    val = rng.standard_normal((t, b)).astype(np.float32)
    last = rng.standard_normal(b).astype(np.float32)
    done = (rng.random((t, b)) < 0.1).astype(np.float32)
    trunc = ((rng.random((t, b)) < 0.05) * (1 - done)).astype(np.float32)
    a1, r1 = gae_k.gae(rew, val, last, done, trunc, gamma, lam)
    a2, r2 = ref.gae(rew, val, last, done, trunc, gamma, lam)
    assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-4, atol=1e-4)


def test_gae_ref_hand_computed():
    # Tiny case worked by hand: T=2, B=1, no dones.
    gamma, lam = 0.5, 0.5
    rew = np.array([[1.0], [1.0]], np.float32)
    val = np.array([[0.0], [0.0]], np.float32)
    last = np.array([2.0], np.float32)
    z = np.zeros((2, 1), np.float32)
    adv, ret = ref.gae(rew, val, last, z, z, gamma, lam)
    # t=1: delta = 1 + .5*2 - 0 = 2 ; adv1 = 2
    # t=0: delta = 1 + .5*0 - 0 = 1 ; adv0 = 1 + .25*2 = 1.5
    assert_allclose(np.asarray(adv), [[1.5], [2.0]], rtol=1e-6)
    assert_allclose(np.asarray(ret), [[1.5], [2.0]], rtol=1e-6)


def test_gae_done_cuts_bootstrap():
    gamma, lam = 0.99, 0.95
    rew = np.array([[1.0], [1.0]], np.float32)
    val = np.array([[5.0], [5.0]], np.float32)
    last = np.array([100.0], np.float32)
    done = np.array([[0.0], [1.0]], np.float32)  # terminal at t=1
    z = np.zeros((2, 1), np.float32)
    adv, _ = ref.gae(rew, val, last, done, z, gamma, lam)
    # t=1 terminal: delta = 1 - 5 = -4 (no bootstrap of 100)
    assert_allclose(np.asarray(adv)[1], [-4.0], rtol=1e-5)


def test_fused_linear_gradients_match_ref():
    import jax

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 12)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((12, 6)).astype(np.float32) * 0.2)
    b = jnp.asarray(rng.standard_normal(6).astype(np.float32))

    def loss_pallas(x, w, b):
        return (fused_linear.linear_act(x, w, b, "tanh") ** 2).sum()

    def loss_ref(x, w, b):
        return (ref.linear_act(x, w, b, "tanh") ** 2).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4, atol=1e-4)
