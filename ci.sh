#!/usr/bin/env bash
# Tier-1 CI gate: format, lint, build, test. Mirrors
# .github/workflows/ci.yml so the same command works locally.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (deny warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "CI OK"
