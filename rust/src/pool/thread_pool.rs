//! The worker ThreadPool (paper §3.3): a fixed set of `std::thread`
//! workers that pull env-step tasks from the ActionBufferQueue, execute
//! them, and commit results straight into the StateBufferQueue. Threads
//! can be pinned to cores to cut context switches and improve cache
//! residency, as the paper recommends.

use super::action_queue::ActionBufferQueue;
use super::state_queue::StateBufferQueue;
use crate::envs::env::Env;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A task for a worker.
#[derive(Debug, Clone)]
pub enum Task {
    /// Step env `env_id` with the action currently in its action slot.
    Step { env_id: u32 },
    /// Reset env `env_id` and report its initial observation.
    Reset { env_id: u32 },
    /// Terminate the receiving worker.
    Shutdown,
}

/// Per-environment state owned by the pool; each env is touched by at
/// most one worker at a time (protocol: an env has at most one
/// outstanding action), so the mutexes below are uncontended.
pub struct EnvSlot {
    pub env: Mutex<Box<dyn Env>>,
    /// Pending action payload for this env (written by `send`).
    pub action: Mutex<Vec<f32>>,
    /// Env finished and must be reset on its next step (EnvPool-style
    /// auto-reset: the reset observation is returned for the next action).
    pub needs_reset: Mutex<bool>,
}

/// Worker pool. Owns the join handles; dropping shuts workers down.
pub struct ThreadPool {
    handles: Vec<JoinHandle<()>>,
    queue: Arc<ActionBufferQueue<Task>>,
    /// Total env steps executed (throughput accounting).
    pub steps: Arc<AtomicU64>,
}

impl ThreadPool {
    /// Spawn `num_threads` workers over the shared env table / queues.
    /// `pin_cores` pins worker `i` to core `i % cores` (paper §3.3).
    pub fn spawn(
        num_threads: usize,
        envs: Arc<Vec<EnvSlot>>,
        queue: Arc<ActionBufferQueue<Task>>,
        states: Arc<StateBufferQueue>,
        pin_cores: bool,
    ) -> ThreadPool {
        let steps = Arc::new(AtomicU64::new(0));
        let handles = (0..num_threads)
            .map(|i| {
                let envs = envs.clone();
                let queue = queue.clone();
                let states = states.clone();
                let steps = steps.clone();
                std::thread::Builder::new()
                    .name(format!("envpool-worker-{i}"))
                    .spawn(move || {
                        if pin_cores {
                            pin_to_core(i);
                        }
                        worker_loop(&envs, &queue, &states, &steps);
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { handles, queue, steps }
    }

    pub fn num_threads(&self) -> usize {
        self.handles.len()
    }

    /// Ask all workers to exit and join them.
    pub fn shutdown(&mut self) {
        for _ in 0..self.handles.len() {
            self.queue.blocking_enqueue(Task::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown();
        }
    }
}

fn worker_loop(
    envs: &[EnvSlot],
    queue: &ActionBufferQueue<Task>,
    states: &StateBufferQueue,
    steps: &AtomicU64,
) {
    // A panic below (env step/reset) would leave this worker's round
    // forever incomplete; poison the queue so the consumer and the other
    // workers error out instead of spinning.
    let _poison = states.poison_guard();
    loop {
        match queue.dequeue() {
            Task::Shutdown => return,
            Task::Reset { env_id } => {
                let slot = &envs[env_id as usize];
                let mut env = slot.env.lock().unwrap();
                *slot.needs_reset.lock().unwrap() = false;
                // None = queue closed mid-teardown: stop producing.
                let Some(t) = states.acquire() else { return };
                // Scenario pools run the queue at the union observation
                // width: hand the env its own row prefix and zero the
                // padding (a no-op for homogeneous pools).
                let d = env.spec().obs_dim();
                states.write(t, env_id, 0.0, false, false, |obs| {
                    obs[d..].fill(0.0);
                    env.reset(&mut obs[..d]);
                });
            }
            Task::Step { env_id } => {
                let slot = &envs[env_id as usize];
                let mut env = slot.env.lock().unwrap();
                let action = slot.action.lock().unwrap();
                let mut needs_reset = slot.needs_reset.lock().unwrap();
                let Some(t) = states.acquire() else { return };
                let d = env.spec().obs_dim();
                if *needs_reset {
                    // EnvPool auto-reset: the action after a terminal
                    // transition triggers reset; its "step" result is the
                    // initial observation with zero reward.
                    *needs_reset = false;
                    states.write(t, env_id, 0.0, false, false, |obs| {
                        obs[d..].fill(0.0);
                        env.reset(&mut obs[..d]);
                    });
                } else {
                    let mut finished = false;
                    states.write_with(t, env_id, |obs| {
                        obs[d..].fill(0.0);
                        let r = env.step(&action, &mut obs[..d]);
                        finished = r.finished();
                        (r.reward, r.done, r.truncated)
                    });
                    if finished {
                        *needs_reset = true;
                    }
                }
                steps.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Pin the calling thread to a core (best effort, Linux only). The
/// vendored crate set has no `libc`, so the one syscall wrapper needed is
/// declared directly against the C library every linux-gnu binary links.
#[cfg(target_os = "linux")]
pub fn pin_to_core(idx: usize) {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // cpu_set_t is a 1024-bit mask on glibc; clamp so >1024-core hosts
    // degrade to imperfect pinning instead of an out-of-bounds panic.
    let core = (idx % cores).min(1023);
    // Mirror glibc's CPU_SET: bit (cpu % bits) of unsigned-long word
    // (cpu / bits) — word-wise, so the layout is endian-correct.
    let mut mask = [0u64; 16];
    mask[core / 64] |= 1u64 << (core % 64);
    // Best effort: failure just means no pinning.
    let _ = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
}

/// Pin the calling thread to a core (no-op off Linux).
#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_idx: usize) {}
