//! EnvPool core — the paper's system contribution (§3).
//!
//! Three components, optimized exactly as the paper describes:
//!
//! - [`ActionBufferQueue`] — a lock-free circular buffer of capacity `2N`
//!   with two atomic counters and a semaphore, caching actions from
//!   `send` until worker threads consume them (paper Appendix D.1).
//! - [`ThreadPool`] — a fixed set of worker threads (optionally pinned to
//!   cores) that pop actions, step the owning environment, and write the
//!   result straight into the state queue (paper §3.3).
//! - [`StateBufferQueue`] — a circular queue of pre-allocated *blocks*,
//!   each holding `batch_size` transition slots. A worker acquires a slot
//!   with one atomic fetch-add and writes observation bytes in place;
//!   when the write-count hits `batch_size` the block is handed to the
//!   consumer whole — zero batching copies (paper Appendix D.2).
//!
//! Synchronous vs asynchronous execution (paper §3.2) falls out of the
//! `num_envs` / `batch_size` pair: `M == N` makes consecutive
//! `send`/`recv` equivalent to a synchronous vectorized step; `M < N`
//! waits only for the fastest `M` environments, hiding the long tail.
//!
//! For cheap environments, per-env task dispatch itself dominates; the
//! [`ChunkedThreadPool`] (`ExecMode::Vectorized`) amortizes it by making
//! each task a chunk of `ceil(N / num_threads)` envs stepped by a
//! struct-of-arrays kernel ([`crate::envs::vector`]) that writes
//! observations directly into state-queue slots.

pub mod sem;
pub mod action_queue;
pub mod state_queue;
pub mod thread_pool;
pub mod chunked;
pub mod batch;
pub mod envpool;
pub mod hetero;
pub mod lease;
pub mod numa;

pub use action_queue::ActionBufferQueue;
pub use batch::BatchedTransition;
pub use chunked::ChunkedThreadPool;
pub use envpool::{EnvPool, ExecMode, PoolConfig};
pub use hetero::{GroupedVecEnv, VecLaneEnv};
pub use lease::{LeaseConfig, LeaseEvent, LeaseId, LeasePool, Wave};
pub use numa::NumaPool;
pub use state_queue::StateBufferQueue;
pub use thread_pool::ThreadPool;
