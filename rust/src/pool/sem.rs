//! A counting semaphore (std has none): Mutex<count> + Condvar. Used to
//! park worker threads when the action queue is empty and consumers when
//! no block is ready — exactly the two waits the paper's queues need.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Counting semaphore.
pub struct Semaphore {
    count: Mutex<isize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(initial: isize) -> Self {
        Semaphore { count: Mutex::new(initial), cv: Condvar::new() }
    }

    /// Release `n` permits.
    pub fn post_n(&self, n: isize) {
        let mut c = self.count.lock().unwrap();
        *c += n;
        if n == 1 {
            self.cv.notify_one();
        } else {
            self.cv.notify_all();
        }
    }

    /// Release one permit.
    pub fn post(&self) {
        self.post_n(1);
    }

    /// Acquire one permit, blocking.
    pub fn wait(&self) {
        let mut c = self.count.lock().unwrap();
        while *c <= 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    /// Acquire one permit with a timeout; returns false on timeout.
    pub fn wait_timeout(&self, d: Duration) -> bool {
        let deadline = std::time::Instant::now() + d;
        let mut c = self.count.lock().unwrap();
        while *c <= 0 {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, res) = self.cv.wait_timeout(c, deadline - now).unwrap();
            c = guard;
            if res.timed_out() && *c <= 0 {
                return false;
            }
        }
        *c -= 1;
        true
    }

    /// Current permit count (diagnostics only; racy by nature).
    pub fn approx_count(&self) -> isize {
        *self.count.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn post_then_wait() {
        let s = Semaphore::new(0);
        s.post();
        s.wait(); // must not block
        assert_eq!(s.approx_count(), 0);
    }

    #[test]
    fn wait_timeout_expires() {
        let s = Semaphore::new(0);
        assert!(!s.wait_timeout(Duration::from_millis(10)));
        s.post();
        assert!(s.wait_timeout(Duration::from_millis(10)));
    }

    #[test]
    fn cross_thread_handoff() {
        let s = Arc::new(Semaphore::new(0));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            for _ in 0..100 {
                s2.wait();
            }
        });
        for _ in 0..100 {
            s.post();
        }
        h.join().unwrap();
    }

    #[test]
    fn post_n_releases_many() {
        let s = Arc::new(Semaphore::new(0));
        let mut handles = vec![];
        for _ in 0..4 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || s2.wait()));
        }
        s.post_n(4);
        for h in handles {
            h.join().unwrap();
        }
    }
}
