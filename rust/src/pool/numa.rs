//! NUMA-sharded pool (paper "EnvPool (numa+async)"): one independent
//! EnvPool per NUMA node, each with its own ActionBufferQueue /
//! StateBufferQueue / workers, eliminating cross-node queue contention.
//!
//! On this single-socket container the shards are logical (no node
//! binding is possible), but the structure — and the contention-isolation
//! benefit it measures in `benches/table1_throughput` — is the same.

use super::batch::BatchedTransition;
use super::envpool::{EnvPool, PoolConfig};
use crate::envs::spec::EnvSpec;
use crate::Result;

/// A set of independent EnvPool shards addressed through one facade.
/// Env ids are global: shard `k` owns ids `[k*per, (k+1)*per)`.
pub struct NumaPool {
    shards: Vec<EnvPool>,
    envs_per_shard: usize,
}

impl NumaPool {
    /// Split `cfg` across `nodes` shards. `num_envs`, `batch_size` and
    /// `num_threads` must all divide evenly (matching the paper's setup
    /// of one identical pool per node) — an indivisible thread count
    /// would silently over-subscribe cores, so it is rejected like the
    /// other two. Every other knob — `exec_mode` (each shard can run
    /// its own `ChunkedThreadPool`), `wrappers`, `pin_cores` — is
    /// plumbed through to the shards unchanged.
    pub fn make(cfg: PoolConfig, nodes: usize) -> Result<NumaPool> {
        if cfg.scenario.is_some() {
            // Sharding would split scenario groups across nodes and
            // re-seed each shard, breaking the group-contiguity and
            // replayability contracts. Run scenarios on a single pool.
            return Err(crate::Error::Config(
                "scenario pools do not support NUMA sharding; use a single EnvPool".into(),
            ));
        }
        if nodes == 0
            || cfg.num_envs % nodes != 0
            || cfg.batch_size % nodes != 0
            || cfg.num_threads % nodes != 0
        {
            return Err(crate::Error::Config(format!(
                "num_envs {}, batch_size {} and num_threads {} must divide across {nodes} nodes",
                cfg.num_envs, cfg.batch_size, cfg.num_threads
            )));
        }
        let per = cfg.num_envs / nodes;
        let shards = (0..nodes)
            .map(|k| {
                let mut c = cfg.clone();
                c.num_envs = per;
                c.batch_size = cfg.batch_size / nodes;
                c.num_threads = cfg.num_threads / nodes;
                c.seed = cfg.seed.wrapping_add(k as u64 * 0x9E37_79B9);
                EnvPool::make(c)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(NumaPool { shards, envs_per_shard: per })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Env spec for this pool's task (all shards share it).
    pub fn spec(&self) -> &EnvSpec {
        self.shards[0].spec()
    }

    /// Kick off all shards.
    pub fn async_reset(&mut self) {
        for s in &mut self.shards {
            s.async_reset();
        }
    }

    /// Send actions routed by *global* env id.
    ///
    /// Rows are grouped by shard and forwarded as **one batched `send`
    /// per shard**: each shard's action-queue lock and semaphore post
    /// happen once per batch instead of once per env id (the per-id
    /// version took the shard queue lock `N` times per batch — the
    /// exact contention the NUMA split exists to avoid). The two small
    /// per-shard scratch `Vec`s are the price of `&self`; they are
    /// `num_shards` allocations per batch, not `N`.
    pub fn send(&self, actions: &[f32], env_ids: &[u32]) -> Result<()> {
        let act_dim = self.shards[0].spec().action_space.dim();
        if actions.len() != env_ids.len() * act_dim {
            return Err(crate::Error::ActionShape {
                actions: actions.len(),
                ids: env_ids.len(),
            });
        }
        let nshards = self.shards.len();
        let hint = env_ids.len().div_ceil(nshards);
        let mut acts: Vec<Vec<f32>> =
            (0..nshards).map(|_| Vec::with_capacity(hint * act_dim)).collect();
        let mut ids: Vec<Vec<u32>> = (0..nshards).map(|_| Vec::with_capacity(hint)).collect();
        for (k, &gid) in env_ids.iter().enumerate() {
            let shard = gid as usize / self.envs_per_shard;
            if shard >= nshards {
                return Err(crate::Error::BadEnvId {
                    id: gid as usize,
                    num_envs: self.envs_per_shard * nshards,
                });
            }
            let local = gid as usize % self.envs_per_shard;
            acts[shard].extend_from_slice(&actions[k * act_dim..(k + 1) * act_dim]);
            ids[shard].push(local as u32);
        }
        for s in 0..nshards {
            if !ids[s].is_empty() {
                self.shards[s].send(&acts[s], &ids[s])?;
            }
        }
        Ok(())
    }

    /// Receive one batch from every shard, concatenated, with env ids
    /// translated back to global numbering. `outs` must hold one buffer
    /// per shard (`make_outputs`).
    pub fn recv_all(&self, outs: &mut [BatchedTransition]) -> Result<()> {
        for (k, s) in self.shards.iter().enumerate() {
            s.recv_into(&mut outs[k])?;
            for id in &mut outs[k].env_ids {
                *id += (k * self.envs_per_shard) as u32;
            }
        }
        Ok(())
    }

    /// Per-shard reusable output buffers.
    pub fn make_outputs(&self) -> Vec<BatchedTransition> {
        self.shards.iter().map(|s| s.make_output()).collect()
    }

    /// Total steps across shards.
    pub fn total_steps(&self) -> u64 {
        self.shards.iter().map(|s| s.total_steps()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_all_envs() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(4).num_threads(2).seed(5);
        let mut pool = NumaPool::make(cfg, 2).unwrap();
        assert_eq!(pool.num_shards(), 2);
        pool.async_reset();
        let mut outs = pool.make_outputs();
        let mut seen = vec![0u32; 8];
        for _ in 0..50 {
            pool.recv_all(&mut outs).unwrap();
            let mut ids = vec![];
            let mut actions = vec![];
            for o in &outs {
                for &id in &o.env_ids {
                    seen[id as usize] += 1;
                    ids.push(id);
                    actions.push(0.0f32);
                }
            }
            pool.send(&actions, &ids).unwrap();
        }
        assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
        assert!(seen[0..4].iter().sum::<u32>() > 0 && seen[4..8].iter().sum::<u32>() > 0);
    }

    #[test]
    fn send_rejects_out_of_range_ids_and_bad_shapes() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(4).num_threads(2).seed(3);
        let mut pool = NumaPool::make(cfg, 2).unwrap();
        pool.async_reset();
        let mut outs = pool.make_outputs();
        pool.recv_all(&mut outs).unwrap();
        // global id beyond num_envs must be a BadEnvId error, not a
        // shard-index panic
        match pool.send(&[0.0], &[9]) {
            Err(crate::Error::BadEnvId { id, num_envs }) => {
                assert_eq!((id, num_envs), (9, 8));
            }
            other => panic!("expected BadEnvId, got {:?}", other.map(|_| ())),
        }
        // row/id count mismatch must be an ActionShape error
        assert!(matches!(
            pool.send(&[0.0, 0.0], &[0]),
            Err(crate::Error::ActionShape { .. })
        ));
        // drain the outstanding batch so shutdown stays clean
        let mut ids = vec![];
        let mut actions = vec![];
        for o in &outs {
            for &id in &o.env_ids {
                ids.push(id);
                actions.push(0.0f32);
            }
        }
        pool.send(&actions, &ids).unwrap();
    }

    #[test]
    fn batched_send_routes_interleaved_ids_across_shards() {
        // Ids arriving shard-interleaved (the common recv_all order is
        // shard-major, but callers may reorder) must still land on the
        // right shards with the right action rows: drive CartPole with
        // a constant per-env action policy and check progress on every
        // env — a routing mistake would stall or misroute some id.
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(4).num_threads(2).seed(11);
        let mut pool = NumaPool::make(cfg, 2).unwrap();
        pool.async_reset();
        let mut outs = pool.make_outputs();
        let mut seen = vec![0u32; 8];
        for _ in 0..40 {
            pool.recv_all(&mut outs).unwrap();
            let mut ids = vec![];
            for o in &outs {
                ids.extend_from_slice(&o.env_ids);
            }
            // deliberately reverse: shard-1 ids first
            ids.reverse();
            let actions: Vec<f32> = ids.iter().map(|&id| (id % 2) as f32).collect();
            for &id in &ids {
                seen[id as usize] += 1;
            }
            pool.send(&actions, &ids).unwrap();
        }
        assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
    }

    #[test]
    fn uneven_split_rejected() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(6).batch_size(3).num_threads(2);
        assert!(NumaPool::make(cfg, 4).is_err());
    }

    #[test]
    fn indivisible_thread_count_rejected() {
        // 3 threads over 2 nodes used to silently become 1 thread per
        // shard (over/under-subscription); it must now be a Config error
        // like the num_envs/batch_size checks.
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(4).num_threads(3);
        match NumaPool::make(cfg, 2) {
            Err(crate::Error::Config(msg)) => assert!(msg.contains("num_threads"), "{msg}"),
            other => panic!("expected Config rejection, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn vectorized_shards_run_chunked_pools() {
        // ExecMode is plumbed through NumaPool::make: each shard runs a
        // ChunkedThreadPool. 8 envs / 2 nodes -> shards of 4 envs with 2
        // threads each (2 chunks of 2); shard batch 2 <= num_chunks.
        use crate::pool::envpool::ExecMode;
        let cfg = PoolConfig::new("CartPole-v1")
            .num_envs(8)
            .batch_size(4)
            .num_threads(4)
            .seed(13)
            .exec_mode(ExecMode::Vectorized);
        let mut pool = NumaPool::make(cfg, 2).unwrap();
        assert_eq!(pool.num_shards(), 2);
        assert_eq!(pool.spec().id, "CartPole-v1");
        pool.async_reset();
        let mut outs = pool.make_outputs();
        let mut seen = vec![0u32; 8];
        for _ in 0..40 {
            pool.recv_all(&mut outs).unwrap();
            let mut ids = vec![];
            let mut actions = vec![];
            for o in &outs {
                for &id in &o.env_ids {
                    seen[id as usize] += 1;
                    ids.push(id);
                    actions.push(1.0f32);
                }
            }
            pool.send(&actions, &ids).unwrap();
        }
        assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
        assert!(pool.total_steps() > 0);
    }
}
