//! NUMA-sharded pool (paper "EnvPool (numa+async)"): one independent
//! EnvPool per NUMA node, each with its own ActionBufferQueue /
//! StateBufferQueue / workers, eliminating cross-node queue contention.
//!
//! On this single-socket container the shards are logical (no node
//! binding is possible), but the structure — and the contention-isolation
//! benefit it measures in `benches/table1_throughput` — is the same.

use super::batch::BatchedTransition;
use super::envpool::{EnvPool, PoolConfig};
use crate::envs::spec::EnvSpec;
use crate::Result;

/// A set of independent EnvPool shards addressed through one facade.
/// Env ids are global: shard `k` owns ids `[k*per, (k+1)*per)`.
pub struct NumaPool {
    shards: Vec<EnvPool>,
    envs_per_shard: usize,
}

impl NumaPool {
    /// Split `cfg` across `nodes` shards. `num_envs`, `batch_size` and
    /// `num_threads` must all divide evenly (matching the paper's setup
    /// of one identical pool per node) — an indivisible thread count
    /// would silently over-subscribe cores, so it is rejected like the
    /// other two. Every other knob — `exec_mode` (each shard can run
    /// its own `ChunkedThreadPool`), `wrappers`, `pin_cores` — is
    /// plumbed through to the shards unchanged.
    pub fn make(cfg: PoolConfig, nodes: usize) -> Result<NumaPool> {
        if nodes == 0
            || cfg.num_envs % nodes != 0
            || cfg.batch_size % nodes != 0
            || cfg.num_threads % nodes != 0
        {
            return Err(crate::Error::Config(format!(
                "num_envs {}, batch_size {} and num_threads {} must divide across {nodes} nodes",
                cfg.num_envs, cfg.batch_size, cfg.num_threads
            )));
        }
        let per = cfg.num_envs / nodes;
        let shards = (0..nodes)
            .map(|k| {
                let mut c = cfg.clone();
                c.num_envs = per;
                c.batch_size = cfg.batch_size / nodes;
                c.num_threads = cfg.num_threads / nodes;
                c.seed = cfg.seed.wrapping_add(k as u64 * 0x9E37_79B9);
                EnvPool::make(c)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(NumaPool { shards, envs_per_shard: per })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Env spec for this pool's task (all shards share it).
    pub fn spec(&self) -> &EnvSpec {
        self.shards[0].spec()
    }

    /// Kick off all shards.
    pub fn async_reset(&mut self) {
        for s in &mut self.shards {
            s.async_reset();
        }
    }

    /// Send actions routed by *global* env id.
    pub fn send(&self, actions: &[f32], env_ids: &[u32]) -> Result<()> {
        let act_dim = self.shards[0].spec().action_space.dim();
        for (k, &gid) in env_ids.iter().enumerate() {
            let shard = gid as usize / self.envs_per_shard;
            let local = gid as usize % self.envs_per_shard;
            self.shards[shard]
                .send(&actions[k * act_dim..(k + 1) * act_dim], &[local as u32])?;
        }
        Ok(())
    }

    /// Receive one batch from every shard, concatenated, with env ids
    /// translated back to global numbering. `outs` must hold one buffer
    /// per shard (`make_outputs`).
    pub fn recv_all(&self, outs: &mut [BatchedTransition]) {
        for (k, s) in self.shards.iter().enumerate() {
            s.recv_into(&mut outs[k]);
            for id in &mut outs[k].env_ids {
                *id += (k * self.envs_per_shard) as u32;
            }
        }
    }

    /// Per-shard reusable output buffers.
    pub fn make_outputs(&self) -> Vec<BatchedTransition> {
        self.shards.iter().map(|s| s.make_output()).collect()
    }

    /// Total steps across shards.
    pub fn total_steps(&self) -> u64 {
        self.shards.iter().map(|s| s.total_steps()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_all_envs() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(4).num_threads(2).seed(5);
        let mut pool = NumaPool::make(cfg, 2).unwrap();
        assert_eq!(pool.num_shards(), 2);
        pool.async_reset();
        let mut outs = pool.make_outputs();
        let mut seen = vec![0u32; 8];
        for _ in 0..50 {
            pool.recv_all(&mut outs);
            let mut ids = vec![];
            let mut actions = vec![];
            for o in &outs {
                for &id in &o.env_ids {
                    seen[id as usize] += 1;
                    ids.push(id);
                    actions.push(0.0f32);
                }
            }
            pool.send(&actions, &ids).unwrap();
        }
        assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
        assert!(seen[0..4].iter().sum::<u32>() > 0 && seen[4..8].iter().sum::<u32>() > 0);
    }

    #[test]
    fn uneven_split_rejected() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(6).batch_size(3).num_threads(2);
        assert!(NumaPool::make(cfg, 4).is_err());
    }

    #[test]
    fn indivisible_thread_count_rejected() {
        // 3 threads over 2 nodes used to silently become 1 thread per
        // shard (over/under-subscription); it must now be a Config error
        // like the num_envs/batch_size checks.
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(4).num_threads(3);
        match NumaPool::make(cfg, 2) {
            Err(crate::Error::Config(msg)) => assert!(msg.contains("num_threads"), "{msg}"),
            other => panic!("expected Config rejection, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn vectorized_shards_run_chunked_pools() {
        // ExecMode is plumbed through NumaPool::make: each shard runs a
        // ChunkedThreadPool. 8 envs / 2 nodes -> shards of 4 envs with 2
        // threads each (2 chunks of 2); shard batch 2 <= num_chunks.
        use crate::pool::envpool::ExecMode;
        let cfg = PoolConfig::new("CartPole-v1")
            .num_envs(8)
            .batch_size(4)
            .num_threads(4)
            .seed(13)
            .exec_mode(ExecMode::Vectorized);
        let mut pool = NumaPool::make(cfg, 2).unwrap();
        assert_eq!(pool.num_shards(), 2);
        assert_eq!(pool.spec().id, "CartPole-v1");
        pool.async_reset();
        let mut outs = pool.make_outputs();
        let mut seen = vec![0u32; 8];
        for _ in 0..40 {
            pool.recv_all(&mut outs);
            let mut ids = vec![];
            let mut actions = vec![];
            for o in &outs {
                for &id in &o.env_ids {
                    seen[id as usize] += 1;
                    ids.push(id);
                    actions.push(1.0f32);
                }
            }
            pool.send(&actions, &ids).unwrap();
        }
        assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
        assert!(pool.total_steps() > 0);
    }
}
