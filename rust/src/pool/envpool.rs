//! The EnvPool facade: `make` → `send`/`recv` (async) or `step` (sync),
//! mirroring the paper's Python API (Appendix A) in Rust.

use super::action_queue::ActionBufferQueue;
use super::batch::BatchedTransition;
use super::state_queue::StateBufferQueue;
use super::thread_pool::{EnvSlot, Task, ThreadPool};
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::{Error, Result};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Pool construction parameters (builder style).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Task id, e.g. `"Pong-v5"`.
    pub task_id: String,
    /// Number of environment instances N.
    pub num_envs: usize,
    /// Batch size M returned by `recv` (`M == N` ⇒ synchronous mode).
    pub batch_size: usize,
    /// Worker threads (paper recommends ≈ CPU cores, with N = 2-3× that).
    pub num_threads: usize,
    /// Experiment seed.
    pub seed: u64,
    /// Pin worker threads to cores.
    pub pin_cores: bool,
}

impl PoolConfig {
    pub fn new(task_id: &str) -> Self {
        PoolConfig {
            task_id: task_id.to_string(),
            num_envs: 1,
            batch_size: 1,
            num_threads: 1,
            seed: 0,
            pin_cores: false,
        }
    }

    pub fn num_envs(mut self, n: usize) -> Self {
        self.num_envs = n;
        if self.batch_size > n {
            self.batch_size = n;
        }
        self
    }

    pub fn batch_size(mut self, m: usize) -> Self {
        self.batch_size = m;
        self
    }

    pub fn num_threads(mut self, t: usize) -> Self {
        self.num_threads = t;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn pin_cores(mut self, p: bool) -> Self {
        self.pin_cores = p;
        self
    }

    /// Synchronous-mode config (`batch_size = num_envs`).
    pub fn sync(mut self) -> Self {
        self.batch_size = self.num_envs;
        self
    }

    fn validate(&self) -> Result<()> {
        if self.num_envs == 0 {
            return Err(Error::Config("num_envs must be > 0".into()));
        }
        if self.batch_size == 0 || self.batch_size > self.num_envs {
            return Err(Error::Config(format!(
                "batch_size {} must be in [1, num_envs {}]",
                self.batch_size, self.num_envs
            )));
        }
        if self.num_threads == 0 {
            return Err(Error::Config("num_threads must be > 0".into()));
        }
        Ok(())
    }
}

/// The environment pool.
pub struct EnvPool {
    spec: EnvSpec,
    cfg: PoolConfig,
    envs: Arc<Vec<EnvSlot>>,
    queue: Arc<ActionBufferQueue<Task>>,
    states: Arc<StateBufferQueue>,
    workers: Option<ThreadPool>,
    /// Reusable output block for the owned-recv convenience API.
    scratch: BatchedTransition,
    started: bool,
}

impl EnvPool {
    /// Construct the pool: instantiate `num_envs` environments (each with
    /// its own RNG stream), pre-allocate the state queue, spawn workers.
    pub fn make(cfg: PoolConfig) -> Result<EnvPool> {
        cfg.validate()?;
        let spec = registry::spec_for(&cfg.task_id)?;
        let act_dim = spec.action_space.dim();
        let mut slots = Vec::with_capacity(cfg.num_envs);
        for i in 0..cfg.num_envs {
            slots.push(EnvSlot {
                env: Mutex::new(registry::make_env(&cfg.task_id, cfg.seed, i as u64)?),
                action: Mutex::new(vec![0.0; act_dim]),
                needs_reset: Mutex::new(false),
            });
        }
        let envs = Arc::new(slots);
        // paper: ActionBufferQueue sized 2N (+ room for shutdown tasks)
        let queue = Arc::new(ActionBufferQueue::new(2 * cfg.num_envs + cfg.num_threads));
        let states = Arc::new(StateBufferQueue::new(cfg.num_envs, cfg.batch_size, spec.obs_dim()));
        let workers =
            ThreadPool::spawn(cfg.num_threads, envs.clone(), queue.clone(), states.clone(), cfg.pin_cores);
        let scratch = states.make_output();
        Ok(EnvPool { spec, cfg, envs, queue, states, workers: Some(workers), scratch, started: false })
    }

    /// Env spec for this pool's task.
    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Total env steps executed by the workers so far.
    pub fn total_steps(&self) -> u64 {
        self.workers
            .as_ref()
            .map(|w| w.steps.load(std::sync::atomic::Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Kick off the pool: schedule a reset for every environment
    /// (paper's `async_reset`; call exactly once before the recv loop).
    pub fn async_reset(&mut self) {
        assert!(!self.started, "async_reset may only be called once");
        self.started = true;
        for i in 0..self.cfg.num_envs {
            self.enqueue(Task::Reset { env_id: i as u32 });
        }
    }

    fn enqueue(&self, mut t: Task) {
        loop {
            match self.queue.enqueue(t) {
                Ok(()) => return,
                Err(back) => {
                    t = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Send a batch of actions. `actions` is row-major
    /// `[env_ids.len(), act_dim]`; `env_ids` routes each row (use the ids
    /// from the last `recv`). Returns immediately (paper §3.1).
    pub fn send(&self, actions: &[f32], env_ids: &[u32]) -> Result<()> {
        let act_dim = self.spec.action_space.dim();
        if actions.len() != env_ids.len() * act_dim {
            return Err(Error::ActionShape { actions: actions.len(), ids: env_ids.len() });
        }
        for (k, &id) in env_ids.iter().enumerate() {
            let i = id as usize;
            if i >= self.cfg.num_envs {
                return Err(Error::BadEnvId { id: i, num_envs: self.cfg.num_envs });
            }
            let mut slot = self.envs[i].action.lock().unwrap();
            slot.copy_from_slice(&actions[k * act_dim..(k + 1) * act_dim]);
        }
        // single semaphore post for the whole batch (§Perf optimization)
        self.queue
            .enqueue_batch(env_ids.iter().map(|&id| Task::Step { env_id: id }));
        Ok(())
    }

    /// Receive the next ready batch into a reusable buffer (hot path —
    /// zero allocation, zero batching copies).
    pub fn recv_into(&self, out: &mut BatchedTransition) {
        self.states.recv_into(out);
    }

    /// Timed receive; false on timeout.
    pub fn recv_into_timeout(&self, out: &mut BatchedTransition, d: Duration) -> bool {
        self.states.recv_into_timeout(out, d)
    }

    /// Convenience receive returning a clone of the internal scratch
    /// buffer (allocates; use [`Self::recv_into`] on hot paths).
    pub fn recv(&mut self) -> Result<BatchedTransition> {
        let mut out = std::mem::take(&mut self.scratch);
        self.states.recv_into(&mut out);
        self.scratch = out.clone();
        Ok(out)
    }

    /// Synchronous vectorized step: send then recv. Only meaningful in
    /// sync mode (`batch_size == num_envs`), where the returned batch
    /// contains exactly the stepped envs.
    pub fn step_into(
        &self,
        actions: &[f32],
        env_ids: &[u32],
        out: &mut BatchedTransition,
    ) -> Result<()> {
        self.send(actions, env_ids)?;
        self.recv_into(out);
        Ok(())
    }

    /// Reset all envs and collect the full first batch (sync mode only).
    pub fn reset_into(&mut self, out: &mut BatchedTransition) -> Result<()> {
        if self.cfg.batch_size != self.cfg.num_envs {
            return Err(Error::Config(
                "reset_into requires sync mode (batch_size == num_envs); use async_reset".into(),
            ));
        }
        if !self.started {
            self.started = true;
        }
        for i in 0..self.cfg.num_envs {
            self.enqueue(Task::Reset { env_id: i as u32 });
        }
        self.recv_into(out);
        Ok(())
    }

    /// A correctly-sized reusable output buffer.
    pub fn make_output(&self) -> BatchedTransition {
        self.states.make_output()
    }

    /// Shut down worker threads (also happens on drop).
    pub fn close(&mut self) {
        if let Some(mut w) = self.workers.take() {
            w.shutdown();
        }
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_mode_steps_all_envs() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(7);
        let mut pool = EnvPool::make(cfg).unwrap();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        assert_eq!(out.len(), 4);
        let mut ids: Vec<u32> = out.env_ids.clone();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        for _ in 0..50 {
            let actions: Vec<f32> = out.env_ids.iter().map(|_| 1.0).collect();
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            assert_eq!(out.len(), 4);
            assert!(out.obs.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn async_mode_returns_batches_of_m() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(3).num_threads(2).seed(1);
        let mut pool = EnvPool::make(cfg).unwrap();
        pool.async_reset();
        let mut out = pool.make_output();
        let mut seen = vec![0u32; 8];
        for _ in 0..100 {
            pool.recv_into(&mut out);
            assert_eq!(out.len(), 3);
            for &id in &out.env_ids {
                seen[id as usize] += 1;
            }
            let actions = vec![0.0f32; out.len()];
            pool.send(&actions, &out.env_ids.clone()).unwrap();
        }
        // all envs participate; none dominates pathologically
        assert!(seen.iter().all(|&c| c > 0), "every env must be served: {seen:?}");
    }

    #[test]
    fn auto_reset_keeps_pool_running_forever() {
        // CartPole episodes end quickly under random actions; the pool
        // must keep producing batches across episode boundaries.
        let cfg = PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(3);
        let mut pool = EnvPool::make(cfg).unwrap();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        let mut dones = 0;
        for step in 0..500 {
            let actions: Vec<f32> = (0..4).map(|k| ((step + k) % 2) as f32).collect();
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            dones += out.done.iter().filter(|&&d| d != 0).count();
        }
        assert!(dones > 5, "random cartpole must terminate episodes, saw {dones}");
    }

    #[test]
    fn bad_inputs_are_rejected() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(2).batch_size(2).num_threads(1);
        let pool = EnvPool::make(cfg).unwrap();
        assert!(matches!(
            pool.send(&[0.0, 0.0], &[0]),
            Err(Error::ActionShape { .. })
        ));
        assert!(matches!(
            pool.send(&[0.0], &[9]),
            Err(Error::BadEnvId { .. })
        ));
        assert!(EnvPool::make(PoolConfig::new("CartPole-v1").num_envs(0)).is_err());
        assert!(EnvPool::make(PoolConfig::new("NoSuchEnv-v0")).is_err());
    }

    #[test]
    fn continuous_actions_route_correctly() {
        let cfg = PoolConfig::new("Pendulum-v1").num_envs(3).batch_size(3).num_threads(2).seed(2);
        let mut pool = EnvPool::make(cfg).unwrap();
        assert_eq!(pool.spec().action_space.dim(), 1);
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        for _ in 0..20 {
            let actions: Vec<f32> = out.env_ids.iter().map(|&i| i as f32 - 1.0).collect();
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            // pendulum never terminates before 200 steps
            assert!(out.done.iter().all(|&d| d == 0));
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Same seed, same per-env actions => same rewards regardless of
        // worker parallelism (RNG streams are per-env).
        let run = |threads: usize| -> Vec<f32> {
            let cfg =
                PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(threads).seed(11);
            let mut pool = EnvPool::make(cfg).unwrap();
            let mut out = pool.make_output();
            pool.reset_into(&mut out).unwrap();
            let mut rewards = vec![0.0f32; 4];
            for step in 0..60 {
                let ids = out.env_ids.clone();
                let actions: Vec<f32> = ids.iter().map(|&i| ((step + i as usize) % 2) as f32).collect();
                pool.step_into(&actions, &ids, &mut out).unwrap();
                for (k, &id) in out.env_ids.iter().enumerate() {
                    rewards[id as usize] += out.rew[k] * (step as f32 + 1.0);
                }
            }
            rewards
        };
        assert_eq!(run(1), run(3));
    }
}
