//! The EnvPool facade: `make` → `send`/`recv` (async) or `step` (sync),
//! mirroring the paper's Python API (Appendix A) in Rust.

use super::action_queue::ActionBufferQueue;
use super::batch::BatchedTransition;
use super::chunked::{Chunk, ChunkedThreadPool};
use super::state_queue::StateBufferQueue;
use super::thread_pool::{EnvSlot, Task, ThreadPool};
use crate::envs::registry::{self, WrapConfig};
use crate::envs::spec::EnvSpec;
use crate::{Error, Result};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How worker threads execute environment steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// One task per env step (the paper's baseline design): maximal
    /// scheduling freedom, best for expensive envs (Atari, MuJoCo).
    #[default]
    Scalar,
    /// One task per **chunk** of `ceil(num_envs / num_threads)` envs,
    /// stepped by a struct-of-arrays kernel writing observations straight
    /// into state-queue slots. Amortizes wakeups/dispatch for cheap envs
    /// (classic control) — see [`crate::envs::vector`].
    Vectorized,
}

/// Pool construction parameters (builder style).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Task id, e.g. `"Pong-v5"`.
    pub task_id: String,
    /// Number of environment instances N.
    pub num_envs: usize,
    /// Batch size M returned by `recv` (`M == N` ⇒ synchronous mode).
    pub batch_size: usize,
    /// Worker threads (paper recommends ≈ CPU cores, with N = 2-3× that).
    pub num_threads: usize,
    /// Experiment seed.
    pub seed: u64,
    /// Pin worker threads to cores.
    pub pin_cores: bool,
    /// Step execution backend (per-env tasks vs per-chunk SoA kernels).
    pub exec_mode: ExecMode,
    /// Engine-side wrapper stack, applied identically in both exec
    /// modes (batch-wise `VecWrapper`s on chunks, one-lane adapters on
    /// scalar envs).
    pub wrappers: WrapConfig,
    /// SIMD lane width for the vectorized kernels (ignored by
    /// `ExecMode::Scalar`). Every width is bitwise identical — a pure
    /// throughput knob; see [`crate::simd::LanePass`].
    pub lane_pass: crate::simd::LanePass,
    /// Heterogeneous scenario: mixed-task lane groups with per-group
    /// wrappers, seeds and physics overrides
    /// ([`crate::config::ScenarioConfig`]). When set, `task_id` and
    /// `wrappers` are ignored (each group carries its own) and
    /// `num_envs` must equal the scenario's total lane count. `None`
    /// (the default) leaves every existing path bitwise untouched.
    pub scenario: Option<crate::config::ScenarioConfig>,
}

impl PoolConfig {
    pub fn new(task_id: &str) -> Self {
        PoolConfig {
            task_id: task_id.to_string(),
            num_envs: 1,
            batch_size: 1,
            num_threads: 1,
            seed: 0,
            pin_cores: false,
            exec_mode: ExecMode::Scalar,
            wrappers: WrapConfig::none(),
            lane_pass: crate::simd::LanePass::Auto,
            scenario: None,
        }
    }

    pub fn num_envs(mut self, n: usize) -> Self {
        self.num_envs = n;
        self
    }

    pub fn batch_size(mut self, m: usize) -> Self {
        self.batch_size = m;
        self
    }

    pub fn num_threads(mut self, t: usize) -> Self {
        self.num_threads = t;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn pin_cores(mut self, p: bool) -> Self {
        self.pin_cores = p;
        self
    }

    /// Select the execution backend (see [`ExecMode`]).
    pub fn exec_mode(mut self, m: ExecMode) -> Self {
        self.exec_mode = m;
        self
    }

    /// Apply an engine-side wrapper stack (see [`WrapConfig`]).
    pub fn wrappers(mut self, w: WrapConfig) -> Self {
        self.wrappers = w;
        self
    }

    /// Select the SIMD lane width for vectorized kernels (see
    /// [`crate::simd::LanePass`]; bitwise-identical at every width).
    pub fn lane_pass(mut self, lp: crate::simd::LanePass) -> Self {
        self.lane_pass = lp;
        self
    }

    /// Run a heterogeneous scenario (see [`PoolConfig::scenario`]).
    /// Sets `num_envs` to the scenario's total lane count; set
    /// `batch_size` (or call [`Self::sync`]) afterwards.
    pub fn scenario(mut self, sc: crate::config::ScenarioConfig) -> Self {
        self.num_envs = sc.num_envs();
        self.scenario = Some(sc);
        self
    }

    /// Synchronous-mode config (`batch_size = num_envs`).
    pub fn sync(mut self) -> Self {
        self.batch_size = self.num_envs;
        self
    }

    fn validate(&self) -> Result<()> {
        if let Some(sc) = &self.scenario {
            sc.validate()?;
            if self.num_envs != sc.num_envs() {
                return Err(Error::Config(format!(
                    "num_envs {} does not match the scenario's total lane count {} \
                     (the .scenario() builder sets it; don't override it afterwards)",
                    self.num_envs,
                    sc.num_envs()
                )));
            }
            if !self.wrappers.is_empty() {
                return Err(Error::Config(
                    "pool-level wrappers cannot combine with a scenario; put the \
                     wrapper stack on each scenario group instead"
                        .into(),
                ));
            }
        }
        if self.num_envs == 0 {
            return Err(Error::Config("num_envs must be > 0".into()));
        }
        if self.batch_size == 0 || self.batch_size > self.num_envs {
            return Err(Error::Config(format!(
                "batch_size {} must be in [1, num_envs {}]; the builder setters apply \
                 literally in any order (num_envs never clamps batch_size), so set \
                 batch_size after num_envs — or call .sync() for batch_size == num_envs",
                self.batch_size, self.num_envs
            )));
        }
        if self.num_threads == 0 {
            return Err(Error::Config("num_threads must be > 0".into()));
        }
        Ok(())
    }
}

/// The per-mode execution engine behind the pool facade.
enum Engine {
    /// Per-env tasks over a shared env table (paper baseline).
    Scalar {
        envs: Arc<Vec<EnvSlot>>,
        queue: Arc<ActionBufferQueue<Task>>,
        workers: Option<ThreadPool>,
    },
    /// Per-chunk tasks over struct-of-arrays backends.
    Chunked { pool: Option<ChunkedThreadPool> },
}

/// The environment pool.
pub struct EnvPool {
    spec: EnvSpec,
    cfg: PoolConfig,
    states: Arc<StateBufferQueue>,
    engine: Engine,
    /// Reusable output block for the borrowed-recv convenience API
    /// (behind a mutex so [`EnvPool::recv`] can take `&self` and return
    /// a guard without freezing the pool for `send`).
    scratch: Mutex<BatchedTransition>,
    started: bool,
}

impl EnvPool {
    /// Construct the pool: instantiate `num_envs` environments (each with
    /// its own RNG stream), pre-allocate the state queue, spawn workers.
    pub fn make(cfg: PoolConfig) -> Result<EnvPool> {
        cfg.validate()?;
        let spec = match &cfg.scenario {
            // Union spec with per-group views; queue rows and action
            // buffers run at the union widths.
            Some(sc) => registry::scenario_spec(sc)?,
            None => registry::spec_for_wrapped(&cfg.task_id, &cfg.wrappers)?,
        };
        let act_dim = spec.action_space.dim();
        let states = Arc::new(StateBufferQueue::new(cfg.num_envs, cfg.batch_size, spec.obs_dim()));
        let engine = match cfg.exec_mode {
            ExecMode::Scalar => {
                let mut slots = Vec::with_capacity(cfg.num_envs);
                for i in 0..cfg.num_envs {
                    let env = match &cfg.scenario {
                        // Env i = lane (i - first) of its group, built as
                        // a one-lane kernel (bitwise the grouped lanes).
                        Some(sc) => {
                            let (gi, lane) = sc.locate(i);
                            registry::make_scenario_env(sc, gi, lane, cfg.seed)?
                        }
                        None => {
                            let w = &cfg.wrappers;
                            registry::make_env_wrapped(&cfg.task_id, cfg.seed, i as u64, w)?
                        }
                    };
                    slots.push(EnvSlot {
                        env: Mutex::new(env),
                        action: Mutex::new(vec![0.0; act_dim]),
                        needs_reset: Mutex::new(false),
                    });
                }
                let envs = Arc::new(slots);
                // paper: ActionBufferQueue sized 2N (+ room for shutdown tasks)
                let queue = Arc::new(ActionBufferQueue::new(2 * cfg.num_envs + cfg.num_threads));
                let workers = ThreadPool::spawn(
                    cfg.num_threads,
                    envs.clone(),
                    queue.clone(),
                    states.clone(),
                    cfg.pin_cores,
                );
                Engine::Scalar { envs, queue, workers: Some(workers) }
            }
            ExecMode::Vectorized => {
                // Chunking math (homogeneous): K = ceil(N / threads);
                // the last chunk takes the remainder (see `envs::vector`
                // module docs). With N < threads this yields fewer
                // chunks than requested workers;
                // `ChunkedThreadPool::spawn` clamps the worker count to
                // the chunk count. Scenario pools instead build **one
                // chunk per lane group** — chunking never splits a
                // group, so every group's kernel keeps its full lane
                // width and its group-local env ids.
                let (chunk_size, num_chunks) = match &cfg.scenario {
                    Some(sc) => {
                        let widest = sc.groups.iter().map(|g| g.count).max().unwrap_or(1);
                        (widest, sc.groups.len())
                    }
                    None => {
                        let k = cfg.num_envs.div_ceil(cfg.num_threads);
                        (k, cfg.num_envs.div_ceil(k))
                    }
                };
                // Liveness constraint for async mode: a chunk only steps
                // once ALL its envs have actions, so with M > num_chunks
                // every chunk can be left partially armed while the
                // state queue's tail block holds up to M-1 rows — a
                // cycle nothing breaks. Pigeonhole: N = staged + tail
                // with staged <= N - num_chunks and tail <= M - 1, so
                // deadlock needs M >= num_chunks + 1; M <= num_chunks is
                // safe. Sync mode (M == N) is separately safe: sends
                // arrive as a full batch and always arm every chunk.
                if cfg.batch_size < cfg.num_envs && cfg.batch_size > num_chunks {
                    return Err(Error::Config(format!(
                        "vectorized async mode requires batch_size <= num_chunks \
                         (= {num_chunks} here: {num_chunks} chunks of up to {chunk_size} envs) \
                         or sync mode (batch_size == num_envs); got batch_size {}. \
                         Lower batch_size, raise num_threads, or use ExecMode::Scalar",
                        cfg.batch_size
                    )));
                }
                let mut chunks = Vec::new();
                match &cfg.scenario {
                    Some(sc) => {
                        for gi in 0..sc.groups.len() {
                            let mut backend = registry::make_scenario_group(sc, gi, cfg.seed)?;
                            backend.set_lane_pass(cfg.lane_pass);
                            chunks.push(Chunk::new(backend, sc.first_env(gi) as u32));
                        }
                    }
                    None => {
                        let mut first = 0usize;
                        while first < cfg.num_envs {
                            let len = chunk_size.min(cfg.num_envs - first);
                            let mut backend = registry::make_vec_env_wrapped(
                                &cfg.task_id,
                                cfg.seed,
                                first as u64,
                                len,
                                &cfg.wrappers,
                            )?;
                            backend.set_lane_pass(cfg.lane_pass);
                            chunks.push(Chunk::new(backend, first as u32));
                            first += len;
                        }
                    }
                }
                let pool = ChunkedThreadPool::spawn(
                    cfg.num_threads,
                    chunks,
                    states.clone(),
                    chunk_size,
                    act_dim,
                    cfg.pin_cores,
                );
                Engine::Chunked { pool: Some(pool) }
            }
        };
        let scratch = Mutex::new(states.make_output());
        Ok(EnvPool { spec, cfg, states, engine, scratch, started: false })
    }

    /// Env spec for this pool's task.
    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Total env steps executed by the workers so far.
    pub fn total_steps(&self) -> u64 {
        match &self.engine {
            Engine::Scalar { workers, .. } => workers
                .as_ref()
                .map(|w| w.steps.load(std::sync::atomic::Ordering::Relaxed))
                .unwrap_or(0),
            Engine::Chunked { pool } => pool
                .as_ref()
                .map(|p| p.steps.load(std::sync::atomic::Ordering::Relaxed))
                .unwrap_or(0),
        }
    }

    /// Kick off the pool: schedule a reset for every environment
    /// (paper's `async_reset`; call exactly once before the recv loop).
    pub fn async_reset(&mut self) {
        assert!(!self.started, "async_reset may only be called once");
        self.started = true;
        self.schedule_all_resets();
    }

    /// Schedule a reset of every env/chunk on the worker side.
    fn schedule_all_resets(&self) {
        match &self.engine {
            Engine::Scalar { .. } => {
                for i in 0..self.cfg.num_envs {
                    self.enqueue(Task::Reset { env_id: i as u32 });
                }
            }
            Engine::Chunked { pool } => {
                if let Some(p) = pool.as_ref() {
                    p.schedule_reset_all();
                }
            }
        }
    }

    fn enqueue(&self, t: Task) {
        let Engine::Scalar { queue, .. } = &self.engine else {
            unreachable!("enqueue is scalar-engine only");
        };
        queue.blocking_enqueue(t);
    }

    /// Schedule an explicit reset for a subset of envs; each produces one
    /// row on the state queue like any step. The serve-mode lease table
    /// uses this to recycle a dead client's envs without touching the
    /// rest of the pool. Scalar engine only: chunked kernels step whole
    /// groups and cannot reset individual lanes out of band.
    pub fn schedule_resets(&self, env_ids: &[u32]) -> Result<()> {
        for &id in env_ids {
            if id as usize >= self.cfg.num_envs {
                return Err(Error::BadEnvId { id: id as usize, num_envs: self.cfg.num_envs });
            }
        }
        match &self.engine {
            Engine::Scalar { queue, .. } => {
                queue.enqueue_batch(env_ids.iter().map(|&id| Task::Reset { env_id: id }));
                Ok(())
            }
            Engine::Chunked { .. } => Err(Error::Config(
                "schedule_resets requires ExecMode::Scalar (chunked kernels reset whole groups)"
                    .into(),
            )),
        }
    }

    /// Send a batch of actions. `actions` is row-major
    /// `[env_ids.len(), act_dim]`; `env_ids` routes each row (use the ids
    /// from the last `recv`). Returns immediately (paper §3.1).
    pub fn send(&self, actions: &[f32], env_ids: &[u32]) -> Result<()> {
        let act_dim = self.spec.action_space.dim();
        if actions.len() != env_ids.len() * act_dim {
            return Err(Error::ActionShape { actions: actions.len(), ids: env_ids.len() });
        }
        for &id in env_ids {
            if id as usize >= self.cfg.num_envs {
                return Err(Error::BadEnvId { id: id as usize, num_envs: self.cfg.num_envs });
            }
        }
        match &self.engine {
            Engine::Scalar { envs, queue, .. } => {
                for (k, &id) in env_ids.iter().enumerate() {
                    let mut slot = envs[id as usize].action.lock().unwrap();
                    slot.copy_from_slice(&actions[k * act_dim..(k + 1) * act_dim]);
                }
                // single semaphore post for the whole batch (§Perf optimization)
                queue.enqueue_batch(env_ids.iter().map(|&id| Task::Step { env_id: id }));
            }
            Engine::Chunked { pool } => {
                if let Some(p) = pool.as_ref() {
                    p.send_actions(actions, env_ids);
                }
            }
        }
        Ok(())
    }

    /// Receive the next ready batch into a reusable buffer (hot path —
    /// zero allocation, zero batching copies). [`Error::Closed`] after
    /// [`Self::close`] or a worker panic poisoned the state queue.
    pub fn recv_into(&self, out: &mut BatchedTransition) -> Result<()> {
        self.states.recv_into(out)
    }

    /// Timed receive; `Ok(false)` on timeout, [`Error::Closed`] once the
    /// pool is closed or poisoned.
    pub fn recv_into_timeout(&self, out: &mut BatchedTransition, d: Duration) -> Result<bool> {
        self.states.recv_into_timeout(out, d)
    }

    /// Convenience receive returning a **view** of the pool's internal
    /// scratch buffer. Steady state allocates and copies nothing: the
    /// scratch rotates with the state queue's preallocated block
    /// payloads via [`Self::recv_into`]'s buffer swap (it used to clone
    /// the whole batch back into the scratch on every call). The guard
    /// borrows `self` immutably, so `send` with the batch's `env_ids`
    /// works while it is alive; clone the view if you need to keep a
    /// batch across steps, or use [`Self::recv_into`] with your own
    /// buffer to also skip the (uncontended) lock.
    pub fn recv(&self) -> Result<std::sync::MutexGuard<'_, BatchedTransition>> {
        let mut g = self.scratch.lock().unwrap();
        self.states.recv_into(&mut g)?;
        Ok(g)
    }

    /// Synchronous vectorized step: send then recv. Only meaningful in
    /// sync mode (`batch_size == num_envs`), where the returned batch
    /// contains exactly the stepped envs.
    pub fn step_into(
        &self,
        actions: &[f32],
        env_ids: &[u32],
        out: &mut BatchedTransition,
    ) -> Result<()> {
        self.send(actions, env_ids)?;
        self.recv_into(out)
    }

    /// Reset all envs and collect the full first batch (sync mode only).
    pub fn reset_into(&mut self, out: &mut BatchedTransition) -> Result<()> {
        if self.cfg.batch_size != self.cfg.num_envs {
            return Err(Error::Config(
                "reset_into requires sync mode (batch_size == num_envs); use async_reset".into(),
            ));
        }
        if !self.started {
            self.started = true;
        }
        self.schedule_all_resets();
        self.recv_into(out)
    }

    /// A correctly-sized reusable output buffer.
    pub fn make_output(&self) -> BatchedTransition {
        self.states.make_output()
    }

    /// Shut down worker threads (also happens on drop).
    ///
    /// Closes the state queue *first*: workers spinning in `acquire`
    /// (e.g. when the pool is dropped with results in flight that the
    /// consumer stopped draining) bail out instead of spinning forever,
    /// so the join below cannot hang. Subsequent `recv` calls return
    /// [`Error::Closed`].
    pub fn close(&mut self) {
        self.states.close();
        match &mut self.engine {
            Engine::Scalar { workers, .. } => {
                if let Some(mut w) = workers.take() {
                    w.shutdown();
                }
            }
            Engine::Chunked { pool } => {
                if let Some(mut p) = pool.take() {
                    p.shutdown();
                }
            }
        }
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_mode_steps_all_envs() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(7);
        let mut pool = EnvPool::make(cfg).unwrap();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        assert_eq!(out.len(), 4);
        let mut ids: Vec<u32> = out.env_ids.clone();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        for _ in 0..50 {
            let actions: Vec<f32> = out.env_ids.iter().map(|_| 1.0).collect();
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            assert_eq!(out.len(), 4);
            assert!(out.obs.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn async_mode_returns_batches_of_m() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(8).batch_size(3).num_threads(2).seed(1);
        let mut pool = EnvPool::make(cfg).unwrap();
        pool.async_reset();
        let mut out = pool.make_output();
        let mut seen = vec![0u32; 8];
        for _ in 0..100 {
            pool.recv_into(&mut out).unwrap();
            assert_eq!(out.len(), 3);
            for &id in &out.env_ids {
                seen[id as usize] += 1;
            }
            let actions = vec![0.0f32; out.len()];
            pool.send(&actions, &out.env_ids.clone()).unwrap();
        }
        // all envs participate; none dominates pathologically
        assert!(seen.iter().all(|&c| c > 0), "every env must be served: {seen:?}");
    }

    #[test]
    fn auto_reset_keeps_pool_running_forever() {
        // CartPole episodes end quickly under random actions; the pool
        // must keep producing batches across episode boundaries.
        let cfg = PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(3);
        let mut pool = EnvPool::make(cfg).unwrap();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        let mut dones = 0;
        for step in 0..500 {
            let actions: Vec<f32> = (0..4).map(|k| ((step + k) % 2) as f32).collect();
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            dones += out.done.iter().filter(|&&d| d != 0).count();
        }
        assert!(dones > 5, "random cartpole must terminate episodes, saw {dones}");
    }

    #[test]
    fn recv_view_reuses_queue_buffers_without_cloning() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(3);
        let mut pool = EnvPool::make(cfg).unwrap();
        pool.async_reset();
        let mut ptrs = std::collections::HashSet::new();
        let mut caps = std::collections::HashSet::new();
        for _ in 0..40 {
            let (ids, ptr, cap) = {
                let b = pool.recv().unwrap();
                assert_eq!(b.len(), 4);
                (b.env_ids.clone(), b.obs.as_ptr() as usize, b.obs.capacity())
            };
            // The view must BE the scratch buffer, not a clone of it.
            assert_eq!(pool.scratch.lock().unwrap().obs.as_ptr() as usize, ptr);
            ptrs.insert(ptr);
            caps.insert(cap);
            let actions: Vec<f32> = ids.iter().map(|_| 1.0).collect();
            pool.send(&actions, &ids).unwrap();
        }
        // `recv_into` swaps the scratch with the queue's preallocated
        // block payloads, so the convenience path must rotate among a
        // fixed buffer set — never grow it. (The pre-fix take+clone
        // implementation minted a fresh scratch every call.)
        assert!(
            ptrs.len() <= pool.states.num_blocks() + 1,
            "recv() must not allocate per call: saw {} distinct obs buffers over 40 recvs",
            ptrs.len()
        );
        assert_eq!(caps.len(), 1, "obs capacity must stay fixed, saw {caps:?}");
    }

    #[test]
    fn bad_inputs_are_rejected() {
        let cfg = PoolConfig::new("CartPole-v1").num_envs(2).batch_size(2).num_threads(1);
        let pool = EnvPool::make(cfg).unwrap();
        assert!(matches!(
            pool.send(&[0.0, 0.0], &[0]),
            Err(Error::ActionShape { .. })
        ));
        assert!(matches!(
            pool.send(&[0.0], &[9]),
            Err(Error::BadEnvId { .. })
        ));
        assert!(EnvPool::make(PoolConfig::new("CartPole-v1").num_envs(0)).is_err());
        assert!(EnvPool::make(PoolConfig::new("NoSuchEnv-v0")).is_err());
    }

    #[test]
    fn builder_order_does_not_silently_clamp_batch_size() {
        // Regression: `num_envs` used to clamp an already-set batch_size
        // (so `.batch_size(8).num_envs(4)` silently became sync mode with
        // batch 4, while the reverse order errored). The builder now
        // stores what it is given in either order and `make` rejects the
        // inconsistency with an actionable message.
        let cfg = PoolConfig::new("CartPole-v1").batch_size(8).num_envs(4).num_threads(1);
        assert_eq!(cfg.batch_size, 8, "builder must not rewrite batch_size");
        match EnvPool::make(cfg) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("batch_size 8"), "{msg}");
                assert!(msg.contains("num_envs 4"), "{msg}");
            }
            other => panic!("expected Config rejection, got {:?}", other.map(|_| ())),
        }
        // The same shape stated consistently still works in either order.
        let cfg = PoolConfig::new("CartPole-v1").batch_size(2).num_envs(4).num_threads(1);
        assert!(EnvPool::make(cfg).is_ok());
    }

    #[test]
    fn continuous_actions_route_correctly() {
        let cfg = PoolConfig::new("Pendulum-v1").num_envs(3).batch_size(3).num_threads(2).seed(2);
        let mut pool = EnvPool::make(cfg).unwrap();
        assert_eq!(pool.spec().action_space.dim(), 1);
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        for _ in 0..20 {
            let actions: Vec<f32> = out.env_ids.iter().map(|&i| i as f32 - 1.0).collect();
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            // pendulum never terminates before 200 steps
            assert!(out.done.iter().all(|&d| d == 0));
        }
    }

    #[test]
    fn vectorized_sync_mode_matches_scalar_exactly() {
        // ExecMode must be purely an execution detail: same seeds + same
        // actions => bitwise-identical batches (after env-id reordering).
        let run = |mode: ExecMode| -> (Vec<f32>, Vec<f32>) {
            let cfg = PoolConfig::new("CartPole-v1")
                .num_envs(6)
                .batch_size(6)
                .num_threads(2)
                .seed(17)
                .exec_mode(mode);
            let mut pool = EnvPool::make(cfg).unwrap();
            let mut out = pool.make_output();
            pool.reset_into(&mut out).unwrap();
            let mut obs_trace = Vec::new();
            let mut rew_trace = Vec::new();
            for step in 0..100 {
                let ids = out.env_ids.clone();
                let actions: Vec<f32> =
                    ids.iter().map(|&i| ((step + i as usize) % 2) as f32).collect();
                pool.step_into(&actions, &ids, &mut out).unwrap();
                // canonical env-id order for comparison
                let mut order: Vec<usize> = (0..out.len()).collect();
                order.sort_by_key(|&k| out.env_ids[k]);
                for &k in &order {
                    obs_trace.extend_from_slice(out.obs_row(k));
                    rew_trace.push(out.rew[k]);
                }
            }
            (obs_trace, rew_trace)
        };
        let (so, sr) = run(ExecMode::Scalar);
        let (vo, vr) = run(ExecMode::Vectorized);
        assert_eq!(sr, vr, "rewards diverge between exec modes");
        assert_eq!(so, vo, "observations diverge between exec modes");
    }

    #[test]
    fn vectorized_async_mode_serves_every_env() {
        // 3 threads => 3 chunks of 3; batch_size 3 == num_chunks is the
        // largest async batch the liveness constraint admits here.
        let cfg = PoolConfig::new("Acrobot-v1")
            .num_envs(9)
            .batch_size(3)
            .num_threads(3)
            .seed(4)
            .exec_mode(ExecMode::Vectorized);
        let mut pool = EnvPool::make(cfg).unwrap();
        pool.async_reset();
        let mut out = pool.make_output();
        let mut seen = vec![0u32; 9];
        for _ in 0..60 {
            pool.recv_into(&mut out).unwrap();
            assert_eq!(out.len(), 3);
            for &id in &out.env_ids {
                seen[id as usize] += 1;
            }
            let actions = vec![1.0f32; out.len()];
            pool.send(&actions, &out.env_ids.clone()).unwrap();
        }
        assert!(seen.iter().all(|&c| c > 0), "every env must be served: {seen:?}");
        assert!(pool.total_steps() > 0);
    }

    #[test]
    fn vectorized_async_rejects_deadlock_prone_batch_size() {
        // 2 threads => 2 chunks; an async batch of 3 could leave every
        // chunk partially armed forever, so construction must fail.
        let cfg = PoolConfig::new("CartPole-v1")
            .num_envs(9)
            .batch_size(3)
            .num_threads(2)
            .exec_mode(ExecMode::Vectorized);
        match EnvPool::make(cfg) {
            Err(Error::Config(msg)) => assert!(msg.contains("num_chunks"), "{msg}"),
            other => panic!("expected Config rejection, got {:?}", other.map(|_| ())),
        }
        // Sync mode with the same shape is fine.
        let cfg = PoolConfig::new("CartPole-v1")
            .num_envs(9)
            .batch_size(9)
            .num_threads(2)
            .exec_mode(ExecMode::Vectorized);
        assert!(EnvPool::make(cfg).is_ok());
    }

    #[test]
    fn vectorized_mode_runs_atari_kernels_too() {
        // Non-classic tasks route through real batch kernels (AtariVec).
        let cfg = PoolConfig::new("Pong-v5")
            .num_envs(2)
            .batch_size(2)
            .num_threads(2)
            .seed(1)
            .exec_mode(ExecMode::Vectorized);
        let mut pool = EnvPool::make(cfg).unwrap();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        for _ in 0..3 {
            let actions = vec![0.0f32; 2];
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            assert!(out.obs.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn wrapper_stack_applies_in_both_exec_modes() {
        // A time limit the bare task doesn't have (Pendulum truncates at
        // 200 natively) must show up through the pool identically in
        // Scalar and Vectorized modes.
        let run = |mode: ExecMode| -> (Vec<f32>, Vec<u8>) {
            let cfg = PoolConfig::new("Pendulum-v1")
                .num_envs(4)
                .batch_size(4)
                .num_threads(2)
                .seed(3)
                .exec_mode(mode)
                .wrappers(crate::envs::WrapConfig {
                    time_limit: Some(6),
                    reward_clip: true,
                    normalize_obs: true,
                    ..crate::envs::WrapConfig::none()
                });
            let mut pool = EnvPool::make(cfg).unwrap();
            assert_eq!(pool.spec().max_episode_steps, 6);
            let mut out = pool.make_output();
            pool.reset_into(&mut out).unwrap();
            let mut rew = Vec::new();
            let mut trunc = Vec::new();
            for _ in 0..20 {
                let ids = out.env_ids.clone();
                let actions = vec![0.5f32; ids.len()];
                pool.step_into(&actions, &ids, &mut out).unwrap();
                let mut order: Vec<usize> = (0..out.len()).collect();
                order.sort_by_key(|&k| out.env_ids[k]);
                for &k in &order {
                    rew.push(out.rew[k]);
                    trunc.push(out.trunc[k]);
                    assert!(out.obs_row(k).iter().all(|x| x.abs() <= 10.0), "normalized");
                }
            }
            (rew, trunc)
        };
        let (sr, st) = run(ExecMode::Scalar);
        let (vr, vt) = run(ExecMode::Vectorized);
        assert!(sr.iter().all(|&r| r == 0.0 || r == -1.0), "clipped rewards");
        assert!(st.iter().any(|&t| t != 0), "time limit must truncate");
        assert_eq!(sr, vr, "wrapped rewards diverge between exec modes");
        assert_eq!(st, vt, "wrapped truncations diverge between exec modes");
    }

    #[test]
    fn scenario_pool_round_trips_in_both_exec_modes() {
        // A ragged two-group scenario must run behind the same facade:
        // union-width rows, zero padding past each group's own width.
        let sc = crate::config::ScenarioConfig::parse(
            "[group]\ntask = CartPole-v1\ncount = 3\n\
             [group]\ntask = Pendulum-v1\ncount = 2\n",
        )
        .unwrap();
        for mode in [ExecMode::Scalar, ExecMode::Vectorized] {
            let cfg = PoolConfig::new("ignored")
                .scenario(sc.clone())
                .num_threads(2)
                .seed(5)
                .exec_mode(mode)
                .sync();
            let mut pool = EnvPool::make(cfg).unwrap();
            assert!(pool.spec().is_grouped());
            assert_eq!(pool.spec().obs_dim(), 4);
            let mut out = pool.make_output();
            pool.reset_into(&mut out).unwrap();
            assert_eq!(out.len(), 5);
            for _ in 0..30 {
                let ids = out.env_ids.clone();
                let actions = vec![0.0f32; ids.len()];
                pool.step_into(&actions, &ids, &mut out).unwrap();
                for (k, &id) in out.env_ids.iter().enumerate() {
                    assert!(out.obs_row(k).iter().all(|x| x.is_finite()));
                    if id >= 3 {
                        // Pendulum rows: 3 live lanes + exact 0.0 pad.
                        assert_eq!(out.obs_row(k)[3], 0.0, "mode {mode:?} env {id}");
                    }
                }
            }
        }
    }

    #[test]
    fn scenario_rejects_inconsistent_config() {
        let sc = crate::config::ScenarioConfig::parse(
            "[group]\ntask = CartPole-v1\ncount = 2\n",
        )
        .unwrap();
        // num_envs overridden after .scenario() must be rejected.
        let cfg = PoolConfig::new("x").scenario(sc.clone()).num_envs(7).sync();
        assert!(EnvPool::make(cfg).is_err());
        // Pool-level wrappers cannot combine with a scenario.
        let cfg = PoolConfig::new("x")
            .scenario(sc)
            .wrappers(crate::envs::WrapConfig { reward_clip: true, ..Default::default() })
            .sync();
        assert!(EnvPool::make(cfg).is_err());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Same seed, same per-env actions => same rewards regardless of
        // worker parallelism (RNG streams are per-env).
        let run = |threads: usize| -> Vec<f32> {
            let cfg =
                PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(threads).seed(11);
            let mut pool = EnvPool::make(cfg).unwrap();
            let mut out = pool.make_output();
            pool.reset_into(&mut out).unwrap();
            let mut rewards = vec![0.0f32; 4];
            for step in 0..60 {
                let ids = out.env_ids.clone();
                let actions: Vec<f32> = ids.iter().map(|&i| ((step + i as usize) % 2) as f32).collect();
                pool.step_into(&actions, &ids, &mut out).unwrap();
                for (k, &id) in out.env_ids.iter().enumerate() {
                    rewards[id as usize] += out.rew[k] * (step as f32 + 1.0);
                }
            }
            rewards
        };
        assert_eq!(run(1), run(3));
    }
}
