//! Chunked worker pool: the vectorized execution backend
//! (`ExecMode::Vectorized`).
//!
//! Each task in the action queue is a whole **chunk** of `K` envs rather
//! than a single env, so one semaphore wake, one task dequeue, and one
//! (uncontended) mutex pair serve `K` environment steps. The chunk's
//! [`VecEnv`] backend steps all lanes in one call and writes every
//! observation **directly into an acquired state-queue slot** (via
//! [`StateBufferQueue::slot_obs_mut`]) — the paper's zero-copy invariant
//! is preserved end to end.
//!
//! Homogeneous pools use chunk size `K = ceil(num_envs / num_threads)`
//! (see the chunking math in [`crate::envs::vector`]); heterogeneous
//! scenario pools build **one chunk per lane group** (a chunk never
//! splits a group), so chunk lengths and per-chunk action/observation
//! widths may vary. Routing is therefore a precomputed `env →
//! (chunk, lane)` table rather than division, and each chunk stages
//! actions at **its own** kernel stride while the pool-level buffers
//! run at the union stride; observation rows narrower than the state
//! queue's are zero-padded at the write site. A chunk becomes runnable
//! when all of its member envs have a pending action — the per-env "at
//! most one outstanding action" protocol makes a simple atomic counter
//! sufficient.
//!
//! All-lanes-or-nothing dispatch constrains asynchronous mode: with
//! `batch_size > num_chunks`, every chunk can be left partially armed
//! while the state queue's incomplete tail block withholds the missing
//! results — a cycle nothing breaks. `EnvPool::make` therefore rejects
//! vectorized async configs with `batch_size > num_chunks` (sync mode,
//! where sends always arm whole chunks, is exempt).

use super::action_queue::ActionBufferQueue;
use super::state_queue::{SlotTicket, StateBufferQueue};
use super::thread_pool::pin_to_core;
use crate::envs::env::Step;
use crate::envs::vector::{ObsArena, VecEnv};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A task for a chunked worker.
#[derive(Debug, Clone)]
pub enum ChunkTask {
    /// Step every env in chunk `chunk` with its staged actions.
    Step { chunk: u32 },
    /// Reset every env in chunk `chunk` and report initial observations.
    Reset { chunk: u32 },
    /// Terminate the receiving worker.
    Shutdown,
}

/// Mutable per-chunk execution state. Touched by at most one worker at a
/// time (a chunk has at most one outstanding task), so the mutex around
/// it is uncontended.
struct ChunkState {
    envs: Box<dyn VecEnv>,
    /// Lane finished last step and must auto-reset on its next action.
    needs_reset: Vec<u8>,
    /// Acquired state-queue slots for the in-flight batch (reused).
    tickets: Vec<SlotTicket>,
    /// Per-lane step results scratch (reused).
    results: Vec<Step>,
}

/// One chunk of `len` envs starting at global id `first_env`.
pub struct Chunk {
    state: Mutex<ChunkState>,
    /// Staged actions, row-major `[len, act_dim]` (written by `send`).
    actions: Mutex<Vec<f32>>,
    /// Envs with a staged action since the last dispatch.
    pending: AtomicUsize,
    first_env: u32,
    len: usize,
    /// This chunk's kernel action width (= pool width for homogeneous
    /// pools; may be narrower than the union in a scenario pool).
    act_dim: usize,
    /// This chunk's kernel observation width (queue rows at the union
    /// width are zero-padded past it).
    obs_dim: usize,
}

impl Chunk {
    /// Wrap a vector backend as a dispatchable chunk. Action and
    /// observation widths come from the backend's own spec.
    pub fn new(envs: Box<dyn VecEnv>, first_env: u32) -> Chunk {
        let len = envs.num_envs();
        let act_dim = envs.spec().action_space.dim();
        let obs_dim = envs.spec().obs_dim();
        Chunk {
            state: Mutex::new(ChunkState {
                envs,
                needs_reset: vec![0; len],
                tickets: Vec::with_capacity(len),
                results: vec![Step::default(); len],
            }),
            actions: Mutex::new(vec![0.0; len * act_dim]),
            pending: AtomicUsize::new(0),
            first_env,
            len,
            act_dim,
            obs_dim,
        }
    }
}

/// [`ObsArena`] over acquired state-queue slots: lane `l`'s observation
/// row is ticket `l`'s block memory, truncated to the chunk's own
/// observation width with the union padding tail zero-filled (a no-op
/// slice for homogeneous pools, where `dim` equals the row width).
struct QueueArena<'a> {
    queue: &'a StateBufferQueue,
    tickets: &'a [SlotTicket],
    dim: usize,
}

impl ObsArena for QueueArena<'_> {
    #[inline]
    fn row(&mut self, lane: usize) -> &mut [f32] {
        // Safety: each ticket was freshly acquired for this batch and is
        // committed exactly once after the kernel finishes; rows of
        // distinct tickets are disjoint.
        let r = unsafe { self.queue.slot_obs_mut(self.tickets[lane]) };
        r[self.dim..].fill(0.0);
        &mut r[..self.dim]
    }
}

/// Worker pool for `ExecMode::Vectorized`. Owns the chunk table and the
/// chunk-task queue; dropping shuts workers down.
pub struct ChunkedThreadPool {
    handles: Vec<JoinHandle<()>>,
    queue: Arc<ActionBufferQueue<ChunkTask>>,
    chunks: Arc<Vec<Chunk>>,
    chunk_size: usize,
    /// Pool-level (union) action stride of the caller's flat buffers.
    act_dim: usize,
    /// Global env id → owning chunk (supports the ragged chunk lengths
    /// of scenario pools; for homogeneous pools this is just `e / K`).
    env_to_chunk: Vec<u32>,
    /// Total env steps executed (throughput accounting).
    pub steps: Arc<AtomicU64>,
}

impl ChunkedThreadPool {
    /// Spawn workers over `chunks`. `chunk_size` is the uniform size of
    /// every chunk but the last (used for id routing).
    ///
    /// The worker count is clamped to the chunk count: a chunk is the
    /// unit of dispatch, so with `num_envs < num_threads` the chunk math
    /// `K = ceil(N / threads)` yields fewer chunks than requested
    /// workers, and any surplus worker would sit pinned to a core doing
    /// nothing forever. (Zero environments never reach this layer —
    /// `PoolConfig::validate` and `registry::make_vec_env` reject them
    /// with a config error.)
    pub fn spawn(
        num_threads: usize,
        chunks: Vec<Chunk>,
        states: Arc<StateBufferQueue>,
        chunk_size: usize,
        act_dim: usize,
        pin_cores: bool,
    ) -> ChunkedThreadPool {
        let num_threads = num_threads.clamp(1, chunks.len().max(1));
        let queue = Arc::new(ActionBufferQueue::new(2 * chunks.len() + num_threads));
        let mut env_to_chunk = Vec::new();
        for (c, chunk) in chunks.iter().enumerate() {
            assert_eq!(
                chunk.first_env as usize,
                env_to_chunk.len(),
                "chunks must cover env ids contiguously"
            );
            env_to_chunk.extend(std::iter::repeat(c as u32).take(chunk.len));
        }
        let chunks = Arc::new(chunks);
        let steps = Arc::new(AtomicU64::new(0));
        let handles = (0..num_threads)
            .map(|i| {
                let chunks = chunks.clone();
                let queue = queue.clone();
                let states = states.clone();
                let steps = steps.clone();
                std::thread::Builder::new()
                    .name(format!("envpool-chunk-{i}"))
                    .spawn(move || {
                        if pin_cores {
                            pin_to_core(i);
                        }
                        worker_loop(&chunks, &queue, &states, &steps);
                    })
                    .expect("spawn chunk worker")
            })
            .collect();
        ChunkedThreadPool { handles, queue, chunks, chunk_size, act_dim, env_to_chunk, steps }
    }

    pub fn num_threads(&self) -> usize {
        self.handles.len()
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Stage one action row per env id and dispatch every chunk whose
    /// members all have a pending action. Ids must be in range (the
    /// facade validates) and each env must have no other action in
    /// flight (the pool protocol). Consecutive ids belonging to the same
    /// chunk are staged under one lock and counted with one atomic RMW,
    /// so a sync-mode send costs one lock/RMW pair per *chunk*, not per
    /// env (chunk members complete — and are therefore re-sent —
    /// together).
    pub fn send_actions(&self, actions: &[f32], env_ids: &[u32]) {
        // Caller rows run at the pool (union) stride; each chunk stages
        // at its kernel's own stride — extra union lanes are padding a
        // narrower kernel never reads.
        let src = self.act_dim;
        let mut k = 0;
        while k < env_ids.len() {
            let c = self.env_to_chunk[env_ids[k] as usize] as usize;
            let chunk = &self.chunks[c];
            let dst = chunk.act_dim;
            let start = k;
            while k < env_ids.len() && self.env_to_chunk[env_ids[k] as usize] as usize == c {
                k += 1;
            }
            {
                let mut slot = chunk.actions.lock().unwrap();
                for j in start..k {
                    let lane = (env_ids[j] - chunk.first_env) as usize;
                    slot[lane * dst..(lane + 1) * dst]
                        .copy_from_slice(&actions[j * src..j * src + dst]);
                }
            }
            let run = k - start;
            let filled = chunk.pending.fetch_add(run, Ordering::AcqRel) + run;
            debug_assert!(filled <= chunk.len, "env sent twice without recv");
            if filled == chunk.len {
                // All members armed; no further sends for these envs can
                // arrive until their results are received, so the reset
                // cannot race with another increment.
                chunk.pending.store(0, Ordering::Relaxed);
                self.queue.blocking_enqueue(ChunkTask::Step { chunk: c as u32 });
            }
        }
    }

    /// Schedule a reset of every chunk (the pool's `async_reset`).
    pub fn schedule_reset_all(&self) {
        for c in 0..self.chunks.len() {
            self.queue.blocking_enqueue(ChunkTask::Reset { chunk: c as u32 });
        }
    }

    /// Ask all workers to exit and join them.
    pub fn shutdown(&mut self) {
        for _ in 0..self.handles.len() {
            self.queue.blocking_enqueue(ChunkTask::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ChunkedThreadPool {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown();
        }
    }
}

fn worker_loop(
    chunks: &[Chunk],
    queue: &ActionBufferQueue<ChunkTask>,
    states: &StateBufferQueue,
    steps: &AtomicU64,
) {
    // A panic below (kernel step/reset) would leave this chunk's slots
    // forever uncommitted; poison the queue so the consumer and the
    // other workers error out instead of spinning.
    let _poison = states.poison_guard();
    loop {
        match queue.dequeue() {
            ChunkTask::Shutdown => return,
            ChunkTask::Reset { chunk } => {
                let c = &chunks[chunk as usize];
                let mut st = c.state.lock().unwrap();
                let st = &mut *st;
                for lane in 0..c.len {
                    // None = queue closed mid-teardown: stop producing.
                    let Some(t) = states.acquire() else { return };
                    // Safety: fresh ticket, committed immediately below.
                    let obs = unsafe { states.slot_obs_mut(t) };
                    obs[c.obs_dim..].fill(0.0);
                    st.envs.reset_lane(lane, &mut obs[..c.obs_dim]);
                    st.needs_reset[lane] = 0;
                    states.commit(t, c.first_env + lane as u32, 0.0, false, false);
                }
            }
            ChunkTask::Step { chunk } => {
                let c = &chunks[chunk as usize];
                let mut st = c.state.lock().unwrap();
                let st = &mut *st;
                st.tickets.clear();
                for _ in 0..c.len {
                    let Some(t) = states.acquire() else { return };
                    st.tickets.push(t);
                }
                {
                    let actions = c.actions.lock().unwrap();
                    let mut arena =
                        QueueArena { queue: states, tickets: &st.tickets, dim: c.obs_dim };
                    st.envs.step_batch(&actions, &st.needs_reset, &mut arena, &mut st.results);
                }
                for lane in 0..c.len {
                    let s = st.results[lane];
                    st.needs_reset[lane] = s.finished() as u8;
                    states.commit(
                        st.tickets[lane],
                        c.first_env + lane as u32,
                        s.reward,
                        s.done,
                        s.truncated,
                    );
                }
                steps.fetch_add(c.len as u64, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry;

    #[test]
    fn chunked_pool_round_trips_directly() {
        // Drive the chunk pool without the EnvPool facade: 4 envs in 2
        // chunks, sync-style full batches.
        let n = 4;
        let chunk_size = 2;
        let states = Arc::new(StateBufferQueue::new(n, n, 4));
        let chunks: Vec<Chunk> = (0..2)
            .map(|c| {
                let envs =
                    registry::make_vec_env("CartPole-v1", 7, (c * chunk_size) as u64, chunk_size)
                        .unwrap();
                Chunk::new(envs, (c * chunk_size) as u32)
            })
            .collect();
        let mut pool = ChunkedThreadPool::spawn(2, chunks, states.clone(), chunk_size, 1, false);
        pool.schedule_reset_all();
        let mut out = crate::pool::batch::BatchedTransition::with_capacity(n, 4);
        states.recv_into(&mut out).unwrap();
        assert_eq!(out.len(), n);
        for _ in 0..50 {
            let actions = vec![1.0f32; n];
            let ids = out.env_ids.clone();
            pool.send_actions(&actions, &ids);
            states.recv_into(&mut out).unwrap();
            assert!(out.obs.iter().all(|x| x.is_finite()));
        }
        assert_eq!(pool.steps.load(Ordering::Relaxed), 50 * n as u64);
        pool.shutdown();
    }

    #[test]
    fn worker_count_is_clamped_to_chunk_count() {
        // 2 chunks but 8 requested workers: only 2 may spawn (no idle
        // pinned threads), and the pool must still round-trip.
        let n = 4;
        let chunk_size = 2;
        let states = Arc::new(StateBufferQueue::new(n, n, 4));
        let chunks: Vec<Chunk> = (0..2)
            .map(|c| {
                let envs =
                    registry::make_vec_env("CartPole-v1", 3, (c * chunk_size) as u64, chunk_size)
                        .unwrap();
                Chunk::new(envs, (c * chunk_size) as u32)
            })
            .collect();
        let mut pool = ChunkedThreadPool::spawn(8, chunks, states.clone(), chunk_size, 1, false);
        assert_eq!(pool.num_threads(), 2, "workers clamped to chunk count");
        assert_eq!(pool.num_chunks(), 2);
        pool.schedule_reset_all();
        let mut out = crate::pool::batch::BatchedTransition::with_capacity(n, 4);
        states.recv_into(&mut out).unwrap();
        assert_eq!(out.len(), n);
        for _ in 0..10 {
            let ids = out.env_ids.clone();
            pool.send_actions(&vec![1.0f32; n], &ids);
            states.recv_into(&mut out).unwrap();
            assert!(out.obs.iter().all(|x| x.is_finite()));
        }
        pool.shutdown();
    }
}
