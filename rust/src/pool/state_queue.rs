//! StateBufferQueue (paper Appendix D.2): a circular queue of
//! pre-allocated blocks, each holding `batch_size` transition slots.
//!
//! A worker that finishes an env step *acquires* a slot with one atomic
//! fetch-add and writes the observation directly into the block's memory
//! (first come, first served) — there is no collect-then-batch copy.
//! When a block's write count reaches `batch_size` it is published to the
//! consumer whole; `recv_into` swaps the block's buffers with the
//! caller's recycled ones, which is the Rust equivalent of the paper's
//! "ownership of the block is transferred to Python".
//!
//! Blocks complete in allocation order (slots are acquired *after* the
//! env step finishes and written immediately), so consumption is FIFO.
//!
//! Both hot paths spin: `acquire` until its block is recycled, the
//! consumer until its block fills. A writer that panics mid-round (its
//! slot never commits) or a pool torn down with slots in flight would
//! leave either spin with nothing to wait for, so the queue carries a
//! `shutdown` flag: [`StateBufferQueue::close`] (or a writer-side
//! [`StateBufferQueue::poison_guard`] unwinding) makes `acquire` return
//! `None` and `recv_into` return [`Error::Closed`] instead of hanging.

use super::batch::BatchedTransition;
use super::sem::Semaphore;
use crate::{Error, Result};
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Duration;

struct Block {
    /// Generation counter: block is writable for global round `gen`.
    gen: AtomicUsize,
    /// Slots committed so far in the current round.
    written: AtomicUsize,
    data: UnsafeCell<BatchedTransition>,
}

unsafe impl Sync for Block {}

/// The block-structured state queue.
pub struct StateBufferQueue {
    blocks: Vec<Block>,
    batch_size: usize,
    obs_dim: usize,
    /// Global slot allocation cursor (slot -> block via div/mod).
    alloc_pos: AtomicUsize,
    /// Next block round to consume (single consumer).
    consume_pos: AtomicUsize,
    ready: Semaphore,
    /// Closed or poisoned: both spin loops bail out instead of waiting
    /// for progress that can no longer happen.
    shutdown: AtomicBool,
}

/// An acquired slot: write target for exactly one transition.
#[derive(Debug, Clone, Copy)]
pub struct SlotTicket {
    block: usize,
    slot: usize,
}

/// RAII guard for writer threads: if the holder unwinds (env step or
/// kernel panic), `Drop` poisons the queue so the consumer and the other
/// writers error out instead of spinning on a round that will never
/// complete.
pub struct PoisonGuard<'a> {
    q: &'a StateBufferQueue,
}

impl Drop for PoisonGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.q.close();
        }
    }
}

impl StateBufferQueue {
    /// `num_envs` bounds the number of in-flight transitions; block count
    /// is sized so a worker can always acquire a slot as long as the
    /// consumer keeps up (paper pre-allocates on the same reasoning).
    pub fn new(num_envs: usize, batch_size: usize, obs_dim: usize) -> Self {
        assert!(batch_size >= 1 && batch_size <= num_envs);
        let num_blocks = num_envs.div_ceil(batch_size) + 2;
        let blocks = (0..num_blocks)
            .map(|i| Block {
                gen: AtomicUsize::new(i), // block i serves round i first
                written: AtomicUsize::new(0),
                data: UnsafeCell::new(BatchedTransition::with_capacity(batch_size, obs_dim)),
            })
            .collect();
        StateBufferQueue {
            blocks,
            batch_size,
            obs_dim,
            alloc_pos: AtomicUsize::new(0),
            consume_pos: AtomicUsize::new(0),
            ready: Semaphore::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Mark the queue closed (teardown) or poisoned (writer panic) and
    /// wake every blocked consumer. Idempotent. After this, `acquire`
    /// returns `None` and the recv family returns [`Error::Closed`].
    pub fn close(&self) {
        self.shutdown.store(true, Ordering::Release);
        // Flood the semaphore so every present and future waiter wakes.
        self.ready.post_n(1 << 20);
    }

    pub fn is_closed(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Writer-side panic hook: hold this for the scope that steps envs
    /// and writes slots; an unwind poisons the queue (see [`PoisonGuard`]).
    pub fn poison_guard(&self) -> PoisonGuard<'_> {
        PoisonGuard { q: self }
    }

    /// Acquire the next free slot (first come, first served). Spins (with
    /// yield) in the rare case every block is still owned by the consumer.
    /// Returns `None` once the queue is closed or poisoned — callers must
    /// stop producing.
    pub fn acquire(&self) -> Option<SlotTicket> {
        if self.is_closed() {
            return None;
        }
        let g = self.alloc_pos.fetch_add(1, Ordering::Relaxed);
        let round = g / self.batch_size;
        let block = round % self.blocks.len();
        let slot = g % self.batch_size;
        // Wait until the block has been recycled up to our round.
        let mut spins = 0u32;
        while self.blocks[block].gen.load(Ordering::Acquire) != round {
            if self.is_closed() {
                return None;
            }
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        Some(SlotTicket { block, slot })
    }

    /// Write a transition into an acquired slot. `fill` writes the
    /// observation directly into block memory and returns the
    /// `(reward, done, truncated)` scalars — this is where the env step
    /// itself runs, so the observation never exists anywhere else.
    pub fn write_with(
        &self,
        t: SlotTicket,
        env_id: u32,
        fill: impl FnOnce(&mut [f32]) -> (f32, bool, bool),
    ) {
        let b = &self.blocks[t.block];
        // Safety: slot indices within a round are unique (fetch-add), and
        // the generation check in acquire() guarantees the consumer is
        // not holding this block.
        unsafe {
            let data = &mut *b.data.get();
            let o = t.slot * self.obs_dim;
            let (rew, done, trunc) = fill(&mut data.obs[o..o + self.obs_dim]);
            data.rew[t.slot] = rew;
            data.done[t.slot] = done as u8;
            data.trunc[t.slot] = trunc as u8;
            data.env_ids[t.slot] = env_id;
        }
        let prev = b.written.fetch_add(1, Ordering::AcqRel);
        if prev + 1 == self.batch_size {
            self.ready.post();
        }
    }

    /// Direct access to an acquired slot's observation row, for writers
    /// that fill several slots before committing any (the vectorized
    /// chunk path: kernels write each lane's observation straight into
    /// block memory, then [`Self::commit`] publishes the scalars).
    ///
    /// # Safety
    ///
    /// `t` must come from [`Self::acquire`] on this queue, must not yet
    /// have been committed, and no other alias of this slot's row may be
    /// live. Slot uniqueness (one `acquire` → one writer) makes distinct
    /// tickets' rows disjoint; the generation check in `acquire`
    /// guarantees the consumer is not holding the block.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slot_obs_mut(&self, t: SlotTicket) -> &mut [f32] {
        let data = &mut *self.blocks[t.block].data.get();
        let o = t.slot * self.obs_dim;
        &mut data.obs[o..o + self.obs_dim]
    }

    /// Publish an acquired slot whose observation was already written in
    /// place (via [`Self::slot_obs_mut`]): store the scalar lanes and
    /// count the slot toward block completion. Exactly one `commit` (or
    /// `write`/`write_with`) per acquired ticket.
    pub fn commit(&self, t: SlotTicket, env_id: u32, rew: f32, done: bool, trunc: bool) {
        let b = &self.blocks[t.block];
        // Safety: same argument as `write_with` — the ticket is uniquely
        // owned and the consumer cannot hold this block.
        unsafe {
            let data = &mut *b.data.get();
            data.rew[t.slot] = rew;
            data.done[t.slot] = done as u8;
            data.trunc[t.slot] = trunc as u8;
            data.env_ids[t.slot] = env_id;
        }
        let prev = b.written.fetch_add(1, Ordering::AcqRel);
        if prev + 1 == self.batch_size {
            self.ready.post();
        }
    }

    /// Convenience wrapper over [`Self::write_with`] for pre-computed
    /// scalars.
    pub fn write(
        &self,
        t: SlotTicket,
        env_id: u32,
        rew: f32,
        done: bool,
        trunc: bool,
        fill_obs: impl FnOnce(&mut [f32]),
    ) {
        self.write_with(t, env_id, |obs| {
            fill_obs(obs);
            (rew, done, trunc)
        });
    }

    /// Consumer side: wait for the next block (FIFO) and swap its payload
    /// into `out` (which must have been created by
    /// [`BatchedTransition::with_capacity`] with matching sizes, or have
    /// come from a previous `recv_into`). Zero copies, zero allocation.
    /// Errors with [`Error::Closed`] once the queue is closed/poisoned.
    pub fn recv_into(&self, out: &mut BatchedTransition) -> Result<()> {
        self.ready.wait();
        self.take_ready(out)
    }

    /// Timed variant; `Ok(false)` if nothing became ready in `d`.
    pub fn recv_into_timeout(&self, out: &mut BatchedTransition, d: Duration) -> Result<bool> {
        if !self.ready.wait_timeout(d) {
            if self.is_closed() {
                return Err(Error::Closed);
            }
            return Ok(false);
        }
        self.take_ready(out)?;
        Ok(true)
    }

    fn take_ready(&self, out: &mut BatchedTransition) -> Result<()> {
        if self.is_closed() {
            return Err(Error::Closed);
        }
        let round = self.consume_pos.fetch_add(1, Ordering::Relaxed);
        let bi = round % self.blocks.len();
        let b = &self.blocks[bi];
        // Blocks complete in order; the posted permit may belong to a
        // later block in rare interleavings, so wait for ours.
        let mut spins = 0u32;
        while b.written.load(Ordering::Acquire) < self.batch_size {
            if self.is_closed() {
                // A writer panicked mid-round or the pool is tearing
                // down: this block will never fill.
                return Err(Error::Closed);
            }
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        debug_assert_eq!(b.gen.load(Ordering::Relaxed), round);
        // Safety: all writers for this round have committed (written ==
        // batch_size with Acquire), and no writer for a later round can
        // touch the block until we bump `gen` below.
        unsafe {
            let data = &mut *b.data.get();
            std::mem::swap(data, out);
            debug_assert_eq!(out.rew.len(), self.batch_size);
        }
        b.written.store(0, Ordering::Relaxed);
        b.gen.store(round + self.blocks.len(), Ordering::Release);
        Ok(())
    }

    /// A correctly-sized reusable output buffer.
    pub fn make_output(&self) -> BatchedTransition {
        BatchedTransition::with_capacity(self.batch_size, self.obs_dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_thread_round_trip() {
        let q = StateBufferQueue::new(4, 2, 3);
        for i in 0..4u32 {
            let t = q.acquire().unwrap();
            q.write(t, i, i as f32, false, false, |obs| {
                obs.fill(i as f32);
            });
        }
        let mut out = q.make_output();
        q.recv_into(&mut out).unwrap();
        assert_eq!(out.env_ids, vec![0, 1]);
        assert_eq!(out.obs_row(1), &[1.0, 1.0, 1.0]);
        q.recv_into(&mut out).unwrap();
        assert_eq!(out.env_ids, vec![2, 3]);
        assert_eq!(out.rew, vec![2.0, 3.0]);
    }

    #[test]
    fn blocks_recycle_many_rounds() {
        let q = StateBufferQueue::new(4, 2, 1);
        let mut out = q.make_output();
        for round in 0..50u32 {
            for k in 0..2u32 {
                let t = q.acquire().unwrap();
                q.write(t, k, (round * 2 + k) as f32, false, false, |o| o[0] = round as f32);
            }
            q.recv_into(&mut out).unwrap();
            assert_eq!(out.rew, vec![(round * 2) as f32, (round * 2 + 1) as f32]);
            assert_eq!(out.obs, vec![round as f32, round as f32]);
        }
    }

    #[test]
    fn concurrent_writers_fill_blocks() {
        let q = Arc::new(StateBufferQueue::new(16, 4, 8));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        let t = q.acquire().unwrap();
                        q.write(t, w * 1000 + i, 1.0, false, false, |obs| {
                            obs.fill((w * 1000 + i) as f32);
                        });
                    }
                })
            })
            .collect();
        let mut out = q.make_output();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            q.recv_into(&mut out).unwrap();
            for i in 0..out.len() {
                let id = out.env_ids[i];
                assert!(seen.insert(id), "duplicate env_id {id}");
                assert!(out.obs_row(i).iter().all(|&x| x == id as f32), "torn obs write");
            }
        }
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(seen.len(), 400);
    }

    #[test]
    fn slot_obs_then_commit_roundtrip() {
        // The two-phase write path used by the vectorized chunk workers:
        // observations land in block memory first, commits can arrive in
        // any order within the block.
        let q = StateBufferQueue::new(2, 2, 3);
        let t0 = q.acquire().unwrap();
        let t1 = q.acquire().unwrap();
        unsafe { q.slot_obs_mut(t0) }.fill(7.0);
        unsafe { q.slot_obs_mut(t1) }.fill(9.0);
        q.commit(t1, 1, -1.0, false, true);
        q.commit(t0, 0, 1.0, true, false);
        let mut out = q.make_output();
        q.recv_into(&mut out).unwrap();
        assert_eq!(out.obs_row(0), &[7.0, 7.0, 7.0]);
        assert_eq!(out.obs_row(1), &[9.0, 9.0, 9.0]);
        assert_eq!(out.rew, vec![1.0, -1.0]);
        assert_eq!(out.done, vec![1, 0]);
        assert_eq!(out.trunc, vec![0, 1]);
        assert_eq!(out.env_ids, vec![0, 1]);
    }

    #[test]
    fn timeout_when_incomplete() {
        let q = StateBufferQueue::new(4, 2, 1);
        let t = q.acquire().unwrap();
        q.write(t, 0, 0.0, false, false, |o| o[0] = 0.0);
        // only 1 of 2 slots written
        let mut out = q.make_output();
        assert!(!q.recv_into_timeout(&mut out, Duration::from_millis(10)).unwrap());
    }

    #[test]
    fn done_and_trunc_flags_roundtrip() {
        let q = StateBufferQueue::new(2, 2, 1);
        let t = q.acquire().unwrap();
        q.write(t, 0, 1.0, true, false, |o| o[0] = 0.0);
        let t = q.acquire().unwrap();
        q.write(t, 1, -1.0, false, true, |o| o[0] = 0.0);
        let mut out = q.make_output();
        q.recv_into(&mut out).unwrap();
        assert_eq!(out.done, vec![1, 0]);
        assert_eq!(out.trunc, vec![0, 1]);
        assert!(out.finished(0) && out.finished(1));
    }

    #[test]
    fn close_errors_blocked_and_future_receivers() {
        let q = Arc::new(StateBufferQueue::new(4, 2, 1));
        // Half-written round: without close(), recv would wait forever.
        let t = q.acquire().unwrap();
        q.write(t, 0, 0.0, false, false, |o| o[0] = 0.0);
        let waiter = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut out = q.make_output();
                q.recv_into(&mut out)
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        let r = waiter.join().unwrap();
        assert!(matches!(r, Err(Error::Closed)), "blocked recv must error on close");
        // And every later call errors immediately instead of spinning.
        let mut out = q.make_output();
        assert!(matches!(q.recv_into(&mut out), Err(Error::Closed)));
        assert!(matches!(
            q.recv_into_timeout(&mut out, Duration::from_millis(1)),
            Err(Error::Closed)
        ));
        assert!(q.acquire().is_none(), "acquire after close must refuse slots");
    }

    #[test]
    fn acquire_spin_bails_out_on_close() {
        // Exhaust every block so the next acquire spins waiting for the
        // consumer, then close from another thread: the spinner must
        // return None, not hang.
        let q = Arc::new(StateBufferQueue::new(2, 1, 1));
        let capacity = q.num_blocks(); // slots == blocks at batch_size 1
        for i in 0..capacity as u32 {
            let t = q.acquire().unwrap();
            q.write(t, i, 0.0, false, false, |o| o[0] = 0.0);
        }
        let spinner = {
            let q = q.clone();
            std::thread::spawn(move || q.acquire().is_none())
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(spinner.join().unwrap(), "spinning acquire must bail out on close");
    }

    #[test]
    fn panicking_writer_poisons_the_queue() {
        let q = Arc::new(StateBufferQueue::new(4, 2, 1));
        let writer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let _guard = q.poison_guard();
                let t = q.acquire().unwrap();
                q.write(t, 0, 0.0, false, false, |o| o[0] = 0.0);
                panic!("env step exploded");
            })
        };
        assert!(writer.join().is_err());
        // The round is half-written and will never complete; the poison
        // flag turns the would-be hang into an error.
        let mut out = q.make_output();
        assert!(matches!(q.recv_into(&mut out), Err(Error::Closed)));
        assert!(q.acquire().is_none());
    }
}
