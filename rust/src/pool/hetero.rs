//! Heterogeneous scenario pools: several task groups behind one
//! [`VecEnv`].
//!
//! A scenario ([`crate::config::ScenarioConfig`]) declares contiguous
//! *lane groups* — `{task, count, wrappers, seed, physics overrides}` —
//! and the registry builds each group as the task's real full-width
//! kernel ([`crate::envs::registry::make_scenario_group`]). This module
//! composes those per-group kernels into a single pool backend:
//!
//! - [`GroupedVecEnv`] implements [`VecEnv`] over the **union spec**
//!   (widest observation/action across groups; see
//!   [`crate::envs::registry::scenario_spec`]). Global env id `e` maps
//!   to `(group, lane)` through a precomputed table; groups are
//!   contiguous runs, so a group's slice of the global `reset_mask` /
//!   `out` arrays needs no staging. Observations are written **ragged
//!   through the caller's arena**: each group sees a [`GroupArena`]
//!   view that offsets rows by the group's first env id, hands the
//!   kernel only the group's own width, and zero-fills the padding tail
//!   — kernels stay allocation-free and never learn about the union.
//! - [`VecLaneEnv`] adapts a one-lane [`VecEnv`] to the scalar
//!   [`Env`] trait, which is how `ExecMode::Scalar` runs scenarios:
//!   each env is lane `l` of its group's kernel built at width 1
//!   ([`crate::envs::registry::make_scenario_env`]). RNG streams are
//!   keyed `(group seed, group-local lane)`, so the scalar and
//!   vectorized scenario engines — and a homogeneous pool of the same
//!   task/seed — produce bitwise-identical episodes
//!   (`tests/scenario.rs` pins the three-way parity).
//!
//! Chunking: the pool's vectorized engine builds **one chunk per
//! group** (never splitting a group across chunks and never fusing two
//! groups), so each group steps on its own worker with its kernel's
//! full lane width — the issue's "chunking never splits a group"
//! invariant.

use crate::envs::env::{Env, Step};
use crate::envs::spec::EnvSpec;
use crate::envs::vector::{ObsArena, SliceArena, VecEnv};
use crate::simd::LanePass;

/// Arena view a group's kernel writes through: rows are offset by the
/// group's first global env id, truncated to the group's own
/// observation width, and the union padding tail is zero-filled on
/// every fetch (idempotent — masked-reset lanes may fetch twice).
struct GroupArena<'a> {
    inner: &'a mut dyn ObsArena,
    first: usize,
    dim: usize,
}

impl ObsArena for GroupArena<'_> {
    #[inline]
    fn row(&mut self, lane: usize) -> &mut [f32] {
        let r = self.inner.row(self.first + lane);
        r[self.dim..].fill(0.0);
        &mut r[..self.dim]
    }
}

/// A heterogeneous pool backend: one [`VecEnv`] kernel per scenario
/// group, composed behind the [`VecEnv`] trait over the scenario's
/// union spec. Built by [`crate::envs::registry::make_scenario_pool`].
pub struct GroupedVecEnv {
    groups: Vec<Box<dyn VecEnv>>,
    /// Union spec; `spec.groups` carries the per-group views.
    spec: EnvSpec,
    /// Global env id → `(group index, group-local lane)`.
    env_to_group: Vec<(u32, u32)>,
    /// Per-group observation width (un-padded).
    obs_dims: Vec<usize>,
    /// Per-group action width (un-padded).
    act_dims: Vec<usize>,
    /// Staging buffer: global actions arrive at the union stride; each
    /// group's kernel wants its own contiguous `[count, act_dim]`.
    act_stage: Vec<f32>,
}

impl GroupedVecEnv {
    /// Compose `backends` (one per view in `spec.groups`, same order)
    /// behind the union `spec`. Panics if the backends disagree with
    /// the views — both come from the registry, so a mismatch is a
    /// construction bug, not a user error.
    pub fn new(backends: Vec<Box<dyn VecEnv>>, spec: EnvSpec) -> Self {
        assert!(spec.is_grouped(), "GroupedVecEnv needs a grouped union spec");
        assert_eq!(backends.len(), spec.groups.len(), "one backend per group view");
        let mut env_to_group = Vec::new();
        let mut obs_dims = Vec::new();
        let mut act_dims = Vec::new();
        for (gi, (b, v)) in backends.iter().zip(&spec.groups).enumerate() {
            assert_eq!(b.num_envs(), v.count, "group {gi} lane count");
            assert_eq!(v.first_env, env_to_group.len(), "group {gi} must be contiguous");
            assert!(v.spec.obs_dim() <= spec.obs_dim(), "union obs must cover group {gi}");
            assert!(
                v.spec.action_space.dim() <= spec.action_space.dim(),
                "union action must cover group {gi}"
            );
            for l in 0..v.count {
                env_to_group.push((gi as u32, l as u32));
            }
            obs_dims.push(v.spec.obs_dim());
            act_dims.push(v.spec.action_space.dim());
        }
        let max_stage = spec
            .groups
            .iter()
            .zip(&act_dims)
            .map(|(v, &d)| v.count * d)
            .max()
            .unwrap();
        GroupedVecEnv {
            groups: backends,
            spec,
            env_to_group,
            obs_dims,
            act_dims,
            act_stage: vec![0.0; max_stage],
        }
    }

    /// Number of groups.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Map a global env id to `(group index, group-local lane)`.
    pub fn locate(&self, env_id: usize) -> (usize, usize) {
        let (g, l) = self.env_to_group[env_id];
        (g as usize, l as usize)
    }

    /// Split into the per-group backends (one chunk per group — the
    /// vectorized pool engine's entry point) together with the union
    /// spec and each group's first global env id.
    pub fn into_group_chunks(self) -> (EnvSpec, Vec<(usize, Box<dyn VecEnv>)>) {
        let firsts: Vec<usize> = self.spec.groups.iter().map(|v| v.first_env).collect();
        (self.spec, firsts.into_iter().zip(self.groups).collect())
    }
}

impl VecEnv for GroupedVecEnv {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.env_to_group.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        for g in &mut self.groups {
            g.set_lane_pass(lane_pass);
        }
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let (g, l) = self.locate(lane);
        let d = self.obs_dims[g];
        obs[d..].fill(0.0);
        self.groups[g].reset_lane(l, &mut obs[..d]);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let union_adim = self.spec.action_space.dim();
        debug_assert_eq!(actions.len(), self.num_envs() * union_adim);
        for (gi, group) in self.groups.iter_mut().enumerate() {
            let first = self.spec.groups[gi].first_env;
            let count = self.spec.groups[gi].count;
            let adim = self.act_dims[gi];
            // Re-stride this group's action rows from the union width
            // to the kernel's own (a no-op copy when they match).
            for l in 0..count {
                let src = (first + l) * union_adim;
                self.act_stage[l * adim..(l + 1) * adim]
                    .copy_from_slice(&actions[src..src + adim]);
            }
            let mut garena =
                GroupArena { inner: arena, first, dim: self.obs_dims[gi] };
            group.step_batch(
                &self.act_stage[..count * adim],
                &reset_mask[first..first + count],
                &mut garena,
                &mut out[first..first + count],
            );
        }
    }
}

/// Scalar [`Env`] view over a one-lane [`VecEnv`] kernel — how
/// `ExecMode::Scalar` runs scenario envs without a scalar twin of the
/// parameterized kernels. The spec it reports is the **group's own**
/// (un-padded); the scalar pool pads rows to the union width at its
/// write site.
pub struct VecLaneEnv {
    inner: Box<dyn VecEnv>,
}

impl VecLaneEnv {
    /// Wrap a width-1 kernel.
    pub fn new(inner: Box<dyn VecEnv>) -> Self {
        assert_eq!(inner.num_envs(), 1, "VecLaneEnv adapts exactly one lane");
        VecLaneEnv { inner }
    }
}

impl Env for VecLaneEnv {
    fn spec(&self) -> &EnvSpec {
        self.inner.spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        let d = self.inner.spec().obs_dim();
        self.inner.reset_lane(0, &mut obs[..d]);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let d = self.inner.spec().obs_dim();
        let adim = self.inner.spec().action_space.dim();
        let mut out = [Step::default()];
        let mut arena = SliceArena::new(&mut obs[..d], d);
        self.inner.step_batch(&action[..adim], &[0], &mut arena, &mut out);
        out[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioConfig;
    use crate::envs::registry;

    const MIX: &str = "[group]\ntask = CartPole-v1\ncount = 3\n\
                       [group]\ntask = Pendulum-v1\ncount = 2\n";

    fn pool() -> GroupedVecEnv {
        let sc = ScenarioConfig::parse(MIX).unwrap();
        registry::make_scenario_pool(&sc, 7).unwrap()
    }

    #[test]
    fn maps_global_ids_to_group_lanes() {
        let p = pool();
        assert_eq!(p.num_envs(), 5);
        assert_eq!(p.num_groups(), 2);
        assert_eq!(p.locate(0), (0, 0));
        assert_eq!(p.locate(2), (0, 2));
        assert_eq!(p.locate(3), (1, 0));
        assert_eq!(p.locate(4), (1, 1));
    }

    #[test]
    fn steps_ragged_groups_with_zero_padding() {
        let mut p = pool();
        let dim = p.spec().obs_dim();
        let adim = p.spec().action_space.dim();
        assert_eq!(dim, 4); // CartPole 4 lanes wide; Pendulum 3, padded.
        let n = p.num_envs();
        let mut obs = vec![f32::NAN; n * dim];
        for e in 0..n {
            p.reset_lane(e, &mut obs[e * dim..(e + 1) * dim]);
        }
        // Pendulum rows (envs 3,4) are padded with an exact 0.0 tail.
        for e in 3..5 {
            assert_eq!(obs[e * dim + 3], 0.0, "env {e} pad");
        }
        // CartPole rows use all four lanes (position may be any sign,
        // but they were written — no NaN survives).
        assert!(obs.iter().all(|v| v.is_finite()));

        let actions = vec![0.0; n * adim];
        let mut out = vec![Step::default(); n];
        obs.fill(f32::NAN);
        let mut arena = SliceArena::new(&mut obs, dim);
        p.step_batch(&actions, &[0; 5], &mut arena, &mut out);
        assert!(obs.iter().all(|v| v.is_finite()));
        for e in 3..5 {
            assert_eq!(obs[e * dim + 3], 0.0, "env {e} pad after step");
        }
        // Pendulum never terminates; CartPole may. Rewards flowed.
        assert!(out[3].reward != 0.0 || out[4].reward != 0.0);
    }

    #[test]
    fn group_lanes_match_homogeneous_kernels() {
        // Each group must behave exactly like a standalone kernel of
        // the same task built with the group seed — the parity contract
        // make_scenario_group documents.
        let sc = ScenarioConfig::parse(MIX).unwrap();
        let mut p = registry::make_scenario_pool(&sc, 7).unwrap();
        let dim = p.spec().obs_dim();
        let mut homo = registry::make_vec_env("CartPole-v1", sc.group_seed(0, 7), 0, 3).unwrap();

        let n = p.num_envs();
        let mut obs = vec![0.0; n * dim];
        for e in 0..n {
            p.reset_lane(e, &mut obs[e * dim..(e + 1) * dim]);
        }
        let mut hobs = vec![0.0; 3 * 4];
        for l in 0..3 {
            homo.reset_lane(l, &mut hobs[l * 4..(l + 1) * 4]);
        }
        for l in 0..3 {
            assert_eq!(obs[l * dim..l * dim + 4], hobs[l * 4..(l + 1) * 4]);
        }

        // One step, action 1 everywhere.
        let actions = vec![1.0; n];
        let mut out = vec![Step::default(); n];
        let mut arena = SliceArena::new(&mut obs, dim);
        p.step_batch(&actions, &[0; 5], &mut arena, &mut out);
        let hact = vec![1.0; 3];
        let mut hout = vec![Step::default(); 3];
        let mut harena = SliceArena::new(&mut hobs, 4);
        homo.step_batch(&hact, &[0; 3], &mut harena, &mut hout);
        for l in 0..3 {
            assert_eq!(obs[l * dim..l * dim + 4], hobs[l * 4..(l + 1) * 4]);
            assert_eq!(out[l], hout[l]);
        }
    }

    #[test]
    fn vec_lane_env_matches_group_lane() {
        // Scalar scenario envs are lanes of the same kernels: episode
        // streams must be bitwise identical to the grouped backend.
        let sc = ScenarioConfig::parse(MIX).unwrap();
        let mut p = registry::make_scenario_pool(&sc, 7).unwrap();
        let dim = p.spec().obs_dim();
        let n = p.num_envs();
        let mut obs = vec![0.0; n * dim];
        for e in 0..n {
            p.reset_lane(e, &mut obs[e * dim..(e + 1) * dim]);
        }

        // Env 4 = lane 1 of the Pendulum group.
        let mut e = registry::make_scenario_env(&sc, 1, 1, 7).unwrap();
        assert_eq!(e.spec().obs_dim(), 3);
        let mut eobs = vec![0.0; 3];
        e.reset(&mut eobs);
        assert_eq!(obs[4 * dim..4 * dim + 3], eobs[..]);

        for step in 0..5 {
            let actions = vec![0.25; n * p.spec().action_space.dim()];
            let mut out = vec![Step::default(); n];
            let mut arena = SliceArena::new(&mut obs, dim);
            p.step_batch(&actions, &[0; 5], &mut arena, &mut out);
            let es = e.step(&[0.25], &mut eobs);
            assert_eq!(obs[4 * dim..4 * dim + 3], eobs[..], "step {step}");
            assert_eq!(out[4], es, "step {step}");
        }
    }

    #[test]
    fn into_group_chunks_preserves_layout() {
        let (spec, chunks) = pool().into_group_chunks();
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].0, 0);
        assert_eq!(chunks[0].1.num_envs(), 3);
        assert_eq!(chunks[1].0, 3);
        assert_eq!(chunks[1].1.num_envs(), 2);
        assert!(spec.is_grouped());
    }
}
