//! Lease table over [`EnvPool`] — the server side of `envpool serve`.
//!
//! The pool's `env_id` space is carved into `max_clients` contiguous
//! **leases** of `lease_size` envs each; a client attaches to one lease
//! and drives exactly its envs. The pool itself runs **async scalar**
//! with `batch_size == lease_size`, and every submission is a *full wave*
//! (one action per leased env), so the number of in-flight rows is always
//! a multiple of the batch size — state-queue blocks always fill and the
//! pool stays live no matter how many leases are attached.
//!
//! Rows coming back from [`EnvPool::recv_into_timeout`] are routed by
//! `env_id / lease_size` into per-lease wave buffers; a buffer that
//! reaches `lease_size` rows is a completed wave, surfaced to the caller
//! as a [`LeaseEvent::Wave`] in lease-local env order.
//!
//! Client death is handled without touching other leases: the lease
//! **drains** any in-flight wave (discarding the rows), schedules one
//! explicit reset per env ([`EnvPool::schedule_resets`]), and **parks**
//! the completed reset wave. The next client to attach receives the
//! parked wave as its initial batch — so every attach observes exactly
//! one reset per env, which keeps served trajectories bitwise equal to an
//! in-process pool over the same seeds.
//!
//! This type is transport-agnostic and fully testable in-process; the
//! Unix-socket + shared-memory-slab wiring lives in
//! [`crate::executors::serve`] / [`crate::executors::shm`].

use super::envpool::{EnvPool, ExecMode, PoolConfig};
use crate::envs::spec::EnvSpec;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Index of a lease in the table (also its slot in the slab directory).
pub type LeaseId = usize;

/// Construction parameters for a [`LeasePool`].
#[derive(Debug, Clone)]
pub struct LeaseConfig {
    /// Task id served to every lease, e.g. `"CartPole-v1"`.
    pub task_id: String,
    /// Number of leases (= maximum concurrently attached clients).
    pub max_clients: usize,
    /// Envs per lease; also the pool's batch size.
    pub lease_size: usize,
    /// Worker threads for the underlying pool.
    pub num_threads: usize,
    /// Experiment seed (env `i` seeds as `(seed, i)`, like any pool).
    pub seed: u64,
    /// Bound on outstanding waves per lease: one in the pool plus up to
    /// `max_outstanding - 1` queued server-side. A submit beyond the
    /// bound is refused with [`Error::Lease`] — the backpressure signal.
    pub max_outstanding: usize,
}

impl LeaseConfig {
    pub fn new(task_id: &str) -> Self {
        LeaseConfig {
            task_id: task_id.to_string(),
            max_clients: 2,
            lease_size: 8,
            num_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            seed: 0,
            max_outstanding: 2,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_clients == 0 {
            return Err(Error::Config("max_clients must be > 0".into()));
        }
        if self.lease_size == 0 {
            return Err(Error::Config("lease_size must be > 0".into()));
        }
        if self.max_outstanding == 0 {
            return Err(Error::Config("max_outstanding must be > 0".into()));
        }
        Ok(())
    }
}

/// One completed batch for one lease, rows in lease-local env order
/// (row `i` is global env `first_env + i`). Buffers are recycled through
/// [`LeasePool::recycle`]; steady state allocates nothing.
#[derive(Debug, Default)]
pub struct Wave {
    pub obs: Vec<f32>,
    pub rew: Vec<f32>,
    pub done: Vec<u8>,
    pub trunc: Vec<u8>,
    filled: usize,
    mask: Vec<bool>,
}

impl Wave {
    fn with_shape(k: usize, obs_dim: usize) -> Wave {
        Wave {
            obs: vec![0.0; k * obs_dim],
            rew: vec![0.0; k],
            done: vec![0; k],
            trunc: vec![0; k],
            filled: 0,
            mask: vec![false; k],
        }
    }

    fn clear(&mut self) {
        self.filled = 0;
        self.mask.iter_mut().for_each(|m| *m = false);
    }
}

/// What [`LeasePool::pump`] surfaced this tick.
#[derive(Debug)]
pub enum LeaseEvent {
    /// A completed wave for an attached client; `seq` counts from 0 per
    /// attach (seq 0 is the initial reset batch).
    Wave { lease: LeaseId, seq: u64, wave: Wave },
    /// A detached lease finished draining + resetting: its envs are fresh
    /// and parked, and the lease is back in the admission pool.
    Reclaimed { lease: LeaseId },
}

/// Where a lease's *envs* are in their recycle cycle (orthogonal to
/// whether a client is currently attached).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lifecycle {
    /// Unknown state — must be reset before serving (initial state).
    Dirty,
    /// A dead client's wave is still in flight; rows are discarded as
    /// they return, then a reset is scheduled.
    Draining,
    /// A reset wave is in flight (becomes the initial batch if a client
    /// is attached, or parks as `Fresh` otherwise).
    Resetting,
    /// Reset done and parked; the next attach gets the wave instantly.
    Fresh,
    /// Serving episodes for an attached client.
    Live,
}

struct Lease {
    attached: bool,
    lifecycle: Lifecycle,
    /// Rows currently inside the pool for this lease (0 or `lease_size`).
    in_flight: usize,
    /// Action waves accepted but not yet submitted to the pool (the pool
    /// holds at most one wave per lease — `EnvSlot` has a single action
    /// buffer, so a second send would overwrite the first).
    pending: VecDeque<Vec<f32>>,
    /// Accumulates the currently returning wave.
    wave: Wave,
    /// Completed reset wave kept for the next attach.
    parked: Option<Wave>,
    /// Next wave sequence number for the attached client.
    seq: u64,
    /// This lease's global env ids, precomputed for `send`.
    env_ids: Vec<u32>,
}

/// Lease table + routing over an async scalar [`EnvPool`]. All methods
/// take `&self`; attach/submit/detach lock the table briefly while
/// `pump` (single consumer) drains the pool's state queue.
pub struct LeasePool {
    pool: EnvPool,
    spec: EnvSpec,
    k: usize,
    obs_dim: usize,
    act_dim: usize,
    max_outstanding: usize,
    table: Mutex<Table>,
    scratch: Mutex<super::batch::BatchedTransition>,
    attaches: AtomicU64,
    reclaims: AtomicU64,
}

struct Table {
    leases: Vec<Lease>,
    spare: Vec<Wave>,
}

impl LeasePool {
    pub fn new(cfg: LeaseConfig) -> Result<LeasePool> {
        cfg.validate()?;
        let num_envs = cfg.max_clients * cfg.lease_size;
        let pool = EnvPool::make(
            PoolConfig::new(&cfg.task_id)
                .num_envs(num_envs)
                .batch_size(cfg.lease_size)
                .num_threads(cfg.num_threads)
                .seed(cfg.seed)
                .exec_mode(ExecMode::Scalar),
        )?;
        let spec = pool.spec().clone();
        let obs_dim = spec.obs_dim();
        let act_dim = spec.action_space.dim();
        let k = cfg.lease_size;
        let leases = (0..cfg.max_clients)
            .map(|l| Lease {
                attached: false,
                lifecycle: Lifecycle::Dirty,
                in_flight: 0,
                pending: VecDeque::new(),
                wave: Wave::with_shape(k, obs_dim),
                parked: None,
                seq: 0,
                env_ids: (l * k..(l + 1) * k).map(|i| i as u32).collect(),
            })
            .collect();
        let scratch = Mutex::new(pool.make_output());
        Ok(LeasePool {
            pool,
            spec,
            k,
            obs_dim,
            act_dim,
            max_outstanding: cfg.max_outstanding,
            table: Mutex::new(Table { leases, spare: Vec::new() }),
            scratch,
            attaches: AtomicU64::new(0),
            reclaims: AtomicU64::new(0),
        })
    }

    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    pub fn lease_size(&self) -> usize {
        self.k
    }

    pub fn max_clients(&self) -> usize {
        self.table.lock().unwrap().leases.len()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// First global env id of a lease (its rows are `first..first + k`).
    pub fn first_env(&self, lease: LeaseId) -> u32 {
        (lease * self.k) as u32
    }

    /// Total attaches served (monotone; for stats/tests).
    pub fn attaches(&self) -> u64 {
        self.attaches.load(Ordering::Relaxed)
    }

    /// Total completed reclaims (detached lease fully reset + parked).
    pub fn reclaims(&self) -> u64 {
        self.reclaims.load(Ordering::Relaxed)
    }

    /// Claim the lowest unattached lease. Returns the lease id and, when
    /// its envs were already reset and parked, the initial wave (seq 0)
    /// to deliver immediately. Otherwise the initial wave surfaces later
    /// through [`Self::pump`] once the (scheduled) reset completes.
    pub fn attach(&self) -> Result<(LeaseId, Option<(u64, Wave)>)> {
        let mut t = self.table.lock().unwrap();
        let Some(id) = t.leases.iter().position(|l| !l.attached) else {
            let n = t.leases.len();
            return Err(Error::Attach(format!("all {n} leases attached")));
        };
        let lease = &mut t.leases[id];
        lease.attached = true;
        lease.seq = 0;
        lease.pending.clear();
        self.attaches.fetch_add(1, Ordering::Relaxed);
        match lease.lifecycle {
            Lifecycle::Fresh => {
                let wave = lease.parked.take().expect("Fresh lease has a parked wave");
                lease.lifecycle = Lifecycle::Live;
                lease.seq = 1;
                Ok((id, Some((0, wave))))
            }
            Lifecycle::Dirty => {
                self.pool.schedule_resets(&lease.env_ids)?;
                lease.in_flight = self.k;
                lease.lifecycle = Lifecycle::Resetting;
                Ok((id, None))
            }
            // A reclaim is still in progress; the reset wave in flight
            // (or about to be scheduled) becomes this client's initial
            // batch when it completes.
            Lifecycle::Draining | Lifecycle::Resetting => Ok((id, None)),
            Lifecycle::Live => unreachable!("Live lease cannot be unattached"),
        }
    }

    /// Submit one full action wave (`lease_size * act_dim` f32s, rows in
    /// lease-local env order). At most one wave rides the pool per lease;
    /// extras queue server-side up to the `max_outstanding` bound, beyond
    /// which the submit is refused — the backpressure contract.
    pub fn submit(&self, lease: LeaseId, actions: &[f32]) -> Result<()> {
        let mut t = self.table.lock().unwrap();
        let l = t
            .leases
            .get_mut(lease)
            .ok_or_else(|| Error::Lease(format!("no such lease {lease}")))?;
        if !l.attached {
            return Err(Error::Lease(format!("lease {lease} is not attached")));
        }
        if l.lifecycle != Lifecycle::Live {
            return Err(Error::Lease(format!(
                "lease {lease} cannot step before its initial reset batch is delivered"
            )));
        }
        if actions.len() != self.k * self.act_dim {
            return Err(Error::Lease(format!(
                "action wave of {} f32s (lease wants {} envs x {} dims)",
                actions.len(),
                self.k,
                self.act_dim
            )));
        }
        let outstanding = usize::from(l.in_flight > 0) + l.pending.len();
        if outstanding >= self.max_outstanding {
            return Err(Error::Lease(format!(
                "lease {lease} backpressure: {outstanding} waves outstanding (max {})",
                self.max_outstanding
            )));
        }
        if l.in_flight == 0 {
            debug_assert!(l.pending.is_empty(), "pending drains before in_flight clears");
            self.pool.send(actions, &l.env_ids)?;
            l.in_flight = self.k;
        } else {
            l.pending.push_back(actions.to_vec());
        }
        Ok(())
    }

    /// Client-requested re-reset of a live lease (no waves outstanding).
    /// The reset wave arrives as the next [`LeaseEvent::Wave`].
    pub fn request_reset(&self, lease: LeaseId) -> Result<()> {
        let mut t = self.table.lock().unwrap();
        let l = t
            .leases
            .get_mut(lease)
            .ok_or_else(|| Error::Lease(format!("no such lease {lease}")))?;
        if !l.attached || l.lifecycle != Lifecycle::Live {
            return Err(Error::Lease(format!("lease {lease} is not live")));
        }
        if l.in_flight > 0 || !l.pending.is_empty() {
            return Err(Error::Lease(format!(
                "lease {lease} cannot reset with waves outstanding"
            )));
        }
        self.pool.schedule_resets(&l.env_ids)?;
        l.in_flight = self.k;
        l.lifecycle = Lifecycle::Resetting;
        Ok(())
    }

    /// Release a lease — graceful detach and client-death reclaim share
    /// this path (idempotent; a reader-thread EOF after an explicit
    /// detach is a no-op). Queued waves are dropped; any in-flight wave
    /// drains first (rows discarded), then every env is reset and the
    /// fresh wave parks for the next attach. Completion is signalled by
    /// [`LeaseEvent::Reclaimed`] out of [`Self::pump`].
    pub fn detach(&self, lease: LeaseId) -> Result<()> {
        let mut t = self.table.lock().unwrap();
        let l = t
            .leases
            .get_mut(lease)
            .ok_or_else(|| Error::Lease(format!("no such lease {lease}")))?;
        if !l.attached {
            return Ok(());
        }
        l.attached = false;
        l.pending.clear();
        match l.lifecycle {
            Lifecycle::Live => {
                if l.in_flight > 0 {
                    // Keep the partially-routed wave accumulating — rows
                    // still in the pool complete it, and route() discards
                    // it wholesale once full.
                    l.lifecycle = Lifecycle::Draining;
                } else {
                    self.pool.schedule_resets(&l.env_ids)?;
                    l.in_flight = self.k;
                    l.lifecycle = Lifecycle::Resetting;
                }
            }
            // Reset already in flight — it will park as Fresh now that
            // the client is gone.
            Lifecycle::Resetting => {}
            // Unattached lifecycles can't be reached with attached=true.
            Lifecycle::Dirty | Lifecycle::Draining | Lifecycle::Fresh => {}
        }
        Ok(())
    }

    /// Drain ready pool batches and route rows into per-lease waves.
    /// Blocks at most `timeout` waiting for the *first* batch; anything
    /// already queued behind it is drained without blocking. Completed
    /// waves and reclaim completions are appended to `events`.
    pub fn pump(&self, timeout: Duration, events: &mut Vec<LeaseEvent>) -> Result<()> {
        let mut scratch = self.scratch.lock().unwrap();
        let mut got = self.pool.recv_into_timeout(&mut scratch, timeout)?;
        while got {
            self.route(&scratch, events)?;
            got = self.pool.recv_into_timeout(&mut scratch, Duration::ZERO)?;
        }
        Ok(())
    }

    /// Return a published wave's buffer for reuse.
    pub fn recycle(&self, mut wave: Wave) {
        wave.clear();
        self.table.lock().unwrap().spare.push(wave);
    }

    fn route(
        &self,
        batch: &super::batch::BatchedTransition,
        events: &mut Vec<LeaseEvent>,
    ) -> Result<()> {
        let d = self.obs_dim;
        let mut t = self.table.lock().unwrap();
        for row in 0..batch.len() {
            let env_id = batch.env_ids[row] as usize;
            let lease = env_id / self.k;
            let local = env_id % self.k;
            let completed = {
                let l = &mut t.leases[lease];
                debug_assert!(!l.wave.mask[local], "env {env_id} produced two rows in one wave");
                l.wave.mask[local] = true;
                l.wave.obs[local * d..(local + 1) * d]
                    .copy_from_slice(&batch.obs[row * d..(row + 1) * d]);
                l.wave.rew[local] = batch.rew[row];
                l.wave.done[local] = batch.done[row];
                l.wave.trunc[local] = batch.trunc[row];
                l.wave.filled += 1;
                l.in_flight -= 1;
                l.wave.filled == self.k
            };
            if !completed {
                continue;
            }
            // Completed wave: swap it out against a spare buffer.
            let mut full = match t.spare.pop() {
                Some(w) if w.mask.len() == self.k => w,
                _ => Wave::with_shape(self.k, d),
            };
            std::mem::swap(&mut t.leases[lease].wave, &mut full);
            t.leases[lease].wave.clear();
            let (lifecycle, attached) = {
                let l = &t.leases[lease];
                (l.lifecycle, l.attached)
            };
            match (lifecycle, attached) {
                (Lifecycle::Live, true) => {
                    let l = &mut t.leases[lease];
                    let seq = l.seq;
                    l.seq += 1;
                    if let Some(next) = l.pending.pop_front() {
                        self.pool.send(&next, &l.env_ids)?;
                        l.in_flight = self.k;
                    }
                    events.push(LeaseEvent::Wave { lease, seq, wave: full });
                }
                (Lifecycle::Resetting, true) => {
                    let l = &mut t.leases[lease];
                    l.lifecycle = Lifecycle::Live;
                    let seq = l.seq;
                    l.seq += 1;
                    events.push(LeaseEvent::Wave { lease, seq, wave: full });
                }
                (Lifecycle::Resetting, false) => {
                    let l = &mut t.leases[lease];
                    l.lifecycle = Lifecycle::Fresh;
                    l.parked = Some(full);
                    self.reclaims.fetch_add(1, Ordering::Relaxed);
                    events.push(LeaseEvent::Reclaimed { lease });
                }
                // Note `Draining` drains regardless of `attached`: a new
                // client may claim the lease mid-drain, and the reset
                // scheduled here then becomes its initial batch.
                (Lifecycle::Draining, _) | (Lifecycle::Live, false) => {
                    // Dead client's wave fully drained: discard the rows,
                    // recycle the buffer, reset the envs.
                    t.spare.push(full);
                    self.pool.schedule_resets(&t.leases[lease].env_ids)?;
                    let l = &mut t.leases[lease];
                    l.in_flight = self.k;
                    l.lifecycle = Lifecycle::Resetting;
                }
                (state, attached) => {
                    debug_assert!(false, "wave completed in {state:?}/attached={attached}");
                    t.spare.push(full);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pump_until(
        lp: &LeasePool,
        pred: impl Fn(&LeaseEvent) -> bool,
        what: &str,
    ) -> LeaseEvent {
        let mut events = Vec::new();
        for _ in 0..2000 {
            lp.pump(Duration::from_millis(5), &mut events).unwrap();
            if let Some(i) = events.iter().position(&pred) {
                return events.swap_remove(i);
            }
        }
        panic!("no {what} event within timeout");
    }

    fn cfg(clients: usize, k: usize) -> LeaseConfig {
        let mut c = LeaseConfig::new("CartPole-v1");
        c.max_clients = clients;
        c.lease_size = k;
        c.num_threads = 2;
        c.seed = 7;
        c
    }

    #[test]
    fn attach_initial_wave_then_step() {
        let lp = LeasePool::new(cfg(2, 4)).unwrap();
        let (id, parked) = lp.attach().unwrap();
        assert_eq!(id, 0);
        assert!(parked.is_none(), "cold lease has no parked wave");
        let ev = pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { .. }), "initial wave");
        let LeaseEvent::Wave { lease, seq, wave } = ev else { unreachable!() };
        assert_eq!((lease, seq), (0, 0));
        assert_eq!(wave.obs.len(), 4 * lp.obs_dim());
        assert!(wave.obs.iter().all(|x| x.is_finite()));
        assert!(wave.done.iter().all(|&d| d == 0), "reset rows are not terminal");
        lp.recycle(wave);

        lp.submit(0, &[0.0; 4]).unwrap();
        let ev = pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { seq: 1, .. }), "step wave");
        let LeaseEvent::Wave { wave, .. } = ev else { unreachable!() };
        assert!(wave.rew.iter().all(|&r| r == 1.0), "CartPole pays 1 per step");
        lp.recycle(wave);
    }

    #[test]
    fn backpressure_bounds_outstanding_waves() {
        let lp = LeasePool::new(cfg(1, 4)).unwrap();
        let (id, _) = lp.attach().unwrap();
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { .. }), "initial wave");
        // Without pumping, in_flight never clears: wave 1 rides the pool,
        // wave 2 queues, wave 3 must be refused.
        lp.submit(id, &[0.0; 4]).unwrap();
        lp.submit(id, &[0.0; 4]).unwrap();
        let err = lp.submit(id, &[0.0; 4]).unwrap_err();
        assert!(matches!(err, Error::Lease(_)), "got {err}");
        assert!(err.to_string().contains("backpressure"), "got {err}");
        // Pumping both waves out clears the window again.
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { seq: 2, .. }), "queued wave");
        lp.submit(id, &[0.0; 4]).unwrap();
    }

    #[test]
    fn submit_validates_shape_and_state() {
        let lp = LeasePool::new(cfg(1, 4)).unwrap();
        let (id, _) = lp.attach().unwrap();
        // Before the initial batch: not yet live.
        assert!(lp.submit(id, &[0.0; 4]).is_err());
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { .. }), "initial wave");
        // Wrong wave width.
        let err = lp.submit(id, &[0.0; 3]).unwrap_err();
        assert!(err.to_string().contains("action wave"), "got {err}");
        // Unknown lease id.
        assert!(lp.submit(9, &[0.0; 4]).is_err());
    }

    #[test]
    fn attach_exhaustion_is_refused() {
        let lp = LeasePool::new(cfg(1, 2)).unwrap();
        lp.attach().unwrap();
        let err = lp.attach().unwrap_err();
        assert!(matches!(err, Error::Attach(_)), "got {err}");
    }

    #[test]
    fn detach_drains_resets_and_parks_for_reattach() {
        let lp = LeasePool::new(cfg(1, 4)).unwrap();
        let (id, _) = lp.attach().unwrap();
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { .. }), "initial wave");
        // Die with a wave in flight: drain → reset → park.
        lp.submit(id, &[0.0; 4]).unwrap();
        lp.detach(id).unwrap();
        lp.detach(id).unwrap(); // idempotent (EOF after explicit detach)
        pump_until(&lp, |e| matches!(e, LeaseEvent::Reclaimed { .. }), "reclaim");
        assert_eq!(lp.reclaims(), 1);
        // Reattach gets the parked wave instantly, seq starts over at 0.
        let (id2, parked) = lp.attach().unwrap();
        assert_eq!(id2, id);
        let (seq, wave) = parked.expect("reclaimed lease parks an initial wave");
        assert_eq!(seq, 0);
        assert!(wave.obs.iter().all(|x| x.is_finite()));
        assert!(wave.done.iter().all(|&d| d == 0));
        lp.recycle(wave);
        // And the lease steps normally again.
        lp.submit(id2, &[1.0; 4]).unwrap();
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { seq: 1, .. }), "step after reattach");
        assert_eq!(lp.attaches(), 2);
    }

    #[test]
    fn two_leases_are_independent() {
        let lp = LeasePool::new(cfg(2, 2)).unwrap();
        let (a, _) = lp.attach().unwrap();
        let (b, _) = lp.attach().unwrap();
        assert_ne!(a, b);
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { lease, .. } if *lease == a), "init a");
        pump_until(&lp, |e| matches!(e, LeaseEvent::Wave { lease, .. } if *lease == b), "init b");
        // Killing a never stalls b.
        lp.detach(a).unwrap();
        for s in 1..=3u64 {
            lp.submit(b, &[0.0; 2]).unwrap();
            pump_until(
                &lp,
                |e| matches!(e, LeaseEvent::Wave { lease, seq, .. } if *lease == b && *seq == s),
                "b steps while a reclaims",
            );
        }
        pump_until(&lp, |e| matches!(e, LeaseEvent::Reclaimed { lease } if *lease == a), "a parks");
    }
}
