//! ActionBufferQueue (paper Appendix D.1): a lock-free bounded MPMC
//! circular buffer with two atomic cursors and per-slot sequence numbers
//! (Vyukov's algorithm — the per-slot sequence generalizes the paper's
//! two-counter scheme to arbitrary producer/consumer interleavings), plus
//! a semaphore so idle worker threads sleep instead of spinning.
//!
//! The paper sizes the buffer at `2N`; we round up to the next power of
//! two for mask indexing.

use super::sem::Semaphore;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free MPMC queue with blocking (semaphore) dequeue.
pub struct ActionBufferQueue<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
    items: Semaphore,
}

unsafe impl<T: Send> Sync for ActionBufferQueue<T> {}
unsafe impl<T: Send> Send for ActionBufferQueue<T> {}

impl<T> ActionBufferQueue<T> {
    /// Create with capacity at least `min_capacity` (paper: `2 * num_envs`).
    pub fn new(min_capacity: usize) -> Self {
        let cap = min_capacity.max(2).next_power_of_two();
        let buf: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        ActionBufferQueue {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
            items: Semaphore::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Enqueue; returns `Err(v)` if the queue is full (a protocol
    /// violation in the pool — there are never more than `N` outstanding
    /// actions — but recoverable for library users).
    pub fn enqueue(&self, v: T) -> Result<(), T> {
        self.enqueue_nopost(v)?;
        self.items.post();
        Ok(())
    }

    /// Enqueue, yielding until space frees up (the task-submission path
    /// shared by both worker engines; under the pool protocol the queue
    /// is sized so this never actually has to wait).
    pub fn blocking_enqueue(&self, mut v: T) {
        loop {
            match self.enqueue(v) {
                Ok(()) => return,
                Err(back) => {
                    v = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Enqueue a batch with a single semaphore post (one futex wake
    /// instead of `items.len()`): the `send` hot path's optimization —
    /// measured in `benches/queues.rs` and EXPERIMENTS.md §Perf.
    pub fn enqueue_batch(&self, items: impl ExactSizeIterator<Item = T>) -> usize {
        let mut n = 0isize;
        for mut v in items {
            loop {
                match self.enqueue_nopost(v) {
                    Ok(()) => break,
                    Err(back) => {
                        v = back;
                        // queue full: flush what we have so consumers drain it
                        if n > 0 {
                            self.items.post_n(n);
                            n = 0;
                        }
                        std::thread::yield_now();
                    }
                }
            }
            n += 1;
        }
        if n > 0 {
            self.items.post_n(n);
        }
        n as usize
    }

    fn enqueue_nopost(&self, v: T) -> Result<(), T> {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.val.get()).write(v) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return Err(v);
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop one item without blocking; `None` if empty.
    pub fn try_dequeue(&self) -> Option<T> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(v);
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Blocking dequeue: parks on the semaphore until an item arrives.
    pub fn dequeue(&self) -> T {
        loop {
            self.items.wait();
            if let Some(v) = self.try_dequeue() {
                return v;
            }
            // Raced with another consumer: give the permit back.
            self.items.post();
            std::thread::yield_now();
        }
    }

    /// Blocking dequeue with timeout.
    pub fn dequeue_timeout(&self, d: Duration) -> Option<T> {
        if !self.items.wait_timeout(d) {
            return None;
        }
        match self.try_dequeue() {
            Some(v) => Some(v),
            None => {
                self.items.post();
                None
            }
        }
    }

    /// Approximate queue length (diagnostics).
    pub fn len(&self) -> usize {
        let e = self.enqueue_pos.load(Ordering::Relaxed);
        let d = self.dequeue_pos.load(Ordering::Relaxed);
        e.saturating_sub(d)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for ActionBufferQueue<T> {
    fn drop(&mut self) {
        while self.try_dequeue().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::prop_assert;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = ActionBufferQueue::new(8);
        for i in 0..8 {
            q.enqueue(i).unwrap();
        }
        for i in 0..8 {
            assert_eq!(q.try_dequeue(), Some(i));
        }
        assert_eq!(q.try_dequeue(), None);
    }

    #[test]
    fn full_queue_rejects() {
        let q = ActionBufferQueue::new(4);
        for i in 0..q.capacity() {
            q.enqueue(i).unwrap();
        }
        assert!(q.enqueue(99).is_err());
        q.try_dequeue();
        q.enqueue(99).unwrap();
    }

    #[test]
    fn blocking_enqueue_waits_for_space() {
        let q = Arc::new(ActionBufferQueue::new(4));
        for i in 0..q.capacity() {
            q.enqueue(i).unwrap();
        }
        let qc = q.clone();
        let h = std::thread::spawn(move || qc.blocking_enqueue(99));
        // free one slot; the blocked producer must complete
        assert!(q.try_dequeue().is_some());
        h.join().unwrap();
        let mut drained = vec![];
        while let Some(v) = q.try_dequeue() {
            drained.push(v);
        }
        assert!(drained.contains(&99));
    }

    #[test]
    fn capacity_rounds_to_pow2() {
        assert_eq!(ActionBufferQueue::<u8>::new(6).capacity(), 8);
        assert_eq!(ActionBufferQueue::<u8>::new(16).capacity(), 16);
    }

    #[test]
    fn spmc_no_loss_no_dup() {
        // One producer, several consumers: every item delivered exactly once.
        let q = Arc::new(ActionBufferQueue::new(64));
        let n_items = 10_000usize;
        let n_consumers = 4;
        let mut handles = vec![];
        for _ in 0..n_consumers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = vec![];
                loop {
                    let v: usize = q.dequeue();
                    if v == usize::MAX {
                        break;
                    }
                    got.push(v);
                }
                got
            }));
        }
        for i in 0..n_items {
            while q.enqueue(i).is_err() {
                std::thread::yield_now();
            }
        }
        for _ in 0..n_consumers {
            while q.enqueue(usize::MAX).is_err() {
                std::thread::yield_now();
            }
        }
        let mut seen = vec![false; n_items];
        for h in handles {
            for v in h.join().unwrap() {
                assert!(!seen[v], "duplicate delivery of {v}");
                seen[v] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "lost items");
    }

    #[test]
    fn enqueue_batch_single_post_delivers_all() {
        let q = ActionBufferQueue::new(16);
        let n = q.enqueue_batch((0..10u32).map(|i| i));
        assert_eq!(n, 10);
        assert_eq!(q.len(), 10);
        for i in 0..10 {
            assert_eq!(q.dequeue(), i, "blocking dequeue must see batch permits");
        }
    }

    #[test]
    fn enqueue_batch_handles_full_queue() {
        let q = ActionBufferQueue::new(4);
        // capacity 4; feed 6 items while a consumer drains concurrently
        let q = std::sync::Arc::new(q);
        let qc = q.clone();
        let h = std::thread::spawn(move || (0..6).map(|_| qc.dequeue()).collect::<Vec<u32>>());
        q.enqueue_batch((0..6u32).map(|i| i));
        let got = h.join().unwrap();
        let mut sorted = got.clone();
        sorted.sort();
        assert_eq!(sorted, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn dequeue_timeout_on_empty() {
        let q: ActionBufferQueue<u32> = ActionBufferQueue::new(4);
        assert_eq!(q.dequeue_timeout(Duration::from_millis(5)), None);
    }

    #[test]
    fn prop_interleaved_ops_preserve_multiset() {
        forall("queue-multiset", |g| {
            let cap = 1 << g.usize_in(2, 6);
            let q = ActionBufferQueue::new(cap);
            let mut model: std::collections::VecDeque<usize> = Default::default();
            let ops = g.usize_in(1, 200);
            let mut next = 0usize;
            for _ in 0..ops {
                if g.bool() {
                    match q.enqueue(next) {
                        Ok(()) => model.push_back(next),
                        Err(_) => prop_assert!(
                            model.len() == q.capacity(),
                            "enqueue failed while not full ({} of {})",
                            model.len(),
                            q.capacity()
                        ),
                    }
                    next += 1;
                } else {
                    let got = q.try_dequeue();
                    let want = model.pop_front();
                    prop_assert!(got == want, "dequeue mismatch: {got:?} vs {want:?}");
                }
            }
            prop_assert!(q.len() == model.len(), "len mismatch");
            Ok(())
        });
    }
}
