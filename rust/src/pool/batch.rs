//! The batched transition container handed to the consumer by `recv` —
//! one StateBufferQueue block's payload (paper Appendix D.2): contiguous
//! observation matrix plus reward/done/truncated/env_id lanes.

/// One batch of transitions, laid out exactly as the block memory is.
#[derive(Debug, Clone, Default)]
pub struct BatchedTransition {
    /// Row-major `[batch, obs_dim]` observations.
    pub obs: Vec<f32>,
    /// Rewards, length `batch`.
    pub rew: Vec<f32>,
    /// Terminal flags (true termination), length `batch`.
    pub done: Vec<u8>,
    /// Truncation flags, length `batch`.
    pub trunc: Vec<u8>,
    /// Which env produced each row — the `info["env_id"]` of the paper's
    /// API, needed to route the next actions.
    pub env_ids: Vec<u32>,
    /// Observation row width.
    pub obs_dim: usize,
}

impl BatchedTransition {
    /// Pre-allocate for `batch` rows of `obs_dim` observations.
    pub fn with_capacity(batch: usize, obs_dim: usize) -> Self {
        BatchedTransition {
            obs: vec![0.0; batch * obs_dim],
            rew: vec![0.0; batch],
            done: vec![0; batch],
            trunc: vec![0; batch],
            env_ids: vec![0; batch],
            obs_dim,
        }
    }

    /// Number of rows in this batch.
    pub fn len(&self) -> usize {
        self.rew.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rew.is_empty()
    }

    /// Observation row `i`.
    pub fn obs_row(&self, i: usize) -> &[f32] {
        &self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]
    }

    /// Either finished flag for row `i`.
    pub fn finished(&self, i: usize) -> bool {
        self.done[i] != 0 || self.trunc[i] != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_addressable() {
        let mut b = BatchedTransition::with_capacity(3, 4);
        b.obs[4..8].copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.done[2] = 1;
        assert_eq!(b.len(), 3);
        assert_eq!(b.obs_row(1), &[1.0, 2.0, 3.0, 4.0]);
        assert!(b.finished(2));
        assert!(!b.finished(0));
    }
}
