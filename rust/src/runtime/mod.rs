//! The compute tier: two interchangeable [`ComputeBackend`]s behind one
//! trait ([`backend`]).
//!
//! **PJRT path** — load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//! Python never runs at request time — the flow is
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.
//!
//! **Native path** ([`native`]) — a pure-Rust MLP actor-critic, PPO
//! losses with analytic backprop, and Adam, so `envpool train --backend
//! native` runs with no XLA bindings and no artifacts at all.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod literal;
pub mod native;
pub mod policy;
pub mod trainer_exec;

pub use artifact::{ArtifactConfig, Manifest};
pub use backend::{make_backend, BackendSpec, ComputeBackend, NativeBackend, PjrtBackend};
pub use client::Runtime;
pub use native::NativeNet;
pub use policy::{Policy, PolicyOutput};
pub use trainer_exec::{GaeExec, TrainExec, TrainStats};

/// True when `e` only signals that the **optional** compute tier is
/// absent — no PJRT runtime in this build (the vendored `xla` stub) or
/// no AOT artifacts in this checkout (`make artifacts` not run). The
/// pure-Rust environment/pool/executor tiers are unaffected; tests that
/// need the compute tier use this to *skip* instead of fail.
/// Deliberately narrow: a *present* runtime erroring (real XLA shape or
/// compile failures), a present-but-corrupt manifest, and plain I/O
/// errors are genuine failures and must not be skipped.
pub fn unavailable(e: &crate::Error) -> bool {
    match e {
        // The vendored stub's marker; real bindings never produce it.
        crate::Error::Xla(m) => m.contains("PJRT unavailable"),
        // Unreadable manifest.txt => artifacts were never generated. A
        // present-but-malformed manifest reports a parse error instead
        // and does not match.
        crate::Error::Artifact(m) => m.contains("manifest.txt") && m.contains("io: "),
        _ => false,
    }
}

/// Evaluate a `Result` from the optional compute tier: unwrap on
/// success, `return` from the calling test with a "skipping" note when
/// the tier is [`unavailable`], panic on any other error. Test support,
/// shared by the unit suites and `tests/train_smoke.rs`.
#[macro_export]
macro_rules! compute_or_skip {
    ($e:expr) => {
        match $e {
            Ok(x) => x,
            Err(e) if $crate::runtime::unavailable(&e) => {
                eprintln!("skipping: {e}");
                return;
            }
            Err(e) => panic!("{e}"),
        }
    };
}
