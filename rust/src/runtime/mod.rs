//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//! Python never runs at request time — the flow is
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.

pub mod artifact;
pub mod client;
pub mod literal;
pub mod policy;
pub mod trainer_exec;

pub use artifact::{ArtifactConfig, Manifest};
pub use client::Runtime;
pub use policy::{Policy, PolicyOutput};
pub use trainer_exec::{GaeExec, TrainExec, TrainStats};
