//! The policy executable: batched actor-critic forward pass from the
//! L3 hot loop.

use super::artifact::ArtifactConfig;
use super::client::Runtime;
use super::literal::to_vec_f32;
use crate::agent::params::ParamStore;
use crate::Result;
use std::sync::Arc;

/// Host-side result of one policy call.
#[derive(Debug, Clone)]
pub struct PolicyOutput {
    /// Discrete: logits `[B, A]`. Continuous: mean `[B, A]`.
    pub dist: Vec<f32>,
    /// Continuous only: per-sample log-std `[B, A]` (empty for discrete).
    pub log_std: Vec<f32>,
    /// State values `[B]`.
    pub value: Vec<f32>,
}

/// Compiled policy forward pass.
pub struct Policy {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub continuous: bool,
}

impl Policy {
    pub fn load(rt: &Runtime, cfg: &ArtifactConfig) -> Result<Policy> {
        Ok(Policy {
            exe: rt.load(&cfg.policy_file)?,
            batch: cfg.num_envs,
            obs_dim: cfg.obs_dim,
            act_dim: cfg.act_dim,
            continuous: cfg.continuous,
        })
    }

    /// Forward a `[batch, obs_dim]` observation matrix.
    pub fn forward(&self, rt: &Runtime, params: &ParamStore, obs: &[f32]) -> Result<PolicyOutput> {
        debug_assert_eq!(obs.len(), self.batch * self.obs_dim);
        let mut args = params.buffers(rt)?;
        args.push(rt.buf_f32(obs, &[self.batch, self.obs_dim])?);
        let out = rt.run_bufs(&self.exe, &args)?;
        if self.continuous {
            // (mu, log_std_b, value)
            Ok(PolicyOutput {
                dist: to_vec_f32(&out[0])?,
                log_std: to_vec_f32(&out[1])?,
                value: to_vec_f32(&out[2])?,
            })
        } else {
            // (logits, value)
            Ok(PolicyOutput {
                dist: to_vec_f32(&out[0])?,
                log_std: Vec::new(),
                value: to_vec_f32(&out[1])?,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;

    use crate::compute_or_skip;

    #[test]
    fn discrete_and_continuous_policies_forward() {
        let rt = compute_or_skip!(Runtime::cpu());
        let m = compute_or_skip!(Manifest::load("artifacts"));

        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let params = ParamStore::load(&m, cfg).unwrap();
        let pol = Policy::load(&rt, cfg).unwrap();
        let out = pol.forward(&rt, &params, &vec![0.05; 8 * 4]).unwrap();
        assert_eq!(out.dist.len(), 16);
        assert_eq!(out.value.len(), 8);
        assert!(out.log_std.is_empty());

        let cfg = m.for_task("Pendulum-v1", 4).unwrap();
        let params = ParamStore::load(&m, cfg).unwrap();
        let pol = Policy::load(&rt, cfg).unwrap();
        let out = pol.forward(&rt, &params, &vec![0.1; 4 * 3]).unwrap();
        assert_eq!(out.dist.len(), 4);
        assert_eq!(out.log_std.len(), 4);
        assert_eq!(out.value.len(), 4);
        // log_std initialised to 0
        assert!(out.log_std.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn identical_obs_rows_give_identical_outputs() {
        let rt = compute_or_skip!(Runtime::cpu());
        let m = compute_or_skip!(Manifest::load("artifacts"));
        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let params = ParamStore::load(&m, cfg).unwrap();
        let pol = Policy::load(&rt, cfg).unwrap();
        let obs = vec![0.3; 8 * 4]; // all rows identical
        let out = pol.forward(&rt, &params, &obs).unwrap();
        for b in 1..8 {
            assert_eq!(out.dist[0], out.dist[b * 2]);
            assert_eq!(out.value[0], out.value[b]);
        }
    }
}
