//! The train-step and GAE executables.

use super::artifact::ArtifactConfig;
use super::client::Runtime;
use super::literal::{scalar_of, to_vec_f32};
use crate::agent::params::ParamStore;
use crate::Result;
use std::sync::Arc;

/// Scalars reported by one PPO minibatch update.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainStats {
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
}

/// One minibatch of training data (host-side views).
pub struct Minibatch<'a> {
    pub obs: &'a [f32],
    pub actions: &'a [f32],
    pub logp: &'a [f32],
    pub adv: &'a [f32],
    pub ret: &'a [f32],
}

/// Compiled PPO train step (params, adam, minibatch, lr) -> updated state.
pub struct TrainExec {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub minibatch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub continuous: bool,
    n_params: usize,
}

impl TrainExec {
    pub fn load(rt: &Runtime, cfg: &ArtifactConfig) -> Result<TrainExec> {
        Ok(TrainExec {
            exe: rt.load(&cfg.train_file)?,
            minibatch: cfg.minibatch_size,
            obs_dim: cfg.obs_dim,
            act_dim: cfg.act_dim,
            continuous: cfg.continuous,
            n_params: cfg.params.len(),
        })
    }

    /// One update: mutates `params`, `m`, `v`, `t` in place and returns
    /// the loss statistics.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        rt: &Runtime,
        params: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        t: &mut f32,
        mb: &Minibatch<'_>,
        lr: f32,
    ) -> Result<TrainStats> {
        let b = self.minibatch;
        debug_assert_eq!(mb.obs.len(), b * self.obs_dim);
        let mut args = params.buffers(rt)?;
        args.extend(m.buffers(rt)?);
        args.extend(v.buffers(rt)?);
        args.push(rt.buf_scalar(*t)?);
        args.push(rt.buf_f32(mb.obs, &[b, self.obs_dim])?);
        if self.continuous {
            args.push(rt.buf_f32(mb.actions, &[b, self.act_dim])?);
        } else {
            args.push(rt.buf_f32(mb.actions, &[b])?);
        }
        args.push(rt.buf_f32(mb.logp, &[b])?);
        args.push(rt.buf_f32(mb.adv, &[b])?);
        args.push(rt.buf_f32(mb.ret, &[b])?);
        args.push(rt.buf_scalar(lr)?);

        let out = rt.run_bufs(&self.exe, &args)?;
        let p = self.n_params;
        debug_assert_eq!(out.len(), 3 * p + 1 + 5);
        params.update_from(&out[0..p])?;
        m.update_from(&out[p..2 * p])?;
        v.update_from(&out[2 * p..3 * p])?;
        *t = scalar_of(&out[3 * p])?;
        Ok(TrainStats {
            loss: scalar_of(&out[3 * p + 1])?,
            pg_loss: scalar_of(&out[3 * p + 2])?,
            v_loss: scalar_of(&out[3 * p + 3])?,
            entropy: scalar_of(&out[3 * p + 4])?,
            approx_kl: scalar_of(&out[3 * p + 5])?,
        })
    }
}

/// Compiled GAE (the L1 reverse-scan kernel when lowered with --pallas).
pub struct GaeExec {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub t: usize,
    pub n: usize,
}

impl GaeExec {
    pub fn load(rt: &Runtime, cfg: &ArtifactConfig) -> Result<GaeExec> {
        Ok(GaeExec { exe: rt.load(&cfg.gae_file)?, t: cfg.num_steps, n: cfg.num_envs })
    }

    /// All inputs time-major `[T, N]`; returns (advantages, returns).
    pub fn compute(
        &self,
        rt: &Runtime,
        rewards: &[f32],
        values: &[f32],
        last_value: &[f32],
        dones: &[f32],
        truncs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (t, n) = (self.t, self.n);
        let args = [
            rt.buf_f32(rewards, &[t, n])?,
            rt.buf_f32(values, &[t, n])?,
            rt.buf_f32(last_value, &[n])?,
            rt.buf_f32(dones, &[t, n])?,
            rt.buf_f32(truncs, &[t, n])?,
        ];
        let out = rt.run_bufs(&self.exe, &args)?;
        Ok((to_vec_f32(&out[0])?, to_vec_f32(&out[1])?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;

    use crate::compute_or_skip;

    #[test]
    fn gae_exec_matches_rust_reference() {
        let rt = compute_or_skip!(Runtime::cpu());
        let m = compute_or_skip!(Manifest::load("artifacts"));
        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let g = GaeExec::load(&rt, cfg).unwrap();
        let (t, n) = (cfg.num_steps, cfg.num_envs);
        let mut rng = crate::rng::Pcg32::new(5, 5);
        let rewards: Vec<f32> = (0..t * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let values: Vec<f32> = (0..t * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let last: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let dones: Vec<f32> = (0..t * n).map(|_| (rng.uniform() < 0.05) as u8 as f32).collect();
        let truncs = vec![0.0; t * n];
        let (adv, ret) = g.compute(&rt, &rewards, &values, &last, &dones, &truncs).unwrap();
        let (adv2, ret2) = crate::agent::gae::gae_ref(
            &rewards, &values, &last, &dones, &truncs, t, n, cfg.gamma, cfg.lam,
        );
        for i in 0..t * n {
            assert!((adv[i] - adv2[i]).abs() < 1e-3, "adv[{i}] {} vs {}", adv[i], adv2[i]);
            assert!((ret[i] - ret2[i]).abs() < 1e-3, "ret[{i}]");
        }
    }

    #[test]
    fn train_step_updates_parameters() {
        let rt = compute_or_skip!(Runtime::cpu());
        let man = compute_or_skip!(Manifest::load("artifacts"));
        let cfg = man.for_task("CartPole-v1", 8).unwrap();
        let mut params = ParamStore::load(&man, cfg).unwrap();
        let before = params.values.clone();
        let mut m = params.zeros_like();
        let mut v = params.zeros_like();
        let mut t = 0.0f32;
        let tr = TrainExec::load(&rt, cfg).unwrap();
        let b = cfg.minibatch_size;
        let mut rng = crate::rng::Pcg32::new(1, 2);
        let obs: Vec<f32> = (0..b * cfg.obs_dim).map(|_| rng.range(-0.1, 0.1)).collect();
        let actions: Vec<f32> = (0..b).map(|_| rng.below(2) as f32).collect();
        let logp = vec![-0.6931f32; b]; // log(0.5)
        let adv: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let ret: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let mb = Minibatch { obs: &obs, actions: &actions, logp: &logp, adv: &adv, ret: &ret };
        let stats = tr.step(&rt, &mut params, &mut m, &mut v, &mut t, &mb, 1e-3).unwrap();
        assert!(stats.loss.is_finite());
        assert!(stats.entropy > 0.0, "fresh policy should have entropy, got {}", stats.entropy);
        assert_eq!(t, 1.0);
        assert!(params.values != before, "parameters must move");
        assert!(m.global_norm() > 0.0, "adam m must accumulate");
    }
}
