//! PJRT client wrapper with an executable cache: each HLO-text artifact
//! is parsed and compiled once, then reused for the whole run.

use crate::{Error, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// The process-wide XLA runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()?, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text file (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = path.as_ref().to_string_lossy().into_owned();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        if !path.as_ref().is_file() {
            return Err(Error::Artifact(format!(
                "{key} not found — run `make artifacts`"
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(&key)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute with literal inputs; returns the decomposed output tuple
    /// (aot.py lowers everything with `return_tuple=True`).
    ///
    /// NOTE: prefer [`Self::run_bufs`] on hot paths — the xla crate's
    /// literal `execute` leaks its internal literal→buffer conversions
    /// (~arg bytes per call; see EXPERIMENTS.md §Perf), while the buffer
    /// path is clean.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = args
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        self.run_bufs(exe, &bufs)
    }

    /// Upload a host f32 tensor to a device buffer.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a host f32 scalar.
    pub fn buf_scalar(&self, x: f32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[x], &[], None)?)
    }

    /// Execute with device-buffer inputs; returns the decomposed output
    /// tuple as host literals.
    pub fn run_bufs(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute_b::<xla::PjRtBuffer>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute_or_skip;
    use crate::runtime::literal::{tensor_f32, to_vec_f32};

    #[test]
    fn cpu_client_comes_up() {
        let rt = compute_or_skip!(Runtime::cpu());
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn load_and_run_policy_artifact() {
        let rt = compute_or_skip!(Runtime::cpu());
        let m = compute_or_skip!(crate::runtime::artifact::Manifest::load("artifacts"));
        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let exe = rt.load(&cfg.policy_file).unwrap();
        // cache hit second time
        let _exe2 = rt.load(&cfg.policy_file).unwrap();

        let params = m.load_params(cfg).unwrap();
        let mut args: Vec<xla::Literal> = cfg
            .params
            .iter()
            .zip(&params)
            .map(|(meta, vals)| tensor_f32(vals, &meta.shape).unwrap())
            .collect();
        let obs = vec![0.1f32; 8 * 4];
        args.push(tensor_f32(&obs, &[8, 4]).unwrap());
        let out = rt.run(&exe, &args).unwrap();
        assert_eq!(out.len(), 2, "discrete policy returns (logits, value)");
        let logits = to_vec_f32(&out[0]).unwrap();
        let value = to_vec_f32(&out[1]).unwrap();
        assert_eq!(logits.len(), 8 * 2);
        assert_eq!(value.len(), 8);
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
