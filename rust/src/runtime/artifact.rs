//! Artifact manifest: parse `artifacts/manifest.txt` (the flat mirror of
//! manifest.json emitted by `compile.aot`) and load initial parameter
//! blobs.

use crate::config::KvFile;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// One named parameter's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered training configuration.
#[derive(Debug, Clone)]
pub struct ArtifactConfig {
    pub key: String,
    pub task: String,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: usize,
    pub continuous: bool,
    pub num_envs: usize,
    pub num_steps: usize,
    pub num_minibatches: usize,
    pub minibatch_size: usize,
    pub gamma: f32,
    pub lam: f32,
    pub params: Vec<ParamMeta>,
    pub policy_file: PathBuf,
    pub train_file: PathBuf,
    pub gae_file: PathBuf,
    pub params_file: PathBuf,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ArtifactConfig>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let kv = KvFile::load(path.to_str().ok_or_else(|| Error::Artifact("bad path".into()))?)
            .map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
        let keys = kv.get("configs", "");
        let mut configs = Vec::new();
        for key in keys.split(',').filter(|s| !s.is_empty()) {
            let g = |f: &str| kv.get(&format!("{key}.{f}"), "");
            let gi = |f: &str| -> Result<usize> {
                g(f).parse().map_err(|_| Error::Artifact(format!("{key}.{f} missing/bad")))
            };
            let gf = |f: &str| -> Result<f32> {
                g(f).parse().map_err(|_| Error::Artifact(format!("{key}.{f} missing/bad")))
            };
            let params = g("params")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|item| {
                    let (name, dims) = item
                        .split_once(':')
                        .ok_or_else(|| Error::Artifact(format!("bad param entry {item}")))?;
                    let shape = dims
                        .split('x')
                        .map(|d| d.parse().map_err(|_| Error::Artifact(format!("bad dim {d}"))))
                        .collect::<Result<Vec<usize>>>()?;
                    Ok(ParamMeta { name: name.to_string(), shape })
                })
                .collect::<Result<Vec<_>>>()?;
            configs.push(ArtifactConfig {
                key: key.to_string(),
                task: g("task"),
                obs_dim: gi("obs_dim")?,
                act_dim: gi("act_dim")?,
                hidden: gi("hidden")?,
                continuous: g("continuous") == "true",
                num_envs: gi("num_envs")?,
                num_steps: gi("num_steps")?,
                num_minibatches: gi("num_minibatches")?,
                minibatch_size: gi("minibatch_size")?,
                gamma: gf("gamma")?,
                lam: gf("lam")?,
                params,
                policy_file: dir.join(g("files.policy")),
                train_file: dir.join(g("files.train")),
                gae_file: dir.join(g("files.gae")),
                params_file: dir.join(g("files.params")),
            });
        }
        if configs.is_empty() {
            return Err(Error::Artifact(format!("no configs in {}", path.display())));
        }
        Ok(Manifest { dir, configs })
    }

    /// Find a config by exact key.
    pub fn by_key(&self, key: &str) -> Result<&ArtifactConfig> {
        self.configs
            .iter()
            .find(|c| c.key == key)
            .ok_or_else(|| Error::Artifact(format!("no artifact config named {key:?}")))
    }

    /// Find the config for `(task, num_envs)` — how the trainer resolves
    /// which executable set matches its TrainConfig.
    pub fn for_task(&self, task: &str, num_envs: usize) -> Result<&ArtifactConfig> {
        self.configs
            .iter()
            .find(|c| c.task == task && c.num_envs == num_envs && !c.key.ends_with("_pallas"))
            .ok_or_else(|| {
                let have: Vec<String> = self
                    .configs
                    .iter()
                    .filter(|c| c.task == task)
                    .map(|c| format!("{} (N={})", c.key, c.num_envs))
                    .collect();
                Error::Artifact(format!(
                    "no artifacts for task {task:?} with num_envs {num_envs}; \
                     available: {have:?} — add a config to python/compile/aot.py \
                     and re-run `make artifacts`"
                ))
            })
    }

    /// Load the initial parameter blob for a config (raw f32 LE,
    /// concatenated in spec order).
    pub fn load_params(&self, cfg: &ArtifactConfig) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(&cfg.params_file)?;
        let total: usize = cfg.params.iter().map(|p| p.numel()).sum();
        if bytes.len() != total * 4 {
            return Err(Error::Artifact(format!(
                "{}: {} bytes, expected {} ({} f32s)",
                cfg.params_file.display(),
                bytes.len(),
                total * 4,
                total
            )));
        }
        let mut out = Vec::with_capacity(cfg.params.len());
        let mut off = 0;
        for p in &cfg.params {
            let n = p.numel();
            let vals = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push(vals);
            off += n * 4;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::load("artifacts").expect("run `make artifacts` first")
    }

    #[test]
    fn manifest_parses_and_has_cartpole() {
        let m = manifest();
        let c = m.for_task("CartPole-v1", 8).unwrap();
        assert_eq!(c.obs_dim, 4);
        assert_eq!(c.act_dim, 2);
        assert!(!c.continuous);
        assert_eq!(c.params.len(), 8);
        assert_eq!(c.params[0].shape, vec![4, 64]);
        assert!(c.policy_file.is_file());
        assert!(c.train_file.is_file());
        assert!(c.gae_file.is_file());
    }

    #[test]
    fn params_blob_loads_with_correct_sizes() {
        let m = manifest();
        let c = m.for_task("CartPole-v1", 8).unwrap();
        let params = m.load_params(c).unwrap();
        assert_eq!(params.len(), 8);
        assert_eq!(params[0].len(), 4 * 64);
        // orthogonal init => nonzero weights, zero biases
        assert!(params[0].iter().any(|&x| x != 0.0));
        assert!(params[1].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn continuous_config_has_log_std() {
        let m = manifest();
        let c = m.for_task("Ant-v4", 64).unwrap();
        assert!(c.continuous);
        assert!(c.params.iter().any(|p| p.name == "log_std"));
    }

    #[test]
    fn unknown_lookup_is_helpful() {
        let m = manifest();
        let e = m.for_task("CartPole-v1", 999).unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }
}
