//! Artifact manifest: parse `artifacts/manifest.txt` (the flat mirror of
//! manifest.json emitted by `compile.aot`) and load initial parameter
//! blobs.

use crate::config::KvFile;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// One named parameter's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered training configuration.
#[derive(Debug, Clone)]
pub struct ArtifactConfig {
    pub key: String,
    pub task: String,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: usize,
    pub continuous: bool,
    pub num_envs: usize,
    pub num_steps: usize,
    pub num_minibatches: usize,
    pub minibatch_size: usize,
    pub gamma: f32,
    pub lam: f32,
    pub params: Vec<ParamMeta>,
    pub policy_file: PathBuf,
    pub train_file: PathBuf,
    pub gae_file: PathBuf,
    pub params_file: PathBuf,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ArtifactConfig>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let kv = KvFile::load(path.to_str().ok_or_else(|| Error::Artifact("bad path".into()))?)
            .map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
        let keys = kv.get("configs", "");
        let mut configs = Vec::new();
        for key in keys.split(',').filter(|s| !s.is_empty()) {
            let g = |f: &str| kv.get(&format!("{key}.{f}"), "");
            let gi = |f: &str| -> Result<usize> {
                g(f).parse().map_err(|_| Error::Artifact(format!("{key}.{f} missing/bad")))
            };
            let gf = |f: &str| -> Result<f32> {
                g(f).parse().map_err(|_| Error::Artifact(format!("{key}.{f} missing/bad")))
            };
            let params = g("params")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|item| {
                    let (name, dims) = item
                        .split_once(':')
                        .ok_or_else(|| Error::Artifact(format!("bad param entry {item}")))?;
                    let shape = dims
                        .split('x')
                        .map(|d| d.parse().map_err(|_| Error::Artifact(format!("bad dim {d}"))))
                        .collect::<Result<Vec<usize>>>()?;
                    Ok(ParamMeta { name: name.to_string(), shape })
                })
                .collect::<Result<Vec<_>>>()?;
            configs.push(ArtifactConfig {
                key: key.to_string(),
                task: g("task"),
                obs_dim: gi("obs_dim")?,
                act_dim: gi("act_dim")?,
                hidden: gi("hidden")?,
                continuous: g("continuous") == "true",
                num_envs: gi("num_envs")?,
                num_steps: gi("num_steps")?,
                num_minibatches: gi("num_minibatches")?,
                minibatch_size: gi("minibatch_size")?,
                gamma: gf("gamma")?,
                lam: gf("lam")?,
                params,
                policy_file: dir.join(g("files.policy")),
                train_file: dir.join(g("files.train")),
                gae_file: dir.join(g("files.gae")),
                params_file: dir.join(g("files.params")),
            });
        }
        if configs.is_empty() {
            return Err(Error::Artifact(format!("no configs in {}", path.display())));
        }
        Ok(Manifest { dir, configs })
    }

    /// Find a config by exact key.
    pub fn by_key(&self, key: &str) -> Result<&ArtifactConfig> {
        self.configs
            .iter()
            .find(|c| c.key == key)
            .ok_or_else(|| Error::Artifact(format!("no artifact config named {key:?}")))
    }

    /// Find the config for `(task, num_envs)` — how the trainer resolves
    /// which executable set matches its TrainConfig.
    pub fn for_task(&self, task: &str, num_envs: usize) -> Result<&ArtifactConfig> {
        self.configs
            .iter()
            .find(|c| c.task == task && c.num_envs == num_envs && !c.key.ends_with("_pallas"))
            .ok_or_else(|| {
                let have: Vec<String> = self
                    .configs
                    .iter()
                    .filter(|c| c.task == task)
                    .map(|c| format!("{} (N={})", c.key, c.num_envs))
                    .collect();
                // NOTE: `runtime::backend::missing_task_config` matches
                // the "no artifacts for task" prefix to let `--backend
                // auto` fall back to native; keep them in sync (the
                // fallback test pins the behavior).
                Error::Artifact(format!(
                    "no artifacts for task {task:?} with num_envs {num_envs}; \
                     available: {have:?} — add a config to python/compile/aot.py \
                     and re-run `make artifacts`"
                ))
            })
    }

    /// Load the initial parameter blob for a config (raw f32 LE,
    /// concatenated in spec order).
    pub fn load_params(&self, cfg: &ArtifactConfig) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(&cfg.params_file)?;
        let total: usize = cfg.params.iter().map(|p| p.numel()).sum();
        if bytes.len() != total * 4 {
            return Err(Error::Artifact(format!(
                "{}: {} bytes, expected {} ({} f32s)",
                cfg.params_file.display(),
                bytes.len(),
                total * 4,
                total
            )));
        }
        let mut out = Vec::with_capacity(cfg.params.len());
        let mut off = 0;
        for p in &cfg.params {
            let n = p.numel();
            let vals = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push(vals);
            off += n * 4;
        }
        Ok(out)
    }
}

/// Test support: synthesize a minimal-but-complete artifacts directory
/// (manifest + params blob + placeholder HLO files) so the manifest and
/// parameter-loading code paths are exercised without running
/// `make artifacts`. Unit tests across the crate share this.
#[cfg(test)]
pub(crate) mod testsupport {
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    static COUNTER: AtomicU64 = AtomicU64::new(0);

    const MANIFEST: &str = "\
configs = cartpole_n8,pend_n4
# discrete config
cartpole_n8.task = CartPole-v1
cartpole_n8.obs_dim = 4
cartpole_n8.act_dim = 2
cartpole_n8.hidden = 64
cartpole_n8.continuous = false
cartpole_n8.num_envs = 8
cartpole_n8.num_steps = 128
cartpole_n8.num_minibatches = 4
cartpole_n8.minibatch_size = 256
cartpole_n8.gamma = 0.99
cartpole_n8.lam = 0.95
cartpole_n8.params = w1:4x64,b1:64,w2:64x64,b2:64,wp:64x2,bp:2,wv:64x1,bv:1
cartpole_n8.files.policy = cartpole_n8.policy.hlo
cartpole_n8.files.train = cartpole_n8.train.hlo
cartpole_n8.files.gae = cartpole_n8.gae.hlo
cartpole_n8.files.params = cartpole_n8.params.bin
# continuous config
pend_n4.task = Pendulum-v1
pend_n4.obs_dim = 3
pend_n4.act_dim = 1
pend_n4.hidden = 64
pend_n4.continuous = true
pend_n4.num_envs = 4
pend_n4.num_steps = 64
pend_n4.num_minibatches = 4
pend_n4.minibatch_size = 64
pend_n4.gamma = 0.99
pend_n4.lam = 0.95
pend_n4.params = w1:3x64,b1:64,wp:64x1,bp:1,log_std:1,wv:64x1,bv:1
pend_n4.files.policy = pend_n4.policy.hlo
pend_n4.files.train = pend_n4.train.hlo
pend_n4.files.gae = pend_n4.gae.hlo
pend_n4.files.params = pend_n4.params.bin
";

    /// Write a synthetic artifacts dir and return its path. Weight
    /// tensors are filled with a nonzero pattern, bias tensors with
    /// zeros (mirroring the orthogonal/zero init aot.py exports).
    pub(crate) fn synth_artifacts_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "envpool-test-artifacts-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
        let m = super::Manifest::load(&dir).unwrap();
        for cfg in &m.configs {
            for f in [&cfg.policy_file, &cfg.train_file, &cfg.gae_file] {
                std::fs::write(f, "HloModule placeholder\n").unwrap();
            }
            let mut blob = Vec::new();
            for p in &cfg.params {
                // "weights" (rank >= 2 or named log_std) nonzero, biases zero
                let nonzero = p.shape.len() >= 2 || p.name == "log_std";
                for i in 0..p.numel() {
                    let v: f32 = if nonzero { 0.01 * (i % 97 + 1) as f32 } else { 0.0 };
                    blob.extend_from_slice(&v.to_le_bytes());
                }
            }
            std::fs::write(&cfg.params_file, blob).unwrap();
        }
        dir
    }
}

#[cfg(test)]
mod tests {
    use super::testsupport::synth_artifacts_dir;
    use super::*;

    #[test]
    fn manifest_parses_and_has_cartpole() {
        let dir = synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let c = m.for_task("CartPole-v1", 8).unwrap();
        assert_eq!(c.obs_dim, 4);
        assert_eq!(c.act_dim, 2);
        assert!(!c.continuous);
        assert_eq!(c.params.len(), 8);
        assert_eq!(c.params[0].shape, vec![4, 64]);
        assert!(c.policy_file.is_file());
        assert!(c.train_file.is_file());
        assert!(c.gae_file.is_file());
    }

    #[test]
    fn params_blob_loads_with_correct_sizes() {
        let dir = synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let c = m.for_task("CartPole-v1", 8).unwrap();
        let params = m.load_params(c).unwrap();
        assert_eq!(params.len(), 8);
        assert_eq!(params[0].len(), 4 * 64);
        // weight init nonzero, bias init zero
        assert!(params[0].iter().any(|&x| x != 0.0));
        assert!(params[1].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn truncated_params_blob_is_rejected() {
        let dir = synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let c = m.for_task("CartPole-v1", 8).unwrap();
        let blob = std::fs::read(&c.params_file).unwrap();
        std::fs::write(&c.params_file, &blob[..blob.len() - 4]).unwrap();
        assert!(matches!(m.load_params(c), Err(Error::Artifact(_))));
    }

    #[test]
    fn continuous_config_has_log_std() {
        let dir = synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let c = m.for_task("Pendulum-v1", 4).unwrap();
        assert!(c.continuous);
        assert!(c.params.iter().any(|p| p.name == "log_std"));
    }

    #[test]
    fn unknown_lookup_is_helpful() {
        let dir = synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let e = m.for_task("CartPole-v1", 999).unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn missing_manifest_reports_artifact_error() {
        assert!(matches!(
            Manifest::load("definitely-not-an-artifacts-dir"),
            Err(Error::Artifact(_))
        ));
    }
}
