//! Typed helpers over `xla::Literal` (f32 tensors on the host side).

use crate::Result;

/// Build an f32 literal of the given shape from a flat slice.
pub fn tensor_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let l = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(l.reshape(&dims_i64)?)
}

/// Scalar f32 literal.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Read an f32 literal back to a host vector.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Read an f32 literal's first element (for scalar outputs).
pub fn scalar_of(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let l = tensor_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), data);
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
    }

    #[test]
    fn scalar_roundtrip() {
        let l = scalar_f32(2.5);
        assert_eq!(scalar_of(&l).unwrap(), 2.5);
    }
}
