//! Pure-Rust compute core for the `native` backend: an MLP actor-critic
//! (two Tanh hidden layers, discrete-logits or continuous mu/log_std
//! heads plus a value head), PPO clipped-surrogate + value + entropy
//! losses with **analytic backprop**, global-norm gradient clipping, and
//! an Adam optimizer — no XLA, no artifacts, no allocation surprises.
//!
//! All internal math is `f64`: the backend is a fallback for laptops and
//! CI, not a throughput record, and double precision makes the
//! finite-difference gradient check in this module airtight (central
//! differences at `eps = 1e-6` resolve ~1e-10, far below the test
//! tolerance). The API boundary stays `f32` to match the PJRT backend.
//!
//! Parameter order mirrors the AOT artifact convention
//! ([`crate::agent::params::actor_critic_meta`]): `w1, b1, w2, b2, wp,
//! bp, [log_std,] wv, bv`, with `log_std` present only for continuous
//! action spaces (state-independent, CleanRL-style).

use crate::agent::params::{actor_critic_meta, ParamStore};
use crate::runtime::artifact::ParamMeta;
use crate::simd::{axpy_f32, gemm_bt_f32};
use crate::{Error, Result};

/// Tensor indices into [`NativeNet::params`] (fixed by construction).
const W1: usize = 0;
const B1: usize = 1;
const W2: usize = 2;
const B2: usize = 3;
const WP: usize = 4;
const BP: usize = 5;
/// `log_std` sits at 6 for continuous nets; `wv`/`bv` shift accordingly.
const LOG_STD: usize = 6;

const LN_2PI: f64 = 1.837_877_066_409_345_3;

/// PPO loss hyperparameters consumed by [`NativeNet::loss_and_grad`].
#[derive(Debug, Clone, Copy)]
pub struct PpoHyper {
    /// Clip coefficient epsilon.
    pub clip_coef: f64,
    /// Value loss coefficient c1.
    pub vf_coef: f64,
    /// Entropy bonus coefficient c2.
    pub ent_coef: f64,
    /// Normalize advantages per minibatch (CleanRL default).
    pub norm_adv: bool,
}

/// Scalars of one loss evaluation (f64; the backend converts to
/// [`crate::runtime::TrainStats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeStats {
    pub loss: f64,
    pub pg_loss: f64,
    pub v_loss: f64,
    pub entropy: f64,
    pub approx_kl: f64,
}

/// One minibatch in f64 (the backend converts from the shared f32
/// [`crate::runtime::trainer_exec::Minibatch`] views).
pub struct MinibatchF64 {
    /// `[B, obs_dim]`
    pub obs: Vec<f64>,
    /// Discrete: `[B]` action ids; continuous: `[B, act_dim]`.
    pub actions: Vec<f64>,
    /// `[B]` behaviour-policy log-probs.
    pub logp: Vec<f64>,
    /// `[B]` advantages (pre-normalization).
    pub adv: Vec<f64>,
    /// `[B]` returns.
    pub ret: Vec<f64>,
}

/// Output of the shared per-sample PPO head pass: loss scalars plus
/// gradients w.r.t. the head outputs (`dist`, `value`, `log_std`).
struct HeadPass {
    stats: NativeStats,
    /// `[B, act_dim]` dL/d(logits or mu).
    d_dist: Vec<f64>,
    /// `[B]` dL/d(value).
    d_value: Vec<f64>,
    /// `[act_dim]` dL/d(log_std) (continuous only; empty otherwise).
    d_log_std: Vec<f64>,
}

/// Forward-pass activations cached for backprop.
pub struct Forward {
    /// `[B, hidden]` after the first Tanh.
    pub h1: Vec<f64>,
    /// `[B, hidden]` after the second Tanh.
    pub h2: Vec<f64>,
    /// `[B, act_dim]` logits (discrete) or mu (continuous).
    pub dist: Vec<f64>,
    /// `[B]` state values.
    pub value: Vec<f64>,
}

/// The native MLP actor-critic.
#[derive(Debug, Clone)]
pub struct NativeNet {
    pub obs_dim: usize,
    /// Discrete action count or continuous action dimension.
    pub act_dim: usize,
    pub hidden: usize,
    pub continuous: bool,
    /// Parameter tensors in [`actor_critic_meta`] order, flat row-major.
    pub params: Vec<Vec<f64>>,
    /// Matching shape metadata (shared naming with the artifact path).
    pub meta: Vec<ParamMeta>,
}

impl NativeNet {
    /// Deterministic construction from `(seed)`: scaled-Gaussian init via
    /// [`ParamStore::init_actor_critic`] (`Pcg32`-seeded), promoted to
    /// f64.
    pub fn new(
        obs_dim: usize,
        act_dim: usize,
        hidden: usize,
        continuous: bool,
        seed: u64,
    ) -> Result<NativeNet> {
        if obs_dim == 0 || act_dim == 0 || hidden == 0 {
            return Err(Error::Config(format!(
                "native net dims must be > 0 (obs_dim {obs_dim}, act_dim {act_dim}, \
                 hidden {hidden})"
            )));
        }
        let store = ParamStore::init_actor_critic(obs_dim, act_dim, hidden, continuous, seed);
        Ok(NativeNet::from_store(obs_dim, act_dim, hidden, continuous, &store))
    }

    /// Promote an f32 [`ParamStore`] (in [`actor_critic_meta`] order) to
    /// the f64 working representation.
    pub fn from_store(
        obs_dim: usize,
        act_dim: usize,
        hidden: usize,
        continuous: bool,
        store: &ParamStore,
    ) -> NativeNet {
        debug_assert_eq!(store.meta, actor_critic_meta(obs_dim, act_dim, hidden, continuous));
        let params = store
            .values
            .iter()
            .map(|v| v.iter().map(|&x| x as f64).collect())
            .collect();
        NativeNet { obs_dim, act_dim, hidden, continuous, params, meta: store.meta.clone() }
    }

    /// Demote back to an f32 [`ParamStore`] (reporting/checkpointing).
    pub fn to_store(&self) -> ParamStore {
        ParamStore {
            meta: self.meta.clone(),
            values: self.params.iter().map(|v| v.iter().map(|&x| x as f32).collect()).collect(),
        }
    }

    fn idx_wv(&self) -> usize {
        if self.continuous {
            LOG_STD + 1
        } else {
            LOG_STD
        }
    }

    fn idx_bv(&self) -> usize {
        self.idx_wv() + 1
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.meta.iter().map(|m| m.numel()).sum()
    }

    /// Zero tensors with the parameter shapes (grads / Adam moments).
    pub fn zeros_like(&self) -> Vec<Vec<f64>> {
        self.params.iter().map(|v| vec![0.0; v.len()]).collect()
    }

    /// Batched forward pass: `x` is `[bsz, obs_dim]` row-major.
    pub fn forward(&self, x: &[f64], bsz: usize) -> Forward {
        debug_assert_eq!(x.len(), bsz * self.obs_dim);
        let h = self.hidden;
        let a = self.act_dim;
        let mut h1 = vec![0.0; bsz * h];
        let mut h2 = vec![0.0; bsz * h];
        let mut dist = vec![0.0; bsz * a];
        let mut value = vec![0.0; bsz];
        affine(x, &self.params[W1], &self.params[B1], &mut h1, bsz, self.obs_dim, h);
        for v in h1.iter_mut() {
            *v = v.tanh();
        }
        affine(&h1, &self.params[W2], &self.params[B2], &mut h2, bsz, h, h);
        for v in h2.iter_mut() {
            *v = v.tanh();
        }
        affine(&h2, &self.params[WP], &self.params[BP], &mut dist, bsz, h, a);
        // value head: wv is [hidden, 1], so this is affine with d_out = 1
        let (wv, bv) = (&self.params[self.idx_wv()], &self.params[self.idx_bv()]);
        affine(&h2, wv, bv, &mut value, bsz, h, 1);
        Forward { h1, h2, dist, value }
    }

    /// The per-sample log-std vector (continuous nets only; empty
    /// otherwise) — state-independent, broadcast by the backend.
    pub fn log_std(&self) -> &[f64] {
        if self.continuous {
            &self.params[LOG_STD]
        } else {
            &[]
        }
    }

    /// The per-sample PPO head pass shared by the f64 path and the f32
    /// fast path: from the head outputs (`dist`, `value`, `log_std`) and
    /// the minibatch, compute the loss scalars and the gradients w.r.t.
    /// the head outputs. Branchy decisions (clip branch, softmax max)
    /// always run in f64 — under `--precision f32` the inputs are
    /// promoted activations, so the two precisions share every branch
    /// and differ only by f32 rounding of the linear algebra.
    /// `log_std` is a parameter (not read from `self.params`) so the
    /// f32 path differentiates w.r.t. its own demoted copy.
    fn head_pass(
        &self,
        dist: &[f64],
        value: &[f64],
        log_std: &[f64],
        mb: &MinibatchF64,
        hp: &PpoHyper,
    ) -> HeadPass {
        let a = self.act_dim;
        let bsz = mb.logp.len();
        debug_assert_eq!(dist.len(), bsz * a);
        debug_assert_eq!(value.len(), bsz);
        debug_assert_eq!(mb.actions.len(), if self.continuous { bsz * a } else { bsz });
        let bf = bsz as f64;

        // Advantage normalization is constant w.r.t. parameters.
        let advn: Vec<f64> = if hp.norm_adv {
            let mean = mb.adv.iter().sum::<f64>() / bf;
            let var = mb.adv.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / bf;
            let std = var.sqrt().max(1e-8);
            mb.adv.iter().map(|x| (x - mean) / std).collect()
        } else {
            mb.adv.clone()
        };

        // dL/d dist-params and dL/d value, accumulated per sample.
        let mut d_dist = vec![0.0; bsz * a];
        let mut d_value = vec![0.0; bsz];
        let mut d_log_std = vec![0.0; if self.continuous { a } else { 0 }];

        let (mut pg_sum, mut ent_sum, mut v_sum, mut kl_sum) = (0.0, 0.0, 0.0, 0.0);
        let mut p = vec![0.0; a]; // softmax scratch (discrete)
        let mut zs = vec![0.0; a]; // z-score scratch (continuous)
        for i in 0..bsz {
            // ---- value head: c1 * 0.5 (V - ret)^2, meaned over batch ----
            let dv = value[i] - mb.ret[i];
            v_sum += 0.5 * dv * dv;
            d_value[i] = hp.vf_coef * dv / bf;

            // ---- new log-prob of the stored action ----
            let (logp_new, entropy_i);
            let mut lse = 0.0; // discrete log-sum-exp, reused by the grad pass
            if self.continuous {
                let mu = &dist[i * a..(i + 1) * a];
                let acts = &mb.actions[i * a..(i + 1) * a];
                let mut lp = 0.0;
                let mut ent = 0.0;
                for k in 0..a {
                    let ls = log_std[k];
                    let z = (acts[k] - mu[k]) * (-ls).exp();
                    zs[k] = z;
                    lp += -0.5 * z * z - ls - 0.5 * LN_2PI;
                    ent += ls + 0.5 * (1.0 + LN_2PI);
                }
                logp_new = lp;
                entropy_i = ent;
            } else {
                let logits = &dist[i * a..(i + 1) * a];
                let maxl = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut z = 0.0;
                for k in 0..a {
                    p[k] = (logits[k] - maxl).exp();
                    z += p[k];
                }
                lse = maxl + z.ln();
                for v in p.iter_mut() {
                    *v /= z;
                }
                let act = mb.actions[i] as usize;
                debug_assert!(act < a, "action id {act} out of range");
                logp_new = logits[act] - lse;
                entropy_i = -(0..a).map(|k| p[k] * (logits[k] - lse)).sum::<f64>();
            }
            ent_sum += entropy_i;

            // ---- clipped surrogate ----
            let logratio = logp_new - mb.logp[i];
            let ratio = logratio.exp();
            kl_sum += (ratio - 1.0) - logratio;
            let adv = advn[i];
            let pg1 = -adv * ratio;
            let pg2 = -adv * ratio.clamp(1.0 - hp.clip_coef, 1.0 + hp.clip_coef);
            pg_sum += pg1.max(pg2);
            // Gradient flows through `ratio` only on the unclipped branch
            // (when the clipped branch wins strictly, the ratio sits
            // outside the band and d clip/d ratio = 0).
            let dpg_dratio = if pg1 >= pg2 { -adv } else { 0.0 };
            // d ratio / d logp_new = ratio.
            let dl_dlogp = dpg_dratio * ratio / bf;

            // ---- distribute into head gradients ----
            if self.continuous {
                for k in 0..a {
                    let ls = log_std[k];
                    // d logp / d mu_k = z / std
                    d_dist[i * a + k] = dl_dlogp * zs[k] * (-ls).exp();
                    // d logp / d log_std_k = z^2 - 1
                    d_log_std[k] += dl_dlogp * (zs[k] * zs[k] - 1.0);
                }
            } else {
                let logits = &dist[i * a..(i + 1) * a];
                let act = mb.actions[i] as usize;
                for k in 0..a {
                    let logp_k = logits[k] - lse;
                    let ind = if k == act { 1.0 } else { 0.0 };
                    // policy-gradient term through logp(action)
                    let mut g = dl_dlogp * (ind - p[k]);
                    // entropy bonus: L += -c2 * mean(H);
                    // dH/dlogit_k = -p_k (logp_k + H)
                    g += hp.ent_coef / bf * p[k] * (logp_k + entropy_i);
                    d_dist[i * a + k] = g;
                }
            }
        }
        // Continuous entropy is distribution-wide: H = sum_k log_std_k + c,
        // so d(-c2·mean H)/d log_std_k = -c2.
        if self.continuous {
            for g in d_log_std.iter_mut() {
                *g += -hp.ent_coef;
            }
        }

        let stats = NativeStats {
            pg_loss: pg_sum / bf,
            v_loss: v_sum / bf,
            entropy: ent_sum / bf,
            approx_kl: kl_sum / bf,
            loss: pg_sum / bf - hp.ent_coef * (ent_sum / bf) + hp.vf_coef * (v_sum / bf),
        };
        HeadPass { stats, d_dist, d_value, d_log_std }
    }

    /// Evaluate the PPO loss on one minibatch; when `want_grad`, also
    /// return analytic gradients (same shapes as `params`, **unclipped**
    /// — clipping happens in [`Adam::step`] so finite differences
    /// compare against the raw derivative).
    ///
    /// Loss (CleanRL semantics): `L = pg - c2·H + c1·v`, with
    /// `pg = mean(max(-Â·r, -Â·clip(r, 1±eps)))`,
    /// `v = mean(0.5 (V - ret)²)`, `H` the mean policy entropy, and `Â`
    /// the (optionally minibatch-normalized) advantages.
    pub fn loss_and_grad(
        &self,
        mb: &MinibatchF64,
        hp: &PpoHyper,
        want_grad: bool,
    ) -> (NativeStats, Option<Vec<Vec<f64>>>) {
        let a = self.act_dim;
        let h = self.hidden;
        let bsz = mb.logp.len();
        debug_assert_eq!(mb.obs.len(), bsz * self.obs_dim);
        let fwd = self.forward(&mb.obs, bsz);
        let head = self.head_pass(&fwd.dist, &fwd.value, self.log_std(), mb, hp);
        let HeadPass { stats, d_dist, d_value, d_log_std } = head;
        if !want_grad {
            return (stats, None);
        }

        // ---- backprop through the trunk ----
        let mut g = self.zeros_like();
        // policy head: gwp[k,j] = sum_i h2[i,k] d_dist[i,j]
        for i in 0..bsz {
            let h2row = &fwd.h2[i * h..(i + 1) * h];
            let drow = &d_dist[i * a..(i + 1) * a];
            for k in 0..h {
                let gk = &mut g[WP][k * a..(k + 1) * a];
                for j in 0..a {
                    gk[j] += h2row[k] * drow[j];
                }
            }
            for j in 0..a {
                g[BP][j] += drow[j];
            }
        }
        // value head
        let (iwv, ibv) = (self.idx_wv(), self.idx_bv());
        for i in 0..bsz {
            let h2row = &fwd.h2[i * h..(i + 1) * h];
            for k in 0..h {
                g[iwv][k] += h2row[k] * d_value[i];
            }
            g[ibv][0] += d_value[i];
        }
        if self.continuous {
            g[LOG_STD].copy_from_slice(&d_log_std);
        }
        // dh2 = d_dist @ wp^T + d_value ⊗ wv, then through Tanh.
        let mut dpre2 = vec![0.0; bsz * h];
        let (wp, wv) = (&self.params[WP], &self.params[iwv]);
        for i in 0..bsz {
            let drow = &d_dist[i * a..(i + 1) * a];
            let h2row = &fwd.h2[i * h..(i + 1) * h];
            let out = &mut dpre2[i * h..(i + 1) * h];
            for k in 0..h {
                let mut acc = d_value[i] * wv[k];
                let wrow = &wp[k * a..(k + 1) * a];
                for j in 0..a {
                    acc += drow[j] * wrow[j];
                }
                out[k] = acc * (1.0 - h2row[k] * h2row[k]);
            }
        }
        // gw2[k,j] = sum_i h1[i,k] dpre2[i,j]; dh1 = dpre2 @ w2^T
        let mut dpre1 = vec![0.0; bsz * h];
        let w2 = &self.params[W2];
        for i in 0..bsz {
            let h1row = &fwd.h1[i * h..(i + 1) * h];
            let drow = &dpre2[i * h..(i + 1) * h];
            for k in 0..h {
                let gk = &mut g[W2][k * h..(k + 1) * h];
                for j in 0..h {
                    gk[j] += h1row[k] * drow[j];
                }
            }
            for j in 0..h {
                g[B2][j] += drow[j];
            }
            let out = &mut dpre1[i * h..(i + 1) * h];
            for k in 0..h {
                let mut acc = 0.0;
                let wrow = &w2[k * h..(k + 1) * h];
                for j in 0..h {
                    acc += drow[j] * wrow[j];
                }
                out[k] = acc * (1.0 - h1row[k] * h1row[k]);
            }
        }
        // gw1[d,j] = sum_i x[i,d] dpre1[i,j]
        let d_in = self.obs_dim;
        for i in 0..bsz {
            let xrow = &mb.obs[i * d_in..(i + 1) * d_in];
            let drow = &dpre1[i * h..(i + 1) * h];
            for k in 0..d_in {
                let gk = &mut g[W1][k * h..(k + 1) * h];
                for j in 0..h {
                    gk[j] += xrow[k] * drow[j];
                }
            }
            for j in 0..h {
                g[B1][j] += drow[j];
            }
        }
        (stats, Some(g))
    }
}

/// `out[i,j] = b[j] + sum_k x[i,k] w[k,j]` (row-major everywhere).
#[allow(clippy::too_many_arguments)]
fn affine(
    x: &[f64],
    w: &[f64],
    b: &[f64],
    out: &mut [f64],
    bsz: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(w.len(), d_in * d_out);
    for i in 0..bsz {
        let orow = &mut out[i * d_out..(i + 1) * d_out];
        orow.copy_from_slice(b);
        let xrow = &x[i * d_in..(i + 1) * d_in];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * d_out..(k + 1) * d_out];
            for j in 0..d_out {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

// ---------------------------------------------------------------------
// f32 fast path (`TrainConfig::precision = f32`)
// ---------------------------------------------------------------------

/// f32 mirror of the parameter tensors — the **compute weights** of the
/// f32 fast path. The f64 tensors in [`NativeNet::params`] remain the
/// master weights: Adam updates them in f64, then
/// [`NativeNet::refresh_params_f32`] re-demotes into this mirror (the
/// classic mixed-precision scheme, so optimizer drift never accumulates
/// in half the mantissa).
#[derive(Debug, Clone)]
pub struct ParamsF32 {
    /// Tensors in [`actor_critic_meta`] order, flat row-major.
    pub t: Vec<Vec<f32>>,
    /// `[hidden, obs_dim]` transpose of `W1` — the blocked-GEMM compute
    /// layout ([`crate::simd::gemm_bt_f32`] wants `[d_out, d_in]` rows
    /// so each output element is one contiguous dot). Rebuilt by
    /// [`NativeNet::refresh_params_f32`] /
    /// [`NativeNet::rebuild_transposes_f32`]; `t` stays the source of
    /// truth (it is what the finite-difference guard perturbs and what
    /// gradients are expressed against).
    pub w1t: Vec<f32>,
    /// `[hidden, hidden]` transpose of `W2`.
    pub w2t: Vec<f32>,
    /// `[act_dim, hidden]` transpose of `WP`. (The value head's `WV` is
    /// `[hidden, 1]`, whose transpose is the same flat buffer — no
    /// mirror needed.)
    pub wpt: Vec<f32>,
}

/// `wt[j·d_in + k] = w[k·d_out + j]` — demoted-weight transpose into
/// the `[d_out, d_in]` GEMM layout.
fn transpose_into(w: &[f32], d_in: usize, d_out: usize, wt: &mut [f32]) {
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(wt.len(), d_in * d_out);
    for k in 0..d_in {
        let wrow = &w[k * d_out..(k + 1) * d_out];
        for (j, &v) in wrow.iter().enumerate() {
            wt[j * d_in + k] = v;
        }
    }
}

/// f32 forward-pass activations cached for backprop.
pub struct ForwardF32 {
    /// `[B, hidden]` after the first Tanh.
    pub h1: Vec<f32>,
    /// `[B, hidden]` after the second Tanh.
    pub h2: Vec<f32>,
    /// `[B, act_dim]` logits (discrete) or mu (continuous).
    pub dist: Vec<f32>,
    /// `[B]` state values.
    pub value: Vec<f32>,
}

impl NativeNet {
    /// Demote the f64 master weights into a fresh f32 mirror (including
    /// the transposed GEMM layouts).
    pub fn params_f32(&self) -> ParamsF32 {
        let t: Vec<Vec<f32>> =
            self.params.iter().map(|v| v.iter().map(|&x| x as f32).collect()).collect();
        let mut p = ParamsF32 {
            w1t: vec![0.0; t[W1].len()],
            w2t: vec![0.0; t[W2].len()],
            wpt: vec![0.0; t[WP].len()],
            t,
        };
        self.rebuild_transposes_f32(&mut p);
        p
    }

    /// Re-demote the master weights into an existing mirror (after each
    /// optimizer step; no allocation) and refresh the transposed GEMM
    /// layouts.
    pub fn refresh_params_f32(&self, dst: &mut ParamsF32) {
        for (d, sv) in dst.t.iter_mut().zip(&self.params) {
            for (x, &y) in d.iter_mut().zip(sv) {
                *x = y as f32;
            }
        }
        self.rebuild_transposes_f32(dst);
    }

    /// Rebuild the `[d_out, d_in]` transposes from `dst.t` — the sync
    /// point for any code (tests, FD guards) that edits the row-major
    /// tensors directly. Pure permutation: bitwise copies, no rounding.
    pub fn rebuild_transposes_f32(&self, dst: &mut ParamsF32) {
        let h = self.hidden;
        transpose_into(&dst.t[W1], self.obs_dim, h, &mut dst.w1t);
        transpose_into(&dst.t[W2], h, h, &mut dst.w2t);
        transpose_into(&dst.t[WP], h, self.act_dim, &mut dst.wpt);
    }

    /// The f32 mirror's state-independent log-std row (continuous nets
    /// only; empty otherwise).
    pub fn log_std_of<'a>(&self, p: &'a ParamsF32) -> &'a [f32] {
        if self.continuous {
            &p.t[LOG_STD]
        } else {
            &[]
        }
    }

    /// Batched f32 forward pass over the mirror weights: the same
    /// network as [`NativeNet::forward`], with every affine running the
    /// cache-blocked transposed-weights GEMM
    /// ([`crate::simd::gemm_bt_f32`]) and the activation running the
    /// deterministic `tanh` twin ([`crate::simd::math::tanh_f32`],
    /// ≤ 2 ULP vs demoted f64 libm) instead of one scalar libm call per
    /// hidden unit. This is the rollout-inference hot path under
    /// `--precision f32` — no f64 promotion anywhere, and the result is
    /// independent of `bsz` and machine (see the GEMM's docs). The
    /// retained axpy GEMV ([`affine_f32`]) is the Table 2g baseline and
    /// the reassociation-budget reference in `tests/simd_parity.rs`.
    pub fn forward_f32(&self, p: &ParamsF32, x: &[f32], bsz: usize) -> ForwardF32 {
        debug_assert_eq!(x.len(), bsz * self.obs_dim);
        let h = self.hidden;
        let a = self.act_dim;
        let mut h1 = vec![0.0f32; bsz * h];
        let mut h2 = vec![0.0f32; bsz * h];
        let mut dist = vec![0.0f32; bsz * a];
        let mut value = vec![0.0f32; bsz];
        gemm_bt_f32(x, &p.w1t, &p.t[B1], &mut h1, bsz, self.obs_dim, h);
        for v in h1.iter_mut() {
            *v = crate::simd::math::tanh_f32(*v);
        }
        gemm_bt_f32(&h1, &p.w2t, &p.t[B2], &mut h2, bsz, h, h);
        for v in h2.iter_mut() {
            *v = crate::simd::math::tanh_f32(*v);
        }
        gemm_bt_f32(&h2, &p.wpt, &p.t[BP], &mut dist, bsz, h, a);
        // WV is [hidden, 1]: its transpose is the same flat buffer, so
        // the GEMM reads it directly as the single [1, hidden] row.
        let (wv, bv) = (&p.t[self.idx_wv()], &p.t[self.idx_bv()]);
        gemm_bt_f32(&h2, wv, bv, &mut value, bsz, h, 1);
        ForwardF32 { h1, h2, dist, value }
    }

    /// The f32 fast-path loss + gradient: f32 SIMD forward, the shared
    /// f64 head pass on promoted head outputs (every branch decision is
    /// taken by the same f64 code as the f64 path — the precisions can
    /// only differ by rounding, never by branching), then f32 SIMD
    /// backward GEMMs. Returns gradients w.r.t. the **mirror** weights
    /// `p` (what the finite-difference guard in the tests perturbs);
    /// the backend promotes them to f64 for Adam on the master weights.
    ///
    /// `mb` supplies actions/logp/adv/ret (f64, shared head pass);
    /// `obs32` is the raw f32 observation matrix — the fast path never
    /// promotes the `[B, obs_dim]` block.
    pub fn loss_and_grad_f32(
        &self,
        p: &ParamsF32,
        obs32: &[f32],
        mb: &MinibatchF64,
        hp: &PpoHyper,
    ) -> (NativeStats, Vec<Vec<f32>>) {
        let a = self.act_dim;
        let h = self.hidden;
        let bsz = mb.logp.len();
        debug_assert_eq!(obs32.len(), bsz * self.obs_dim);
        let fwd = self.forward_f32(p, obs32, bsz);

        // Promote head outputs (O(B·A), tiny next to the GEMMs).
        let dist64: Vec<f64> = fwd.dist.iter().map(|&v| v as f64).collect();
        let value64: Vec<f64> = fwd.value.iter().map(|&v| v as f64).collect();
        let ls64: Vec<f64> = self.log_std_of(p).iter().map(|&v| v as f64).collect();
        let head = self.head_pass(&dist64, &value64, &ls64, mb, hp);

        // Demote head gradients; everything below is f32 + SIMD.
        let d_dist: Vec<f32> = head.d_dist.iter().map(|&v| v as f32).collect();
        let d_value: Vec<f32> = head.d_value.iter().map(|&v| v as f32).collect();
        let mut g: Vec<Vec<f32>> = self.params.iter().map(|v| vec![0.0f32; v.len()]).collect();

        // policy head: gwp[k, :] += h2[i, k] · d_dist[i, :]
        for i in 0..bsz {
            let h2row = &fwd.h2[i * h..(i + 1) * h];
            let drow = &d_dist[i * a..(i + 1) * a];
            for k in 0..h {
                axpy_f32(h2row[k], drow, &mut g[WP][k * a..(k + 1) * a]);
            }
            for (bj, &dj) in g[BP].iter_mut().zip(drow) {
                *bj += dj;
            }
        }
        // value head (axpy over the hidden dim — the vectorized axis)
        let (iwv, ibv) = (self.idx_wv(), self.idx_bv());
        for i in 0..bsz {
            let h2row = &fwd.h2[i * h..(i + 1) * h];
            axpy_f32(d_value[i], h2row, &mut g[iwv]);
            g[ibv][0] += d_value[i];
        }
        if self.continuous {
            for (dst, &v) in g[LOG_STD].iter_mut().zip(&head.d_log_std) {
                *dst = v as f32;
            }
        }
        // dpre2 = (d_dist @ wp^T + d_value ⊗ wv) ⊙ (1 − h2²)
        let mut dpre2 = vec![0.0f32; bsz * h];
        {
            let wp = &p.t[WP];
            let wv = &p.t[iwv];
            for i in 0..bsz {
                let drow = &d_dist[i * a..(i + 1) * a];
                let h2row = &fwd.h2[i * h..(i + 1) * h];
                let outr = &mut dpre2[i * h..(i + 1) * h];
                for k in 0..h {
                    let mut acc = d_value[i] * wv[k];
                    let wrow = &wp[k * a..(k + 1) * a];
                    for j in 0..a {
                        acc += drow[j] * wrow[j];
                    }
                    outr[k] = acc * (1.0 - h2row[k] * h2row[k]);
                }
            }
        }
        // gw2[k, :] += h1[i, k] · dpre2[i, :]; dpre1 = dpre2 @ w2^T — the
        // w2ᵀ contraction runs the SIMD reduction (`dot_f32`, the one
        // reassociating op: ULP-budgeted, see `tests/simd_parity.rs`).
        let mut dpre1 = vec![0.0f32; bsz * h];
        {
            let w2 = &p.t[W2];
            for i in 0..bsz {
                let h1row = &fwd.h1[i * h..(i + 1) * h];
                let drow = &dpre2[i * h..(i + 1) * h];
                for k in 0..h {
                    axpy_f32(h1row[k], drow, &mut g[W2][k * h..(k + 1) * h]);
                }
                for (bj, &dj) in g[B2].iter_mut().zip(drow) {
                    *bj += dj;
                }
                let outr = &mut dpre1[i * h..(i + 1) * h];
                for k in 0..h {
                    let acc = crate::simd::dot_f32(drow, &w2[k * h..(k + 1) * h]);
                    outr[k] = acc * (1.0 - h1row[k] * h1row[k]);
                }
            }
        }
        // gw1[d, :] += x[i, d] · dpre1[i, :]
        let d_in = self.obs_dim;
        for i in 0..bsz {
            let xrow = &obs32[i * d_in..(i + 1) * d_in];
            let drow = &dpre1[i * h..(i + 1) * h];
            for k in 0..d_in {
                axpy_f32(xrow[k], drow, &mut g[W1][k * h..(k + 1) * h]);
            }
            for (bj, &dj) in g[B1].iter_mut().zip(drow) {
                *bj += dj;
            }
        }
        (head.stats, g)
    }
}

/// `out[i,j] = b[j] + sum_k x[i,k] w[k,j]` in f32 with the SIMD lane
/// pass over `j` ([`axpy_f32`]): per output the accumulation order is
/// identical to the scalar loop (k ascending), so this is **bitwise**
/// equal to a naive f32 affine — only the f32-vs-f64 precision differs
/// from [`affine`], and that is governed by the tolerance tests.
///
/// No longer on the forward hot path (the blocked transposed GEMM
/// [`crate::simd::gemm_bt_f32`] replaced it in
/// [`NativeNet::forward_f32`]); kept `pub` as the sequential-
/// accumulation reference the GEMM's reassociation budget is measured
/// against (`tests/simd_parity.rs`) and as the Table 2g GEMV baseline
/// (`benches/table2g_contig.rs`).
#[allow(clippy::too_many_arguments)]
pub fn affine_f32(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    bsz: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(w.len(), d_in * d_out);
    for i in 0..bsz {
        let orow = &mut out[i * d_out..(i + 1) * d_out];
        orow.copy_from_slice(b);
        let xrow = &x[i * d_in..(i + 1) * d_in];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            axpy_f32(xv, &w[k * d_out..(k + 1) * d_out], orow);
        }
    }
}

/// Global-norm gradient clipping (in place); returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Vec<f64>], max_norm: f64) -> f64 {
    let sq: f64 = grads.iter().flat_map(|g| g.iter()).map(|x| x * x).sum();
    let norm = sq.sqrt();
    if max_norm > 0.0 && norm > max_norm {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

/// Adam optimizer state (bias-corrected; CleanRL's `eps = 1e-5`).
#[derive(Debug, Clone)]
pub struct Adam {
    pub m: Vec<Vec<f64>>,
    pub v: Vec<Vec<f64>>,
    pub t: u64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Adam {
    pub fn new(net: &NativeNet) -> Adam {
        Adam { m: net.zeros_like(), v: net.zeros_like(), t: 0, beta1: 0.9, beta2: 0.999, eps: 1e-5 }
    }

    /// One update: clip `grads` to `max_grad_norm`, then apply Adam with
    /// learning rate `lr` to `net.params` in place.
    pub fn step(
        &mut self,
        net: &mut NativeNet,
        grads: &mut [Vec<f64>],
        lr: f64,
        max_grad_norm: f64,
    ) {
        clip_global_norm(grads, max_grad_norm);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ti in 0..net.params.len() {
            let (m, v) = (&mut self.m[ti], &mut self.v[ti]);
            let p = &mut net.params[ti];
            let g = &grads[ti];
            for k in 0..p.len() {
                m[k] = self.beta1 * m[k] + (1.0 - self.beta1) * g[k];
                v[k] = self.beta2 * v[k] + (1.0 - self.beta2) * g[k] * g[k];
                let mhat = m[k] / bc1;
                let vhat = v[k] / bc2;
                p[k] -= lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::sampler;
    use crate::rng::Pcg32;

    fn hyper() -> PpoHyper {
        PpoHyper { clip_coef: 0.2, vf_coef: 0.5, ent_coef: 0.01, norm_adv: true }
    }

    /// A synthetic minibatch whose `logp_old` is the net's own log-prob
    /// plus noise, so ratios land on both sides of the clip band without
    /// sitting exactly on a kink.
    fn synth_minibatch(net: &NativeNet, bsz: usize, seed: u64) -> MinibatchF64 {
        let mut rng = Pcg32::new(seed, 77);
        let a = net.act_dim;
        let obs: Vec<f64> =
            (0..bsz * net.obs_dim).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let fwd = net.forward(&obs, bsz);
        let mut actions = Vec::new();
        let mut logp = Vec::new();
        for i in 0..bsz {
            if net.continuous {
                let mut lp = 0.0;
                for k in 0..a {
                    let ls = net.params[LOG_STD][k];
                    let act = fwd.dist[i * a + k] + rng.range(-1.0, 1.0) as f64;
                    let z = (act - fwd.dist[i * a + k]) * (-ls).exp();
                    lp += -0.5 * z * z - ls - 0.5 * LN_2PI;
                    actions.push(act);
                }
                logp.push(lp + rng.range(-0.3, 0.3) as f64);
            } else {
                let logits = &fwd.dist[i * a..(i + 1) * a];
                let act = rng.below(a as u32) as usize;
                let maxl = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let lse = maxl + logits.iter().map(|l| (l - maxl).exp()).sum::<f64>().ln();
                actions.push(act as f64);
                logp.push(logits[act] - lse + rng.range(-0.3, 0.3) as f64);
            }
        }
        let adv: Vec<f64> = (0..bsz).map(|_| rng.range(-2.0, 2.0) as f64).collect();
        let ret: Vec<f64> = (0..bsz).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        MinibatchF64 { obs, actions, logp, adv, ret }
    }

    /// Central finite differences against the analytic gradient, for a
    /// spread of indices in **every** tensor (trunk, policy head, value
    /// head, and log_std when present).
    fn finite_difference_check(net: &NativeNet, mb: &MinibatchF64) {
        let hp = hyper();
        let (_, grads) = net.loss_and_grad(mb, &hp, true);
        let grads = grads.unwrap();
        let eps = 1e-6;
        for ti in 0..net.params.len() {
            let len = net.params[ti].len();
            let stride = (len / 5).max(1);
            for k in (0..len).step_by(stride) {
                let mut plus = net.clone();
                plus.params[ti][k] += eps;
                let mut minus = net.clone();
                minus.params[ti][k] -= eps;
                let lp = plus.loss_and_grad(mb, &hp, false).0.loss;
                let lm = minus.loss_and_grad(mb, &hp, false).0.loss;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[ti][k];
                let tol = 1e-6 + 1e-5 * fd.abs().max(an.abs());
                assert!(
                    (fd - an).abs() <= tol,
                    "tensor {} ({}) index {k}: finite-diff {fd:.9} vs analytic {an:.9}",
                    ti,
                    net.meta[ti].name,
                );
            }
        }
    }

    /// Like [`synth_minibatch`], but the behaviour-policy log-prob
    /// offsets are pushed well away from the PPO clip kinks
    /// (|logratio| near 0 or 0.5; the boundary sits at ln(1.2) = 0.18),
    /// so f32-sized finite-difference steps and f32-vs-f64 comparisons
    /// never straddle a `max()` branch - the budgets those tests assert
    /// measure rounding, not branch flips.
    fn synth_minibatch_margin(net: &NativeNet, bsz: usize, seed: u64) -> MinibatchF64 {
        let mut rng = Pcg32::new(seed, 177);
        let a = net.act_dim;
        let obs: Vec<f64> =
            (0..bsz * net.obs_dim).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let fwd = net.forward(&obs, bsz);
        let mut actions = Vec::new();
        let mut logp = Vec::new();
        for i in 0..bsz {
            let noise = match i % 3 {
                0 => rng.range(-0.02, 0.02) as f64,
                1 => 0.5 + rng.range(-0.02, 0.02) as f64,
                _ => -0.5 + rng.range(-0.02, 0.02) as f64,
            };
            if net.continuous {
                let mut lp = 0.0;
                for k in 0..a {
                    let ls = net.params[LOG_STD][k];
                    let act = fwd.dist[i * a + k] + rng.range(-1.0, 1.0) as f64;
                    let z = (act - fwd.dist[i * a + k]) * (-ls).exp();
                    lp += -0.5 * z * z - ls - 0.5 * LN_2PI;
                    actions.push(act);
                }
                logp.push(lp + noise);
            } else {
                let logits = &fwd.dist[i * a..(i + 1) * a];
                let act = rng.below(a as u32) as usize;
                let maxl = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let lse = maxl + logits.iter().map(|l| (l - maxl).exp()).sum::<f64>().ln();
                actions.push(act as f64);
                logp.push(logits[act] - lse + noise);
            }
        }
        let adv: Vec<f64> = (0..bsz).map(|_| rng.range(-2.0, 2.0) as f64).collect();
        let ret: Vec<f64> = (0..bsz).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        MinibatchF64 { obs, actions, logp, adv, ret }
    }

    #[test]
    fn f32_path_agrees_with_f64_within_documented_budget() {
        // The documented f32-vs-f64 budget (also in lib.rs): loss and
        // entropy within 1e-4 relative, per-element gradients within
        // 1e-4 + 1e-2*|g|. Away from clip kinks (margin minibatch) the
        // two paths share every branch, so the residual is pure f32
        // rounding of the GEMMs - typically orders of magnitude below
        // this budget.
        for (continuous, seed) in [(false, 31u64), (true, 37)] {
            let net = NativeNet::new(5, 2, 16, continuous, seed).unwrap();
            let mb = synth_minibatch_margin(&net, 16, seed + 1);
            let hp = hyper();
            let (s64, g64) = net.loss_and_grad(&mb, &hp, true);
            let g64 = g64.unwrap();
            let p32 = net.params_f32();
            let obs32: Vec<f32> = mb.obs.iter().map(|&x| x as f32).collect();
            let (s32, g32) = net.loss_and_grad_f32(&p32, &obs32, &mb, &hp);
            assert!(
                (s32.loss - s64.loss).abs() <= 1e-4 * (1.0 + s64.loss.abs()),
                "continuous={continuous}: loss {} vs {}",
                s32.loss,
                s64.loss
            );
            assert!((s32.entropy - s64.entropy).abs() <= 1e-4 * (1.0 + s64.entropy.abs()));
            assert!((s32.v_loss - s64.v_loss).abs() <= 1e-4 * (1.0 + s64.v_loss.abs()));
            for ti in 0..g64.len() {
                for k in 0..g64[ti].len() {
                    let (a, b) = (g32[ti][k] as f64, g64[ti][k]);
                    assert!(
                        (a - b).abs() <= 1e-4 + 1e-2 * b.abs(),
                        "continuous={continuous} tensor {} [{k}]: {a} vs {b}",
                        net.meta[ti].name
                    );
                }
            }
        }
    }

    #[test]
    fn finite_difference_gradients_f32_path() {
        // The FD guard re-run under the f32 fast path: central
        // differences on the f32 compute weights vs the analytic f32
        // gradients. eps is a power of two (exact in f32); the loss is
        // accumulated in f64 from promoted activations, so FD noise is
        // f32 forward rounding (~1e-6 abs) - far below tol at this eps.
        // The margin minibatch keeps the step from crossing clip kinks.
        for (continuous, seed) in [(false, 41u64), (true, 43)] {
            let net = NativeNet::new(4, 2, 8, continuous, seed).unwrap();
            let mb = synth_minibatch_margin(&net, 10, seed + 2);
            let obs32: Vec<f32> = mb.obs.iter().map(|&x| x as f32).collect();
            let p32 = net.params_f32();
            let hp = hyper();
            let (_, grads) = net.loss_and_grad_f32(&p32, &obs32, &mb, &hp);
            let eps = 0.00390625f32; // 2^-8
            for ti in 0..p32.t.len() {
                let len = p32.t[ti].len();
                let stride = (len / 4).max(1);
                for k in (0..len).step_by(stride) {
                    let mut plus = p32.clone();
                    plus.t[ti][k] += eps;
                    net.rebuild_transposes_f32(&mut plus);
                    let mut minus = p32.clone();
                    minus.t[ti][k] -= eps;
                    net.rebuild_transposes_f32(&mut minus);
                    let lp = net.loss_and_grad_f32(&plus, &obs32, &mb, &hp).0.loss;
                    let lm = net.loss_and_grad_f32(&minus, &obs32, &mb, &hp).0.loss;
                    let fd = (lp - lm) / (2.0 * eps as f64);
                    let an = grads[ti][k] as f64;
                    let tol = 5e-4 + 3e-2 * fd.abs().max(an.abs());
                    assert!(
                        (fd - an).abs() <= tol,
                        "continuous={continuous} tensor {} ({}) index {k}: \
                         finite-diff {fd:.7} vs analytic {an:.7}",
                        ti,
                        net.meta[ti].name,
                    );
                }
            }
        }
    }

    #[test]
    fn f32_mirror_roundtrip_and_affine_bitwise() {
        let net = NativeNet::new(3, 2, 8, true, 7).unwrap();
        let mut p32 = net.params_f32();
        assert_eq!(p32.t.len(), net.params.len());
        assert_eq!(net.log_std_of(&p32).len(), 2);
        // transposes are exact permutations of the demoted tensors
        for k in 0..3 {
            for j in 0..8 {
                assert_eq!(p32.w1t[j * 3 + k].to_bits(), p32.t[W1][k * 8 + j].to_bits());
            }
        }
        for k in 0..8 {
            for j in 0..8 {
                assert_eq!(p32.w2t[j * 8 + k].to_bits(), p32.t[W2][k * 8 + j].to_bits());
            }
            for j in 0..2 {
                assert_eq!(p32.wpt[j * 8 + k].to_bits(), p32.t[WP][k * 2 + j].to_bits());
            }
        }
        // refresh reproduces a fresh demotion bitwise (transposes too)
        let fresh = net.params_f32();
        for v in p32.t.iter_mut().flatten() {
            *v = 99.0;
        }
        for v in p32.w1t.iter_mut().chain(&mut p32.w2t).chain(&mut p32.wpt) {
            *v = 99.0;
        }
        net.refresh_params_f32(&mut p32);
        for (a, b) in p32.t.iter().flatten().zip(fresh.t.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in p32.w1t.iter().chain(&p32.w2t).chain(&p32.wpt).zip(
            fresh.w1t.iter().chain(&fresh.w2t).chain(&fresh.wpt),
        ) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // affine_f32's lane pass preserves per-output accumulation
        // order: bitwise equal to the naive scalar f32 loop.
        let mut rng = Pcg32::new(9, 9);
        for (bsz, d_in, d_out) in [(3usize, 4usize, 64usize), (2, 7, 5), (1, 11, 1)] {
            let x: Vec<f32> = (0..bsz * d_in).map(|_| rng.range(-1.0, 1.0)).collect();
            let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..d_out).map(|_| rng.range(-1.0, 1.0)).collect();
            let mut got = vec![0.0f32; bsz * d_out];
            affine_f32(&x, &w, &b, &mut got, bsz, d_in, d_out);
            let mut want = vec![0.0f32; bsz * d_out];
            for i in 0..bsz {
                let orow = &mut want[i * d_out..(i + 1) * d_out];
                orow.copy_from_slice(&b);
                for k in 0..d_in {
                    let xv = x[i * d_in + k];
                    if xv == 0.0 {
                        continue;
                    }
                    for j in 0..d_out {
                        orow[j] += xv * w[k * d_out + j];
                    }
                }
            }
            for (a, bb) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), bb.to_bits(), "{bsz}x{d_in}x{d_out}");
            }
        }
    }

    #[test]
    fn finite_difference_gradients_discrete() {
        let net = NativeNet::new(5, 3, 8, false, 11).unwrap();
        let mb = synth_minibatch(&net, 12, 21);
        finite_difference_check(&net, &mb);
    }

    #[test]
    fn finite_difference_gradients_continuous() {
        let net = NativeNet::new(4, 2, 8, true, 13).unwrap();
        let mb = synth_minibatch(&net, 12, 23);
        finite_difference_check(&net, &mb);
    }

    #[test]
    fn entropy_matches_f32_reference_samplers() {
        // Cross-check the in-loss entropy against the f32 reference
        // helpers the rollout path uses.
        let net = NativeNet::new(4, 3, 8, false, 5).unwrap();
        let mb = synth_minibatch(&net, 6, 9);
        let (stats, _) = net.loss_and_grad(&mb, &hyper(), false);
        let fwd = net.forward(&mb.obs, 6);
        let mut ref_ent = 0.0f32;
        for i in 0..6 {
            let row: Vec<f32> = fwd.dist[i * 3..(i + 1) * 3].iter().map(|&x| x as f32).collect();
            ref_ent += sampler::categorical_entropy(&row);
        }
        assert!((stats.entropy - (ref_ent / 6.0) as f64).abs() < 1e-4);

        let netc = NativeNet::new(3, 2, 8, true, 6).unwrap();
        let mbc = synth_minibatch(&netc, 6, 10);
        let (statsc, _) = netc.loss_and_grad(&mbc, &hyper(), false);
        let ls: Vec<f32> = netc.log_std().iter().map(|&x| x as f32).collect();
        let want = sampler::gaussian_entropy(&ls);
        assert!((statsc.entropy - want as f64).abs() < 1e-4);
    }

    #[test]
    fn forward_is_deterministic_and_rowwise() {
        let net = NativeNet::new(4, 2, 16, false, 42).unwrap();
        let net2 = NativeNet::new(4, 2, 16, false, 42).unwrap();
        let obs: Vec<f64> = (0..8 * 4).map(|i| ((i % 4) as f64) * 0.1).collect();
        let (fa, fb) = (net.forward(&obs, 8), net2.forward(&obs, 8));
        assert_eq!(fa.dist, fb.dist, "same seed => same init => same forward");
        // identical rows => identical outputs
        for i in 1..8 {
            assert_eq!(fa.dist[0], fa.dist[i * 2]);
            assert_eq!(fa.value[0], fa.value[i]);
        }
        // different seed => different params
        let net3 = NativeNet::new(4, 2, 16, false, 43).unwrap();
        assert_ne!(net3.forward(&obs, 8).dist, fa.dist);
    }

    #[test]
    fn adam_step_moves_params_toward_lower_loss() {
        let mut net = NativeNet::new(4, 2, 8, false, 3).unwrap();
        let mb = synth_minibatch(&net, 16, 4);
        let hp = hyper();
        let mut opt = Adam::new(&net);
        let before = net.loss_and_grad(&mb, &hp, false).0.loss;
        for _ in 0..25 {
            let (_, g) = net.loss_and_grad(&mb, &hp, true);
            opt.step(&mut net, &mut g.unwrap(), 1e-2, 0.5);
        }
        let after = net.loss_and_grad(&mb, &hp, false).0.loss;
        assert!(after < before, "25 Adam steps must reduce the loss: {before} -> {after}");
        assert_eq!(opt.t, 25);
        assert!(opt.m.iter().flat_map(|m| m.iter()).any(|&x| x != 0.0));
    }

    #[test]
    fn clip_global_norm_bounds_and_preserves_direction() {
        let mut g = vec![vec![3.0, 4.0], vec![0.0]];
        let norm = clip_global_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-12);
        assert!((g[0][0] - 0.6).abs() < 1e-12);
        assert!((g[0][1] - 0.8).abs() < 1e-12);
        // under the bound: untouched
        let mut g2 = vec![vec![0.3]];
        let n2 = clip_global_norm(&mut g2, 1.0);
        assert!((n2 - 0.3).abs() < 1e-12);
        assert_eq!(g2[0][0], 0.3);
    }

    #[test]
    fn zero_dims_rejected() {
        assert!(NativeNet::new(0, 2, 8, false, 0).is_err());
        assert!(NativeNet::new(4, 0, 8, false, 0).is_err());
        assert!(NativeNet::new(4, 2, 0, false, 0).is_err());
    }

    #[test]
    fn store_roundtrip_preserves_shapes() {
        let net = NativeNet::new(6, 3, 8, true, 9).unwrap();
        let store = net.to_store();
        assert_eq!(store.numel(), net.numel());
        let back = NativeNet::from_store(6, 3, 8, true, &store);
        assert_eq!(back.params.len(), net.params.len());
        assert!(store.meta.iter().any(|m| m.name == "log_std"));
    }
}
