//! The compute-tier abstraction: one trait, two interchangeable
//! implementations.
//!
//! - [`PjrtBackend`] — the original path: AOT HLO-text artifacts
//!   (policy forward, PPO train step, GAE scan) compiled and executed
//!   through PJRT. Requires real `xla` bindings and `make artifacts`.
//! - [`NativeBackend`] — a pure-Rust MLP actor-critic with analytic
//!   PPO backprop, Adam, and the reference GAE
//!   ([`crate::agent::gae::gae_ref`]). Needs nothing beyond the crate,
//!   so `envpool train --backend native` works in every checkout —
//!   including ones where the vendored `xla` stub makes PJRT report
//!   unavailable.
//!
//! [`make_backend`] resolves [`BackendKind`]: `pjrt` and `native` are
//! explicit; `auto` (the default) tries PJRT and falls back to native
//! when [`crate::runtime::unavailable`] says the compute tier is absent,
//! or when the artifacts on disk were lowered for a different
//! `(task, num_envs)` than this run asks for — genuine PJRT errors
//! (corrupt manifest, compile/shape failures) still surface.

use super::native::{Adam, MinibatchF64, NativeNet, ParamsF32, PpoHyper};
use super::policy::PolicyOutput;
use super::trainer_exec::{GaeExec, Minibatch, TrainExec, TrainStats};
use super::{Manifest, Policy, Runtime};
use crate::agent::params::ParamStore;
use crate::config::{BackendKind, Precision, TrainConfig};
use crate::envs::spec::EnvSpec;
use crate::{Error, Result};

/// Hidden width of the native MLP (CleanRL's default).
pub const NATIVE_HIDDEN: usize = 64;

/// The shapes and schedule a backend trains with. For PJRT these come
/// from the artifact manifest (baked into the compiled graphs); for the
/// native backend they come straight from [`TrainConfig`].
#[derive(Debug, Clone)]
pub struct BackendSpec {
    pub num_envs: usize,
    pub num_steps: usize,
    pub obs_dim: usize,
    /// Discrete action count or continuous action dimension.
    pub act_dim: usize,
    pub continuous: bool,
    pub minibatch_size: usize,
    pub num_minibatches: usize,
    pub gamma: f32,
    pub lam: f32,
}

/// A compute backend: policy forward, PPO minibatch update, GAE.
pub trait ComputeBackend {
    /// `"pjrt"` or `"native"` (reported in the train summary).
    fn kind(&self) -> &'static str;

    /// Arithmetic the backend computes in, reported in the train
    /// summary: `"f32"` for the PJRT artifacts (XLA f32 graphs, the
    /// default impl) and for the native fast path; `"f64"` for the
    /// native reference path.
    fn precision(&self) -> &'static str {
        "f32"
    }

    /// Shapes/schedule this backend was built for.
    fn spec(&self) -> &BackendSpec;

    /// Total policy parameter count.
    fn param_count(&self) -> usize;

    /// Batched actor-critic forward over `[num_envs, obs_dim]` (or any
    /// whole multiple of `obs_dim`) observations.
    fn forward(&mut self, obs: &[f32]) -> Result<PolicyOutput>;

    /// One PPO minibatch update (mutates the optimizer + parameters).
    fn train_minibatch(&mut self, mb: &Minibatch<'_>, lr: f32) -> Result<TrainStats>;

    /// GAE over time-major `[T, N]` arrays; returns (advantages, returns).
    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        last_value: &[f32],
        dones: &[f32],
        truncs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)>;
}

/// Present artifacts dir, but nothing lowered for this `(task,
/// num_envs)` — for *this run* the compute tier is just as absent as a
/// missing dir, so `auto` may fall back (matches the message
/// `Manifest::for_task` emits).
fn missing_task_config(e: &Error) -> bool {
    matches!(e, Error::Artifact(m) if m.contains("no artifacts for task"))
}

/// Build the backend selected by `cfg.backend` (env metadata from
/// `env_spec`; see module docs for the `auto` fallback rule).
pub fn make_backend(cfg: &TrainConfig, env_spec: &EnvSpec) -> Result<Box<dyn ComputeBackend>> {
    match cfg.backend {
        BackendKind::Pjrt => PjrtBackend::make(cfg),
        BackendKind::Native => Ok(Box::new(NativeBackend::make(cfg, env_spec)?)),
        BackendKind::Auto => match PjrtBackend::make(cfg) {
            Ok(b) => Ok(b),
            Err(e) if super::unavailable(&e) || missing_task_config(&e) => {
                Ok(Box::new(NativeBackend::make(cfg, env_spec)?))
            }
            Err(e) => Err(e),
        },
    }
}

// ---------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------

/// The artifact/PJRT compute backend (see module docs).
pub struct PjrtBackend {
    rt: Runtime,
    policy: Policy,
    trainer: TrainExec,
    gae_exec: GaeExec,
    params: ParamStore,
    adam_m: ParamStore,
    adam_v: ParamStore,
    adam_t: f32,
    spec: BackendSpec,
}

impl PjrtBackend {
    /// Load manifest + runtime + the three executables for
    /// `(cfg.env_id, cfg.num_envs)`.
    pub fn make(cfg: &TrainConfig) -> Result<Box<dyn ComputeBackend>> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let art = manifest.for_task(&cfg.env_id, cfg.num_envs)?;
        let rt = Runtime::cpu()?;
        let policy = Policy::load(&rt, art)?;
        let trainer = TrainExec::load(&rt, art)?;
        let gae_exec = GaeExec::load(&rt, art)?;
        let params = ParamStore::load(&manifest, art)?;
        let adam_m = params.zeros_like();
        let adam_v = params.zeros_like();
        let spec = BackendSpec {
            num_envs: art.num_envs,
            num_steps: art.num_steps,
            obs_dim: art.obs_dim,
            act_dim: art.act_dim,
            continuous: art.continuous,
            minibatch_size: art.minibatch_size,
            num_minibatches: art.num_minibatches,
            gamma: art.gamma,
            lam: art.lam,
        };
        Ok(Box::new(PjrtBackend {
            rt,
            policy,
            trainer,
            gae_exec,
            params,
            adam_m,
            adam_v,
            adam_t: 0.0,
            spec,
        }))
    }
}

impl ComputeBackend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn param_count(&self) -> usize {
        self.params.numel()
    }

    fn forward(&mut self, obs: &[f32]) -> Result<PolicyOutput> {
        self.policy.forward(&self.rt, &self.params, obs)
    }

    fn train_minibatch(&mut self, mb: &Minibatch<'_>, lr: f32) -> Result<TrainStats> {
        self.trainer.step(
            &self.rt,
            &mut self.params,
            &mut self.adam_m,
            &mut self.adam_v,
            &mut self.adam_t,
            mb,
            lr,
        )
    }

    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        last_value: &[f32],
        dones: &[f32],
        truncs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.gae_exec.compute(&self.rt, rewards, values, last_value, dones, truncs)
    }
}

// ---------------------------------------------------------------------
// Native
// ---------------------------------------------------------------------

/// The pure-Rust compute backend (see module docs and
/// [`crate::runtime::native`]).
pub struct NativeBackend {
    net: NativeNet,
    opt: Adam,
    hp: PpoHyper,
    max_grad_norm: f64,
    spec: BackendSpec,
    /// Compute precision (`TrainConfig::precision`): `F64` runs the
    /// scalar reference loops, `F32` the SIMD GEMV fast path with f64
    /// master weights (see [`crate::runtime::native`]).
    precision: Precision,
    /// f32 mirror of the master weights — the fast path's compute
    /// weights, re-demoted after every optimizer step. Only read (and
    /// only refreshed) under `Precision::F32`; precision is fixed at
    /// construction.
    params32: ParamsF32,
    /// f64 scratch for promoting f32-path gradients into Adam.
    g64: Vec<Vec<f64>>,
    /// Scratch for f32⇄f64 forward conversion (reused across calls).
    obs64: Vec<f64>,
    /// Scratch for f32⇄f64 minibatch conversion (reused across calls).
    mb64: MinibatchF64,
}

impl NativeBackend {
    /// Build from the train config + env spec alone — no artifacts, no
    /// PJRT, deterministic under `cfg.seed`.
    pub fn make(cfg: &TrainConfig, env_spec: &EnvSpec) -> Result<NativeBackend> {
        let obs_dim = env_spec.obs_dim();
        let act_dim = env_spec.action_space.n();
        let continuous = !env_spec.action_space.is_discrete();
        let rollout = cfg.num_envs * cfg.num_steps;
        if cfg.num_minibatches == 0 || rollout % cfg.num_minibatches != 0 {
            return Err(Error::Config(format!(
                "native backend: rollout size {rollout} not divisible by num_minibatches {}",
                cfg.num_minibatches
            )));
        }
        let net = NativeNet::new(obs_dim, act_dim, NATIVE_HIDDEN, continuous, cfg.seed)?;
        let opt = Adam::new(&net);
        let hp = PpoHyper {
            clip_coef: cfg.clip_coef as f64,
            vf_coef: cfg.vf_coef as f64,
            ent_coef: cfg.ent_coef as f64,
            norm_adv: true,
        };
        let spec = BackendSpec {
            num_envs: cfg.num_envs,
            num_steps: cfg.num_steps,
            obs_dim,
            act_dim,
            continuous,
            minibatch_size: rollout / cfg.num_minibatches,
            num_minibatches: cfg.num_minibatches,
            gamma: cfg.gamma,
            lam: cfg.gae_lambda,
        };
        let params32 = net.params_f32();
        let g64 = net.zeros_like();
        Ok(NativeBackend {
            net,
            opt,
            hp,
            max_grad_norm: cfg.max_grad_norm as f64,
            spec,
            precision: cfg.precision,
            params32,
            g64,
            obs64: Vec::new(),
            mb64: MinibatchF64 {
                obs: Vec::new(),
                actions: Vec::new(),
                logp: Vec::new(),
                adv: Vec::new(),
                ret: Vec::new(),
            },
        })
    }

    /// The current parameters as an f32 [`ParamStore`] (reporting /
    /// checkpointing; same naming as the artifact path).
    pub fn params(&self) -> ParamStore {
        self.net.to_store()
    }
}

impl ComputeBackend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn precision(&self) -> &'static str {
        match self.precision {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn param_count(&self) -> usize {
        self.net.numel()
    }

    fn forward(&mut self, obs: &[f32]) -> Result<PolicyOutput> {
        let d = self.spec.obs_dim;
        if obs.is_empty() || obs.len() % d != 0 {
            return Err(Error::Config(format!(
                "native forward: obs length {} is not a multiple of obs_dim {d}",
                obs.len()
            )));
        }
        let bsz = obs.len() / d;
        if self.precision == Precision::F32 {
            // Fast path: f32 SIMD forward on the mirror weights — no
            // f32⇄f64 conversion anywhere on the inference hot path.
            let fwd = self.net.forward_f32(&self.params32, obs, bsz);
            let log_std = if self.spec.continuous {
                let ls = self.net.log_std_of(&self.params32);
                let mut out = Vec::with_capacity(bsz * ls.len());
                for _ in 0..bsz {
                    out.extend_from_slice(ls);
                }
                out
            } else {
                Vec::new()
            };
            return Ok(PolicyOutput { dist: fwd.dist, log_std, value: fwd.value });
        }
        self.obs64.clear();
        self.obs64.extend(obs.iter().map(|&x| x as f64));
        let fwd = self.net.forward(&self.obs64, bsz);
        let log_std = if self.spec.continuous {
            // state-independent parameter, broadcast to [B, A]
            let ls = self.net.log_std();
            let mut out = Vec::with_capacity(bsz * ls.len());
            for _ in 0..bsz {
                out.extend(ls.iter().map(|&x| x as f32));
            }
            out
        } else {
            Vec::new()
        };
        Ok(PolicyOutput {
            dist: fwd.dist.iter().map(|&x| x as f32).collect(),
            log_std,
            value: fwd.value.iter().map(|&x| x as f32).collect(),
        })
    }

    fn train_minibatch(&mut self, mb: &Minibatch<'_>, lr: f32) -> Result<TrainStats> {
        let b = mb.logp.len();
        debug_assert_eq!(mb.obs.len(), b * self.spec.obs_dim);
        fn refill(dst: &mut Vec<f64>, src: &[f32]) {
            dst.clear();
            dst.extend(src.iter().map(|&x| x as f64));
        }
        refill(&mut self.mb64.actions, mb.actions);
        refill(&mut self.mb64.logp, mb.logp);
        refill(&mut self.mb64.adv, mb.adv);
        refill(&mut self.mb64.ret, mb.ret);
        let stats = if self.precision == Precision::F32 {
            // Fast path: f32 SIMD forward+backward on the mirror
            // weights (obs stays f32 — the head pass only needs the
            // f64 action/logp/adv/ret views refilled above), then
            // promote the gradients and run Adam on the f64 master
            // weights, then re-demote the mirror.
            let (stats, g32) =
                self.net.loss_and_grad_f32(&self.params32, mb.obs, &self.mb64, &self.hp);
            for (dst, src) in self.g64.iter_mut().zip(&g32) {
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = v as f64;
                }
            }
            self.opt.step(&mut self.net, &mut self.g64, lr as f64, self.max_grad_norm);
            self.net.refresh_params_f32(&mut self.params32);
            stats
        } else {
            refill(&mut self.mb64.obs, mb.obs);
            let (stats, grads) = self.net.loss_and_grad(&self.mb64, &self.hp, true);
            let mut grads = grads.expect("want_grad = true always yields gradients");
            self.opt.step(&mut self.net, &mut grads, lr as f64, self.max_grad_norm);
            // No mirror refresh here: under F64 the mirror is never
            // read, and precision cannot change after construction.
            stats
        };
        Ok(TrainStats {
            loss: stats.loss as f32,
            pg_loss: stats.pg_loss as f32,
            v_loss: stats.v_loss as f32,
            entropy: stats.entropy as f32,
            approx_kl: stats.approx_kl as f32,
        })
    }

    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        last_value: &[f32],
        dones: &[f32],
        truncs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (t, n) = (self.spec.num_steps, self.spec.num_envs);
        Ok(crate::agent::gae::gae_ref(
            rewards,
            values,
            last_value,
            dones,
            truncs,
            t,
            n,
            self.spec.gamma,
            self.spec.lam,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry;

    fn native_cfg(env: &str) -> TrainConfig {
        TrainConfig {
            env_id: env.into(),
            backend: BackendKind::Native,
            num_envs: 4,
            batch_size: 4,
            num_steps: 16,
            num_minibatches: 4,
            ..TrainConfig::default()
        }
    }

    fn mk_native(env: &str) -> NativeBackend {
        let cfg = native_cfg(env);
        let spec = registry::spec_for(env).unwrap();
        NativeBackend::make(&cfg, &spec).unwrap()
    }

    #[test]
    fn native_backend_shapes_discrete_and_continuous() {
        let mut b = mk_native("CartPole-v1");
        assert_eq!(b.kind(), "native");
        assert_eq!(b.spec().act_dim, 2);
        assert!(!b.spec().continuous);
        assert_eq!(b.spec().minibatch_size, 16);
        let out = b.forward(&[0.05; 4 * 4]).unwrap();
        assert_eq!(out.dist.len(), 4 * 2);
        assert_eq!(out.value.len(), 4);
        assert!(out.log_std.is_empty());
        assert!(b.param_count() > 4 * 64);
        assert_eq!(b.params().numel(), b.param_count());

        let mut c = mk_native("Pendulum-v1");
        assert!(c.spec().continuous);
        let out = c.forward(&[0.1; 4 * 3]).unwrap();
        assert_eq!(out.dist.len(), 4);
        assert_eq!(out.log_std.len(), 4);
        assert!(out.log_std.iter().all(|&x| x == 0.0), "log_std init 0");
    }

    #[test]
    fn native_train_minibatch_updates_parameters() {
        let mut b = mk_native("CartPole-v1");
        let before = b.params().values.clone();
        let bsz = b.spec().minibatch_size;
        let mut rng = crate::rng::Pcg32::new(1, 2);
        let obs: Vec<f32> = (0..bsz * 4).map(|_| rng.range(-0.1, 0.1)).collect();
        let actions: Vec<f32> = (0..bsz).map(|_| rng.below(2) as f32).collect();
        let logp = vec![-0.6931f32; bsz];
        let adv: Vec<f32> = (0..bsz).map(|_| rng.range(-1.0, 1.0)).collect();
        let ret: Vec<f32> = (0..bsz).map(|_| rng.range(-1.0, 1.0)).collect();
        let mb = Minibatch { obs: &obs, actions: &actions, logp: &logp, adv: &adv, ret: &ret };
        let stats = b.train_minibatch(&mb, 1e-3).unwrap();
        assert!(stats.loss.is_finite());
        assert!(stats.entropy > 0.0, "fresh policy must have entropy");
        assert!(b.params().values != before, "parameters must move");
    }

    #[test]
    fn native_gae_matches_reference() {
        let mut b = mk_native("CartPole-v1");
        let (t, n) = (b.spec().num_steps, b.spec().num_envs);
        let mut rng = crate::rng::Pcg32::new(5, 5);
        let rewards: Vec<f32> = (0..t * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let values: Vec<f32> = (0..t * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let last: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let dones: Vec<f32> = (0..t * n).map(|_| (rng.uniform() < 0.05) as u8 as f32).collect();
        let truncs = vec![0.0; t * n];
        let (adv, ret) = b.gae(&rewards, &values, &last, &dones, &truncs).unwrap();
        let (adv2, ret2) = crate::agent::gae::gae_ref(
            &rewards, &values, &last, &dones, &truncs, t, n, 0.99, 0.95,
        );
        assert_eq!(adv, adv2);
        assert_eq!(ret, ret2);
    }

    #[test]
    fn f32_precision_trains_deterministically_and_tracks_f64() {
        use crate::rng::Pcg32;
        let spec = registry::spec_for("CartPole-v1").unwrap();
        let mk = |precision: Precision| {
            let mut cfg = native_cfg("CartPole-v1");
            cfg.precision = precision;
            NativeBackend::make(&cfg, &spec).unwrap()
        };
        let mut a = mk(Precision::F32);
        let mut b = mk(Precision::F32);
        let mut c = mk(Precision::F64);
        assert_eq!(ComputeBackend::precision(&a), "f32");
        assert_eq!(ComputeBackend::precision(&c), "f64");

        let mut rng = Pcg32::new(5, 2);
        let bsz = 16;
        let obs: Vec<f32> = (0..bsz * 4).map(|_| rng.range(-0.1, 0.1)).collect();
        let actions: Vec<f32> = (0..bsz).map(|_| rng.below(2) as f32).collect();
        let logp = vec![-0.6931f32; bsz];
        let adv: Vec<f32> = (0..bsz).map(|_| rng.range(-1.0, 1.0)).collect();
        let ret: Vec<f32> = (0..bsz).map(|_| rng.range(-1.0, 1.0)).collect();

        // Same init: the f32 fast-path forward tracks the f64 forward
        // within forward-rounding tolerance.
        let fa = a.forward(&obs).unwrap();
        let fc = c.forward(&obs).unwrap();
        for (x, y) in fa.dist.iter().zip(&fc.dist) {
            assert!((x - y).abs() <= 1e-4, "dist {x} vs {y}");
        }
        for (x, y) in fa.value.iter().zip(&fc.value) {
            assert!((x - y).abs() <= 1e-4, "value {x} vs {y}");
        }

        let mb = Minibatch { obs: &obs, actions: &actions, logp: &logp, adv: &adv, ret: &ret };
        let sa = a.train_minibatch(&mb, 1e-3).unwrap();
        let sb = b.train_minibatch(&mb, 1e-3).unwrap();
        let sc = c.train_minibatch(&mb, 1e-3).unwrap();

        // Exact rerun determinism of the fast path: identical stats and
        // bitwise-identical master weights across the two f32 runs.
        assert_eq!(sa.loss.to_bits(), sb.loss.to_bits());
        for (va, vb) in
            a.params().values.iter().flatten().zip(b.params().values.iter().flatten())
        {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
        // Documented budget on the identical minibatch: stats within
        // 1e-4 relative of the f64 reference, master weights within
        // 2·lr after one Adam step (Adam's sign-normalized update
        // bounds per-element drift to ~lr; 2× covers a sign flip of a
        // near-zero gradient).
        assert!(
            (sa.loss - sc.loss).abs() <= 1e-4 * (1.0 + sc.loss.abs()),
            "loss {} vs {}",
            sa.loss,
            sc.loss
        );
        assert!((sa.entropy - sc.entropy).abs() <= 1e-3);
        for (va, vc) in
            a.params().values.iter().flatten().zip(c.params().values.iter().flatten())
        {
            assert!((va - vc).abs() <= 2e-3, "param {va} vs {vc}");
        }
    }

    #[test]
    fn auto_falls_back_to_native_when_pjrt_unavailable() {
        // With the vendored stub / no artifacts, `auto` must resolve to
        // the native backend instead of erroring.
        let mut cfg = native_cfg("CartPole-v1");
        cfg.backend = BackendKind::Auto;
        cfg.artifacts_dir = "definitely-not-an-artifacts-dir".into();
        let spec = registry::spec_for("CartPole-v1").unwrap();
        match make_backend(&cfg, &spec) {
            Ok(b) => assert_eq!(b.kind(), "native"),
            Err(e) => {
                // Real bindings + real artifacts present: pjrt is fine too,
                // but this artifacts_dir cannot exist.
                panic!("auto must fall back to native, got error: {e}");
            }
        }
    }

    #[test]
    fn auto_falls_back_when_artifacts_lack_this_task_config() {
        // A real artifacts dir that was lowered for num_envs = 8 only:
        // `auto` at num_envs = 16 must fall back to native (deterministic
        // in both stub and real-bindings checkouts — `for_task` fails
        // before any PJRT call), while `pjrt` must surface the error.
        let dir = crate::runtime::artifact::testsupport::synth_artifacts_dir();
        let mut cfg = native_cfg("CartPole-v1");
        cfg.backend = BackendKind::Auto;
        cfg.num_envs = 16;
        cfg.batch_size = 16;
        cfg.artifacts_dir = dir.to_str().unwrap().to_string();
        let spec = registry::spec_for("CartPole-v1").unwrap();
        let b = make_backend(&cfg, &spec).unwrap();
        assert_eq!(b.kind(), "native");
        assert_eq!(b.spec().num_envs, 16);
        cfg.backend = BackendKind::Pjrt;
        assert!(matches!(make_backend(&cfg, &spec), Err(Error::Artifact(_))));
    }

    #[test]
    fn explicit_pjrt_does_not_fall_back() {
        let mut cfg = native_cfg("CartPole-v1");
        cfg.backend = BackendKind::Pjrt;
        cfg.artifacts_dir = "definitely-not-an-artifacts-dir".into();
        let spec = registry::spec_for("CartPole-v1").unwrap();
        assert!(
            make_backend(&cfg, &spec).is_err(),
            "--backend pjrt must surface the missing compute tier, not fall back"
        );
    }

    #[test]
    fn bad_minibatch_split_rejected() {
        let mut cfg = native_cfg("CartPole-v1");
        cfg.num_minibatches = 7; // 4*16 = 64 rows, not divisible
        let spec = registry::spec_for("CartPole-v1").unwrap();
        assert!(matches!(
            NativeBackend::make(&cfg, &spec),
            Err(Error::Config(_))
        ));
    }
}
