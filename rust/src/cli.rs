//! Minimal command-line argument parser (the vendored crate set has no
//! `clap`). Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments; typed getters with defaults.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process's own argv, skipping program name (and an optional
    /// expected subcommand which is returned separately by the caller).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is the boolean flag present? `--flag` or `--flag=true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map_or(false, |v| v == "true" || v == "1")
    }

    /// String option with default.
    pub fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.options.get(name).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Optional string option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default; panics with a clear message on bad parse.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        self.parse_opt(name).unwrap_or(default)
    }

    /// Typed optional option (`None` when absent); panics with a clear
    /// message on a bad parse, matching [`Self::parse_or`].
    pub fn parse_opt<T: std::str::FromStr>(&self, name: &str) -> Option<T>
    where
        T::Err: std::fmt::Display,
    {
        self.options.get(name).map(|v| match v.parse() {
            Ok(x) => x,
            Err(e) => panic!("--{name}={v}: {e}"),
        })
    }

    /// Comma-separated list of a parseable type.
    pub fn list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap_or_else(|e| panic!("--{name} item {s}: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("bench --env Pong-v5 --num-envs 8 run");
        assert_eq!(a.positional, vec!["bench", "run"]);
        assert_eq!(a.get("env", ""), "Pong-v5");
        assert_eq!(a.parse_or::<usize>("num-envs", 0), 8);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("--mode=async --verbose --steps=100");
        assert_eq!(a.get("mode", ""), "async");
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or::<u64>("steps", 0), 100);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get("missing", "d"), "d");
        assert_eq!(a.parse_or::<f32>("missing", 1.5), 1.5);
        assert!(!a.flag("missing"));
    }

    #[test]
    fn parse_opt_is_none_when_absent() {
        let a = parse("--target-return 475.0");
        assert_eq!(a.parse_opt::<f32>("target-return"), Some(475.0));
        assert_eq!(a.parse_opt::<f32>("missing"), None);
    }

    #[test]
    fn list_option() {
        let a = parse("--n 1,2,8");
        assert_eq!(a.list::<usize>("n", &[]), vec![1, 2, 8]);
        assert_eq!(a.list::<usize>("m", &[4]), vec![4]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b", ""), "v");
    }
}
