//! RL environment substrates.
//!
//! The paper evaluates on Atari, MuJoCo, dm_control and classic-control
//! environments. None of those C/C++ engines are available here, so each
//! family is rebuilt from scratch with the same observation/action/reward
//! interface and — importantly for the benchmarks — the same *cost
//! profile* (see DESIGN.md §2 for the substitution argument):
//!
//! - [`classic`] — CartPole, MountainCar, Pendulum, Acrobot with the
//!   textbook dynamics (exactly the Gym equations).
//! - [`atari`] — an arcade simulator (Pong, Breakout) that renders real
//!   grayscale frames and applies the standard DQN preprocessing stack
//!   (frameskip 4, 2-frame max-pool, resize to 84×84, 4-frame stack).
//! - [`mujoco`] — a planar articulated rigid-body physics engine
//!   (sequential-impulse solver) with Hopper / HalfCheetah / Ant-like
//!   models, 5 physics substeps per env step as in Gym MuJoCo.
//! - [`dmc`] — dm_control-style tasks (cheetah run) over the same engine,
//!   exposed through a dm_env-like `TimeStep`.
//! - [`wrappers`] — time limit, reward clipping, episodic life,
//!   observation normalization — each with a batch-wise `VecWrapper`
//!   surface ([`wrappers::vec`]) and a one-lane scalar adapter over the
//!   same cores.
//!
//! All environments implement [`Env`] and are constructed by name through
//! [`registry::make_env`], mirroring `envpool.make(task_id, ...)`.
//! Batched execution is first-class: every task also constructs through
//! [`registry::make_vec_env`] as a [`VecEnv`] kernel, and
//! [`registry::make_env_wrapped`] / [`registry::make_vec_env_wrapped`]
//! compose the standard wrapper stack identically on both surfaces.

pub mod spec;
pub mod env;
pub mod classic;
pub mod atari;
pub mod mujoco;
pub mod dmc;
pub mod vector;
pub mod wrappers;
pub mod registry;

pub use env::{Env, Step};
pub use registry::{
    make_env, make_env_wrapped, make_vec_env, make_vec_env_wrapped, spec_for, WrapConfig,
};
pub use spec::{ActionSpace, EnvSpec};
pub use vector::{ObsArena, SliceArena, VecEnv};
