//! Pendulum batch kernel: a [`LaneDynamics`] descriptor over the shared
//! SoA driver ([`super::SoaKernel`]). Math and RNG streams are shared
//! with [`crate::envs::classic::pendulum`]; bitwise identical to the
//! scalar env at every lane width.

use super::{LaneDynamics, SoaKernel, MAX_PARAMS};
use crate::envs::classic::pendulum;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, Mask};

/// Pendulum's dynamics/reward rules for the shared driver. State lanes
/// are `[theta, theta_dot]`; the env never terminates (done is always
/// false, episodes truncate at `MAX_STEPS`). Overridable physics
/// (scenario pools): `gravity`, `mass`, `length`.
pub struct PendulumDyn;

impl LaneDynamics<2> for PendulumDyn {
    fn spec(&self) -> EnvSpec {
        pendulum::spec()
    }

    fn rng_for(&self, seed: u64, env_id: u64) -> Pcg32 {
        pendulum::rng(seed, env_id)
    }

    fn max_steps(&self) -> usize {
        pendulum::MAX_STEPS
    }

    fn reset_state(&self, rng: &mut Pcg32) -> [f32; 2] {
        let (theta, theta_dot) = pendulum::reset_state(rng);
        [theta, theta_dot]
    }

    fn param_names(&self) -> &'static [&'static str] {
        &["gravity", "mass", "length"]
    }

    fn default_params(&self) -> [f32; MAX_PARAMS] {
        [pendulum::G, pendulum::M, pendulum::L, 0.0]
    }

    fn step1(
        &self,
        s: [f32; 2],
        actions: &[f32],
        lane: usize,
        p: &[f32; MAX_PARAMS],
    ) -> ([f32; 2], bool, f32) {
        let (theta, theta_dot, cost) =
            pendulum::dynamics_p(s[0], s[1], actions[lane], p[0], p[1], p[2]);
        ([theta, theta_dot], false, -cost)
    }

    fn input(&self, actions: &[f32], lane: usize) -> f32 {
        actions[lane]
    }

    fn step_lanes<const W: usize>(
        &self,
        s: [F32s<W>; 2],
        u: F32s<W>,
        p: &[F32s<W>; MAX_PARAMS],
    ) -> ([F32s<W>; 2], Mask<W>, F32s<W>) {
        let (theta, theta_dot, cost) = pendulum::dynamics_lanes_p(s[0], s[1], u, p[0], p[1], p[2]);
        ([theta, theta_dot], Mask([false; W]), -cost)
    }

    fn write_obs(&self, s: &[f32; 2], obs: &mut [f32]) {
        pendulum::write_obs(s[0], s[1], obs);
    }
}

/// SoA batch of Pendulum environments.
pub type PendulumVec = SoaKernel<2, PendulumDyn>;

impl SoaKernel<2, PendulumDyn> {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        SoaKernel::with_dynamics(PendulumDyn, seed, first_env_id, count)
    }
}
