//! Struct-of-arrays Pendulum batch kernel (math and RNG streams shared
//! with [`crate::envs::classic::pendulum`]).

use super::{ObsArena, VecEnv};
use crate::envs::classic::pendulum;
use crate::envs::env::Step;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;

/// SoA batch of Pendulum environments.
pub struct PendulumVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    steps: Vec<u32>,
}

impl PendulumVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        PendulumVec {
            spec: pendulum::spec(),
            rng: (0..count).map(|l| pendulum::rng(seed, first_env_id + l as u64)).collect(),
            theta: vec![0.0; count],
            theta_dot: vec![0.0; count],
            steps: vec![0; count],
        }
    }

    #[inline]
    fn write_obs(&self, lane: usize, obs: &mut [f32]) {
        obs[0] = self.theta[lane].cos();
        obs[1] = self.theta[lane].sin();
        obs[2] = self.theta_dot[lane];
    }
}

impl VecEnv for PendulumVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let (theta, theta_dot) = pendulum::reset_state(&mut self.rng[lane]);
        self.theta[lane] = theta;
        self.theta_dot[lane] = theta_dot;
        self.steps[lane] = 0;
        self.write_obs(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let (theta, theta_dot, cost) =
                pendulum::dynamics(self.theta[lane], self.theta_dot[lane], actions[lane]);
            self.theta[lane] = theta;
            self.theta_dot[lane] = theta_dot;
            self.steps[lane] += 1;
            self.write_obs(lane, arena.row(lane));
            out[lane] = Step {
                reward: -cost,
                done: false,
                truncated: self.steps[lane] as usize >= pendulum::MAX_STEPS,
            };
        }
    }
}
