//! Struct-of-arrays Pendulum batch kernel (math and RNG streams shared
//! with [`crate::envs::classic::pendulum`]; the SIMD lane pass applies
//! `dynamics_lanes`, bitwise identical to the scalar reference at every
//! lane width).

use super::{ObsArena, VecEnv};
use crate::envs::classic::pendulum;
use crate::envs::env::Step;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, LanePass};

/// SoA batch of Pendulum environments.
pub struct PendulumVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    steps: Vec<u32>,
    /// Resolved SIMD lane width (1 = scalar reference loop).
    width: usize,
}

impl PendulumVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        PendulumVec {
            spec: pendulum::spec(),
            rng: (0..count).map(|l| pendulum::rng(seed, first_env_id + l as u64)).collect(),
            theta: vec![0.0; count],
            theta_dot: vec![0.0; count],
            steps: vec![0; count],
            // Scalar reference until configured: the wired paths (pool,
            // executors) always call `set_lane_pass`, which is also the
            // single place the `Auto` width (env override + feature
            // detection) resolves — keeping construction infallible.
            width: LanePass::Scalar.width(),
        }
    }

    /// Finish one stepped lane: bookkeeping, flags, observation row.
    #[inline]
    fn finish_lane(&mut self, lane: usize, cost: f32, arena: &mut dyn ObsArena, out: &mut [Step]) {
        self.steps[lane] += 1;
        pendulum::write_obs(self.theta[lane], self.theta_dot[lane], arena.row(lane));
        out[lane] = Step {
            reward: -cost,
            done: false,
            truncated: self.steps[lane] as usize >= pendulum::MAX_STEPS,
        };
    }

    /// The scalar reference loop (lane width 1).
    fn step_scalar(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        for lane in 0..self.num_envs() {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let (theta, theta_dot, cost) =
                pendulum::dynamics(self.theta[lane], self.theta_dot[lane], actions[lane]);
            self.theta[lane] = theta;
            self.theta_dot[lane] = theta_dot;
            self.finish_lane(lane, cost, arena, out);
        }
    }

    /// The SIMD lane pass (masked tail + masked resets, same structure
    /// as the CartPole kernel — see the module docs in [`super`]).
    fn step_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            for lane in g..g + n {
                if reset_mask[lane] != 0 {
                    self.reset_lane(lane, arena.row(lane));
                    out[lane] = Step::default();
                }
            }
            let theta = F32s::<W>::load_or(&self.theta[g..g + n], 0.0);
            let theta_dot = F32s::<W>::load_or(&self.theta_dot[g..g + n], 0.0);
            let action = F32s::<W>::load_or(&actions[g..g + n], 0.0);
            let (nt, ntd, cost) = pendulum::dynamics_lanes(theta, theta_dot, action);
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                self.theta[lane] = nt.0[i];
                self.theta_dot[lane] = ntd.0[i];
                self.finish_lane(lane, cost.0[i], arena, out);
            }
            g += W;
        }
    }
}

impl VecEnv for PendulumVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let (theta, theta_dot) = pendulum::reset_state(&mut self.rng[lane]);
        self.theta[lane] = theta;
        self.theta_dot[lane] = theta_dot;
        self.steps[lane] = 0;
        pendulum::write_obs(theta, theta_dot, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        match self.width {
            8 => self.step_lanes::<8>(actions, reset_mask, arena, out),
            4 => self.step_lanes::<4>(actions, reset_mask, arena, out),
            _ => self.step_scalar(actions, reset_mask, arena, out),
        }
    }
}
