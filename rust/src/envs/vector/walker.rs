//! Struct-of-arrays MuJoCo-walker batch kernel ([`WalkerVec`]) and the
//! dm_control shaping over it ([`CheetahRunVec`]).
//!
//! # Layout
//!
//! Task-level state lives in SoA *qpos/qvel lanes*: for each body field
//! (`pos_x`, `pos_y`, `angle`, `vel_x`, `vel_y`, `omega`) one flat array
//! indexed `[lane * num_bodies + body]`. Everything the task layer does
//! — reward, healthy checks, truncation, observation extraction — runs
//! as batch passes over these contiguous lanes, using static per-joint
//! metadata captured once from the prototype model (all lanes share one
//! articulation topology).
//!
//! # Physics and parity
//!
//! The constraint solver itself steps one lane at a time through the
//! *scalar* [`World::step`](crate::envs::mujoco::World::step) — each
//! lane keeps its own `World` because joint warm-start impulses and
//! contact caches are per-trajectory state (sharing them across lanes
//! would couple trajectories and break chunking invariance). After each
//! lane's `frame_skip` substeps the body state is scattered back into
//! the SoA lanes. Reusing the scalar solver makes the kernel
//! **bitwise identical** to [`WalkerEnv`](crate::envs::mujoco::WalkerEnv)
//! — the documented parity tolerance is exact equality (0 ulp), pinned
//! by `tests/vector_parity.rs`; a future SIMD solver pass may relax the
//! contract to a documented ≤1e-5 relative tolerance, at which point
//! that test's assertion is the place to loosen.
//!
//! The throughput win for walkers is therefore the chunked-dispatch
//! amortization plus the batch task passes — the solver cost dominates
//! and is unchanged, which is why `benches/table2_single_env` gates
//! vectorized ≥ scalar (not a multiple) on this family.

use super::{ObsArena, VecEnv};
use crate::envs::dmc::cheetah_run::{cheetah_spec, shape_step};
use crate::simd::{F32s, LanePass, Mask};
use crate::envs::env::Step;
use crate::envs::mujoco::models::Model;
use crate::envs::mujoco::walker::{self, Task};
use crate::envs::mujoco::{DT, FRAME_SKIP};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;

/// SoA batch of walker environments (Hopper / HalfCheetah / Ant).
pub struct WalkerVec {
    spec: EnvSpec,
    /// Prototype model: reset template + task constants + topology.
    proto: Model,
    /// Actuated joint indices (action layout), shared by all lanes.
    actuated: Vec<usize>,
    /// Per actuated joint: `(body_a, body_b, ref_angle)` — the static
    /// metadata that lets observation extraction run on SoA lanes only.
    jmeta: Vec<(usize, usize, f32)>,
    /// Bodies per lane.
    nb: usize,
    rng: Vec<Pcg32>,
    steps: Vec<u32>,
    /// Per-lane solver state (bodies + joint/contact warm starts).
    models: Vec<Model>,
    // SoA qpos lanes, indexed [lane * nb + body].
    pos_x: Vec<f32>,
    pos_y: Vec<f32>,
    angle: Vec<f32>,
    // SoA qvel lanes.
    vel_x: Vec<f32>,
    vel_y: Vec<f32>,
    omega: Vec<f32>,
    /// Torso x before the current batch step (forward-reward scratch).
    x_before: Vec<f32>,
    /// Resolved SIMD lane width for the batch task pass (1 = the scalar
    /// reference loop; the constraint solver is per-lane either way).
    width: usize,
}

impl WalkerVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(task: Task, seed: u64, first_env_id: u64, count: usize) -> Self {
        let proto = task.build();
        let actuated = proto.world.actuated();
        let n = actuated.len();
        let nb = proto.world.bodies.len();
        let jmeta = actuated
            .iter()
            .map(|&ji| {
                let j = &proto.world.joints[ji];
                (j.body_a, j.body_b, j.ref_angle)
            })
            .collect();
        WalkerVec {
            spec: walker::spec_for_task(task, n),
            actuated,
            jmeta,
            nb,
            rng: (0..count).map(|l| walker::make_rng(seed, first_env_id + l as u64)).collect(),
            steps: vec![0; count],
            models: (0..count).map(|_| proto.clone()).collect(),
            pos_x: vec![0.0; count * nb],
            pos_y: vec![0.0; count * nb],
            angle: vec![0.0; count * nb],
            vel_x: vec![0.0; count * nb],
            vel_y: vec![0.0; count * nb],
            omega: vec![0.0; count * nb],
            x_before: vec![0.0; count],
            // Scalar reference until configured (see the classic-control
            // kernels): `set_lane_pass` is the single Auto-resolution
            // point, so construction never reads env vars or cpuid.
            width: LanePass::Scalar.width(),
            proto,
        }
    }

    /// Copy lane `lane`'s body state from its world into the SoA lanes.
    fn scatter(&mut self, lane: usize) {
        let base = lane * self.nb;
        let bodies = &self.models[lane].world.bodies;
        for (b, body) in bodies.iter().enumerate() {
            self.pos_x[base + b] = body.pos.x;
            self.pos_y[base + b] = body.pos.y;
            self.angle[base + b] = body.angle;
            self.vel_x[base + b] = body.vel.x;
            self.vel_y[base + b] = body.vel.y;
            self.omega[base + b] = body.omega;
        }
    }

    /// Healthy test on the SoA lanes — same predicate (and evaluation
    /// order) as the scalar env's `healthy()`.
    fn lane_healthy(&self, lane: usize) -> bool {
        let t = lane * self.nb + self.proto.torso;
        if let Some((lo, hi)) = self.proto.healthy_z {
            if self.pos_y[t] < lo || self.pos_y[t] > hi {
                return false;
            }
        }
        if let Some(dev) = self.proto.healthy_angle_dev {
            if (self.angle[t] - self.proto.init_angle).abs() > dev {
                return false;
            }
        }
        !self.lane_is_bad(lane)
    }

    /// Any non-finite state in lane `lane`?
    fn lane_is_bad(&self, lane: usize) -> bool {
        for i in lane * self.nb..(lane + 1) * self.nb {
            if !self.pos_x[i].is_finite()
                || !self.pos_y[i].is_finite()
                || !self.angle[i].is_finite()
                || !self.vel_x[i].is_finite()
                || !self.vel_y[i].is_finite()
                || !self.omega[i].is_finite()
            {
                return true;
            }
        }
        false
    }

    /// Write lane `lane`'s observation from the SoA lanes (the scalar
    /// env's layout: `[z, angle, q.., vx, vz, omega, qd..]`).
    fn write_obs_lane(&self, lane: usize, obs: &mut [f32]) {
        let base = lane * self.nb;
        let t = base + self.proto.torso;
        let n = self.actuated.len();
        obs[0] = self.pos_y[t];
        obs[1] = self.angle[t] - self.proto.init_angle;
        for (k, &(a, b, ref_angle)) in self.jmeta.iter().enumerate() {
            obs[2 + k] = self.angle[base + b] - self.angle[base + a] - ref_angle;
        }
        obs[2 + n] = self.vel_x[t];
        obs[3 + n] = self.vel_y[t];
        obs[4 + n] = self.omega[t];
        for (k, &(a, b, _)) in self.jmeta.iter().enumerate() {
            obs[5 + n + k] = self.omega[base + b] - self.omega[base + a];
        }
    }
}

impl WalkerVec {
    /// Phase 2 as a SIMD lane pass: forward reward, control cost,
    /// healthy test and reward composed over groups of `W` lanes per
    /// instruction. Identical operations in identical order to the
    /// scalar phase-2 loop (the per-lane control-cost accumulation
    /// walks joints in the same sequence), so this is bitwise equal to
    /// the width-1 reference — and to the scalar [`WalkerEnv`]
    /// (`crate::envs::mujoco::WalkerEnv`), keeping the kernel's bitwise
    /// parity contract intact.
    fn task_pass_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let adim = self.actuated.len();
        let nb = self.nb;
        let torso = self.proto.torso;
        let s = F32s::<W>::splat;
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            // Gathers (stride nb) — reset/tail lanes ride along, their
            // results are discarded by the masked store below.
            let x_after =
                F32s::<W>::from_fn(|i| if i < n { self.pos_x[(g + i) * nb + torso] } else { 0.0 });
            let x_before = F32s::<W>::load_or(&self.x_before[g..g + n], 0.0);
            let forward = (x_after - x_before) / s(DT * FRAME_SKIP as f32);
            let mut ctrl = s(0.0);
            for j in 0..adim {
                let aj = F32s::<W>::from_fn(|i| {
                    if i < n {
                        actions[(g + i) * adim + j]
                    } else {
                        0.0
                    }
                });
                ctrl = ctrl + aj * aj;
            }
            // Healthy test — the same comparisons (and NaN behavior) as
            // `lane_healthy`, lane-wise.
            let mut healthy = Mask([true; W]);
            if let Some((lo, hi)) = self.proto.healthy_z {
                let y = F32s::<W>::from_fn(|i| {
                    if i < n {
                        self.pos_y[(g + i) * nb + torso]
                    } else {
                        0.0
                    }
                });
                healthy = healthy & !(y.lt(s(lo)) | y.gt(s(hi)));
            }
            if let Some(dev) = self.proto.healthy_angle_dev {
                let a = F32s::<W>::from_fn(|i| {
                    if i < n {
                        self.angle[(g + i) * nb + torso]
                    } else {
                        0.0
                    }
                });
                healthy = healthy & !(a - s(self.proto.init_angle)).abs().gt(s(dev));
            }
            let bad = Mask(std::array::from_fn(|i| i < n && self.lane_is_bad(g + i)));
            healthy = healthy & !bad;
            let reward = s(self.proto.forward_weight) * forward
                + healthy.select_f32(s(self.proto.healthy_reward), s(0.0))
                - s(self.proto.ctrl_cost) * ctrl;
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                let done = !healthy.0[i];
                let truncated =
                    !done && self.steps[lane] as usize >= self.spec.max_episode_steps;
                out[lane] = Step { reward: reward.0[i], done, truncated };
            }
            g += W;
        }
    }
}

impl VecEnv for WalkerVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.models[lane] = self.proto.clone();
        walker::apply_reset_noise(&mut self.models[lane].world, &mut self.rng[lane]);
        self.steps[lane] = 0;
        self.scatter(lane);
        self.write_obs_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let adim = self.actuated.len();
        debug_assert_eq!(actions.len(), k * adim);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        // Phase 1 — auto-resets, then physics: each stepped lane runs
        // `FRAME_SKIP` substeps of the scalar solver (bitwise parity)
        // and scatters its body state back into the qpos/qvel lanes.
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            self.x_before[lane] = self.pos_x[lane * self.nb + self.proto.torso];
            let act = &actions[lane * adim..(lane + 1) * adim];
            let w = &mut self.models[lane].world;
            for _ in 0..FRAME_SKIP {
                w.step(DT, act);
            }
            self.scatter(lane);
            self.steps[lane] += 1;
        }
        // Phase 2 — batch task pass over the SoA lanes: forward reward,
        // control cost, healthy termination, truncation. SIMD lane pass
        // when a width is selected (bitwise identical to the scalar
        // loop below, which remains the width-1 reference).
        match self.width {
            8 => self.task_pass_lanes::<8>(actions, reset_mask, out),
            4 => self.task_pass_lanes::<4>(actions, reset_mask, out),
            _ => {
                for lane in 0..k {
                    if reset_mask[lane] != 0 {
                        continue;
                    }
                    let x_after = self.pos_x[lane * self.nb + self.proto.torso];
                    let forward = (x_after - self.x_before[lane]) / (DT * FRAME_SKIP as f32);
                    let act = &actions[lane * adim..(lane + 1) * adim];
                    let ctrl: f32 = act.iter().map(|a| a * a).sum();
                    let healthy = self.lane_healthy(lane);
                    let reward = self.proto.forward_weight * forward
                        + if healthy { self.proto.healthy_reward } else { 0.0 }
                        - self.proto.ctrl_cost * ctrl;
                    let done = !healthy;
                    let truncated =
                        !done && self.steps[lane] as usize >= self.spec.max_episode_steps;
                    out[lane] = Step { reward, done, truncated };
                }
            }
        }
        // Phase 3 — observation rows straight from the SoA lanes.
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                continue;
            }
            self.write_obs_lane(lane, arena.row(lane));
        }
    }
}

/// dm_control `cheetah run` over the SoA walker kernel: the HalfCheetah
/// lanes with the Control Suite's shaped reward
/// `clip(vx / TARGET_SPEED, 0, 1)` and no failure termination — the
/// batched analog of [`CheetahRun`](crate::envs::dmc::CheetahRun),
/// bitwise identical to it.
pub struct CheetahRunVec {
    inner: WalkerVec,
    spec: EnvSpec,
}

impl CheetahRunVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        let inner = WalkerVec::new(Task::HalfCheetah, seed, first_env_id, count);
        let spec = cheetah_spec(inner.spec());
        CheetahRunVec { inner, spec }
    }
}

impl VecEnv for CheetahRunVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.inner.set_lane_pass(lane_pass);
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.inner.reset_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        self.inner.step_batch(actions, reset_mask, arena, out);
        // Reshape rewards batch-wise: vx sits at obs[2 + n_joints] in
        // the row just written (same recovery the scalar task uses, via
        // the shared `shape_step` core).
        let n_joints = self.spec.action_space.dim();
        for lane in 0..out.len() {
            if reset_mask[lane] != 0 {
                continue;
            }
            let vx = arena.row(lane)[2 + n_joints];
            out[lane] = shape_step(vx, out[lane]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::dmc::CheetahRun;
    use crate::envs::env::Env;
    use crate::envs::mujoco::WalkerEnv;
    use crate::envs::vector::SliceArena;

    /// Drive a scalar env and the matching kernel lane-for-lane with the
    /// same action stream (including auto-resets) and demand bitwise
    /// equality — the documented parity tolerance for this kernel.
    fn check_parity(task: Task, steps: usize) {
        let seed = 31;
        let n = 2;
        let mut vec_env = WalkerVec::new(task, seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let adim = vec_env.spec().action_space.dim();
        let mut scalars: Vec<WalkerEnv> =
            (0..n).map(|i| WalkerEnv::new(task, seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mut mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..steps {
            let actions: Vec<f32> = (0..n * adim).map(|k| ((t + k) as f32 * 0.37).sin()).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                if mask[l] != 0 {
                    env.reset(&mut sobs);
                    assert_eq!(results[l], Step::default(), "reset step {t} lane {l}");
                } else {
                    let s = env.step(&actions[l * adim..(l + 1) * adim], &mut sobs);
                    assert_eq!(results[l], s, "step {t} lane {l}");
                }
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
                mask[l] = results[l].finished() as u8;
            }
        }
    }

    #[test]
    fn hopper_vec_matches_scalar_bitwise() {
        check_parity(Task::Hopper, 120);
    }

    #[test]
    fn half_cheetah_vec_matches_scalar_bitwise() {
        check_parity(Task::HalfCheetah, 80);
    }

    #[test]
    fn ant_vec_matches_scalar_bitwise() {
        check_parity(Task::Ant, 60);
    }

    #[test]
    fn cheetah_run_vec_matches_scalar_bitwise() {
        let seed = 17;
        let n = 2;
        let mut vec_env = CheetahRunVec::new(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let adim = vec_env.spec().action_space.dim();
        let mut scalars: Vec<CheetahRun> =
            (0..n).map(|i| CheetahRun::new(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..80 {
            let actions: Vec<f32> = (0..n * adim).map(|k| ((t + k) as f32 * 0.21).cos()).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                let s = env.step(&actions[l * adim..(l + 1) * adim], &mut sobs);
                assert_eq!(results[l], s, "step {t} lane {l}");
                assert!(!results[l].done, "cheetah_run never terminates");
                assert!((0.0..=1.0).contains(&results[l].reward));
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }
}
