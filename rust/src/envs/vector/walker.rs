//! Struct-of-arrays MuJoCo-walker batch kernel ([`WalkerVec`]) and the
//! dm_control shaping over it ([`CheetahRunVec`]).
//!
//! # Layout
//!
//! *All* mutable physics state — body qpos/qvel lanes, joint warm-start
//! impulses, contact caches — lives in the batch-resident
//! [`WorldBatch`](crate::envs::mujoco::WorldBatch) core, indexed
//! **body-major** (`[body * lanes + lane]`, via
//! `WorldBatch::body_index`), so a lane group of any body attribute is
//! one contiguous slice. This kernel owns the task layer on
//! top: reward, healthy checks, truncation and observation extraction
//! run as batch passes over the batch's contiguous lanes, using static
//! per-joint metadata captured once from the prototype model (all lanes
//! share one articulation topology). There are **no per-lane `World`
//! clones** anymore; the scalar
//! [`WalkerEnv`](crate::envs::mujoco::WalkerEnv) is a width-1 view over
//! this very kernel, so there is exactly one solver in the tree.
//!
//! # Physics and parity
//!
//! The sequential-impulse solver phases run **lane-grouped** inside
//! `WorldBatch::step`, at the width selected by
//! [`VecEnv::set_lane_pass`] (wired from `PoolConfig::lane_pass` /
//! `--lane-width`, overridable via `ENVPOOL_LANE_WIDTH` — exactly the
//! classic-control plumbing):
//!
//! - **Width 1** is the bitwise reference: the batch applies the same
//!   scalar operations in the same order as the AoS
//!   [`World::step`](crate::envs::mujoco::World::step) (libm trig
//!   included), so width-1 trajectories reproduce the pre-refactor
//!   scalar envs exactly — pinned by the in-file tests here and the
//!   seeded pins in `tests/mujoco_batch_parity.rs`.
//! - **Widths 4/8** rotate anchors/endpoints through the deterministic
//!   [`crate::simd::math`] trig twins so the whole solver vectorizes;
//!   trajectories drift from width 1 within the **documented, asserted
//!   tolerance budget**
//!   ([`LANE_TOL_ABS`](crate::envs::mujoco::batch::LANE_TOL_ABS)`/
//!   `[`LANE_TOL_REL`](crate::envs::mujoco::batch::LANE_TOL_REL)) plus
//!   cross-width invariants (flags, penetration bound, energy bound) —
//!   the relaxed contract that replaced the old bitwise-only one. Tests
//!   that need bitwise walker equality across execution modes pin
//!   `LanePass::Scalar`.

use super::{ObsArena, VecEnv};
use crate::envs::dmc::cheetah_run::{cheetah_spec, shape_step};
use crate::envs::env::Step;
use crate::envs::mujoco::models::Model;
use crate::envs::mujoco::walker::{self, Task};
use crate::envs::mujoco::{WorldBatch, DT, FRAME_SKIP};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, LanePass, Mask};

/// SoA batch of walker environments (Hopper / HalfCheetah / Ant) over a
/// batch-resident [`WorldBatch`] core.
pub struct WalkerVec {
    spec: EnvSpec,
    /// Prototype model: task constants + topology (the batch holds the
    /// reset template itself).
    proto: Model,
    /// Actuated joint indices (action layout), shared by all lanes.
    actuated: Vec<usize>,
    /// Per actuated joint: `(body_a, body_b, ref_angle)` — the static
    /// metadata that lets observation extraction run on SoA lanes only.
    jmeta: Vec<(usize, usize, f32)>,
    rng: Vec<Pcg32>,
    steps: Vec<u32>,
    /// Batch-resident solver state: body lanes + joint/contact warm
    /// starts, stepped lane-grouped.
    batch: WorldBatch,
    /// Torso x before the current batch step (forward-reward scratch).
    x_before: Vec<f32>,
    /// Resolved SIMD lane width for the solver and the batch task pass
    /// (1 = the bitwise scalar reference).
    width: usize,
}

impl WalkerVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(task: Task, seed: u64, first_env_id: u64, count: usize) -> Self {
        let proto = task.build();
        let actuated = proto.world.actuated();
        let n = actuated.len();
        let jmeta = actuated
            .iter()
            .map(|&ji| {
                let j = &proto.world.joints[ji];
                (j.body_a, j.body_b, j.ref_angle)
            })
            .collect();
        WalkerVec {
            spec: walker::spec_for_task(task, n),
            actuated,
            jmeta,
            rng: (0..count).map(|l| walker::make_rng(seed, first_env_id + l as u64)).collect(),
            steps: vec![0; count],
            batch: WorldBatch::from_world(&proto.world, count),
            x_before: vec![0.0; count],
            // Scalar reference until configured (see the classic-control
            // kernels): `set_lane_pass` is the single Auto-resolution
            // point, so construction never reads env vars or cpuid.
            width: LanePass::Scalar.width(),
            proto,
        }
    }

    /// The batch-resident physics core (read-only) — invariant probes
    /// (penetration, kinetic energy, finiteness) for the tolerance
    /// test layer.
    pub fn batch(&self) -> &WorldBatch {
        &self.batch
    }

    /// Healthy test on the SoA lanes — same predicate (and evaluation
    /// order) as the pre-refactor scalar env's `healthy()`.
    fn lane_healthy(&self, lane: usize) -> bool {
        let t = self.batch.body_index(lane, self.proto.torso);
        if let Some((lo, hi)) = self.proto.healthy_z {
            if self.batch.pos_y[t] < lo || self.batch.pos_y[t] > hi {
                return false;
            }
        }
        if let Some(dev) = self.proto.healthy_angle_dev {
            if (self.batch.angle[t] - self.proto.init_angle).abs() > dev {
                return false;
            }
        }
        !self.batch.lane_is_bad(lane)
    }

    /// Write lane `lane`'s observation from the SoA lanes (the scalar
    /// env's layout: `[z, angle, q.., vx, vz, omega, qd..]`).
    fn write_obs_lane(&self, lane: usize, obs: &mut [f32]) {
        let bi = |b: usize| self.batch.body_index(lane, b);
        let t = bi(self.proto.torso);
        let n = self.actuated.len();
        obs[0] = self.batch.pos_y[t];
        obs[1] = self.batch.angle[t] - self.proto.init_angle;
        for (k, &(a, b, ref_angle)) in self.jmeta.iter().enumerate() {
            obs[2 + k] = self.batch.angle[bi(b)] - self.batch.angle[bi(a)] - ref_angle;
        }
        obs[2 + n] = self.batch.vel_x[t];
        obs[3 + n] = self.batch.vel_y[t];
        obs[4 + n] = self.batch.omega[t];
        for (k, &(a, b, _)) in self.jmeta.iter().enumerate() {
            obs[5 + n + k] = self.batch.omega[bi(b)] - self.batch.omega[bi(a)];
        }
    }
}

impl WalkerVec {
    /// The task layer as a SIMD lane pass: forward reward, control
    /// cost, healthy test and reward composed over groups of `W` lanes
    /// per instruction. Identical operations in identical order to the
    /// scalar task loop (the per-lane control-cost accumulation walks
    /// joints in the same sequence), so for a given solver state this
    /// pass is bitwise equal to the width-1 task loop — the width-1 /
    /// width-N trajectory difference comes entirely from the solver's
    /// trig twins (see the module docs).
    fn task_pass_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let adim = self.actuated.len();
        let torso = self.proto.torso;
        let tb = self.batch.body_index(0, torso);
        let s = F32s::<W>::splat;
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            // Body-major layout: each torso attribute for the group is
            // one contiguous slice — reset/tail lanes ride along, their
            // results are discarded by the masked store below.
            let x_after = F32s::<W>::load_or(&self.batch.pos_x[tb + g..tb + g + n], 0.0);
            let x_before = F32s::<W>::load_or(&self.x_before[g..g + n], 0.0);
            let forward = (x_after - x_before) / s(DT * FRAME_SKIP as f32);
            let mut ctrl = s(0.0);
            for j in 0..adim {
                let aj = F32s::<W>::from_fn(|i| {
                    if i < n {
                        actions[(g + i) * adim + j]
                    } else {
                        0.0
                    }
                });
                ctrl = ctrl + aj * aj;
            }
            // Healthy test — the same comparisons (and NaN behavior) as
            // `lane_healthy`, lane-wise.
            let mut healthy = Mask([true; W]);
            if let Some((lo, hi)) = self.proto.healthy_z {
                let y = F32s::<W>::load_or(&self.batch.pos_y[tb + g..tb + g + n], 0.0);
                healthy = healthy & !(y.lt(s(lo)) | y.gt(s(hi)));
            }
            if let Some(dev) = self.proto.healthy_angle_dev {
                let a = F32s::<W>::load_or(&self.batch.angle[tb + g..tb + g + n], 0.0);
                healthy = healthy & !(a - s(self.proto.init_angle)).abs().gt(s(dev));
            }
            let bad =
                Mask(std::array::from_fn(|i| i < n && self.batch.lane_is_bad(g + i)));
            healthy = healthy & !bad;
            let reward = s(self.proto.forward_weight) * forward
                + healthy.select_f32(s(self.proto.healthy_reward), s(0.0))
                - s(self.proto.ctrl_cost) * ctrl;
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                let done = !healthy.0[i];
                let truncated =
                    !done && self.steps[lane] as usize >= self.spec.max_episode_steps;
                out[lane] = Step { reward: reward.0[i], done, truncated };
            }
            g += W;
        }
    }
}

impl VecEnv for WalkerVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn param_names(&self) -> &'static [&'static str] {
        &["gravity", "gear_scale"]
    }

    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        match name {
            "gravity" => self.batch.set_gravity_lanes(values),
            "gear_scale" => self.batch.set_gear_scale_lanes(values),
            _ => return false,
        }
        true
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.batch.reset_lane(lane);
        self.batch.apply_reset_noise(lane, &mut self.rng[lane]);
        self.steps[lane] = 0;
        self.write_obs_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let adim = self.actuated.len();
        debug_assert_eq!(actions.len(), k * adim);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        // Phase 1 — auto-resets + forward-reward scratch.
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
            } else {
                self.x_before[lane] = self.batch.pos_x[self.batch.body_index(lane, self.proto.torso)];
                self.steps[lane] += 1;
            }
        }
        // Physics — `FRAME_SKIP` lane-grouped substeps of the batch
        // solver; resetting lanes ride along fully masked.
        for _ in 0..FRAME_SKIP {
            self.batch.step(DT, actions, adim, reset_mask, self.width);
        }
        // Phase 2 — batch task pass over the SoA lanes: forward reward,
        // control cost, healthy termination, truncation.
        match self.width {
            8 => self.task_pass_lanes::<8>(actions, reset_mask, out),
            4 => self.task_pass_lanes::<4>(actions, reset_mask, out),
            _ => {
                for lane in 0..k {
                    if reset_mask[lane] != 0 {
                        continue;
                    }
                    let x_after = self.batch.pos_x[self.batch.body_index(lane, self.proto.torso)];
                    let forward = (x_after - self.x_before[lane]) / (DT * FRAME_SKIP as f32);
                    let act = &actions[lane * adim..(lane + 1) * adim];
                    let ctrl: f32 = act.iter().map(|a| a * a).sum();
                    let healthy = self.lane_healthy(lane);
                    let reward = self.proto.forward_weight * forward
                        + if healthy { self.proto.healthy_reward } else { 0.0 }
                        - self.proto.ctrl_cost * ctrl;
                    let done = !healthy;
                    let truncated =
                        !done && self.steps[lane] as usize >= self.spec.max_episode_steps;
                    out[lane] = Step { reward, done, truncated };
                }
            }
        }
        // Phase 3 — observation rows straight from the SoA lanes.
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                continue;
            }
            self.write_obs_lane(lane, arena.row(lane));
        }
    }
}

/// dm_control `cheetah run` over the SoA walker kernel: the HalfCheetah
/// lanes with the Control Suite's shaped reward
/// `clip(vx / TARGET_SPEED, 0, 1)` and no failure termination — the
/// batched analog of [`CheetahRun`](crate::envs::dmc::CheetahRun)
/// (bitwise identical to it at width 1; the walker tolerance contract
/// applies at wider lanes).
pub struct CheetahRunVec {
    inner: WalkerVec,
    spec: EnvSpec,
}

impl CheetahRunVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        let inner = WalkerVec::new(Task::HalfCheetah, seed, first_env_id, count);
        let spec = cheetah_spec(inner.spec());
        CheetahRunVec { inner, spec }
    }

    /// Invariant probe passthrough (see [`WalkerVec::batch`]).
    pub fn batch(&self) -> &WorldBatch {
        self.inner.batch()
    }
}

impl VecEnv for CheetahRunVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.inner.set_lane_pass(lane_pass);
    }

    fn param_names(&self) -> &'static [&'static str] {
        self.inner.param_names()
    }

    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        self.inner.set_param_lanes(name, values)
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.inner.reset_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        self.inner.step_batch(actions, reset_mask, arena, out);
        // Reshape rewards batch-wise: vx sits at obs[2 + n_joints] in
        // the row just written (same recovery the scalar task uses, via
        // the shared `shape_step` core).
        let n_joints = self.spec.action_space.dim();
        for lane in 0..out.len() {
            if reset_mask[lane] != 0 {
                continue;
            }
            let vx = arena.row(lane)[2 + n_joints];
            out[lane] = shape_step(vx, out[lane]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::dmc::CheetahRun;
    use crate::envs::env::Env;
    use crate::envs::mujoco::WalkerEnv;
    use crate::envs::vector::SliceArena;

    /// Drive a scalar env (itself a width-1 view over a one-lane batch)
    /// and the matching N-lane kernel lane-for-lane with the same action
    /// stream (including auto-resets) and demand bitwise equality — the
    /// width-1 parity contract. This pins the view plumbing (RNG
    /// streams, reset masking, obs extraction) on top of the solver pin
    /// in `envs/mujoco/batch.rs`.
    fn check_parity(task: Task, steps: usize) {
        let seed = 31;
        let n = 2;
        let mut vec_env = WalkerVec::new(task, seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let adim = vec_env.spec().action_space.dim();
        let mut scalars: Vec<WalkerEnv> =
            (0..n).map(|i| WalkerEnv::new(task, seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mut mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..steps {
            let actions: Vec<f32> = (0..n * adim).map(|k| ((t + k) as f32 * 0.37).sin()).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                if mask[l] != 0 {
                    env.reset(&mut sobs);
                    assert_eq!(results[l], Step::default(), "reset step {t} lane {l}");
                } else {
                    let s = env.step(&actions[l * adim..(l + 1) * adim], &mut sobs);
                    assert_eq!(results[l], s, "step {t} lane {l}");
                }
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
                mask[l] = results[l].finished() as u8;
            }
        }
    }

    #[test]
    fn hopper_vec_matches_scalar_bitwise() {
        check_parity(Task::Hopper, 120);
    }

    #[test]
    fn half_cheetah_vec_matches_scalar_bitwise() {
        check_parity(Task::HalfCheetah, 80);
    }

    #[test]
    fn ant_vec_matches_scalar_bitwise() {
        check_parity(Task::Ant, 60);
    }

    #[test]
    fn cheetah_run_vec_matches_scalar_bitwise() {
        let seed = 17;
        let n = 2;
        let mut vec_env = CheetahRunVec::new(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let adim = vec_env.spec().action_space.dim();
        let mut scalars: Vec<CheetahRun> =
            (0..n).map(|i| CheetahRun::new(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..80 {
            let actions: Vec<f32> = (0..n * adim).map(|k| ((t + k) as f32 * 0.21).cos()).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                let s = env.step(&actions[l * adim..(l + 1) * adim], &mut sobs);
                assert_eq!(results[l], s, "step {t} lane {l}");
                assert!(!results[l].done, "cheetah_run never terminates");
                assert!((0.0..=1.0).contains(&results[l].reward));
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }
}
