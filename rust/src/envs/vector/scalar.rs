//! **Explicit opt-in** [`VecEnv`] over a batch of boxed scalar
//! environments. Every registered task now has a dedicated batch kernel
//! and `registry::make_vec_env` no longer falls back here; construct a
//! [`ScalarVec`] directly when an out-of-registry or experimental env
//! needs the chunked-dispatch amortization — one task dequeue and one
//! wakeup per `K` envs — without a SoA state layout.

use super::{ObsArena, VecEnv};
use crate::envs::env::{Env, Step};
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::Result;

/// A chunk of scalar envs behind the vectorized interface.
pub struct ScalarVec {
    spec: EnvSpec,
    envs: Vec<Box<dyn Env>>,
}

impl ScalarVec {
    /// Batch of `count` scalar envs with global ids `first_env_id..+count`.
    pub fn new(task_id: &str, seed: u64, first_env_id: u64, count: usize) -> Result<Self> {
        let envs = (0..count)
            .map(|l| registry::make_env(task_id, seed, first_env_id + l as u64))
            .collect::<Result<Vec<_>>>()?;
        // Take the spec from a member env; construction (ROM/model load)
        // is exactly what this fallback path wants to avoid duplicating.
        let spec = match envs.first() {
            Some(e) => e.spec().clone(),
            None => registry::spec_for(task_id)?,
        };
        Ok(ScalarVec { spec, envs })
    }
}

impl VecEnv for ScalarVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.envs.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.envs[lane].reset(obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let adim = self.spec.action_space.dim();
        debug_assert_eq!(actions.len(), self.envs.len() * adim);
        for (lane, env) in self.envs.iter_mut().enumerate() {
            let obs = arena.row(lane);
            if reset_mask[lane] != 0 {
                env.reset(obs);
                out[lane] = Step::default();
            } else {
                out[lane] = env.step(&actions[lane * adim..(lane + 1) * adim], obs);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::vector::SliceArena;

    #[test]
    fn scalar_vec_steps_any_task() {
        let mut v = ScalarVec::new("Pendulum-v1", 3, 0, 2).unwrap();
        assert_eq!(v.num_envs(), 2);
        let dim = v.spec().obs_dim();
        let mut obs = vec![0.0f32; 2 * dim];
        for lane in 0..2 {
            v.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
        }
        let mut out = vec![Step::default(); 2];
        let mut arena = SliceArena::new(&mut obs, dim);
        v.step_batch(&[0.5, -0.5], &[0, 0], &mut arena, &mut out);
        assert!(out.iter().all(|s| s.reward <= 0.0 && !s.done));
    }
}
