//! Acrobot batch kernel: a [`LaneDynamics`] descriptor over the shared
//! SoA driver ([`super::SoaKernel`]). RK4 math and RNG streams are
//! shared with [`crate::envs::classic::acrobot`]; the lane pass runs
//! the whole RK4 integration over lane groups via `dynamics_lanes`,
//! bitwise identical to the scalar env at every lane width.

use super::{LaneDynamics, SoaKernel, MAX_PARAMS};
use crate::envs::classic::acrobot;
use crate::envs::env::discrete_action;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, Mask};

/// Acrobot's dynamics/terminal/reward rules for the shared driver.
/// State lanes are `[theta1, theta2, dtheta1, dtheta2]`. Acrobot
/// intentionally exposes **no** overridable physics (`param_names` is
/// empty): its RK4 `dsdt` composites are const-folded and cannot be
/// pinned bitwise against a runtime recompute without a toolchain, so
/// scenario validation rejects parameter overrides for this task.
pub struct AcrobotDyn;

impl LaneDynamics<4> for AcrobotDyn {
    fn spec(&self) -> EnvSpec {
        acrobot::spec()
    }

    fn rng_for(&self, seed: u64, env_id: u64) -> Pcg32 {
        acrobot::rng(seed, env_id)
    }

    fn max_steps(&self) -> usize {
        acrobot::MAX_STEPS
    }

    fn reset_state(&self, rng: &mut Pcg32) -> [f32; 4] {
        acrobot::reset_state(rng)
    }

    fn step1(
        &self,
        s: [f32; 4],
        actions: &[f32],
        lane: usize,
        _p: &[f32; MAX_PARAMS],
    ) -> ([f32; 4], bool, f32) {
        let a = discrete_action(&actions[lane..lane + 1], 3);
        let s2 = acrobot::dynamics(s, a);
        let done = acrobot::is_terminal(&s2);
        (s2, done, if done { 0.0 } else { -1.0 })
    }

    fn input(&self, actions: &[f32], lane: usize) -> f32 {
        discrete_action(&actions[lane..lane + 1], 3) as f32 - 1.0
    }

    fn step_lanes<const W: usize>(
        &self,
        s: [F32s<W>; 4],
        u: F32s<W>,
        _p: &[F32s<W>; MAX_PARAMS],
    ) -> ([F32s<W>; 4], Mask<W>, F32s<W>) {
        let s2 = acrobot::dynamics_lanes(s, u);
        let term = acrobot::is_terminal_lanes(s2[0], s2[1]);
        let reward = term.select_f32(F32s::splat(0.0), F32s::splat(-1.0));
        (s2, term, reward)
    }

    fn write_obs(&self, s: &[f32; 4], obs: &mut [f32]) {
        acrobot::write_obs(s, obs);
    }
}

/// SoA batch of Acrobot environments.
pub type AcrobotVec = SoaKernel<4, AcrobotDyn>;

impl SoaKernel<4, AcrobotDyn> {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        SoaKernel::with_dynamics(AcrobotDyn, seed, first_env_id, count)
    }
}
