//! Struct-of-arrays Acrobot batch kernel (RK4 math and RNG streams
//! shared with [`crate::envs::classic::acrobot`]).

use super::{ObsArena, VecEnv};
use crate::envs::classic::acrobot;
use crate::envs::env::{discrete_action, Step};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;

/// SoA batch of Acrobot environments. State lanes are
/// `[theta1, theta2, dtheta1, dtheta2]`.
pub struct AcrobotVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    theta1: Vec<f32>,
    theta2: Vec<f32>,
    dtheta1: Vec<f32>,
    dtheta2: Vec<f32>,
    steps: Vec<u32>,
}

impl AcrobotVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        AcrobotVec {
            spec: acrobot::spec(),
            rng: (0..count).map(|l| acrobot::rng(seed, first_env_id + l as u64)).collect(),
            theta1: vec![0.0; count],
            theta2: vec![0.0; count],
            dtheta1: vec![0.0; count],
            dtheta2: vec![0.0; count],
            steps: vec![0; count],
        }
    }

    #[inline]
    fn scatter(&mut self, lane: usize, s: [f32; 4]) {
        self.theta1[lane] = s[0];
        self.theta2[lane] = s[1];
        self.dtheta1[lane] = s[2];
        self.dtheta2[lane] = s[3];
    }

    #[inline]
    fn write_obs(s: &[f32; 4], obs: &mut [f32]) {
        obs[0] = s[0].cos();
        obs[1] = s[0].sin();
        obs[2] = s[1].cos();
        obs[3] = s[1].sin();
        obs[4] = s[2];
        obs[5] = s[3];
    }
}

impl VecEnv for AcrobotVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let s = acrobot::reset_state(&mut self.rng[lane]);
        self.scatter(lane, s);
        self.steps[lane] = 0;
        Self::write_obs(&s, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let a = discrete_action(&actions[lane..lane + 1], 3);
            let s = acrobot::dynamics(
                [self.theta1[lane], self.theta2[lane], self.dtheta1[lane], self.dtheta2[lane]],
                a,
            );
            self.scatter(lane, s);
            self.steps[lane] += 1;

            let done = acrobot::is_terminal(&s);
            let truncated = !done && self.steps[lane] as usize >= acrobot::MAX_STEPS;
            Self::write_obs(&s, arena.row(lane));
            out[lane] = Step { reward: if done { 0.0 } else { -1.0 }, done, truncated };
        }
    }
}
