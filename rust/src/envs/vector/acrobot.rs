//! Struct-of-arrays Acrobot batch kernel (RK4 math and RNG streams
//! shared with [`crate::envs::classic::acrobot`]; the SIMD lane pass
//! runs the whole RK4 integration over lane groups via
//! `dynamics_lanes`, bitwise identical to the scalar reference at every
//! lane width).

use super::{ObsArena, VecEnv};
use crate::envs::classic::acrobot;
use crate::envs::env::{discrete_action, Step};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, LanePass};

/// SoA batch of Acrobot environments. State lanes are
/// `[theta1, theta2, dtheta1, dtheta2]`.
pub struct AcrobotVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    theta1: Vec<f32>,
    theta2: Vec<f32>,
    dtheta1: Vec<f32>,
    dtheta2: Vec<f32>,
    steps: Vec<u32>,
    /// Resolved SIMD lane width (1 = scalar reference loop).
    width: usize,
}

impl AcrobotVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        AcrobotVec {
            spec: acrobot::spec(),
            rng: (0..count).map(|l| acrobot::rng(seed, first_env_id + l as u64)).collect(),
            theta1: vec![0.0; count],
            theta2: vec![0.0; count],
            dtheta1: vec![0.0; count],
            dtheta2: vec![0.0; count],
            steps: vec![0; count],
            // Scalar reference until configured: the wired paths (pool,
            // executors) always call `set_lane_pass`, which is also the
            // single place the `Auto` width (env override + feature
            // detection) resolves — keeping construction infallible.
            width: LanePass::Scalar.width(),
        }
    }

    #[inline]
    fn scatter(&mut self, lane: usize, s: [f32; 4]) {
        self.theta1[lane] = s[0];
        self.theta2[lane] = s[1];
        self.dtheta1[lane] = s[2];
        self.dtheta2[lane] = s[3];
    }

    /// Finish one stepped lane: bookkeeping, flags, observation row.
    #[inline]
    fn finish_lane(&mut self, lane: usize, done: bool, arena: &mut dyn ObsArena, out: &mut [Step]) {
        self.steps[lane] += 1;
        let truncated = !done && self.steps[lane] as usize >= acrobot::MAX_STEPS;
        let s =
            [self.theta1[lane], self.theta2[lane], self.dtheta1[lane], self.dtheta2[lane]];
        acrobot::write_obs(&s, arena.row(lane));
        out[lane] = Step { reward: if done { 0.0 } else { -1.0 }, done, truncated };
    }

    /// The scalar reference loop (lane width 1).
    fn step_scalar(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        for lane in 0..self.num_envs() {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let a = discrete_action(&actions[lane..lane + 1], 3);
            let s = acrobot::dynamics(
                [self.theta1[lane], self.theta2[lane], self.dtheta1[lane], self.dtheta2[lane]],
                a,
            );
            self.scatter(lane, s);
            let done = acrobot::is_terminal(&s);
            self.finish_lane(lane, done, arena, out);
        }
    }

    /// The SIMD lane pass (masked tail + masked resets, same structure
    /// as the CartPole kernel — see the module docs in [`super`]).
    fn step_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            for lane in g..g + n {
                if reset_mask[lane] != 0 {
                    self.reset_lane(lane, arena.row(lane));
                    out[lane] = Step::default();
                }
            }
            let state = [
                F32s::<W>::load_or(&self.theta1[g..g + n], 0.0),
                F32s::<W>::load_or(&self.theta2[g..g + n], 0.0),
                F32s::<W>::load_or(&self.dtheta1[g..g + n], 0.0),
                F32s::<W>::load_or(&self.dtheta2[g..g + n], 0.0),
            ];
            let torque = F32s::<W>::from_fn(|i| {
                let lane = g + i;
                if i < n && reset_mask[lane] == 0 {
                    discrete_action(&actions[lane..lane + 1], 3) as f32 - 1.0
                } else {
                    0.0
                }
            });
            let s = acrobot::dynamics_lanes(state, torque);
            let term = acrobot::is_terminal_lanes(s[0], s[1]);
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                self.scatter(lane, [s[0].0[i], s[1].0[i], s[2].0[i], s[3].0[i]]);
                self.finish_lane(lane, term.0[i], arena, out);
            }
            g += W;
        }
    }
}

impl VecEnv for AcrobotVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let s = acrobot::reset_state(&mut self.rng[lane]);
        self.scatter(lane, s);
        self.steps[lane] = 0;
        acrobot::write_obs(&s, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        match self.width {
            8 => self.step_lanes::<8>(actions, reset_mask, arena, out),
            4 => self.step_lanes::<4>(actions, reset_mask, arena, out),
            _ => self.step_scalar(actions, reset_mask, arena, out),
        }
    }
}
