//! Struct-of-arrays CartPole batch kernel. Per-lane math and RNG streams
//! are shared with [`crate::envs::classic::cartpole`], making this path
//! bitwise identical to stepping N scalar envs.

use super::{ObsArena, VecEnv};
use crate::envs::classic::cartpole;
use crate::envs::env::{discrete_action, Step};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;

/// SoA batch of CartPole environments.
pub struct CartPoleVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    x: Vec<f32>,
    x_dot: Vec<f32>,
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    steps: Vec<u32>,
}

impl CartPoleVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        CartPoleVec {
            spec: cartpole::spec(),
            rng: (0..count).map(|l| cartpole::rng(seed, first_env_id + l as u64)).collect(),
            x: vec![0.0; count],
            x_dot: vec![0.0; count],
            theta: vec![0.0; count],
            theta_dot: vec![0.0; count],
            steps: vec![0; count],
        }
    }
}

impl VecEnv for CartPoleVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let s = cartpole::reset_state(&mut self.rng[lane]);
        self.x[lane] = s[0];
        self.x_dot[lane] = s[1];
        self.theta[lane] = s[2];
        self.theta_dot[lane] = s[3];
        self.steps[lane] = 0;
        obs[..4].copy_from_slice(&s);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let a = discrete_action(&actions[lane..lane + 1], 2);
            let s = cartpole::dynamics(
                [self.x[lane], self.x_dot[lane], self.theta[lane], self.theta_dot[lane]],
                a,
            );
            self.x[lane] = s[0];
            self.x_dot[lane] = s[1];
            self.theta[lane] = s[2];
            self.theta_dot[lane] = s[3];
            self.steps[lane] += 1;

            let fell = cartpole::fell(&s);
            let truncated = !fell && self.steps[lane] as usize >= cartpole::MAX_STEPS;
            arena.row(lane)[..4].copy_from_slice(&s);
            out[lane] = Step { reward: 1.0, done: fell, truncated };
        }
    }
}
