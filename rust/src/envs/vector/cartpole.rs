//! Struct-of-arrays CartPole batch kernel. Per-lane math and RNG streams
//! are shared with [`crate::envs::classic::cartpole`], making this path
//! bitwise identical to stepping N scalar envs — at every SIMD lane
//! width: the lane pass applies `cartpole::dynamics_lanes`, the same
//! operations in the same order as the scalar `dynamics`, to groups of
//! [`LanePass::width`] environments per instruction, with a masked tail
//! and a masked-reset path (see `tests/simd_parity.rs`).

use super::{ObsArena, VecEnv};
use crate::envs::classic::cartpole;
use crate::envs::env::{discrete_action, Step};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, LanePass};

/// SoA batch of CartPole environments.
pub struct CartPoleVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    x: Vec<f32>,
    x_dot: Vec<f32>,
    theta: Vec<f32>,
    theta_dot: Vec<f32>,
    steps: Vec<u32>,
    /// Resolved SIMD lane width (1 = scalar reference loop).
    width: usize,
}

impl CartPoleVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        CartPoleVec {
            spec: cartpole::spec(),
            rng: (0..count).map(|l| cartpole::rng(seed, first_env_id + l as u64)).collect(),
            x: vec![0.0; count],
            x_dot: vec![0.0; count],
            theta: vec![0.0; count],
            theta_dot: vec![0.0; count],
            steps: vec![0; count],
            // Scalar reference until configured: the wired paths (pool,
            // executors) always call `set_lane_pass`, which is also the
            // single place the `Auto` width (env override + feature
            // detection) resolves — keeping construction infallible.
            width: LanePass::Scalar.width(),
        }
    }

    /// Finish one stepped lane: bookkeeping, flags, observation row.
    #[inline]
    fn finish_lane(&mut self, lane: usize, fell: bool, arena: &mut dyn ObsArena, out: &mut [Step]) {
        self.steps[lane] += 1;
        let truncated = !fell && self.steps[lane] as usize >= cartpole::MAX_STEPS;
        let obs = arena.row(lane);
        obs[0] = self.x[lane];
        obs[1] = self.x_dot[lane];
        obs[2] = self.theta[lane];
        obs[3] = self.theta_dot[lane];
        out[lane] = Step { reward: 1.0, done: fell, truncated };
    }

    /// The scalar reference loop (lane width 1) — the pre-SIMD kernel,
    /// kept verbatim as the parity baseline.
    fn step_scalar(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        for lane in 0..self.num_envs() {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let a = discrete_action(&actions[lane..lane + 1], 2);
            let s = cartpole::dynamics(
                [self.x[lane], self.x_dot[lane], self.theta[lane], self.theta_dot[lane]],
                a,
            );
            self.x[lane] = s[0];
            self.x_dot[lane] = s[1];
            self.theta[lane] = s[2];
            self.theta_dot[lane] = s[3];
            let fell = cartpole::fell(&s);
            self.finish_lane(lane, fell, arena, out);
        }
    }

    /// The SIMD lane pass: groups of `W` lanes per instruction. Lanes
    /// being auto-reset (and tail padding) ride along in the vector
    /// compute but are excluded from the store — the masked-reset /
    /// masked-tail path.
    fn step_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            for lane in g..g + n {
                if reset_mask[lane] != 0 {
                    self.reset_lane(lane, arena.row(lane));
                    out[lane] = Step::default();
                }
            }
            // Load the group (freshly-reset lanes included — their
            // results are discarded below; tail lanes padded with 0,
            // a valid state).
            let state = [
                F32s::<W>::load_or(&self.x[g..g + n], 0.0),
                F32s::<W>::load_or(&self.x_dot[g..g + n], 0.0),
                F32s::<W>::load_or(&self.theta[g..g + n], 0.0),
                F32s::<W>::load_or(&self.theta_dot[g..g + n], 0.0),
            ];
            let force = F32s::<W>::from_fn(|i| {
                let lane = g + i;
                if i < n && reset_mask[lane] == 0 {
                    cartpole::force_for(discrete_action(&actions[lane..lane + 1], 2))
                } else {
                    0.0
                }
            });
            let s = cartpole::dynamics_lanes(state, force);
            let fell = cartpole::fell_lanes(s[0], s[2]);
            // Masked store: only stepped lanes take the new state.
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                self.x[lane] = s[0].0[i];
                self.x_dot[lane] = s[1].0[i];
                self.theta[lane] = s[2].0[i];
                self.theta_dot[lane] = s[3].0[i];
                self.finish_lane(lane, fell.0[i], arena, out);
            }
            g += W;
        }
    }
}

impl VecEnv for CartPoleVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let s = cartpole::reset_state(&mut self.rng[lane]);
        self.x[lane] = s[0];
        self.x_dot[lane] = s[1];
        self.theta[lane] = s[2];
        self.theta_dot[lane] = s[3];
        self.steps[lane] = 0;
        obs[..4].copy_from_slice(&s);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        match self.width {
            8 => self.step_lanes::<8>(actions, reset_mask, arena, out),
            4 => self.step_lanes::<4>(actions, reset_mask, arena, out),
            _ => self.step_scalar(actions, reset_mask, arena, out),
        }
    }
}
