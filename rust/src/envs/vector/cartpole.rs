//! CartPole batch kernel: a [`LaneDynamics`] descriptor over the shared
//! SoA driver ([`super::SoaKernel`]). Per-lane math and RNG streams are
//! shared with [`crate::envs::classic::cartpole`], making this path
//! bitwise identical to stepping N scalar envs — at every SIMD lane
//! width: the lane pass applies `cartpole::dynamics_lanes`, the same
//! operations in the same order as the scalar `dynamics` (see
//! `tests/simd_parity.rs`).

use super::{LaneDynamics, SoaKernel, MAX_PARAMS};
use crate::envs::classic::cartpole;
use crate::envs::env::discrete_action;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, Mask};

/// CartPole's dynamics/terminal/reward rules for the shared driver.
/// Overridable physics (scenario pools): `gravity`, `length` (half pole
/// length), `force_mag` — slots 0..3 of the parameter lanes.
pub struct CartPoleDyn;

impl LaneDynamics<4> for CartPoleDyn {
    fn spec(&self) -> EnvSpec {
        cartpole::spec()
    }

    fn rng_for(&self, seed: u64, env_id: u64) -> Pcg32 {
        cartpole::rng(seed, env_id)
    }

    fn max_steps(&self) -> usize {
        cartpole::MAX_STEPS
    }

    fn reset_state(&self, rng: &mut Pcg32) -> [f32; 4] {
        cartpole::reset_state(rng)
    }

    fn param_names(&self) -> &'static [&'static str] {
        &["gravity", "length", "force_mag"]
    }

    fn default_params(&self) -> [f32; MAX_PARAMS] {
        [cartpole::GRAVITY, cartpole::LENGTH, cartpole::FORCE_MAG, 0.0]
    }

    fn step1(
        &self,
        s: [f32; 4],
        actions: &[f32],
        lane: usize,
        p: &[f32; MAX_PARAMS],
    ) -> ([f32; 4], bool, f32) {
        let a = discrete_action(&actions[lane..lane + 1], 2);
        let s2 = cartpole::dynamics_p(s, cartpole::force_for_p(a, p[2]), p[0], p[1]);
        let fell = cartpole::fell(&s2);
        (s2, fell, 1.0)
    }

    fn input(&self, actions: &[f32], lane: usize) -> f32 {
        // Push *direction*; the lane pass scales by the per-lane
        // `force_mag` (±1.0 · m is an exact sign transfer, so the
        // default is bitwise the old ±FORCE_MAG input).
        if discrete_action(&actions[lane..lane + 1], 2) == 1 {
            1.0
        } else {
            -1.0
        }
    }

    fn step_lanes<const W: usize>(
        &self,
        s: [F32s<W>; 4],
        u: F32s<W>,
        p: &[F32s<W>; MAX_PARAMS],
    ) -> ([F32s<W>; 4], Mask<W>, F32s<W>) {
        let force = u * p[2];
        let s2 = cartpole::dynamics_lanes_p(s, force, p[0], p[1]);
        let fell = cartpole::fell_lanes(s2[0], s2[2]);
        (s2, fell, F32s::splat(1.0))
    }

    fn write_obs(&self, s: &[f32; 4], obs: &mut [f32]) {
        obs[..4].copy_from_slice(s);
    }
}

/// SoA batch of CartPole environments.
pub type CartPoleVec = SoaKernel<4, CartPoleDyn>;

impl SoaKernel<4, CartPoleDyn> {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        SoaKernel::with_dynamics(CartPoleDyn, seed, first_env_id, count)
    }
}
