//! Batched Atari kernel: steps a chunk of emulator lanes in one call
//! and runs the DQN preprocessing as a lane-streaming **SoA pass**
//! straight into [`ObsArena`] rows.
//!
//! CuLE's observation is that the win for Atari comes from batching the
//! *simulator loop itself* — emulator ticks plus preprocessing — not
//! just the transport. [`AtariVec`] owns the lanes' games plus one
//! **contiguous pixel slab** (all native frames and stack rings packed
//! lane-major) and serves a whole chunk per dispatch in three phases:
//!
//! 1. **Emulate** (scalar per lane — data-dependent control flow):
//!    frameskip ticks + native renders via
//!    [`PreprocCore::step_emulate`], recording an [`EmulatePhase`] per
//!    lane in a preallocated scratch row (no per-step allocation).
//! 2. **Pixel pass** (pure lane math, contiguous): 2-frame max-pool,
//!    2×2 max downsample and stack push for every lane back-to-back
//!    via [`PreprocCore::step_finish`] — the slab keeps the pass
//!    streaming through memory with no emulator work interleaved.
//! 3. **Readout**: [`PreprocCore::write_obs`] per lane into its final
//!    destination row (a state-queue slot on the pool path — no
//!    intermediate buffer is ever materialized per step).
//!
//! Preprocessing semantics live in one place —
//! [`PreprocCore`](crate::envs::atari::preproc) — shared verbatim with
//! the scalar [`AtariEnv`](crate::envs::atari::AtariEnv), so this path
//! is **bitwise identical** to stepping `K` scalar envs (pinned by
//! `tests/vector_parity.rs` and the in-file tests). Deferring a lane's
//! pixel phase behind other lanes' emulator phases is safe because the
//! phases share no state: the emulate phase never reads the stack and
//! the pixel phase never touches the game.

use super::{ObsArena, VecEnv};
use crate::envs::atari::game::Game;
use crate::envs::atari::preproc::{spec_for, EmulatePhase, PreprocCore};
use crate::envs::atari::{breakout::Breakout, pong::Pong, NATIVE, SCREEN, STACK};
use crate::envs::env::Step;
use crate::envs::spec::EnvSpec;

/// Bytes of one native frame plane.
const FRAME: usize = NATIVE * NATIVE;
/// Floats of one lane's stack ring.
const RING: usize = STACK * SCREEN * SCREEN;

/// SoA-of-lanes Atari batch: `K` games stepped per dispatch, pixel
/// state packed into contiguous lane-major slabs.
pub struct AtariVec<G: Game> {
    spec: EnvSpec,
    games: Vec<G>,
    ctl: Vec<PreprocCore>,
    /// `[K, NATIVE²]` newest native frames (pooled in place).
    frames_a: Vec<u8>,
    /// `[K, NATIVE²]` previous native frames (flicker pool partner).
    frames_b: Vec<u8>,
    /// `[K, STACK·SCREEN²]` stack rings.
    stacks: Vec<f32>,
    /// Per-dispatch emulate-phase results (`None` marks a reset lane);
    /// preallocated so `step_batch` never allocates.
    phases: Vec<Option<EmulatePhase>>,
}

impl<G: Game> AtariVec<G> {
    /// Batch of `count` envs built by `make`, with global ids
    /// `first_env_id..+count` (RNG streams keyed per id, exactly as the
    /// scalar constructor does).
    pub fn new(
        make: impl Fn() -> G,
        seed: u64,
        first_env_id: u64,
        count: usize,
        episodic_life: bool,
    ) -> Self {
        let games: Vec<G> = (0..count).map(|_| make()).collect();
        let ctl: Vec<PreprocCore> = games
            .iter()
            .enumerate()
            .map(|(l, game)| {
                let mut c = PreprocCore::new(game.n_actions(), seed, first_env_id + l as u64);
                c.set_episodic_life(episodic_life);
                c
            })
            .collect();
        // Derive the spec from lane 0 (a probe instance only for the
        // degenerate empty batch).
        let spec = match games.first() {
            Some(g) => spec_for(g),
            None => spec_for(&make()),
        };
        AtariVec {
            spec,
            games,
            ctl,
            frames_a: vec![0; count * FRAME],
            frames_b: vec![0; count * FRAME],
            stacks: vec![0.0; count * RING],
            phases: vec![None; count],
        }
    }
}

/// Batched `Pong-v5` (same construction flags as `preproc::pong`).
pub fn pong_vec(seed: u64, first_env_id: u64, count: usize) -> AtariVec<Pong> {
    AtariVec::new(Pong::new, seed, first_env_id, count, false)
}

/// Batched `Breakout-v5` (episodic-life on, as `preproc::breakout`).
pub fn breakout_vec(seed: u64, first_env_id: u64, count: usize) -> AtariVec<Breakout> {
    AtariVec::new(Breakout::new, seed, first_env_id, count, true)
}

impl<G: Game> VecEnv for AtariVec<G> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.games.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let fa = &mut self.frames_a[lane * FRAME..(lane + 1) * FRAME];
        let stack = &mut self.stacks[lane * RING..(lane + 1) * RING];
        self.ctl[lane].reset(&mut self.games[lane], fa, stack);
        self.ctl[lane].write_obs(stack, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.games.len();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);

        // Phase 1 — emulator lanes (scalar): ticks + native renders.
        for lane in 0..k {
            let fa = &mut self.frames_a[lane * FRAME..(lane + 1) * FRAME];
            self.phases[lane] = if reset_mask[lane] != 0 {
                self.ctl[lane].reset_emulate(&mut self.games[lane], fa);
                None
            } else {
                let fb = &mut self.frames_b[lane * FRAME..(lane + 1) * FRAME];
                Some(self.ctl[lane].step_emulate(
                    &mut self.games[lane],
                    &actions[lane..lane + 1],
                    fa,
                    fb,
                ))
            };
        }

        // Phase 2 — SoA pixel pass: max-pool + downsample + stack push,
        // streaming through the contiguous slabs.
        for lane in 0..k {
            let fa = &mut self.frames_a[lane * FRAME..(lane + 1) * FRAME];
            let fb = &self.frames_b[lane * FRAME..(lane + 1) * FRAME];
            let stack = &mut self.stacks[lane * RING..(lane + 1) * RING];
            out[lane] = match self.phases[lane] {
                None => {
                    self.ctl[lane].reset_finish(fa, stack);
                    Step::default()
                }
                Some(ph) => self.ctl[lane].step_finish(fa, fb, stack, ph),
            };
        }

        // Phase 3 — stacked readout into the destination rows.
        for lane in 0..k {
            let stack = &self.stacks[lane * RING..(lane + 1) * RING];
            self.ctl[lane].write_obs(stack, arena.row(lane));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::atari::preproc;
    use crate::envs::env::Env;
    use crate::envs::vector::SliceArena;

    #[test]
    fn pong_vec_matches_scalar_env_bitwise() {
        let seed = 9;
        let n = 2;
        let mut vec_env = pong_vec(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let mut scalars: Vec<_> = (0..n).map(|i| preproc::pong(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..25 {
            let actions: Vec<f32> = (0..n).map(|l| ((t + l) % 6) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                let s = env.step(&actions[l..l + 1], &mut sobs);
                assert_eq!(results[l], s, "step {t} lane {l}");
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }

    #[test]
    fn masked_reset_lanes_match_scalar_resets_bitwise() {
        // The phased slab path must keep reset lanes (emulate-half +
        // pixel-half split across the batch phases) bitwise identical
        // to scalar resets, while the other lanes keep stepping.
        let seed = 14;
        let n = 3;
        let mut vec_env = pong_vec(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let mut scalars: Vec<_> = (0..n).map(|i| preproc::pong(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
        }
        let mut results = vec![Step::default(); n];
        for t in 0..20 {
            // Rotate a reset through the lanes every third step.
            let mut mask = vec![0u8; n];
            if t % 3 == 2 {
                mask[t % n] = 1;
            }
            let actions: Vec<f32> = (0..n).map(|l| ((t + 2 * l) % 6) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                if mask[l] != 0 {
                    env.reset(&mut sobs);
                    assert_eq!(results[l], Step::default(), "step {t} lane {l}");
                } else {
                    let s = env.step(&actions[l..l + 1], &mut sobs);
                    assert_eq!(results[l], s, "step {t} lane {l}");
                }
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }

    #[test]
    fn breakout_vec_carries_episodic_life() {
        // Spam FIRE on one lane until a life is lost: the vec path must
        // report done with the game not over, exactly like the scalar
        // episodic-life wrapper.
        let mut v = breakout_vec(3, 0, 1);
        let dim = v.spec().obs_dim();
        let mut obs = vec![0.0f32; dim];
        v.reset_lane(0, &mut obs);
        let mut results = vec![Step::default(); 1];
        let mut mask = vec![0u8; 1];
        for _ in 0..20_000 {
            {
                let mut arena = SliceArena::new(&mut obs, dim);
                v.step_batch(&[1.0], &mask, &mut arena, &mut results);
            }
            if results[0].done {
                assert!(v.games[0].lives() > 0, "episodic life ends before game over");
                return;
            }
            mask[0] = results[0].finished() as u8;
        }
        panic!("life should be lost");
    }
}
