//! Batched Atari kernel: steps a chunk of emulator lanes in one call
//! and runs the DQN preprocessing as a lane-streaming **SoA pass**
//! straight into [`ObsArena`] rows.
//!
//! CuLE's observation is that the win for Atari comes from batching the
//! *simulator loop itself* — emulator ticks plus preprocessing — not
//! just the transport. [`AtariVec`] owns the lanes' SoA game state
//! ([`LaneGame`]) plus one **contiguous pixel slab** (all native frames
//! and stack rings packed lane-major) and serves a whole chunk per
//! dispatch in three phases:
//!
//! 1. **Emulate** (batched): the frameskip loop runs as masked
//!    lane-group tick passes over the SoA game state
//!    ([`step_emulate_batch`] /
//!    [`LaneGame::tick_pass`]) at the configured
//!    [`LanePass`] width, recording an [`EmulatePhase`] per lane in a
//!    preallocated scratch row (no per-step allocation). Reset lanes
//!    take the scalar per-lane reset path first and sit out the pass.
//! 2. **Pixel pass** (pure lane math, contiguous): 2-frame max-pool,
//!    2×2 max downsample and stack push for every lane back-to-back
//!    via [`PreprocCore::step_finish`] — the slab keeps the pass
//!    streaming through memory with no emulator work interleaved.
//! 3. **Readout**: [`PreprocCore::write_obs`] per lane into its final
//!    destination row (a state-queue slot on the pool path — no
//!    intermediate buffer is ever materialized per step).
//!
//! Preprocessing semantics live in one place —
//! [`PreprocCore`](crate::envs::atari::preproc) — shared verbatim with
//! the scalar [`AtariEnv`](crate::envs::atari::AtariEnv), and the lane
//! passes are bitwise twins of the scalar games **at every width**
//! (see `atari_emulate`), so this path is **bitwise identical** to
//! stepping `K` scalar envs (pinned by `tests/vector_parity.rs`,
//! `tests/atari_emulate_parity.rs` and the in-file tests).

use super::atari_emulate::{step_emulate_batch, BreakoutLanes, EmulateScratch, LaneGame, PongLanes};
use super::{ObsArena, VecEnv};
use crate::envs::atari::preproc::{game_rng, spec_for_parts, EmulatePhase, PreprocCore};
use crate::envs::atari::{NATIVE, SCREEN, STACK};
use crate::envs::env::Step;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::LanePass;

/// Bytes of one native frame plane.
const FRAME: usize = NATIVE * NATIVE;
/// Floats of one lane's stack ring.
const RING: usize = STACK * SCREEN * SCREEN;

/// SoA-of-lanes Atari batch: `K` games stepped per dispatch through
/// masked lane-group tick passes, pixel state packed into contiguous
/// lane-major slabs.
pub struct AtariVec<L: LaneGame> {
    spec: EnvSpec,
    pub(crate) lanes: L,
    /// Per-lane game RNG streams (keyed by env id exactly as the scalar
    /// constructor does — see [`game_rng`]).
    rngs: Vec<Pcg32>,
    ctl: Vec<PreprocCore>,
    /// `[K, NATIVE²]` newest native frames (pooled in place).
    frames_a: Vec<u8>,
    /// `[K, NATIVE²]` previous native frames (flicker pool partner).
    frames_b: Vec<u8>,
    /// `[K, STACK·SCREEN²]` stack rings.
    stacks: Vec<f32>,
    /// Per-dispatch emulate-phase results (`None` marks a reset lane);
    /// preallocated so `step_batch` never allocates.
    phases: Vec<Option<EmulatePhase>>,
    scratch: EmulateScratch,
    /// Lane-group width for the emulator tick passes (bitwise identical
    /// at every width; see `atari_emulate`).
    width: usize,
}

impl<L: LaneGame> AtariVec<L> {
    /// Batch over `lanes`, with global ids `first_env_id..+count` (RNG
    /// streams keyed per id, exactly as the scalar constructor does).
    pub fn new(lanes: L, seed: u64, first_env_id: u64, episodic_life: bool) -> Self {
        let count = lanes.count();
        let rngs: Vec<Pcg32> =
            (0..count).map(|l| game_rng(seed, first_env_id + l as u64)).collect();
        let ctl: Vec<PreprocCore> = (0..count)
            .map(|_| {
                let mut c = PreprocCore::new(lanes.n_actions());
                c.set_episodic_life(episodic_life);
                c
            })
            .collect();
        let spec = spec_for_parts(lanes.name(), lanes.n_actions());
        AtariVec {
            spec,
            lanes,
            rngs,
            ctl,
            frames_a: vec![0; count * FRAME],
            frames_b: vec![0; count * FRAME],
            stacks: vec![0.0; count * RING],
            phases: vec![None; count],
            scratch: EmulateScratch::new(count),
            width: LanePass::Scalar.width(),
        }
    }

    /// Emulator half of a reset for one lane: full game reset only when
    /// the episodic-life continuation doesn't apply (the batched twin
    /// of [`PreprocCore::reset_emulate`], same predicate, same single
    /// RNG draw), then the first native render into the slab.
    fn reset_emulate_lane(&mut self, lane: usize) {
        if self.ctl[lane].reset_wants_full(self.lanes.lives(lane)) {
            self.lanes.reset_lane(lane, &mut self.rngs[lane]);
        }
        self.ctl[lane].begin_episode(self.lanes.lives(lane));
        self.lanes.render_lane(lane, &mut self.frames_a[lane * FRAME..(lane + 1) * FRAME]);
    }

    /// The batched emulator phase at one monomorphized width.
    fn emulate_batch<const W: usize>(&mut self, actions: &[f32]) {
        step_emulate_batch::<L, W>(
            &mut self.lanes,
            &mut self.rngs,
            actions,
            &mut self.scratch,
            &mut self.frames_a,
            &mut self.frames_b,
            &mut self.phases,
        );
    }
}

/// Batched `Pong-v5` (same construction flags as `preproc::pong`).
pub fn pong_vec(seed: u64, first_env_id: u64, count: usize) -> AtariVec<PongLanes> {
    AtariVec::new(PongLanes::new(count), seed, first_env_id, false)
}

/// Batched `Breakout-v5` (episodic-life on, as `preproc::breakout`).
pub fn breakout_vec(seed: u64, first_env_id: u64, count: usize) -> AtariVec<BreakoutLanes> {
    AtariVec::new(BreakoutLanes::new(count), seed, first_env_id, true)
}

impl<L: LaneGame> VecEnv for AtariVec<L> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.lanes.count()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.reset_emulate_lane(lane);
        let fa = &self.frames_a[lane * FRAME..(lane + 1) * FRAME];
        let stack = &mut self.stacks[lane * RING..(lane + 1) * RING];
        self.ctl[lane].reset_finish(fa, stack);
        self.ctl[lane].write_obs(stack, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.lanes.count();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);

        // Phase 1 — emulator. Reset lanes take the scalar per-lane
        // reset path (rare, data-dependent, one RNG draw) and sit out
        // the pass; everyone else goes through the batched frameskip
        // driver at the configured lane-group width.
        for lane in 0..k {
            self.scratch.skip[lane] = (reset_mask[lane] == 0) as u8;
            if reset_mask[lane] != 0 {
                self.phases[lane] = None;
                self.reset_emulate_lane(lane);
            }
        }
        match self.width {
            8 => self.emulate_batch::<8>(actions),
            4 => self.emulate_batch::<4>(actions),
            _ => self.emulate_batch::<1>(actions),
        }

        // Phase 2 — SoA pixel pass: max-pool + downsample + stack push,
        // streaming through the contiguous slabs.
        for lane in 0..k {
            let fa = &mut self.frames_a[lane * FRAME..(lane + 1) * FRAME];
            let fb = &self.frames_b[lane * FRAME..(lane + 1) * FRAME];
            let stack = &mut self.stacks[lane * RING..(lane + 1) * RING];
            out[lane] = match self.phases[lane] {
                None => {
                    self.ctl[lane].reset_finish(fa, stack);
                    Step::default()
                }
                Some(ph) => self.ctl[lane].step_finish(fa, fb, stack, ph),
            };
        }

        // Phase 3 — stacked readout into the destination rows.
        for lane in 0..k {
            let stack = &self.stacks[lane * RING..(lane + 1) * RING];
            self.ctl[lane].write_obs(stack, arena.row(lane));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::atari::preproc;
    use crate::envs::env::Env;
    use crate::envs::vector::SliceArena;

    #[test]
    fn pong_vec_matches_scalar_env_bitwise() {
        let seed = 9;
        let n = 2;
        let mut vec_env = pong_vec(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let mut scalars: Vec<_> = (0..n).map(|i| preproc::pong(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..25 {
            let actions: Vec<f32> = (0..n).map(|l| ((t + l) % 6) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                let s = env.step(&actions[l..l + 1], &mut sobs);
                assert_eq!(results[l], s, "step {t} lane {l}");
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }

    #[test]
    fn pong_vec_bitwise_at_every_lane_width() {
        // The emulator lane pass must not change a single bit across
        // widths: run the same action tape at widths 1/4/8 and compare
        // rewards/dones/obs bit for bit.
        let run = |lp: LanePass| {
            let n = 5;
            let mut v = pong_vec(17, 0, n);
            v.set_lane_pass(lp);
            let dim = v.spec().obs_dim();
            let mut obs = vec![0.0f32; n * dim];
            for l in 0..n {
                let row = &mut obs[l * dim..(l + 1) * dim];
                v.reset_lane(l, row);
            }
            let mask = vec![0u8; n];
            let mut results = vec![Step::default(); n];
            let mut sig: Vec<u32> = Vec::new();
            for t in 0..40 {
                let actions: Vec<f32> = (0..n).map(|l| ((t + 2 * l) % 6) as f32).collect();
                let mut arena = SliceArena::new(&mut obs, dim);
                v.step_batch(&actions, &mask, &mut arena, &mut results);
                drop(arena);
                for r in &results {
                    sig.push(r.reward.to_bits());
                    sig.push(r.done as u32);
                }
                sig.push(obs[dim / 2].to_bits());
                sig.push(obs[3 * dim + 7].to_bits());
            }
            sig
        };
        let w1 = run(LanePass::Scalar);
        assert_eq!(w1, run(LanePass::Width4), "width 4 diverged from width 1");
        assert_eq!(w1, run(LanePass::Width8), "width 8 diverged from width 1");
    }

    #[test]
    fn masked_reset_lanes_match_scalar_resets_bitwise() {
        // The phased slab path must keep reset lanes (emulate-half +
        // pixel-half split across the batch phases) bitwise identical
        // to scalar resets, while the other lanes keep stepping.
        let seed = 14;
        let n = 3;
        let mut vec_env = pong_vec(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let mut scalars: Vec<_> = (0..n).map(|i| preproc::pong(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
        }
        let mut results = vec![Step::default(); n];
        for t in 0..20 {
            // Rotate a reset through the lanes every third step.
            let mut mask = vec![0u8; n];
            if t % 3 == 2 {
                mask[t % n] = 1;
            }
            let actions: Vec<f32> = (0..n).map(|l| ((t + 2 * l) % 6) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                if mask[l] != 0 {
                    env.reset(&mut sobs);
                    assert_eq!(results[l], Step::default(), "step {t} lane {l}");
                } else {
                    let s = env.step(&actions[l..l + 1], &mut sobs);
                    assert_eq!(results[l], s, "step {t} lane {l}");
                }
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }

    #[test]
    fn breakout_vec_carries_episodic_life() {
        // Spam FIRE on one lane until a life is lost: the vec path must
        // report done with the game not over, exactly like the scalar
        // episodic-life wrapper.
        let mut v = breakout_vec(3, 0, 1);
        let dim = v.spec().obs_dim();
        let mut obs = vec![0.0f32; dim];
        v.reset_lane(0, &mut obs);
        let mut results = vec![Step::default(); 1];
        let mut mask = vec![0u8; 1];
        for _ in 0..20_000 {
            {
                let mut arena = SliceArena::new(&mut obs, dim);
                v.step_batch(&[1.0], &mask, &mut arena, &mut results);
            }
            if results[0].done {
                assert!(v.lanes.lives(0) > 0, "episodic life ends before game over");
                return;
            }
            mask[0] = results[0].finished() as u8;
        }
        panic!("life should be lost");
    }
}
