//! Batched Atari kernel: steps a chunk of emulator lanes in one call
//! and runs the DQN preprocessing per lane straight into [`ObsArena`]
//! rows.
//!
//! CuLE's observation is that the win for Atari comes from batching the
//! *simulator loop itself* — emulator ticks plus preprocessing — not
//! just the transport. [`AtariVec`] owns a lane of `(game, preproc)`
//! pairs and serves a whole chunk per dispatch: one task dequeue, one
//! wakeup, and one virtual call cover `K` envs' frameskip loops, and
//! each lane's stacked `(4, 84, 84)` observation is written directly
//! into its final destination row (a state-queue slot on the pool path
//! — no intermediate frame buffer is ever materialized per step).
//!
//! Preprocessing semantics live in one place —
//! [`PreprocState`](crate::envs::atari::preproc) — shared verbatim with
//! the scalar [`AtariEnv`](crate::envs::atari::AtariEnv), so this path
//! is **bitwise identical** to stepping `K` scalar envs (pinned by
//! `tests/vector_parity.rs`).

use super::{ObsArena, VecEnv};
use crate::envs::atari::game::Game;
use crate::envs::atari::preproc::{spec_for, PreprocState};
use crate::envs::atari::{breakout::Breakout, pong::Pong};
use crate::envs::env::Step;
use crate::envs::spec::EnvSpec;

/// One emulator lane: game state + its preprocessing state machine.
struct Lane<G: Game> {
    game: G,
    st: PreprocState,
}

/// SoA-of-lanes Atari batch: `K` games stepped per dispatch.
pub struct AtariVec<G: Game> {
    spec: EnvSpec,
    lanes: Vec<Lane<G>>,
}

impl<G: Game> AtariVec<G> {
    /// Batch of `count` envs built by `make`, with global ids
    /// `first_env_id..+count` (RNG streams keyed per id, exactly as the
    /// scalar constructor does).
    pub fn new(
        make: impl Fn() -> G,
        seed: u64,
        first_env_id: u64,
        count: usize,
        episodic_life: bool,
    ) -> Self {
        let lanes: Vec<Lane<G>> = (0..count)
            .map(|l| {
                let game = make();
                let mut st = PreprocState::new(game.n_actions(), seed, first_env_id + l as u64);
                st.set_episodic_life(episodic_life);
                Lane { game, st }
            })
            .collect();
        // Derive the spec from lane 0 (a probe instance only for the
        // degenerate empty batch).
        let spec = match lanes.first() {
            Some(l) => spec_for(&l.game),
            None => spec_for(&make()),
        };
        AtariVec { spec, lanes }
    }
}

/// Batched `Pong-v5` (same construction flags as `preproc::pong`).
pub fn pong_vec(seed: u64, first_env_id: u64, count: usize) -> AtariVec<Pong> {
    AtariVec::new(Pong::new, seed, first_env_id, count, false)
}

/// Batched `Breakout-v5` (episodic-life on, as `preproc::breakout`).
pub fn breakout_vec(seed: u64, first_env_id: u64, count: usize) -> AtariVec<Breakout> {
    AtariVec::new(Breakout::new, seed, first_env_id, count, true)
}

impl<G: Game> VecEnv for AtariVec<G> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.lanes.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let l = &mut self.lanes[lane];
        l.st.reset(&mut l.game);
        l.st.write_obs(obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.lanes.len();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        for (lane, l) in self.lanes.iter_mut().enumerate() {
            if reset_mask[lane] != 0 {
                l.st.reset(&mut l.game);
                l.st.write_obs(arena.row(lane));
                out[lane] = Step::default();
            } else {
                out[lane] = l.st.step(&mut l.game, &actions[lane..lane + 1]);
                l.st.write_obs(arena.row(lane));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::atari::preproc;
    use crate::envs::env::Env;
    use crate::envs::vector::SliceArena;

    #[test]
    fn pong_vec_matches_scalar_env_bitwise() {
        let seed = 9;
        let n = 2;
        let mut vec_env = pong_vec(seed, 0, n);
        let dim = vec_env.spec().obs_dim();
        let mut scalars: Vec<_> = (0..n).map(|i| preproc::pong(seed, i as u64)).collect();
        let mut vobs = vec![0.0f32; n * dim];
        let mut sobs = vec![0.0f32; dim];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "reset lane {l}");
        }
        let mask = vec![0u8; n];
        let mut results = vec![Step::default(); n];
        for t in 0..25 {
            let actions: Vec<f32> = (0..n).map(|l| ((t + l) % 6) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut results);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                let s = env.step(&actions[l..l + 1], &mut sobs);
                assert_eq!(results[l], s, "step {t} lane {l}");
                assert_eq!(&vobs[l * dim..(l + 1) * dim], &sobs[..], "obs {t} lane {l}");
            }
        }
    }

    #[test]
    fn breakout_vec_carries_episodic_life() {
        // Spam FIRE on one lane until a life is lost: the vec path must
        // report done with the game not over, exactly like the scalar
        // episodic-life wrapper.
        let mut v = breakout_vec(3, 0, 1);
        let dim = v.spec().obs_dim();
        let mut obs = vec![0.0f32; dim];
        v.reset_lane(0, &mut obs);
        let mut results = vec![Step::default(); 1];
        let mut mask = vec![0u8; 1];
        for _ in 0..20_000 {
            {
                let mut arena = SliceArena::new(&mut obs, dim);
                v.step_batch(&[1.0], &mask, &mut arena, &mut results);
            }
            if results[0].done {
                assert!(v.lanes[0].game.lives() > 0, "episodic life ends before game over");
                return;
            }
            mask[0] = results[0].finished() as u8;
        }
        panic!("life should be lost");
    }
}
