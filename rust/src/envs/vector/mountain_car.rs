//! MountainCar batch kernel: a [`LaneDynamics`] descriptor over the
//! shared SoA driver ([`super::SoaKernel`]). Math and RNG streams are
//! shared with [`crate::envs::classic::mountain_car`]; bitwise identical
//! to the scalar env at every lane width.

use super::{LaneDynamics, SoaKernel, MAX_PARAMS};
use crate::envs::classic::mountain_car;
use crate::envs::env::discrete_action;
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, Mask};

/// MountainCar's dynamics/terminal/reward rules for the shared driver.
/// State lanes are `[pos, vel]`. Overridable physics (scenario pools):
/// `force` (push strength), `gravity`.
pub struct MountainCarDyn;

impl LaneDynamics<2> for MountainCarDyn {
    fn spec(&self) -> EnvSpec {
        mountain_car::spec()
    }

    fn rng_for(&self, seed: u64, env_id: u64) -> Pcg32 {
        mountain_car::rng(seed, env_id)
    }

    fn max_steps(&self) -> usize {
        mountain_car::MAX_STEPS
    }

    fn reset_state(&self, rng: &mut Pcg32) -> [f32; 2] {
        [mountain_car::reset_pos(rng), 0.0]
    }

    fn param_names(&self) -> &'static [&'static str] {
        &["force", "gravity"]
    }

    fn default_params(&self) -> [f32; MAX_PARAMS] {
        [mountain_car::FORCE, mountain_car::GRAVITY, 0.0, 0.0]
    }

    fn step1(
        &self,
        s: [f32; 2],
        actions: &[f32],
        lane: usize,
        p: &[f32; MAX_PARAMS],
    ) -> ([f32; 2], bool, f32) {
        let a = discrete_action(&actions[lane..lane + 1], 3);
        let (pos, vel) = mountain_car::dynamics_p(s[0], s[1], a, p[0], p[1]);
        ([pos, vel], mountain_car::at_goal(pos), -1.0)
    }

    fn input(&self, actions: &[f32], lane: usize) -> f32 {
        discrete_action(&actions[lane..lane + 1], 3) as f32 - 1.0
    }

    fn step_lanes<const W: usize>(
        &self,
        s: [F32s<W>; 2],
        u: F32s<W>,
        p: &[F32s<W>; MAX_PARAMS],
    ) -> ([F32s<W>; 2], Mask<W>, F32s<W>) {
        let (pos, vel) = mountain_car::dynamics_lanes_p(s[0], s[1], u, p[0], p[1]);
        let goal = mountain_car::at_goal_lanes(pos);
        ([pos, vel], goal, F32s::splat(-1.0))
    }

    fn write_obs(&self, s: &[f32; 2], obs: &mut [f32]) {
        obs[0] = s[0];
        obs[1] = s[1];
    }
}

/// SoA batch of MountainCar environments.
pub type MountainCarVec = SoaKernel<2, MountainCarDyn>;

impl SoaKernel<2, MountainCarDyn> {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        SoaKernel::with_dynamics(MountainCarDyn, seed, first_env_id, count)
    }
}
