//! Struct-of-arrays MountainCar batch kernel (math and RNG streams
//! shared with [`crate::envs::classic::mountain_car`]).

use super::{ObsArena, VecEnv};
use crate::envs::classic::mountain_car;
use crate::envs::env::{discrete_action, Step};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;

/// SoA batch of MountainCar environments.
pub struct MountainCarVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    pos: Vec<f32>,
    vel: Vec<f32>,
    steps: Vec<u32>,
}

impl MountainCarVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        MountainCarVec {
            spec: mountain_car::spec(),
            rng: (0..count).map(|l| mountain_car::rng(seed, first_env_id + l as u64)).collect(),
            pos: vec![0.0; count],
            vel: vec![0.0; count],
            steps: vec![0; count],
        }
    }
}

impl VecEnv for MountainCarVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.pos[lane] = mountain_car::reset_pos(&mut self.rng[lane]);
        self.vel[lane] = 0.0;
        self.steps[lane] = 0;
        obs[0] = self.pos[lane];
        obs[1] = self.vel[lane];
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        for lane in 0..k {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let a = discrete_action(&actions[lane..lane + 1], 3);
            let (pos, vel) = mountain_car::dynamics(self.pos[lane], self.vel[lane], a);
            self.pos[lane] = pos;
            self.vel[lane] = vel;
            self.steps[lane] += 1;

            let done = mountain_car::at_goal(pos);
            let truncated = !done && self.steps[lane] as usize >= mountain_car::MAX_STEPS;
            let obs = arena.row(lane);
            obs[0] = pos;
            obs[1] = vel;
            out[lane] = Step { reward: -1.0, done, truncated };
        }
    }
}
