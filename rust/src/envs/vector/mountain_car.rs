//! Struct-of-arrays MountainCar batch kernel (math and RNG streams
//! shared with [`crate::envs::classic::mountain_car`]; the SIMD lane
//! pass applies `dynamics_lanes`, bitwise identical to the scalar
//! reference at every lane width).

use super::{ObsArena, VecEnv};
use crate::envs::classic::mountain_car;
use crate::envs::env::{discrete_action, Step};
use crate::envs::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, LanePass};

/// SoA batch of MountainCar environments.
pub struct MountainCarVec {
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    pos: Vec<f32>,
    vel: Vec<f32>,
    steps: Vec<u32>,
    /// Resolved SIMD lane width (1 = scalar reference loop).
    width: usize,
}

impl MountainCarVec {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn new(seed: u64, first_env_id: u64, count: usize) -> Self {
        MountainCarVec {
            spec: mountain_car::spec(),
            rng: (0..count).map(|l| mountain_car::rng(seed, first_env_id + l as u64)).collect(),
            pos: vec![0.0; count],
            vel: vec![0.0; count],
            steps: vec![0; count],
            // Scalar reference until configured: the wired paths (pool,
            // executors) always call `set_lane_pass`, which is also the
            // single place the `Auto` width (env override + feature
            // detection) resolves — keeping construction infallible.
            width: LanePass::Scalar.width(),
        }
    }

    /// Finish one stepped lane: bookkeeping, flags, observation row.
    #[inline]
    fn finish_lane(&mut self, lane: usize, done: bool, arena: &mut dyn ObsArena, out: &mut [Step]) {
        self.steps[lane] += 1;
        let truncated = !done && self.steps[lane] as usize >= mountain_car::MAX_STEPS;
        let obs = arena.row(lane);
        obs[0] = self.pos[lane];
        obs[1] = self.vel[lane];
        out[lane] = Step { reward: -1.0, done, truncated };
    }

    /// The scalar reference loop (lane width 1).
    fn step_scalar(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        for lane in 0..self.num_envs() {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let a = discrete_action(&actions[lane..lane + 1], 3);
            let (pos, vel) = mountain_car::dynamics(self.pos[lane], self.vel[lane], a);
            self.pos[lane] = pos;
            self.vel[lane] = vel;
            let done = mountain_car::at_goal(pos);
            self.finish_lane(lane, done, arena, out);
        }
    }

    /// The SIMD lane pass (masked tail + masked resets, same structure
    /// as the CartPole kernel — see the module docs in [`super`]).
    fn step_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            for lane in g..g + n {
                if reset_mask[lane] != 0 {
                    self.reset_lane(lane, arena.row(lane));
                    out[lane] = Step::default();
                }
            }
            let pos = F32s::<W>::load_or(&self.pos[g..g + n], 0.0);
            let vel = F32s::<W>::load_or(&self.vel[g..g + n], 0.0);
            let accel = F32s::<W>::from_fn(|i| {
                let lane = g + i;
                if i < n && reset_mask[lane] == 0 {
                    discrete_action(&actions[lane..lane + 1], 3) as f32 - 1.0
                } else {
                    0.0
                }
            });
            let (np, nv) = mountain_car::dynamics_lanes(pos, vel, accel);
            let goal = mountain_car::at_goal_lanes(np);
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                self.pos[lane] = np.0[i];
                self.vel[lane] = nv.0[i];
                self.finish_lane(lane, goal.0[i], arena, out);
            }
            g += W;
        }
    }
}

impl VecEnv for MountainCarVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.pos[lane] = mountain_car::reset_pos(&mut self.rng[lane]);
        self.vel[lane] = 0.0;
        self.steps[lane] = 0;
        obs[0] = self.pos[lane];
        obs[1] = self.vel[lane];
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k);
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        match self.width {
            8 => self.step_lanes::<8>(actions, reset_mask, arena, out),
            4 => self.step_lanes::<4>(actions, reset_mask, arena, out),
            _ => self.step_scalar(actions, reset_mask, arena, out),
        }
    }
}
