//! Batched Atari emulation: SoA game state with masked lane-group tick
//! passes (the CuLE construction — see PAPERS.md).
//!
//! The scalar [`Game`](crate::envs::atari::game::Game) impls advance
//! one lane per call through data-dependent branches. This module holds
//! the *same* game state laid out struct-of-arrays — one `[lane]` array
//! per field — and advances a whole lane group per native frame with
//! branches converted to [`Mask`] selects over [`F32s<W>`](F32s):
//!
//! - every f32 update is the identical per-lane scalar operation (add,
//!   mul, `clamp`, `abs`, `signum`, compare), applied through a select
//!   so untaken lanes keep their old bits — **bitwise identical to the
//!   scalar tick at every width**, a stronger contract than classic
//!   control's because there are no cross-lane reductions or trig;
//! - RNG draws (serves) and integer/bitset updates (scores, bricks,
//!   lives, serve timers) stay scalar *per lane, in lane order*.
//!   Streams can't interleave across lanes anyway: each lane owns an
//!   independent `Pcg32` keyed by env id (see
//!   [`game_rng`](crate::envs::atari::preproc::game_rng)).
//!
//! [`step_emulate_batch`] drives [`LaneGame::tick_pass`] through the
//! frameskip loop with the exact reward/done/render/pool bookkeeping of
//! the scalar [`PreprocCore::step_emulate`], rasterizing into the
//! caller's lane-major native-frame slabs via the shared
//! [`render`](crate::envs::atari::render) primitives. `LanePass` /
//! `ENVPOOL_LANE_WIDTH` select the width exactly as for classic
//! control; the scalar games remain the reference implementation
//! (width 1 is the `ScalarVec`-style view), pinned by the in-file
//! tests and `tests/atari_emulate_parity.rs`.

use crate::envs::atari::preproc::EmulatePhase;
use crate::envs::atari::{breakout, pong, render, FRAMESKIP, NATIVE};
use crate::envs::env::discrete_action;
use crate::rng::Pcg32;
use crate::simd::{F32s, Mask};

/// Bytes of one native frame plane.
const FRAME: usize = NATIVE * NATIVE;

/// One game's state for a whole batch of lanes, advanced a lane group
/// at a time. Implementations must be bitwise twins of the scalar
/// [`Game`](crate::envs::atari::game::Game): same state transitions,
/// same RNG draw order per lane, same rasterization.
pub trait LaneGame: Send {
    /// Number of lanes held.
    fn count(&self) -> usize;

    /// Discrete (minimal) action count — matches the scalar game.
    fn n_actions(&self) -> usize;

    /// Task id suffix, e.g. `"Pong"`.
    fn name(&self) -> &'static str;

    /// Full game reset of one lane (the scalar `Game::reset` twin).
    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg32);

    /// Remaining lives of one lane (1 if the game has no life system).
    fn lives(&self, lane: usize) -> u32;

    /// Rasterize one lane's screen (the scalar `Game::render` twin).
    fn render_lane(&self, lane: usize, frame: &mut [u8]);

    /// Advance every lane with `step[lane] != 0` by one native frame.
    /// Writes per-lane reward/done for stepped lanes (untouched
    /// otherwise). `W` is the lane-group width; results are bitwise
    /// identical at every width.
    fn tick_pass<const W: usize>(
        &mut self,
        actions: &[usize],
        step: &[u8],
        rngs: &mut [Pcg32],
        reward: &mut [f32],
        done: &mut [u8],
    );
}

/// Masked store: lane `i` of `v` is written to `dst[i]` iff the mask
/// lane is set — the store-side half of branch→select conversion.
#[inline(always)]
fn store_masked<const W: usize>(dst: &mut [f32], v: F32s<W>, m: Mask<W>, n: usize) {
    for i in 0..n {
        if m.0[i] {
            dst[i] = v.0[i];
        }
    }
}

// ---------------------------------------------------------------------------
// Pong lanes
// ---------------------------------------------------------------------------

/// SoA [`Pong`](crate::envs::atari::pong::Pong): one array per scalar
/// field, `[lane]` indexed.
pub struct PongLanes {
    count: usize,
    ball_x: Vec<f32>,
    ball_y: Vec<f32>,
    vx: Vec<f32>,
    vy: Vec<f32>,
    left_y: Vec<f32>,
    right_y: Vec<f32>,
    score_left: Vec<u32>,
    score_right: Vec<u32>,
    serve_timer: Vec<u32>,
    serving_right: Vec<bool>,
    over: Vec<bool>,
}

impl PongLanes {
    /// `count` lanes, each in the scalar `Pong::new()` state.
    pub fn new(count: usize) -> Self {
        PongLanes {
            count,
            ball_x: vec![84.0; count],
            ball_y: vec![84.0; count],
            vx: vec![0.0; count],
            vy: vec![0.0; count],
            left_y: vec![84.0; count],
            right_y: vec![84.0; count],
            score_left: vec![0; count],
            score_right: vec![0; count],
            serve_timer: vec![pong::SERVE_DELAY; count],
            serving_right: vec![true; count],
            over: vec![false; count],
        }
    }

    /// One lane group (`W` lanes from `g`, `n` valid) of the scalar
    /// `Pong::tick`, branches as selects. Kept in one function so the
    /// statement order mirrors the scalar code line for line.
    #[allow(clippy::too_many_arguments)]
    fn tick_group<const W: usize>(
        &mut self,
        g: usize,
        n: usize,
        actions: &[usize],
        step: &[u8],
        rngs: &mut [Pcg32],
        reward: &mut [f32],
        done: &mut [u8],
    ) {
        let nf = NATIVE as f32;
        let half = pong::PADDLE_H / 2.0;

        // Over lanes are the scalar early return: (0.0, true), state
        // untouched. Everything below is masked by `active`.
        let active =
            Mask::<W>::from_fn(|i| i < n && step[g + i] != 0 && !self.over[g + i]);

        // Agent paddle: UP = 2/4, DOWN = 3/5.
        let dy = F32s::<W>::from_fn(|i| {
            if i < n {
                match actions[g + i] {
                    2 | 4 => -pong::PADDLE_SPEED,
                    3 | 5 => pong::PADDLE_SPEED,
                    _ => 0.0,
                }
            } else {
                0.0
            }
        });
        let right0 = F32s::<W>::load_or(&self.right_y[g..g + n], 84.0);
        let right_y = active.select_f32((right0 + dy).clamp(half, nf - half), right0);
        store_masked(&mut self.right_y[g..g + n], right_y, active, n);

        // AI paddle tracks the ball with capped speed + deadzone.
        let bally = F32s::<W>::load_or(&self.ball_y[g..g + n], 84.0);
        let left0 = F32s::<W>::load_or(&self.left_y[g..g + n], 84.0);
        let diff = bally - left0;
        let tracked =
            (left0 + diff.signum() * F32s::splat(pong::AI_SPEED)).clamp(half, nf - half);
        let ai_move = active & diff.abs().gt(F32s::splat(2.0));
        let left_y = ai_move.select_f32(tracked, left0);
        store_masked(&mut self.left_y[g..g + n], left_y, ai_move, n);

        // Serve pause: integer timers + RNG draws stay per lane, in
        // lane order (each lane's stream is independent, so grouping
        // cannot reorder draws within a lane).
        let mut pause = [false; W];
        for i in 0..n {
            let l = g + i;
            if active.0[i] && self.serve_timer[l] > 0 {
                pause[i] = true;
                self.serve_timer[l] -= 1;
                if self.serve_timer[l] == 0 {
                    // Scalar `serve()`: two draws, then direction by server.
                    self.ball_x[l] = nf / 2.0;
                    self.ball_y[l] = rngs[l].range(40.0, nf - 40.0);
                    let dir = if self.serving_right[l] { 1.0 } else { -1.0 };
                    self.vx[l] = dir * 2.2;
                    self.vy[l] = rngs[l].range(-1.8, 1.8);
                }
            }
        }
        let play = active & !Mask(pause);

        // Ball advance (serve writes above only touched paused lanes,
        // which `play` masks out — loads here serve the play lanes).
        let bx0 = F32s::<W>::load_or(&self.ball_x[g..g + n], 84.0);
        let vx0 = F32s::<W>::load_or(&self.vx[g..g + n], 0.0);
        let vy0 = F32s::<W>::load_or(&self.vy[g..g + n], 0.0);
        let bx = bx0 + vx0;
        let mut by = bally + vy0;
        let mut vy = vy0;

        // Wall bounces (exclusive if / else-if: `hi` is evaluated on
        // the post-`lo` ball like the scalar else-branch, and the two
        // can't both fire).
        let lo = by.lt(F32s::splat(pong::BALL / 2.0));
        by = lo.select_f32(F32s::splat(pong::BALL / 2.0), by);
        vy = lo.select_f32(vy.abs(), vy);
        let hi = by.gt(F32s::splat(nf - pong::BALL / 2.0));
        by = hi.select_f32(F32s::splat(nf - pong::BALL / 2.0), by);
        vy = hi.select_f32(-vy.abs(), vy);

        // Paddle collisions: `Rect::intersects` inlined, the vx-sign
        // guards make the two arms mutually exclusive exactly as the
        // scalar else-if does.
        let two = F32s::splat(2.0);
        let wsum = F32s::splat(pong::BALL + pong::PADDLE_W);
        let hsum = F32s::splat(pong::BALL + pong::PADDLE_H);
        let int_l = ((bx - F32s::splat(10.0)).abs() * two).lt(wsum)
            & ((by - left_y).abs() * two).lt(hsum);
        let int_r = ((bx - F32s::splat(nf - 10.0)).abs() * two).lt(wsum)
            & ((by - right_y).abs() * two).lt(hsum);
        let hit_l = vx0.lt(F32s::splat(0.0)) & int_l;
        let hit_r = vx0.gt(F32s::splat(0.0)) & int_r;
        // Reflect with rally speed-up, english by contact offset (the
        // operation order matches the scalar `/ half * 1.2` exactly —
        // f32 is not associative, so no algebraic rearranging).
        let vx_hit = -vx0 * F32s::splat(1.03);
        let vy_l = vy + (by - left_y) / F32s::splat(half) * F32s::splat(1.2);
        let vy_r = vy + (by - right_y) / F32s::splat(half) * F32s::splat(1.2);
        let mut vx = (hit_l | hit_r).select_f32(vx_hit, vx0);
        vy = hit_l.select_f32(vy_l, hit_r.select_f32(vy_r, vy));
        vx = vx.clamp(-6.0, 6.0);
        vy = vy.clamp(-4.0, 4.0);

        // Store + scoring (integer) + outputs, per lane.
        for i in 0..n {
            let l = g + i;
            let mut rew = 0.0;
            if play.0[i] {
                self.ball_x[l] = bx.0[i];
                self.ball_y[l] = by.0[i];
                self.vx[l] = vx.0[i];
                self.vy[l] = vy.0[i];
                if bx.0[i] < 0.0 {
                    self.score_right[l] += 1;
                    rew = 1.0;
                    self.serving_right[l] = false;
                    self.serve_timer[l] = pong::SERVE_DELAY;
                } else if bx.0[i] > nf {
                    self.score_left[l] += 1;
                    rew = -1.0;
                    self.serving_right[l] = true;
                    self.serve_timer[l] = pong::SERVE_DELAY;
                }
                if self.score_left[l] >= pong::WIN_SCORE
                    || self.score_right[l] >= pong::WIN_SCORE
                {
                    self.over[l] = true;
                }
            }
            if i < n && step[l] != 0 {
                reward[l] = rew;
                done[l] = self.over[l] as u8;
            }
        }
    }
}

impl LaneGame for PongLanes {
    fn count(&self) -> usize {
        self.count
    }

    fn n_actions(&self) -> usize {
        6
    }

    fn name(&self) -> &'static str {
        "Pong"
    }

    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg32) {
        // Scalar: `*self = Pong::new()` then one draw for ball.y.
        self.ball_x[lane] = 84.0;
        self.ball_y[lane] = rng.range(60.0, 108.0);
        self.vx[lane] = 0.0;
        self.vy[lane] = 0.0;
        self.left_y[lane] = 84.0;
        self.right_y[lane] = 84.0;
        self.score_left[lane] = 0;
        self.score_right[lane] = 0;
        self.serve_timer[lane] = pong::SERVE_DELAY;
        self.serving_right[lane] = true;
        self.over[lane] = false;
    }

    fn lives(&self, _lane: usize) -> u32 {
        1
    }

    fn render_lane(&self, lane: usize, frame: &mut [u8]) {
        render::clear(frame, 44);
        render::vline_dashed(frame, NATIVE / 2, 90);
        render::rect(frame, 10.0, self.left_y[lane], pong::PADDLE_W, pong::PADDLE_H, 200);
        render::rect(
            frame,
            NATIVE as f32 - 10.0,
            self.right_y[lane],
            pong::PADDLE_W,
            pong::PADDLE_H,
            200,
        );
        if self.serve_timer[lane] == 0 {
            render::rect(frame, self.ball_x[lane], self.ball_y[lane], pong::BALL, pong::BALL, 255);
        }
        render::hbar(frame, 4, 20, self.score_left[lane] as usize * 3, 160);
        render::hbar(
            frame,
            4,
            NATIVE - 20 - self.score_right[lane] as usize * 3,
            self.score_right[lane] as usize * 3,
            160,
        );
    }

    fn tick_pass<const W: usize>(
        &mut self,
        actions: &[usize],
        step: &[u8],
        rngs: &mut [Pcg32],
        reward: &mut [f32],
        done: &mut [u8],
    ) {
        let k = self.count;
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            self.tick_group::<W>(g, n, actions, step, rngs, reward, done);
            g += W;
        }
    }
}

// ---------------------------------------------------------------------------
// Breakout lanes
// ---------------------------------------------------------------------------

/// SoA [`Breakout`](crate::envs::atari::breakout::Breakout). The brick
/// wall is one `u32` bitset per (row, lane) — bit `c` set means brick
/// `(row, c)` is alive — stored row-major (`[row * count + lane]`).
pub struct BreakoutLanes {
    count: usize,
    bricks: Vec<u32>,
    remaining: Vec<u32>,
    paddle_x: Vec<f32>,
    ball_x: Vec<f32>,
    ball_y: Vec<f32>,
    vx: Vec<f32>,
    vy: Vec<f32>,
    in_play: Vec<bool>,
    lives: Vec<u32>,
    over: Vec<bool>,
}

/// All `COLS` brick bits set.
const FULL_ROW: u32 = (1u32 << breakout::COLS) - 1;

impl BreakoutLanes {
    /// `count` lanes, each in the scalar `Breakout::new()` state.
    pub fn new(count: usize) -> Self {
        BreakoutLanes {
            count,
            bricks: vec![FULL_ROW; breakout::ROWS * count],
            remaining: vec![(breakout::ROWS * breakout::COLS) as u32; count],
            paddle_x: vec![84.0; count],
            ball_x: vec![84.0; count],
            ball_y: vec![120.0; count],
            vx: vec![0.0; count],
            vy: vec![0.0; count],
            in_play: vec![false; count],
            lives: vec![5; count],
            over: vec![false; count],
        }
    }

    /// Is brick `(r, c)` of `lane` alive? (test/render helper)
    fn brick(&self, lane: usize, r: usize, c: usize) -> bool {
        self.bricks[r * self.count + lane] >> c & 1 != 0
    }

    /// One lane group of the scalar `Breakout::tick`, branches as
    /// selects; the brick phase (bitset + integer + data-dependent
    /// early return) stays a per-lane scalar island between the wall
    /// and paddle select phases, exactly where the scalar code runs it.
    #[allow(clippy::too_many_arguments)]
    fn tick_group<const W: usize>(
        &mut self,
        g: usize,
        n: usize,
        actions: &[usize],
        step: &[u8],
        rngs: &mut [Pcg32],
        reward: &mut [f32],
        done: &mut [u8],
    ) {
        let nf = NATIVE as f32;
        let half_p = breakout::PADDLE_W / 2.0;

        let active =
            Mask::<W>::from_fn(|i| i < n && step[g + i] != 0 && !self.over[g + i]);

        // Action phase. FIRE serves (reads the pre-clamp paddle, which
        // is already in range since FIRE doesn't move it); the draw is
        // per lane in lane order.
        for i in 0..n {
            let l = g + i;
            if active.0[i] && actions[l] == 1 && !self.in_play[l] {
                self.ball_x[l] = self.paddle_x[l];
                self.ball_y[l] = breakout::PADDLE_Y - 8.0;
                self.vx[l] = rngs[l].range(-1.5, 1.5);
                self.vy[l] = -2.2;
                self.in_play[l] = true;
            }
        }
        let dpad = F32s::<W>::from_fn(|i| {
            if i < n {
                match actions[g + i] {
                    2 => breakout::PADDLE_SPEED,
                    3 => -breakout::PADDLE_SPEED,
                    _ => 0.0,
                }
            } else {
                0.0
            }
        });
        let pad0 = F32s::<W>::load_or(&self.paddle_x[g..g + n], 84.0);
        let pad = active.select_f32((pad0 + dpad).clamp(half_p, nf - half_p), pad0);
        store_masked(&mut self.paddle_x[g..g + n], pad, active, n);

        // Out-of-play lanes early-return (0.0, false) after the paddle
        // move; just-served lanes are in play this same tick.
        let play = Mask::<W>::from_fn(|i| active.0[i] && self.in_play[g + i]);

        // Ball advance + side/top walls.
        let bx0 = F32s::<W>::load_or(&self.ball_x[g..g + n], 84.0);
        let by0 = F32s::<W>::load_or(&self.ball_y[g..g + n], 120.0);
        let vx0 = F32s::<W>::load_or(&self.vx[g..g + n], 0.0);
        let vy0 = F32s::<W>::load_or(&self.vy[g..g + n], 0.0);
        let mut bx = bx0 + vx0;
        let mut by = by0 + vy0;
        let mut vx = vx0;
        let mut vy = vy0;
        let lo_x = bx.lt(F32s::splat(breakout::BALL / 2.0));
        bx = lo_x.select_f32(F32s::splat(breakout::BALL / 2.0), bx);
        vx = lo_x.select_f32(vx.abs(), vx);
        let hi_x = bx.gt(F32s::splat(nf - breakout::BALL / 2.0));
        bx = hi_x.select_f32(F32s::splat(nf - breakout::BALL / 2.0), bx);
        vx = hi_x.select_f32(-vx.abs(), vx);
        let lo_y = by.lt(F32s::splat(breakout::BALL / 2.0));
        by = lo_y.select_f32(F32s::splat(breakout::BALL / 2.0), by);
        vy = lo_y.select_f32(vy.abs(), vy);

        // Brick phase (per-lane island). A cleared wall is the scalar
        // early return: the lane freezes before the paddle/lost phases.
        let mut rew_arr = [0.0f32; W];
        let mut cleared = [false; W];
        let mut vy_arr = vy.0;
        for i in 0..n {
            if !play.0[i] {
                continue;
            }
            let l = g + i;
            let (x, y) = (bx.0[i], by.0[i]);
            if y >= breakout::BRICK_TOP
                && y < breakout::BRICK_TOP + breakout::ROWS as f32 * breakout::BRICK_H
            {
                let r = ((y - breakout::BRICK_TOP) / breakout::BRICK_H) as usize;
                let c = (x / breakout::BRICK_W) as usize;
                if r < breakout::ROWS && c < breakout::COLS && self.brick(l, r, c) {
                    self.bricks[r * self.count + l] &= !(1u32 << c);
                    self.remaining[l] -= 1;
                    rew_arr[i] = breakout::ROW_SCORE[r];
                    vy_arr[i] = -vy_arr[i];
                    // ball speeds up when reaching the upper rows
                    if r < 2 {
                        vy_arr[i] = vy_arr[i].signum() * vy_arr[i].abs().max(3.0);
                    }
                    if self.remaining[l] == 0 {
                        self.over[l] = true;
                        cleared[i] = true;
                    }
                }
            }
        }
        let vy_brick = F32s(vy_arr);
        let fly = play & !Mask(cleared);

        // Paddle bounce with english (guarded on downward motion).
        let two = F32s::splat(2.0);
        let int_p = ((bx - pad).abs() * two)
            .lt(F32s::splat(breakout::BALL + breakout::PADDLE_W))
            & ((by - F32s::splat(breakout::PADDLE_Y)).abs() * two)
                .lt(F32s::splat(breakout::BALL + breakout::PADDLE_H));
        let hit = fly & vy_brick.gt(F32s::splat(0.0)) & int_p;
        let vy_fin = hit.select_f32(-vy_brick.abs(), vy_brick);
        // `/ half_p * 1.5` in scalar order — f32 is not associative.
        let vx_eng =
            (vx + (bx - pad) / F32s::splat(half_p) * F32s::splat(1.5)).clamp(-3.5, 3.5);
        let vx_fin = hit.select_f32(vx_eng, vx);

        // Store + ball-lost (integer) + outputs, per lane.
        for i in 0..n {
            let l = g + i;
            if play.0[i] {
                self.ball_x[l] = bx.0[i];
                self.ball_y[l] = by.0[i];
                if cleared[i] {
                    // Early-returned lane: paddle/lost phases skipped.
                    self.vx[l] = vx.0[i];
                    self.vy[l] = vy_brick.0[i];
                } else {
                    self.vx[l] = vx_fin.0[i];
                    self.vy[l] = vy_fin.0[i];
                    if by.0[i] > nf {
                        self.lives[l] -= 1;
                        self.in_play[l] = false;
                        if self.lives[l] == 0 {
                            self.over[l] = true;
                        }
                    }
                }
            }
            if i < n && step[l] != 0 {
                reward[l] = rew_arr[i];
                done[l] = self.over[l] as u8;
            }
        }
    }
}

impl LaneGame for BreakoutLanes {
    fn count(&self) -> usize {
        self.count
    }

    fn n_actions(&self) -> usize {
        4
    }

    fn name(&self) -> &'static str {
        "Breakout"
    }

    fn reset_lane(&mut self, lane: usize, rng: &mut Pcg32) {
        // Scalar: `*self = Breakout::new()` then one draw for paddle_x.
        for r in 0..breakout::ROWS {
            self.bricks[r * self.count + lane] = FULL_ROW;
        }
        self.remaining[lane] = (breakout::ROWS * breakout::COLS) as u32;
        self.paddle_x[lane] = rng.range(40.0, NATIVE as f32 - 40.0);
        self.ball_x[lane] = 84.0;
        self.ball_y[lane] = 120.0;
        self.vx[lane] = 0.0;
        self.vy[lane] = 0.0;
        self.in_play[lane] = false;
        self.lives[lane] = 5;
        self.over[lane] = false;
    }

    fn lives(&self, lane: usize) -> u32 {
        self.lives[lane]
    }

    fn render_lane(&self, lane: usize, frame: &mut [u8]) {
        render::clear(frame, 30);
        for r in 0..breakout::ROWS {
            let shade = 120 + (r * 20) as u8;
            let row = self.bricks[r * self.count + lane];
            for c in 0..breakout::COLS {
                if row >> c & 1 != 0 {
                    render::rect(
                        frame,
                        (c as f32 + 0.5) * breakout::BRICK_W,
                        breakout::BRICK_TOP + (r as f32 + 0.5) * breakout::BRICK_H,
                        breakout::BRICK_W - 1.0,
                        breakout::BRICK_H - 1.0,
                        shade,
                    );
                }
            }
        }
        render::rect(
            frame,
            self.paddle_x[lane],
            breakout::PADDLE_Y,
            breakout::PADDLE_W,
            breakout::PADDLE_H,
            220,
        );
        if self.in_play[lane] {
            render::rect(
                frame,
                self.ball_x[lane],
                self.ball_y[lane],
                breakout::BALL,
                breakout::BALL,
                255,
            );
        }
        render::hbar(frame, 2, 4, self.lives[lane] as usize * 4, 180);
    }

    fn tick_pass<const W: usize>(
        &mut self,
        actions: &[usize],
        step: &[u8],
        rngs: &mut [Pcg32],
        reward: &mut [f32],
        done: &mut [u8],
    ) {
        let k = self.count;
        let mut g = 0;
        while g < k {
            let n = W.min(k - g);
            self.tick_group::<W>(g, n, actions, step, rngs, reward, done);
            g += W;
        }
    }
}

// ---------------------------------------------------------------------------
// Batched frameskip driver
// ---------------------------------------------------------------------------

/// Preallocated scratch for [`step_emulate_batch`] — one row per lane,
/// reused every dispatch so the batched step never allocates.
pub struct EmulateScratch {
    /// Decoded minimal-set action per lane.
    acts: Vec<usize>,
    /// Lane still ticking within the current skip.
    alive: Vec<u8>,
    /// Per-tick outputs from the lane pass.
    rew: Vec<f32>,
    done: Vec<u8>,
    /// Skip accumulators (scalar `step_emulate` locals, one per lane).
    acc_rew: Vec<f32>,
    acc_done: Vec<bool>,
    pool: Vec<bool>,
    /// Inverted reset mask (`1` = step this lane), fed to the passes.
    pub(crate) skip: Vec<u8>,
}

impl EmulateScratch {
    pub fn new(count: usize) -> Self {
        EmulateScratch {
            acts: vec![0; count],
            alive: vec![0; count],
            rew: vec![0.0; count],
            done: vec![0; count],
            acc_rew: vec![0.0; count],
            acc_done: vec![false; count],
            pool: vec![false; count],
            skip: vec![0; count],
        }
    }
}

/// Batched twin of [`PreprocCore::step_emulate`]: the frameskip loop as
/// `FRAMESKIP` masked lane-group tick passes, with per-lane render and
/// pool bookkeeping identical to the scalar loop — `frame_b` rendered
/// after the second-to-last tick, `frame_a` + pool after the last, an
/// early `frame_a` render (no pool) for lanes that die mid-skip, which
/// then stop ticking. Lanes with `skip == 0` are untouched. `frames_a`
/// / `frames_b` are the lane-major native-frame slabs.
pub(crate) fn step_emulate_batch<L: LaneGame, const W: usize>(
    lanes: &mut L,
    rngs: &mut [Pcg32],
    actions: &[f32],
    sc: &mut EmulateScratch,
    frames_a: &mut [u8],
    frames_b: &mut [u8],
    phases: &mut [Option<EmulatePhase>],
) {
    let k = lanes.count();
    let n_act = lanes.n_actions();
    for l in 0..k {
        sc.alive[l] = sc.skip[l];
        sc.acc_rew[l] = 0.0;
        sc.acc_done[l] = false;
        sc.pool[l] = false;
        if sc.skip[l] != 0 {
            sc.acts[l] = discrete_action(&actions[l..l + 1], n_act);
        }
    }
    for tick in 0..FRAMESKIP {
        if !sc.alive.iter().any(|&a| a != 0) {
            break;
        }
        lanes.tick_pass::<W>(&sc.acts, &sc.alive, rngs, &mut sc.rew, &mut sc.done);
        for l in 0..k {
            if sc.alive[l] == 0 {
                continue;
            }
            sc.acc_rew[l] += sc.rew[l];
            if tick == FRAMESKIP - 2 {
                lanes.render_lane(l, &mut frames_b[l * FRAME..(l + 1) * FRAME]);
            } else if tick == FRAMESKIP - 1 {
                lanes.render_lane(l, &mut frames_a[l * FRAME..(l + 1) * FRAME]);
                sc.pool[l] = true;
            }
            if sc.done[l] != 0 {
                sc.acc_done[l] = true;
                // render whatever we have if we died early in the skip
                if tick < FRAMESKIP - 1 {
                    lanes.render_lane(l, &mut frames_a[l * FRAME..(l + 1) * FRAME]);
                }
                sc.alive[l] = 0;
            }
        }
    }
    for l in 0..k {
        if sc.skip[l] != 0 {
            phases[l] = Some(EmulatePhase {
                reward: sc.acc_rew[l],
                done: sc.acc_done[l],
                pool: sc.pool[l],
                lives: lanes.lives(l),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::atari::breakout::Breakout;
    use crate::envs::atari::game::Game;
    use crate::envs::atari::pong::Pong;

    /// Drive `K` lanes and `K` scalar games through identical action /
    /// RNG streams with forced mid-run resets; every tick must agree
    /// bitwise on (reward, done, lives) and periodically on the full
    /// rendered frame.
    fn assert_lane_parity<const W: usize, L: LaneGame, G: Game, F: FnMut() -> G>(
        mut lanes: L,
        mut mk: F,
        n_actions: u32,
        seed: u64,
        ticks: usize,
    ) {
        let k = lanes.count();
        let mut rngs: Vec<Pcg32> = (0..k).map(|l| Pcg32::new(seed, l as u64)).collect();
        let mut srngs = rngs.clone();
        let mut games: Vec<G> = (0..k).map(|_| mk()).collect();
        for l in 0..k {
            lanes.reset_lane(l, &mut rngs[l]);
            games[l].reset(&mut srngs[l]);
        }
        let mut arng = Pcg32::new(seed ^ 0xACC, 99);
        let mut acts = vec![0usize; k];
        let step = vec![1u8; k];
        let mut rew = vec![0.0f32; k];
        let mut done = vec![0u8; k];
        let (mut fl, mut fs) = (vec![0u8; FRAME], vec![0u8; FRAME]);
        for t in 0..ticks {
            if t % 131 == 47 {
                // Forced mid-run reset of one lane (stream stays shared).
                let l = arng.below(k as u32) as usize;
                lanes.reset_lane(l, &mut rngs[l]);
                games[l].reset(&mut srngs[l]);
            }
            for a in acts.iter_mut() {
                *a = arng.below(n_actions) as usize;
            }
            lanes.tick_pass::<W>(&acts, &step, &mut rngs, &mut rew, &mut done);
            for l in 0..k {
                let (r, d) = games[l].tick(acts[l], &mut srngs[l]);
                assert_eq!(rew[l].to_bits(), r.to_bits(), "reward t={t} lane={l} W={W}");
                assert_eq!(done[l] != 0, d, "done t={t} lane={l} W={W}");
                assert_eq!(lanes.lives(l), games[l].lives(), "lives t={t} lane={l}");
                if (t + l) % 17 == 0 {
                    lanes.render_lane(l, &mut fl);
                    games[l].render(&mut fs);
                    assert!(fl == fs, "frame mismatch t={t} lane={l} W={W}");
                }
            }
        }
    }

    #[test]
    fn pong_lane_pass_bitwise_at_all_widths() {
        // K=9: a full width-8 group plus a tail lane, two width-4
        // groups + tail, and the width-1 path.
        assert_lane_parity::<1, _, _, _>(PongLanes::new(9), Pong::new, 6, 5, 2000);
        assert_lane_parity::<4, _, _, _>(PongLanes::new(9), Pong::new, 6, 5, 2000);
        assert_lane_parity::<8, _, _, _>(PongLanes::new(9), Pong::new, 6, 5, 2000);
    }

    #[test]
    fn breakout_lane_pass_bitwise_at_all_widths() {
        assert_lane_parity::<1, _, _, _>(BreakoutLanes::new(9), Breakout::new, 4, 11, 2000);
        assert_lane_parity::<4, _, _, _>(BreakoutLanes::new(9), Breakout::new, 4, 11, 2000);
        assert_lane_parity::<8, _, _, _>(BreakoutLanes::new(9), Breakout::new, 4, 11, 2000);
    }

    #[test]
    fn unstepped_lanes_are_untouched() {
        let k = 6;
        let mut lanes = PongLanes::new(k);
        let mut rngs: Vec<Pcg32> = (0..k).map(|l| Pcg32::new(3, l as u64)).collect();
        for l in 0..k {
            lanes.reset_lane(l, &mut rngs[l]);
        }
        let (mut f0, mut f1) = (vec![0u8; FRAME], vec![0u8; FRAME]);
        lanes.render_lane(2, &mut f0);
        // Step every lane except 2 for a while.
        let step: Vec<u8> = (0..k).map(|l| (l != 2) as u8).collect();
        let acts = vec![0usize; k];
        let mut rew = vec![0.0f32; k];
        let mut done = vec![0u8; k];
        for _ in 0..50 {
            lanes.tick_pass::<8>(&acts, &step, &mut rngs, &mut rew, &mut done);
        }
        lanes.render_lane(2, &mut f1);
        assert_eq!(f0, f1, "masked-out lane must not advance");
    }

    #[test]
    fn batched_driver_matches_scalar_step_emulate() {
        // One lane through the batched driver vs the scalar core: the
        // EmulatePhase records and both frame slabs must match exactly,
        // including early-death renders around scoring ticks.
        use crate::envs::atari::preproc::{game_rng, PreprocCore};
        let mut lanes = PongLanes::new(1);
        let mut rngs = vec![game_rng(21, 0)];
        let mut srng = game_rng(21, 0);
        let mut game = Pong::new();
        lanes.reset_lane(0, &mut rngs[0]);
        game.reset(&mut srng);
        let mut core = PreprocCore::new(6);
        let mut sc = EmulateScratch::new(1);
        sc.skip[0] = 1;
        let (mut fa, mut fb) = (vec![0u8; FRAME], vec![0u8; FRAME]);
        let (mut sfa, mut sfb) = (vec![0u8; FRAME], vec![0u8; FRAME]);
        let mut phases = vec![None; 1];
        for t in 0..200 {
            let a = [(t % 6) as f32];
            step_emulate_batch::<_, 8>(
                &mut lanes,
                &mut rngs,
                &a,
                &mut sc,
                &mut fa,
                &mut fb,
                &mut phases,
            );
            let ph = core.step_emulate(&mut game, &mut srng, &a, &mut sfa, &mut sfb);
            let bp = phases[0].expect("stepped lane has a phase");
            assert_eq!(bp.reward.to_bits(), ph.reward.to_bits(), "t={t}");
            assert_eq!(bp.done, ph.done, "t={t}");
            assert_eq!(bp.pool, ph.pool, "t={t}");
            assert_eq!(bp.lives, ph.lives, "t={t}");
            assert!(fa == sfa, "frame_a mismatch t={t}");
            assert!(fb == sfb, "frame_b mismatch t={t}");
        }
    }
}
