//! Vectorized (struct-of-arrays) environment batches.
//!
//! # Why this layer exists
//!
//! The paper's lock-free queues (Appendix D) remove *transport* overhead,
//! but for very cheap environments the remaining cost is *dispatch*: one
//! task dequeue, one virtual call, one mutex acquisition, and one slot
//! commit **per env per step**. A CartPole step is ~20 flops; the
//! dispatch around it is an order of magnitude more. CuLE makes the same
//! observation for GPU Atari (batch the emulator loop, not the
//! transport) and Sample Factory keeps workers saturated with per-worker
//! env batches. This module provides the execution half of that design:
//! a [`VecEnv`] steps a whole batch of `K` environments in one call, so
//! all per-task costs are amortized `K`-fold.
//!
//! # SoA layout
//!
//! Each kernel stores env state as parallel arrays (struct-of-arrays),
//! e.g. [`CartPoleVec`] holds `x[]`, `x_dot[]`, `theta[]`, `theta_dot[]`
//! rather than an array of 4-float structs. The step loop walks lanes
//! sequentially with all state for a field contiguous in cache, and the
//! per-lane math is the *same inlined function* the scalar env uses
//! ([`crate::envs::classic`] exports its dynamics), which makes the two
//! paths bitwise identical — the property test in `tests/vector_parity.rs`
//! pins this.
//!
//! # SIMD lane pass
//!
//! On top of the SoA layout, the classic-control kernels (and the
//! walker's batch task pass) step whole **lane groups** of environments
//! per instruction through [`crate::simd`]: width 4 or 8 groups with a
//! masked tail (env counts that are not a multiple of the width) and a
//! masked-reset path (lanes auto-resetting mid-batch are excluded from
//! the vector store, never from the group). The lane-group dynamics
//! live next to the scalar dynamics in [`crate::envs::classic`] and
//! apply the identical operations in the identical order — every lane
//! width is **bitwise identical** to the width-1 scalar reference loop,
//! pinned per step by `tests/simd_parity.rs`. Width selection is a
//! kernel config ([`VecEnv::set_lane_pass`], wired from
//! `PoolConfig::lane_pass` / `--lane-width`).
//!
//! # Every family is batch-first
//!
//! Vectorized execution is the engine's primary abstraction, not a
//! classic-control carve-out: every registered task has a real kernel.
//! [`WalkerVec`] keeps MuJoCo qpos/qvel state in SoA lanes (physics
//! reuses the scalar solver per lane — bitwise parity), [`AtariVec`]
//! steps emulator lanes in one call with preprocessing shared verbatim
//! with the scalar env, and [`CheetahRunVec`] layers the dm_control
//! reward shaping batch-wise. [`ScalarVec`] — a chunk of boxed scalar
//! envs behind this interface — remains as an *explicit opt-in* for
//! out-of-registry envs; `registry::make_vec_env` never falls back to
//! it. Wrappers compose batch-wise through
//! [`crate::envs::wrappers::vec`].
//!
//! # Observation arenas — no per-env allocation
//!
//! Kernels never allocate observation buffers. The caller hands an
//! [`ObsArena`], a view that yields the final destination row for each
//! lane. The pool's chunked executor backs the arena directly with
//! acquired [`crate::pool::StateBufferQueue`] slots (observations are
//! written in place in block memory, the paper's zero-copy invariant);
//! the synchronous executors back it with their contiguous output
//! buffer ([`SliceArena`]).
//!
//! # Chunking math
//!
//! The chunked pool derives the chunk size `K = ceil(num_envs /
//! num_threads)` so every worker owns at most one chunk's work per
//! round; the last chunk takes the remainder (`num_envs - (chunks-1)*K`).
//! With `K = 1` the design degenerates to the paper's per-env tasks;
//! with `K = num_envs / num_threads` each thread wakeup serves a full
//! chunk, cutting semaphore posts and task dequeues by `K×`.
//!
//! # Auto-reset semantics
//!
//! [`VecEnv::step_batch`] takes a `reset_mask`: lanes whose previous
//! transition finished are *reset* instead of stepped, producing the
//! fresh observation with zero reward — exactly the EnvPool auto-reset
//! contract the scalar [`crate::pool::ThreadPool`] implements, so every
//! executor agrees on episode-boundary semantics.

pub mod acrobot;
pub mod atari;
pub mod cartpole;
pub mod mountain_car;
pub mod pendulum;
pub mod scalar;
pub mod walker;

pub use acrobot::AcrobotVec;
pub use atari::AtariVec;
pub use cartpole::CartPoleVec;
pub use mountain_car::MountainCarVec;
pub use pendulum::PendulumVec;
pub use scalar::ScalarVec;
pub use walker::{CheetahRunVec, WalkerVec};

use super::env::Step;
use super::spec::EnvSpec;

/// Destination rows for a batch of observations. `row(lane)` returns the
/// final storage for lane `lane`'s observation (length `obs_dim`) — a
/// state-queue slot, an output-buffer row, or any other pre-allocated
/// memory. Implementations must return disjoint rows for distinct lanes.
pub trait ObsArena {
    /// Observation row for batch lane `lane`.
    fn row(&mut self, lane: usize) -> &mut [f32];
}

/// [`ObsArena`] over a contiguous row-major `[K, obs_dim]` buffer.
pub struct SliceArena<'a> {
    buf: &'a mut [f32],
    dim: usize,
}

impl<'a> SliceArena<'a> {
    /// View `buf` (length `K * dim`) as `K` rows of width `dim`.
    pub fn new(buf: &'a mut [f32], dim: usize) -> Self {
        debug_assert!(dim > 0 && buf.len() % dim == 0);
        SliceArena { buf, dim }
    }
}

impl ObsArena for SliceArena<'_> {
    #[inline]
    fn row(&mut self, lane: usize) -> &mut [f32] {
        &mut self.buf[lane * self.dim..(lane + 1) * self.dim]
    }
}

/// A fixed batch of environments stepped as one unit.
///
/// Lane `l` corresponds to global env id `first_env_id + l` (RNG streams
/// are keyed by global id, so trajectories are independent of how envs
/// are grouped into batches — the determinism tests rely on this).
pub trait VecEnv: Send {
    /// Spec of the underlying task (shared by every lane).
    fn spec(&self) -> &EnvSpec;

    /// Number of lanes (environments) in this batch.
    fn num_envs(&self) -> usize;

    /// Select the SIMD lane pass for kernels that have one (classic
    /// control, the walker task pass). Width 1 is the scalar reference
    /// loop; every width is **bitwise identical** (see
    /// [`crate::simd`]), so this is purely a throughput knob. Kernels
    /// without a lane pass ignore it (default no-op); wrappers forward
    /// it to their inner kernel.
    fn set_lane_pass(&mut self, lane_pass: crate::simd::LanePass) {
        let _ = lane_pass;
    }

    /// Reset lane `lane`, writing its initial observation into `obs`
    /// (length `spec().obs_dim()`).
    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]);

    /// Step every lane: `actions` is row-major `[K, act_dim]`. Lanes with
    /// `reset_mask[lane] != 0` are reset instead of stepped (EnvPool
    /// auto-reset) and report a default [`Step`] (zero reward, no flags).
    /// Observations go through `arena.row(lane)`; step results into
    /// `out[lane]`.
    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_arena_rows_are_disjoint_and_ordered() {
        let mut buf = vec![0.0f32; 6];
        let mut a = SliceArena::new(&mut buf, 2);
        a.row(1).copy_from_slice(&[1.0, 2.0]);
        a.row(2)[0] = 3.0;
        assert_eq!(buf, vec![0.0, 0.0, 1.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn cartpole_vec_matches_scalar_env_bitwise() {
        use crate::envs::classic::CartPole;
        use crate::envs::env::Env;

        let seed = 42;
        let n = 3;
        let mut vec_env = CartPoleVec::new(seed, 0, n);
        let mut scalars: Vec<CartPole> = (0..n).map(|i| CartPole::new(seed, i as u64)).collect();

        let mut vobs = vec![0.0f32; n * 4];
        let mut sobs = [0.0f32; 4];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * 4..(l + 1) * 4]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * 4..(l + 1) * 4], &sobs, "reset lane {l}");
        }

        let mut mask = vec![0u8; n];
        let mut steps = vec![Step::default(); n];
        for t in 0..200 {
            let actions: Vec<f32> = (0..n).map(|l| ((t + l) % 2) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, 4);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut steps);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                if mask[l] != 0 {
                    env.reset(&mut sobs);
                    assert_eq!(steps[l], Step::default(), "reset step {t} lane {l}");
                } else {
                    let s = env.step(&actions[l..l + 1], &mut sobs);
                    assert_eq!(steps[l], s, "step {t} lane {l}");
                }
                assert_eq!(&vobs[l * 4..(l + 1) * 4], &sobs, "obs {t} lane {l}");
                mask[l] = steps[l].finished() as u8;
            }
        }
    }
}
