//! Vectorized (struct-of-arrays) environment batches.
//!
//! # Why this layer exists
//!
//! The paper's lock-free queues (Appendix D) remove *transport* overhead,
//! but for very cheap environments the remaining cost is *dispatch*: one
//! task dequeue, one virtual call, one mutex acquisition, and one slot
//! commit **per env per step**. A CartPole step is ~20 flops; the
//! dispatch around it is an order of magnitude more. CuLE makes the same
//! observation for GPU Atari (batch the emulator loop, not the
//! transport) and Sample Factory keeps workers saturated with per-worker
//! env batches. This module provides the execution half of that design:
//! a [`VecEnv`] steps a whole batch of `K` environments in one call, so
//! all per-task costs are amortized `K`-fold.
//!
//! # SoA layout and the shared driver
//!
//! Each kernel stores env state as parallel arrays (struct-of-arrays).
//! The four classic-control kernels are instances of one generic driver,
//! [`SoaKernel`], parameterized over the state-lane count and a
//! per-kernel [`LaneDynamics`] descriptor (scalar dynamics, lane-group
//! dynamics twin, action decode, terminal/reward rules, obs layout).
//! The driver owns everything the kernels used to duplicate: the state
//! arrays, the per-lane RNG streams, the step counters, the width
//! dispatch, and — most importantly — the **masked-reset protocol**
//! (auto-reset lanes ride along in the vector compute and are excluded
//! from every store), so episode-boundary semantics live in exactly one
//! place. Per-lane math is the *same inlined function* the scalar env
//! uses ([`crate::envs::classic`] exports its dynamics), which makes
//! the two paths bitwise identical — the property test in
//! `tests/vector_parity.rs` pins this.
//!
//! # SIMD lane pass
//!
//! On top of the SoA layout, kernels step whole **lane groups** of
//! environments per instruction through [`crate::simd`]: width 4 or 8
//! groups with a masked tail and the masked-reset path. Width selection
//! is a kernel config ([`VecEnv::set_lane_pass`], wired from
//! `PoolConfig::lane_pass` / `--lane-width`). The parity contract is
//! per family:
//!
//! - **classic control**: the lane-group dynamics live next to the
//!   scalar dynamics in [`crate::envs::classic`] and apply identical
//!   operations in identical order — every lane width is **bitwise
//!   identical** to the width-1 scalar reference loop, pinned per step
//!   by `tests/simd_parity.rs`.
//! - **MuJoCo walkers / dm_control**: the *constraint solver itself*
//!   runs lane-grouped inside the batch-resident
//!   [`WorldBatch`](crate::envs::mujoco::WorldBatch). Width 1 is
//!   bitwise with the scalar envs; widths 4/8 use the deterministic
//!   trig twins and follow the **documented, asserted tolerance
//!   budget** pinned by `tests/mujoco_batch_parity.rs` (see
//!   [`walker`] for the contract).
//! - **Atari**: the *emulator itself* runs lane-grouped — SoA game
//!   state with masked select-based tick passes ([`atari_emulate`]).
//!   Pure per-lane f32 arithmetic (no cross-lane math, no trig), so
//!   every width is **bitwise identical** to the scalar `Game::tick`,
//!   pinned by `tests/atari_emulate_parity.rs`.
//!
//! # Every family is batch-first
//!
//! Vectorized execution is the engine's primary abstraction, not a
//! classic-control carve-out: every registered task has a real kernel.
//! [`WalkerVec`] keeps MuJoCo body/joint/contact state batch-resident
//! in a shared [`WorldBatch`](crate::envs::mujoco::WorldBatch) core
//! (the scalar walker env is a width-1 view over the same kernel;
//! since the body-major rewrite every solver lane group is one
//! contiguous slice of the batch state), [`AtariVec`] holds SoA game
//! state and runs the emulator frameskip as masked lane-group tick
//! passes with all pixel state packed into contiguous lane-major
//! slabs — the pure preprocessing math runs as a separate SoA pass
//! over the slabs, sharing `PreprocCore` verbatim with the scalar
//! env — and [`CheetahRunVec`] layers the dm_control reward
//! shaping batch-wise. [`ScalarVec`] — a chunk of
//! boxed scalar envs behind this interface — remains as an *explicit
//! opt-in* for out-of-registry envs; `registry::make_vec_env` never
//! falls back to it. Wrappers compose batch-wise through
//! [`crate::envs::wrappers::vec`].
//!
//! # Observation arenas — no per-env allocation
//!
//! Kernels never allocate observation buffers. The caller hands an
//! [`ObsArena`], a view that yields the final destination row for each
//! lane. The pool's chunked executor backs the arena directly with
//! acquired [`crate::pool::StateBufferQueue`] slots (observations are
//! written in place in block memory, the paper's zero-copy invariant);
//! the synchronous executors back it with their contiguous output
//! buffer ([`SliceArena`]).
//!
//! # Chunking math
//!
//! The chunked pool derives the chunk size `K = ceil(num_envs /
//! num_threads)` so every worker owns at most one chunk's work per
//! round; the last chunk takes the remainder (`num_envs - (chunks-1)*K`).
//! With `K = 1` the design degenerates to the paper's per-env tasks;
//! with `K = num_envs / num_threads` each thread wakeup serves a full
//! chunk, cutting semaphore posts and task dequeues by `K×`.
//!
//! # Auto-reset semantics
//!
//! [`VecEnv::step_batch`] takes a `reset_mask`: lanes whose previous
//! transition finished are *reset* instead of stepped, producing the
//! fresh observation with zero reward — exactly the EnvPool auto-reset
//! contract the scalar [`crate::pool::ThreadPool`] implements, so every
//! executor agrees on episode-boundary semantics.

pub mod acrobot;
pub mod atari;
pub mod atari_emulate;
pub mod cartpole;
pub mod mountain_car;
pub mod pendulum;
pub mod scalar;
pub mod walker;

pub use acrobot::AcrobotVec;
pub use atari::AtariVec;
pub use atari_emulate::{BreakoutLanes, LaneGame, PongLanes};
pub use cartpole::CartPoleVec;
pub use mountain_car::MountainCarVec;
pub use pendulum::PendulumVec;
pub use scalar::ScalarVec;
pub use walker::{CheetahRunVec, WalkerVec};

use super::env::Step;
use super::spec::EnvSpec;
use crate::rng::Pcg32;
use crate::simd::{F32s, LanePass, Mask};

/// Maximum number of per-lane physics parameters a kernel can expose
/// through [`VecEnv::set_param_lanes`]. Fixed-size so [`SoaKernel`] can
/// keep the parameter lanes in plain arrays with no per-step branching;
/// every current kernel uses ≤ 3 (`registry::supported_params` is the
/// authoritative per-task list).
pub const MAX_PARAMS: usize = 4;

/// Destination rows for a batch of observations. `row(lane)` returns the
/// final storage for lane `lane`'s observation (length `obs_dim`) — a
/// state-queue slot, an output-buffer row, or any other pre-allocated
/// memory. Implementations must return disjoint rows for distinct lanes.
pub trait ObsArena {
    /// Observation row for batch lane `lane`.
    fn row(&mut self, lane: usize) -> &mut [f32];
}

/// [`ObsArena`] over a contiguous row-major `[K, obs_dim]` buffer.
pub struct SliceArena<'a> {
    buf: &'a mut [f32],
    dim: usize,
}

impl<'a> SliceArena<'a> {
    /// View `buf` (length `K * dim`) as `K` rows of width `dim`.
    pub fn new(buf: &'a mut [f32], dim: usize) -> Self {
        debug_assert!(dim > 0 && buf.len() % dim == 0);
        SliceArena { buf, dim }
    }
}

impl ObsArena for SliceArena<'_> {
    #[inline]
    fn row(&mut self, lane: usize) -> &mut [f32] {
        &mut self.buf[lane * self.dim..(lane + 1) * self.dim]
    }
}

/// A fixed batch of environments stepped as one unit.
///
/// Lane `l` corresponds to global env id `first_env_id + l` (RNG streams
/// are keyed by global id, so trajectories are independent of how envs
/// are grouped into batches — the determinism tests rely on this).
pub trait VecEnv: Send {
    /// Spec of the underlying task (shared by every lane).
    fn spec(&self) -> &EnvSpec;

    /// Number of lanes (environments) in this batch.
    fn num_envs(&self) -> usize;

    /// Select the SIMD lane pass for kernels that have one. Width 1 is
    /// the scalar reference loop. For classic control every width is
    /// **bitwise identical** (see [`crate::simd`]), so the knob is
    /// purely throughput; for the walker family widths > 1 run the
    /// lane-grouped solver under the documented tolerance contract
    /// (see [`walker`]). Kernels without a lane pass ignore it
    /// (default no-op); wrappers forward it to their inner kernel.
    fn set_lane_pass(&mut self, lane_pass: crate::simd::LanePass) {
        let _ = lane_pass;
    }

    /// Physics parameter names this kernel accepts through
    /// [`Self::set_param_lanes`], in parameter-index order (the order
    /// the scenario layer draws jitter streams in — part of the
    /// replayability contract). Empty for kernels with no overridable
    /// parameters. Wrappers forward to their inner kernel.
    fn param_names(&self) -> &'static [&'static str] {
        &[]
    }

    /// Override physics parameter `name` per lane (`values.len()` must
    /// equal [`Self::num_envs`]). Returns `false` if the kernel does
    /// not expose `name` — callers validate against
    /// [`Self::param_names`] / `registry::supported_params` first, so a
    /// `false` from a wired path is a bug. Parameters persist across
    /// [`Self::reset_lane`] (a lane keeps its drawn physics for the
    /// whole pool lifetime — the scenario replayability contract), and
    /// the defaults are the task constants, bitwise (pinned by the
    /// classic kernels' `param_defaults_are_bitwise` tests).
    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        let _ = (name, values);
        false
    }

    /// Reset lane `lane`, writing its initial observation into `obs`
    /// (length `spec().obs_dim()`).
    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]);

    /// Step every lane: `actions` is row-major `[K, act_dim]`. Lanes with
    /// `reset_mask[lane] != 0` are reset instead of stepped (EnvPool
    /// auto-reset) and report a default [`Step`] (zero reward, no flags).
    /// Observations go through `arena.row(lane)`; step results into
    /// `out[lane]`.
    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    );
}

/// Per-kernel dynamics descriptor for the shared SoA driver
/// ([`SoaKernel`]). `S` is the number of state lanes. Implementations
/// must keep `step1` (the width-1 reference) and `step_lanes` (the
/// lane-group twin) applying **identical operations in identical
/// order** — that is the bitwise-at-every-width contract the classic
/// kernels ship under (`tests/simd_parity.rs`).
pub trait LaneDynamics<const S: usize>: Send {
    /// Env spec for this kernel.
    fn spec(&self) -> EnvSpec;

    /// Per-env RNG stream (keyed by global env id, shared with the
    /// scalar env).
    fn rng_for(&self, seed: u64, env_id: u64) -> Pcg32;

    /// Truncation limit (the task's `max_episode_steps`).
    fn max_steps(&self) -> usize;

    /// Fresh episode state.
    fn reset_state(&self, rng: &mut Pcg32) -> [f32; S];

    /// Overridable physics parameter names, in the index order of the
    /// `p` argument to [`Self::step1`] / [`Self::step_lanes`]. Empty
    /// (the default) for kernels whose dynamics are not parameterized.
    fn param_names(&self) -> &'static [&'static str] {
        &[]
    }

    /// Default value per parameter slot — the task constants. Slots
    /// past `param_names().len()` are ignored (0.0 by convention). The
    /// driver seeds every lane with these, so an un-overridden kernel
    /// feeds the dynamics the exact constant bits.
    fn default_params(&self) -> [f32; MAX_PARAMS] {
        [0.0; MAX_PARAMS]
    }

    /// Width-1 reference step: decode lane `lane`'s action row from
    /// `actions` and apply the scalar dynamics with the lane's physics
    /// parameters `p` (slots in [`Self::param_names`] order). Returns
    /// `(next state, done, reward)`.
    fn step1(
        &self,
        s: [f32; S],
        actions: &[f32],
        lane: usize,
        p: &[f32; MAX_PARAMS],
    ) -> ([f32; S], bool, f32);

    /// Scalar control input for the SIMD pass (the driver feeds `0.0`
    /// to masked/tail lanes; their results are discarded).
    fn input(&self, actions: &[f32], lane: usize) -> f32;

    /// Lane-group twin of [`Self::step1`] (`p` holds the lane-group's
    /// parameter vectors — broadcast defaults when nothing is
    /// overridden). Returns `(next state, done mask, reward lanes)`.
    fn step_lanes<const W: usize>(
        &self,
        s: [F32s<W>; S],
        u: F32s<W>,
        p: &[F32s<W>; MAX_PARAMS],
    ) -> ([F32s<W>; S], Mask<W>, F32s<W>);

    /// Write the observation for state `s`.
    fn write_obs(&self, s: &[f32; S], obs: &mut [f32]);
}

/// The generic SoA batch driver: state lanes, per-lane RNG streams,
/// step counters, lane-width dispatch and the **masked-reset protocol**
/// for every [`LaneDynamics`] kernel — one implementation instead of
/// four copies (the classic kernels are type aliases over this).
pub struct SoaKernel<const S: usize, K: LaneDynamics<S>> {
    k: K,
    spec: EnvSpec,
    rng: Vec<Pcg32>,
    /// SoA state lanes, one `Vec` per state dimension.
    state: [Vec<f32>; S],
    /// Per-lane physics parameter lanes (scenario pools), one `Vec`
    /// per [`LaneDynamics::param_names`] slot, seeded with
    /// [`LaneDynamics::default_params`]. Never touched by resets.
    params: [Vec<f32>; MAX_PARAMS],
    /// Copy of the defaults, used to pad SIMD tail lanes.
    defaults: [f32; MAX_PARAMS],
    steps: Vec<u32>,
    /// Resolved SIMD lane width (1 = scalar reference loop).
    width: usize,
}

impl<const S: usize, K: LaneDynamics<S>> SoaKernel<S, K> {
    /// Batch of `count` envs with global ids `first_env_id..+count`.
    pub fn with_dynamics(k: K, seed: u64, first_env_id: u64, count: usize) -> Self {
        // The LaneDynamics surface passes exactly one f32 control per
        // lane (`input`, and the descriptors index `actions[lane]`); a
        // kernel with a wider action row would misindex every lane but
        // 0, so reject it loudly at construction.
        assert_eq!(
            k.spec().action_space.dim(),
            1,
            "SoaKernel supports act_dim == 1 kernels only"
        );
        let defaults = k.default_params();
        SoaKernel {
            spec: k.spec(),
            rng: (0..count).map(|l| k.rng_for(seed, first_env_id + l as u64)).collect(),
            state: std::array::from_fn(|_| vec![0.0; count]),
            params: std::array::from_fn(|j| vec![defaults[j]; count]),
            defaults,
            steps: vec![0; count],
            // Scalar reference until configured: the wired paths (pool,
            // executors) always call `set_lane_pass`, which is also the
            // single place the `Auto` width (env override + feature
            // detection) resolves — keeping construction infallible.
            width: LanePass::Scalar.width(),
            k,
        }
    }

    /// The scalar reference loop (lane width 1) — the pre-SIMD step
    /// sequence, kept verbatim as the parity baseline.
    fn step_scalar(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        for lane in 0..self.num_envs() {
            if reset_mask[lane] != 0 {
                self.reset_lane(lane, arena.row(lane));
                out[lane] = Step::default();
                continue;
            }
            let s: [f32; S] = std::array::from_fn(|j| self.state[j][lane]);
            let p: [f32; MAX_PARAMS] = std::array::from_fn(|j| self.params[j][lane]);
            let (s2, done, reward) = self.k.step1(s, actions, lane, &p);
            for (j, arr) in self.state.iter_mut().enumerate() {
                arr[lane] = s2[j];
            }
            self.steps[lane] += 1;
            let truncated = !done && self.steps[lane] as usize >= self.k.max_steps();
            self.k.write_obs(&s2, arena.row(lane));
            out[lane] = Step { reward, done, truncated };
        }
    }

    /// The SIMD lane pass: groups of `W` lanes per instruction. Lanes
    /// being auto-reset (and tail padding) ride along in the vector
    /// compute but are excluded from the store — the masked-reset /
    /// masked-tail path, in one place for every kernel.
    fn step_lanes<const W: usize>(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let n_envs = self.num_envs();
        let mut g = 0;
        while g < n_envs {
            let n = W.min(n_envs - g);
            for lane in g..g + n {
                if reset_mask[lane] != 0 {
                    self.reset_lane(lane, arena.row(lane));
                    out[lane] = Step::default();
                }
            }
            // Load the group (freshly-reset lanes included — their
            // results are discarded below; tail lanes padded with 0,
            // a valid state).
            let state: [F32s<W>; S] =
                std::array::from_fn(|j| F32s::load_or(&self.state[j][g..g + n], 0.0));
            // Parameter lanes ride along like state (tail lanes padded
            // with the defaults — a valid parameterization).
            let p: [F32s<W>; MAX_PARAMS] = std::array::from_fn(|j| {
                F32s::load_or(&self.params[j][g..g + n], self.defaults[j])
            });
            let u = F32s::<W>::from_fn(|i| {
                let lane = g + i;
                if i < n && reset_mask[lane] == 0 {
                    self.k.input(actions, lane)
                } else {
                    0.0
                }
            });
            let (s2, term, reward) = self.k.step_lanes(state, u, &p);
            // Masked store: only stepped lanes take the new state.
            for i in 0..n {
                let lane = g + i;
                if reset_mask[lane] != 0 {
                    continue;
                }
                for (j, arr) in self.state.iter_mut().enumerate() {
                    arr[lane] = s2[j].0[i];
                }
                self.steps[lane] += 1;
                let done = term.0[i];
                let truncated = !done && self.steps[lane] as usize >= self.k.max_steps();
                let srow: [f32; S] = std::array::from_fn(|j| s2[j].0[i]);
                self.k.write_obs(&srow, arena.row(lane));
                out[lane] = Step { reward: reward.0[i], done, truncated };
            }
            g += W;
        }
    }
}

impl<const S: usize, K: LaneDynamics<S>> VecEnv for SoaKernel<S, K> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn set_lane_pass(&mut self, lane_pass: LanePass) {
        self.width = lane_pass.width();
    }

    fn param_names(&self) -> &'static [&'static str] {
        self.k.param_names()
    }

    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        let Some(idx) = self.k.param_names().iter().position(|&n| n == name) else {
            return false;
        };
        assert_eq!(values.len(), self.num_envs(), "param lane count for {name}");
        self.params[idx].copy_from_slice(values);
        true
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        let s = self.k.reset_state(&mut self.rng[lane]);
        for (j, arr) in self.state.iter_mut().enumerate() {
            arr[lane] = s[j];
        }
        self.steps[lane] = 0;
        self.k.write_obs(&s, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        let k = self.num_envs();
        debug_assert_eq!(actions.len(), k * self.spec.action_space.dim());
        debug_assert_eq!(reset_mask.len(), k);
        debug_assert_eq!(out.len(), k);
        match self.width {
            8 => self.step_lanes::<8>(actions, reset_mask, arena, out),
            4 => self.step_lanes::<4>(actions, reset_mask, arena, out),
            _ => self.step_scalar(actions, reset_mask, arena, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_arena_rows_are_disjoint_and_ordered() {
        let mut buf = vec![0.0f32; 6];
        let mut a = SliceArena::new(&mut buf, 2);
        a.row(1).copy_from_slice(&[1.0, 2.0]);
        a.row(2)[0] = 3.0;
        assert_eq!(buf, vec![0.0, 0.0, 1.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn cartpole_vec_matches_scalar_env_bitwise() {
        use crate::envs::classic::CartPole;
        use crate::envs::env::Env;

        let seed = 42;
        let n = 3;
        let mut vec_env = CartPoleVec::new(seed, 0, n);
        let mut scalars: Vec<CartPole> = (0..n).map(|i| CartPole::new(seed, i as u64)).collect();

        let mut vobs = vec![0.0f32; n * 4];
        let mut sobs = [0.0f32; 4];
        for (l, env) in scalars.iter_mut().enumerate() {
            vec_env.reset_lane(l, &mut vobs[l * 4..(l + 1) * 4]);
            env.reset(&mut sobs);
            assert_eq!(&vobs[l * 4..(l + 1) * 4], &sobs, "reset lane {l}");
        }

        let mut mask = vec![0u8; n];
        let mut steps = vec![Step::default(); n];
        for t in 0..200 {
            let actions: Vec<f32> = (0..n).map(|l| ((t + l) % 2) as f32).collect();
            {
                let mut arena = SliceArena::new(&mut vobs, 4);
                vec_env.step_batch(&actions, &mask, &mut arena, &mut steps);
            }
            for (l, env) in scalars.iter_mut().enumerate() {
                if mask[l] != 0 {
                    env.reset(&mut sobs);
                    assert_eq!(steps[l], Step::default(), "reset step {t} lane {l}");
                } else {
                    let s = env.step(&actions[l..l + 1], &mut sobs);
                    assert_eq!(steps[l], s, "step {t} lane {l}");
                }
                assert_eq!(&vobs[l * 4..(l + 1) * 4], &sobs, "obs {t} lane {l}");
                mask[l] = steps[l].finished() as u8;
            }
        }
    }

    #[test]
    fn soa_driver_width_dispatch_covers_all_kernels() {
        // Smoke every classic kernel at every width through the shared
        // driver (the bitwise cross-width property lives in
        // tests/simd_parity.rs).
        use crate::envs::registry;
        for task in ["CartPole-v1", "MountainCar-v0", "Pendulum-v1", "Acrobot-v1"] {
            for lp in [LanePass::Scalar, LanePass::Width4, LanePass::Width8] {
                let mut k = registry::make_vec_env(task, 3, 0, 5).unwrap();
                k.set_lane_pass(lp);
                let dim = k.spec().obs_dim();
                let mut obs = vec![0.0f32; 5 * dim];
                for lane in 0..5 {
                    k.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
                }
                let mut outs = vec![Step::default(); 5];
                let mask = vec![0u8; 5];
                let actions = vec![0.0f32; 5];
                let mut arena = SliceArena::new(&mut obs, dim);
                k.step_batch(&actions, &mask, &mut arena, &mut outs);
                assert!(outs.iter().all(|s| s.reward.is_finite()), "{task} {lp}");
            }
        }
    }
}
