//! Classic-control environments with the textbook (Gym) dynamics.

pub mod cartpole;
pub mod mountain_car;
pub mod pendulum;
pub mod acrobot;

pub use acrobot::Acrobot;
pub use cartpole::CartPole;
pub use mountain_car::MountainCar;
pub use pendulum::Pendulum;
