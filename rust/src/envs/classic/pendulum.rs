//! Pendulum-v1: continuous-control swing-up with the Gym dynamics —
//! the smallest continuous-action task, used by the Gaussian-policy tests.

use crate::envs::env::{Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;
use crate::simd::{math::sin_cos_f32, math::sin_f32, F32s};

const MAX_SPEED: f32 = 8.0;
const MAX_TORQUE: f32 = 2.0;
const DT: f32 = 0.05;
pub(crate) const G: f32 = 10.0;
pub(crate) const M: f32 = 1.0;
pub(crate) const L: f32 = 1.0;

/// Pendulum environment. Observation `[cos θ, sin θ, θ̇]`, one torque
/// action in `[-2, 2]`, reward `-(θ² + 0.1 θ̇² + 0.001 u²)`.
pub struct Pendulum {
    spec: EnvSpec,
    rng: Pcg32,
    theta: f32,
    theta_dot: f32,
    steps: usize,
}

#[inline]
fn angle_normalize(x: f32) -> f32 {
    let two_pi = 2.0 * std::f32::consts::PI;
    ((x + std::f32::consts::PI).rem_euclid(two_pi)) - std::f32::consts::PI
}

/// Maximum episode length (shared with the SoA kernel).
pub(crate) const MAX_STEPS: usize = 200;

/// The Pendulum-v1 spec (shared with the SoA kernel).
pub(crate) fn spec() -> EnvSpec {
    EnvSpec {
        id: "Pendulum-v1".into(),
        obs_shape: vec![3],
        action_space: ActionSpace::Continuous { dim: 1, low: -MAX_TORQUE, high: MAX_TORQUE },
        max_episode_steps: MAX_STEPS,
        groups: vec![],
    }
}

/// Per-env RNG stream, keyed identically in the scalar and SoA paths
/// (family salt "pen").
#[inline]
pub(crate) fn rng(seed: u64, env_id: u64) -> Pcg32 {
    crate::rng::env_rng(seed, 0x70656e, env_id)
}

/// Fresh-episode state draw: `(theta, theta_dot)` in RNG call order.
#[inline]
pub(crate) fn reset_state(rng: &mut Pcg32) -> (f32, f32) {
    let theta = rng.range(-std::f32::consts::PI, std::f32::consts::PI);
    let theta_dot = rng.range(-1.0, 1.0);
    (theta, theta_dot)
}

/// One step of the pendulum dynamics (Gym equations): returns the new
/// `(theta, theta_dot)` and the step cost. Shared by the scalar env and
/// the SoA kernel so both paths are bitwise identical (sine via the
/// deterministic shared kernel the lane pass also uses).
#[inline]
pub(crate) fn dynamics(theta: f32, theta_dot: f32, action: f32) -> (f32, f32, f32) {
    dynamics_p(theta, theta_dot, action, G, M, L)
}

/// [`dynamics`] with overridable physics (scenario pools): gravity,
/// pendulum mass and length. The two composites are recomputed with the
/// exact op order of the const expressions (`3.0 * g / (2.0 * l)` and
/// `3.0 / (m * l * l)`), so the defaults are bitwise identical to the
/// constant path (pinned by `param_defaults_are_bitwise` below).
#[inline]
pub(crate) fn dynamics_p(
    theta: f32,
    theta_dot: f32,
    action: f32,
    g: f32,
    m: f32,
    l: f32,
) -> (f32, f32, f32) {
    let u = action.clamp(-MAX_TORQUE, MAX_TORQUE);
    let th = angle_normalize(theta);
    let cost = th * th + 0.1 * theta_dot * theta_dot + 0.001 * u * u;
    let mut theta_dot =
        theta_dot + (3.0 * g / (2.0 * l) * sin_f32(theta) + 3.0 / (m * l * l) * u) * DT;
    theta_dot = theta_dot.clamp(-MAX_SPEED, MAX_SPEED);
    let theta = theta + theta_dot * DT;
    (theta, theta_dot, cost)
}

/// [`dynamics`] over a lane group — the same operations in the same
/// order per lane (`angle_normalize`'s `rem_euclid` is applied
/// per-lane: it is the one libm-backed op in this kernel). Bitwise
/// identical to [`dynamics`] per lane.
#[inline]
pub(crate) fn dynamics_lanes<const W: usize>(
    theta: F32s<W>,
    theta_dot: F32s<W>,
    action: F32s<W>,
) -> (F32s<W>, F32s<W>, F32s<W>) {
    let s = F32s::<W>::splat;
    dynamics_lanes_p(theta, theta_dot, action, s(G), s(M), s(L))
}

/// [`dynamics_p`] over a lane group: per-lane gravity/mass/length
/// vectors (broadcast constants when no override is set — the two
/// composite coefficients are rebuilt with the const expressions' op
/// order so the default is bitwise [`dynamics_lanes`]).
#[inline]
pub(crate) fn dynamics_lanes_p<const W: usize>(
    theta: F32s<W>,
    theta_dot: F32s<W>,
    action: F32s<W>,
    g: F32s<W>,
    m: F32s<W>,
    l: F32s<W>,
) -> (F32s<W>, F32s<W>, F32s<W>) {
    let s = F32s::<W>::splat;
    let u = action.clamp(-MAX_TORQUE, MAX_TORQUE);
    let th = F32s::from_fn(|i| angle_normalize(theta.0[i]));
    let cost = th * th + s(0.1) * theta_dot * theta_dot + s(0.001) * u * u;
    let swing = s(3.0) * g / (s(2.0) * l);
    let torque = s(3.0) / (m * l * l);
    let theta_dot = (theta_dot + (swing * theta.sin() + torque * u) * s(DT))
        .clamp(-MAX_SPEED, MAX_SPEED);
    let theta = theta + theta_dot * s(DT);
    (theta, theta_dot, cost)
}

/// The `[cos θ, sin θ, θ̇]` observation for one lane (shared by the
/// scalar env and every lane width of the SoA kernel).
#[inline]
pub(crate) fn write_obs(theta: f32, theta_dot: f32, obs: &mut [f32]) {
    let (sin_t, cos_t) = sin_cos_f32(theta);
    obs[0] = cos_t;
    obs[1] = sin_t;
    obs[2] = theta_dot;
}

impl Pendulum {
    pub fn new(seed: u64, env_id: u64) -> Self {
        Pendulum { spec: spec(), rng: rng(seed, env_id), theta: 0.0, theta_dot: 0.0, steps: 0 }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        write_obs(self.theta, self.theta_dot, obs);
    }
}

impl Env for Pendulum {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        (self.theta, self.theta_dot) = reset_state(&mut self.rng);
        self.steps = 0;
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        // Gym dynamics (theta measured from upright).
        let (theta, theta_dot, cost) = dynamics(self.theta, self.theta_dot, action[0]);
        self.theta = theta;
        self.theta_dot = theta_dot;
        self.steps += 1;
        self.write_obs(obs);
        Step {
            reward: -cost,
            done: false,
            truncated: self.steps >= self.spec.max_episode_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_nonpositive_and_bounded() {
        let mut env = Pendulum::new(0, 0);
        let mut obs = [0.0f32; 3];
        env.reset(&mut obs);
        for _ in 0..200 {
            let s = env.step(&[1.0], &mut obs);
            assert!(s.reward <= 0.0);
            // max cost = pi^2 + 0.1*64 + 0.001*4
            assert!(s.reward >= -(std::f32::consts::PI.powi(2) + 6.4 + 0.004) - 1e-4);
        }
    }

    #[test]
    fn obs_is_unit_circle() {
        let mut env = Pendulum::new(4, 1);
        let mut obs = [0.0f32; 3];
        env.reset(&mut obs);
        for _ in 0..100 {
            env.step(&[-2.0], &mut obs);
            let r = obs[0] * obs[0] + obs[1] * obs[1];
            assert!((r - 1.0).abs() < 1e-5);
            assert!(obs[2].abs() <= MAX_SPEED);
        }
    }

    #[test]
    fn truncates_never_terminates() {
        let mut env = Pendulum::new(8, 2);
        let mut obs = [0.0f32; 3];
        env.reset(&mut obs);
        for t in 0..200 {
            let s = env.step(&[0.0], &mut obs);
            assert!(!s.done);
            assert_eq!(s.truncated, t == 199);
        }
    }

    #[test]
    fn param_defaults_are_bitwise() {
        // Routing through the `_p` twins with broadcast defaults must
        // not move a single bit — the contract that lets SoaKernel use
        // them unconditionally. The composites used to be const-folded;
        // pin that rustc's const evaluation and the runtime recompute
        // agree exactly (black_box keeps the right side at runtime).
        use std::hint::black_box;
        const SWING: f32 = 3.0 * G / (2.0 * L);
        const TORQUE: f32 = 3.0 / (M * L * L);
        let (g, m, l) = (black_box(G), black_box(M), black_box(L));
        assert_eq!((3.0 * g / (2.0 * l)).to_bits(), SWING.to_bits());
        assert_eq!((3.0 / (m * l * l)).to_bits(), TORQUE.to_bits());
        let mut r = Pcg32::new(19, 0);
        for _ in 0..500 {
            let th = r.range(-4.0, 4.0);
            let td = r.range(-8.0, 8.0);
            let a = r.range(-2.5, 2.5);
            let want = dynamics(th, td, a);
            let got = dynamics_p(th, td, a, G, M, L);
            assert_eq!(got.0.to_bits(), want.0.to_bits());
            assert_eq!(got.1.to_bits(), want.1.to_bits());
            assert_eq!(got.2.to_bits(), want.2.to_bits());
            let s = F32s::<4>::splat;
            let lw = dynamics_lanes(s(th), s(td), s(a));
            let lg = dynamics_lanes_p(s(th), s(td), s(a), s(G), s(M), s(L));
            assert_eq!(lg.0 .0[0].to_bits(), lw.0 .0[0].to_bits());
            assert_eq!(lg.1 .0[0].to_bits(), lw.1 .0[0].to_bits());
            assert_eq!(lg.2 .0[0].to_bits(), lw.2 .0[0].to_bits());
        }
    }

    #[test]
    fn angle_normalize_range() {
        for i in -100..100 {
            let x = angle_normalize(i as f32 * 0.37);
            assert!((-std::f32::consts::PI..=std::f32::consts::PI).contains(&x));
        }
    }
}
