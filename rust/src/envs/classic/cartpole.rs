//! CartPole-v1: the classic pole-balancing task (Barto, Sutton & Anderson
//! 1983), with exactly the Gym dynamics and termination bounds.

use crate::envs::env::{discrete_action, Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;

const GRAVITY: f32 = 9.8;
const MASS_CART: f32 = 1.0;
const MASS_POLE: f32 = 0.1;
const TOTAL_MASS: f32 = MASS_CART + MASS_POLE;
const LENGTH: f32 = 0.5; // half pole length
const POLE_MASS_LENGTH: f32 = MASS_POLE * LENGTH;
const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02;
const THETA_LIMIT: f32 = 12.0 * std::f32::consts::PI / 180.0;
const X_LIMIT: f32 = 2.4;

/// CartPole environment. Observation `[x, x_dot, theta, theta_dot]`,
/// actions {push left, push right}, reward 1 per step while upright.
pub struct CartPole {
    spec: EnvSpec,
    rng: Pcg32,
    state: [f32; 4],
    steps: usize,
    needs_reset: bool,
}

impl CartPole {
    pub fn new(seed: u64, env_id: u64) -> Self {
        CartPole {
            spec: EnvSpec {
                id: "CartPole-v1".into(),
                obs_shape: vec![4],
                action_space: ActionSpace::Discrete(2),
                max_episode_steps: 500,
            },
            rng: Pcg32::new(seed, env_id),
            state: [0.0; 4],
            steps: 0,
            needs_reset: true,
        }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs[..4].copy_from_slice(&self.state);
    }
}

impl Env for CartPole {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        for s in &mut self.state {
            *s = self.rng.range(-0.05, 0.05);
        }
        self.steps = 0;
        self.needs_reset = false;
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        debug_assert!(!self.needs_reset, "step() after terminal without reset()");
        let a = discrete_action(action, 2);
        let force = if a == 1 { FORCE_MAG } else { -FORCE_MAG };
        let [x, x_dot, theta, theta_dot] = self.state;
        let (sin_t, cos_t) = theta.sin_cos();
        let temp = (force + POLE_MASS_LENGTH * theta_dot * theta_dot * sin_t) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin_t - cos_t * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LENGTH * theta_acc * cos_t / TOTAL_MASS;
        // Semi-explicit Euler, matching Gym's "euler" kinematics integrator.
        self.state = [
            x + TAU * x_dot,
            x_dot + TAU * x_acc,
            theta + TAU * theta_dot,
            theta_dot + TAU * theta_acc,
        ];
        self.steps += 1;

        let fell = self.state[0].abs() > X_LIMIT || self.state[2].abs() > THETA_LIMIT;
        let truncated = !fell && self.steps >= self.spec.max_episode_steps;
        self.needs_reset = fell || truncated;
        self.write_obs(obs);
        Step { reward: 1.0, done: fell, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_near_zero() {
        let mut env = CartPole::new(0, 0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut obs);
        assert!(obs.iter().all(|x| x.abs() <= 0.05));
    }

    #[test]
    fn constant_action_eventually_falls() {
        let mut env = CartPole::new(1, 0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut obs);
        let mut steps = 0;
        loop {
            let s = env.step(&[1.0], &mut obs);
            steps += 1;
            assert_eq!(s.reward, 1.0);
            if s.finished() {
                assert!(s.done, "pushing one way must terminate by falling, not truncation");
                break;
            }
            assert!(steps < 500, "should have fallen");
        }
        assert!(steps < 200, "constant push falls fast, took {steps}");
    }

    #[test]
    fn truncates_at_500() {
        // A crude balancing policy: push against the pole lean.
        let mut env = CartPole::new(2, 0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut obs);
        for t in 0..500 {
            let a = if obs[2] + 0.3 * obs[3] > 0.0 { 1.0 } else { 0.0 };
            let s = env.step(&[a], &mut obs);
            if s.finished() {
                assert!(t > 50, "balancer should survive a while, died at {t}");
                if s.truncated {
                    assert_eq!(t, 499);
                }
                return;
            }
        }
        panic!("episode must finish within 500 steps");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = CartPole::new(seed, 3);
            let mut obs = [0.0f32; 4];
            env.reset(&mut obs);
            let mut tot = 0.0;
            for i in 0..50 {
                let s = env.step(&[(i % 2) as f32], &mut obs);
                tot += s.reward + obs[0];
                if s.finished() {
                    env.reset(&mut obs);
                }
            }
            tot
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
