//! CartPole-v1: the classic pole-balancing task (Barto, Sutton & Anderson
//! 1983), with exactly the Gym dynamics and termination bounds.

use crate::envs::env::{discrete_action, Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;
use crate::simd::{math::sin_cos_f32, F32s, Mask};

pub(crate) const GRAVITY: f32 = 9.8;
const MASS_CART: f32 = 1.0;
const MASS_POLE: f32 = 0.1;
const TOTAL_MASS: f32 = MASS_CART + MASS_POLE;
pub(crate) const LENGTH: f32 = 0.5; // half pole length
pub(crate) const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02;
const THETA_LIMIT: f32 = 12.0 * std::f32::consts::PI / 180.0;
const X_LIMIT: f32 = 2.4;

/// Maximum episode length (shared with the SoA kernel).
pub(crate) const MAX_STEPS: usize = 500;

/// The push force for an action id (shared with the SoA kernel's lane
/// pass, which precomputes it per lane before [`dynamics_lanes`]).
#[inline]
pub(crate) fn force_for(action: usize) -> f32 {
    if action == 1 {
        FORCE_MAG
    } else {
        -FORCE_MAG
    }
}

/// [`force_for`] with an overridable push magnitude (scenario pools).
/// `force_for(a) == force_for_p(a, FORCE_MAG)` bitwise.
#[inline]
pub(crate) fn force_for_p(action: usize, force_mag: f32) -> f32 {
    if action == 1 {
        force_mag
    } else {
        -force_mag
    }
}

/// One semi-explicit Euler step of the cart-pole dynamics, matching
/// Gym's "euler" kinematics integrator. Shared by the scalar env and the
/// struct-of-arrays kernel in [`crate::envs::vector`] so the two paths
/// are bitwise identical. Trig goes through the deterministic shared
/// kernel ([`sin_cos_f32`]) — the same function the SIMD lane pass
/// applies per lane, which is what keeps every lane width bitwise equal
/// to this reference.
#[inline]
pub(crate) fn dynamics(state: [f32; 4], action: usize) -> [f32; 4] {
    dynamics_p(state, force_for(action), GRAVITY, LENGTH)
}

/// [`dynamics`] with overridable physics (scenario pools / domain
/// randomization): per-lane gravity and half pole length, plus the
/// caller-derived push `force` (±`force_mag`). The composite
/// `MASS_POLE * length` is recomputed here with the same single IEEE
/// multiply that const-folds `POLE_MASS_LENGTH`, so at the default
/// parameters this is bitwise identical to the constant path (pinned
/// by `param_defaults_are_bitwise` below).
#[inline]
pub(crate) fn dynamics_p(state: [f32; 4], force: f32, gravity: f32, length: f32) -> [f32; 4] {
    let pole_mass_length = MASS_POLE * length;
    let [x, x_dot, theta, theta_dot] = state;
    let (sin_t, cos_t) = sin_cos_f32(theta);
    let temp = (force + pole_mass_length * theta_dot * theta_dot * sin_t) / TOTAL_MASS;
    let theta_acc = (gravity * sin_t - cos_t * temp)
        / (length * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / TOTAL_MASS));
    let x_acc = temp - pole_mass_length * theta_acc * cos_t / TOTAL_MASS;
    [
        x + TAU * x_dot,
        x_dot + TAU * x_acc,
        theta + TAU * theta_dot,
        theta_dot + TAU * theta_acc,
    ]
}

/// [`dynamics`] over a lane group: the same operations in the same
/// order applied to `W` environments per instruction (`force` is the
/// per-lane ±`FORCE_MAG` the caller derived from the action ids).
/// Bitwise identical to [`dynamics`] per lane — pinned by a unit test
/// here and by `tests/simd_parity.rs` end to end.
#[inline]
pub(crate) fn dynamics_lanes<const W: usize>(
    state: [F32s<W>; 4],
    force: F32s<W>,
) -> [F32s<W>; 4] {
    let s = F32s::<W>::splat;
    dynamics_lanes_p(state, force, s(GRAVITY), s(LENGTH))
}

/// [`dynamics_p`] over a lane group: gravity and half length arrive as
/// per-lane vectors (broadcast constants when no override is set, so
/// the default is bitwise [`dynamics_lanes`]).
#[inline]
pub(crate) fn dynamics_lanes_p<const W: usize>(
    state: [F32s<W>; 4],
    force: F32s<W>,
    gravity: F32s<W>,
    length: F32s<W>,
) -> [F32s<W>; 4] {
    let s = F32s::<W>::splat;
    let pole_mass_length = s(MASS_POLE) * length;
    let [x, x_dot, theta, theta_dot] = state;
    let (sin_t, cos_t) = theta.sin_cos();
    let temp = (force + pole_mass_length * theta_dot * theta_dot * sin_t) / s(TOTAL_MASS);
    let theta_acc = (gravity * sin_t - cos_t * temp)
        / (length * (s(4.0 / 3.0) - s(MASS_POLE) * cos_t * cos_t / s(TOTAL_MASS)));
    let x_acc = temp - pole_mass_length * theta_acc * cos_t / s(TOTAL_MASS);
    [
        x + s(TAU) * x_dot,
        x_dot + s(TAU) * x_acc,
        theta + s(TAU) * theta_dot,
        theta_dot + s(TAU) * theta_acc,
    ]
}

/// [`fell`] over a lane group (same comparisons, lane-wise).
#[inline]
pub(crate) fn fell_lanes<const W: usize>(x: F32s<W>, theta: F32s<W>) -> Mask<W> {
    let s = F32s::<W>::splat;
    x.abs().gt(s(X_LIMIT)) | theta.abs().gt(s(THETA_LIMIT))
}

/// Termination test (cart off the track or pole past the angle limit).
#[inline]
pub(crate) fn fell(state: &[f32; 4]) -> bool {
    state[0].abs() > X_LIMIT || state[2].abs() > THETA_LIMIT
}

/// Fresh-episode state draw (RNG call order shared with the SoA kernel).
#[inline]
pub(crate) fn reset_state(rng: &mut Pcg32) -> [f32; 4] {
    let mut s = [0.0f32; 4];
    for x in &mut s {
        *x = rng.range(-0.05, 0.05);
    }
    s
}

/// CartPole environment. Observation `[x, x_dot, theta, theta_dot]`,
/// actions {push left, push right}, reward 1 per step while upright.
pub struct CartPole {
    spec: EnvSpec,
    rng: Pcg32,
    state: [f32; 4],
    steps: usize,
    needs_reset: bool,
}

/// The CartPole-v1 spec (shared with the SoA kernel).
pub(crate) fn spec() -> EnvSpec {
    EnvSpec {
        id: "CartPole-v1".into(),
        obs_shape: vec![4],
        action_space: ActionSpace::Discrete(2),
        max_episode_steps: MAX_STEPS,
        groups: vec![],
    }
}

/// Per-env RNG stream, keyed identically in the scalar and SoA paths.
/// CartPole predates family salting, so its salt is 0 (`seed ^ 0 ==
/// seed` keeps the historical streams bitwise).
#[inline]
pub(crate) fn rng(seed: u64, env_id: u64) -> Pcg32 {
    crate::rng::env_rng(seed, 0, env_id)
}

impl CartPole {
    pub fn new(seed: u64, env_id: u64) -> Self {
        CartPole {
            spec: spec(),
            rng: rng(seed, env_id),
            state: [0.0; 4],
            steps: 0,
            needs_reset: true,
        }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs[..4].copy_from_slice(&self.state);
    }
}

impl Env for CartPole {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.state = reset_state(&mut self.rng);
        self.steps = 0;
        self.needs_reset = false;
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        debug_assert!(!self.needs_reset, "step() after terminal without reset()");
        let a = discrete_action(action, 2);
        self.state = dynamics(self.state, a);
        self.steps += 1;

        let fell = fell(&self.state);
        let truncated = !fell && self.steps >= self.spec.max_episode_steps;
        self.needs_reset = fell || truncated;
        self.write_obs(obs);
        Step { reward: 1.0, done: fell, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_near_zero() {
        let mut env = CartPole::new(0, 0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut obs);
        assert!(obs.iter().all(|x| x.abs() <= 0.05));
    }

    #[test]
    fn constant_action_eventually_falls() {
        let mut env = CartPole::new(1, 0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut obs);
        let mut steps = 0;
        loop {
            let s = env.step(&[1.0], &mut obs);
            steps += 1;
            assert_eq!(s.reward, 1.0);
            if s.finished() {
                assert!(s.done, "pushing one way must terminate by falling, not truncation");
                break;
            }
            assert!(steps < 500, "should have fallen");
        }
        assert!(steps < 200, "constant push falls fast, took {steps}");
    }

    #[test]
    fn truncates_at_500() {
        // A crude balancing policy: push against the pole lean.
        let mut env = CartPole::new(2, 0);
        let mut obs = [0.0f32; 4];
        env.reset(&mut obs);
        for t in 0..500 {
            let a = if obs[2] + 0.3 * obs[3] > 0.0 { 1.0 } else { 0.0 };
            let s = env.step(&[a], &mut obs);
            if s.finished() {
                assert!(t > 50, "balancer should survive a while, died at {t}");
                if s.truncated {
                    assert_eq!(t, 499);
                }
                return;
            }
        }
        panic!("episode must finish within 500 steps");
    }

    #[test]
    fn lane_dynamics_bitwise_matches_scalar() {
        let mut rng = Pcg32::new(77, 0);
        for _ in 0..200 {
            let states: Vec<[f32; 4]> = (0..8)
                .map(|_| {
                    [
                        rng.range(-2.4, 2.4),
                        rng.range(-3.0, 3.0),
                        rng.range(-0.25, 0.25),
                        rng.range(-3.0, 3.0),
                    ]
                })
                .collect();
            for action in 0..2usize {
                let force =
                    F32s::<8>::splat(if action == 1 { FORCE_MAG } else { -FORCE_MAG });
                let lanes = [
                    F32s::<8>::from_fn(|i| states[i][0]),
                    F32s::<8>::from_fn(|i| states[i][1]),
                    F32s::<8>::from_fn(|i| states[i][2]),
                    F32s::<8>::from_fn(|i| states[i][3]),
                ];
                let out = dynamics_lanes(lanes, force);
                let fell_m = fell_lanes(out[0], out[2]);
                for (i, &st) in states.iter().enumerate() {
                    let want = dynamics(st, action);
                    for f in 0..4 {
                        assert_eq!(out[f].0[i].to_bits(), want[f].to_bits(), "lane {i} field {f}");
                    }
                    assert_eq!(fell_m.0[i], fell(&want), "lane {i}");
                }
            }
        }
    }

    #[test]
    fn param_defaults_are_bitwise() {
        // The parameterized twins at the default constants must equal
        // the constant path bit for bit — this is what lets the SoA
        // kernels route unconditionally through the `_p` functions
        // without breaking the no-scenario parity contract. The
        // `MASS_POLE * length` composite used to be const-folded; pin
        // that const evaluation and the runtime multiply agree exactly.
        const POLE_MASS_LENGTH: f32 = MASS_POLE * LENGTH;
        let length = std::hint::black_box(LENGTH);
        assert_eq!((MASS_POLE * length).to_bits(), POLE_MASS_LENGTH.to_bits());
        let mut rng = Pcg32::new(31, 0);
        for _ in 0..500 {
            let st = [
                rng.range(-2.4, 2.4),
                rng.range(-3.0, 3.0),
                rng.range(-0.25, 0.25),
                rng.range(-3.0, 3.0),
            ];
            for a in 0..2usize {
                let want = dynamics(st, a);
                let got = dynamics_p(st, force_for_p(a, FORCE_MAG), GRAVITY, LENGTH);
                for f in 0..4 {
                    assert_eq!(got[f].to_bits(), want[f].to_bits(), "field {f}");
                }
                let s = F32s::<4>::splat;
                let lanes = [s(st[0]), s(st[1]), s(st[2]), s(st[3])];
                let lw = dynamics_lanes(lanes, s(force_for(a)));
                let lg = dynamics_lanes_p(
                    lanes,
                    s(force_for_p(a, FORCE_MAG)),
                    s(GRAVITY),
                    s(LENGTH),
                );
                for f in 0..4 {
                    assert_eq!(lg[f].0[0].to_bits(), lw[f].0[0].to_bits(), "lane field {f}");
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = CartPole::new(seed, 3);
            let mut obs = [0.0f32; 4];
            env.reset(&mut obs);
            let mut tot = 0.0;
            for i in 0..50 {
                let s = env.step(&[(i % 2) as f32], &mut obs);
                tot += s.reward + obs[0];
                if s.finished() {
                    env.reset(&mut obs);
                }
            }
            tot
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
