//! MountainCar-v0 (Moore 1990), Gym dynamics: an underpowered car must
//! build momentum to reach the flag on the right hill.

use crate::envs::env::{discrete_action, Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;
use crate::simd::{math::cos_f32, F32s, Mask};

const MIN_POS: f32 = -1.2;
const MAX_POS: f32 = 0.6;
const MAX_SPEED: f32 = 0.07;
const GOAL_POS: f32 = 0.5;
pub(crate) const FORCE: f32 = 0.001;
pub(crate) const GRAVITY: f32 = 0.0025;

/// Maximum episode length (shared with the SoA kernel).
pub(crate) const MAX_STEPS: usize = 200;

/// The MountainCar-v0 spec (shared with the SoA kernel).
pub(crate) fn spec() -> EnvSpec {
    EnvSpec {
        id: "MountainCar-v0".into(),
        obs_shape: vec![2],
        action_space: ActionSpace::Discrete(3),
        max_episode_steps: MAX_STEPS,
        groups: vec![],
    }
}

/// Per-env RNG stream, keyed identically in the scalar and SoA paths
/// (family salt "mc").
#[inline]
pub(crate) fn rng(seed: u64, env_id: u64) -> Pcg32 {
    crate::rng::env_rng(seed, 0x6d63, env_id)
}

/// Fresh-episode position draw (velocity starts at 0).
#[inline]
pub(crate) fn reset_pos(rng: &mut Pcg32) -> f32 {
    rng.range(-0.6, -0.4)
}

/// One step of the mountain-car dynamics (Gym equations), shared by the
/// scalar env and the SoA kernel so both paths are bitwise identical
/// (cosine via the deterministic shared kernel the lane pass also uses).
#[inline]
pub(crate) fn dynamics(pos: f32, vel: f32, action: usize) -> (f32, f32) {
    dynamics_p(pos, vel, action, FORCE, GRAVITY)
}

/// [`dynamics`] with overridable push force and gravity (scenario
/// pools). Both enter the velocity update as direct multiplies, so the
/// defaults are trivially bitwise identical to the constant path.
#[inline]
pub(crate) fn dynamics_p(pos: f32, vel: f32, action: usize, force: f32, gravity: f32) -> (f32, f32) {
    let a = action as f32 - 1.0; // -1, 0, +1
    let mut vel = vel + a * force - gravity * cos_f32(3.0 * pos);
    vel = vel.clamp(-MAX_SPEED, MAX_SPEED);
    let pos = (pos + vel).clamp(MIN_POS, MAX_POS);
    if pos <= MIN_POS && vel < 0.0 {
        vel = 0.0; // inelastic left wall
    }
    (pos, vel)
}

/// [`dynamics`] over a lane group (`accel` is the per-lane `action − 1`
/// the caller derived from the action ids); bitwise identical per lane.
#[inline]
pub(crate) fn dynamics_lanes<const W: usize>(
    pos: F32s<W>,
    vel: F32s<W>,
    accel: F32s<W>,
) -> (F32s<W>, F32s<W>) {
    let s = F32s::<W>::splat;
    dynamics_lanes_p(pos, vel, accel, s(FORCE), s(GRAVITY))
}

/// [`dynamics_p`] over a lane group: per-lane force/gravity vectors
/// (broadcast constants when no override is set).
#[inline]
pub(crate) fn dynamics_lanes_p<const W: usize>(
    pos: F32s<W>,
    vel: F32s<W>,
    accel: F32s<W>,
    force: F32s<W>,
    gravity: F32s<W>,
) -> (F32s<W>, F32s<W>) {
    let s = F32s::<W>::splat;
    let vel = (vel + accel * force - gravity * (s(3.0) * pos).cos())
        .clamp(-MAX_SPEED, MAX_SPEED);
    let pos = (pos + vel).clamp(MIN_POS, MAX_POS);
    // inelastic left wall: vel = 0 where pos <= MIN_POS && vel < 0
    let wall = pos.le(s(MIN_POS)) & vel.lt(s(0.0));
    (pos, wall.select_f32(s(0.0), vel))
}

/// Goal test.
#[inline]
pub(crate) fn at_goal(pos: f32) -> bool {
    pos >= GOAL_POS
}

/// [`at_goal`] over a lane group.
#[inline]
pub(crate) fn at_goal_lanes<const W: usize>(pos: F32s<W>) -> Mask<W> {
    pos.ge(F32s::splat(GOAL_POS))
}

/// MountainCar environment. Observation `[position, velocity]`, actions
/// {push left, no-op, push right}, reward -1 per step until the goal.
pub struct MountainCar {
    spec: EnvSpec,
    rng: Pcg32,
    pos: f32,
    vel: f32,
    steps: usize,
}

impl MountainCar {
    pub fn new(seed: u64, env_id: u64) -> Self {
        MountainCar { spec: spec(), rng: rng(seed, env_id), pos: 0.0, vel: 0.0, steps: 0 }
    }
}

impl Env for MountainCar {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.pos = reset_pos(&mut self.rng);
        self.vel = 0.0;
        self.steps = 0;
        obs[0] = self.pos;
        obs[1] = self.vel;
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let a = discrete_action(action, 3);
        (self.pos, self.vel) = dynamics(self.pos, self.vel, a);
        self.steps += 1;
        let done = at_goal(self.pos);
        let truncated = !done && self.steps >= self.spec.max_episode_steps;
        obs[0] = self.pos;
        obs[1] = self.vel;
        Step { reward: -1.0, done, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_never_reaches_goal() {
        let mut env = MountainCar::new(0, 0);
        let mut obs = [0.0f32; 2];
        env.reset(&mut obs);
        for _ in 0..200 {
            let s = env.step(&[1.0], &mut obs);
            assert!(!s.done, "no-op cannot climb the hill");
            if s.truncated {
                return;
            }
        }
        panic!("must truncate at 200");
    }

    #[test]
    fn bang_bang_policy_reaches_goal() {
        // Energy pumping: push in the direction of current velocity.
        let mut env = MountainCar::new(3, 1);
        let mut obs = [0.0f32; 2];
        env.reset(&mut obs);
        for _ in 0..5 {
            for _ in 0..200 {
                let a = if obs[1] >= 0.0 { 2.0 } else { 0.0 };
                let s = env.step(&[a], &mut obs);
                if s.done {
                    assert!(obs[0] >= GOAL_POS);
                    return;
                }
                if s.truncated {
                    break;
                }
            }
            env.reset(&mut obs);
        }
        panic!("energy pumping should reach the flag within a few episodes");
    }

    #[test]
    fn lane_dynamics_bitwise_matches_scalar() {
        let mut rng = Pcg32::new(5, 9);
        for _ in 0..300 {
            let st: Vec<(f32, f32)> = (0..4)
                .map(|_| (rng.range(MIN_POS, MAX_POS), rng.range(-MAX_SPEED, MAX_SPEED)))
                .collect();
            for action in 0..3usize {
                let accel = F32s::<4>::splat(action as f32 - 1.0);
                let (p, v) = dynamics_lanes(
                    F32s::<4>::from_fn(|i| st[i].0),
                    F32s::<4>::from_fn(|i| st[i].1),
                    accel,
                );
                let goal = at_goal_lanes(p);
                for (i, &(pos, vel)) in st.iter().enumerate() {
                    let (wp, wv) = dynamics(pos, vel, action);
                    assert_eq!(p.0[i].to_bits(), wp.to_bits(), "lane {i}");
                    assert_eq!(v.0[i].to_bits(), wv.to_bits(), "lane {i}");
                    assert_eq!(goal.0[i], at_goal(wp), "lane {i}");
                }
            }
        }
    }

    #[test]
    fn velocity_bounded() {
        let mut env = MountainCar::new(9, 2);
        let mut obs = [0.0f32; 2];
        env.reset(&mut obs);
        for i in 0..500 {
            let s = env.step(&[(i % 3) as f32], &mut obs);
            assert!(obs[1].abs() <= MAX_SPEED + 1e-6);
            assert!((MIN_POS..=MAX_POS).contains(&obs[0]));
            if s.finished() {
                env.reset(&mut obs);
            }
        }
    }
}
