//! MountainCar-v0 (Moore 1990), Gym dynamics: an underpowered car must
//! build momentum to reach the flag on the right hill.

use crate::envs::env::{discrete_action, Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;

const MIN_POS: f32 = -1.2;
const MAX_POS: f32 = 0.6;
const MAX_SPEED: f32 = 0.07;
const GOAL_POS: f32 = 0.5;
const FORCE: f32 = 0.001;
const GRAVITY: f32 = 0.0025;

/// MountainCar environment. Observation `[position, velocity]`, actions
/// {push left, no-op, push right}, reward -1 per step until the goal.
pub struct MountainCar {
    spec: EnvSpec,
    rng: Pcg32,
    pos: f32,
    vel: f32,
    steps: usize,
}

impl MountainCar {
    pub fn new(seed: u64, env_id: u64) -> Self {
        MountainCar {
            spec: EnvSpec {
                id: "MountainCar-v0".into(),
                obs_shape: vec![2],
                action_space: ActionSpace::Discrete(3),
                max_episode_steps: 200,
            },
            rng: Pcg32::new(seed ^ 0x6d63, env_id),
            pos: 0.0,
            vel: 0.0,
            steps: 0,
        }
    }
}

impl Env for MountainCar {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.pos = self.rng.range(-0.6, -0.4);
        self.vel = 0.0;
        self.steps = 0;
        obs[0] = self.pos;
        obs[1] = self.vel;
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let a = discrete_action(action, 3) as f32 - 1.0; // -1, 0, +1
        self.vel += a * FORCE - GRAVITY * (3.0 * self.pos).cos();
        self.vel = self.vel.clamp(-MAX_SPEED, MAX_SPEED);
        self.pos += self.vel;
        self.pos = self.pos.clamp(MIN_POS, MAX_POS);
        if self.pos <= MIN_POS && self.vel < 0.0 {
            self.vel = 0.0; // inelastic left wall
        }
        self.steps += 1;
        let done = self.pos >= GOAL_POS;
        let truncated = !done && self.steps >= self.spec.max_episode_steps;
        obs[0] = self.pos;
        obs[1] = self.vel;
        Step { reward: -1.0, done, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_never_reaches_goal() {
        let mut env = MountainCar::new(0, 0);
        let mut obs = [0.0f32; 2];
        env.reset(&mut obs);
        for _ in 0..200 {
            let s = env.step(&[1.0], &mut obs);
            assert!(!s.done, "no-op cannot climb the hill");
            if s.truncated {
                return;
            }
        }
        panic!("must truncate at 200");
    }

    #[test]
    fn bang_bang_policy_reaches_goal() {
        // Energy pumping: push in the direction of current velocity.
        let mut env = MountainCar::new(3, 1);
        let mut obs = [0.0f32; 2];
        env.reset(&mut obs);
        for _ in 0..5 {
            for _ in 0..200 {
                let a = if obs[1] >= 0.0 { 2.0 } else { 0.0 };
                let s = env.step(&[a], &mut obs);
                if s.done {
                    assert!(obs[0] >= GOAL_POS);
                    return;
                }
                if s.truncated {
                    break;
                }
            }
            env.reset(&mut obs);
        }
        panic!("energy pumping should reach the flag within a few episodes");
    }

    #[test]
    fn velocity_bounded() {
        let mut env = MountainCar::new(9, 2);
        let mut obs = [0.0f32; 2];
        env.reset(&mut obs);
        for i in 0..500 {
            let s = env.step(&[(i % 3) as f32], &mut obs);
            assert!(obs[1].abs() <= MAX_SPEED + 1e-6);
            assert!((MIN_POS..=MAX_POS).contains(&obs[0]));
            if s.finished() {
                env.reset(&mut obs);
            }
        }
    }
}
