//! Acrobot-v1 (Sutton 1996): two-link underactuated pendulum, torque on
//! the second joint, swing the tip above the bar. Gym dynamics with RK4.

use crate::envs::env::{discrete_action, Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;
use crate::simd::math::{cos_f32, sin_cos_f32};
use crate::simd::{F32s, Mask};

const DT: f32 = 0.2;
const L1: f32 = 1.0;
const M1: f32 = 1.0;
const M2: f32 = 1.0;
const LC1: f32 = 0.5;
const LC2: f32 = 0.5;
const I1: f32 = 1.0;
const I2: f32 = 1.0;
const G: f32 = 9.8;
const MAX_VEL1: f32 = 4.0 * std::f32::consts::PI;
const MAX_VEL2: f32 = 9.0 * std::f32::consts::PI;

/// Acrobot environment. Observation
/// `[cosθ1, sinθ1, cosθ2, sinθ2, θ̇1, θ̇2]`, actions {-1, 0, +1} torque.
pub struct Acrobot {
    spec: EnvSpec,
    rng: Pcg32,
    /// `[theta1, theta2, dtheta1, dtheta2]`
    s: [f32; 4],
    steps: usize,
}

#[inline]
fn wrap(x: f32, lo: f32, hi: f32) -> f32 {
    lo + (x - lo).rem_euclid(hi - lo)
}

/// Equations of motion from Sutton & Barto / Gym `_dsdt` (trig via the
/// deterministic shared kernel the SIMD lane pass also uses).
fn dsdt(s: &[f32; 5]) -> [f32; 5] {
    let [theta1, theta2, dtheta1, dtheta2, a] = *s;
    let (sin_t2, cos_t2) = sin_cos_f32(theta2);
    let d1 = M1 * LC1 * LC1
        + M2 * (L1 * L1 + LC2 * LC2 + 2.0 * L1 * LC2 * cos_t2)
        + I1
        + I2;
    let d2 = M2 * (LC2 * LC2 + L1 * LC2 * cos_t2) + I2;
    let phi2 = M2 * LC2 * G * cos_f32(theta1 + theta2 - std::f32::consts::FRAC_PI_2);
    let phi1 = -M2 * L1 * LC2 * dtheta2 * dtheta2 * sin_t2
        - 2.0 * M2 * L1 * LC2 * dtheta2 * dtheta1 * sin_t2
        + (M1 * LC1 + M2 * L1) * G * cos_f32(theta1 - std::f32::consts::FRAC_PI_2)
        + phi2;
    let ddtheta2 = (a + d2 / d1 * phi1
        - M2 * L1 * LC2 * dtheta1 * dtheta1 * sin_t2
        - phi2)
        / (M2 * LC2 * LC2 + I2 - d2 * d2 / d1);
    let ddtheta1 = -(d2 * ddtheta2 + phi1) / d1;
    [dtheta1, dtheta2, ddtheta1, ddtheta2, 0.0]
}

/// [`dsdt`] over a lane group: the same operations in the same order,
/// `W` environments per instruction.
fn dsdt_lanes<const W: usize>(y: &[F32s<W>; 5]) -> [F32s<W>; 5] {
    let s = F32s::<W>::splat;
    let [theta1, theta2, dtheta1, dtheta2, a] = *y;
    let (sin_t2, cos_t2) = theta2.sin_cos();
    let pi2 = s(std::f32::consts::FRAC_PI_2);
    let d1 = s(M1 * LC1 * LC1)
        + s(M2) * (s(L1 * L1 + LC2 * LC2) + s(2.0 * L1 * LC2) * cos_t2)
        + s(I1)
        + s(I2);
    let d2 = s(M2) * (s(LC2 * LC2) + s(L1 * LC2) * cos_t2) + s(I2);
    let phi2 = s(M2 * LC2 * G) * (theta1 + theta2 - pi2).cos();
    let phi1 = s(-M2 * L1 * LC2) * dtheta2 * dtheta2 * sin_t2
        - s(2.0 * M2 * L1 * LC2) * dtheta2 * dtheta1 * sin_t2
        + s((M1 * LC1 + M2 * L1) * G) * (theta1 - pi2).cos()
        + phi2;
    let ddtheta2 = (a + d2 / d1 * phi1
        - s(M2 * L1 * LC2) * dtheta1 * dtheta1 * sin_t2
        - phi2)
        / (s(M2 * LC2 * LC2 + I2) - d2 * d2 / d1);
    let ddtheta1 = -(d2 * ddtheta2 + phi1) / d1;
    [dtheta1, dtheta2, ddtheta1, ddtheta2, s(0.0)]
}

/// One RK4 step of the augmented state (state + constant torque lane).
fn rk4(y0: [f32; 5], dt: f32) -> [f32; 5] {
    let add = |y: &[f32; 5], k: &[f32; 5], h: f32| {
        let mut o = [0.0f32; 5];
        for i in 0..5 {
            o[i] = y[i] + k[i] * h;
        }
        o
    };
    let k1 = dsdt(&y0);
    let k2 = dsdt(&add(&y0, &k1, dt / 2.0));
    let k3 = dsdt(&add(&y0, &k2, dt / 2.0));
    let k4 = dsdt(&add(&y0, &k3, dt));
    let mut out = y0;
    for i in 0..5 {
        out[i] = y0[i] + dt / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
    }
    out
}

/// [`rk4`] over a lane group (same Butcher weights, same op order).
fn rk4_lanes<const W: usize>(y0: [F32s<W>; 5], dt: f32) -> [F32s<W>; 5] {
    let s = F32s::<W>::splat;
    let add = |y: &[F32s<W>; 5], k: &[F32s<W>; 5], h: f32| {
        let mut o = [s(0.0); 5];
        for i in 0..5 {
            o[i] = y[i] + k[i] * s(h);
        }
        o
    };
    let k1 = dsdt_lanes(&y0);
    let k2 = dsdt_lanes(&add(&y0, &k1, dt / 2.0));
    let k3 = dsdt_lanes(&add(&y0, &k2, dt / 2.0));
    let k4 = dsdt_lanes(&add(&y0, &k3, dt));
    let mut out = y0;
    for i in 0..5 {
        out[i] = y0[i] + s(dt / 6.0) * (k1[i] + s(2.0) * k2[i] + s(2.0) * k3[i] + k4[i]);
    }
    out
}

/// Maximum episode length (shared with the SoA kernel).
pub(crate) const MAX_STEPS: usize = 500;

/// The Acrobot-v1 spec (shared with the SoA kernel).
pub(crate) fn spec() -> EnvSpec {
    EnvSpec {
        id: "Acrobot-v1".into(),
        obs_shape: vec![6],
        action_space: ActionSpace::Discrete(3),
        max_episode_steps: MAX_STEPS,
        groups: vec![],
    }
}

/// Per-env RNG stream, keyed identically in the scalar and SoA paths
/// (family salt "acr"). Acrobot intentionally exposes **no** scenario
/// parameter overrides: its `dsdt` core leans on many const-folded
/// composites (`M1 * LC1 * LC1`, moment-of-inertia sums, ...) whose
/// runtime recomputation could not be pinned bitwise without a
/// toolchain run, so overrides are rejected at scenario validation
/// (see `registry::supported_params`).
#[inline]
pub(crate) fn rng(seed: u64, env_id: u64) -> Pcg32 {
    crate::rng::env_rng(seed, 0x616372, env_id)
}

/// Fresh-episode state draw (RNG call order shared with the SoA kernel).
#[inline]
pub(crate) fn reset_state(rng: &mut Pcg32) -> [f32; 4] {
    let mut s = [0.0f32; 4];
    for x in &mut s {
        *x = rng.range(-0.1, 0.1);
    }
    s
}

/// One RK4 step + wrap/clamp of the acrobot state under a torque id in
/// {0, 1, 2}. Shared by the scalar env and the SoA kernel so both paths
/// are bitwise identical.
#[inline]
pub(crate) fn dynamics(s: [f32; 4], action: usize) -> [f32; 4] {
    let torque = action as f32 - 1.0;
    let y = rk4([s[0], s[1], s[2], s[3], torque], DT);
    [
        wrap(y[0], -std::f32::consts::PI, std::f32::consts::PI),
        wrap(y[1], -std::f32::consts::PI, std::f32::consts::PI),
        y[2].clamp(-MAX_VEL1, MAX_VEL1),
        y[3].clamp(-MAX_VEL2, MAX_VEL2),
    ]
}

/// [`dynamics`] over a lane group (`torque` is the per-lane
/// `action − 1`); bitwise identical to [`dynamics`] per lane. The
/// angle wrap is applied per-lane (`rem_euclid` is libm-backed), the
/// RK4 body is fully lane-parallel.
#[inline]
pub(crate) fn dynamics_lanes<const W: usize>(
    state: [F32s<W>; 4],
    torque: F32s<W>,
) -> [F32s<W>; 4] {
    let pi = std::f32::consts::PI;
    let y = rk4_lanes([state[0], state[1], state[2], state[3], torque], DT);
    [
        F32s::from_fn(|i| wrap(y[0].0[i], -pi, pi)),
        F32s::from_fn(|i| wrap(y[1].0[i], -pi, pi)),
        y[2].clamp(-MAX_VEL1, MAX_VEL1),
        y[3].clamp(-MAX_VEL2, MAX_VEL2),
    ]
}

/// Termination test: tip above the bar.
#[inline]
pub(crate) fn is_terminal(s: &[f32; 4]) -> bool {
    -cos_f32(s[0]) - cos_f32(s[1] + s[0]) > 1.0
}

/// [`is_terminal`] over a lane group.
#[inline]
pub(crate) fn is_terminal_lanes<const W: usize>(
    theta1: F32s<W>,
    theta2: F32s<W>,
) -> Mask<W> {
    let one = F32s::<W>::splat(1.0);
    (-theta1.cos() - (theta2 + theta1).cos()).gt(one)
}

/// The 6-dim observation for one lane (shared by the scalar env and
/// every lane width of the SoA kernel).
#[inline]
pub(crate) fn write_obs(s: &[f32; 4], obs: &mut [f32]) {
    let (sin_1, cos_1) = sin_cos_f32(s[0]);
    let (sin_2, cos_2) = sin_cos_f32(s[1]);
    obs[0] = cos_1;
    obs[1] = sin_1;
    obs[2] = cos_2;
    obs[3] = sin_2;
    obs[4] = s[2];
    obs[5] = s[3];
}

impl Acrobot {
    pub fn new(seed: u64, env_id: u64) -> Self {
        Acrobot { spec: spec(), rng: rng(seed, env_id), s: [0.0; 4], steps: 0 }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        write_obs(&self.s, obs);
    }

    fn terminal(&self) -> bool {
        is_terminal(&self.s)
    }
}

impl Env for Acrobot {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.s = reset_state(&mut self.rng);
        self.steps = 0;
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        self.s = dynamics(self.s, discrete_action(action, 3));
        self.steps += 1;
        let done = self.terminal();
        let truncated = !done && self.steps >= self.spec.max_episode_steps;
        self.write_obs(obs);
        Step { reward: if done { 0.0 } else { -1.0 }, done, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_hanging_down() {
        let mut env = Acrobot::new(0, 0);
        let mut obs = [0.0f32; 6];
        env.reset(&mut obs);
        // theta near 0 => cos near 1 (hanging), not terminal.
        assert!(obs[0] > 0.99);
        assert!(!env.terminal());
    }

    #[test]
    fn velocities_clamped() {
        let mut env = Acrobot::new(1, 1);
        let mut obs = [0.0f32; 6];
        env.reset(&mut obs);
        for _ in 0..500 {
            let s = env.step(&[2.0], &mut obs);
            assert!(obs[4].abs() <= MAX_VEL1 + 1e-4);
            assert!(obs[5].abs() <= MAX_VEL2 + 1e-4);
            if s.finished() {
                env.reset(&mut obs);
            }
        }
    }

    #[test]
    fn lane_dynamics_bitwise_matches_scalar() {
        let mut rng = Pcg32::new(13, 2);
        for _ in 0..100 {
            let states: Vec<[f32; 4]> = (0..8)
                .map(|_| {
                    [
                        rng.range(-std::f32::consts::PI, std::f32::consts::PI),
                        rng.range(-std::f32::consts::PI, std::f32::consts::PI),
                        rng.range(-MAX_VEL1, MAX_VEL1),
                        rng.range(-MAX_VEL2, MAX_VEL2),
                    ]
                })
                .collect();
            for action in 0..3usize {
                let torque = F32s::<8>::splat(action as f32 - 1.0);
                let lanes = std::array::from_fn(|f| F32s::<8>::from_fn(|i| states[i][f]));
                let out = dynamics_lanes(lanes, torque);
                let term = is_terminal_lanes(out[0], out[1]);
                for (i, &st) in states.iter().enumerate() {
                    let want = dynamics(st, action);
                    for f in 0..4 {
                        assert_eq!(out[f].0[i].to_bits(), want[f].to_bits(), "lane {i} field {f}");
                    }
                    assert_eq!(term.0[i], is_terminal(&want), "lane {i}");
                }
            }
        }
    }

    #[test]
    fn energy_pumping_solves() {
        // Torque with the second link's velocity direction pumps energy.
        let mut env = Acrobot::new(5, 2);
        let mut obs = [0.0f32; 6];
        env.reset(&mut obs);
        for _ in 0..3 {
            for _ in 0..500 {
                let a = if obs[5] >= 0.0 { 2.0 } else { 0.0 };
                let s = env.step(&[a], &mut obs);
                if s.done {
                    assert_eq!(s.reward, 0.0);
                    return;
                }
                if s.truncated {
                    break;
                }
            }
            env.reset(&mut obs);
        }
        panic!("pumping should raise the tip within 3 episodes");
    }
}
