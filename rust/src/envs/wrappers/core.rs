//! Wrapper *cores*: the single source of truth for each wrapper's math.
//!
//! Both wrapper surfaces — the scalar [`crate::envs::env::Env`] wrappers
//! and the batch-wise [`super::vec`] (`VecWrapper`) layer — are thin
//! adapters over these cores: a scalar wrapper is exactly the one-lane
//! use of the same state machine the vectorized wrapper runs per lane.
//! This is what makes `ExecMode::Scalar` and `ExecMode::Vectorized`
//! bitwise-identical through a wrapped stack (pinned by
//! `tests/wrapper_parity.rs`): there are no two implementations to
//! drift apart.

use crate::envs::env::Step;

/// Clip a reward to its sign (`{-1, 0, +1}`), the DQN/Atari convention.
#[inline]
pub fn clip_reward(r: f32) -> f32 {
    if r > 0.0 {
        1.0
    } else if r < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Apply a time limit to a step result: after `t` steps of an episode,
/// a non-terminal transition at or past `limit` becomes truncated
/// (termination wins over truncation, as in Gym v26 / EnvPool).
#[inline]
pub fn apply_time_limit(s: &mut Step, t: usize, limit: usize) {
    if !s.done && t >= limit {
        s.truncated = true;
    }
}

/// Per-dimension running mean/variance (Welford) observation normalizer —
/// one lane's statistics. Scalar [`super::NormalizeObs`] owns one;
/// [`super::vec::NormalizeObsVec`] owns one per lane (or one shared
/// across lanes in shared-stats mode).
#[derive(Debug, Clone)]
pub struct RunningNorm {
    count: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
    frozen: bool,
    clip: f32,
}

impl RunningNorm {
    /// Fresh statistics for `dim`-dimensional observations.
    pub fn new(dim: usize) -> Self {
        RunningNorm {
            count: 1e-4,
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
            frozen: false,
            clip: 10.0,
        }
    }

    /// Stop (or resume) updating statistics — freeze for evaluation.
    pub fn freeze(&mut self, on: bool) {
        self.frozen = on;
    }

    /// Current per-dimension running means (test/diagnostic hook).
    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// Welford-update the statistics with `obs` (unless frozen), then
    /// normalize `obs` in place to ~N(0,1) clipped to `±clip`.
    pub fn update_and_normalize(&mut self, obs: &mut [f32]) {
        if !self.frozen {
            self.count += 1.0;
            for (i, &x) in obs.iter().enumerate() {
                let d = x as f64 - self.mean[i];
                self.mean[i] += d / self.count;
                self.m2[i] += d * (x as f64 - self.mean[i]);
            }
        }
        for (i, x) in obs.iter_mut().enumerate() {
            let var = (self.m2[i] / self.count).max(1e-8);
            *x = (((*x as f64 - self.mean[i]) / var.sqrt()) as f32).clamp(-self.clip, self.clip);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_reward_is_sign() {
        assert_eq!(clip_reward(7.25), 1.0);
        assert_eq!(clip_reward(-0.01), -1.0);
        assert_eq!(clip_reward(0.0), 0.0);
    }

    #[test]
    fn time_limit_truncates_only_non_terminal() {
        let mut s = Step::default();
        apply_time_limit(&mut s, 3, 5);
        assert!(!s.truncated);
        apply_time_limit(&mut s, 5, 5);
        assert!(s.truncated && !s.done);
        let mut done = Step { reward: 0.0, done: true, truncated: false };
        apply_time_limit(&mut done, 9, 5);
        assert!(done.done && !done.truncated, "termination wins over truncation");
    }

    #[test]
    fn running_norm_centers_a_constant_stream() {
        let mut n = RunningNorm::new(2);
        let mut last = [0.0f32; 2];
        for _ in 0..500 {
            let mut obs = [3.0f32, -2.0];
            n.update_and_normalize(&mut obs);
            last = obs;
        }
        // A constant stream normalizes to ~0 once the mean converges.
        assert!(last[0].abs() < 0.1 && last[1].abs() < 0.1, "{last:?}");
    }

    #[test]
    fn freeze_stops_updates_but_keeps_normalizing() {
        let mut n = RunningNorm::new(1);
        for i in 0..100 {
            n.update_and_normalize(&mut [i as f32]);
        }
        n.freeze(true);
        let mean = n.mean().to_vec();
        let mut a = [5.0f32];
        n.update_and_normalize(&mut a);
        assert_eq!(mean, n.mean());
        assert!(a[0].is_finite());
    }
}
