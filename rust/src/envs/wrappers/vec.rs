//! The `VecWrapper` layer: batch-wise wrappers over [`VecEnv`] backends.
//!
//! With `ExecMode::Vectorized` a whole chunk of environments is stepped
//! by one kernel call, so wrappers must operate batch-wise too — a
//! per-env scalar wrapper around a SoA kernel would reintroduce exactly
//! the per-env dispatch the kernel amortizes away. Each wrapper here
//! implements [`VecEnv`] over an inner [`VecEnv`], keeping per-lane
//! state in parallel arrays and post-processing the whole batch after
//! one `step_batch` call:
//!
//! - [`TimeLimitVec`] — per-lane step counters; truncates non-terminal
//!   transitions at the limit (termination wins).
//! - [`RewardClipVec`] — clips every lane's reward to its sign.
//! - [`NormalizeObsVec`] — Welford running-stat normalization applied
//!   to each lane's observation row *in place* in the [`ObsArena`]
//!   (a state-queue slot on the pool path — the zero-copy invariant
//!   survives wrapping). Statistics are per-lane by default, which
//!   makes the stack bitwise-identical to per-env scalar wrappers; the
//!   [`NormalizeObsVec::new_shared`] variant pools one statistic across
//!   all lanes of the batch (gym `VecNormalize`-style), updated in lane
//!   order so runs stay deterministic for a fixed chunking. Selected via
//!   `WrapConfig::normalize_obs_shared` (and
//!   `TrainConfig::normalize_obs_shared` from the trainer) — vectorized
//!   exec mode only, since a scalar env has no batch to share.
//!
//! The math lives in [`super::core`], shared with the scalar wrappers —
//! the scalar surface is the one-lane adapter over the same cores, so
//! `registry::make_env_wrapped` and `registry::make_vec_env_wrapped`
//! compose the exact same stack in both exec modes.
//!
//! Auto-reset contract: lanes with `reset_mask[lane] != 0` are reset by
//! the innermost kernel and report `Step::default()`; wrappers must
//! reset their per-lane state for those lanes (and, for normalization,
//! still transform the fresh observation — matching what the scalar
//! wrapper's `reset` does).

use super::core::{apply_time_limit, clip_reward, RunningNorm};
use crate::envs::env::Step;
use crate::envs::spec::EnvSpec;
use crate::envs::vector::{ObsArena, VecEnv};

/// Batch-wise time limit: truncate every lane's episode at `limit` steps.
pub struct TimeLimitVec {
    inner: Box<dyn VecEnv>,
    spec: EnvSpec,
    limit: usize,
    t: Vec<u32>,
}

impl TimeLimitVec {
    pub fn new(inner: Box<dyn VecEnv>, limit: usize) -> Self {
        let mut spec = inner.spec().clone();
        // Tighten-only, as the scalar adapter does: the inner kernel
        // still truncates at its native limit.
        spec.max_episode_steps = spec.max_episode_steps.min(limit);
        let t = vec![0; inner.num_envs()];
        TimeLimitVec { inner, spec, limit, t }
    }
}

impl VecEnv for TimeLimitVec {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }

    fn set_lane_pass(&mut self, lane_pass: crate::simd::LanePass) {
        self.inner.set_lane_pass(lane_pass);
    }

    fn param_names(&self) -> &'static [&'static str] {
        self.inner.param_names()
    }

    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        self.inner.set_param_lanes(name, values)
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.t[lane] = 0;
        self.inner.reset_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        self.inner.step_batch(actions, reset_mask, arena, out);
        for lane in 0..out.len() {
            if reset_mask[lane] != 0 {
                self.t[lane] = 0;
                continue;
            }
            self.t[lane] += 1;
            apply_time_limit(&mut out[lane], self.t[lane] as usize, self.limit);
        }
    }
}

/// Batch-wise reward clipping to `{-1, 0, +1}`.
pub struct RewardClipVec {
    inner: Box<dyn VecEnv>,
}

impl RewardClipVec {
    pub fn new(inner: Box<dyn VecEnv>) -> Self {
        RewardClipVec { inner }
    }
}

impl VecEnv for RewardClipVec {
    fn spec(&self) -> &EnvSpec {
        self.inner.spec()
    }

    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }

    fn set_lane_pass(&mut self, lane_pass: crate::simd::LanePass) {
        self.inner.set_lane_pass(lane_pass);
    }

    fn param_names(&self) -> &'static [&'static str] {
        self.inner.param_names()
    }

    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        self.inner.set_param_lanes(name, values)
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.inner.reset_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        self.inner.step_batch(actions, reset_mask, arena, out);
        for s in out.iter_mut() {
            // Reset lanes carry reward 0, which clips to 0 — harmless.
            s.reward = clip_reward(s.reward);
        }
    }
}

/// Per-lane (or shared) running statistics behind [`NormalizeObsVec`].
enum Stats {
    /// One independent statistic per lane — bitwise-identical to a
    /// per-env scalar [`super::NormalizeObs`] stack (the default, and
    /// what `ExecMode` parity requires).
    PerLane(Vec<RunningNorm>),
    /// One statistic pooled across all lanes, updated in lane order
    /// (deterministic for a fixed chunking; batches mix faster).
    Shared(RunningNorm),
}

/// Batch-wise running observation normalization.
pub struct NormalizeObsVec {
    inner: Box<dyn VecEnv>,
    stats: Stats,
}

impl NormalizeObsVec {
    /// Per-lane statistics (matches per-env scalar wrappers bitwise).
    pub fn new(inner: Box<dyn VecEnv>) -> Self {
        let dim = inner.spec().obs_dim();
        let lanes = inner.num_envs();
        let stats = Stats::PerLane((0..lanes).map(|_| RunningNorm::new(dim)).collect());
        NormalizeObsVec { inner, stats }
    }

    /// One statistic shared by every lane of the batch.
    pub fn new_shared(inner: Box<dyn VecEnv>) -> Self {
        let dim = inner.spec().obs_dim();
        NormalizeObsVec { inner, stats: Stats::Shared(RunningNorm::new(dim)) }
    }

    /// Freeze/unfreeze statistics (for evaluation).
    pub fn freeze(&mut self, on: bool) {
        match &mut self.stats {
            Stats::PerLane(ns) => {
                for n in ns {
                    n.freeze(on);
                }
            }
            Stats::Shared(n) => n.freeze(on),
        }
    }

    fn normalize_lane(&mut self, lane: usize, obs: &mut [f32]) {
        match &mut self.stats {
            Stats::PerLane(ns) => ns[lane].update_and_normalize(obs),
            Stats::Shared(n) => n.update_and_normalize(obs),
        }
    }
}

impl VecEnv for NormalizeObsVec {
    fn spec(&self) -> &EnvSpec {
        self.inner.spec()
    }

    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }

    fn set_lane_pass(&mut self, lane_pass: crate::simd::LanePass) {
        self.inner.set_lane_pass(lane_pass);
    }

    fn param_names(&self) -> &'static [&'static str] {
        self.inner.param_names()
    }

    fn set_param_lanes(&mut self, name: &str, values: &[f32]) -> bool {
        self.inner.set_param_lanes(name, values)
    }

    fn reset_lane(&mut self, lane: usize, obs: &mut [f32]) {
        self.inner.reset_lane(lane, obs);
        self.normalize_lane(lane, obs);
    }

    fn step_batch(
        &mut self,
        actions: &[f32],
        reset_mask: &[u8],
        arena: &mut dyn ObsArena,
        out: &mut [Step],
    ) {
        self.inner.step_batch(actions, reset_mask, arena, out);
        // Every lane got a fresh observation (stepped or auto-reset);
        // normalize each row in place in its final destination.
        for lane in 0..out.len() {
            let row = arena.row(lane);
            self.normalize_lane(lane, row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry;
    use crate::envs::vector::SliceArena;

    fn pendulum_vec(n: usize) -> Box<dyn VecEnv> {
        registry::make_vec_env("Pendulum-v1", 3, 0, n).unwrap()
    }

    fn drive(env: &mut dyn VecEnv, steps: usize) -> (Vec<f32>, Vec<Step>) {
        let n = env.num_envs();
        let dim = env.spec().obs_dim();
        let adim = env.spec().action_space.dim();
        let mut obs = vec![0.0f32; n * dim];
        for lane in 0..n {
            env.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
        }
        let mut mask = vec![0u8; n];
        let mut out = vec![Step::default(); n];
        let mut obs_trace = Vec::new();
        let mut step_trace = Vec::new();
        for t in 0..steps {
            let actions: Vec<f32> = (0..n * adim).map(|k| ((t + k) % 3) as f32 - 1.0).collect();
            {
                let mut arena = SliceArena::new(&mut obs, dim);
                env.step_batch(&actions, &mask, &mut arena, &mut out);
            }
            for lane in 0..n {
                mask[lane] = out[lane].finished() as u8;
            }
            obs_trace.extend_from_slice(&obs);
            step_trace.extend_from_slice(&out);
        }
        (obs_trace, step_trace)
    }

    #[test]
    fn time_limit_vec_truncates_every_lane() {
        let mut env = TimeLimitVec::new(pendulum_vec(3), 5);
        assert_eq!(env.spec().max_episode_steps, 5);
        let (_, steps) = drive(&mut env, 12);
        // Per-lane schedule: steps 0..4 run, step 4 truncates, step 5 is
        // the auto-reset row, then the clock restarts.
        for lane in 0..3 {
            for t in 0..12 {
                let s = steps[t * 3 + lane];
                let phase = t % 6;
                assert_eq!(s.truncated, phase == 4, "lane {lane} t {t}");
                assert!(!s.done, "pendulum never terminates");
            }
        }
    }

    #[test]
    fn reward_clip_vec_bounds_rewards() {
        let mut env = RewardClipVec::new(pendulum_vec(2));
        let (_, steps) = drive(&mut env, 30);
        assert!(steps.iter().all(|s| s.reward == -1.0 || s.reward == 0.0));
        assert!(steps.iter().any(|s| s.reward == -1.0), "pendulum costs are negative");
    }

    #[test]
    fn normalize_obs_vec_keeps_obs_bounded_and_is_deterministic() {
        let run = |shared: bool| {
            let mut env = if shared {
                NormalizeObsVec::new_shared(pendulum_vec(2))
            } else {
                NormalizeObsVec::new(pendulum_vec(2))
            };
            drive(&mut env, 50)
        };
        for shared in [false, true] {
            let (obs, _) = run(shared);
            assert!(obs.iter().all(|x| x.abs() <= 10.0 && x.is_finite()));
            assert_eq!(run(shared).0, obs, "shared={shared} must be deterministic");
        }
        // Shared stats mix lanes, so the two modes genuinely differ.
        assert_ne!(run(false).0, run(true).0);
    }

    #[test]
    fn wrappers_preserve_lane_count_and_spec_id() {
        let env = TimeLimitVec::new(
            Box::new(RewardClipVec::new(Box::new(NormalizeObsVec::new(pendulum_vec(4))))),
            99,
        );
        assert_eq!(env.num_envs(), 4);
        assert_eq!(env.spec().id, "Pendulum-v1");
        assert_eq!(env.spec().max_episode_steps, 99);
    }
}
