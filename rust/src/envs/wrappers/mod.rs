//! Environment wrappers, implemented on the C++^W Rust side as in
//! EnvPool (the paper optimizes the "well-established Python wrappers"
//! inside the engine): time limits, reward clipping, observation
//! normalization. Frame stacking and episodic life live inside
//! [`crate::envs::atari`] where they belong to the preprocessing stack.
//!
//! The wrapper logic has a single source of truth, [`core`]; it is
//! surfaced twice:
//!
//! - batch-wise, as the [`vec`] (`VecWrapper`) layer over [`crate::envs::vector::VecEnv`]
//!   backends — the primary form, used by `ExecMode::Vectorized` chunks;
//! - per-env, as thin one-lane adapters over the same cores
//!   ([`TimeLimit`], [`RewardClip`], [`NormalizeObs`]) — used by
//!   `ExecMode::Scalar` and the baseline executors.
//!
//! `registry::make_env_wrapped` / `registry::make_vec_env_wrapped`
//! compose identical stacks from a shared `WrapConfig`, so switching
//! `ExecMode` never changes semantics (`tests/wrapper_parity.rs`).

pub mod core;
pub mod time_limit;
pub mod reward_clip;
pub mod normalize_obs;
pub mod vec;

pub use normalize_obs::NormalizeObs;
pub use reward_clip::RewardClip;
pub use time_limit::TimeLimit;
pub use vec::{NormalizeObsVec, RewardClipVec, TimeLimitVec};
