//! Environment wrappers, implemented on the C++^W Rust side as in
//! EnvPool (the paper optimizes the "well-established Python wrappers"
//! inside the engine): time limits, reward clipping, observation
//! normalization. Frame stacking and episodic life live inside
//! [`crate::envs::atari`] where they belong to the preprocessing stack.

pub mod time_limit;
pub mod reward_clip;
pub mod normalize_obs;

pub use normalize_obs::NormalizeObs;
pub use reward_clip::RewardClip;
pub use time_limit::TimeLimit;
