//! Running observation normalization (Welford), the MuJoCo-PPO staple.
//! Kept on the env side so the policy network always sees ~N(0,1) inputs;
//! statistics update only during training (freeze for evaluation).
//! One-lane adapter over [`super::core::RunningNorm`] — the batch-wise
//! [`super::vec::NormalizeObsVec`] runs the identical core per lane.

use super::core::RunningNorm;
use crate::envs::env::{Env, Step};
use crate::envs::spec::EnvSpec;

/// Per-dimension running mean/var normalizer wrapper.
pub struct NormalizeObs<E: Env> {
    env: E,
    norm: RunningNorm,
}

impl<E: Env> NormalizeObs<E> {
    pub fn new(env: E) -> Self {
        let dim = env.spec().obs_dim();
        NormalizeObs { env, norm: RunningNorm::new(dim) }
    }

    /// Stop updating statistics (for evaluation).
    pub fn freeze(&mut self, on: bool) {
        self.norm.freeze(on);
    }
}

impl<E: Env> Env for NormalizeObs<E> {
    fn spec(&self) -> &EnvSpec {
        self.env.spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.env.reset(obs);
        self.norm.update_and_normalize(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let s = self.env.step(action, obs);
        self.norm.update_and_normalize(obs);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::classic::Pendulum;

    #[test]
    fn normalized_obs_have_sane_scale() {
        let mut env = NormalizeObs::new(Pendulum::new(0, 0));
        let mut obs = vec![0.0; 3];
        env.reset(&mut obs);
        let mut sum = vec![0.0f64; 3];
        let mut n = 0.0;
        for i in 0..2000 {
            let s = env.step(&[((i % 7) as f32 - 3.0) / 2.0], &mut obs);
            for (k, &x) in obs.iter().enumerate() {
                assert!(x.abs() <= 10.0);
                sum[k] += x as f64;
            }
            n += 1.0;
            if s.finished() {
                env.reset(&mut obs);
            }
        }
        for &s in &sum {
            assert!((s / n).abs() < 0.5, "running normalization should near-center, got {}", s / n);
        }
    }

    #[test]
    fn freeze_stops_updates() {
        let mut env = NormalizeObs::new(Pendulum::new(1, 0));
        let mut obs = vec![0.0; 3];
        env.reset(&mut obs);
        for _ in 0..100 {
            env.step(&[1.0], &mut obs);
        }
        env.freeze(true);
        let mean_before = env.norm.mean().to_vec();
        for _ in 0..100 {
            env.step(&[1.0], &mut obs);
        }
        assert_eq!(mean_before, env.norm.mean());
    }
}
