//! TimeLimit wrapper: truncates episodes after a step budget, overriding
//! (tightening) whatever limit the inner env carries. One-lane adapter
//! over [`super::core::apply_time_limit`] — the same rule the batch-wise
//! [`super::vec::TimeLimitVec`] applies per lane, so the two exec modes
//! cannot drift apart.

use super::core::apply_time_limit;
use crate::envs::env::{Env, Step};
use crate::envs::spec::EnvSpec;

/// Truncate episodes at `limit` steps.
pub struct TimeLimit<E: Env> {
    env: E,
    spec: EnvSpec,
    limit: usize,
    t: usize,
}

impl<E: Env> TimeLimit<E> {
    pub fn new(env: E, limit: usize) -> Self {
        let mut spec = env.spec().clone();
        // The wrapper can only tighten — the inner env keeps truncating
        // at its native limit — so advertise the effective minimum.
        spec.max_episode_steps = spec.max_episode_steps.min(limit);
        TimeLimit { env, spec, limit, t: 0 }
    }
}

impl<E: Env> Env for TimeLimit<E> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.t = 0;
        self.env.reset(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let mut s = self.env.step(action, obs);
        self.t += 1;
        apply_time_limit(&mut s, self.t, self.limit);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::classic::Pendulum;

    #[test]
    fn truncates_early() {
        let mut env = TimeLimit::new(Pendulum::new(0, 0), 10);
        assert_eq!(env.spec().max_episode_steps, 10);
        let mut obs = vec![0.0; 3];
        env.reset(&mut obs);
        for t in 0..10 {
            let s = env.step(&[0.0], &mut obs);
            assert_eq!(s.truncated, t == 9);
            assert!(!s.done);
        }
    }

    #[test]
    fn reset_restarts_the_clock() {
        let mut env = TimeLimit::new(Pendulum::new(1, 0), 5);
        let mut obs = vec![0.0; 3];
        env.reset(&mut obs);
        for _ in 0..5 {
            env.step(&[0.0], &mut obs);
        }
        env.reset(&mut obs);
        let s = env.step(&[0.0], &mut obs);
        assert!(!s.truncated);
    }
}
