//! Reward clipping to `{-1, 0, +1}` via `sign(r)` — the DQN/Atari
//! convention the paper's training runs use. One-lane adapter over
//! [`super::core::clip_reward`], shared with the batch-wise
//! [`super::vec::RewardClipVec`].

use super::core::clip_reward;
use crate::envs::env::{Env, Step};
use crate::envs::spec::EnvSpec;

/// Clip rewards to their sign.
pub struct RewardClip<E: Env> {
    env: E,
}

impl<E: Env> RewardClip<E> {
    pub fn new(env: E) -> Self {
        RewardClip { env }
    }
}

impl<E: Env> Env for RewardClip<E> {
    fn spec(&self) -> &EnvSpec {
        self.env.spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.env.reset(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let mut s = self.env.step(action, obs);
        s.reward = clip_reward(s.reward);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::atari::preproc;

    #[test]
    fn breakout_rewards_become_unit() {
        // Breakout row scores are 1/4/7 — clipped they must be exactly 1.
        let mut env = RewardClip::new(preproc::breakout(3, 0));
        let mut obs = vec![0.0; env.spec().obs_dim()];
        env.reset(&mut obs);
        let mut saw_one = false;
        for _ in 0..10_000 {
            let s = env.step(&[1.0], &mut obs);
            assert!(s.reward == 0.0 || s.reward == 1.0 || s.reward == -1.0);
            if s.reward == 1.0 {
                saw_one = true;
            }
            if s.finished() {
                env.reset(&mut obs);
            }
        }
        assert!(saw_one, "FIRE-spam should break at least one brick");
    }
}
