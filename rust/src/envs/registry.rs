//! Task registry: `make_env("Pong-v5", seed, env_id)` — the Rust analog
//! of `envpool.make(task_id, ...)`. Every supported task id is listed in
//! [`ALL_TASKS`]; specs are obtainable without constructing an env.
//!
//! The registry builds both execution surfaces from one table:
//! [`make_env`] (scalar) and [`make_vec_env`] (batched — every task maps
//! to a real kernel, see [`crate::envs::vector`]), plus the `_wrapped`
//! variants which compose the standard wrapper stack identically in both
//! modes from a shared [`WrapConfig`].

use super::atari::preproc;
use super::classic::{Acrobot, CartPole, MountainCar, Pendulum};
use super::dmc::CheetahRun;
use super::env::Env;
use super::mujoco::walker::{Task, WalkerEnv};
use super::spec::EnvSpec;
use super::vector::atari::{breakout_vec, pong_vec};
use super::vector::{
    AcrobotVec, CartPoleVec, CheetahRunVec, MountainCarVec, PendulumVec, VecEnv, WalkerVec,
};
use super::wrappers::{
    NormalizeObs, NormalizeObsVec, RewardClip, RewardClipVec, TimeLimit, TimeLimitVec,
};
use crate::{Error, Result};

/// Every registered task id.
pub const ALL_TASKS: &[&str] = &[
    "CartPole-v1",
    "MountainCar-v0",
    "Pendulum-v1",
    "Acrobot-v1",
    "Pong-v5",
    "Breakout-v5",
    "Hopper-v4",
    "HalfCheetah-v4",
    "Ant-v4",
    "cheetah_run",
];

/// The error every unknown-task path returns: names the offending id
/// *and* the full registered list, sorted, so a typo'd config points
/// straight at the fix instead of requiring a source dive.
pub fn unknown_env(task_id: &str) -> Error {
    let mut known: Vec<&str> = ALL_TASKS.to_vec();
    known.sort_unstable();
    Error::UnknownEnv(format!("{task_id} (registered tasks: {})", known.join(", ")))
}

/// Physics parameters a task accepts through
/// [`VecEnv::set_param_lanes`], in parameter-index order — the order
/// scenario jitter streams are keyed by, so it is part of the
/// replayability contract (mirrors each kernel's `param_names`; pinned
/// by a test below). Tasks without an entry expose nothing: Atari has
/// no physics, and Acrobot's RK4 composites are const-folded in a way
/// that cannot be pinned bitwise against a runtime recompute, so it
/// deliberately rejects overrides.
pub fn supported_params(task_id: &str) -> &'static [&'static str] {
    match task_id {
        "CartPole-v1" => &["gravity", "length", "force_mag"],
        "Pendulum-v1" => &["gravity", "mass", "length"],
        "MountainCar-v0" => &["force", "gravity"],
        "Hopper-v4" | "HalfCheetah-v4" | "Ant-v4" | "cheetah_run" => &["gravity", "gear_scale"],
        _ => &[],
    }
}

/// The standard wrapper stack, applied engine-side as in EnvPool.
/// Composition order (innermost first): time limit → reward clip →
/// observation normalization. The same config produces an identical
/// stack through [`make_env_wrapped`] (scalar one-lane adapters) and
/// [`make_vec_env_wrapped`] (the batch-wise `VecWrapper` layer) — the
/// exec modes cannot diverge semantically.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WrapConfig {
    /// Truncate episodes at this many steps (tightening the env's own
    /// limit); `None` leaves the env limit in force.
    pub time_limit: Option<usize>,
    /// Clip rewards to `{-1, 0, +1}` (DQN convention).
    pub reward_clip: bool,
    /// Welford running observation normalization (per env/lane).
    pub normalize_obs: bool,
    /// Pool one normalization statistic across all lanes of a vectorized
    /// chunk (gym `VecNormalize`-style;
    /// [`NormalizeObsVec::new_shared`]). Mutually exclusive with
    /// `normalize_obs`, and only meaningful for the vectorized surface —
    /// [`make_env_wrapped`] rejects it because a scalar env has no batch
    /// to share a statistic over. The statistic's scope is the *chunk*
    /// the kernel is built for, so through the pool its numerics depend
    /// on the chunking (i.e. `num_threads`) — per-lane `normalize_obs`
    /// is the thread-count-invariant option.
    pub normalize_obs_shared: bool,
}

impl WrapConfig {
    /// No wrappers (the default).
    pub fn none() -> Self {
        WrapConfig::default()
    }

    /// Does this config add any wrapper at all?
    pub fn is_empty(&self) -> bool {
        self.time_limit.is_none()
            && !self.reward_clip
            && !self.normalize_obs
            && !self.normalize_obs_shared
    }

    /// Reject combinations no surface can build.
    fn check(&self) -> Result<()> {
        if self.normalize_obs && self.normalize_obs_shared {
            return Err(Error::Config(
                "normalize_obs and normalize_obs_shared are mutually exclusive \
                 (per-lane vs pooled statistics)"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Construct an environment by task id. `seed` is the experiment seed;
/// `env_id` is the instance index within a pool (each instance gets an
/// independent RNG stream, making pool runs scheduling-invariant).
pub fn make_env(task_id: &str, seed: u64, env_id: u64) -> Result<Box<dyn Env>> {
    Ok(match task_id {
        "CartPole-v1" => Box::new(CartPole::new(seed, env_id)),
        "MountainCar-v0" => Box::new(MountainCar::new(seed, env_id)),
        "Pendulum-v1" => Box::new(Pendulum::new(seed, env_id)),
        "Acrobot-v1" => Box::new(Acrobot::new(seed, env_id)),
        "Pong-v5" => Box::new(preproc::pong(seed, env_id)),
        "Breakout-v5" => Box::new(preproc::breakout(seed, env_id)),
        "Hopper-v4" => Box::new(WalkerEnv::new(Task::Hopper, seed, env_id)),
        "HalfCheetah-v4" => Box::new(WalkerEnv::new(Task::HalfCheetah, seed, env_id)),
        "Ant-v4" => Box::new(WalkerEnv::new(Task::Ant, seed, env_id)),
        "cheetah_run" => Box::new(CheetahRun::new(seed, env_id)),
        other => return Err(unknown_env(other)),
    })
}

/// Fetch the spec of a task without keeping the env.
pub fn spec_for(task_id: &str) -> Result<EnvSpec> {
    Ok(make_env(task_id, 0, 0)?.spec().clone())
}

/// Spec of a task as seen through a wrapper stack (only the time limit
/// changes the spec).
pub fn spec_for_wrapped(task_id: &str, wrap: &WrapConfig) -> Result<EnvSpec> {
    let mut spec = spec_for(task_id)?;
    if let Some(limit) = wrap.time_limit {
        // The wrapper can only tighten: the inner env still truncates at
        // its native limit, so the effective cap is the minimum (the
        // TimeLimit wrappers advertise the same).
        spec.max_episode_steps = spec.max_episode_steps.min(limit);
    }
    Ok(spec)
}

/// Construct a **vectorized** batch of `count` environments with global
/// ids `first_env_id..first_env_id + count` — the vector analog of
/// [`make_env`]. Every registered family maps to a real batch kernel:
/// classic control to struct-of-arrays kernels (bitwise identical to the
/// scalar envs), the walkers to [`WalkerVec`] (batch-resident
/// `WorldBatch` physics, lane-grouped solver; bitwise at width 1,
/// documented tolerance budget at wider lanes), Atari to
/// [`AtariVec`](super::vector::AtariVec) (SoA game state, masked
/// lane-group emulator passes — bitwise at every width), and
/// `cheetah_run` to [`CheetahRunVec`]. There is **no scalar fallback**;
/// [`super::vector::ScalarVec`] is an explicit opt-in for
/// out-of-registry envs.
pub fn make_vec_env(
    task_id: &str,
    seed: u64,
    first_env_id: u64,
    count: usize,
) -> Result<Box<dyn VecEnv>> {
    if count == 0 {
        return Err(Error::Config(format!(
            "make_vec_env({task_id:?}): lane count must be > 0"
        )));
    }
    Ok(match task_id {
        "CartPole-v1" => Box::new(CartPoleVec::new(seed, first_env_id, count)),
        "MountainCar-v0" => Box::new(MountainCarVec::new(seed, first_env_id, count)),
        "Pendulum-v1" => Box::new(PendulumVec::new(seed, first_env_id, count)),
        "Acrobot-v1" => Box::new(AcrobotVec::new(seed, first_env_id, count)),
        "Pong-v5" => Box::new(pong_vec(seed, first_env_id, count)),
        "Breakout-v5" => Box::new(breakout_vec(seed, first_env_id, count)),
        "Hopper-v4" => Box::new(WalkerVec::new(Task::Hopper, seed, first_env_id, count)),
        "HalfCheetah-v4" => Box::new(WalkerVec::new(Task::HalfCheetah, seed, first_env_id, count)),
        "Ant-v4" => Box::new(WalkerVec::new(Task::Ant, seed, first_env_id, count)),
        "cheetah_run" => Box::new(CheetahRunVec::new(seed, first_env_id, count)),
        other => return Err(unknown_env(other)),
    })
}

/// [`make_env`] plus the standard wrapper stack (scalar surface: thin
/// one-lane adapters over the same cores the vec wrappers run).
pub fn make_env_wrapped(
    task_id: &str,
    seed: u64,
    env_id: u64,
    wrap: &WrapConfig,
) -> Result<Box<dyn Env>> {
    wrap.check()?;
    if wrap.normalize_obs_shared {
        return Err(Error::Config(
            "normalize_obs_shared pools statistics across the lanes of a vectorized \
             chunk; scalar execution has only per-lane stats — use \
             ExecMode::Vectorized (or per-lane normalize_obs)"
                .into(),
        ));
    }
    let mut env: Box<dyn Env> = make_env(task_id, seed, env_id)?;
    if let Some(limit) = wrap.time_limit {
        env = Box::new(TimeLimit::new(env, limit));
    }
    if wrap.reward_clip {
        env = Box::new(RewardClip::new(env));
    }
    if wrap.normalize_obs {
        env = Box::new(NormalizeObs::new(env));
    }
    Ok(env)
}

/// [`make_vec_env`] plus the standard wrapper stack (the batch-wise
/// `VecWrapper` layer), composed in the same order as
/// [`make_env_wrapped`].
pub fn make_vec_env_wrapped(
    task_id: &str,
    seed: u64,
    first_env_id: u64,
    count: usize,
    wrap: &WrapConfig,
) -> Result<Box<dyn VecEnv>> {
    wrap.check()?;
    let mut env = make_vec_env(task_id, seed, first_env_id, count)?;
    if let Some(limit) = wrap.time_limit {
        env = Box::new(TimeLimitVec::new(env, limit));
    }
    if wrap.reward_clip {
        env = Box::new(RewardClipVec::new(env));
    }
    if wrap.normalize_obs_shared {
        env = Box::new(NormalizeObsVec::new_shared(env));
    } else if wrap.normalize_obs {
        env = Box::new(NormalizeObsVec::new(env));
    }
    Ok(env)
}

/// Resolve a scenario group's per-lane parameter values: fixed
/// `param.*` overrides broadcast to every lane, then each `jitter.*`
/// range drawn lane-by-lane from a dedicated PCG32 stream keyed
/// `(group_seed ^ JITTER_SALT, parameter index)` — index taken from
/// [`supported_params`] order, so the draw is independent of file
/// ordering, exec mode, chunking and thread count. Returns
/// `(name, one value per lane)` pairs.
pub fn resolve_lane_params(
    group: &crate::config::ScenarioGroup,
    group_seed: u64,
) -> Vec<(String, Vec<f32>)> {
    use crate::config::scenario::JITTER_SALT;
    let supported = supported_params(&group.task_id);
    let mut out = Vec::new();
    for (name, v) in &group.params {
        out.push((name.clone(), vec![*v; group.count]));
    }
    for (name, lo, hi) in &group.jitter {
        // Validated names only reach here (ScenarioConfig::parse).
        let pi = supported.iter().position(|&s| s == name.as_str()).expect("validated") as u64;
        let mut rng = crate::rng::Pcg32::new(group_seed ^ JITTER_SALT, pi);
        let lanes = (0..group.count).map(|_| rng.range(*lo, *hi)).collect();
        out.push((name.clone(), lanes));
    }
    out
}

/// Build group `gi` of a scenario as one full-width [`VecEnv`]: the
/// task's real kernel at the group's whole lane count, parameters
/// resolved and applied, then the group's wrapper stack. The kernel is
/// seeded with the **group seed** and group-local env ids `0..count`,
/// so its lanes draw exactly the streams of a homogeneous pool built
/// with the same seed — the mixed-vs-homogeneous parity contract.
pub fn make_scenario_group(
    sc: &crate::config::ScenarioConfig,
    gi: usize,
    pool_seed: u64,
) -> Result<Box<dyn VecEnv>> {
    let g = &sc.groups[gi];
    let seed = sc.group_seed(gi, pool_seed);
    let mut env = make_vec_env_wrapped(&g.task_id, seed, 0, g.count, &g.wrap)?;
    for (name, lanes) in resolve_lane_params(g, seed) {
        if !env.set_param_lanes(&name, &lanes) {
            return Err(Error::Config(format!(
                "task {} rejected parameter {name:?} (supported: {:?})",
                g.task_id,
                supported_params(&g.task_id)
            )));
        }
    }
    Ok(env)
}

/// Build one env of a scenario group as a scalar [`Env`] — lane `lane`
/// of group `gi`, as a one-lane kernel behind the
/// [`VecLaneEnv`](crate::pool::hetero::VecLaneEnv) adapter. Because
/// env RNG streams are keyed by `(group seed, group-local env id)` and
/// jitter values are resolved for the whole group before slicing out
/// this lane, the env is bitwise the same lane of
/// [`make_scenario_group`] — scenario pools behave identically under
/// `ExecMode::Scalar` and `ExecMode::Vectorized`.
pub fn make_scenario_env(
    sc: &crate::config::ScenarioConfig,
    gi: usize,
    lane: usize,
    pool_seed: u64,
) -> Result<Box<dyn Env>> {
    let g = &sc.groups[gi];
    let seed = sc.group_seed(gi, pool_seed);
    let mut env = make_vec_env_wrapped(&g.task_id, seed, lane as u64, 1, &g.wrap)?;
    for (name, lanes) in resolve_lane_params(g, seed) {
        if !env.set_param_lanes(&name, &lanes[lane..lane + 1]) {
            return Err(Error::Config(format!(
                "task {} rejected parameter {name:?}",
                g.task_id
            )));
        }
    }
    Ok(Box::new(crate::pool::hetero::VecLaneEnv::new(env)))
}

/// The union [`EnvSpec`] of a scenario: per-group views in env-id
/// order, observation shape and action width padded to the widest
/// group (rows are zero-filled past a group's own width), episode
/// limit the max. If every group shares one action space the union
/// keeps it verbatim; a genuine mix is carried as a continuous box
/// wide enough for every group (the pool only uses its `dim()` for
/// buffer strides — per-group semantics live in the views).
pub fn scenario_spec(sc: &crate::config::ScenarioConfig) -> Result<EnvSpec> {
    use super::spec::{ActionSpace, GroupView};
    sc.validate()?;
    let mut groups = Vec::new();
    let mut first = 0;
    for g in &sc.groups {
        let spec = spec_for_wrapped(&g.task_id, &g.wrap)?;
        groups.push(GroupView {
            task_id: g.task_id.clone(),
            first_env: first,
            count: g.count,
            spec,
        });
        first += g.count;
    }
    let obs_dim = groups.iter().map(|g| g.spec.obs_dim()).max().unwrap();
    let max_steps = groups.iter().map(|g| g.spec.max_episode_steps).max().unwrap();
    let first_space = &groups[0].spec.action_space;
    let action_space = if groups.iter().all(|g| &g.spec.action_space == first_space) {
        first_space.clone()
    } else {
        let dim = groups.iter().map(|g| g.spec.action_space.dim()).max().unwrap();
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for g in &groups {
            match g.spec.action_space {
                // Discrete ids ride the wire as f32 action ids.
                ActionSpace::Discrete(n) => {
                    lo = lo.min(0.0);
                    hi = hi.max((n - 1) as f32);
                }
                ActionSpace::Continuous { low, high, .. } => {
                    lo = lo.min(low);
                    hi = hi.max(high);
                }
            }
        }
        ActionSpace::Continuous { dim, low: lo, high: hi }
    };
    let ids: Vec<&str> = groups.iter().map(|g| g.task_id.as_str()).collect();
    Ok(EnvSpec {
        id: format!("scenario[{}]", ids.join("+")),
        obs_shape: vec![obs_dim],
        action_space,
        max_episode_steps: max_steps,
        groups,
    })
}

/// Build every group of a scenario and compose them behind the
/// [`VecEnv`] trait as one
/// [`GroupedVecEnv`](crate::pool::hetero::GroupedVecEnv) — the
/// heterogeneous pool backend (issue-level entry point; the pool's
/// vectorized engine instead builds one chunk per group so groups step
/// on separate workers).
pub fn make_scenario_pool(
    sc: &crate::config::ScenarioConfig,
    pool_seed: u64,
) -> Result<crate::pool::hetero::GroupedVecEnv> {
    let spec = scenario_spec(sc)?;
    let backends = (0..sc.groups.len())
        .map(|gi| make_scenario_group(sc, gi, pool_seed))
        .collect::<Result<Vec<_>>>()?;
    Ok(crate::pool::hetero::GroupedVecEnv::new(backends, spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_construct_and_step() {
        for &task in ALL_TASKS {
            let mut env = make_env(task, 0, 0).unwrap();
            let dim = env.spec().obs_dim();
            let adim = env.spec().action_space.dim();
            let mut obs = vec![0.0f32; dim];
            env.reset(&mut obs);
            let action = vec![0.0f32; adim];
            for _ in 0..3 {
                let s = env.step(&action, &mut obs);
                assert!(s.reward.is_finite(), "{task}");
                assert!(obs.iter().all(|x| x.is_finite()), "{task}");
            }
        }
    }

    #[test]
    fn unknown_task_errors() {
        assert!(matches!(make_env("Doom-v0", 0, 0), Err(Error::UnknownEnv(_))));
        assert!(matches!(make_vec_env("Doom-v0", 0, 0, 1), Err(Error::UnknownEnv(_))));
    }

    #[test]
    fn unknown_task_error_lists_all_tasks_sorted() {
        let msg = make_env("Doom-v0", 0, 0).unwrap_err().to_string();
        assert!(msg.contains("Doom-v0"));
        // Complete: every registered id appears…
        let mut sorted: Vec<&str> = ALL_TASKS.to_vec();
        sorted.sort_unstable();
        for t in &sorted {
            assert!(msg.contains(t), "error must list {t}: {msg}");
        }
        // …and sorted: first occurrences are in ascending position.
        let tail = &msg[msg.find("registered tasks:").unwrap()..];
        let positions: Vec<usize> = sorted.iter().map(|t| tail.find(*t).unwrap()).collect();
        assert!(
            positions.windows(2).all(|w| w[0] < w[1]),
            "task list must be sorted: {msg}"
        );
        // Both constructors produce the identical message.
        assert_eq!(msg, make_vec_env("Doom-v0", 0, 0, 1).unwrap_err().to_string());
    }

    #[test]
    fn supported_params_mirror_kernel_param_names() {
        // The registry table is the scenario layer's validation source;
        // each kernel's `param_names` is what `set_param_lanes` accepts.
        // They must agree exactly (order included — jitter streams are
        // keyed by index).
        for &task in ALL_TASKS {
            let v = make_vec_env(task, 0, 0, 1).unwrap();
            assert_eq!(v.param_names(), supported_params(task), "{task}");
        }
        assert_eq!(supported_params("not-a-task"), &[] as &[&str]);
    }

    #[test]
    fn scenario_spec_builds_views_and_union() {
        use crate::config::ScenarioConfig;
        let sc = ScenarioConfig::parse(
            "[group]\ntask = CartPole-v1\ncount = 4\n\
             [group]\ntask = Hopper-v4\ncount = 2\n\
             [group]\ntask = Pong-v5\ncount = 2\n",
        )
        .unwrap();
        let spec = scenario_spec(&sc).unwrap();
        assert!(spec.is_grouped());
        assert_eq!(spec.groups.len(), 3);
        assert_eq!(spec.groups[1].first_env, 4);
        assert_eq!(spec.groups[2].first_env, 6);
        // Union widths: Pong obs dominates (4*84*84), Hopper act (3).
        assert_eq!(spec.obs_dim(), 4 * 84 * 84);
        assert_eq!(spec.action_space.dim(), 3);
        assert_eq!(spec.max_episode_steps, 108_000);
        assert_eq!(spec.uniform_group_spec(), None);
        // A single-task scenario collapses to the task spec's shape.
        let uni = ScenarioConfig::parse("[group]\ntask = Pendulum-v1\ncount = 3\n").unwrap();
        let uspec = scenario_spec(&uni).unwrap();
        assert_eq!(
            uspec.uniform_group_spec().unwrap(),
            &spec_for("Pendulum-v1").unwrap()
        );
    }

    #[test]
    fn resolve_lane_params_is_replayable_and_in_range() {
        use crate::config::ScenarioConfig;
        let sc = ScenarioConfig::parse(
            "[group]\ntask = CartPole-v1\ncount = 8\nparam.gravity = 9.0\n\
             jitter.length = 0.4 0.6\njitter.force_mag = 8.0 12.0\n",
        )
        .unwrap();
        let a = resolve_lane_params(&sc.groups[0], 99);
        let b = resolve_lane_params(&sc.groups[0], 99);
        assert_eq!(a, b, "same group seed must reproduce identical draws");
        let c = resolve_lane_params(&sc.groups[0], 100);
        assert_ne!(a, c, "different group seed must redraw jitters");
        let by_name: std::collections::BTreeMap<&str, &Vec<f32>> =
            a.iter().map(|(n, v)| (n.as_str(), v)).collect();
        assert!(by_name["gravity"].iter().all(|&v| v == 9.0));
        assert!(by_name["length"].iter().all(|&v| (0.4..0.6).contains(&v)));
        assert!(by_name["force_mag"].iter().all(|&v| (8.0..12.0).contains(&v)));
        // Jittered lanes genuinely vary.
        assert!(by_name["length"].windows(2).any(|w| w[0] != w[1]));
        // Fixed overrides stay fixed across pool seeds (param, not jitter).
        assert_eq!(by_name["gravity"], c.iter().find(|(n, _)| n == "gravity").map(|(_, v)| v).unwrap());
    }

    #[test]
    fn all_tasks_construct_vectorized() {
        for &task in ALL_TASKS {
            let mut v = make_vec_env(task, 0, 0, 2).unwrap();
            assert_eq!(v.num_envs(), 2);
            assert_eq!(v.spec(), &spec_for(task).unwrap(), "{task}");
            let dim = v.spec().obs_dim();
            let mut obs = vec![0.0f32; 2 * dim];
            for lane in 0..2 {
                v.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
            }
            assert!(obs.iter().all(|x| x.is_finite()), "{task}");
        }
    }

    #[test]
    fn spec_matches_env() {
        for &task in ALL_TASKS {
            let spec = spec_for(task).unwrap();
            let env = make_env(task, 0, 0).unwrap();
            assert_eq!(&spec, env.spec(), "{task}");
        }
    }

    #[test]
    fn zero_lane_vec_env_is_a_config_error() {
        assert!(matches!(
            make_vec_env("CartPole-v1", 0, 0, 0),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            make_vec_env_wrapped("CartPole-v1", 0, 0, 0, &WrapConfig::none()),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn shared_normalization_is_vectorized_only() {
        let shared = WrapConfig { normalize_obs_shared: true, ..WrapConfig::none() };
        assert!(!shared.is_empty());
        // vectorized surface accepts it
        let mut v = make_vec_env_wrapped("Pendulum-v1", 1, 0, 3, &shared).unwrap();
        assert_eq!(v.num_envs(), 3);
        let mut obs = vec![0.0f32; 3 * 3];
        for lane in 0..3 {
            v.reset_lane(lane, &mut obs[lane * 3..(lane + 1) * 3]);
        }
        assert!(obs.iter().all(|x| x.is_finite()));
        // scalar surface rejects it
        match make_env_wrapped("Pendulum-v1", 1, 0, &shared) {
            Err(Error::Config(msg)) => assert!(msg.contains("per-lane"), "{msg}"),
            other => panic!("expected Config rejection, got {:?}", other.map(|_| ())),
        }
        // both-at-once is contradictory on every surface
        let both = WrapConfig {
            normalize_obs: true,
            normalize_obs_shared: true,
            ..WrapConfig::none()
        };
        assert!(make_vec_env_wrapped("Pendulum-v1", 1, 0, 2, &both).is_err());
        assert!(make_env_wrapped("Pendulum-v1", 1, 0, &both).is_err());
    }

    #[test]
    fn wrapped_constructors_apply_the_stack_in_both_modes() {
        let wrap = WrapConfig {
            time_limit: Some(9),
            reward_clip: true,
            normalize_obs: true,
            ..WrapConfig::none()
        };
        assert!(!wrap.is_empty());
        assert!(WrapConfig::none().is_empty());
        let spec = spec_for_wrapped("Pendulum-v1", &wrap).unwrap();
        assert_eq!(spec.max_episode_steps, 9);

        let mut env = make_env_wrapped("Pendulum-v1", 1, 0, &wrap).unwrap();
        assert_eq!(env.spec().max_episode_steps, 9);
        let mut obs = vec![0.0f32; 3];
        env.reset(&mut obs);
        for t in 0..9 {
            let s = env.step(&[1.0], &mut obs);
            assert!(s.reward == 0.0 || s.reward == -1.0, "clipped");
            assert_eq!(s.truncated, t == 8, "time limit");
            assert!(obs.iter().all(|x| x.abs() <= 10.0), "normalized");
        }

        let mut v = make_vec_env_wrapped("Pendulum-v1", 1, 0, 2, &wrap).unwrap();
        assert_eq!(v.spec().max_episode_steps, 9);
        assert_eq!(v.num_envs(), 2);
    }

    #[test]
    fn empty_wrap_config_is_the_bare_env() {
        let wrap = WrapConfig::none();
        let env = make_env_wrapped("CartPole-v1", 0, 0, &wrap).unwrap();
        assert_eq!(env.spec(), &spec_for("CartPole-v1").unwrap());
        assert_eq!(
            spec_for_wrapped("CartPole-v1", &wrap).unwrap(),
            spec_for("CartPole-v1").unwrap()
        );
    }
}
