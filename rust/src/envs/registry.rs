//! Task registry: `make_env("Pong-v5", seed, env_id)` — the Rust analog
//! of `envpool.make(task_id, ...)`. Every supported task id is listed in
//! [`ALL_TASKS`]; specs are obtainable without constructing an env.

use super::atari::preproc;
use super::classic::{Acrobot, CartPole, MountainCar, Pendulum};
use super::dmc::CheetahRun;
use super::env::Env;
use super::mujoco::walker::{Task, WalkerEnv};
use super::spec::EnvSpec;
use crate::{Error, Result};

/// Every registered task id.
pub const ALL_TASKS: &[&str] = &[
    "CartPole-v1",
    "MountainCar-v0",
    "Pendulum-v1",
    "Acrobot-v1",
    "Pong-v5",
    "Breakout-v5",
    "Hopper-v4",
    "HalfCheetah-v4",
    "Ant-v4",
    "cheetah_run",
];

/// Construct an environment by task id. `seed` is the experiment seed;
/// `env_id` is the instance index within a pool (each instance gets an
/// independent RNG stream, making pool runs scheduling-invariant).
pub fn make_env(task_id: &str, seed: u64, env_id: u64) -> Result<Box<dyn Env>> {
    Ok(match task_id {
        "CartPole-v1" => Box::new(CartPole::new(seed, env_id)),
        "MountainCar-v0" => Box::new(MountainCar::new(seed, env_id)),
        "Pendulum-v1" => Box::new(Pendulum::new(seed, env_id)),
        "Acrobot-v1" => Box::new(Acrobot::new(seed, env_id)),
        "Pong-v5" => Box::new(preproc::pong(seed, env_id)),
        "Breakout-v5" => Box::new(preproc::breakout(seed, env_id)),
        "Hopper-v4" => Box::new(WalkerEnv::new(Task::Hopper, seed, env_id)),
        "HalfCheetah-v4" => Box::new(WalkerEnv::new(Task::HalfCheetah, seed, env_id)),
        "Ant-v4" => Box::new(WalkerEnv::new(Task::Ant, seed, env_id)),
        "cheetah_run" => Box::new(CheetahRun::new(seed, env_id)),
        other => return Err(Error::UnknownEnv(other.to_string())),
    })
}

/// Fetch the spec of a task without keeping the env.
pub fn spec_for(task_id: &str) -> Result<EnvSpec> {
    Ok(make_env(task_id, 0, 0)?.spec().clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_construct_and_step() {
        for &task in ALL_TASKS {
            let mut env = make_env(task, 0, 0).unwrap();
            let dim = env.spec().obs_dim();
            let adim = env.spec().action_space.dim();
            let mut obs = vec![0.0f32; dim];
            env.reset(&mut obs);
            let action = vec![0.0f32; adim];
            for _ in 0..3 {
                let s = env.step(&action, &mut obs);
                assert!(s.reward.is_finite(), "{task}");
                assert!(obs.iter().all(|x| x.is_finite()), "{task}");
            }
        }
    }

    #[test]
    fn unknown_task_errors() {
        assert!(matches!(make_env("Doom-v0", 0, 0), Err(Error::UnknownEnv(_))));
    }

    #[test]
    fn spec_matches_env() {
        for &task in ALL_TASKS {
            let spec = spec_for(task).unwrap();
            let env = make_env(task, 0, 0).unwrap();
            assert_eq!(&spec, env.spec(), "{task}");
        }
    }
}
