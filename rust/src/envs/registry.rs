//! Task registry: `make_env("Pong-v5", seed, env_id)` — the Rust analog
//! of `envpool.make(task_id, ...)`. Every supported task id is listed in
//! [`ALL_TASKS`]; specs are obtainable without constructing an env.

use super::atari::preproc;
use super::classic::{Acrobot, CartPole, MountainCar, Pendulum};
use super::dmc::CheetahRun;
use super::env::Env;
use super::mujoco::walker::{Task, WalkerEnv};
use super::spec::EnvSpec;
use super::vector::{AcrobotVec, CartPoleVec, MountainCarVec, PendulumVec, ScalarVec, VecEnv};
use crate::{Error, Result};

/// Every registered task id.
pub const ALL_TASKS: &[&str] = &[
    "CartPole-v1",
    "MountainCar-v0",
    "Pendulum-v1",
    "Acrobot-v1",
    "Pong-v5",
    "Breakout-v5",
    "Hopper-v4",
    "HalfCheetah-v4",
    "Ant-v4",
    "cheetah_run",
];

/// Construct an environment by task id. `seed` is the experiment seed;
/// `env_id` is the instance index within a pool (each instance gets an
/// independent RNG stream, making pool runs scheduling-invariant).
pub fn make_env(task_id: &str, seed: u64, env_id: u64) -> Result<Box<dyn Env>> {
    Ok(match task_id {
        "CartPole-v1" => Box::new(CartPole::new(seed, env_id)),
        "MountainCar-v0" => Box::new(MountainCar::new(seed, env_id)),
        "Pendulum-v1" => Box::new(Pendulum::new(seed, env_id)),
        "Acrobot-v1" => Box::new(Acrobot::new(seed, env_id)),
        "Pong-v5" => Box::new(preproc::pong(seed, env_id)),
        "Breakout-v5" => Box::new(preproc::breakout(seed, env_id)),
        "Hopper-v4" => Box::new(WalkerEnv::new(Task::Hopper, seed, env_id)),
        "HalfCheetah-v4" => Box::new(WalkerEnv::new(Task::HalfCheetah, seed, env_id)),
        "Ant-v4" => Box::new(WalkerEnv::new(Task::Ant, seed, env_id)),
        "cheetah_run" => Box::new(CheetahRun::new(seed, env_id)),
        other => return Err(Error::UnknownEnv(other.to_string())),
    })
}

/// Fetch the spec of a task without keeping the env.
pub fn spec_for(task_id: &str) -> Result<EnvSpec> {
    Ok(make_env(task_id, 0, 0)?.spec().clone())
}

/// Construct a **vectorized** batch of `count` environments with global
/// ids `first_env_id..first_env_id + count` — the vector analog of
/// [`make_env`]. Classic-control tasks get dedicated struct-of-arrays
/// kernels (bitwise identical to the scalar envs, see
/// [`crate::envs::vector`]); every other task falls back to a
/// [`ScalarVec`] chunk, which still amortizes per-task dispatch.
pub fn make_vec_env(
    task_id: &str,
    seed: u64,
    first_env_id: u64,
    count: usize,
) -> Result<Box<dyn VecEnv>> {
    Ok(match task_id {
        "CartPole-v1" => Box::new(CartPoleVec::new(seed, first_env_id, count)),
        "MountainCar-v0" => Box::new(MountainCarVec::new(seed, first_env_id, count)),
        "Pendulum-v1" => Box::new(PendulumVec::new(seed, first_env_id, count)),
        "Acrobot-v1" => Box::new(AcrobotVec::new(seed, first_env_id, count)),
        other if ALL_TASKS.contains(&other) => {
            Box::new(ScalarVec::new(other, seed, first_env_id, count)?)
        }
        other => return Err(Error::UnknownEnv(other.to_string())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_construct_and_step() {
        for &task in ALL_TASKS {
            let mut env = make_env(task, 0, 0).unwrap();
            let dim = env.spec().obs_dim();
            let adim = env.spec().action_space.dim();
            let mut obs = vec![0.0f32; dim];
            env.reset(&mut obs);
            let action = vec![0.0f32; adim];
            for _ in 0..3 {
                let s = env.step(&action, &mut obs);
                assert!(s.reward.is_finite(), "{task}");
                assert!(obs.iter().all(|x| x.is_finite()), "{task}");
            }
        }
    }

    #[test]
    fn unknown_task_errors() {
        assert!(matches!(make_env("Doom-v0", 0, 0), Err(Error::UnknownEnv(_))));
        assert!(matches!(make_vec_env("Doom-v0", 0, 0, 1), Err(Error::UnknownEnv(_))));
    }

    #[test]
    fn all_tasks_construct_vectorized() {
        for &task in ALL_TASKS {
            let mut v = make_vec_env(task, 0, 0, 2).unwrap();
            assert_eq!(v.num_envs(), 2);
            assert_eq!(v.spec(), &spec_for(task).unwrap(), "{task}");
            let dim = v.spec().obs_dim();
            let mut obs = vec![0.0f32; 2 * dim];
            for lane in 0..2 {
                v.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
            }
            assert!(obs.iter().all(|x| x.is_finite()), "{task}");
        }
    }

    #[test]
    fn spec_matches_env() {
        for &task in ALL_TASKS {
            let spec = spec_for(task).unwrap();
            let env = make_env(task, 0, 0).unwrap();
            assert_eq!(&spec, env.spec(), "{task}");
        }
    }
}
