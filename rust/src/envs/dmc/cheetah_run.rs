//! `cheetah run` (dm_control substitute): the HalfCheetah body with the
//! Control Suite's shaped run reward — `r = clip(vx / target, 0, 1)` —
//! and fixed 1000-step episodes with no early termination.

use crate::envs::env::{Env, Step};
use crate::envs::mujoco::walker::{Task, WalkerEnv};
use crate::envs::mujoco::{DT, FRAME_SKIP};
use crate::envs::spec::EnvSpec;

/// Target running speed for full reward (dm_control uses 10 m/s).
pub const TARGET_SPEED: f32 = 6.0;

/// The Control Suite shaping over one walker transition: reward
/// `clip(vx / TARGET_SPEED, 0, 1)`, no failure termination (a walker
/// `done` becomes truncation). Single source of truth shared by the
/// scalar [`CheetahRun`] and the batched
/// [`crate::envs::vector::CheetahRunVec`] so the two surfaces cannot
/// drift.
#[inline]
pub(crate) fn shape_step(vx: f32, inner: Step) -> Step {
    Step {
        reward: (vx / TARGET_SPEED).clamp(0.0, 1.0),
        done: false,
        truncated: inner.truncated || inner.done,
    }
}

/// The `cheetah_run` spec over the inner HalfCheetah spec — the other
/// half of the shared core (id + fixed 1000-step episodes), used by
/// both the scalar task and the batched kernel.
pub(crate) fn cheetah_spec(inner: &EnvSpec) -> EnvSpec {
    let mut spec = inner.clone();
    spec.id = "cheetah_run".into();
    spec.max_episode_steps = 1000;
    spec
}

/// The dm_control `cheetah run` task. Like [`WalkerEnv`], this scalar
/// surface is a width-1 view over the batch-resident physics core
/// (`envs::mujoco::WorldBatch`) — the shaping here and the spec above
/// are the only cheetah-specific code, shared verbatim with the batched
/// [`crate::envs::vector::CheetahRunVec`].
pub struct CheetahRun {
    inner: WalkerEnv,
    spec: EnvSpec,
}

impl CheetahRun {
    pub fn new(seed: u64, env_id: u64) -> Self {
        let inner = WalkerEnv::new(Task::HalfCheetah, seed, env_id);
        let spec = cheetah_spec(inner.spec());
        CheetahRun { inner, spec }
    }
}

impl Env for CheetahRun {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.inner.reset(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let n = self.spec.obs_dim();
        let s = self.inner.step(action, obs);
        // Recover vx from the observation layout: index 2 + n_joints.
        let n_joints = self.spec.action_space.dim();
        let vx = obs[2 + n_joints];
        debug_assert_eq!(n, obs.len());
        let _ = (DT, FRAME_SKIP); // constants shared with the gym task
        shape_step(vx, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_in_unit_interval() {
        let mut env = CheetahRun::new(0, 0);
        let mut obs = vec![0.0; env.spec().obs_dim()];
        let n = env.spec().action_space.dim();
        env.reset(&mut obs);
        for i in 0..300 {
            let a: Vec<f32> = (0..n).map(|k| ((i + k) as f32).sin()).collect();
            let s = env.step(&a, &mut obs);
            assert!((0.0..=1.0).contains(&s.reward), "r={}", s.reward);
            assert!(!s.done);
        }
    }

    #[test]
    fn episode_is_1000_steps() {
        let mut env = CheetahRun::new(1, 0);
        let mut obs = vec![0.0; env.spec().obs_dim()];
        env.reset(&mut obs);
        let zeros = vec![0.0f32; env.spec().action_space.dim()];
        for t in 0..1000 {
            let s = env.step(&zeros, &mut obs);
            assert_eq!(s.truncated, t == 999, "t={t}");
        }
    }
}
