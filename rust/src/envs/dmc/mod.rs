//! dm_control-style tasks (DeepMind Control Suite substitute) and the
//! dm_env `TimeStep` API, mirroring EnvPool's dual gym/dm API support.

pub mod cheetah_run;
pub mod timestep;

pub use cheetah_run::CheetahRun;
pub use timestep::{DmEnvAdapter, StepType, TimeStep};
