//! dm_env API surface: `TimeStep { step_type, reward, discount, obs }`
//! and an adapter that exposes any [`Env`] through it — EnvPool supports
//! both gym and dm APIs over one engine (paper Appendix A.2).

use crate::envs::env::Env;

/// dm_env step types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepType {
    First,
    Mid,
    Last,
}

/// A dm_env timestep (observation lives in the caller's buffer, as
/// everywhere in this crate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimeStep {
    pub step_type: StepType,
    pub reward: f32,
    /// 0.0 on true termination, 1.0 otherwise (including truncation —
    /// dm_env's discount encodes bootstrappability).
    pub discount: f32,
}

impl TimeStep {
    pub fn first() -> TimeStep {
        TimeStep { step_type: StepType::First, reward: 0.0, discount: 1.0 }
    }

    pub fn is_last(&self) -> bool {
        self.step_type == StepType::Last
    }
}

/// Wrap a gym-style [`Env`] as a dm_env.
pub struct DmEnvAdapter<E: Env> {
    env: E,
    needs_reset: bool,
}

impl<E: Env> DmEnvAdapter<E> {
    pub fn new(env: E) -> Self {
        DmEnvAdapter { env, needs_reset: true }
    }

    pub fn spec(&self) -> &crate::envs::spec::EnvSpec {
        self.env.spec()
    }

    /// dm_env `reset()`.
    pub fn reset(&mut self, obs: &mut [f32]) -> TimeStep {
        self.env.reset(obs);
        self.needs_reset = false;
        TimeStep::first()
    }

    /// dm_env `step()`: auto-resets after a Last step, as dm_env specifies.
    pub fn step(&mut self, action: &[f32], obs: &mut [f32]) -> TimeStep {
        if self.needs_reset {
            return self.reset(obs);
        }
        let s = self.env.step(action, obs);
        if s.finished() {
            self.needs_reset = true;
            TimeStep {
                step_type: StepType::Last,
                reward: s.reward,
                discount: if s.done { 0.0 } else { 1.0 },
            }
        } else {
            TimeStep { step_type: StepType::Mid, reward: s.reward, discount: 1.0 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::classic::CartPole;

    #[test]
    fn lifecycle_first_mid_last() {
        let mut env = DmEnvAdapter::new(CartPole::new(0, 0));
        let mut obs = vec![0.0; 4];
        let ts = env.reset(&mut obs);
        assert_eq!(ts.step_type, StepType::First);
        let mut saw_last = false;
        for _ in 0..600 {
            let ts = env.step(&[1.0], &mut obs);
            if ts.is_last() {
                saw_last = true;
                // push-one-way cartpole falls: true termination => discount 0
                assert_eq!(ts.discount, 0.0);
                break;
            }
            assert_eq!(ts.step_type, StepType::Mid);
            assert_eq!(ts.discount, 1.0);
        }
        assert!(saw_last);
        // next step auto-resets
        let ts = env.step(&[0.0], &mut obs);
        assert_eq!(ts.step_type, StepType::First);
    }

    #[test]
    fn truncation_keeps_discount_one() {
        use crate::envs::dmc::CheetahRun;
        let mut env = DmEnvAdapter::new(CheetahRun::new(0, 0));
        let mut obs = vec![0.0; env.spec().obs_dim()];
        env.reset(&mut obs);
        let zeros = vec![0.0f32; env.spec().action_space.dim()];
        let mut last = None;
        for _ in 0..1000 {
            let ts = env.step(&zeros, &mut obs);
            if ts.is_last() {
                last = Some(ts);
                break;
            }
        }
        let ts = last.expect("must truncate at 1000");
        assert_eq!(ts.discount, 1.0, "truncation is bootstrappable");
    }
}
