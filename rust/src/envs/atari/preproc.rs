//! DQN preprocessing stack wrapped around a [`Game`]: frameskip 4 with
//! max-pool over the last two native frames, 2× downsample to 84×84,
//! 4-frame stacking — producing the canonical `(4, 84, 84)` observation.
//!
//! # Split for the SoA batch path
//!
//! The preprocessing semantics live in **one** state machine,
//! [`PreprocCore`], factored so the per-step work separates into an
//! emulator phase and a pure pixel phase:
//!
//! - [`PreprocCore::step_emulate`] / [`PreprocCore::reset_emulate`] —
//!   emulator ticks and native renders, producing an [`EmulatePhase`]
//!   record. The scalar methods here are the *reference*; the batched
//!   kernel replaces them with masked SoA lane-group tick passes
//!   (`envs::vector::atari_emulate`) that are bitwise identical;
//! - [`PreprocCore::step_finish`] / [`PreprocCore::reset_finish`] —
//!   the pure lane math (2-frame max-pool, 2×2 max downsample, stack
//!   push, episodic-life/truncation bookkeeping) over caller-owned
//!   pixel buffers, plus [`PreprocCore::write_obs`] for the stacked
//!   readout.
//!
//! The scalar [`AtariEnv`] wraps the core with per-env owned buffers
//! ([`PreprocState`], API unchanged). The batched
//! [`AtariVec`](crate::envs::vector::AtariVec) kernel owns one
//! **contiguous slab** of all lanes' frames and stack rings and runs
//! the finish phase as a lane-streaming SoA pass after every lane's
//! emulator phase — same core methods, so the two execution paths stay
//! bitwise identical (pinned by `tests/vector_parity.rs` and the
//! in-file parity tests in `envs/vector/atari.rs`).

use super::game::Game;
use super::{FRAMESKIP, NATIVE, SCREEN, STACK};
use crate::envs::env::{discrete_action, Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::rng::Pcg32;

/// Atari episode cap in env steps (108k frames / frameskip).
pub(crate) const MAX_STEPS: usize = 27_000;

/// The spec of an Atari task over `game` (shared by scalar env and
/// batched kernel).
pub(crate) fn spec_for<G: Game>(game: &G) -> EnvSpec {
    spec_for_parts(game.name(), game.n_actions())
}

/// [`spec_for`] without a game instance — the batched kernel builds its
/// spec from the lane state's name/action count, even at zero lanes.
pub(crate) fn spec_for_parts(name: &str, n_actions: usize) -> EnvSpec {
    EnvSpec {
        id: format!("{name}-v5"),
        obs_shape: vec![STACK, SCREEN, SCREEN],
        action_space: ActionSpace::Discrete(n_actions),
        max_episode_steps: MAX_STEPS,
        groups: vec![],
    }
}

/// The per-env *game* RNG stream. One shared constructor so the scalar
/// env and the batched emulator draw the identical `Pcg32` sequence for
/// lane `env_id` (the salt is ASCII `ATAR`).
pub(crate) fn game_rng(seed: u64, env_id: u64) -> Pcg32 {
    Pcg32::new(seed ^ 0x41544152, env_id)
}

/// Result of the emulator phase of one step: everything the pixel
/// phase needs, so the finish pass never touches the game.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct EmulatePhase {
    /// Frameskip-summed reward.
    pub reward: f32,
    /// Game reported termination during the skip.
    pub done: bool,
    /// The skip reached its last frame, so `frame_a` must be max-pooled
    /// with `frame_b` (early death skips the pool, exactly as the
    /// original single-phase loop did).
    pub pool: bool,
    /// Life counter snapshot after the skip (pure getter — reading it
    /// here instead of after the pixel work cannot change it).
    pub lives: u32,
}

/// One environment's preprocessing **control** state: stack-ring head,
/// step/life counters. All the semantics of an Atari env step
/// (frameskip, max-pool, episodic life, truncation) live in the
/// methods here; the pixel buffers (two native frames + the stack
/// ring) are borrowed per call, so the scalar env can own them per
/// lane while the batched kernel packs every lane into one contiguous
/// slab (see module docs). The *game* RNG is likewise borrowed (built
/// via [`game_rng`]): the scalar [`PreprocState`] owns one per env,
/// the batched kernel owns one per lane so its lane passes can draw
/// per-lane in lane order.
pub(crate) struct PreprocCore {
    /// Index of the *newest* plane in the stack ring.
    head: usize,
    steps: usize,
    episodic_life: bool,
    lives: u32,
    n_actions: usize,
}

impl PreprocCore {
    pub(crate) fn new(n_actions: usize) -> Self {
        PreprocCore { head: 0, steps: 0, episodic_life: false, lives: 0, n_actions }
    }

    pub(crate) fn n_actions(&self) -> usize {
        self.n_actions
    }

    pub(crate) fn set_episodic_life(&mut self, on: bool) {
        self.episodic_life = on;
    }

    /// Push the pooled screen in `frame_a` into the stack ring.
    fn push_screen(&mut self, frame_a: &[u8], stack: &mut [f32]) {
        self.head = (self.head + 1) % STACK;
        let plane = SCREEN * SCREEN;
        let dst = &mut stack[self.head * plane..(self.head + 1) * plane];
        super::render::downsample_into(frame_a, dst);
    }

    /// Write the stacked observation, newest plane last (channel order
    /// oldest→newest, matching gym's FrameStack). Pure lane math — the
    /// batched kernel calls this in its SoA readout pass.
    pub(crate) fn write_obs(&self, stack: &[f32], obs: &mut [f32]) {
        let plane = SCREEN * SCREEN;
        for k in 0..STACK {
            let src_idx = (self.head + 1 + k) % STACK; // oldest first
            let src = &stack[src_idx * plane..(src_idx + 1) * plane];
            obs[k * plane..(k + 1) * plane].copy_from_slice(src);
        }
    }

    /// Does a reset need a **full** game reset (vs. the episodic-life
    /// continuation, which keeps the game running)? `lives` is the
    /// game's current life counter.
    pub(crate) fn reset_wants_full(&self, lives: u32) -> bool {
        !self.episodic_life || lives == 0 || self.steps == 0
    }

    /// Episode-start bookkeeping shared by every reset path: snapshot
    /// the life counter, zero the step count.
    pub(crate) fn begin_episode(&mut self, lives: u32) {
        self.lives = lives;
        self.steps = 0;
    }

    /// Emulator half of a reset: full game reset only when the game is
    /// actually over (episodic-life continuation otherwise, as the
    /// standard wrapper does), then the first native render. The
    /// batched kernel runs the same [`Self::reset_wants_full`] /
    /// [`Self::begin_episode`] protocol against its lane state.
    pub(crate) fn reset_emulate<G: Game>(
        &mut self,
        game: &mut G,
        rng: &mut Pcg32,
        frame_a: &mut [u8],
    ) {
        if self.reset_wants_full(game.lives()) {
            game.reset(rng);
        }
        self.begin_episode(game.lives());
        game.render(frame_a);
    }

    /// Pixel half of a reset: clear the stack ring and push the first
    /// screen.
    pub(crate) fn reset_finish(&mut self, frame_a: &[u8], stack: &mut [f32]) {
        stack.fill(0.0);
        self.push_screen(frame_a, stack);
    }

    /// Full reset (scalar path); the batched kernel runs the two halves
    /// in its phased loops instead.
    pub(crate) fn reset<G: Game>(
        &mut self,
        game: &mut G,
        rng: &mut Pcg32,
        frame_a: &mut [u8],
        stack: &mut [f32],
    ) {
        self.reset_emulate(game, rng, frame_a);
        self.reset_finish(frame_a, stack);
    }

    /// Emulator half of a step: frameskip ticks + native renders. No
    /// pixel math happens here — the caller completes the step with
    /// [`Self::step_finish`]. The batched twin is
    /// `vector::atari_emulate::step_emulate_batch`, which runs this
    /// exact skip protocol as masked lane-group tick passes.
    pub(crate) fn step_emulate<G: Game>(
        &mut self,
        game: &mut G,
        rng: &mut Pcg32,
        action: &[f32],
        frame_a: &mut [u8],
        frame_b: &mut [u8],
    ) -> EmulatePhase {
        let a = discrete_action(action, self.n_actions);
        let mut reward = 0.0;
        let mut done = false;
        let mut pool = false;
        // frameskip with max-pool of the last two frames (the pool
        // itself is deferred to the pixel phase)
        for k in 0..FRAMESKIP {
            let (r, d) = game.tick(a, rng);
            reward += r;
            if k == FRAMESKIP - 2 {
                game.render(frame_b);
            } else if k == FRAMESKIP - 1 {
                game.render(frame_a);
                pool = true;
            }
            if d {
                done = true;
                // render whatever we have if we died early in the skip
                if k < FRAMESKIP - 1 {
                    game.render(frame_a);
                }
                break;
            }
        }
        EmulatePhase { reward, done, pool, lives: game.lives() }
    }

    /// Pixel half of a step: 2-frame max-pool (when the skip
    /// completed), downsample + stack push, then episodic-life and
    /// truncation bookkeeping. Pure lane math over the borrowed
    /// buffers — the batched kernel streams this over its lane slab.
    pub(crate) fn step_finish(
        &mut self,
        frame_a: &mut [u8],
        frame_b: &[u8],
        stack: &mut [f32],
        ph: EmulatePhase,
    ) -> Step {
        if ph.pool {
            super::render::max_frames(frame_a, frame_b);
        }
        self.push_screen(frame_a, stack);
        self.steps += 1;

        // Episodic life: losing a life terminates the training episode.
        let mut done = ph.done;
        if self.episodic_life && !done {
            if ph.lives < self.lives {
                done = true;
            }
            self.lives = ph.lives;
        }

        let truncated = !done && self.steps >= MAX_STEPS;
        Step { reward: ph.reward, done, truncated }
    }
}

/// [`PreprocCore`] plus owned pixel buffers — the per-env shape the
/// scalar [`AtariEnv`] uses. Same core methods as the batched slab
/// path, so the two stay bitwise identical.
pub(crate) struct PreprocState {
    core: PreprocCore,
    /// The game's RNG stream (see [`game_rng`]).
    rng: Pcg32,
    /// Two native frame buffers for the flicker max-pool.
    frame_a: Vec<u8>,
    frame_b: Vec<u8>,
    /// Ring of stacked 84×84 planes.
    stack: Vec<f32>,
}

impl PreprocState {
    pub(crate) fn new(n_actions: usize, seed: u64, env_id: u64) -> Self {
        PreprocState {
            core: PreprocCore::new(n_actions),
            rng: game_rng(seed, env_id),
            frame_a: vec![0; NATIVE * NATIVE],
            frame_b: vec![0; NATIVE * NATIVE],
            stack: vec![0.0; STACK * SCREEN * SCREEN],
        }
    }

    pub(crate) fn set_episodic_life(&mut self, on: bool) {
        self.core.set_episodic_life(on);
    }

    /// Write the stacked observation (see [`PreprocCore::write_obs`]).
    pub(crate) fn write_obs(&self, obs: &mut [f32]) {
        self.core.write_obs(&self.stack, obs);
    }

    /// Reset the episode (see [`PreprocCore::reset`]).
    pub(crate) fn reset<G: Game>(&mut self, game: &mut G) {
        self.core.reset(game, &mut self.rng, &mut self.frame_a, &mut self.stack);
    }

    /// One env step: frameskip with max-pool, episodic-life handling,
    /// truncation. The caller writes the observation afterwards via
    /// [`Self::write_obs`].
    pub(crate) fn step<G: Game>(&mut self, game: &mut G, action: &[f32]) -> Step {
        let ph = self.core.step_emulate(
            game,
            &mut self.rng,
            action,
            &mut self.frame_a,
            &mut self.frame_b,
        );
        self.core.step_finish(&mut self.frame_a, &self.frame_b, &mut self.stack, ph)
    }
}

/// Atari-style environment over any [`Game`] — the scalar (one-lane)
/// adapter over [`PreprocState`].
pub struct AtariEnv<G: Game> {
    spec: EnvSpec,
    pub(crate) game: G,
    st: PreprocState,
}

impl<G: Game> AtariEnv<G> {
    pub fn new(game: G, seed: u64, env_id: u64) -> Self {
        let spec = spec_for(&game);
        let st = PreprocState::new(game.n_actions(), seed, env_id);
        AtariEnv { spec, game, st }
    }

    /// Enable episodic-life mode: life loss ends the (training) episode
    /// without resetting the game — the standard DQN wrapper.
    pub fn with_episodic_life(mut self, on: bool) -> Self {
        self.st.set_episodic_life(on);
        self
    }
}

impl<G: Game> Env for AtariEnv<G> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.st.reset(&mut self.game);
        self.st.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let s = self.st.step(&mut self.game, action);
        self.st.write_obs(obs);
        s
    }
}

/// Construct `Pong-v5`.
pub fn pong(seed: u64, env_id: u64) -> AtariEnv<super::pong::Pong> {
    AtariEnv::new(super::pong::Pong::new(), seed, env_id)
}

/// Construct `Breakout-v5` (episodic-life on, as the training stack uses).
pub fn breakout(seed: u64, env_id: u64) -> AtariEnv<super::breakout::Breakout> {
    AtariEnv::new(super::breakout::Breakout::new(), seed, env_id).with_episodic_life(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_shape_and_range() {
        let mut env = pong(0, 0);
        let dim = env.spec().obs_dim();
        assert_eq!(dim, 4 * 84 * 84);
        let mut obs = vec![0.0f32; dim];
        env.reset(&mut obs);
        for _ in 0..10 {
            env.step(&[0.0], &mut obs);
        }
        assert!(obs.iter().all(|&x| (0.0..=1.0).contains(&x)));
        assert!(obs.iter().any(|&x| x > 0.1), "screen should not be black");
    }

    #[test]
    fn stack_shifts_over_time() {
        let mut env = pong(1, 0);
        let dim = env.spec().obs_dim();
        let mut obs = vec![0.0f32; dim];
        env.reset(&mut obs);
        // step enough for the ball to be in play and moving
        for _ in 0..30 {
            env.step(&[2.0], &mut obs);
        }
        let plane = 84 * 84;
        let newest = &obs[3 * plane..4 * plane];
        let oldest = &obs[0..plane];
        let diff: f32 = newest.iter().zip(oldest).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0, "planes should differ as the game animates, diff={diff}");
    }

    #[test]
    fn pong_idle_eventually_done_with_negative_score() {
        let mut env = pong(2, 1);
        let dim = env.spec().obs_dim();
        let mut obs = vec![0.0f32; dim];
        env.reset(&mut obs);
        let mut total = 0.0;
        for _ in 0..60_000 {
            let s = env.step(&[0.0], &mut obs);
            total += s.reward;
            if s.done {
                assert_eq!(total, -21.0);
                return;
            }
        }
        panic!("idle pong episode must end");
    }

    #[test]
    fn breakout_episodic_life_terminates_on_life_loss() {
        let mut env = breakout(3, 0);
        let dim = env.spec().obs_dim();
        let mut obs = vec![0.0f32; dim];
        env.reset(&mut obs);
        // FIRE then idle: lose the first life -> done must fire with lives>0
        for _ in 0..20_000 {
            let s = env.step(&[1.0, 0.0][..1].as_ref(), &mut obs);
            if s.done {
                assert!(env.game.lives() > 0, "episodic life ends before game over");
                return;
            }
        }
        panic!("life should be lost");
    }

    #[test]
    fn deterministic_same_seed() {
        let run = |seed: u64| {
            let mut env = pong(seed, 7);
            let dim = env.spec().obs_dim();
            let mut obs = vec![0.0f32; dim];
            env.reset(&mut obs);
            let mut acc = 0.0f32;
            for i in 0..100 {
                let s = env.step(&[(i % 6) as f32], &mut obs);
                acc += s.reward + obs[1000] + obs[5000];
            }
            acc
        };
        assert_eq!(run(11), run(11));
    }
}
