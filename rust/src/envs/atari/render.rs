//! Grayscale software rasterizer for the arcade games: rectangle fills
//! into a NATIVE×NATIVE `u8` frame. This is where the Atari-like per-step
//! cost lives (as pixel work does in real ALE).
//!
//! The pooling/downsampling primitives at the bottom of this file are
//! the inner loops of the preprocessing **pixel phase**
//! ([`super::preproc::PreprocCore`]): on the batched path
//! (`envs::vector::AtariVec`) they stream over contiguous per-lane
//! slab slices with no emulator work interleaved, so keep them free of
//! per-call state — pure `&[u8]`-in/`&mut`-out — for that pass to stay
//! cache-friendly.

use super::NATIVE;

/// Fill the whole frame with one shade.
#[inline]
pub fn clear(frame: &mut [u8], shade: u8) {
    debug_assert_eq!(frame.len(), NATIVE * NATIVE);
    frame.fill(shade);
}

/// Fill an axis-aligned rectangle centered at `(cx, cy)`.
pub fn rect(frame: &mut [u8], cx: f32, cy: f32, w: f32, h: f32, shade: u8) {
    let x0 = ((cx - w / 2.0).floor().max(0.0)) as usize;
    let x1 = ((cx + w / 2.0).ceil().min(NATIVE as f32)) as usize;
    let y0 = ((cy - h / 2.0).floor().max(0.0)) as usize;
    let y1 = ((cy + h / 2.0).ceil().min(NATIVE as f32)) as usize;
    for y in y0..y1 {
        let row = &mut frame[y * NATIVE..(y + 1) * NATIVE];
        row[x0..x1].fill(shade);
    }
}

/// Dashed vertical line (Pong's net).
pub fn vline_dashed(frame: &mut [u8], x: usize, shade: u8) {
    if x >= NATIVE {
        return;
    }
    for y in (0..NATIVE).step_by(8) {
        for dy in 0..4 {
            if y + dy < NATIVE {
                frame[(y + dy) * NATIVE + x] = shade;
            }
        }
    }
}

/// Horizontal bar of given pixel length starting at `(x, y)` (scoreboard).
pub fn hbar(frame: &mut [u8], y: usize, x: usize, len: usize, shade: u8) {
    if y >= NATIVE {
        return;
    }
    let x1 = (x + len).min(NATIVE);
    let x0 = x.min(x1);
    frame[y * NATIVE + x0..y * NATIVE + x1].fill(shade);
}

/// 2×2 max-downsample NATIVE→SCREEN, writing normalized f32 into `out`
/// (the resize step of DQN preprocessing; max keeps thin sprites visible,
/// which is why ALE pipelines max-pool before resizing too).
pub fn downsample_into(frame: &[u8], out: &mut [f32]) {
    let s = super::SCREEN;
    debug_assert_eq!(frame.len(), NATIVE * NATIVE);
    debug_assert_eq!(out.len(), s * s);
    for y in 0..s {
        let r0 = &frame[(2 * y) * NATIVE..(2 * y) * NATIVE + NATIVE];
        let r1 = &frame[(2 * y + 1) * NATIVE..(2 * y + 1) * NATIVE + NATIVE];
        let dst = &mut out[y * s..(y + 1) * s];
        for (x, d) in dst.iter_mut().enumerate() {
            let m = r0[2 * x].max(r0[2 * x + 1]).max(r1[2 * x]).max(r1[2 * x + 1]);
            *d = m as f32 * (1.0 / 255.0);
        }
    }
}

/// Elementwise max of two native frames (flicker removal / 2-frame pool).
#[inline]
pub fn max_frames(a: &mut [u8], b: &[u8]) {
    for (x, &y) in a.iter_mut().zip(b.iter()) {
        *x = (*x).max(y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::atari::SCREEN;

    #[test]
    fn rect_clips_at_edges() {
        let mut f = vec![0u8; NATIVE * NATIVE];
        rect(&mut f, 0.0, 0.0, 10.0, 10.0, 255); // half off-screen
        rect(&mut f, NATIVE as f32, NATIVE as f32, 10.0, 10.0, 255);
        assert!(f.iter().any(|&p| p == 255));
        // no panic = clipping works; check corners painted
        assert_eq!(f[0], 255);
        assert_eq!(f[NATIVE * NATIVE - 1], 255);
    }

    #[test]
    fn downsample_preserves_bright_pixel() {
        let mut f = vec![0u8; NATIVE * NATIVE];
        f[37 * NATIVE + 91] = 255; // single bright pixel
        let mut out = vec![0.0f32; SCREEN * SCREEN];
        downsample_into(&f, &mut out);
        let v = out[(37 / 2) * SCREEN + 91 / 2];
        assert!((v - 1.0).abs() < 1e-6, "max-pool must keep the pixel, got {v}");
        assert_eq!(out.iter().filter(|&&x| x > 0.0).count(), 1);
    }

    #[test]
    fn max_frames_elementwise() {
        let mut a = vec![10u8; 16];
        let b: Vec<u8> = (0..16).map(|i| i as u8 * 2).collect();
        max_frames(&mut a, &b);
        assert_eq!(a[0], 10);
        assert_eq!(a[15], 30);
    }
}
