//! Breakout game logic: paddle at the bottom, 6 rows × 18 columns of
//! bricks, 5 lives, FIRE serves the ball. Minimal-action set
//! {NOOP, FIRE, RIGHT, LEFT} as in `Breakout-v5` (4 actions).

use super::game::{Game, Rect};
use super::NATIVE;
use crate::rng::Pcg32;

// Shared with the SoA lane twin (`envs::vector::atari_emulate`), which
// must reproduce scalar `tick`/`render` bitwise from the same numbers.
pub(crate) const ROWS: usize = 6;
pub(crate) const COLS: usize = 18;
pub(crate) const BRICK_W: f32 = NATIVE as f32 / COLS as f32;
pub(crate) const BRICK_H: f32 = 5.0;
pub(crate) const BRICK_TOP: f32 = 30.0;
pub(crate) const PADDLE_W: f32 = 18.0;
pub(crate) const PADDLE_H: f32 = 4.0;
pub(crate) const PADDLE_Y: f32 = NATIVE as f32 - 10.0;
pub(crate) const BALL: f32 = 3.0;
pub(crate) const PADDLE_SPEED: f32 = 4.0;
/// Row scores, top row worth most — matches Atari Breakout (7/7/4/4/1/1).
pub(crate) const ROW_SCORE: [f32; ROWS] = [7.0, 7.0, 4.0, 4.0, 1.0, 1.0];

pub struct Breakout {
    bricks: [[bool; COLS]; ROWS],
    remaining: usize,
    paddle_x: f32,
    ball: Rect,
    vx: f32,
    vy: f32,
    in_play: bool,
    lives: u32,
    over: bool,
}

impl Breakout {
    pub fn new() -> Self {
        Breakout {
            bricks: [[true; COLS]; ROWS],
            remaining: ROWS * COLS,
            paddle_x: NATIVE as f32 / 2.0,
            ball: Rect { x: 84.0, y: 120.0, w: BALL, h: BALL },
            vx: 0.0,
            vy: 0.0,
            in_play: false,
            lives: 5,
            over: false,
        }
    }

    fn serve(&mut self, rng: &mut Pcg32) {
        self.ball.x = self.paddle_x;
        self.ball.y = PADDLE_Y - 8.0;
        self.vx = rng.range(-1.5, 1.5);
        self.vy = -2.2;
        self.in_play = true;
    }

    fn brick_row_col(&self, x: f32, y: f32) -> Option<(usize, usize)> {
        if y < BRICK_TOP || y >= BRICK_TOP + ROWS as f32 * BRICK_H {
            return None;
        }
        let r = ((y - BRICK_TOP) / BRICK_H) as usize;
        let c = (x / BRICK_W) as usize;
        if r < ROWS && c < COLS && self.bricks[r][c] {
            Some((r, c))
        } else {
            None
        }
    }
}

impl Default for Breakout {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Breakout {
    fn n_actions(&self) -> usize {
        4
    }

    fn name(&self) -> &'static str {
        "Breakout"
    }

    fn lives(&self) -> u32 {
        self.lives
    }

    fn reset(&mut self, rng: &mut Pcg32) {
        *self = Breakout::new();
        self.paddle_x = rng.range(40.0, NATIVE as f32 - 40.0);
    }

    fn tick(&mut self, action: usize, rng: &mut Pcg32) -> (f32, bool) {
        if self.over {
            return (0.0, true);
        }
        // actions: 0 NOOP, 1 FIRE, 2 RIGHT, 3 LEFT
        match action {
            2 => self.paddle_x += PADDLE_SPEED,
            3 => self.paddle_x -= PADDLE_SPEED,
            1 if !self.in_play => self.serve(rng),
            _ => {}
        }
        let half_p = PADDLE_W / 2.0;
        self.paddle_x = self.paddle_x.clamp(half_p, NATIVE as f32 - half_p);
        if !self.in_play {
            return (0.0, false);
        }

        self.ball.x += self.vx;
        self.ball.y += self.vy;

        // Side / top walls.
        if self.ball.x < BALL / 2.0 {
            self.ball.x = BALL / 2.0;
            self.vx = self.vx.abs();
        } else if self.ball.x > NATIVE as f32 - BALL / 2.0 {
            self.ball.x = NATIVE as f32 - BALL / 2.0;
            self.vx = -self.vx.abs();
        }
        if self.ball.y < BALL / 2.0 {
            self.ball.y = BALL / 2.0;
            self.vy = self.vy.abs();
        }

        // Brick collision: test ball center.
        let mut reward = 0.0;
        if let Some((r, c)) = self.brick_row_col(self.ball.x, self.ball.y) {
            self.bricks[r][c] = false;
            self.remaining -= 1;
            reward = ROW_SCORE[r];
            self.vy = -self.vy;
            // ball speeds up when reaching the upper rows
            if r < 2 {
                self.vy = self.vy.signum() * self.vy.abs().max(3.0);
            }
            if self.remaining == 0 {
                self.over = true; // cleared the wall
                return (reward, true);
            }
        }

        // Paddle bounce with english.
        let paddle = Rect { x: self.paddle_x, y: PADDLE_Y, w: PADDLE_W, h: PADDLE_H };
        if self.vy > 0.0 && self.ball.intersects(&paddle) {
            self.vy = -self.vy.abs();
            self.vx += (self.ball.x - self.paddle_x) / half_p * 1.5;
            self.vx = self.vx.clamp(-3.5, 3.5);
        }

        // Ball lost.
        if self.ball.y > NATIVE as f32 {
            self.lives -= 1;
            self.in_play = false;
            if self.lives == 0 {
                self.over = true;
            }
        }
        (reward, self.over)
    }

    fn render(&self, frame: &mut [u8]) {
        super::render::clear(frame, 30);
        for (r, row) in self.bricks.iter().enumerate() {
            let shade = 120 + (r * 20) as u8;
            for (c, &alive) in row.iter().enumerate() {
                if alive {
                    super::render::rect(
                        frame,
                        (c as f32 + 0.5) * BRICK_W,
                        BRICK_TOP + (r as f32 + 0.5) * BRICK_H,
                        BRICK_W - 1.0,
                        BRICK_H - 1.0,
                        shade,
                    );
                }
            }
        }
        super::render::rect(frame, self.paddle_x, PADDLE_Y, PADDLE_W, PADDLE_H, 220);
        if self.in_play {
            super::render::rect(frame, self.ball.x, self.ball.y, BALL, BALL, 255);
        }
        // lives indicator
        super::render::hbar(frame, 2, 4, self.lives as usize * 4, 180);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fire_serves_and_bricks_break() {
        let mut g = Breakout::new();
        let mut rng = Pcg32::new(2, 0);
        g.reset(&mut rng);
        let mut total = 0.0;
        // track ball with paddle; fire when not in play
        for _ in 0..60_000 {
            let a = if !g.in_play {
                1
            } else if g.ball.x < g.paddle_x - 2.0 {
                3
            } else if g.ball.x > g.paddle_x + 2.0 {
                2
            } else {
                0
            };
            let (r, done) = g.tick(a, &mut rng);
            total += r;
            if done {
                break;
            }
        }
        assert!(total > 10.0, "tracking paddle should break bricks, got {total}");
    }

    #[test]
    fn idle_loses_all_lives() {
        let mut g = Breakout::new();
        let mut rng = Pcg32::new(7, 0);
        g.reset(&mut rng);
        // serve then do nothing, repeatedly
        let mut done = false;
        for _ in 0..200_000 {
            let a = if !g.in_play { 1 } else { 0 };
            let (_, d) = g.tick(a, &mut rng);
            if d {
                done = true;
                break;
            }
        }
        assert!(done, "idle play must end the game");
        assert_eq!(g.lives(), 0);
    }

    // Rasterization pin on exact hand-computable regions of the fresh
    // screen (brick-column geometry involves BRICK_W = 168/18 rounding,
    // so bricks are pinned differentially below instead).
    #[test]
    fn render_golden_regions_fresh_game() {
        let g = Breakout::new(); // paddle centered at 84
        let mut f = vec![0u8; NATIVE * NATIVE];
        g.render(&mut f);
        // Paddle: rect(84, 158, 18, 4) ⇒ x∈[75,93), y∈[156,160).
        for y in 156..160 {
            for x in 75..93 {
                assert_eq!(f[y * NATIVE + x], 220, "paddle at ({x},{y})");
            }
            assert_eq!(f[y * NATIVE + 74], 30);
            assert_eq!(f[y * NATIVE + 93], 30);
        }
        // Lives bar: 5 lives · 4 px at (row 2, x=4..24), shade 180.
        for x in 4..24 {
            assert_eq!(f[2 * NATIVE + x], 180, "lives bar at x={x}");
        }
        assert_eq!(f[2 * NATIVE + 3], 30);
        assert_eq!(f[2 * NATIVE + 24], 30);
        // Ball not in play; background above the bricks and below them.
        assert!(!f.contains(&255));
        assert_eq!(f[0], 30);
        assert_eq!(f[29 * NATIVE + 84], 30, "row above brick field");
        assert_eq!(f[100 * NATIVE + 84], 30, "open field below bricks");
        // Brick field rows carry the per-row shade ramp 120 + 20r at
        // each row's vertical center (rows 30..60, 5 px per row).
        for r in 0..ROWS {
            let y = (BRICK_TOP + (r as f32 + 0.5) * BRICK_H) as usize;
            assert_eq!(f[y * NATIVE + 84], 120 + (r * 20) as u8, "brick row {r}");
        }
    }

    // Differential brick pin: clearing one brick must turn exactly its
    // rectangle (and nothing else) from the row shade back to
    // background, with the area bounded by the brick cell size.
    #[test]
    fn render_cleared_brick_restores_background() {
        let mut g = Breakout::new();
        let mut before = vec![0u8; NATIVE * NATIVE];
        g.render(&mut before);
        g.bricks[2][7] = false;
        let mut after = vec![0u8; NATIVE * NATIVE];
        g.render(&mut after);
        let changed: Vec<usize> =
            (0..before.len()).filter(|&i| before[i] != after[i]).collect();
        assert!(
            (30..=60).contains(&changed.len()),
            "one brick is ~(BRICK_W-1)×(BRICK_H-1) px, changed {}",
            changed.len()
        );
        for &i in &changed {
            assert_eq!(before[i], 120 + 2 * 20, "was row-2 shade");
            assert_eq!(after[i], 30, "now background");
            let (y, x) = (i / NATIVE, i % NATIVE);
            // Row 2 occupies y∈[40,45); brick 7 of 18 sits left of center.
            assert!((40..45).contains(&y), "brick row 2 y bound, got {y}");
            assert!((60..80).contains(&x), "brick col 7 x bound, got {x}");
        }
    }

    #[test]
    fn lives_monotone_nonincreasing() {
        let mut g = Breakout::new();
        let mut rng = Pcg32::new(1, 0);
        g.reset(&mut rng);
        let mut last = g.lives();
        for i in 0..50_000 {
            let a = if !g.in_play { 1 } else { (i % 3) as usize };
            let (_, done) = g.tick(a, &mut rng);
            assert!(g.lives() <= last);
            last = g.lives();
            if done {
                break;
            }
        }
    }
}
