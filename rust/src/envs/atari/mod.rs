//! Atari-like arcade environments (ALE substitute — see DESIGN.md §2).
//!
//! Real ALE is a 6502 emulator; what matters for the *execution engine*
//! benchmarks is the per-step cost profile: advance game logic a few
//! frames, rasterize a grayscale screen, and run the DQN preprocessing
//! stack. This module implements faithful Pong and Breakout game logic,
//! rasterizes at a native 168×168 resolution, and applies the standard
//! preprocessing (frameskip 4, max-pool over the last 2 frames, resize to
//! 84×84, stack 4 frames) so the observation tensor matches `Pong-v5`'s
//! `(4, 84, 84)` exactly.

pub mod game;
pub mod pong;
pub mod breakout;
pub mod render;
pub mod preproc;

pub use preproc::AtariEnv;

/// Native rasterization resolution (downsampled 2× to 84×84).
pub const NATIVE: usize = 168;
/// Output observation edge length.
pub const SCREEN: usize = 84;
/// Frames advanced per env step (ALE frameskip).
pub const FRAMESKIP: usize = 4;
/// Stacked frames in the observation.
pub const STACK: usize = 4;
