//! Pong game logic: agent paddle on the right, tracking AI on the left,
//! first to 21 points. Minimal-action set {NOOP, FIRE, UP, DOWN, UPFIRE,
//! DOWNFIRE} as in `Pong-v5` (6 actions).

use super::game::{Game, Rect};
use super::NATIVE;
use crate::rng::Pcg32;

// Shared with the SoA lane twin (`envs::vector::atari_emulate`), which
// must reproduce scalar `tick`/`render` bitwise from the same numbers.
pub(crate) const PADDLE_W: f32 = 4.0;
pub(crate) const PADDLE_H: f32 = 22.0;
pub(crate) const BALL: f32 = 4.0;
pub(crate) const PADDLE_SPEED: f32 = 4.0;
pub(crate) const AI_SPEED: f32 = 2.6; // slightly slower than the agent: beatable
pub(crate) const SERVE_DELAY: u32 = 20;
pub(crate) const WIN_SCORE: u32 = 21;

pub struct Pong {
    ball: Rect,
    vx: f32,
    vy: f32,
    left_y: f32,
    right_y: f32,
    score_left: u32,
    score_right: u32,
    serve_timer: u32,
    serving_right: bool,
    over: bool,
}

impl Pong {
    pub fn new() -> Self {
        Pong {
            ball: Rect { x: 84.0, y: 84.0, w: BALL, h: BALL },
            vx: 0.0,
            vy: 0.0,
            left_y: 84.0,
            right_y: 84.0,
            score_left: 0,
            score_right: 0,
            serve_timer: SERVE_DELAY,
            serving_right: true,
            over: false,
        }
    }

    fn serve(&mut self, rng: &mut Pcg32) {
        self.ball.x = NATIVE as f32 / 2.0;
        self.ball.y = rng.range(40.0, NATIVE as f32 - 40.0);
        let dir = if self.serving_right { 1.0 } else { -1.0 };
        self.vx = dir * 2.2;
        self.vy = rng.range(-1.8, 1.8);
    }
}

impl Default for Pong {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Pong {
    fn n_actions(&self) -> usize {
        6
    }

    fn name(&self) -> &'static str {
        "Pong"
    }

    fn lives(&self) -> u32 {
        1
    }

    fn reset(&mut self, rng: &mut Pcg32) {
        *self = Pong::new();
        self.ball.y = rng.range(60.0, 108.0);
    }

    fn tick(&mut self, action: usize, rng: &mut Pcg32) -> (f32, bool) {
        if self.over {
            return (0.0, true);
        }
        // Agent paddle: UP = 2/4, DOWN = 3/5 (ALE minimal set ordering).
        let dy = match action {
            2 | 4 => -PADDLE_SPEED,
            3 | 5 => PADDLE_SPEED,
            _ => 0.0,
        };
        let half = PADDLE_H / 2.0;
        self.right_y = (self.right_y + dy).clamp(half, NATIVE as f32 - half);

        // AI paddle tracks the ball with capped speed + deadzone.
        let diff = self.ball.y - self.left_y;
        if diff.abs() > 2.0 {
            self.left_y += diff.signum() * AI_SPEED;
            self.left_y = self.left_y.clamp(half, NATIVE as f32 - half);
        }

        // Serve pause after each point (like the real game's dead time).
        if self.serve_timer > 0 {
            self.serve_timer -= 1;
            if self.serve_timer == 0 {
                self.serve(rng);
            }
            return (0.0, false);
        }

        self.ball.x += self.vx;
        self.ball.y += self.vy;

        // Wall bounces.
        if self.ball.y < BALL / 2.0 {
            self.ball.y = BALL / 2.0;
            self.vy = self.vy.abs();
        } else if self.ball.y > NATIVE as f32 - BALL / 2.0 {
            self.ball.y = NATIVE as f32 - BALL / 2.0;
            self.vy = -self.vy.abs();
        }

        // Paddle collisions: reflect, add english by contact offset.
        let left = Rect { x: 10.0, y: self.left_y, w: PADDLE_W, h: PADDLE_H };
        let right = Rect { x: NATIVE as f32 - 10.0, y: self.right_y, w: PADDLE_W, h: PADDLE_H };
        if self.vx < 0.0 && self.ball.intersects(&left) {
            self.vx = -self.vx * 1.03; // slight speed-up each rally
            self.vy += (self.ball.y - self.left_y) / half * 1.2;
        } else if self.vx > 0.0 && self.ball.intersects(&right) {
            self.vx = -self.vx * 1.03;
            self.vy += (self.ball.y - self.right_y) / half * 1.2;
        }
        self.vx = self.vx.clamp(-6.0, 6.0);
        self.vy = self.vy.clamp(-4.0, 4.0);

        // Scoring.
        let mut reward = 0.0;
        if self.ball.x < 0.0 {
            self.score_right += 1;
            reward = 1.0;
            self.serving_right = false;
            self.serve_timer = SERVE_DELAY;
        } else if self.ball.x > NATIVE as f32 {
            self.score_left += 1;
            reward = -1.0;
            self.serving_right = true;
            self.serve_timer = SERVE_DELAY;
        }
        if self.score_left >= WIN_SCORE || self.score_right >= WIN_SCORE {
            self.over = true;
        }
        (reward, self.over)
    }

    fn render(&self, frame: &mut [u8]) {
        super::render::clear(frame, 44); // Pong's dark background
        // center line
        super::render::vline_dashed(frame, NATIVE / 2, 90);
        super::render::rect(frame, 10.0, self.left_y, PADDLE_W, PADDLE_H, 200);
        super::render::rect(frame, NATIVE as f32 - 10.0, self.right_y, PADDLE_W, PADDLE_H, 200);
        if self.serve_timer == 0 {
            super::render::rect(frame, self.ball.x, self.ball.y, BALL, BALL, 255);
        }
        // score bars at the top (length ~ score) so the screen encodes score
        super::render::hbar(frame, 4, 20, self.score_left as usize * 3, 160);
        super::render::hbar(frame, 4, NATIVE - 20 - self.score_right as usize * 3,
            self.score_right as usize * 3, 160);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_episode(policy: impl Fn(&Pong) -> usize, seed: u64) -> (f32, u32) {
        let mut g = Pong::new();
        let mut rng = Pcg32::new(seed, 0);
        g.reset(&mut rng);
        let mut total = 0.0;
        let mut ticks = 0;
        loop {
            let a = policy(&g);
            let (r, done) = g.tick(a, &mut rng);
            total += r;
            ticks += 1;
            if done || ticks > 200_000 {
                return (total, ticks);
            }
        }
    }

    #[test]
    fn noop_loses_21_points() {
        let (total, _) = run_episode(|_| 0, 3);
        assert_eq!(total, -21.0, "idle agent must lose every point");
    }

    #[test]
    fn tracking_policy_scores_points() {
        // Perfect tracking beats the capped-speed AI eventually.
        let (total, _) = run_episode(
            |g| {
                if g.ball.y < g.right_y - 2.0 {
                    2
                } else if g.ball.y > g.right_y + 2.0 {
                    3
                } else {
                    0
                }
            },
            5,
        );
        assert!(total > 0.0, "tracking agent should win on balance, got {total}");
    }

    #[test]
    fn episode_reward_bounded() {
        let (total, _) = run_episode(|_| 2, 9);
        assert!((-21.0..=21.0).contains(&total));
    }

    #[test]
    fn render_draws_something() {
        let mut g = Pong::new();
        let mut rng = Pcg32::new(0, 0);
        g.reset(&mut rng);
        let mut f = vec![0u8; NATIVE * NATIVE];
        g.render(&mut f);
        let lit = f.iter().filter(|&&p| p > 100).count();
        assert!(lit > 50, "paddles/line should be visible, {lit} bright px");
    }

    // Golden rasterization pin for the fresh-game screen. Every term is
    // integer-exact in f32, so the sum is a hard constant:
    //   background   28224 px · 44      = 1_241_856
    //   center line     84 px · (90-44) = +3_864   (21 dashes × 4 rows)
    //   two paddles  2·88 px · (200-44) = +27_456  (4×22 px each)
    //   ball hidden (serve_timer = 20), score bars length 0.
    // The SoA lane rasterizer (`envs::vector::atari_emulate`) must hit
    // the same constant — it anchors the bitwise claim to real pixels.
    #[test]
    fn render_golden_frame_sum_fresh_game() {
        let g = Pong::new();
        let mut f = vec![0u8; NATIVE * NATIVE];
        g.render(&mut f);
        let sum: u64 = f.iter().map(|&p| p as u64).sum();
        assert_eq!(sum, 1_273_176);
        // Paddle bodies, exactly: left x∈[8,12), right x∈[156,160),
        // both y∈[73,95).
        for y in 73..95 {
            for x in 8..12 {
                assert_eq!(f[y * NATIVE + x], 200, "left paddle at ({x},{y})");
            }
            for x in 156..160 {
                assert_eq!(f[y * NATIVE + x], 200, "right paddle at ({x},{y})");
            }
        }
        assert_eq!(f[72 * NATIVE + 10], 44, "row above paddle is background");
        assert_eq!(f[95 * NATIVE + 10], 44, "row below paddle is background");
    }

    // Golden sum for a constructed mid-rally state: ball at integer-
    // friendly (50, 60) (16 px · 255, away from net/paddles), scores
    // 2:3 drawn as 6 px + 9 px bars at 160 on row 4.
    #[test]
    fn render_golden_frame_sum_ball_and_scores() {
        let mut g = Pong::new();
        g.serve_timer = 0;
        g.ball.x = 50.0;
        g.ball.y = 60.0;
        g.score_left = 2;
        g.score_right = 3;
        let mut f = vec![0u8; NATIVE * NATIVE];
        g.render(&mut f);
        let sum: u64 = f.iter().map(|&p| p as u64).sum();
        // 1_273_176 + 16·(255-44) + (6+9)·(160-44)
        assert_eq!(sum, 1_278_292);
        assert_eq!(f.iter().filter(|&&p| p == 255).count(), 16, "ball is 4×4");
        // Score bars: left starts at x=20, right ends at x=148.
        assert_eq!(f[4 * NATIVE + 20], 160);
        assert_eq!(f[4 * NATIVE + 25], 160);
        assert_eq!(f[4 * NATIVE + 26], 44);
        assert_eq!(f[4 * NATIVE + 139], 160);
        assert_eq!(f[4 * NATIVE + 147], 160);
        assert_eq!(f[4 * NATIVE + 148], 44);
    }
}
