//! The `Game` trait: raw arcade game logic at native frame rate,
//! decoupled from preprocessing (which lives in [`super::preproc`]).

use crate::rng::Pcg32;

/// One arcade game (Pong, Breakout, ...). Coordinates are in native
/// pixels (`[0, NATIVE)`), one `tick` is one native frame (60 Hz-ish).
pub trait Game: Send {
    /// Number of discrete (minimal) actions.
    fn n_actions(&self) -> usize;

    /// Task id suffix, e.g. `"Pong"`.
    fn name(&self) -> &'static str;

    /// Start a new game (full reset: score/lives cleared).
    fn reset(&mut self, rng: &mut Pcg32);

    /// Advance one native frame under `action`; returns (reward, game_over).
    fn tick(&mut self, action: usize, rng: &mut Pcg32) -> (f32, bool);

    /// Rasterize the current screen into `frame` (NATIVE×NATIVE grayscale).
    fn render(&self, frame: &mut [u8]);

    /// Remaining lives (1 if the game has no life system). Used by the
    /// episodic-life wrapper.
    fn lives(&self) -> u32;
}

/// Axis-aligned box with f32 center coordinates, used by both games.
#[derive(Debug, Clone, Copy)]
pub struct Rect {
    pub x: f32,
    pub y: f32,
    pub w: f32,
    pub h: f32,
}

impl Rect {
    pub fn intersects(&self, o: &Rect) -> bool {
        (self.x - o.x).abs() * 2.0 < self.w + o.w && (self.y - o.y).abs() * 2.0 < self.h + o.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_intersection() {
        let a = Rect { x: 10.0, y: 10.0, w: 4.0, h: 4.0 };
        let b = Rect { x: 13.0, y: 10.0, w: 4.0, h: 4.0 };
        let c = Rect { x: 20.0, y: 10.0, w: 4.0, h: 4.0 };
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c));
        assert!(a.intersects(&a));
    }
}
