//! Batch-resident physics: [`WorldBatch`] keeps the body state, joint
//! warm-start impulses and contact caches of **many** worlds in
//! struct-of-arrays lanes and runs every sequential-impulse solver phase
//! as a masked lane-group pass over [`crate::simd::F32s`].
//!
//! # Layout (body-major)
//!
//! All lanes share one articulation **topology** (bodies, joints,
//! limits, gears — captured once from a prototype [`World`]); only the
//! *state* is per lane, stored **body-major** so the lane index is the
//! fastest-moving one:
//!
//! - body state (`pos_x/pos_y/angle/vel_x/vel_y/omega`) is indexed
//!   `[body * lanes + lane]`;
//! - joint solver state (prepared anchors, accumulated point/limit
//!   impulses, limit activity) is indexed `[joint * lanes + lane]`;
//! - contact caches use **padded per-lane contact slots**: every
//!   `(body, endpoint)` pair owns a fixed slot
//!   (`[(body * 2 + endpoint) * lanes + lane]`) with an activity
//!   flag. Divergent contact sets across lanes become activity masks,
//!   and warm-start matching is the slot identity itself — exactly the
//!   `(body, point)` key the AoS [`contact`](super::contact) path
//!   searches `prev` for.
//!
//! The solver walks bodies/joints in the outer loop and lane groups in
//! the inner one, so under this layout **every** lane-group load and
//! store in the hot path is one contiguous `[base .. base + n]` slice —
//! no stride-`nb` gathers (the pre-body-major layout's cost; Table 2g
//! in `benches/table2g_contig.rs` gates the win). The layout is a pure
//! storage permutation: per-lane operation order is unchanged, so the
//! parity contract below is untouched.
//!
//! # Solver phases (identical order to [`World::step`])
//!
//! 1. external forces (gravity, damping, motor torques);
//! 2. joint prepare (anchors, limit states) + warm start, then contact
//!    collect + warm start;
//! 3. `ITERATIONS` velocity rounds (joints sequentially, then contacts);
//! 4. speed clamps + semi-implicit position integration;
//! 5. split position correction (joints + ground), with the
//!    `worst < 5e-4` early-exit applied **per lane** through the
//!    activity mask — each lane stops iterating exactly when its own
//!    scalar run would have.
//!
//! # The parity contract
//!
//! Every arithmetic op in the lane pass is elementwise and applied in
//! the same order as the scalar AoS code (including the literal
//! `+ 0.0` bias terms and `p * -m` sign shapes, which matter for
//! `-0.0`), and every state write is a masked **select** — masked lanes
//! are never touched, not even by adding zero. The one width-dependent
//! ingredient is trig: at `W == 1` anchors and capsule endpoints rotate
//! through `f32::sin_cos` (libm — bitwise identical to the pre-batch
//! [`World::step`], pinned by a unit test below and by
//! `tests/mujoco_batch_parity.rs`); at `W > 1` they rotate through the
//! branchless [`crate::simd::math`] twins so the whole pass
//! vectorizes. The twins sit within 1 ULP of f64 libm, so widths 4/8
//! follow trajectories that drift from width 1 within the documented
//! budget [`LANE_TOL_ABS`]`/`[`LANE_TOL_REL`] over short horizons —
//! the *relaxed, asserted* tolerance contract (`ISSUE 5`), replacing
//! the old bitwise-only contract that forced the solver to stay scalar
//! per lane.

use super::body::Body;
use super::dynamics::{
    World, DAMPING, GRAVITY, ITERATIONS, JOINT_BETA, MAX_OMEGA, MAX_SPEED, POSITION_ITERATIONS,
};
use super::contact::{BETA, FRICTION, SLOP};
use crate::rng::Pcg32;
use crate::simd::{F32s, Mask};

/// Absolute term of the documented widths-4/8-vs-width-1 tolerance
/// budget for walker observations/rewards over the pinned short-horizon
/// parity trajectories (see `tests/mujoco_batch_parity.rs`). Width 1 is
/// bitwise and has no budget.
pub const LANE_TOL_ABS: f32 = 2e-2;
/// Relative term of the widths > 1 tolerance budget.
pub const LANE_TOL_REL: f32 = 2e-2;

/// Contiguous lane-group load: `n` lanes starting at `base`, tail
/// padded with `0.0` (padded lanes are masked out of every store).
/// Body-major layout makes every solver access this shape.
#[inline(always)]
fn ldc<const W: usize>(src: &[f32], base: usize, n: usize) -> F32s<W> {
    F32s::load_or(&src[base..base + n], 0.0)
}

/// Contiguous masked store: lanes where `m` is clear keep their old
/// value — a select, not an add-zero, so `-0.0` survives in masked
/// lanes. Tail lanes are never set in `m`, so `base + i` stays in
/// bounds.
#[inline(always)]
fn stc<const W: usize>(dst: &mut [f32], base: usize, m: &Mask<W>, v: F32s<W>) {
    for i in 0..W {
        if m.0[i] {
            dst[base + i] = v.0[i];
        }
    }
}

/// Rotation trig for the lane pass. Width 1 **must** call the same
/// `f32::sin_cos` the AoS [`super::math::Vec2::rotate`] uses — that is
/// the bitwise half of the parity contract; wider groups use the
/// deterministic branchless twins so the pass vectorizes (the
/// tolerance half).
#[inline(always)]
fn sin_cos_w<const W: usize>(x: F32s<W>) -> (F32s<W>, F32s<W>) {
    if W == 1 {
        let (s, c) = x.0[0].sin_cos();
        (F32s::splat(s), F32s::splat(c))
    } else {
        x.sin_cos()
    }
}

/// Per-lane `f32::clamp` with lane-varying bounds (same NaN/panic
/// semantics as the scalar `.clamp` it replaces).
#[inline(always)]
fn clamp_each<const W: usize>(x: F32s<W>, lo: F32s<W>, hi: F32s<W>) -> F32s<W> {
    F32s::from_fn(|i| x.0[i].clamp(lo.0[i], hi.0[i]))
}

/// Lane-group twin of [`super::math::solve22`]: the degenerate-`det`
/// branch becomes a select (the discarded lanes may compute `inf`, which
/// never escapes the select).
#[inline(always)]
fn solve22_w<const W: usize>(
    k11: F32s<W>,
    k12: F32s<W>,
    k22: F32s<W>,
    bx: F32s<W>,
    by: F32s<W>,
) -> (F32s<W>, F32s<W>) {
    let det = k11 * k22 - k12 * k12;
    let degenerate = det.abs().lt(F32s::splat(1e-12));
    let inv = F32s::splat(1.0) / det;
    let x = inv * (k22 * bx - k12 * by);
    let y = inv * (k11 * by - k12 * bx);
    let zero = F32s::splat(0.0);
    (degenerate.select_f32(zero, x), degenerate.select_f32(zero, y))
}

/// A batch of articulated rigid-body worlds sharing one topology, with
/// all mutable solver state resident in body-major SoA lanes. See the
/// module docs for the layout and the parity contract.
#[derive(Debug, Clone)]
pub struct WorldBatch {
    lanes: usize,
    nb: usize,
    nj: usize,
    // --- shared topology (lane-invariant, captured from the proto) ---
    inv_mass: Vec<f32>,
    inv_inertia: Vec<f32>,
    half_len: Vec<f32>,
    radius: Vec<f32>,
    j_a: Vec<usize>,
    j_b: Vec<usize>,
    anchor_ax: Vec<f32>,
    anchor_ay: Vec<f32>,
    anchor_bx: Vec<f32>,
    anchor_by: Vec<f32>,
    has_limit: Vec<bool>,
    limit_lo: Vec<f32>,
    limit_hi: Vec<f32>,
    ref_angle: Vec<f32>,
    gear: Vec<f32>,
    // --- reset template (the proto's body state, one lane's worth,
    //     body-indexed) ---
    init_pos_x: Vec<f32>,
    init_pos_y: Vec<f32>,
    init_angle: Vec<f32>,
    init_vel_x: Vec<f32>,
    init_vel_y: Vec<f32>,
    init_omega: Vec<f32>,
    // --- per-lane physics parameters (scenario pools / domain
    //     randomization), indexed [lane]. Defaults are the broadcast
    //     constants (GRAVITY, 1.0), which keeps the no-override path
    //     bitwise identical: `grav[l] * dt` with the default is the
    //     same IEEE multiply that const-folded `GRAVITY * dt`, and
    //     `tau * 1.0` is exact. Deliberately NOT cleared by
    //     `reset_lane` — a lane keeps its drawn parameters across
    //     episode resets (the scenario replayability contract). ---
    grav: Vec<f32>,
    gear_scale: Vec<f32>,
    // --- per-lane body state, indexed [body * lanes + lane] ---
    pub pos_x: Vec<f32>,
    pub pos_y: Vec<f32>,
    pub angle: Vec<f32>,
    pub vel_x: Vec<f32>,
    pub vel_y: Vec<f32>,
    pub omega: Vec<f32>,
    // --- per-lane joint solver state, indexed [joint * lanes + lane] ---
    jr_ax: Vec<f32>,
    jr_ay: Vec<f32>,
    jr_bx: Vec<f32>,
    jr_by: Vec<f32>,
    jimp_x: Vec<f32>,
    jimp_y: Vec<f32>,
    jlimit_imp: Vec<f32>,
    /// 0 = inactive, 1 = at lower, 2 = at upper (the AoS `LimitState`).
    jlimit_state: Vec<u8>,
    // --- padded per-lane contact slots, [(body * 2 + endpoint) * lanes + lane] ---
    c_active: Vec<bool>,
    c_rx: Vec<f32>,
    c_ry: Vec<f32>,
    c_jn: Vec<f32>,
    c_jt: Vec<f32>,
}

impl WorldBatch {
    /// Capture `proto`'s topology and replicate its body state across
    /// `lanes` lanes (each lane starts as an un-noised copy of the
    /// prototype — call [`Self::reset_lane`] +
    /// [`Self::apply_reset_noise`] before use, as the task layer does).
    pub fn from_world(proto: &World, lanes: usize) -> WorldBatch {
        let nb = proto.bodies.len();
        let nj = proto.joints.len();
        let b = &proto.bodies;
        let grab = |f: fn(&Body) -> f32| -> Vec<f32> { b.iter().map(|x| f(x)).collect() };
        let init_pos_x = grab(|x| x.pos.x);
        let init_pos_y = grab(|x| x.pos.y);
        let init_angle = grab(|x| x.angle);
        let init_vel_x = grab(|x| x.vel.x);
        let init_vel_y = grab(|x| x.vel.y);
        let init_omega = grab(|x| x.omega);
        // Body-major replication: each body's template value occupies a
        // contiguous run of `lanes` slots.
        let rep = |src: &[f32]| -> Vec<f32> {
            let mut out = Vec::with_capacity(lanes * src.len());
            for &v in src {
                out.extend(std::iter::repeat(v).take(lanes));
            }
            out
        };
        WorldBatch {
            lanes,
            nb,
            nj,
            inv_mass: grab(|x| x.inv_mass),
            inv_inertia: grab(|x| x.inv_inertia),
            half_len: grab(|x| x.half_len),
            radius: grab(|x| x.radius),
            j_a: proto.joints.iter().map(|j| j.body_a).collect(),
            j_b: proto.joints.iter().map(|j| j.body_b).collect(),
            anchor_ax: proto.joints.iter().map(|j| j.local_anchor_a.x).collect(),
            anchor_ay: proto.joints.iter().map(|j| j.local_anchor_a.y).collect(),
            anchor_bx: proto.joints.iter().map(|j| j.local_anchor_b.x).collect(),
            anchor_by: proto.joints.iter().map(|j| j.local_anchor_b.y).collect(),
            has_limit: proto.joints.iter().map(|j| j.limit.is_some()).collect(),
            limit_lo: proto.joints.iter().map(|j| j.limit.map_or(0.0, |l| l.0)).collect(),
            limit_hi: proto.joints.iter().map(|j| j.limit.map_or(0.0, |l| l.1)).collect(),
            ref_angle: proto.joints.iter().map(|j| j.ref_angle).collect(),
            gear: proto.joints.iter().map(|j| j.gear).collect(),
            grav: vec![GRAVITY; lanes],
            gear_scale: vec![1.0; lanes],
            pos_x: rep(&init_pos_x),
            pos_y: rep(&init_pos_y),
            angle: rep(&init_angle),
            vel_x: rep(&init_vel_x),
            vel_y: rep(&init_vel_y),
            omega: rep(&init_omega),
            init_pos_x,
            init_pos_y,
            init_angle,
            init_vel_x,
            init_vel_y,
            init_omega,
            jr_ax: vec![0.0; lanes * nj],
            jr_ay: vec![0.0; lanes * nj],
            jr_bx: vec![0.0; lanes * nj],
            jr_by: vec![0.0; lanes * nj],
            jimp_x: vec![0.0; lanes * nj],
            jimp_y: vec![0.0; lanes * nj],
            jlimit_imp: vec![0.0; lanes * nj],
            jlimit_state: vec![0; lanes * nj],
            c_active: vec![false; lanes * nb * 2],
            c_rx: vec![0.0; lanes * nb * 2],
            c_ry: vec![0.0; lanes * nb * 2],
            c_jn: vec![0.0; lanes * nb * 2],
            c_jt: vec![0.0; lanes * nb * 2],
        }
    }

    /// Number of lanes in the batch.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Override the per-lane gravity (scenario pools / domain
    /// randomization). `values.len()` must equal the lane count.
    pub fn set_gravity_lanes(&mut self, values: &[f32]) {
        assert_eq!(values.len(), self.lanes, "gravity lane count");
        self.grav.copy_from_slice(values);
    }

    /// Override the per-lane motor gear multiplier (applied on top of
    /// the per-joint topology gear). `values.len()` must equal the lane
    /// count; 1.0 is the identity.
    pub fn set_gear_scale_lanes(&mut self, values: &[f32]) {
        assert_eq!(values.len(), self.lanes, "gear_scale lane count");
        self.gear_scale.copy_from_slice(values);
    }

    /// Bodies per lane.
    pub fn num_bodies(&self) -> usize {
        self.nb
    }

    /// Index of `(lane, body)` in the body-state lanes
    /// (`pos_x`/`pos_y`/`angle`/`vel_x`/`vel_y`/`omega`): body-major,
    /// `body * lanes + lane`. The task layer and tests go through this
    /// instead of hardcoding the layout.
    #[inline(always)]
    pub fn body_index(&self, lane: usize, body: usize) -> usize {
        body * self.lanes + lane
    }

    /// Restore lane `lane` to the prototype pose and clear all of its
    /// solver warm-start state (joint impulses, limit states, contact
    /// slots) — the batch equivalent of `model = proto.clone()`. Under
    /// the body-major layout this is a strided walk (one slot per
    /// body/joint/contact row); resets are episode-boundary-rate, not
    /// hot-path.
    pub fn reset_lane(&mut self, lane: usize) {
        let lanes = self.lanes;
        for b in 0..self.nb {
            let i = b * lanes + lane;
            self.pos_x[i] = self.init_pos_x[b];
            self.pos_y[i] = self.init_pos_y[b];
            self.angle[i] = self.init_angle[b];
            self.vel_x[i] = self.init_vel_x[b];
            self.vel_y[i] = self.init_vel_y[b];
            self.omega[i] = self.init_omega[b];
        }
        for j in 0..self.nj {
            let i = j * lanes + lane;
            self.jr_ax[i] = 0.0;
            self.jr_ay[i] = 0.0;
            self.jr_bx[i] = 0.0;
            self.jr_by[i] = 0.0;
            self.jimp_x[i] = 0.0;
            self.jimp_y[i] = 0.0;
            self.jlimit_imp[i] = 0.0;
            self.jlimit_state[i] = 0;
        }
        for slot in 0..self.nb * 2 {
            let i = slot * lanes + lane;
            self.c_active[i] = false;
            self.c_rx[i] = 0.0;
            self.c_ry[i] = 0.0;
            self.c_jn[i] = 0.0;
            self.c_jt[i] = 0.0;
        }
    }

    /// Gym-style reset noise on lane `lane` — the same per-body draw
    /// order (angle, vel.x, vel.y, omega) as the AoS
    /// [`super::walker::apply_reset_noise`], which is the determinism
    /// contract the scalar/vector parity tests rely on.
    pub fn apply_reset_noise(&mut self, lane: usize, rng: &mut Pcg32) {
        let lanes = self.lanes;
        for b in 0..self.nb {
            if self.inv_mass[b] > 0.0 {
                let i = b * lanes + lane;
                self.angle[i] += rng.range(-0.005, 0.005);
                self.vel_x[i] += rng.range(-0.01, 0.01);
                self.vel_y[i] += rng.range(-0.01, 0.01);
                self.omega[i] += rng.range(-0.01, 0.01);
            }
        }
    }

    /// Any non-finite state in lane `lane`? (Batch twin of
    /// [`World::is_bad`].)
    pub fn lane_is_bad(&self, lane: usize) -> bool {
        for b in 0..self.nb {
            let i = b * self.lanes + lane;
            if !self.pos_x[i].is_finite()
                || !self.pos_y[i].is_finite()
                || !self.angle[i].is_finite()
                || !self.vel_x[i].is_finite()
                || !self.vel_y[i].is_finite()
                || !self.omega[i].is_finite()
            {
                return true;
            }
        }
        false
    }

    /// Total kinetic energy of lane `lane` (invariant probes in tests).
    pub fn kinetic_energy(&self, lane: usize) -> f32 {
        let mut ke = 0.0;
        for b in 0..self.nb {
            let m = if self.inv_mass[b] > 0.0 { 1.0 / self.inv_mass[b] } else { 0.0 };
            let i = if self.inv_inertia[b] > 0.0 { 1.0 / self.inv_inertia[b] } else { 0.0 };
            let bi = b * self.lanes + lane;
            let (vx, vy, w) = (self.vel_x[bi], self.vel_y[bi], self.omega[bi]);
            ke += 0.5 * m * (vx * vx + vy * vy) + 0.5 * i * w * w;
        }
        ke
    }

    /// Worst ground penetration (capsule-endpoint depth below `y = 0`)
    /// in lane `lane`; `<= 0` means no contact. The post-correction
    /// penetration invariant in `tests/mujoco_batch_parity.rs` bounds
    /// this at every lane width.
    pub fn max_penetration(&self, lane: usize) -> f32 {
        let mut worst = 0.0f32;
        for b in 0..self.nb {
            if self.inv_mass[b] <= 0.0 {
                continue;
            }
            let bi = b * self.lanes + lane;
            let (s, _c) = self.angle[bi].sin_cos();
            for e in [-1.0f32, 1.0] {
                let ey = self.pos_y[bi] + s * (e * self.half_len[b]);
                worst = worst.max(self.radius[b] - ey);
            }
        }
        worst
    }

    /// Advance every unmasked lane one substep of `dt` seconds.
    /// `ctrl` is row-major `[lanes, adim]` (clamped to `[-1, 1]` per
    /// actuator, as [`World::step`] does); lanes with
    /// `skip[lane] != 0` are left completely untouched. `width`
    /// selects the lane-group size (1 = the bitwise scalar-order
    /// reference; 4/8 = the vectorized solver under the tolerance
    /// contract).
    pub fn step(&mut self, dt: f32, ctrl: &[f32], adim: usize, skip: &[u8], width: usize) {
        debug_assert_eq!(skip.len(), self.lanes);
        debug_assert!(ctrl.len() >= self.lanes * adim);
        match width {
            8 => self.step_all::<8>(dt, ctrl, adim, skip),
            4 => self.step_all::<4>(dt, ctrl, adim, skip),
            _ => self.step_all::<1>(dt, ctrl, adim, skip),
        }
    }

    fn step_all<const W: usize>(&mut self, dt: f32, ctrl: &[f32], adim: usize, skip: &[u8]) {
        let mut g = 0;
        while g < self.lanes {
            let n = W.min(self.lanes - g);
            let act = Mask::<W>(std::array::from_fn(|i| i < n && skip[g + i] == 0));
            if act.any() {
                self.step_group::<W>(g, n, dt, ctrl, adim, &act);
            }
            g += W;
        }
    }

    /// One substep for the lane group `[g, g + n)` (mask `act` excludes
    /// resetting lanes and the tail). Phase structure and per-lane op
    /// order are the AoS [`World::step`]'s, transcribed literally —
    /// see the module docs for what is allowed to differ per width.
    /// Every `bi`/`ai`/`ji`/`si` below is a contiguous base offset
    /// (body-major layout), so each `ldc`/`stc` touches one cache-line
    /// run of `n` lanes.
    fn step_group<const W: usize>(
        &mut self,
        g: usize,
        n: usize,
        dt: f32,
        ctrl: &[f32],
        adim: usize,
        act: &Mask<W>,
    ) {
        let lanes = self.lanes;
        let nb = self.nb;
        let nj = self.nj;
        let s = F32s::<W>::splat;
        let zero = s(0.0);
        let damp = 1.0 - DAMPING * dt;

        // 1. external forces: gravity + damping, then motor torques.
        for b in 0..nb {
            if self.inv_mass[b] <= 0.0 {
                continue; // static bodies take no external forces (uniform)
            }
            let bi = b * lanes + g;
            let vx = ldc::<W>(&self.vel_x, bi, n);
            // Per-lane gravity: `grav[l] * dt` with the default lane
            // value GRAVITY is the same IEEE multiply as the old
            // broadcast `GRAVITY * dt` — bitwise identical.
            let vy = ldc::<W>(&self.vel_y, bi, n) - ldc::<W>(&self.grav, g, n) * s(dt);
            let om = ldc::<W>(&self.omega, bi, n);
            stc(&mut self.vel_x, bi, act, vx * s(damp));
            stc(&mut self.vel_y, bi, act, vy * s(damp));
            stc(&mut self.omega, bi, act, om * s(damp));
        }
        let mut ci = 0usize;
        for j in 0..nj {
            if self.gear[j] <= 0.0 {
                continue;
            }
            let (a, b) = (self.j_a[j], self.j_b[j]);
            let tau = F32s::<W>::from_fn(|i| {
                if i < n && act.0[i] {
                    ctrl.get((g + i) * adim + ci).copied().unwrap_or(0.0).clamp(-1.0, 1.0)
                        * self.gear[j]
                } else {
                    0.0
                }
            });
            // Per-lane motor scaling; masked lanes stay 0.0 and the
            // default 1.0 multiply is exact, so no-override is bitwise.
            let tau = tau * ldc::<W>(&self.gear_scale, g, n);
            ci += 1;
            let ai = a * lanes + g;
            let bi = b * lanes + g;
            let oa = ldc::<W>(&self.omega, ai, n) - s(self.inv_inertia[a]) * tau * s(dt);
            let ob = ldc::<W>(&self.omega, bi, n) + s(self.inv_inertia[b]) * tau * s(dt);
            stc(&mut self.omega, ai, act, oa);
            stc(&mut self.omega, bi, act, ob);
        }

        // 2a. prepare joints (anchors, limit states) + warm start.
        for j in 0..nj {
            let (a, b) = (self.j_a[j], self.j_b[j]);
            let ai = a * lanes + g;
            let bi = b * lanes + g;
            let ji = j * lanes + g;
            let ang_a = ldc::<W>(&self.angle, ai, n);
            let ang_b = ldc::<W>(&self.angle, bi, n);
            let (sa, ca) = sin_cos_w(ang_a);
            let (sb, cb) = sin_cos_w(ang_b);
            // r = local_anchor.rotate(angle): (c·x − s·y, s·x + c·y)
            let (lax, lay) = (s(self.anchor_ax[j]), s(self.anchor_ay[j]));
            let (lbx, lby) = (s(self.anchor_bx[j]), s(self.anchor_by[j]));
            let rax = ca * lax - sa * lay;
            let ray = sa * lax + ca * lay;
            let rbx = cb * lbx - sb * lby;
            let rby = sb * lbx + cb * lby;
            stc(&mut self.jr_ax, ji, act, rax);
            stc(&mut self.jr_ay, ji, act, ray);
            stc(&mut self.jr_bx, ji, act, rbx);
            stc(&mut self.jr_by, ji, act, rby);
            // limit state: AtLower if ang <= lo, else AtUpper if ang >= hi.
            let mut li = ldc::<W>(&self.jlimit_imp, ji, n);
            if self.has_limit[j] {
                let ang = ang_b - ang_a - s(self.ref_angle[j]);
                let at_lower = ang.le(s(self.limit_lo[j]));
                let at_upper = ang.ge(s(self.limit_hi[j])) & !at_lower;
                for i in 0..W {
                    if act.0[i] {
                        self.jlimit_state[ji + i] = if at_lower.0[i] {
                            1
                        } else if at_upper.0[i] {
                            2
                        } else {
                            0
                        };
                    }
                }
                // inactive limits drop their accumulated impulse
                li = (at_lower | at_upper).select_f32(li, zero);
                stc(&mut self.jlimit_imp, ji, act, li);
            }
            // warm start: re-apply last substep's accumulated impulses.
            let px = ldc::<W>(&self.jimp_x, ji, n);
            let py = ldc::<W>(&self.jimp_y, ji, n);
            let (npx, npy) = (-px, -py);
            let (ima, iia) = (s(self.inv_mass[a]), s(self.inv_inertia[a]));
            let (imb, iib) = (s(self.inv_mass[b]), s(self.inv_inertia[b]));
            let vax = ldc::<W>(&self.vel_x, ai, n) + npx * ima;
            let vay = ldc::<W>(&self.vel_y, ai, n) + npy * ima;
            let oa = ldc::<W>(&self.omega, ai, n) + iia * (rax * npy - ray * npx) - iia * li;
            let vbx = ldc::<W>(&self.vel_x, bi, n) + px * imb;
            let vby = ldc::<W>(&self.vel_y, bi, n) + py * imb;
            let ob = ldc::<W>(&self.omega, bi, n) + iib * (rbx * py - rby * px) + iib * li;
            stc(&mut self.vel_x, ai, act, vax);
            stc(&mut self.vel_y, ai, act, vay);
            stc(&mut self.omega, ai, act, oa);
            stc(&mut self.vel_x, bi, act, vbx);
            stc(&mut self.vel_y, bi, act, vby);
            stc(&mut self.omega, bi, act, ob);
        }

        // 2b. collect ground contacts into the fixed (body, endpoint)
        // slots + warm start persisting ones.
        for b in 0..nb {
            if self.inv_mass[b] <= 0.0 {
                continue;
            }
            let bi = b * lanes + g;
            let ang = ldc::<W>(&self.angle, bi, n);
            let (sn, cs) = sin_cos_w(ang);
            let px_ = ldc::<W>(&self.pos_x, bi, n);
            let py_ = ldc::<W>(&self.pos_y, bi, n);
            let rad = s(self.radius[b]);
            let (im, ii) = (s(self.inv_mass[b]), s(self.inv_inertia[b]));
            for e in 0..2 {
                let lx = s(if e == 0 { -self.half_len[b] } else { self.half_len[b] });
                // world endpoint = pos + (lx, 0).rotate(angle), with the
                // literal ·0.0 terms kept (sign-of-zero parity).
                let ex = px_ + (cs * lx - sn * zero);
                let ey = py_ + (sn * lx + cs * zero);
                let lowest = ey - rad;
                let si = (b * 2 + e) * lanes + g;
                let now = lowest.lt(zero) & *act;
                let was = Mask::<W>(std::array::from_fn(|i| i < n && self.c_active[si + i]));
                let keep = now & was;
                let rx = ex - px_;
                let ry = zero - py_;
                let jn = keep.select_f32(ldc::<W>(&self.c_jn, si, n), zero);
                let jt = keep.select_f32(ldc::<W>(&self.c_jt, si, n), zero);
                stc(&mut self.c_rx, si, &now, rx);
                stc(&mut self.c_ry, si, &now, ry);
                stc(&mut self.c_jn, si, &now, jn);
                stc(&mut self.c_jt, si, &now, jt);
                for i in 0..W {
                    if act.0[i] {
                        self.c_active[si + i] = now.0[i];
                    }
                }
                // warm start persisting contacts: apply_impulse((jt, jn), r)
                let vx1 = ldc::<W>(&self.vel_x, bi, n) + jt * im;
                let vy1 = ldc::<W>(&self.vel_y, bi, n) + jn * im;
                let om1 = ldc::<W>(&self.omega, bi, n) + ii * (rx * jn - ry * jt);
                stc(&mut self.vel_x, bi, &keep, vx1);
                stc(&mut self.vel_y, bi, &keep, vy1);
                stc(&mut self.omega, bi, &keep, om1);
            }
        }

        // 3. velocity iterations: joints sequentially, then contacts.
        for _ in 0..ITERATIONS {
            for j in 0..nj {
                self.joint_velocity_pass::<W>(g, n, j, act);
            }
            self.contact_velocity_pass::<W>(g, n, act);
        }

        // 4. speed clamps + semi-implicit integration (all bodies, as
        // the AoS loop does — static bodies are no-ops by value).
        for b in 0..nb {
            let bi = b * lanes + g;
            let vx = ldc::<W>(&self.vel_x, bi, n);
            let vy = ldc::<W>(&self.vel_y, bi, n);
            let sp = (vx * vx + vy * vy).sqrt();
            let over = sp.gt(s(MAX_SPEED));
            let scale = s(MAX_SPEED) / sp;
            let vx1 = over.select_f32(vx * scale, vx);
            let vy1 = over.select_f32(vy * scale, vy);
            let om1 = ldc::<W>(&self.omega, bi, n).clamp(-MAX_OMEGA, MAX_OMEGA);
            let px1 = ldc::<W>(&self.pos_x, bi, n) + vx1 * s(dt);
            let py1 = ldc::<W>(&self.pos_y, bi, n) + vy1 * s(dt);
            let an1 = ldc::<W>(&self.angle, bi, n) + om1 * s(dt);
            stc(&mut self.vel_x, bi, act, vx1);
            stc(&mut self.vel_y, bi, act, vy1);
            stc(&mut self.omega, bi, act, om1);
            stc(&mut self.pos_x, bi, act, px1);
            stc(&mut self.pos_y, bi, act, py1);
            stc(&mut self.angle, bi, act, an1);
        }

        // 5. split position correction with the per-lane early exit:
        // each lane keeps iterating exactly until its own worst joint
        // error drops below 5e-4 (or the iteration budget runs out).
        let mut pc = *act;
        for _ in 0..POSITION_ITERATIONS {
            if !pc.any() {
                break;
            }
            let mut worst = zero;
            for j in 0..nj {
                worst = worst.max(self.joint_position_pass::<W>(g, n, j, &pc));
            }
            self.contact_position_pass::<W>(g, n, &pc);
            pc = pc & !worst.lt(s(5e-4));
        }
    }

    /// One velocity iteration of joint `j` over the group — the lane
    /// transcription of `RevoluteJoint::solve_velocity`.
    fn joint_velocity_pass<const W: usize>(&mut self, g: usize, n: usize, j: usize, act: &Mask<W>) {
        let lanes = self.lanes;
        let s = F32s::<W>::splat;
        let (a, b) = (self.j_a[j], self.j_b[j]);
        let ai = a * lanes + g;
        let bi = b * lanes + g;
        let ji = j * lanes + g;
        let (ma, ia_inv) = (self.inv_mass[a], self.inv_inertia[a]);
        let (mb, ib_inv) = (self.inv_mass[b], self.inv_inertia[b]);

        // angular limit first (touches only omega)
        if self.has_limit[j] {
            let inv_k = ia_inv + ib_inv; // lane-invariant
            if inv_k > 0.0 {
                let lower = Mask::<W>(std::array::from_fn(|i| {
                    i < n && self.jlimit_state[ji + i] == 1
                }));
                let upper = Mask::<W>(std::array::from_fn(|i| {
                    i < n && self.jlimit_state[ji + i] == 2
                }));
                let limited = (lower | upper) & *act;
                if limited.any() {
                    let oa = ldc::<W>(&self.omega, ai, n);
                    let ob = ldc::<W>(&self.omega, bi, n);
                    let rel = ob - oa - s(0.0); // limit_bias is always 0
                    let imp = -rel / s(inv_k);
                    let old = ldc::<W>(&self.jlimit_imp, ji, n);
                    let sum = old + imp;
                    let clamped =
                        lower.select_f32(sum.max(s(0.0)), sum.min(s(0.0)));
                    let dimp = clamped - old;
                    stc(&mut self.jlimit_imp, ji, &limited, clamped);
                    stc(&mut self.omega, ai, &limited, oa - s(ia_inv) * dimp);
                    stc(&mut self.omega, bi, &limited, ob + s(ib_inv) * dimp);
                }
            }
        }

        // point-to-point constraint
        let rax = ldc::<W>(&self.jr_ax, ji, n);
        let ray = ldc::<W>(&self.jr_ay, ji, n);
        let rbx = ldc::<W>(&self.jr_bx, ji, n);
        let rby = ldc::<W>(&self.jr_by, ji, n);
        let k11 = s(ma + mb) + s(ia_inv) * ray * ray + s(ib_inv) * rby * rby;
        let k12 = -(s(ia_inv) * rax) * ray - s(ib_inv) * rbx * rby;
        let k22 = s(ma + mb) + s(ia_inv) * rax * rax + s(ib_inv) * rbx * rbx;
        let vxa = ldc::<W>(&self.vel_x, ai, n);
        let vya = ldc::<W>(&self.vel_y, ai, n);
        let oa = ldc::<W>(&self.omega, ai, n);
        let vxb = ldc::<W>(&self.vel_x, bi, n);
        let vyb = ldc::<W>(&self.vel_y, bi, n);
        let ob = ldc::<W>(&self.omega, bi, n);
        // velocity_at(r) = vel + (−ω·r.y, ω·r.x)
        let vax = vxa + (-oa) * ray;
        let vay = vya + oa * rax;
        let vbx = vxb + (-ob) * rby;
        let vby = vyb + ob * rbx;
        let cdx = vbx - vax + s(0.0); // + bias (always zero, kept literal)
        let cdy = vby - vay + s(0.0);
        let (px, py) = solve22_w(k11, k12, k22, -cdx, -cdy);
        let acc_x = ldc::<W>(&self.jimp_x, ji, n) + px;
        let acc_y = ldc::<W>(&self.jimp_y, ji, n) + py;
        stc(&mut self.jimp_x, ji, act, acc_x);
        stc(&mut self.jimp_y, ji, act, acc_y);
        let (npx, npy) = (-px, -py);
        stc(&mut self.vel_x, ai, act, vxa + npx * s(ma));
        stc(&mut self.vel_y, ai, act, vya + npy * s(ma));
        stc(&mut self.omega, ai, act, oa + s(ia_inv) * (rax * npy - ray * npx));
        stc(&mut self.vel_x, bi, act, vxb + px * s(mb));
        stc(&mut self.vel_y, bi, act, vyb + py * s(mb));
        stc(&mut self.omega, bi, act, ob + s(ib_inv) * (rbx * py - rby * px));
    }

    /// One velocity iteration over every active contact slot of the
    /// group — the lane transcription of `contact::solve` (slot order
    /// is the AoS collect order: body-major, endpoint within body).
    fn contact_velocity_pass<const W: usize>(&mut self, g: usize, n: usize, act: &Mask<W>) {
        let lanes = self.lanes;
        let nb = self.nb;
        let s = F32s::<W>::splat;
        let zero = s(0.0);
        for b in 0..nb {
            if self.inv_mass[b] <= 0.0 {
                continue;
            }
            let bi = b * lanes + g;
            let (im, ii) = (s(self.inv_mass[b]), s(self.inv_inertia[b]));
            for e in 0..2 {
                let si = (b * 2 + e) * lanes + g;
                let on = Mask::<W>(std::array::from_fn(|i| i < n && self.c_active[si + i]))
                    & *act;
                if !on.any() {
                    continue;
                }
                let rx = ldc::<W>(&self.c_rx, si, n);
                let ry = ldc::<W>(&self.c_ry, si, n);
                // normal (y) impulse with accumulated clamp at 0
                let vx0 = ldc::<W>(&self.vel_x, bi, n);
                let vy0 = ldc::<W>(&self.vel_y, bi, n);
                let om0 = ldc::<W>(&self.omega, bi, n);
                let vn = vy0 + om0 * rx;
                let k_n = im + ii * rx * rx;
                let m1 = on & k_n.gt(zero);
                let d_jn = -(vn - zero) / k_n; // − bias (always zero)
                let old_n = ldc::<W>(&self.c_jn, si, n);
                let jn1 = (old_n + d_jn).max(zero);
                let applied = jn1 - old_n;
                stc(&mut self.c_jn, si, &m1, jn1);
                // apply_impulse((0, applied), r) — literal zero terms kept
                stc(&mut self.vel_x, bi, &m1, vx0 + zero * im);
                stc(&mut self.vel_y, bi, &m1, vy0 + applied * im);
                stc(&mut self.omega, bi, &m1, om0 + ii * (rx * applied - ry * zero));
                // tangent (x) friction clamped by μ·jn (reload: the
                // normal impulse just changed the body velocity)
                let vx2 = ldc::<W>(&self.vel_x, bi, n);
                let vy2 = ldc::<W>(&self.vel_y, bi, n);
                let om2 = ldc::<W>(&self.omega, bi, n);
                let vt = vx2 + (-om2) * ry;
                let k_t = im + ii * ry * ry;
                let m2 = on & k_t.gt(zero);
                let d_jt = -vt / k_t;
                let max_f = s(FRICTION) * ldc::<W>(&self.c_jn, si, n);
                let old_t = ldc::<W>(&self.c_jt, si, n);
                let jt1 = clamp_each(old_t + d_jt, -max_f, max_f);
                let applied_t = jt1 - old_t;
                stc(&mut self.c_jt, si, &m2, jt1);
                stc(&mut self.vel_x, bi, &m2, vx2 + applied_t * im);
                stc(&mut self.vel_y, bi, &m2, vy2 + zero * im);
                stc(&mut self.omega, bi, &m2, om2 + ii * (rx * zero - ry * applied_t));
            }
        }
    }

    /// One position iteration of joint `j`; returns the anchor error
    /// length per lane (0 where `pc` is clear) — the lane transcription
    /// of `RevoluteJoint::solve_position`.
    fn joint_position_pass<const W: usize>(
        &mut self,
        g: usize,
        n: usize,
        j: usize,
        pc: &Mask<W>,
    ) -> F32s<W> {
        let lanes = self.lanes;
        let s = F32s::<W>::splat;
        let zero = s(0.0);
        let (a, b) = (self.j_a[j], self.j_b[j]);
        let ai = a * lanes + g;
        let bi = b * lanes + g;
        let (ma, ia_inv) = (self.inv_mass[a], self.inv_inertia[a]);
        let (mb, ib_inv) = (self.inv_mass[b], self.inv_inertia[b]);

        // angular limit positional pushback
        if self.has_limit[j] {
            let inv_k = ia_inv + ib_inv;
            if inv_k > 0.0 {
                let ang_a = ldc::<W>(&self.angle, ai, n);
                let ang_b = ldc::<W>(&self.angle, bi, n);
                let ang = ang_b - ang_a - s(self.ref_angle[j]);
                let below = ang.lt(s(self.limit_lo[j]));
                let above = ang.gt(s(self.limit_hi[j])) & !below;
                let lo_viol = ang - s(self.limit_lo[j]);
                let hi_viol = above.select_f32(ang - s(self.limit_hi[j]), zero);
                let viol = below.select_f32(lo_viol, hi_viol);
                let nonzero = Mask::<W>(std::array::from_fn(|i| viol.0[i] != 0.0));
                let m = nonzero & *pc;
                if m.any() {
                    let corr = (s(-JOINT_BETA) * viol).clamp(-0.2, 0.2) / s(inv_k);
                    stc(&mut self.angle, ai, &m, ang_a - s(ia_inv) * corr);
                    stc(&mut self.angle, bi, &m, ang_b + s(ib_inv) * corr);
                }
            }
        }

        // point-to-point positional correction (fresh anchors from the
        // possibly-just-corrected angles)
        let ang_a = ldc::<W>(&self.angle, ai, n);
        let ang_b = ldc::<W>(&self.angle, bi, n);
        let (sa, ca) = sin_cos_w(ang_a);
        let (sb, cb) = sin_cos_w(ang_b);
        let (lax, lay) = (s(self.anchor_ax[j]), s(self.anchor_ay[j]));
        let (lbx, lby) = (s(self.anchor_bx[j]), s(self.anchor_by[j]));
        let rax = ca * lax - sa * lay;
        let ray = sa * lax + ca * lay;
        let rbx = cb * lbx - sb * lby;
        let rby = sb * lbx + cb * lby;
        let pax = ldc::<W>(&self.pos_x, ai, n);
        let pay = ldc::<W>(&self.pos_y, ai, n);
        let pbx = ldc::<W>(&self.pos_x, bi, n);
        let pby = ldc::<W>(&self.pos_y, bi, n);
        let err_x = (pbx + rbx) - (pax + rax);
        let err_y = (pby + rby) - (pay + ray);
        let elen = (err_x * err_x + err_y * err_y).sqrt();
        let m = elen.gt(s(1e-6)) & *pc;
        if m.any() {
            let k11 = s(ma + mb) + s(ia_inv) * ray * ray + s(ib_inv) * rby * rby;
            let k12 = -(s(ia_inv) * rax) * ray - s(ib_inv) * rbx * rby;
            let k22 = s(ma + mb) + s(ia_inv) * rax * rax + s(ib_inv) * rbx * rbx;
            let mut cx = err_x * s(JOINT_BETA);
            let mut cy = err_y * s(JOINT_BETA);
            let clen = (cx * cx + cy * cy).sqrt();
            let over = clen.gt(s(0.2));
            let cscale = s(0.2) / clen;
            cx = over.select_f32(cx * cscale, cx);
            cy = over.select_f32(cy * cscale, cy);
            let (px, py) = solve22_w(k11, k12, k22, -cx, -cy);
            stc(&mut self.pos_x, ai, &m, pax + px * s(-ma));
            stc(&mut self.pos_y, ai, &m, pay + py * s(-ma));
            stc(&mut self.angle, ai, &m, ang_a - s(ia_inv) * (rax * py - ray * px));
            stc(&mut self.pos_x, bi, &m, pbx + px * s(mb));
            stc(&mut self.pos_y, bi, &m, pby + py * s(mb));
            stc(&mut self.angle, bi, &m, ang_b + s(ib_inv) * (rbx * py - rby * px));
        }
        pc.select_f32(elen, zero)
    }

    /// One positional push-out iteration over penetrating endpoints —
    /// the lane transcription of `contact::correct_positions` (both
    /// endpoints measured from the pre-iteration body snapshot, updates
    /// applied incrementally, as the AoS code does).
    fn contact_position_pass<const W: usize>(&mut self, g: usize, n: usize, pc: &Mask<W>) {
        let lanes = self.lanes;
        let nb = self.nb;
        let s = F32s::<W>::splat;
        let zero = s(0.0);
        for b in 0..nb {
            if self.inv_mass[b] <= 0.0 {
                continue;
            }
            let bi = b * lanes + g;
            let (im, ii) = (s(self.inv_mass[b]), s(self.inv_inertia[b]));
            // snapshot for both endpoints (the AoS loop captures
            // endpoints/pos once per body, before its two corrections)
            let ang0 = ldc::<W>(&self.angle, bi, n);
            let (sn, cs) = sin_cos_w(ang0);
            let px0 = ldc::<W>(&self.pos_x, bi, n);
            let py0 = ldc::<W>(&self.pos_y, bi, n);
            for e in 0..2 {
                let lx = s(if e == 0 { -self.half_len[b] } else { self.half_len[b] });
                let ex = px0 + (cs * lx - sn * zero);
                let ey = py0 + (sn * lx + cs * zero);
                let depth = s(self.radius[b]) - ey;
                let m0 = depth.gt(s(SLOP)) & *pc;
                if !m0.any() {
                    continue;
                }
                let rx = ex - px0;
                let ry = zero - py0;
                let k_n = im + ii * rx * rx;
                let m = m0 & k_n.gt(zero);
                let mag = (s(BETA) * (depth - s(SLOP))).min(s(0.2)) / k_n;
                let py_cur = ldc::<W>(&self.pos_y, bi, n);
                let an_cur = ldc::<W>(&self.angle, bi, n);
                stc(&mut self.pos_y, bi, &m, py_cur + mag * im);
                stc(&mut self.angle, bi, &m, an_cur + ii * (rx * mag - ry * zero));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::mujoco::models;
    use crate::envs::mujoco::DT;

    /// Step an AoS `World` and a width-1 `WorldBatch` lane in lock-step
    /// and demand **bitwise** body-state equality every substep — the
    /// in-crate half of the refactor's parity pin (the integration half
    /// lives in `tests/mujoco_batch_parity.rs`). With one lane the
    /// body-major index degenerates to `body`, so plain `[b]` reads are
    /// still valid here.
    fn check_width1_vs_world(model: crate::envs::mujoco::models::Model, steps: usize, seed: u64) {
        let mut world = model.world.clone();
        let mut batch = WorldBatch::from_world(&model.world, 1);
        let adim = world.actuated().len();
        let mut rng = Pcg32::new(seed, 17);
        let skip = [0u8];
        for t in 0..steps {
            let ctrl: Vec<f32> = (0..adim).map(|_| rng.range(-1.0, 1.0)).collect();
            world.step(DT, &ctrl);
            batch.step(DT, &ctrl, adim, &skip, 1);
            for (b, body) in world.bodies.iter().enumerate() {
                assert_eq!(body.pos.x.to_bits(), batch.pos_x[b].to_bits(), "t={t} b={b} pos.x");
                assert_eq!(body.pos.y.to_bits(), batch.pos_y[b].to_bits(), "t={t} b={b} pos.y");
                assert_eq!(body.angle.to_bits(), batch.angle[b].to_bits(), "t={t} b={b} angle");
                assert_eq!(body.vel.x.to_bits(), batch.vel_x[b].to_bits(), "t={t} b={b} vel.x");
                assert_eq!(body.vel.y.to_bits(), batch.vel_y[b].to_bits(), "t={t} b={b} vel.y");
                assert_eq!(body.omega.to_bits(), batch.omega[b].to_bits(), "t={t} b={b} omega");
            }
        }
    }

    #[test]
    fn width1_hopper_bitwise_matches_world_step() {
        check_width1_vs_world(models::hopper(), 400, 11);
    }

    #[test]
    fn width1_cheetah_bitwise_matches_world_step() {
        check_width1_vs_world(models::half_cheetah(), 250, 12);
    }

    #[test]
    fn width1_ant_bitwise_matches_world_step() {
        check_width1_vs_world(models::ant(), 250, 13);
    }

    #[test]
    fn masked_lanes_are_untouched() {
        let m = models::hopper();
        let mut batch = WorldBatch::from_world(&m.world, 3);
        let adim = m.world.actuated().len();
        // capture lane 1's state, step with lane 1 masked
        let nb = batch.num_bodies();
        let before: Vec<f32> = (0..nb).map(|b| batch.pos_y[batch.body_index(1, b)]).collect();
        let ctrl = vec![0.3f32; 3 * adim];
        batch.step(DT, &ctrl, adim, &[0, 1, 0], 4);
        for b in 0..nb {
            assert_eq!(
                before[b].to_bits(),
                batch.pos_y[batch.body_index(1, b)].to_bits(),
                "masked lane moved"
            );
        }
        // unmasked lanes did move (gravity acted)
        assert!(
            batch.vel_y[batch.body_index(0, 0)] < 0.0
                || batch.pos_y[batch.body_index(0, m.torso)] != batch.init_pos_y[m.torso]
        );
    }

    #[test]
    fn lane_groups_handle_tails_and_stay_finite() {
        for lanes in [1usize, 3, 5, 9] {
            for width in [1usize, 4, 8] {
                let m = models::half_cheetah();
                let mut batch = WorldBatch::from_world(&m.world, lanes);
                let adim = m.world.actuated().len();
                let skip = vec![0u8; lanes];
                let mut rng = Pcg32::new(7, lanes as u64);
                for _ in 0..50 {
                    let ctrl: Vec<f32> =
                        (0..lanes * adim).map(|_| rng.range(-1.0, 1.0)).collect();
                    batch.step(DT, &ctrl, adim, &skip, width);
                }
                for l in 0..lanes {
                    assert!(!batch.lane_is_bad(l), "lanes={lanes} width={width} lane {l}");
                }
            }
        }
    }

    #[test]
    fn wide_lanes_track_width1_within_budget_over_short_horizon() {
        // Widths 4/8 use the trig twins instead of libm, so they drift
        // from width 1 — within the documented budget over a short
        // horizon (the full suite lives in tests/mujoco_batch_parity.rs).
        let m = models::hopper();
        let adim = m.world.actuated().len();
        for width in [4usize, 8] {
            let mut a = WorldBatch::from_world(&m.world, 2);
            let mut b = WorldBatch::from_world(&m.world, 2);
            let skip = [0u8; 2];
            let mut rng = Pcg32::new(3, 9);
            for t in 0..30 {
                let ctrl: Vec<f32> = (0..2 * adim).map(|_| rng.range(-0.5, 0.5)).collect();
                a.step(DT, &ctrl, adim, &skip, 1);
                b.step(DT, &ctrl, adim, &skip, width);
                for i in 0..a.pos_y.len() {
                    let (x, y) = (a.pos_y[i], b.pos_y[i]);
                    assert!(
                        (x - y).abs() <= LANE_TOL_ABS + LANE_TOL_REL * x.abs(),
                        "width {width} t={t}: pos_y[{i}] {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn reset_lane_restores_template_and_clears_solver_state() {
        let m = models::ant();
        let mut batch = WorldBatch::from_world(&m.world, 2);
        let adim = m.world.actuated().len();
        let skip = [0u8; 2];
        let ctrl = vec![1.0f32; 2 * adim];
        for _ in 0..40 {
            batch.step(DT, &ctrl, adim, &skip, 1);
        }
        assert!(batch.pos_x[batch.body_index(0, m.torso)] != batch.init_pos_x[m.torso]);
        batch.reset_lane(0);
        let nb = batch.num_bodies();
        for b in 0..nb {
            assert_eq!(batch.pos_x[batch.body_index(0, b)], batch.init_pos_x[b]);
            assert_eq!(batch.vel_x[batch.body_index(0, b)], batch.init_vel_x[b]);
        }
        // lane 1 untouched by lane 0's reset
        assert!(batch.pos_x[batch.body_index(1, m.torso)] != batch.init_pos_x[m.torso]);
        // solver caches cleared — lane 0's slots stride by `lanes` under
        // the body-major layout
        assert!((0..nb * 2).all(|slot| !batch.c_active[slot * 2]));
        assert!((0..batch.nj).all(|j| batch.jimp_x[j * 2] == 0.0));
        assert!(batch.kinetic_energy(0).is_finite());
        assert!(batch.max_penetration(0) <= SLOP + 1e-6);
    }

    #[test]
    fn body_major_template_replication_roundtrip() {
        // from_world must interleave the template body-major: every
        // body's value occupies a contiguous run of `lanes` slots, and
        // body_index(lane, body) addresses it.
        let m = models::hopper();
        let lanes = 5;
        let batch = WorldBatch::from_world(&m.world, lanes);
        let nb = batch.num_bodies();
        assert_eq!(batch.pos_x.len(), nb * lanes);
        for b in 0..nb {
            for l in 0..lanes {
                let i = batch.body_index(l, b);
                assert_eq!(i, b * lanes + l, "body-major index shape");
                assert_eq!(batch.pos_x[i].to_bits(), batch.init_pos_x[b].to_bits());
                assert_eq!(batch.pos_y[i].to_bits(), batch.init_pos_y[b].to_bits());
                assert_eq!(batch.angle[i].to_bits(), batch.init_angle[b].to_bits());
            }
        }
    }

    #[test]
    fn reset_and_noise_touch_only_their_lane() {
        // Strided reset/noise under the body-major layout must leave
        // every other lane bitwise untouched — including solver caches.
        let m = models::half_cheetah();
        let lanes = 3;
        let mut batch = WorldBatch::from_world(&m.world, lanes);
        let adim = m.world.actuated().len();
        let skip = vec![0u8; lanes];
        let ctrl = vec![0.7f32; lanes * adim];
        for _ in 0..25 {
            batch.step(DT, &ctrl, adim, &skip, 4);
        }
        let snap = batch.clone();
        let mut rng = Pcg32::new(5, 2);
        batch.reset_lane(1);
        batch.apply_reset_noise(1, &mut rng);
        let nb = batch.num_bodies();
        for l in [0usize, 2] {
            for b in 0..nb {
                let i = batch.body_index(l, b);
                assert_eq!(snap.pos_x[i].to_bits(), batch.pos_x[i].to_bits(), "l={l} b={b}");
                assert_eq!(snap.vel_y[i].to_bits(), batch.vel_y[i].to_bits(), "l={l} b={b}");
                assert_eq!(snap.omega[i].to_bits(), batch.omega[i].to_bits(), "l={l} b={b}");
            }
            for j in 0..batch.nj {
                let i = j * lanes + l;
                assert_eq!(snap.jimp_x[i].to_bits(), batch.jimp_x[i].to_bits(), "l={l} j={j}");
                assert_eq!(snap.jlimit_state[i], batch.jlimit_state[i], "l={l} j={j}");
            }
            for slot in 0..nb * 2 {
                let i = slot * lanes + l;
                assert_eq!(snap.c_active[i], batch.c_active[i], "l={l} slot={slot}");
                assert_eq!(snap.c_jn[i].to_bits(), batch.c_jn[i].to_bits(), "l={l} slot={slot}");
            }
        }
        // lane 1 really was reset (solver caches cleared)
        for j in 0..batch.nj {
            assert_eq!(batch.jimp_x[j * lanes + 1], 0.0);
        }
        for slot in 0..nb * 2 {
            assert!(!batch.c_active[slot * lanes + 1]);
        }
    }
}
