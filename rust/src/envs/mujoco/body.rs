//! Rigid bodies: capsule links with mass, rotational inertia, and pose.

use super::math::{v2, Vec2};

/// A rigid capsule link. The capsule axis runs along the body's local
/// x-axis from `-half_len` to `+half_len`; `radius` pads the endpoints
/// for ground contact.
#[derive(Debug, Clone)]
pub struct Body {
    /// World position of the center of mass.
    pub pos: Vec2,
    /// Orientation (radians).
    pub angle: f32,
    /// Linear velocity.
    pub vel: Vec2,
    /// Angular velocity.
    pub omega: f32,
    /// Inverse mass (0 = static).
    pub inv_mass: f32,
    /// Inverse rotational inertia (0 = static).
    pub inv_inertia: f32,
    /// Capsule half-length along local x.
    pub half_len: f32,
    /// Capsule radius.
    pub radius: f32,
}

impl Body {
    /// A dynamic capsule of given mass, half-length and radius. Inertia is
    /// the thin-rod formula `m L² / 12` with `L = 2·half_len` (plus a
    /// small floor so point-like links stay well-conditioned).
    pub fn capsule(mass: f32, half_len: f32, radius: f32) -> Body {
        let l = 2.0 * half_len;
        let inertia = (mass * l * l / 12.0).max(mass * radius * radius * 0.5).max(1e-4);
        Body {
            pos: Vec2::ZERO,
            angle: 0.0,
            vel: Vec2::ZERO,
            omega: 0.0,
            inv_mass: 1.0 / mass,
            inv_inertia: 1.0 / inertia,
            half_len,
            radius,
        }
    }

    /// Transform a local point to world space.
    #[inline]
    pub fn world_point(&self, local: Vec2) -> Vec2 {
        self.pos + local.rotate(self.angle)
    }

    /// World-space velocity of a point given by world offset `r` from COM.
    #[inline]
    pub fn velocity_at(&self, r: Vec2) -> Vec2 {
        self.vel + Vec2::cross_scalar(self.omega, r)
    }

    /// Apply an impulse `p` at world offset `r` from the COM.
    #[inline]
    pub fn apply_impulse(&mut self, p: Vec2, r: Vec2) {
        self.vel += p * self.inv_mass;
        self.omega += self.inv_inertia * r.cross(p);
    }

    /// The two capsule endpoints in world space (contact candidates).
    pub fn endpoints(&self) -> [Vec2; 2] {
        [self.world_point(v2(-self.half_len, 0.0)), self.world_point(v2(self.half_len, 0.0))]
    }

    /// Kinetic energy (for stability tests).
    pub fn kinetic_energy(&self) -> f32 {
        let m = if self.inv_mass > 0.0 { 1.0 / self.inv_mass } else { 0.0 };
        let i = if self.inv_inertia > 0.0 { 1.0 / self.inv_inertia } else { 0.0 };
        0.5 * m * self.vel.dot(self.vel) + 0.5 * i * self.omega * self.omega
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capsule_inertia_positive() {
        let b = Body::capsule(2.0, 0.5, 0.05);
        assert!(b.inv_mass > 0.0 && b.inv_inertia > 0.0);
    }

    #[test]
    fn world_point_rotates() {
        let mut b = Body::capsule(1.0, 1.0, 0.1);
        b.pos = v2(5.0, 5.0);
        b.angle = std::f32::consts::FRAC_PI_2;
        let p = b.world_point(v2(1.0, 0.0));
        assert!((p.x - 5.0).abs() < 1e-5 && (p.y - 6.0).abs() < 1e-5);
    }

    #[test]
    fn impulse_changes_momentum() {
        let mut b = Body::capsule(2.0, 0.5, 0.05);
        b.apply_impulse(v2(4.0, 0.0), v2(0.0, 0.5));
        assert!((b.vel.x - 2.0).abs() < 1e-6); // p/m
        assert!(b.omega < 0.0); // r × p = (0,0.5)×(4,0) = -2
    }

    #[test]
    fn endpoints_at_rest() {
        let b = Body::capsule(1.0, 0.3, 0.05);
        let [a, c] = b.endpoints();
        assert!((a.x + 0.3).abs() < 1e-6);
        assert!((c.x - 0.3).abs() < 1e-6);
    }
}
