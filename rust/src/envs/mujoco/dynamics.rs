//! The physics world: gravity, motors, joints, contacts, integration.
//!
//! Substep order (sequential impulses):
//! 1. integrate external forces (gravity, motor torques) into velocities;
//! 2. prepare constraints (anchors, Baumgarte biases, limit states);
//! 3. iterate velocity constraints (joints + contacts);
//! 4. integrate positions from the corrected velocities.
//!
//! Since the batch-resident refactor, [`World`] plays two roles: the
//! model *description* [`super::models`] assembles (bodies + joints +
//! task constants), and the AoS **reference stepper** — [`World::step`]
//! is kept verbatim as the pre-batch solver so the SoA
//! [`super::batch::WorldBatch`] width-1 path can be pinned against it
//! **bitwise** (unit tests in `batch.rs`, seeded trajectory pins in
//! `tests/mujoco_batch_parity.rs`). Production env stepping goes
//! through `WorldBatch`; change solver behavior there and here in
//! lock-step or the pins will fail.

use super::body::Body;
use super::contact::{self, Contact};
use super::joint::RevoluteJoint;

/// Gravity (m/s², downward).
pub const GRAVITY: f32 = 9.81;
/// Velocity-constraint iterations per substep.
pub const ITERATIONS: usize = 12;
/// Position-correction iterations per substep.
pub const POSITION_ITERATIONS: usize = 6;
/// Baumgarte factor for joint position drift.
pub const JOINT_BETA: f32 = 0.2;
/// Linear/angular velocity damping rate (per second — joint friction /
/// air drag stand-in).
pub const DAMPING: f32 = 0.2;
/// Hard velocity caps: a cheap, deterministic guard against solver
/// blow-ups under adversarial torque sequences (MuJoCo bounds energy via
/// implicit damping; we bound it explicitly).
pub const MAX_SPEED: f32 = 40.0;
/// Angular velocity cap (rad/s).
pub const MAX_OMEGA: f32 = 60.0;

/// An articulated rigid-body world over a flat ground plane.
#[derive(Debug, Clone, Default)]
pub struct World {
    pub bodies: Vec<Body>,
    pub joints: Vec<RevoluteJoint>,
    contacts: Vec<Contact>,
    prev_contacts: Vec<Contact>,
}

impl World {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a body, returning its index.
    pub fn add_body(&mut self, b: Body) -> usize {
        self.bodies.push(b);
        self.bodies.len() - 1
    }

    /// Add a joint, returning its index.
    pub fn add_joint(&mut self, j: RevoluteJoint) -> usize {
        self.joints.push(j);
        self.joints.len() - 1
    }

    /// Indices of actuated joints (gear > 0), in declaration order —
    /// this is the action vector layout.
    pub fn actuated(&self) -> Vec<usize> {
        (0..self.joints.len()).filter(|&i| self.joints[i].gear > 0.0).collect()
    }

    /// Advance one substep of `dt` seconds with `ctrl` applied to the
    /// actuated joints (in [`World::actuated`] order, values in [-1, 1]).
    pub fn step(&mut self, dt: f32, ctrl: &[f32]) {
        let inv_dt = 1.0 / dt;

        // 1. external forces
        let damp = 1.0 - DAMPING * dt;
        for b in &mut self.bodies {
            if b.inv_mass > 0.0 {
                b.vel.y -= GRAVITY * dt;
                // light damping keeps long chains from ringing
                b.vel = b.vel * damp;
                b.omega *= damp;
            }
        }
        let mut ci = 0;
        for j in &self.joints {
            if j.gear > 0.0 {
                let tau = ctrl.get(ci).copied().unwrap_or(0.0).clamp(-1.0, 1.0) * j.gear;
                ci += 1;
                let (a, b) = (j.body_a, j.body_b);
                self.bodies[a].omega -= self.bodies[a].inv_inertia * tau * dt;
                self.bodies[b].omega += self.bodies[b].inv_inertia * tau * dt;
            }
        }

        // 2. prepare constraints (+ warm start from last substep)
        for j in &mut self.joints {
            j.prepare(&mut self.bodies, inv_dt, JOINT_BETA);
        }
        std::mem::swap(&mut self.contacts, &mut self.prev_contacts);
        contact::collect(&mut self.bodies, inv_dt, &mut self.contacts, &self.prev_contacts);

        // 3. velocity iterations
        for _ in 0..ITERATIONS {
            for j in &mut self.joints {
                j.solve_velocity(&mut self.bodies);
            }
            contact::solve(&mut self.bodies, &mut self.contacts);
        }

        // 4. clamp + integrate positions
        for b in &mut self.bodies {
            let sp = b.vel.len();
            if sp > MAX_SPEED {
                b.vel = b.vel * (MAX_SPEED / sp);
            }
            b.omega = b.omega.clamp(-MAX_OMEGA, MAX_OMEGA);
            b.pos += b.vel * dt;
            b.angle += b.omega * dt;
        }

        // 5. split position correction (nonlinear Gauss-Seidel): removes
        // joint drift, limit violation and ground penetration without
        // touching momenta.
        for _ in 0..POSITION_ITERATIONS {
            let mut worst = 0.0f32;
            for j in &self.joints {
                worst = worst.max(j.solve_position(&mut self.bodies, JOINT_BETA));
            }
            contact::correct_positions(&mut self.bodies);
            if worst < 5e-4 {
                break;
            }
        }
    }

    /// Total kinetic energy (stability probes in tests).
    pub fn kinetic_energy(&self) -> f32 {
        self.bodies.iter().map(|b| b.kinetic_energy()).sum()
    }

    /// Any non-finite state anywhere?
    pub fn is_bad(&self) -> bool {
        self.bodies.iter().any(|b| {
            b.pos.is_bad() || b.vel.is_bad() || !b.angle.is_finite() || !b.omega.is_finite()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::mujoco::math::v2;

    #[test]
    fn free_fall_matches_gravity() {
        let mut w = World::new();
        let mut b = Body::capsule(1.0, 0.2, 0.05);
        b.pos = v2(0.0, 10.0);
        w.add_body(b);
        let dt = 0.01;
        for _ in 0..100 {
            w.step(dt, &[]);
        }
        // ~1s of fall: v ≈ g·t (damping makes it slightly less), y ≈ 10 - g t²/2
        let v = w.bodies[0].vel.y;
        assert!(v < -7.5 && v > -10.5, "fall speed {v}");
        assert!(w.bodies[0].pos.y < 6.5);
    }

    #[test]
    fn resting_on_ground_is_stable() {
        let mut w = World::new();
        let mut b = Body::capsule(1.0, 0.5, 0.05);
        b.pos = v2(0.0, 0.05);
        w.add_body(b);
        for _ in 0..500 {
            w.step(0.01, &[]);
        }
        assert!(!w.is_bad());
        let y = w.bodies[0].pos.y;
        assert!(y > 0.0 && y < 0.12, "should rest near radius height, y={y}");
        assert!(w.kinetic_energy() < 0.05, "ke={}", w.kinetic_energy());
    }

    #[test]
    fn pendulum_swings_and_conserves_roughly() {
        // static anchor body + swinging rod
        let mut w = World::new();
        let mut anchor = Body::capsule(1.0, 0.05, 0.01);
        anchor.inv_mass = 0.0;
        anchor.inv_inertia = 0.0;
        anchor.pos = v2(0.0, 2.0);
        let a = w.add_body(anchor);
        let mut rod = Body::capsule(1.0, 0.5, 0.02);
        rod.pos = v2(0.5, 2.0); // horizontal, hinged at (0,2)
        let r = w.add_body(rod);
        w.add_joint(RevoluteJoint::new(a, r, v2(0.0, 0.0), v2(-0.5, 0.0)));
        let mut min_y = f32::INFINITY;
        for _ in 0..200 {
            w.step(0.005, &[]);
            min_y = min_y.min(w.bodies[r].pos.y);
            // hinge must not drift: rod anchor stays near (0,2)
            let anchor_pt = w.bodies[r].world_point(v2(-0.5, 0.0));
            assert!((anchor_pt - v2(0.0, 2.0)).len() < 0.12, "hinge drift {anchor_pt:?}");
        }
        assert!(min_y < 1.7, "rod should swing down, min_y={min_y}");
        assert!(!w.is_bad());
    }

    #[test]
    fn motor_torque_spins_joint() {
        let mut w = World::new();
        let mut anchor = Body::capsule(1.0, 0.05, 0.01);
        anchor.inv_mass = 0.0;
        anchor.inv_inertia = 0.0;
        anchor.pos = v2(0.0, 5.0);
        let a = w.add_body(anchor);
        let mut rod = Body::capsule(0.5, 0.3, 0.02);
        rod.pos = v2(0.3, 5.0);
        let r = w.add_body(rod);
        w.add_joint(RevoluteJoint::new(a, r, v2(0.0, 0.0), v2(-0.3, 0.0)).with_gear(5.0));
        for _ in 0..50 {
            w.step(0.01, &[1.0]);
        }
        assert!(w.bodies[r].omega > 0.5, "motor should spin the rod, omega={}", w.bodies[r].omega);
    }

    #[test]
    fn random_torques_never_nan() {
        use crate::rng::Pcg32;
        let mut w = World::new();
        // small chain: 3 links
        let mut prev = {
            let mut b = Body::capsule(2.0, 0.3, 0.05);
            b.pos = v2(0.0, 1.0);
            w.add_body(b)
        };
        for i in 1..3 {
            let mut b = Body::capsule(1.0, 0.3, 0.05);
            b.pos = v2(0.6 * i as f32, 1.0);
            let idx = w.add_body(b);
            w.add_joint(
                RevoluteJoint::new(prev, idx, v2(0.3, 0.0), v2(-0.3, 0.0))
                    .with_limit(-1.0, 1.0)
                    .with_gear(10.0),
            );
            prev = idx;
        }
        let mut rng = Pcg32::new(99, 0);
        for _ in 0..2000 {
            let ctrl = [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)];
            w.step(0.01, &ctrl);
            assert!(!w.is_bad(), "physics exploded");
        }
    }
}
