//! 2-D vector math for the physics engine.

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// A 2-D vector.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec2 {
    pub x: f32,
    pub y: f32,
}

/// Construct a [`Vec2`].
#[inline]
pub const fn v2(x: f32, y: f32) -> Vec2 {
    Vec2 { x, y }
}

impl Vec2 {
    pub const ZERO: Vec2 = v2(0.0, 0.0);

    #[inline]
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    /// 2-D cross product (scalar z-component).
    #[inline]
    pub fn cross(self, o: Vec2) -> f32 {
        self.x * o.y - self.y * o.x
    }

    /// Cross of a scalar angular velocity with a vector: `w × r`.
    #[inline]
    pub fn cross_scalar(w: f32, r: Vec2) -> Vec2 {
        v2(-w * r.y, w * r.x)
    }

    #[inline]
    pub fn len(self) -> f32 {
        self.dot(self).sqrt()
    }

    /// Rotate by angle `a` (radians).
    #[inline]
    pub fn rotate(self, a: f32) -> Vec2 {
        let (s, c) = a.sin_cos();
        v2(c * self.x - s * self.y, s * self.x + c * self.y)
    }

    /// Perpendicular (rotate +90°).
    #[inline]
    pub fn perp(self) -> Vec2 {
        v2(-self.y, self.x)
    }

    /// Any component non-finite?
    #[inline]
    pub fn is_bad(self) -> bool {
        !self.x.is_finite() || !self.y.is_finite()
    }
}

impl Add for Vec2 {
    type Output = Vec2;
    #[inline]
    fn add(self, o: Vec2) -> Vec2 {
        v2(self.x + o.x, self.y + o.y)
    }
}

impl AddAssign for Vec2 {
    #[inline]
    fn add_assign(&mut self, o: Vec2) {
        self.x += o.x;
        self.y += o.y;
    }
}

impl Sub for Vec2 {
    type Output = Vec2;
    #[inline]
    fn sub(self, o: Vec2) -> Vec2 {
        v2(self.x - o.x, self.y - o.y)
    }
}

impl Mul<f32> for Vec2 {
    type Output = Vec2;
    #[inline]
    fn mul(self, s: f32) -> Vec2 {
        v2(self.x * s, self.y * s)
    }
}

impl Neg for Vec2 {
    type Output = Vec2;
    #[inline]
    fn neg(self) -> Vec2 {
        v2(-self.x, -self.y)
    }
}

/// Symmetric 2×2 matrix solve for the joint effective-mass system.
/// Solves `K x = b` where `K = [[k11, k12], [k12, k22]]`.
#[inline]
pub fn solve22(k11: f32, k12: f32, k22: f32, b: Vec2) -> Vec2 {
    let det = k11 * k22 - k12 * k12;
    if det.abs() < 1e-12 {
        return Vec2::ZERO;
    }
    let inv = 1.0 / det;
    v2(inv * (k22 * b.x - k12 * b.y), inv * (k11 * b.y - k12 * b.x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotate_quarter_turn() {
        let r = v2(1.0, 0.0).rotate(std::f32::consts::FRAC_PI_2);
        assert!((r.x).abs() < 1e-6 && (r.y - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cross_identities() {
        let a = v2(3.0, 4.0);
        assert_eq!(a.cross(a), 0.0);
        let w = 2.0;
        let r = v2(1.0, 0.0);
        let wr = Vec2::cross_scalar(w, r);
        assert_eq!(wr, v2(0.0, 2.0));
    }

    #[test]
    fn solve22_recovers_solution() {
        // K = [[4,1],[1,3]], x = (1,2) => b = (6,7)
        let x = solve22(4.0, 1.0, 3.0, v2(6.0, 7.0));
        assert!((x.x - 1.0).abs() < 1e-5);
        assert!((x.y - 2.0).abs() < 1e-5);
    }

    #[test]
    fn solve22_singular_returns_zero() {
        assert_eq!(solve22(1.0, 1.0, 1.0, v2(1.0, 1.0)), Vec2::ZERO);
    }
}
