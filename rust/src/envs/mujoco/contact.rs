//! Ground contact: each capsule endpoint is a contact candidate against
//! the half-plane `y = 0`, resolved with non-penetration + Coulomb
//! friction impulses (sequential impulses, Baumgarte position bias).

use super::body::Body;
use super::math::{v2, Vec2};

/// Friction coefficient for the ground plane.
pub const FRICTION: f32 = 1.0;
/// Baumgarte factor for penetration correction.
pub const BETA: f32 = 0.2;
/// Penetration slop tolerated without correction.
pub const SLOP: f32 = 0.005;

/// One active contact point for the current substep.
#[derive(Debug, Clone)]
pub struct Contact {
    pub body: usize,
    /// Which capsule endpoint (0/1) — the warm-start matching key.
    pub point: usize,
    /// Offset from the body COM to the contact point (world frame).
    pub r: Vec2,
    /// Penetration depth (>= 0).
    pub depth: f32,
    /// Accumulated normal impulse.
    pub jn: f32,
    /// Accumulated tangent impulse.
    pub jt: f32,
    /// Velocity bias from Baumgarte.
    pub bias: f32,
}

/// Collect ground contacts over all bodies' capsule endpoints. `prev` is
/// last substep's contact set: persisting contacts inherit their
/// accumulated impulses, which are immediately re-applied (warm start).
pub fn collect(bodies: &mut [Body], inv_dt: f32, out: &mut Vec<Contact>, prev: &[Contact]) {
    out.clear();
    for i in 0..bodies.len() {
        if bodies[i].inv_mass == 0.0 {
            continue;
        }
        let (endpoints, radius, pos) = {
            let b = &bodies[i];
            (b.endpoints(), b.radius, b.pos)
        };
        for (k, p) in endpoints.into_iter().enumerate() {
            let lowest = p.y - radius;
            if lowest < 0.0 {
                let depth = -lowest;
                let contact_point = v2(p.x, 0.0);
                let mut c = Contact {
                    body: i,
                    point: k,
                    r: contact_point - pos,
                    depth,
                    jn: 0.0,
                    jt: 0.0,
                    // No Baumgarte velocity bias: penetration is fixed by
                    // the positional pass (`correct_positions`), which
                    // cannot inject kinetic energy.
                    bias: 0.0,
                };
                let _ = inv_dt;
                if let Some(old) = prev.iter().find(|o| o.body == i && o.point == k) {
                    c.jn = old.jn;
                    c.jt = old.jt;
                    bodies[i].apply_impulse(v2(c.jt, c.jn), c.r);
                }
                out.push(c);
            }
        }
    }
}

/// One velocity iteration over all contacts.
pub fn solve(bodies: &mut [Body], contacts: &mut [Contact]) {
    for c in contacts.iter_mut() {
        let b = &mut bodies[c.body];
        // normal (y) impulse with restitution-free non-penetration
        let vn = b.velocity_at(c.r).y;
        let k_n = b.inv_mass + b.inv_inertia * c.r.x * c.r.x;
        if k_n > 0.0 {
            let d_jn = -(vn - c.bias) / k_n;
            let old = c.jn;
            c.jn = (old + d_jn).max(0.0);
            let applied = c.jn - old;
            b.apply_impulse(v2(0.0, applied), c.r);
        }
        // tangent (x) friction impulse clamped by μ·jn
        let vt = b.velocity_at(c.r).x;
        let k_t = b.inv_mass + b.inv_inertia * c.r.y * c.r.y;
        if k_t > 0.0 {
            let d_jt = -vt / k_t;
            let max_f = FRICTION * c.jn;
            let old = c.jt;
            c.jt = (old + d_jt).clamp(-max_f, max_f);
            let applied = c.jt - old;
            b.apply_impulse(v2(applied, 0.0), c.r);
        }
    }
}

/// One positional iteration: push penetrating endpoints out of the
/// ground by moving positions/angles directly (pseudo-impulses).
pub fn correct_positions(bodies: &mut [Body]) {
    for i in 0..bodies.len() {
        if bodies[i].inv_mass == 0.0 {
            continue;
        }
        let (endpoints, radius, pos) = {
            let b = &bodies[i];
            (b.endpoints(), b.radius, b.pos)
        };
        for p in endpoints {
            let depth = radius - p.y;
            if depth > SLOP {
                let r = v2(p.x, 0.0) - pos;
                let b = &mut bodies[i];
                let k_n = b.inv_mass + b.inv_inertia * r.x * r.x;
                if k_n > 0.0 {
                    let mag = (BETA * (depth - SLOP)).min(0.2) / k_n;
                    b.pos.y += mag * b.inv_mass;
                    b.angle += b.inv_inertia * r.cross(v2(0.0, mag));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_contact_above_ground() {
        let mut b = Body::capsule(1.0, 0.5, 0.05);
        b.pos = v2(0.0, 1.0);
        let mut cs = vec![];
        collect(&mut [b], 100.0, &mut cs, &[]);
        assert!(cs.is_empty());
    }

    #[test]
    fn penetrating_body_gets_contacts() {
        let mut b = Body::capsule(1.0, 0.5, 0.05);
        b.pos = v2(0.0, 0.02); // endpoints at y=0.02, radius 0.05 -> depth 0.03
        let mut cs = vec![];
        collect(&mut [b], 100.0, &mut cs, &[]);
        assert_eq!(cs.len(), 2);
        assert!((cs[0].depth - 0.03).abs() < 1e-6);
    }

    #[test]
    fn normal_impulse_stops_falling() {
        let mut bodies = vec![Body::capsule(1.0, 0.5, 0.05)];
        bodies[0].pos = v2(0.0, 0.03);
        bodies[0].vel = v2(0.0, -3.0);
        let mut cs = vec![];
        collect(&mut bodies, 100.0, &mut cs, &[]);
        for _ in 0..10 {
            solve(&mut bodies, &mut cs);
        }
        assert!(bodies[0].vel.y >= -1e-3, "downward velocity removed, vy={}", bodies[0].vel.y);
    }

    #[test]
    fn friction_damps_sliding() {
        let mut bodies = vec![Body::capsule(1.0, 0.5, 0.05)];
        bodies[0].pos = v2(0.0, 0.04);
        bodies[0].vel = v2(2.0, -1.0);
        let mut cs = vec![];
        collect(&mut bodies, 100.0, &mut cs, &[]);
        for _ in 0..10 {
            solve(&mut bodies, &mut cs);
        }
        assert!(bodies[0].vel.x < 2.0, "friction should slow sliding");
        assert!(bodies[0].vel.x >= 0.0, "friction cannot reverse motion");
    }
}
