//! Gym-MuJoCo-style locomotion environment over [`super::models`]:
//! forward-velocity reward, quadratic control cost, healthy termination,
//! 5 physics substeps per env step, reset noise.
//!
//! Since the batch-resident refactor, [`WalkerEnv`] is a **width-1
//! view** over the SoA batch kernel
//! ([`crate::envs::vector::WalkerVec`], which itself steps a
//! [`super::batch::WorldBatch`]): one lane, lane width 1 — the bitwise
//! scalar reference path. There is exactly one solver and one task
//! layer in the tree; this file only keeps the scalar `Env` surface and
//! the shared per-env RNG/noise conventions.

use crate::envs::env::{Env, Step};
use crate::envs::spec::{ActionSpace, EnvSpec};
use crate::envs::vector::{SliceArena, VecEnv, WalkerVec};
use crate::rng::Pcg32;

/// Which locomotion task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Hopper,
    HalfCheetah,
    Ant,
}

impl Task {
    pub(crate) fn build(self) -> super::models::Model {
        match self {
            Task::Hopper => super::models::hopper(),
            Task::HalfCheetah => super::models::half_cheetah(),
            Task::Ant => super::models::ant(),
        }
    }

    pub(crate) fn id(self) -> &'static str {
        match self {
            Task::Hopper => "Hopper-v4",
            Task::HalfCheetah => "HalfCheetah-v4",
            Task::Ant => "Ant-v4",
        }
    }
}

/// Per-env RNG stream, keyed identically in the scalar view and the SoA
/// kernel (lane `l` of a batch starting at `first_env_id` uses
/// `make_rng(seed, first_env_id + l)`), so trajectories are a function
/// of `(seed, global env id)` alone. Public so the parity pin tests can
/// reproduce the stream against the AoS reference stepper.
#[inline]
pub fn make_rng(seed: u64, env_id: u64) -> Pcg32 {
    crate::rng::env_rng(seed, 0x6d6a63, env_id)
}

/// Gym-style reset noise on pose and velocity, on an AoS
/// [`World`](super::dynamics::World). The RNG draw *order* (per body: angle,
/// vel.x, vel.y, omega) is part of the determinism contract and is
/// mirrored exactly by
/// [`WorldBatch::apply_reset_noise`](super::batch::WorldBatch::apply_reset_noise)
/// — the pair is pinned bitwise by `tests/mujoco_batch_parity.rs`,
/// which uses this AoS side to rebuild the pre-refactor trajectories.
pub fn apply_reset_noise(world: &mut super::dynamics::World, rng: &mut Pcg32) {
    for b in &mut world.bodies {
        if b.inv_mass > 0.0 {
            b.angle += rng.range(-0.005, 0.005);
            b.vel.x += rng.range(-0.01, 0.01);
            b.vel.y += rng.range(-0.01, 0.01);
            b.omega += rng.range(-0.01, 0.01);
        }
    }
}

/// The task spec for a walker with `n` actuated joints (shared with the
/// SoA kernel).
pub(crate) fn spec_for_task(task: Task, n: usize) -> EnvSpec {
    EnvSpec {
        id: task.id().into(),
        obs_shape: vec![2 + n + 3 + n],
        action_space: ActionSpace::Continuous { dim: n, low: -1.0, high: 1.0 },
        max_episode_steps: 1000,
        groups: vec![],
    }
}

/// Locomotion environment. Observation layout (matching Gym's planar
/// tasks): `[torso_z, torso_angle, q_1..q_n, vx, vz, omega, qd_1..qd_n]`
/// where `q_i` are joint angles — 11 dims for Hopper, 17 for HalfCheetah,
/// 21 for the planar Ant.
///
/// A width-1 view over [`WalkerVec`]: `reset`/`step` drive lane 0 of a
/// one-lane batch at lane width 1 (the bitwise reference path).
pub struct WalkerEnv {
    inner: WalkerVec,
    task: Task,
}

impl WalkerEnv {
    pub fn new(task: Task, seed: u64, env_id: u64) -> Self {
        WalkerEnv { inner: WalkerVec::new(task, seed, env_id, 1), task }
    }

    pub fn task(&self) -> Task {
        self.task
    }
}

impl Env for WalkerEnv {
    fn spec(&self) -> &EnvSpec {
        self.inner.spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.inner.reset_lane(0, obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let dim = self.inner.spec().obs_dim();
        let mut out = [Step::default()];
        {
            let mut arena = SliceArena::new(&mut obs[..dim], dim);
            self.inner.step_batch(action, &[0], &mut arena, &mut out);
        }
        out[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_dims_match_gym() {
        assert_eq!(WalkerEnv::new(Task::Hopper, 0, 0).spec().obs_dim(), 11);
        assert_eq!(WalkerEnv::new(Task::HalfCheetah, 0, 0).spec().obs_dim(), 17);
        assert_eq!(WalkerEnv::new(Task::Ant, 0, 0).spec().obs_dim(), 21);
    }

    #[test]
    fn cheetah_never_terminates() {
        let mut env = WalkerEnv::new(Task::HalfCheetah, 1, 0);
        let mut obs = vec![0.0; env.spec().obs_dim()];
        let n = env.spec().action_space.dim();
        env.reset(&mut obs);
        for i in 0..1000 {
            let a: Vec<f32> = (0..n).map(|k| ((i + k) as f32 * 0.7).sin()).collect();
            let s = env.step(&a, &mut obs);
            assert!(!s.done, "cheetah has no termination");
            if s.truncated {
                assert_eq!(i, 999);
            }
        }
    }

    #[test]
    fn hopper_zero_action_survives_a_while() {
        let mut env = WalkerEnv::new(Task::Hopper, 2, 0);
        let mut obs = vec![0.0; env.spec().obs_dim()];
        env.reset(&mut obs);
        let zeros = vec![0.0f32; 3];
        let mut alive = 0;
        for _ in 0..1000 {
            let s = env.step(&zeros, &mut obs);
            alive += 1;
            if s.finished() {
                break;
            }
        }
        assert!(alive > 10, "standing hopper dies too fast: {alive} steps");
    }

    #[test]
    fn forward_motion_increases_reward() {
        // Push the cheetah with a sinusoidal gait vs staying still;
        // the forward-velocity term must differentiate the two on average.
        let run = |gait: bool, seed: u64| -> f32 {
            let mut env = WalkerEnv::new(Task::HalfCheetah, seed, 0);
            let mut obs = vec![0.0; env.spec().obs_dim()];
            let n = env.spec().action_space.dim();
            env.reset(&mut obs);
            let mut total = 0.0;
            for i in 0..300 {
                let a: Vec<f32> = if gait {
                    (0..n).map(|k| (i as f32 * 0.35 + k as f32 * 1.1).sin()).collect()
                } else {
                    vec![0.0; n]
                };
                total += env.step(&a, &mut obs).reward;
            }
            total
        };
        let moving = run(true, 5);
        let still = run(false, 5);
        // The gait pays control cost; just require finite, differentiated outcomes.
        assert!(moving.is_finite() && still.is_finite());
        assert_ne!(moving, still);
    }

    #[test]
    fn reset_restores_initial_height() {
        let mut env = WalkerEnv::new(Task::Ant, 3, 0);
        let mut obs = vec![0.0; env.spec().obs_dim()];
        env.reset(&mut obs);
        let z0 = obs[0];
        let a = vec![1.0f32; env.spec().action_space.dim()];
        for _ in 0..50 {
            env.step(&a, &mut obs);
        }
        env.reset(&mut obs);
        assert!((obs[0] - z0).abs() < 0.05, "reset should restore pose");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = WalkerEnv::new(Task::Hopper, seed, 4);
            let mut obs = vec![0.0; env.spec().obs_dim()];
            env.reset(&mut obs);
            let mut acc = 0.0;
            for i in 0..100 {
                let a = vec![(i as f32 * 0.3).sin(); 3];
                let s = env.step(&a, &mut obs);
                acc += s.reward;
                if s.finished() {
                    env.reset(&mut obs);
                }
            }
            acc
        };
        assert_eq!(run(9), run(9));
    }
}
