//! Revolute joints with angle limits and torque motors, solved with
//! sequential impulses (Box2D-lite style point-to-point constraint plus
//! an angular limit constraint).

use super::body::Body;
use super::math::{solve22, Vec2};

/// A revolute (hinge) joint pinning a point of body `a` to a point of
/// body `b`, with optional relative-angle limits and a torque motor.
#[derive(Debug, Clone)]
pub struct RevoluteJoint {
    pub body_a: usize,
    pub body_b: usize,
    /// Anchor in body a's local frame.
    pub local_anchor_a: Vec2,
    /// Anchor in body b's local frame.
    pub local_anchor_b: Vec2,
    /// Relative-angle limits `(lo, hi)` about the reference angle.
    pub limit: Option<(f32, f32)>,
    /// Rest relative angle (`angle_b - angle_a` at assembly).
    pub ref_angle: f32,
    /// Motor torque scale (N·m per unit action); 0 disables the motor.
    pub gear: f32,
    // --- solver scratch (per-step warm-start state) ---
    pub(crate) r_a: Vec2,
    pub(crate) r_b: Vec2,
    pub(crate) bias: Vec2,
    pub(crate) impulse: Vec2,
    pub(crate) limit_impulse: f32,
    pub(crate) limit_bias: f32,
    pub(crate) limit_state: LimitState,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LimitState {
    Inactive,
    AtLower,
    AtUpper,
}

impl RevoluteJoint {
    pub fn new(body_a: usize, body_b: usize, local_anchor_a: Vec2, local_anchor_b: Vec2) -> Self {
        RevoluteJoint {
            body_a,
            body_b,
            local_anchor_a,
            local_anchor_b,
            limit: None,
            ref_angle: 0.0,
            gear: 0.0,
            r_a: Vec2::ZERO,
            r_b: Vec2::ZERO,
            bias: Vec2::ZERO,
            impulse: Vec2::ZERO,
            limit_impulse: 0.0,
            limit_bias: 0.0,
            limit_state: LimitState::Inactive,
        }
    }

    pub fn with_limit(mut self, lo: f32, hi: f32) -> Self {
        self.limit = Some((lo, hi));
        self
    }

    pub fn with_gear(mut self, gear: f32) -> Self {
        self.gear = gear;
        self
    }

    /// Relative joint angle about the reference configuration.
    #[inline]
    pub fn angle(&self, bodies: &[Body]) -> f32 {
        bodies[self.body_b].angle - bodies[self.body_a].angle - self.ref_angle
    }

    /// Relative joint angular velocity.
    #[inline]
    pub fn speed(&self, bodies: &[Body]) -> f32 {
        bodies[self.body_b].omega - bodies[self.body_a].omega
    }

    /// Precompute anchors and Baumgarte bias for this substep, then
    /// warm-start: re-apply last substep's accumulated impulses so the
    /// iterative solver starts near the converged solution (Box2D-style;
    /// without this, long chains under gravity never converge in a
    /// bounded iteration budget).
    pub(crate) fn prepare(&mut self, bodies: &mut [Body], _inv_dt: f32, _beta: f32) {
        let (a, b) = (&bodies[self.body_a], &bodies[self.body_b]);
        self.r_a = self.local_anchor_a.rotate(a.angle);
        self.r_b = self.local_anchor_b.rotate(b.angle);
        // Positional drift is corrected by the split position pass
        // (`solve_position`), NOT a velocity bias — Baumgarte bias injects
        // kinetic energy and made resting stacks vibrate.
        self.bias = Vec2::ZERO;
        self.limit_bias = 0.0;
        self.limit_state = match self.limit {
            None => LimitState::Inactive,
            Some((lo, hi)) => {
                let ang = self.angle(bodies);
                if ang <= lo {
                    LimitState::AtLower
                } else if ang >= hi {
                    LimitState::AtUpper
                } else {
                    LimitState::Inactive
                }
            }
        };
        // warm start from the previous substep's accumulated impulses
        if self.limit_state == LimitState::Inactive {
            self.limit_impulse = 0.0;
        }
        let p = self.impulse;
        let (ia, ib) = (self.body_a, self.body_b);
        let (ra, rb) = (self.r_a, self.r_b);
        bodies[ia].apply_impulse(-p, ra);
        bodies[ib].apply_impulse(p, rb);
        let li = self.limit_impulse;
        bodies[ia].omega -= bodies[ia].inv_inertia * li;
        bodies[ib].omega += bodies[ib].inv_inertia * li;
    }

    /// One velocity iteration: point constraint + angle limit.
    pub(crate) fn solve_velocity(&mut self, bodies: &mut [Body]) {
        let (ia, ib) = (self.body_a, self.body_b);
        // angular limit first (touches only omega)
        if self.limit_state != LimitState::Inactive {
            let rel = bodies[ib].omega - bodies[ia].omega - self.limit_bias;
            let inv_k = bodies[ia].inv_inertia + bodies[ib].inv_inertia;
            if inv_k > 0.0 {
                let mut imp = -rel / inv_k;
                // clamp accumulated impulse by limit side
                let old = self.limit_impulse;
                match self.limit_state {
                    LimitState::AtLower => {
                        self.limit_impulse = (old + imp).max(0.0);
                    }
                    LimitState::AtUpper => {
                        self.limit_impulse = (old + imp).min(0.0);
                    }
                    LimitState::Inactive => unreachable!(),
                }
                imp = self.limit_impulse - old;
                bodies[ia].omega -= bodies[ia].inv_inertia * imp;
                bodies[ib].omega += bodies[ib].inv_inertia * imp;
            }
        }

        // point-to-point constraint
        let (ma, ia_inv) = (bodies[ia].inv_mass, bodies[ia].inv_inertia);
        let (mb, ib_inv) = (bodies[ib].inv_mass, bodies[ib].inv_inertia);
        let (ra, rb) = (self.r_a, self.r_b);
        let k11 = ma + mb + ia_inv * ra.y * ra.y + ib_inv * rb.y * rb.y;
        let k12 = -ia_inv * ra.x * ra.y - ib_inv * rb.x * rb.y;
        let k22 = ma + mb + ia_inv * ra.x * ra.x + ib_inv * rb.x * rb.x;

        let va = bodies[ia].velocity_at(ra);
        let vb = bodies[ib].velocity_at(rb);
        let c_dot = vb - va + self.bias;
        let p = solve22(k11, k12, k22, -c_dot);
        self.impulse += p;

        let pa = -p;
        bodies[ia].apply_impulse(pa, ra);
        bodies[ib].apply_impulse(p, rb);
    }

    /// One nonlinear Gauss-Seidel *position* iteration: moves
    /// positions/angles directly (no momentum change) to remove anchor
    /// separation and limit violation. Returns the anchor error length.
    pub(crate) fn solve_position(&self, bodies: &mut [Body], beta: f32) -> f32 {
        let (ia, ib) = (self.body_a, self.body_b);

        // angular limit positional pushback
        if let Some((lo, hi)) = self.limit {
            let ang = self.angle(bodies);
            let viol = if ang < lo {
                ang - lo // negative
            } else if ang > hi {
                ang - hi // positive
            } else {
                0.0
            };
            if viol != 0.0 {
                let inv_k = bodies[ia].inv_inertia + bodies[ib].inv_inertia;
                if inv_k > 0.0 {
                    let corr = (-beta * viol).clamp(-0.2, 0.2) / inv_k;
                    bodies[ia].angle -= bodies[ia].inv_inertia * corr;
                    bodies[ib].angle += bodies[ib].inv_inertia * corr;
                }
            }
        }

        // point-to-point positional correction
        let ra = self.local_anchor_a.rotate(bodies[ia].angle);
        let rb = self.local_anchor_b.rotate(bodies[ib].angle);
        let err = (bodies[ib].pos + rb) - (bodies[ia].pos + ra);
        let elen = err.len();
        if elen > 1e-6 {
            let (ma, ia_inv) = (bodies[ia].inv_mass, bodies[ia].inv_inertia);
            let (mb, ib_inv) = (bodies[ib].inv_mass, bodies[ib].inv_inertia);
            let k11 = ma + mb + ia_inv * ra.y * ra.y + ib_inv * rb.y * rb.y;
            let k12 = -ia_inv * ra.x * ra.y - ib_inv * rb.x * rb.y;
            let k22 = ma + mb + ia_inv * ra.x * ra.x + ib_inv * rb.x * rb.x;
            let mut corr = err * beta;
            let clen = corr.len();
            if clen > 0.2 {
                corr = corr * (0.2 / clen);
            }
            let p = solve22(k11, k12, k22, -corr);
            // pseudo-impulse: applied to positions, not velocities
            bodies[ia].pos += p * -ma;
            bodies[ia].angle -= ia_inv * ra.cross(p);
            bodies[ib].pos += p * mb;
            bodies[ib].angle += ib_inv * rb.cross(p);
        }
        elen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::mujoco::math::v2;

    fn two_bodies() -> Vec<Body> {
        let mut a = Body::capsule(1.0, 0.5, 0.05);
        let mut b = Body::capsule(1.0, 0.5, 0.05);
        a.pos = v2(0.0, 0.0);
        b.pos = v2(1.0, 0.0); // joined at (0.5, 0)
        vec![a, b]
    }

    #[test]
    fn joint_angle_zero_at_assembly() {
        let bodies = two_bodies();
        let j = RevoluteJoint::new(0, 1, v2(0.5, 0.0), v2(-0.5, 0.0));
        assert_eq!(j.angle(&bodies), 0.0);
        assert_eq!(j.speed(&bodies), 0.0);
    }

    #[test]
    fn velocity_constraint_removes_relative_anchor_velocity() {
        let mut bodies = two_bodies();
        bodies[1].vel = v2(0.0, 2.0); // b moving away vertically
        let mut j = RevoluteJoint::new(0, 1, v2(0.5, 0.0), v2(-0.5, 0.0));
        j.prepare(&mut bodies, 100.0, 0.0); // no bias: pure velocity solve
        for _ in 0..20 {
            j.solve_velocity(&mut bodies);
        }
        let va = bodies[0].velocity_at(j.r_a);
        let vb = bodies[1].velocity_at(j.r_b);
        let rel = vb - va;
        assert!(rel.len() < 1e-3, "anchor velocities should match, rel={rel:?}");
    }

    #[test]
    fn limit_resists_exceeding() {
        let mut bodies = two_bodies();
        bodies[1].omega = 5.0; // spinning past upper limit
        bodies[1].angle = 0.6;
        let mut j = RevoluteJoint::new(0, 1, v2(0.5, 0.0), v2(-0.5, 0.0)).with_limit(-0.5, 0.5);
        j.prepare(&mut bodies, 100.0, 0.0);
        assert_eq!(j.limit_state, LimitState::AtUpper);
        for _ in 0..10 {
            j.solve_velocity(&mut bodies);
        }
        assert!(
            bodies[1].omega - bodies[0].omega <= 1e-3,
            "limit must stop further opening"
        );
    }
}
