//! Articulated walker models: Hopper, HalfCheetah, and a planar
//! Ant-like quadruped, assembled from capsule links + revolute joints.
//!
//! Dimensions loosely follow the Gym MuJoCo models scaled to our planar
//! engine; observation layouts match Gym where the planar reduction
//! allows (Hopper: 11 dims, HalfCheetah: 17 dims — both as in Gym).

use super::body::Body;
use super::dynamics::World;
use super::joint::RevoluteJoint;
use super::math::{v2, Vec2};

/// A built model plus its task constants.
#[derive(Debug, Clone)]
pub struct Model {
    pub world: World,
    /// Index of the torso body (reward/termination reference).
    pub torso: usize,
    /// Healthy torso-height range; episode terminates outside it.
    pub healthy_z: Option<(f32, f32)>,
    /// Max torso-angle deviation from the initial pose before termination.
    pub healthy_angle_dev: Option<f32>,
    /// Control cost weight.
    pub ctrl_cost: f32,
    /// Alive bonus per step.
    pub healthy_reward: f32,
    /// Forward-velocity reward weight.
    pub forward_weight: f32,
    /// Initial torso angle (healthy deviation is measured against this).
    pub init_angle: f32,
}

/// Connect `b` to `a` with a revolute joint whose rest relative angle is
/// the assembly pose, so `joint.angle() == 0` at build time.
fn connect(
    w: &mut World,
    a: usize,
    b: usize,
    anchor_a: Vec2,
    anchor_b: Vec2,
    limit: (f32, f32),
    gear: f32,
) {
    let ref_angle = w.bodies[b].angle - w.bodies[a].angle;
    let mut j = RevoluteJoint::new(a, b, anchor_a, anchor_b)
        .with_limit(limit.0, limit.1)
        .with_gear(gear);
    j.ref_angle = ref_angle;
    w.add_joint(j);
}

/// Place a capsule with its *top* endpoint at `top`, hanging straight
/// down (angle −π/2 so local +x points down). Returns the body index.
fn hang(w: &mut World, top: Vec2, mass: f32, half: f32, radius: f32) -> usize {
    let mut b = Body::capsule(mass, half, radius);
    b.angle = -std::f32::consts::FRAC_PI_2;
    b.pos = top + v2(0.0, -half);
    w.add_body(b)
}

/// Hopper: vertical torso, thigh, leg, horizontal foot; 3 actuated
/// joints. Gym Hopper analog (obs dim 11).
pub fn hopper() -> Model {
    let mut w = World::new();

    // torso: vertical capsule, spans y 0.85..1.25
    let mut torso = Body::capsule(3.6, 0.2, 0.05);
    torso.angle = std::f32::consts::FRAC_PI_2; // +x up
    torso.pos = v2(0.0, 1.05);
    let torso = w.add_body(torso);

    let thigh = hang(&mut w, v2(0.0, 0.85), 1.8, 0.2, 0.05); // 0.85..0.45
    let leg = hang(&mut w, v2(0.0, 0.45), 1.2, 0.2, 0.04); // 0.45..0.05
    let mut foot_b = Body::capsule(1.0, 0.13, 0.045);
    foot_b.pos = v2(0.06, 0.05);
    let foot = w.add_body(foot_b);

    // torso bottom is local (-0.2, 0) because +x is up. Limits are kept
    // tight enough that the chain cannot fold flat — the standing pose is
    // passively metastable, as the Gym hopper's is over short horizons.
    connect(&mut w, torso, thigh, v2(-0.2, 0.0), v2(-0.2, 0.0), (-0.7, 0.7), 6.0);
    connect(&mut w, thigh, leg, v2(0.2, 0.0), v2(-0.2, 0.0), (-0.7, 0.7), 4.0);
    // heel: foot local anchor back end
    connect(&mut w, leg, foot, v2(0.2, 0.0), v2(-0.06, 0.0), (-0.4, 0.4), 2.5);

    Model {
        world: w,
        torso,
        healthy_z: Some((0.5, 2.0)),
        healthy_angle_dev: Some(0.5),
        ctrl_cost: 1e-3,
        healthy_reward: 1.0,
        forward_weight: 1.0,
        init_angle: std::f32::consts::FRAC_PI_2,
    }
}

/// HalfCheetah: horizontal torso with back and front legs of
/// thigh/shin/foot each; 6 actuated joints (obs dim 17).
pub fn half_cheetah() -> Model {
    let mut w = World::new();

    let mut torso = Body::capsule(6.0, 0.5, 0.05);
    torso.pos = v2(0.0, 0.62);
    let torso = w.add_body(torso);

    let mut legs = Vec::new();
    for (side, sign) in [(-0.5f32, -1.0f32), (0.5, 1.0)] {
        let hip = v2(side, 0.62);
        let thigh = hang(&mut w, hip, 1.5, 0.15, 0.045); // 0.62..0.32
        let shin = hang(&mut w, hip + v2(0.0, -0.3), 1.2, 0.15, 0.04); // 0.32..0.02
        let mut foot_b = Body::capsule(0.8, 0.09, 0.04);
        foot_b.pos = hip + v2(sign * 0.07, -0.6);
        let foot = w.add_body(foot_b);

        connect(&mut w, torso, thigh, v2(side, 0.0), v2(-0.15, 0.0), (-0.6, 0.6), 6.0);
        connect(&mut w, thigh, shin, v2(0.15, 0.0), v2(-0.15, 0.0), (-0.7, 0.7), 4.5);
        connect(&mut w, shin, foot, v2(0.15, 0.0), v2(sign * -0.07, 0.0), (-0.4, 0.4), 3.0);
        legs.push((thigh, shin, foot));
    }

    Model {
        world: w,
        torso,
        healthy_z: None, // cheetah never terminates
        healthy_angle_dev: None,
        ctrl_cost: 0.1,
        healthy_reward: 0.0,
        forward_weight: 1.0,
        init_angle: 0.0,
    }
}

/// Planar Ant-like quadruped: horizontal torso, four two-segment legs;
/// 8 actuated joints (obs dim 21). The paper's Ant is 3-D; this is the
/// planar reduction with matching joint count per side profile
/// (DESIGN.md §2).
pub fn ant() -> Model {
    let mut w = World::new();

    let mut torso = Body::capsule(5.0, 0.35, 0.08);
    torso.pos = v2(0.0, 0.72);
    let torso = w.add_body(torso);

    for hip_x in [-0.35f32, -0.12, 0.12, 0.35] {
        let hip = v2(hip_x, 0.72);
        let upper = hang(&mut w, hip, 1.0, 0.16, 0.045); // 0.72..0.40
        let lower = hang(&mut w, hip + v2(0.0, -0.32), 0.8, 0.18, 0.04); // 0.40..0.04
        connect(&mut w, torso, upper, v2(hip_x, 0.0), v2(-0.16, 0.0), (-0.6, 0.6), 5.0);
        connect(&mut w, upper, lower, v2(0.16, 0.0), v2(-0.18, 0.0), (-0.7, 0.3), 4.0);
    }

    Model {
        world: w,
        torso,
        healthy_z: Some((0.3, 1.4)),
        healthy_angle_dev: Some(1.0),
        ctrl_cost: 0.5,
        healthy_reward: 1.0,
        forward_weight: 1.0,
        init_angle: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settle(m: &mut Model, steps: usize) {
        let n = m.world.actuated().len();
        let zeros = vec![0.0f32; n];
        for _ in 0..steps {
            m.world.step(super::super::DT, &zeros);
        }
    }

    #[test]
    fn hopper_has_3_actuators_and_stands() {
        let mut m = hopper();
        assert_eq!(m.world.actuated().len(), 3);
        settle(&mut m, 150);
        assert!(!m.world.is_bad());
        let z = m.world.bodies[m.torso].pos.y;
        // the passive hopper is an inverted pendulum: it must still be
        // upright after 1.5 s (it tips over around ~2.5 s, as expected)
        assert!(z > 0.8, "hopper should still stand at 1.5s, z={z}");
    }

    #[test]
    fn cheetah_has_6_actuators_and_is_stable() {
        let mut m = half_cheetah();
        assert_eq!(m.world.actuated().len(), 6);
        settle(&mut m, 500);
        assert!(!m.world.is_bad());
        let z = m.world.bodies[m.torso].pos.y;
        assert!(z > 0.15 && z < 1.0, "torso at sane height, z={z}");
    }

    #[test]
    fn ant_has_8_actuators_and_is_stable() {
        let mut m = ant();
        assert_eq!(m.world.actuated().len(), 8);
        settle(&mut m, 500);
        assert!(!m.world.is_bad());
        let z = m.world.bodies[m.torso].pos.y;
        assert!(z > 0.2, "ant torso should stay up, z={z}");
    }

    #[test]
    fn joints_start_at_zero_angle() {
        for m in [hopper(), half_cheetah(), ant()] {
            for j in &m.world.joints {
                let a = j.angle(&m.world.bodies);
                assert!(a.abs() < 1e-5, "assembly joint angle {a}");
            }
        }
    }

    #[test]
    fn random_control_never_nan() {
        use crate::rng::Pcg32;
        let mut rng = Pcg32::new(123, 0);
        for mut m in [hopper(), half_cheetah(), ant()] {
            let n = m.world.actuated().len();
            for _ in 0..1500 {
                let ctrl: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
                m.world.step(super::super::DT, &ctrl);
                assert!(!m.world.is_bad());
            }
        }
    }
}
