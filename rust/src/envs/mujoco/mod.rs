//! Planar articulated rigid-body physics (MuJoCo substitute).
//!
//! MuJoCo itself is unavailable; what the paper's benchmarks exercise is
//! the *cost profile* of an articulated-dynamics engine stepped
//! `frame_skip = 5` times per env step. This module implements a planar
//! (2-D) rigid-body engine in the Box2D-lite style: capsule links,
//! revolute joints with limits and torque motors, ground contact with
//! friction, all solved with sequential impulses + Baumgarte
//! stabilization. On top of it, [`models`] defines Hopper, HalfCheetah
//! and a planar Ant-like quadruped, and [`walker`] exposes them with
//! Gym-MuJoCo observation/reward conventions (forward-velocity reward,
//! control cost, healthy termination).

//! # Batch-resident execution
//!
//! Production stepping happens in [`batch::WorldBatch`]: body state,
//! joint warm-start impulses and contact caches for a whole batch of
//! envs live in SoA lanes, and every solver phase runs as a masked
//! lane-group pass ([`crate::simd`]). The AoS [`World`] remains the
//! model *description* (what [`models`] builds) and the scalar
//! **reference stepper** the batch's width-1 path is pinned against
//! bitwise — the scalar [`WalkerEnv`] is a width-1 view over the same
//! `WorldBatch` core, not a separate solver.

pub mod math;
pub mod body;
pub mod joint;
pub mod contact;
pub mod dynamics;
pub mod batch;
pub mod models;
pub mod walker;

pub use batch::WorldBatch;
pub use dynamics::World;
pub use walker::WalkerEnv;

/// Physics substep length (s). `frame_skip` substeps per env step.
pub const DT: f32 = 0.01;
/// Gym-MuJoCo-style frame skip.
pub const FRAME_SKIP: usize = 5;
