//! The core `Env` trait.
//!
//! Observations are written **into caller-provided buffers** rather than
//! returned: this is the hook the paper's StateBufferQueue optimization
//! needs — a worker thread steps the env and writes the observation
//! directly into its pre-allocated slot in the current block, eliminating
//! the collect-then-batch copies the Python subprocess executor pays
//! (paper Appendix D, "Data Movement").

use super::spec::EnvSpec;

/// Result of one environment step (the non-observation part).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Step {
    /// Scalar reward for this transition.
    pub reward: f32,
    /// Episode terminated (true termination, not time limit).
    pub done: bool,
    /// Episode truncated by a time limit (reported separately so GAE can
    /// bootstrap through truncations, as Gym v26 / EnvPool do).
    pub truncated: bool,
}

impl Step {
    /// Terminal for control purposes (either way the env needs a reset).
    pub fn finished(&self) -> bool {
        self.done || self.truncated
    }
}

/// A single RL environment instance.
///
/// Actions arrive as flat `&[f32]` slices of length
/// `spec.action_space.dim()`; discrete envs read `action[0]` as an integer
/// id. This keeps the pool's action transport a single contiguous buffer
/// for every task type.
pub trait Env: Send {
    /// Static spec (shape/space metadata).
    fn spec(&self) -> &EnvSpec;

    /// Reset the episode and write the initial observation into `obs`
    /// (length `spec().obs_dim()`).
    fn reset(&mut self, obs: &mut [f32]);

    /// Advance one step with `action`, writing the next observation into
    /// `obs` and returning reward/termination. Implementations must *not*
    /// auto-reset; the pool does that (so executors agree on semantics).
    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step;
}

/// Boxed environments are environments too — this is what lets the
/// registry stack generic wrappers over `Box<dyn Env>` trait objects
/// (`TimeLimit<Box<dyn Env>>` and friends in `make_env_wrapped`).
impl<E: Env + ?Sized> Env for Box<E> {
    fn spec(&self) -> &EnvSpec {
        (**self).spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        (**self).reset(obs)
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        (**self).step(action, obs)
    }
}

/// Helper for discrete envs: decode the flat action lane to an id,
/// clamping to the valid range so malformed inputs cannot index OOB.
#[inline]
pub fn discrete_action(action: &[f32], n: usize) -> usize {
    debug_assert!(n > 0);
    (action[0] as i64).clamp(0, n as i64 - 1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_finished() {
        assert!(!Step { reward: 0.0, done: false, truncated: false }.finished());
        assert!(Step { reward: 0.0, done: true, truncated: false }.finished());
        assert!(Step { reward: 0.0, done: false, truncated: true }.finished());
    }

    #[test]
    fn discrete_decode_clamps() {
        assert_eq!(discrete_action(&[2.0], 6), 2);
        assert_eq!(discrete_action(&[-1.0], 6), 0);
        assert_eq!(discrete_action(&[99.0], 6), 5);
    }
}
