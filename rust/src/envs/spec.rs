//! Environment specifications: observation/action space metadata, the
//! analogue of EnvPool's C++ `EnvSpec`.

/// Action space of an environment.
#[derive(Debug, Clone, PartialEq)]
pub enum ActionSpace {
    /// `n` discrete actions, encoded on the wire as a single f32 holding
    /// the integer action id (the pool moves flat f32 action buffers).
    Discrete(usize),
    /// Box action in `[low, high]^dim`.
    Continuous { dim: usize, low: f32, high: f32 },
}

impl ActionSpace {
    /// Number of f32 lanes one action occupies in a flat action buffer.
    pub fn dim(&self) -> usize {
        match self {
            ActionSpace::Discrete(_) => 1,
            ActionSpace::Continuous { dim, .. } => *dim,
        }
    }

    /// Is this a discrete space?
    pub fn is_discrete(&self) -> bool {
        matches!(self, ActionSpace::Discrete(_))
    }

    /// Number of discrete actions, or the continuous dimension.
    pub fn n(&self) -> usize {
        match self {
            ActionSpace::Discrete(n) => *n,
            ActionSpace::Continuous { dim, .. } => *dim,
        }
    }

    /// Clamp a continuous action in place to the box bounds (no-op for
    /// discrete).
    pub fn clamp(&self, a: &mut [f32]) {
        if let ActionSpace::Continuous { low, high, .. } = self {
            for x in a {
                *x = x.clamp(*low, *high);
            }
        }
    }
}

/// One lane group of a heterogeneous (scenario) pool, as seen from the
/// pool's union [`EnvSpec`]: which task occupies which contiguous run of
/// global env ids, and that group's own (un-padded) spec.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupView {
    /// Task id of this group.
    pub task_id: String,
    /// First global env id of the group (groups are contiguous).
    pub first_env: usize,
    /// Number of envs (lanes) in the group.
    pub count: usize,
    /// The group's own spec (`groups` empty — views don't nest).
    pub spec: EnvSpec,
}

/// Static environment metadata; one per task id.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvSpec {
    /// Task id, e.g. `"Pong-v5"`.
    pub id: String,
    /// Observation shape (e.g. `[4, 84, 84]` for Atari, `[27]` for Ant).
    pub obs_shape: Vec<usize>,
    /// Action space.
    pub action_space: ActionSpace,
    /// Episode step limit applied by the standard wrapper stack.
    pub max_episode_steps: usize,
    /// Per-group views for heterogeneous (scenario) pools, in global
    /// env-id order. Empty for ordinary single-task specs. When
    /// non-empty, `obs_shape`/`action_space` describe the **padded
    /// union** (max dims across groups; rows are zero-padded past each
    /// group's own width) — consumers either assert a uniform spec via
    /// [`EnvSpec::uniform_group_spec`] or handle the padding.
    pub groups: Vec<GroupView>,
}

impl EnvSpec {
    /// Flattened observation length.
    pub fn obs_dim(&self) -> usize {
        self.obs_shape.iter().product()
    }

    /// Is this a heterogeneous (multi-group) union spec?
    pub fn is_grouped(&self) -> bool {
        !self.groups.is_empty()
    }

    /// If every group shares one task spec (or the spec has no groups
    /// at all), the uniform per-env spec; `None` when groups genuinely
    /// mix shapes/spaces. Trainers use this to reject ragged mixes.
    pub fn uniform_group_spec(&self) -> Option<&EnvSpec> {
        match self.groups.split_first() {
            None => Some(self),
            Some((first, rest)) => rest
                .iter()
                .all(|g| {
                    g.spec.obs_shape == first.spec.obs_shape
                        && g.spec.action_space == first.spec.action_space
                        && g.spec.max_episode_steps == first.spec.max_episode_steps
                })
                .then_some(&first.spec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_dim_products() {
        let s = EnvSpec {
            id: "x".into(),
            obs_shape: vec![4, 84, 84],
            action_space: ActionSpace::Discrete(6),
            max_episode_steps: 108_000,
            groups: vec![],
        };
        assert_eq!(s.obs_dim(), 4 * 84 * 84);
        assert_eq!(s.action_space.dim(), 1);
        assert!(s.action_space.is_discrete());
        assert!(!s.is_grouped());
        assert_eq!(s.uniform_group_spec(), Some(&s));
    }

    #[test]
    fn uniform_group_spec_detects_mixes() {
        let base = |dim: usize| EnvSpec {
            id: "t".into(),
            obs_shape: vec![dim],
            action_space: ActionSpace::Discrete(2),
            max_episode_steps: 100,
            groups: vec![],
        };
        let mut union = base(4);
        union.groups = vec![
            GroupView { task_id: "t".into(), first_env: 0, count: 2, spec: base(4) },
            GroupView { task_id: "t".into(), first_env: 2, count: 2, spec: base(4) },
        ];
        assert!(union.is_grouped());
        assert_eq!(union.uniform_group_spec(), Some(&base(4)));
        union.groups[1].spec = base(3);
        assert_eq!(union.uniform_group_spec(), None);
    }

    #[test]
    fn continuous_clamp() {
        let sp = ActionSpace::Continuous { dim: 3, low: -1.0, high: 1.0 };
        let mut a = [2.0, -3.0, 0.5];
        sp.clamp(&mut a);
        assert_eq!(a, [1.0, -1.0, 0.5]);
        assert_eq!(sp.dim(), 3);
    }
}
