//! Environment specifications: observation/action space metadata, the
//! analogue of EnvPool's C++ `EnvSpec`.

/// Action space of an environment.
#[derive(Debug, Clone, PartialEq)]
pub enum ActionSpace {
    /// `n` discrete actions, encoded on the wire as a single f32 holding
    /// the integer action id (the pool moves flat f32 action buffers).
    Discrete(usize),
    /// Box action in `[low, high]^dim`.
    Continuous { dim: usize, low: f32, high: f32 },
}

impl ActionSpace {
    /// Number of f32 lanes one action occupies in a flat action buffer.
    pub fn dim(&self) -> usize {
        match self {
            ActionSpace::Discrete(_) => 1,
            ActionSpace::Continuous { dim, .. } => *dim,
        }
    }

    /// Is this a discrete space?
    pub fn is_discrete(&self) -> bool {
        matches!(self, ActionSpace::Discrete(_))
    }

    /// Number of discrete actions, or the continuous dimension.
    pub fn n(&self) -> usize {
        match self {
            ActionSpace::Discrete(n) => *n,
            ActionSpace::Continuous { dim, .. } => *dim,
        }
    }

    /// Clamp a continuous action in place to the box bounds (no-op for
    /// discrete).
    pub fn clamp(&self, a: &mut [f32]) {
        if let ActionSpace::Continuous { low, high, .. } = self {
            for x in a {
                *x = x.clamp(*low, *high);
            }
        }
    }
}

/// Static environment metadata; one per task id.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvSpec {
    /// Task id, e.g. `"Pong-v5"`.
    pub id: String,
    /// Observation shape (e.g. `[4, 84, 84]` for Atari, `[27]` for Ant).
    pub obs_shape: Vec<usize>,
    /// Action space.
    pub action_space: ActionSpace,
    /// Episode step limit applied by the standard wrapper stack.
    pub max_episode_steps: usize,
}

impl EnvSpec {
    /// Flattened observation length.
    pub fn obs_dim(&self) -> usize {
        self.obs_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_dim_products() {
        let s = EnvSpec {
            id: "x".into(),
            obs_shape: vec![4, 84, 84],
            action_space: ActionSpace::Discrete(6),
            max_episode_steps: 108_000,
        };
        assert_eq!(s.obs_dim(), 4 * 84 * 84);
        assert_eq!(s.action_space.dim(), 1);
        assert!(s.action_space.is_discrete());
    }

    #[test]
    fn continuous_clamp() {
        let sp = ActionSpace::Continuous { dim: 3, low: -1.0, high: 1.0 };
        let mut a = [2.0, -3.0, 0.5];
        sp.clamp(&mut a);
        assert_eq!(a, [1.0, -1.0, 0.5]);
        assert_eq!(sp.dim(), 3);
    }
}
