//! Scenario configs: heterogeneous (mixed-task) pools with seeded
//! per-lane domain randomization, parsed from a dependency-free text
//! format.
//!
//! # Format
//!
//! Line-based, like [`super::KvFile`] but sectioned. `#` starts a
//! comment line; blank lines are ignored; there are no inline comments.
//! Each `[group]` header opens a lane group, followed by `key = value`
//! pairs:
//!
//! ```text
//! # 3-group mixed pool
//! [group]
//! task = CartPole-v1
//! count = 4
//! # optional; default derives from the pool seed
//! seed = 11
//! # optional WrapConfig fields
//! time_limit = 200
//! reward_clip = true
//! # fixed physics override, all lanes
//! param.gravity = 9.8
//! # per-lane uniform draw in [lo, hi)
//! jitter.length = 0.4 0.6
//!
//! [group]
//! task = Hopper-v4
//! count = 2
//! ```
//!
//! Recognized keys: `task` (required), `count` (required), `seed`,
//! `time_limit`, `reward_clip`, `normalize_obs`, `normalize_obs_shared`,
//! `param.<name>`, `jitter.<name>`. Parameter names are validated
//! against `registry::supported_params` for the group's task at parse
//! time, so a typo fails before any pool is built.
//!
//! # Replayability contract
//!
//! A scenario file plus a pool seed fully determines every lane's
//! physics: fixed `param.*` values apply verbatim, and each `jitter.*`
//! range is drawn from a dedicated [`Pcg32`](crate::rng::Pcg32) stream
//! keyed by `(group seed ^ JITTER_SALT, parameter index)`, in lane
//! order, **at construction** — independent of `ExecMode`, thread
//! count, chunking and batch size. The same file + seed therefore
//! reproduces the same jittered parameters and the same per-env
//! episodes everywhere (pinned by `tests/scenario.rs`).
//!
//! # Round-trip
//!
//! [`ScenarioConfig::to_text`] emits a canonical form that
//! [`ScenarioConfig::parse`] maps back to an identical value (f32s are
//! printed with Rust's shortest round-trip notation), so configs can be
//! re-emitted, diffed and archived losslessly.

use crate::envs::registry::{self, WrapConfig};
use crate::rng::splitmix64;
use crate::{Error, Result};

/// Salt folded into a group's seed for the jitter streams, so parameter
/// draws never alias the env RNG streams built from the same seed.
pub const JITTER_SALT: u64 = 0x6a69_7474; // "jitt"

/// One lane group of a scenario: a task, a lane count, optional wrapper
/// settings and the group's physics overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioGroup {
    /// Registered task id (validated at parse time).
    pub task_id: String,
    /// Number of lanes (environments) in the group.
    pub count: usize,
    /// Explicit group seed; `None` derives one from the pool seed and
    /// the group index (see [`ScenarioConfig::group_seed`]).
    pub seed: Option<u64>,
    /// Per-group wrapper stack (same semantics as a homogeneous pool's
    /// `WrapConfig`).
    pub wrap: WrapConfig,
    /// Fixed physics overrides `(name, value)`, applied to every lane.
    pub params: Vec<(String, f32)>,
    /// Jittered physics `(name, lo, hi)`: each lane draws uniformly
    /// from `[lo, hi)` on the group's seeded jitter stream.
    pub jitter: Vec<(String, f32, f32)>,
}

/// A parsed, validated scenario: an ordered list of lane groups.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    pub groups: Vec<ScenarioGroup>,
}

fn bad(line_no: usize, msg: &str) -> Error {
    Error::Config(format!("scenario line {line_no}: {msg}"))
}

impl ScenarioConfig {
    /// Parse and validate scenario text (see the module docs for the
    /// format).
    pub fn parse(text: &str) -> Result<ScenarioConfig> {
        let mut groups: Vec<ScenarioGroup> = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[group]" {
                groups.push(ScenarioGroup {
                    task_id: String::new(),
                    count: 0,
                    seed: None,
                    wrap: WrapConfig::none(),
                    params: Vec::new(),
                    jitter: Vec::new(),
                });
                continue;
            }
            if line.starts_with('[') {
                return Err(bad(line_no, &format!("unknown section {line:?} (expected [group])")));
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(bad(line_no, &format!("expected `key = value`, got {line:?}")));
            };
            let (key, value) = (key.trim(), value.trim());
            let Some(group) = groups.last_mut() else {
                return Err(bad(line_no, "key outside any [group] section"));
            };
            match key {
                "task" => group.task_id = value.to_string(),
                "count" => {
                    group.count = value
                        .parse()
                        .map_err(|_| bad(line_no, &format!("bad count {value:?}")))?;
                }
                "seed" => {
                    group.seed = Some(
                        value
                            .parse()
                            .map_err(|_| bad(line_no, &format!("bad seed {value:?}")))?,
                    );
                }
                "time_limit" => {
                    group.wrap.time_limit = Some(
                        value
                            .parse()
                            .map_err(|_| bad(line_no, &format!("bad time_limit {value:?}")))?,
                    );
                }
                "reward_clip" | "normalize_obs" | "normalize_obs_shared" => {
                    let b = match value {
                        "true" => true,
                        "false" => false,
                        _ => return Err(bad(line_no, &format!("bad bool {value:?}"))),
                    };
                    match key {
                        "reward_clip" => group.wrap.reward_clip = b,
                        "normalize_obs" => group.wrap.normalize_obs = b,
                        _ => group.wrap.normalize_obs_shared = b,
                    }
                }
                _ if key.starts_with("param.") => {
                    let name = key["param.".len()..].trim();
                    let v: f32 = value
                        .parse()
                        .map_err(|_| bad(line_no, &format!("bad param value {value:?}")))?;
                    group.params.push((name.to_string(), v));
                }
                _ if key.starts_with("jitter.") => {
                    let name = key["jitter.".len()..].trim();
                    let mut it = value.split_whitespace();
                    let (lo, hi) = match (it.next(), it.next(), it.next()) {
                        (Some(lo), Some(hi), None) => (
                            lo.parse::<f32>()
                                .map_err(|_| bad(line_no, &format!("bad jitter lo {lo:?}")))?,
                            hi.parse::<f32>()
                                .map_err(|_| bad(line_no, &format!("bad jitter hi {hi:?}")))?,
                        ),
                        _ => return Err(bad(line_no, "jitter needs exactly `lo hi`")),
                    };
                    group.jitter.push((name.to_string(), lo, hi));
                }
                other => {
                    return Err(bad(line_no, &format!("unknown key {other:?}")));
                }
            }
        }
        let cfg = ScenarioConfig { groups };
        cfg.validate()?;
        Ok(cfg)
    }

    /// [`Self::parse`] a scenario file from disk.
    pub fn load(path: &str) -> Result<ScenarioConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("scenario file {path:?}: {e}")))?;
        Self::parse(&text)
    }

    /// Canonical text form; `parse(to_text(c)) == c` exactly.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (gi, g) in self.groups.iter().enumerate() {
            if gi > 0 {
                out.push('\n');
            }
            out.push_str("[group]\n");
            out.push_str(&format!("task = {}\n", g.task_id));
            out.push_str(&format!("count = {}\n", g.count));
            if let Some(seed) = g.seed {
                out.push_str(&format!("seed = {seed}\n"));
            }
            if let Some(limit) = g.wrap.time_limit {
                out.push_str(&format!("time_limit = {limit}\n"));
            }
            if g.wrap.reward_clip {
                out.push_str("reward_clip = true\n");
            }
            if g.wrap.normalize_obs {
                out.push_str("normalize_obs = true\n");
            }
            if g.wrap.normalize_obs_shared {
                out.push_str("normalize_obs_shared = true\n");
            }
            for (name, v) in &g.params {
                out.push_str(&format!("param.{name} = {v:?}\n"));
            }
            for (name, lo, hi) in &g.jitter {
                out.push_str(&format!("jitter.{name} = {lo:?} {hi:?}\n"));
            }
        }
        out
    }

    /// Structural + name validation (also called by [`Self::parse`]).
    pub fn validate(&self) -> Result<()> {
        if self.groups.is_empty() {
            return Err(Error::Config("scenario has no [group] sections".into()));
        }
        for (gi, g) in self.groups.iter().enumerate() {
            let ctx = |msg: String| Error::Config(format!("scenario group {gi}: {msg}"));
            if g.task_id.is_empty() {
                return Err(ctx("missing `task`".into()));
            }
            if !registry::ALL_TASKS.contains(&g.task_id.as_str()) {
                return Err(registry::unknown_env(&g.task_id));
            }
            if g.count == 0 {
                return Err(ctx("`count` must be > 0".into()));
            }
            let supported = registry::supported_params(&g.task_id);
            let mut seen: Vec<&str> = Vec::new();
            for (name, _) in &g.params {
                check_param(&ctx, &g.task_id, supported, &mut seen, name)?;
            }
            for (name, lo, hi) in &g.jitter {
                check_param(&ctx, &g.task_id, supported, &mut seen, name)?;
                if !lo.is_finite() || !hi.is_finite() || lo > hi {
                    return Err(ctx(format!(
                        "jitter.{name} range [{lo:?}, {hi:?}] must be finite with lo <= hi"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Total number of environments across all groups.
    pub fn num_envs(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// First global env id of group `gi` (groups occupy contiguous,
    /// file-ordered id ranges).
    pub fn first_env(&self, gi: usize) -> usize {
        self.groups[..gi].iter().map(|g| g.count).sum()
    }

    /// Map a global env id to `(group index, group-local lane)`.
    /// Panics on out-of-range ids (callers validate `num_envs` first).
    pub fn locate(&self, env_id: usize) -> (usize, usize) {
        let mut first = 0;
        for (gi, g) in self.groups.iter().enumerate() {
            if env_id < first + g.count {
                return (gi, env_id - first);
            }
            first += g.count;
        }
        panic!("env id {env_id} out of range for scenario of {} envs", self.num_envs());
    }

    /// The seed group `gi` runs under: its explicit `seed` if set, else
    /// a SplitMix64 chain over the pool seed (so distinct groups get
    /// decorrelated defaults that are still a pure function of
    /// `(pool_seed, group index)` — replayable, and identical to a
    /// homogeneous pool built with the same explicit seed).
    pub fn group_seed(&self, gi: usize, pool_seed: u64) -> u64 {
        if let Some(seed) = self.groups[gi].seed {
            return seed;
        }
        let mut st = pool_seed ^ 0x7363_656e; // "scen"
        let mut out = 0;
        for _ in 0..=gi {
            out = splitmix64(&mut st);
        }
        out
    }
}

fn check_param(
    ctx: &dyn Fn(String) -> Error,
    task: &str,
    supported: &[&str],
    seen: &mut Vec<&str>,
    name: &str,
) -> Result<()> {
    let Some(&canon) = supported.iter().find(|&&s| s == name) else {
        return Err(ctx(format!(
            "task {task} has no overridable parameter {name:?} (supported: {supported:?})"
        )));
    };
    if seen.contains(&canon) {
        return Err(ctx(format!("parameter {name:?} set more than once")));
    }
    seen.push(canon);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIXED: &str = "\
# comment line
[group]
task = CartPole-v1
count = 4
seed = 11
time_limit = 200
reward_clip = true
param.gravity = 9.8
jitter.length = 0.4 0.6

[group]
task = Hopper-v4
count = 2
jitter.gravity = 8.0 11.0

[group]
task = Pong-v5
count = 2
";

    #[test]
    fn parses_the_mixed_example() {
        let c = ScenarioConfig::parse(MIXED).unwrap();
        assert_eq!(c.groups.len(), 3);
        assert_eq!(c.num_envs(), 8);
        assert_eq!(c.first_env(0), 0);
        assert_eq!(c.first_env(1), 4);
        assert_eq!(c.first_env(2), 6);
        let g = &c.groups[0];
        assert_eq!(g.task_id, "CartPole-v1");
        assert_eq!(g.count, 4);
        assert_eq!(g.seed, Some(11));
        assert_eq!(g.wrap.time_limit, Some(200));
        assert!(g.wrap.reward_clip);
        assert_eq!(g.params, vec![("gravity".to_string(), 9.8)]);
        assert_eq!(g.jitter, vec![("length".to_string(), 0.4, 0.6)]);
        assert_eq!(c.groups[2].params, vec![]);
    }

    #[test]
    fn round_trips_exactly() {
        let c = ScenarioConfig::parse(MIXED).unwrap();
        let text = c.to_text();
        let c2 = ScenarioConfig::parse(&text).unwrap();
        assert_eq!(c, c2);
        // Canonical text is a fixed point.
        assert_eq!(c2.to_text(), text);
    }

    #[test]
    fn group_seed_is_replayable_and_decorrelated() {
        let c = ScenarioConfig::parse(MIXED).unwrap();
        // Explicit seed wins regardless of the pool seed.
        assert_eq!(c.group_seed(0, 1), 11);
        assert_eq!(c.group_seed(0, 999), 11);
        // Derived seeds are a pure function of (pool seed, index)…
        assert_eq!(c.group_seed(1, 5), c.group_seed(1, 5));
        // …and differ across indices and pool seeds.
        assert_ne!(c.group_seed(1, 5), c.group_seed(2, 5));
        assert_ne!(c.group_seed(1, 5), c.group_seed(1, 6));
    }

    #[test]
    fn rejects_malformed_input() {
        let cases = [
            ("task = X\n", "outside any"),                       // key before [group]
            ("[group]\ncount = 1\n", "missing `task`"),          // no task
            ("[group]\ntask = CartPole-v1\n", "must be > 0"),    // no count
            ("[group]\ntask = Doom-v0\ncount = 1\n", "unknown environment"),
            ("[group]\ntask = CartPole-v1\ncount = 1\nbogus = 1\n", "unknown key"),
            ("[section]\n", "unknown section"),
            ("[group]\ntask = CartPole-v1\ncount = x\n", "bad count"),
            ("[group]\ntask = CartPole-v1\ncount = 1\njitter.length = 1\n", "lo hi"),
            (
                "[group]\ntask = CartPole-v1\ncount = 1\njitter.length = 2.0 1.0\n",
                "lo <= hi",
            ),
            (
                "[group]\ntask = CartPole-v1\ncount = 1\nparam.warp = 1.0\n",
                "no overridable parameter",
            ),
            (
                "[group]\ntask = Acrobot-v1\ncount = 1\nparam.gravity = 9.8\n",
                "no overridable parameter",
            ),
            (
                "[group]\ntask = CartPole-v1\ncount = 1\nparam.gravity = 9.8\n\
                 jitter.gravity = 9.0 10.0\n",
                "more than once",
            ),
        ];
        for (text, needle) in cases {
            let err = ScenarioConfig::parse(text).unwrap_err().to_string();
            assert!(err.contains(needle), "{text:?} -> {err}");
        }
        assert!(ScenarioConfig::parse("# only comments\n").is_err());
    }

    #[test]
    fn float_round_trip_is_bitwise() {
        let text = "[group]\ntask = CartPole-v1\ncount = 1\nparam.gravity = 9.81\n\
                    jitter.length = 0.3333333 0.6666667\n";
        let c = ScenarioConfig::parse(text).unwrap();
        let c2 = ScenarioConfig::parse(&c.to_text()).unwrap();
        let (p, p2) = (&c.groups[0].params[0], &c2.groups[0].params[0]);
        assert_eq!(p.1.to_bits(), p2.1.to_bits());
        let (j, j2) = (&c.groups[0].jitter[0], &c2.groups[0].jitter[0]);
        assert_eq!(j.1.to_bits(), j2.1.to_bits());
        assert_eq!(j.2.to_bits(), j2.2.to_bits());
    }
}
