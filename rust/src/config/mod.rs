//! Configuration system: typed structs populated from `key = value` files
//! (a minimal TOML-flat subset) and `--key value` CLI overrides, in that
//! precedence order (CLI wins). No serde in the vendored set, so parsing
//! is explicit and validated.

pub mod scenario;
pub mod serve;
mod train;
pub use scenario::{ScenarioConfig, ScenarioGroup};
pub use serve::ServeConfig;
pub use train::{BackendKind, ExecutorKind, Precision, TrainConfig};

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Flat key→value store loaded from a config file.
#[derive(Debug, Default, Clone)]
pub struct KvFile {
    pub values: BTreeMap<String, String>,
}

impl KvFile {
    /// Parse `key = value` lines; `#` starts a comment; blank lines ignored.
    pub fn parse(text: &str) -> Result<KvFile> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected `key = value`, got {raw:?}", lineno + 1))
            })?;
            values.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(KvFile { values })
    }

    /// Load from a path.
    pub fn load(path: &str) -> Result<KvFile> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Typed getter with default.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad value for {key}: {v:?}"))),
        }
    }

    /// String getter with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let f = KvFile::parse("a = 1\n# comment\nname = \"pong\"\n\nlr = 2.5e-4 # inline").unwrap();
        assert_eq!(f.parse_or("a", 0usize).unwrap(), 1);
        assert_eq!(f.get("name", ""), "pong");
        assert!((f.parse_or("lr", 0.0f64).unwrap() - 2.5e-4).abs() < 1e-12);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(KvFile::parse("just words").is_err());
    }

    #[test]
    fn defaults_apply() {
        let f = KvFile::parse("").unwrap();
        assert_eq!(f.parse_or("missing", 7u32).unwrap(), 7);
    }

    #[test]
    fn bad_typed_value_errors() {
        let f = KvFile::parse("x = notanumber").unwrap();
        assert!(f.parse_or("x", 0usize).is_err());
    }
}
