//! PPO training configuration, mirroring the paper's Appendix F tables
//! (Table 3: CleanRL Atari PPO; Table 5: CleanRL MuJoCo PPO with N=64).

use super::KvFile;
use crate::cli::Args;
use crate::{Error, Result};

/// Which executor drives the vectorized environments (paper Fig. 4 axes,
/// plus the `*-vec` variants added by the chunked/SoA execution layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Single-thread sequential stepping (paper "For-loop").
    ForLoop,
    /// For-loop over a struct-of-arrays batch kernel (no pool).
    ForLoopVec,
    /// One OS process per env, per-step barrier (paper "Subprocess").
    Subprocess,
    /// EnvPool in synchronous mode (`batch_size == num_envs`).
    EnvPoolSync,
    /// EnvPool sync with `ExecMode::Vectorized` chunk workers.
    EnvPoolSyncVec,
    /// EnvPool in asynchronous mode (`batch_size < num_envs`).
    EnvPoolAsync,
    /// EnvPool async with `ExecMode::Vectorized` chunk workers.
    EnvPoolAsyncVec,
    /// NUMA-sharded async EnvPool (one pool per logical node).
    EnvPoolNumaAsync,
    /// NUMA-sharded async EnvPool with `ExecMode::Vectorized` shards.
    EnvPoolNumaAsyncVec,
    /// Sample-Factory-style double-buffered async workers.
    SampleFactory,
    /// Sample-Factory workers stepping SoA batch kernels.
    SampleFactoryVec,
}

impl ExecutorKind {
    /// Pool execution mode implied by this executor kind — the single
    /// source of truth for which kinds select the chunked SoA backend.
    pub fn pool_exec_mode(self) -> crate::pool::ExecMode {
        match self {
            ExecutorKind::EnvPoolSyncVec
            | ExecutorKind::EnvPoolAsyncVec
            | ExecutorKind::EnvPoolNumaAsyncVec => crate::pool::ExecMode::Vectorized,
            _ => crate::pool::ExecMode::Scalar,
        }
    }
}

impl std::str::FromStr for ExecutorKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "forloop" | "for-loop" => ExecutorKind::ForLoop,
            "forloop-vec" | "for-loop-vec" => ExecutorKind::ForLoopVec,
            "subprocess" => ExecutorKind::Subprocess,
            "envpool" | "envpool-sync" | "sync" => ExecutorKind::EnvPoolSync,
            "envpool-sync-vec" | "sync-vec" => ExecutorKind::EnvPoolSyncVec,
            "envpool-async" | "async" => ExecutorKind::EnvPoolAsync,
            "envpool-async-vec" | "async-vec" => ExecutorKind::EnvPoolAsyncVec,
            "envpool-numa-async" | "numa-async" => ExecutorKind::EnvPoolNumaAsync,
            "envpool-numa-async-vec" | "numa-async-vec" => ExecutorKind::EnvPoolNumaAsyncVec,
            "sample-factory" | "sf" => ExecutorKind::SampleFactory,
            "sample-factory-vec" | "sf-vec" => ExecutorKind::SampleFactoryVec,
            other => return Err(Error::Config(format!("unknown executor {other:?}"))),
        })
    }
}

impl std::fmt::Display for ExecutorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ExecutorKind::ForLoop => "forloop",
            ExecutorKind::ForLoopVec => "forloop-vec",
            ExecutorKind::Subprocess => "subprocess",
            ExecutorKind::EnvPoolSync => "envpool-sync",
            ExecutorKind::EnvPoolSyncVec => "envpool-sync-vec",
            ExecutorKind::EnvPoolAsync => "envpool-async",
            ExecutorKind::EnvPoolAsyncVec => "envpool-async-vec",
            ExecutorKind::EnvPoolNumaAsync => "envpool-numa-async",
            ExecutorKind::EnvPoolNumaAsyncVec => "envpool-numa-async-vec",
            ExecutorKind::SampleFactory => "sample-factory",
            ExecutorKind::SampleFactoryVec => "sample-factory-vec",
        };
        f.write_str(s)
    }
}

/// PPO hyperparameters + system knobs. Defaults follow the original PPO
/// paper / CleanRL (paper Appendix F Table 3).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Environment task id, e.g. "CartPole-v1", "Pong-v5", "Ant-v4".
    pub env_id: String,
    /// Executor paradigm under test.
    pub executor: ExecutorKind,
    /// Number of parallel environments N.
    pub num_envs: usize,
    /// EnvPool batch size M (async mode); defaults to N (sync).
    pub batch_size: usize,
    /// Worker threads for EnvPool / Sample-Factory.
    pub num_threads: usize,
    /// Total environment steps to train for.
    pub total_steps: u64,
    /// Rollout length per environment per iteration.
    pub num_steps: usize,
    /// Discount factor gamma.
    pub gamma: f32,
    /// GAE lambda.
    pub gae_lambda: f32,
    /// Number of minibatches per epoch.
    pub num_minibatches: usize,
    /// PPO update epochs per rollout.
    pub update_epochs: usize,
    /// Learning rate (annealed linearly to 0 when `anneal_lr`).
    pub learning_rate: f32,
    /// Whether to anneal the lr to zero over training.
    pub anneal_lr: bool,
    /// PPO clip coefficient epsilon.
    pub clip_coef: f32,
    /// Value loss coefficient c1.
    pub vf_coef: f32,
    /// Entropy coefficient c2.
    pub ent_coef: f32,
    /// Global grad-norm threshold omega.
    pub max_grad_norm: f32,
    /// RNG seed.
    pub seed: u64,
    /// Normalize observations with a running estimate (MuJoCo-style).
    /// Honored by the EnvPool executors (engine-side wrapper stack,
    /// identical in both exec modes); the bare baseline executors do
    /// not wrap.
    pub normalize_obs: bool,
    /// Directory containing AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            env_id: "CartPole-v1".into(),
            executor: ExecutorKind::EnvPoolSync,
            num_envs: 8,
            batch_size: 8,
            num_threads: 4,
            total_steps: 100_000,
            num_steps: 128,
            gamma: 0.99,
            gae_lambda: 0.95,
            num_minibatches: 4,
            update_epochs: 4,
            learning_rate: 2.5e-4,
            anneal_lr: true,
            clip_coef: 0.1,
            vf_coef: 0.5,
            ent_coef: 0.01,
            max_grad_norm: 0.5,
            seed: 1,
            normalize_obs: false,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl TrainConfig {
    /// Apply `key = value` file values.
    pub fn apply_file(&mut self, f: &KvFile) -> Result<()> {
        self.env_id = f.get("env_id", &self.env_id);
        if let Some(e) = f.values.get("executor") {
            self.executor = e.parse()?;
        }
        self.num_envs = f.parse_or("num_envs", self.num_envs)?;
        self.batch_size = f.parse_or("batch_size", self.num_envs)?;
        self.num_threads = f.parse_or("num_threads", self.num_threads)?;
        self.total_steps = f.parse_or("total_steps", self.total_steps)?;
        self.num_steps = f.parse_or("num_steps", self.num_steps)?;
        self.gamma = f.parse_or("gamma", self.gamma)?;
        self.gae_lambda = f.parse_or("gae_lambda", self.gae_lambda)?;
        self.num_minibatches = f.parse_or("num_minibatches", self.num_minibatches)?;
        self.update_epochs = f.parse_or("update_epochs", self.update_epochs)?;
        self.learning_rate = f.parse_or("learning_rate", self.learning_rate)?;
        self.anneal_lr = f.parse_or("anneal_lr", self.anneal_lr)?;
        self.clip_coef = f.parse_or("clip_coef", self.clip_coef)?;
        self.vf_coef = f.parse_or("vf_coef", self.vf_coef)?;
        self.ent_coef = f.parse_or("ent_coef", self.ent_coef)?;
        self.max_grad_norm = f.parse_or("max_grad_norm", self.max_grad_norm)?;
        self.seed = f.parse_or("seed", self.seed)?;
        self.normalize_obs = f.parse_or("normalize_obs", self.normalize_obs)?;
        self.artifacts_dir = f.get("artifacts_dir", &self.artifacts_dir);
        Ok(())
    }

    /// Apply CLI overrides (these win over file values).
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(e) = a.opt("env") {
            self.env_id = e.to_string();
        }
        if let Some(e) = a.opt("executor") {
            self.executor = e.parse()?;
        }
        self.num_envs = a.parse_or("num-envs", self.num_envs);
        self.batch_size = a.parse_or("batch-size", self.num_envs);
        self.num_threads = a.parse_or("num-threads", self.num_threads);
        self.total_steps = a.parse_or("total-steps", self.total_steps);
        self.num_steps = a.parse_or("num-steps", self.num_steps);
        self.learning_rate = a.parse_or("lr", self.learning_rate);
        self.update_epochs = a.parse_or("update-epochs", self.update_epochs);
        self.num_minibatches = a.parse_or("minibatches", self.num_minibatches);
        self.seed = a.parse_or("seed", self.seed);
        if let Some(d) = a.opt("artifacts") {
            self.artifacts_dir = d.to_string();
        }
        self.validate()
    }

    /// Check invariants the pool/trainer rely on.
    pub fn validate(&self) -> Result<()> {
        if self.num_envs == 0 {
            return Err(Error::Config("num_envs must be > 0".into()));
        }
        if self.batch_size == 0 || self.batch_size > self.num_envs {
            return Err(Error::Config(format!(
                "batch_size must be in [1, num_envs]; got {} vs {}",
                self.batch_size, self.num_envs
            )));
        }
        let rollout = self.num_envs * self.num_steps;
        if rollout % self.num_minibatches != 0 {
            return Err(Error::Config(format!(
                "rollout size {rollout} not divisible by num_minibatches {}",
                self.num_minibatches
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn file_then_cli_precedence() {
        let mut c = TrainConfig::default();
        let f = KvFile::parse("num_envs = 16\nlearning_rate = 1e-3").unwrap();
        c.apply_file(&f).unwrap();
        assert_eq!(c.num_envs, 16);
        let a = Args::parse(["--num-envs".into(), "32".into()]);
        c.apply_args(&a).unwrap();
        assert_eq!(c.num_envs, 32);
        assert!((c.learning_rate - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn batch_size_bounds_enforced() {
        let mut c = TrainConfig::default();
        c.num_envs = 4;
        c.batch_size = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn executor_parse_roundtrip() {
        for s in [
            "forloop",
            "forloop-vec",
            "subprocess",
            "envpool-sync",
            "envpool-sync-vec",
            "envpool-async",
            "envpool-async-vec",
            "envpool-numa-async",
            "envpool-numa-async-vec",
            "sample-factory",
            "sample-factory-vec",
        ] {
            let k: ExecutorKind = s.parse().unwrap();
            assert_eq!(k.to_string(), s);
        }
        assert!("bogus".parse::<ExecutorKind>().is_err());
    }

    #[test]
    fn vec_kinds_imply_vectorized_pool_mode() {
        use crate::pool::ExecMode;
        assert_eq!(ExecutorKind::EnvPoolSyncVec.pool_exec_mode(), ExecMode::Vectorized);
        assert_eq!(ExecutorKind::EnvPoolAsyncVec.pool_exec_mode(), ExecMode::Vectorized);
        assert_eq!(ExecutorKind::EnvPoolNumaAsyncVec.pool_exec_mode(), ExecMode::Vectorized);
        assert_eq!(ExecutorKind::EnvPoolSync.pool_exec_mode(), ExecMode::Scalar);
        assert_eq!(ExecutorKind::EnvPoolNumaAsync.pool_exec_mode(), ExecMode::Scalar);
        // non-pool executors run their own engines; mode is Scalar
        assert_eq!(ExecutorKind::ForLoopVec.pool_exec_mode(), ExecMode::Scalar);
    }
}
