//! PPO training configuration, mirroring the paper's Appendix F tables
//! (Table 3: CleanRL Atari PPO; Table 5: CleanRL MuJoCo PPO with N=64).

use super::KvFile;
use crate::cli::Args;
use crate::{Error, Result};

/// Which executor drives the vectorized environments (paper Fig. 4 axes,
/// plus the `*-vec` variants added by the chunked/SoA execution layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Single-thread sequential stepping (paper "For-loop").
    ForLoop,
    /// For-loop over a struct-of-arrays batch kernel (no pool).
    ForLoopVec,
    /// One OS process per env, per-step barrier (paper "Subprocess").
    Subprocess,
    /// EnvPool in synchronous mode (`batch_size == num_envs`).
    EnvPoolSync,
    /// EnvPool sync with `ExecMode::Vectorized` chunk workers.
    EnvPoolSyncVec,
    /// EnvPool in asynchronous mode (`batch_size < num_envs`).
    EnvPoolAsync,
    /// EnvPool async with `ExecMode::Vectorized` chunk workers.
    EnvPoolAsyncVec,
    /// NUMA-sharded async EnvPool (one pool per logical node).
    EnvPoolNumaAsync,
    /// NUMA-sharded async EnvPool with `ExecMode::Vectorized` shards.
    EnvPoolNumaAsyncVec,
    /// Sample-Factory-style double-buffered async workers.
    SampleFactory,
    /// Sample-Factory workers stepping SoA batch kernels.
    SampleFactoryVec,
}

impl ExecutorKind {
    /// Pool execution mode implied by this executor kind — the single
    /// source of truth for which kinds select the chunked SoA backend.
    pub fn pool_exec_mode(self) -> crate::pool::ExecMode {
        match self {
            ExecutorKind::EnvPoolSyncVec
            | ExecutorKind::EnvPoolAsyncVec
            | ExecutorKind::EnvPoolNumaAsyncVec => crate::pool::ExecMode::Vectorized,
            _ => crate::pool::ExecMode::Scalar,
        }
    }
}

impl std::str::FromStr for ExecutorKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "forloop" | "for-loop" => ExecutorKind::ForLoop,
            "forloop-vec" | "for-loop-vec" => ExecutorKind::ForLoopVec,
            "subprocess" => ExecutorKind::Subprocess,
            "envpool" | "envpool-sync" | "sync" => ExecutorKind::EnvPoolSync,
            "envpool-sync-vec" | "sync-vec" => ExecutorKind::EnvPoolSyncVec,
            "envpool-async" | "async" => ExecutorKind::EnvPoolAsync,
            "envpool-async-vec" | "async-vec" => ExecutorKind::EnvPoolAsyncVec,
            "envpool-numa-async" | "numa-async" => ExecutorKind::EnvPoolNumaAsync,
            "envpool-numa-async-vec" | "numa-async-vec" => ExecutorKind::EnvPoolNumaAsyncVec,
            "sample-factory" | "sf" => ExecutorKind::SampleFactory,
            "sample-factory-vec" | "sf-vec" => ExecutorKind::SampleFactoryVec,
            other => return Err(Error::Config(format!("unknown executor {other:?}"))),
        })
    }
}

impl std::fmt::Display for ExecutorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ExecutorKind::ForLoop => "forloop",
            ExecutorKind::ForLoopVec => "forloop-vec",
            ExecutorKind::Subprocess => "subprocess",
            ExecutorKind::EnvPoolSync => "envpool-sync",
            ExecutorKind::EnvPoolSyncVec => "envpool-sync-vec",
            ExecutorKind::EnvPoolAsync => "envpool-async",
            ExecutorKind::EnvPoolAsyncVec => "envpool-async-vec",
            ExecutorKind::EnvPoolNumaAsync => "envpool-numa-async",
            ExecutorKind::EnvPoolNumaAsyncVec => "envpool-numa-async-vec",
            ExecutorKind::SampleFactory => "sample-factory",
            ExecutorKind::SampleFactoryVec => "sample-factory-vec",
        };
        f.write_str(s)
    }
}

/// Which compute backend executes the policy forward, PPO update, and
/// GAE (see [`crate::runtime::backend`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Try PJRT artifacts; fall back to [`BackendKind::Native`] when the
    /// compute tier is unavailable (vendored `xla` stub or no
    /// `make artifacts`). The default, so `envpool train` always runs.
    #[default]
    Auto,
    /// AOT HLO artifacts executed through PJRT; errors when unavailable.
    Pjrt,
    /// Pure-Rust MLP/Adam/PPO backend — crate-only, deterministic.
    Native,
}

impl std::str::FromStr for BackendKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "pjrt" | "xla" => BackendKind::Pjrt,
            "native" | "rust" => BackendKind::Native,
            other => {
                return Err(Error::Config(format!(
                    "unknown backend {other:?} (expected auto|pjrt|native)"
                )))
            }
        })
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        })
    }
}

/// Arithmetic precision of the **native** compute backend
/// (`--precision {f64,f32}`). `f64` is the scalar reference path
/// (finite-difference-provable, the default); `f32` is the SIMD GEMV
/// fast path — f32 compute weights mirrored from f64 master weights,
/// guarded by the f32-vs-f64 agreement and FD tests in
/// `runtime::native`. The PJRT backend is f32 by construction and
/// ignores this knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// f64 scalar reference loops (default).
    #[default]
    F64,
    /// f32 compute + SIMD lane passes, f64 master weights.
    F32,
}

impl std::str::FromStr for Precision {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "f64" | "double" => Precision::F64,
            "f32" | "single" => Precision::F32,
            other => {
                return Err(Error::Config(format!(
                    "unknown precision {other:?} (expected f64|f32)"
                )))
            }
        })
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        })
    }
}

/// PPO hyperparameters + system knobs. Defaults follow the original PPO
/// paper / CleanRL (paper Appendix F Table 3).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Environment task id, e.g. "CartPole-v1", "Pong-v5", "Ant-v4".
    pub env_id: String,
    /// Executor paradigm under test.
    pub executor: ExecutorKind,
    /// Number of parallel environments N.
    pub num_envs: usize,
    /// EnvPool batch size M (async mode); defaults to N (sync).
    pub batch_size: usize,
    /// Worker threads for EnvPool / Sample-Factory.
    pub num_threads: usize,
    /// Total environment steps to train for.
    pub total_steps: u64,
    /// Rollout length per environment per iteration.
    pub num_steps: usize,
    /// Discount factor gamma.
    pub gamma: f32,
    /// GAE lambda.
    pub gae_lambda: f32,
    /// Number of minibatches per epoch.
    pub num_minibatches: usize,
    /// PPO update epochs per rollout.
    pub update_epochs: usize,
    /// Learning rate (annealed linearly to 0 when `anneal_lr`).
    pub learning_rate: f32,
    /// Whether to anneal the lr to zero over training.
    pub anneal_lr: bool,
    /// PPO clip coefficient epsilon.
    pub clip_coef: f32,
    /// Value loss coefficient c1.
    pub vf_coef: f32,
    /// Entropy coefficient c2.
    pub ent_coef: f32,
    /// Global grad-norm threshold omega.
    pub max_grad_norm: f32,
    /// RNG seed.
    pub seed: u64,
    /// Normalize observations with a running estimate (MuJoCo-style).
    /// Honored by the EnvPool executors (engine-side wrapper stack,
    /// identical in both exec modes); the bare baseline executors do
    /// not wrap.
    pub normalize_obs: bool,
    /// Pool one normalization statistic across all lanes of each
    /// vectorized **chunk** (gym `VecNormalize`-style) instead of
    /// per-lane stats. Requires the `envpool-sync-vec` executor —
    /// scalar execution has no batch to share a statistic over.
    ///
    /// Caveat: the statistic's scope is the chunk, and chunking follows
    /// `K = ceil(num_envs / num_threads)`, so unlike every other knob
    /// the *numerics* of a shared-stats run depend on `num_threads`
    /// (`num_threads = 1` pools over all envs). Runs are deterministic
    /// for a fixed thread count; use per-lane `normalize_obs` when
    /// thread-count invariance matters.
    pub normalize_obs_shared: bool,
    /// Compute backend for policy/update/GAE (`--backend`).
    pub backend: BackendKind,
    /// Native-backend arithmetic (`--precision {f64,f32}`; see
    /// [`Precision`]).
    pub precision: Precision,
    /// SIMD lane width for the SoA env kernels (`--lane-width
    /// {1,4,8,auto}`; every width is bitwise identical — see
    /// [`crate::simd::LanePass`]). Applied by the vectorized pool
    /// engine and the vectorized baseline executors.
    pub lane_pass: crate::simd::LanePass,
    /// Greedy-evaluation episodes to run after training
    /// (`--eval-episodes`; 0 = skip). Runs on whichever compute
    /// backend trained, PJRT or native, against **bare** envs —
    /// rejected in combination with observation normalization (the
    /// policy would see out-of-distribution inputs).
    pub eval_episodes: usize,
    /// Stop training once the trailing mean return reaches this value
    /// (`--target-return`); `None` runs the full step budget.
    pub target_return: Option<f32>,
    /// Run the decoupled actor–learner loop (`--async-train`): the async
    /// pool keeps stepping envs into a double-buffered trajectory store
    /// while the learner updates on the previous rollout. Requires the
    /// `envpool-async[-vec]` executors (the loop *is* the async
    /// protocol); the synchronous trainer ignores it.
    pub async_train: bool,
    /// Bound on how many minibatch updates behind the learner the
    /// behaviour policy may be for transitions collected *during* the
    /// update phase (`--max-policy-lag`; async-train only). `Some(0)`
    /// collects only between rounds; `None` (default) drains whenever
    /// batches are ready. Transitions collected between rounds can
    /// still lag up to one round's worth of updates — that bound is
    /// structural to double-buffering and reported in the summary.
    pub max_policy_lag: Option<u32>,
    /// Directory containing AOT artifacts (PJRT backend only).
    pub artifacts_dir: String,
    /// Path to a heterogeneous scenario file (`--scenario <file>`;
    /// see [`crate::config::ScenarioConfig`]). When set, the pool runs
    /// the scenario's mixed-task lane groups instead of `env_id`;
    /// requires an `envpool-sync[-vec]` executor, a uniform group spec
    /// (the trainer rejects ragged mixes), `num_envs` equal to the
    /// scenario's total lane count, and no pool-level normalization
    /// flags (wrappers live on the groups).
    pub scenario: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            env_id: "CartPole-v1".into(),
            executor: ExecutorKind::EnvPoolSync,
            num_envs: 8,
            batch_size: 8,
            num_threads: 4,
            total_steps: 100_000,
            num_steps: 128,
            gamma: 0.99,
            gae_lambda: 0.95,
            num_minibatches: 4,
            update_epochs: 4,
            learning_rate: 2.5e-4,
            anneal_lr: true,
            clip_coef: 0.1,
            vf_coef: 0.5,
            ent_coef: 0.01,
            max_grad_norm: 0.5,
            seed: 1,
            normalize_obs: false,
            normalize_obs_shared: false,
            backend: BackendKind::Auto,
            precision: Precision::default(),
            lane_pass: crate::simd::LanePass::Auto,
            eval_episodes: 0,
            target_return: None,
            async_train: false,
            max_policy_lag: None,
            artifacts_dir: "artifacts".into(),
            scenario: None,
        }
    }
}

impl TrainConfig {
    /// Apply `key = value` file values.
    pub fn apply_file(&mut self, f: &KvFile) -> Result<()> {
        self.env_id = f.get("env_id", &self.env_id);
        if let Some(e) = f.values.get("executor") {
            self.executor = e.parse()?;
        }
        self.num_envs = f.parse_or("num_envs", self.num_envs)?;
        self.batch_size = f.parse_or("batch_size", self.num_envs)?;
        self.num_threads = f.parse_or("num_threads", self.num_threads)?;
        self.total_steps = f.parse_or("total_steps", self.total_steps)?;
        self.num_steps = f.parse_or("num_steps", self.num_steps)?;
        self.gamma = f.parse_or("gamma", self.gamma)?;
        self.gae_lambda = f.parse_or("gae_lambda", self.gae_lambda)?;
        self.num_minibatches = f.parse_or("num_minibatches", self.num_minibatches)?;
        self.update_epochs = f.parse_or("update_epochs", self.update_epochs)?;
        self.learning_rate = f.parse_or("learning_rate", self.learning_rate)?;
        self.anneal_lr = f.parse_or("anneal_lr", self.anneal_lr)?;
        self.clip_coef = f.parse_or("clip_coef", self.clip_coef)?;
        self.vf_coef = f.parse_or("vf_coef", self.vf_coef)?;
        self.ent_coef = f.parse_or("ent_coef", self.ent_coef)?;
        self.max_grad_norm = f.parse_or("max_grad_norm", self.max_grad_norm)?;
        self.seed = f.parse_or("seed", self.seed)?;
        self.normalize_obs = f.parse_or("normalize_obs", self.normalize_obs)?;
        self.normalize_obs_shared =
            f.parse_or("normalize_obs_shared", self.normalize_obs_shared)?;
        if let Some(b) = f.values.get("backend") {
            self.backend = b.parse()?;
        }
        if let Some(pr) = f.values.get("precision") {
            self.precision = pr.parse()?;
        }
        if let Some(lw) = f.values.get("lane_width") {
            self.lane_pass = lw.parse()?;
        }
        self.eval_episodes = f.parse_or("eval_episodes", self.eval_episodes)?;
        if let Some(t) = f.values.get("target_return") {
            self.target_return = Some(
                t.parse()
                    .map_err(|_| Error::Config(format!("bad value for target_return: {t:?}")))?,
            );
        }
        self.async_train = f.parse_or("async_train", self.async_train)?;
        if let Some(l) = f.values.get("max_policy_lag") {
            self.max_policy_lag = Some(
                l.parse()
                    .map_err(|_| Error::Config(format!("bad value for max_policy_lag: {l:?}")))?,
            );
        }
        self.artifacts_dir = f.get("artifacts_dir", &self.artifacts_dir);
        if let Some(s) = f.values.get("scenario") {
            self.scenario = Some(s.clone());
        }
        Ok(())
    }

    /// Apply CLI overrides (these win over file values).
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(e) = a.opt("env") {
            self.env_id = e.to_string();
        }
        if let Some(e) = a.opt("executor") {
            self.executor = e.parse()?;
        }
        self.num_envs = a.parse_or("num-envs", self.num_envs);
        // `--num-envs` without `--batch-size` implies sync (M = N); when
        // neither flag is given, a file-configured batch_size survives.
        if a.opt("num-envs").is_some() || a.opt("batch-size").is_some() {
            self.batch_size = a.parse_or("batch-size", self.num_envs);
        }
        self.num_threads = a.parse_or("num-threads", self.num_threads);
        self.total_steps = a.parse_or("total-steps", self.total_steps);
        self.num_steps = a.parse_or("num-steps", self.num_steps);
        self.learning_rate = a.parse_or("lr", self.learning_rate);
        self.clip_coef = a.parse_or("clip-coef", self.clip_coef);
        self.update_epochs = a.parse_or("update-epochs", self.update_epochs);
        self.num_minibatches = a.parse_or("minibatches", self.num_minibatches);
        self.seed = a.parse_or("seed", self.seed);
        if let Some(b) = a.opt("backend") {
            self.backend = b.parse()?;
        }
        if let Some(pr) = a.opt("precision") {
            self.precision = pr.parse()?;
        }
        if let Some(lw) = a.opt("lane-width") {
            self.lane_pass = lw.parse()?;
        }
        self.eval_episodes = a.parse_or("eval-episodes", self.eval_episodes);
        if a.flag("normalize-obs") {
            self.normalize_obs = true;
        }
        if a.flag("normalize-obs-shared") {
            self.normalize_obs_shared = true;
        }
        if let Some(t) = a.parse_opt::<f32>("target-return") {
            self.target_return = Some(t);
        }
        if a.flag("async-train") {
            self.async_train = true;
        }
        if let Some(l) = a.parse_opt::<u32>("max-policy-lag") {
            self.max_policy_lag = Some(l);
        }
        if let Some(d) = a.opt("artifacts") {
            self.artifacts_dir = d.to_string();
        }
        if let Some(s) = a.opt("scenario") {
            self.scenario = Some(s.to_string());
        }
        self.validate()
    }

    /// The engine-side wrapper stack this config asks the pool for.
    pub fn wrap_config(&self) -> crate::envs::registry::WrapConfig {
        crate::envs::registry::WrapConfig {
            normalize_obs: self.normalize_obs,
            normalize_obs_shared: self.normalize_obs_shared,
            ..crate::envs::registry::WrapConfig::none()
        }
    }

    /// Check invariants the pool/trainer rely on.
    pub fn validate(&self) -> Result<()> {
        if self.num_envs == 0 {
            return Err(Error::Config("num_envs must be > 0".into()));
        }
        if self.normalize_obs && self.normalize_obs_shared {
            return Err(Error::Config(
                "normalize_obs and normalize_obs_shared are mutually exclusive \
                 (per-lane vs pooled statistics)"
                    .into(),
            ));
        }
        if self.eval_episodes > 0 && (self.normalize_obs || self.normalize_obs_shared) {
            return Err(Error::Config(
                "eval_episodes runs greedy evaluation on bare (unwrapped) environments, \
                 so a policy trained on normalized observations would be evaluated \
                 out-of-distribution; drop --eval-episodes or the normalization flag"
                    .into(),
            ));
        }
        if self.num_steps == 0 {
            return Err(Error::Config("num_steps must be > 0".into()));
        }
        if self.num_minibatches == 0 {
            return Err(Error::Config("num_minibatches must be > 0".into()));
        }
        if self.batch_size == 0 || self.batch_size > self.num_envs {
            return Err(Error::Config(format!(
                "batch_size must be in [1, num_envs]; got {} vs {}",
                self.batch_size, self.num_envs
            )));
        }
        let rollout = self.num_envs * self.num_steps;
        if rollout % self.num_minibatches != 0 {
            return Err(Error::Config(format!(
                "rollout size {rollout} not divisible by num_minibatches {}",
                self.num_minibatches
            )));
        }
        if self.async_train
            && !matches!(
                self.executor,
                ExecutorKind::EnvPoolAsync | ExecutorKind::EnvPoolAsyncVec
            )
        {
            return Err(Error::Config(format!(
                "--async-train runs the decoupled actor–learner loop over the async pool \
                 protocol; executor {} cannot drive it — use envpool-async or \
                 envpool-async-vec",
                self.executor
            )));
        }
        if self.max_policy_lag.is_some() && !self.async_train {
            return Err(Error::Config(
                "--max-policy-lag bounds the decoupled loop's sampling staleness; it \
                 requires --async-train"
                    .into(),
            ));
        }
        if self.scenario.is_some() {
            if !matches!(
                self.executor,
                ExecutorKind::EnvPoolSync | ExecutorKind::EnvPoolSyncVec
            ) {
                return Err(Error::Config(format!(
                    "--scenario runs a heterogeneous pool behind the synchronous EnvPool \
                     facade; executor {} cannot drive it — use envpool-sync or \
                     envpool-sync-vec",
                    self.executor
                )));
            }
            if self.normalize_obs || self.normalize_obs_shared {
                return Err(Error::Config(
                    "--scenario pools carry wrappers per group (normalize_obs in the \
                     scenario file); the pool-level normalization flags cannot combine \
                     with a scenario"
                        .into(),
                ));
            }
            if self.eval_episodes > 0 {
                return Err(Error::Config(
                    "--eval-episodes evaluates against bare `env_id` environments, \
                     which a scenario ignores (and whose jittered physics it could \
                     not reproduce); drop one of the flags"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn file_then_cli_precedence() {
        let mut c = TrainConfig::default();
        let f = KvFile::parse("num_envs = 16\nlearning_rate = 1e-3").unwrap();
        c.apply_file(&f).unwrap();
        assert_eq!(c.num_envs, 16);
        let a = Args::parse(["--num-envs".into(), "32".into()]);
        c.apply_args(&a).unwrap();
        assert_eq!(c.num_envs, 32);
        assert!((c.learning_rate - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn batch_size_bounds_enforced() {
        let mut c = TrainConfig::default();
        c.num_envs = 4;
        c.batch_size = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn cli_without_batch_flags_preserves_file_configured_batch_size() {
        // Regression: apply_args used to reset batch_size to num_envs
        // whenever --batch-size was absent, silently discarding a
        // file-configured async batch.
        let mut c = TrainConfig::default();
        let f = KvFile::parse("num_envs = 16\nbatch_size = 8").unwrap();
        c.apply_file(&f).unwrap();
        c.apply_args(&Args::parse(["--seed".into(), "2".into()])).unwrap();
        assert_eq!((c.num_envs, c.batch_size), (16, 8), "file batch_size must survive");
        // --num-envs alone still implies sync
        c.apply_args(&Args::parse(["--num-envs".into(), "32".into()])).unwrap();
        assert_eq!((c.num_envs, c.batch_size), (32, 32));
    }

    #[test]
    fn zero_steps_and_minibatches_are_config_errors_not_panics() {
        let c = TrainConfig { num_steps: 0, ..TrainConfig::default() };
        assert!(matches!(c.validate(), Err(Error::Config(_))));
        let c = TrainConfig { num_minibatches: 0, ..TrainConfig::default() };
        assert!(matches!(c.validate(), Err(Error::Config(_))));
    }

    #[test]
    fn executor_parse_roundtrip() {
        for s in [
            "forloop",
            "forloop-vec",
            "subprocess",
            "envpool-sync",
            "envpool-sync-vec",
            "envpool-async",
            "envpool-async-vec",
            "envpool-numa-async",
            "envpool-numa-async-vec",
            "sample-factory",
            "sample-factory-vec",
        ] {
            let k: ExecutorKind = s.parse().unwrap();
            assert_eq!(k.to_string(), s);
        }
        assert!("bogus".parse::<ExecutorKind>().is_err());
    }

    #[test]
    fn backend_parse_roundtrip_and_flags() {
        for s in ["auto", "pjrt", "native"] {
            let b: BackendKind = s.parse().unwrap();
            assert_eq!(b.to_string(), s);
        }
        assert!("tpu".parse::<BackendKind>().is_err());
        assert_eq!(TrainConfig::default().backend, BackendKind::Auto);

        let mut c = TrainConfig { seed: 9, ..TrainConfig::default() };
        let a = Args::parse(
            ["--backend", "native", "--target-return", "475"].map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        assert_eq!(c.target_return, Some(475.0));

        let f = KvFile::parse("backend = pjrt\ntarget_return = 200").unwrap();
        let mut c2 = TrainConfig { seed: 9, ..TrainConfig::default() };
        c2.apply_file(&f).unwrap();
        assert_eq!(c2.backend, BackendKind::Pjrt);
        assert_eq!(c2.target_return, Some(200.0));
    }

    #[test]
    fn precision_and_lane_width_parse_and_plumb() {
        use crate::simd::LanePass;
        for s in ["f64", "f32"] {
            let pr: Precision = s.parse().unwrap();
            assert_eq!(pr.to_string(), s);
        }
        assert!("f16".parse::<Precision>().is_err());
        assert_eq!(TrainConfig::default().precision, Precision::F64);
        assert_eq!(TrainConfig::default().lane_pass, LanePass::Auto);
        assert_eq!(TrainConfig::default().eval_episodes, 0);

        let mut c = TrainConfig::default();
        let f = KvFile::parse("precision = f32\nlane_width = 4\neval_episodes = 3").unwrap();
        c.apply_file(&f).unwrap();
        assert_eq!(c.precision, Precision::F32);
        assert_eq!(c.lane_pass, LanePass::Width4);
        assert_eq!(c.eval_episodes, 3);

        let a = Args::parse(
            ["--precision", "f64", "--lane-width", "8", "--eval-episodes", "5"]
                .map(String::from),
        );
        c.apply_args(&a).unwrap();
        assert_eq!(c.precision, Precision::F64);
        assert_eq!(c.lane_pass, LanePass::Width8);
        assert_eq!(c.eval_episodes, 5);
        assert!(Args::parse(["--lane-width".into(), "2".into()])
            .opt("lane-width")
            .unwrap()
            .parse::<LanePass>()
            .is_err());
    }

    #[test]
    fn eval_episodes_rejected_with_normalized_observations() {
        // Greedy eval runs on bare envs; evaluating a normalized-obs
        // policy there would be silently out-of-distribution.
        let mut c = TrainConfig {
            eval_episodes: 4,
            normalize_obs: true,
            ..TrainConfig::default()
        };
        assert!(matches!(c.validate(), Err(Error::Config(_))));
        c.normalize_obs = false;
        c.normalize_obs_shared = true;
        assert!(matches!(c.validate(), Err(Error::Config(_))));
        c.normalize_obs_shared = false;
        c.validate().unwrap();
    }

    #[test]
    fn shared_and_per_lane_normalization_conflict() {
        let mut c = TrainConfig {
            normalize_obs: true,
            normalize_obs_shared: true,
            ..TrainConfig::default()
        };
        assert!(c.validate().is_err());
        c.normalize_obs = false;
        c.validate().unwrap();
        let w = c.wrap_config();
        assert!(w.normalize_obs_shared && !w.normalize_obs);
        assert!(!w.is_empty());
    }

    #[test]
    fn async_train_flags_parse_and_validate() {
        // parses from file and CLI, and the CLI wins
        let mut c = TrainConfig { executor: ExecutorKind::EnvPoolAsync, ..TrainConfig::default() };
        c.batch_size = 4;
        let f = KvFile::parse("async_train = true\nmax_policy_lag = 8").unwrap();
        c.apply_file(&f).unwrap();
        assert!(c.async_train);
        assert_eq!(c.max_policy_lag, Some(8));
        c.apply_args(&Args::parse(["--max-policy-lag".into(), "2".into()])).unwrap();
        assert_eq!(c.max_policy_lag, Some(2));

        // async_train demands an async executor
        let c = TrainConfig { async_train: true, ..TrainConfig::default() };
        match c.validate() {
            Err(Error::Config(msg)) => assert!(msg.contains("envpool-async"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // max_policy_lag without async_train is a config error
        let c = TrainConfig { max_policy_lag: Some(1), ..TrainConfig::default() };
        match c.validate() {
            Err(Error::Config(msg)) => assert!(msg.contains("--async-train"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // the valid combination passes
        let c = TrainConfig {
            executor: ExecutorKind::EnvPoolAsync,
            batch_size: 4,
            async_train: true,
            max_policy_lag: Some(0),
            ..TrainConfig::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn scenario_flag_parses_and_validates() {
        let mut c = TrainConfig::default();
        let f = KvFile::parse("scenario = examples/scenarios/mixed.scn").unwrap();
        c.apply_file(&f).unwrap();
        assert_eq!(c.scenario.as_deref(), Some("examples/scenarios/mixed.scn"));
        c.apply_args(&Args::parse(["--scenario".into(), "other.scn".into()])).unwrap();
        assert_eq!(c.scenario.as_deref(), Some("other.scn"));

        // Only the synchronous pool executors may drive a scenario.
        let c = TrainConfig {
            scenario: Some("x.scn".into()),
            executor: ExecutorKind::EnvPoolAsync,
            batch_size: 4,
            ..TrainConfig::default()
        };
        match c.validate() {
            Err(Error::Config(msg)) => assert!(msg.contains("envpool-sync"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // Pool-level normalization flags conflict with per-group wrappers.
        let c = TrainConfig {
            scenario: Some("x.scn".into()),
            normalize_obs: true,
            ..TrainConfig::default()
        };
        assert!(c.validate().is_err());
        let c = TrainConfig { scenario: Some("x.scn".into()), ..TrainConfig::default() };
        c.validate().unwrap();
    }

    #[test]
    fn vec_kinds_imply_vectorized_pool_mode() {
        use crate::pool::ExecMode;
        assert_eq!(ExecutorKind::EnvPoolSyncVec.pool_exec_mode(), ExecMode::Vectorized);
        assert_eq!(ExecutorKind::EnvPoolAsyncVec.pool_exec_mode(), ExecMode::Vectorized);
        assert_eq!(ExecutorKind::EnvPoolNumaAsyncVec.pool_exec_mode(), ExecMode::Vectorized);
        assert_eq!(ExecutorKind::EnvPoolSync.pool_exec_mode(), ExecMode::Scalar);
        assert_eq!(ExecutorKind::EnvPoolNumaAsync.pool_exec_mode(), ExecMode::Scalar);
        // non-pool executors run their own engines; mode is Scalar
        assert_eq!(ExecutorKind::ForLoopVec.pool_exec_mode(), ExecMode::Scalar);
    }
}
