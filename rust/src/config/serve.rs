//! `envpool serve` configuration: where the control socket lives, where
//! the shared-memory slabs are backed, and how the env id space is carved
//! into client leases. Populated builder-style and overridable from CLI
//! flags via [`ServeConfig::validate`]'s caller (see `main.rs`).

use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Configuration for a pool server ([`crate::executors::serve::PoolServer`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Task id every lease serves, e.g. `"CartPole-v1"`.
    pub task_id: String,
    /// Unix socket path for the attach/step control channel.
    pub socket_path: PathBuf,
    /// Directory backing the obs/action slab files. `None` picks
    /// `/dev/shm` when present (true shared memory on Linux) and the
    /// system temp dir otherwise.
    pub slab_dir: Option<PathBuf>,
    /// Number of leases = maximum concurrently attached clients.
    pub max_clients: usize,
    /// Envs per lease (the pool runs `max_clients * lease_size` envs,
    /// batch size `lease_size`).
    pub lease_size: usize,
    /// Worker threads for the underlying pool.
    pub num_threads: usize,
    /// Experiment seed.
    pub seed: u64,
    /// Slots in each per-lease obs/action ring. A client may pipeline at
    /// most `ring_slots - 1` waves, so slots are never overwritten before
    /// they are read.
    pub ring_slots: usize,
    /// Reclaim a lease whose client sent nothing (not even a heartbeat)
    /// for this long. Socket EOF is the primary death signal — a SIGKILL
    /// closes the socket immediately — so this only catches wedged-but-
    /// alive clients; `None` disables the timer.
    pub heartbeat_timeout: Option<Duration>,
}

impl ServeConfig {
    pub fn new(task_id: &str, socket_path: impl Into<PathBuf>) -> Self {
        ServeConfig {
            task_id: task_id.to_string(),
            socket_path: socket_path.into(),
            slab_dir: None,
            max_clients: 2,
            lease_size: 8,
            num_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            seed: 0,
            ring_slots: 4,
            heartbeat_timeout: None,
        }
    }

    pub fn max_clients(mut self, n: usize) -> Self {
        self.max_clients = n;
        self
    }

    pub fn lease_size(mut self, k: usize) -> Self {
        self.lease_size = k;
        self
    }

    pub fn num_threads(mut self, t: usize) -> Self {
        self.num_threads = t;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn ring_slots(mut self, n: usize) -> Self {
        self.ring_slots = n;
        self
    }

    pub fn slab_dir(mut self, d: impl Into<PathBuf>) -> Self {
        self.slab_dir = Some(d.into());
        self
    }

    pub fn heartbeat_timeout(mut self, d: Option<Duration>) -> Self {
        self.heartbeat_timeout = d;
        self
    }

    /// Bound on outstanding waves per lease, derived from the ring depth:
    /// one slot is always kept free so the server never overwrites a slot
    /// the client has not consumed.
    pub fn max_outstanding(&self) -> usize {
        self.ring_slots - 1
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_clients == 0 {
            return Err(Error::Config("serve: max_clients must be > 0".into()));
        }
        if self.lease_size == 0 {
            return Err(Error::Config("serve: lease_size must be > 0".into()));
        }
        if self.num_threads == 0 {
            return Err(Error::Config("serve: num_threads must be > 0".into()));
        }
        if self.ring_slots < 2 {
            return Err(Error::Config(
                "serve: ring_slots must be >= 2 (one in flight + one being read)".into(),
            ));
        }
        if self.socket_path.as_os_str().is_empty() {
            return Err(Error::Config("serve: socket_path must be set".into()));
        }
        Ok(())
    }

    /// Resolve the slab directory: explicit > `/dev/shm` > temp dir.
    pub fn resolved_slab_dir(&self) -> PathBuf {
        if let Some(d) = &self.slab_dir {
            return d.clone();
        }
        let shm = Path::new("/dev/shm");
        if shm.is_dir() {
            return shm.to_path_buf();
        }
        std::env::temp_dir()
    }

    /// Slab file path for one lease's observation (server→client) ring.
    /// Names embed the socket file stem and the server pid so concurrent
    /// servers (or a restarted one) never collide.
    pub fn obs_slab_path(&self, lease: usize) -> PathBuf {
        self.slab_path(lease, "obs")
    }

    /// Slab file path for one lease's action (client→server) ring.
    pub fn act_slab_path(&self, lease: usize) -> PathBuf {
        self.slab_path(lease, "act")
    }

    fn slab_path(&self, lease: usize, kind: &str) -> PathBuf {
        let stem = self
            .socket_path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "envpool".to_string());
        self.resolved_slab_dir()
            .join(format!("{stem}.{}.lease{lease}.{kind}", std::process::id()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_degenerate_shapes() {
        let ok = ServeConfig::new("CartPole-v1", "/tmp/s.sock");
        ok.validate().unwrap();
        assert!(ok.clone().max_clients(0).validate().is_err());
        assert!(ok.clone().lease_size(0).validate().is_err());
        assert!(ok.clone().ring_slots(1).validate().is_err());
        assert!(ServeConfig::new("CartPole-v1", "").validate().is_err());
    }

    #[test]
    fn slab_paths_are_distinct_and_dir_resolves() {
        let c = ServeConfig::new("CartPole-v1", "/tmp/pool.sock").slab_dir("/tmp/slabs");
        assert_ne!(c.obs_slab_path(0), c.act_slab_path(0));
        assert_ne!(c.obs_slab_path(0), c.obs_slab_path(1));
        assert!(c.obs_slab_path(0).starts_with("/tmp/slabs"));
        let auto = ServeConfig::new("CartPole-v1", "/tmp/pool.sock");
        assert!(auto.resolved_slab_dir().is_dir());
    }

    #[test]
    fn ring_depth_bounds_pipelining() {
        let c = ServeConfig::new("CartPole-v1", "/tmp/s.sock").ring_slots(4);
        assert_eq!(c.max_outstanding(), 3);
    }
}
