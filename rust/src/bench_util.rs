//! Tiny benchmarking harness (the vendored crate set has no `criterion`).
//!
//! `cargo bench` targets use [`Bencher`] to run warmup + timed iterations
//! and print mean / std / throughput lines in a stable, grep-able format
//! that the EXPERIMENTS.md tables are built from.

use crate::metrics::stats::Streaming;
use std::time::Instant;

/// One benchmark runner with warmup and repeated timed samples.
pub struct Bencher {
    /// Number of timed samples.
    pub samples: usize,
    /// Warmup iterations before sampling.
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { samples: 10, warmup: 2 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub std_secs: f64,
    /// Work units (frames, ops...) per invocation — used for throughput.
    pub units: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.mean_secs == 0.0 { 0.0 } else { self.units / self.mean_secs }
    }

    pub fn report(&self) -> String {
        format!(
            "bench {:<42} mean {:>10.6}s  std {:>9.6}s  throughput {:>14.1}/s",
            self.name,
            self.mean_secs,
            self.std_secs,
            self.throughput()
        )
    }
}

impl Bencher {
    /// Quick-mode bencher for CI (`ENVPOOL_BENCH_QUICK=1` shrinks samples).
    pub fn from_env() -> Bencher {
        if std::env::var("ENVPOOL_BENCH_QUICK").is_ok() {
            Bencher { samples: 3, warmup: 1 }
        } else {
            Bencher::default()
        }
    }

    /// Run `f` (which performs `units` units of work per call) and report.
    pub fn run<F: FnMut()>(&self, name: &str, units: f64, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Streaming::new();
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            s.push(t.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            mean_secs: s.mean(),
            std_secs: s.std(),
            units,
        };
        println!("{}", r.report());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher { samples: 3, warmup: 1 };
        let mut count = 0u64;
        let r = b.run("noop", 100.0, || {
            count += 1;
            std::hint::black_box(());
        });
        assert_eq!(count, 4); // warmup + samples
        assert!(r.throughput() > 0.0);
        assert!(r.report().contains("noop"));
    }
}
