//! Tiny benchmarking harness (the vendored crate set has no `criterion`).
//!
//! `cargo bench` targets use [`Bencher`] to run warmup + timed iterations
//! and print mean / std / throughput lines in a stable, grep-able format
//! that the EXPERIMENTS.md tables are built from.
//!
//! Every result is also recorded on the bencher, and each bench target
//! ends with [`Bencher::write_snapshot`], which serializes the run to
//! `BENCH_<table>.json` (hand-rolled writer — no serde in the vendored
//! crate set). The snapshot carries the git sha, the lane-width setting
//! and the quick/full mode flag alongside env-steps/s per row, so the
//! bench-smoke CI job can archive a per-commit throughput record and
//! EXPERIMENTS.md tables can cite an exact commit.

use crate::metrics::stats::Streaming;
use std::cell::RefCell;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// One benchmark runner with warmup and repeated timed samples.
pub struct Bencher {
    /// Number of timed samples.
    pub samples: usize,
    /// Warmup iterations before sampling.
    pub warmup: usize,
    /// Every result produced by [`Bencher::run`], in run order —
    /// drained into `BENCH_<table>.json` by [`Bencher::write_snapshot`].
    /// Interior-mutable so `run` can keep taking `&self`.
    results: RefCell<Vec<BenchResult>>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(10, 2)
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub std_secs: f64,
    /// Work units (frames, ops...) per invocation — used for throughput.
    pub units: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.mean_secs == 0.0 { 0.0 } else { self.units / self.mean_secs }
    }

    pub fn report(&self) -> String {
        format!(
            "bench {:<42} mean {:>10.6}s  std {:>9.6}s  throughput {:>14.1}/s",
            self.name,
            self.mean_secs,
            self.std_secs,
            self.throughput()
        )
    }
}

impl Bencher {
    pub fn new(samples: usize, warmup: usize) -> Bencher {
        Bencher { samples, warmup, results: RefCell::new(Vec::new()) }
    }

    /// Quick-mode bencher for CI (`ENVPOOL_BENCH_QUICK=1` shrinks samples).
    pub fn from_env() -> Bencher {
        if std::env::var("ENVPOOL_BENCH_QUICK").is_ok() {
            Bencher::new(3, 1)
        } else {
            Bencher::default()
        }
    }

    /// Run `f` (which performs `units` units of work per call) and report.
    pub fn run<F: FnMut()>(&self, name: &str, units: f64, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Streaming::new();
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            s.push(t.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            mean_secs: s.mean(),
            std_secs: s.std(),
            units,
        };
        println!("{}", r.report());
        self.results.borrow_mut().push(r.clone());
        r
    }

    /// Write every recorded result to `BENCH_<table>.json` in
    /// `$ENVPOOL_BENCH_DIR` (default: the working directory). Called
    /// once at the end of each bench target's `main`.
    pub fn write_snapshot(&self, table: &str) -> std::io::Result<PathBuf> {
        let dir = std::env::var("ENVPOOL_BENCH_DIR").unwrap_or_else(|_| ".".into());
        self.write_snapshot_to(table, Path::new(&dir))
    }

    /// [`Bencher::write_snapshot`] with an explicit directory (tests).
    ///
    /// Layout (all hand-rolled — the vendored crate set has no serde):
    ///
    /// ```json
    /// {"table": "...", "git_sha": "...", "lane_width": "...",
    ///  "quick": false,
    ///  "rows": [{"name": "...", "units": N, "mean_secs": N,
    ///            "std_secs": N, "throughput_per_s": N}, ...]}
    /// ```
    pub fn write_snapshot_to(&self, table: &str, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{table}.json"));
        let lane = std::env::var("ENVPOOL_LANE_WIDTH")
            .unwrap_or_else(|_| format!("auto({})", crate::simd::LanePass::Auto.width()));
        let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
        let mut s = String::new();
        s.push_str(&format!(
            "{{\n  \"table\": \"{}\",\n  \"git_sha\": \"{}\",\n  \
             \"lane_width\": \"{}\",\n  \"quick\": {},\n  \"rows\": [",
            json_escape(table),
            json_escape(&git_sha()),
            json_escape(&lane),
            quick
        ));
        let rows = self.results.borrow();
        for (i, r) in rows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"units\": {}, \"mean_secs\": {}, \
                 \"std_secs\": {}, \"throughput_per_s\": {}}}",
                json_escape(&r.name),
                json_num(r.units),
                json_num(r.mean_secs),
                json_num(r.std_secs),
                json_num(r.throughput())
            ));
        }
        s.push_str("\n  ]\n}\n");
        let mut f = std::fs::File::create(&path)?;
        f.write_all(s.as_bytes())?;
        println!("bench snapshot written: {}", path.display());
        Ok(path)
    }
}

/// Escape a string for a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Infinity — map non-finite values to `null`.
fn json_num(x: f64) -> String {
    if x.is_finite() { format!("{x}") } else { "null".to_string() }
}

/// Commit the snapshot is measured at. Resolution order:
///
/// 1. `$GITHUB_SHA` (GitHub Actions), then `$GIT_COMMIT` (Jenkins and
///    most other CI systems) — first non-empty wins.
/// 2. `git rev-parse HEAD` (needs a `git` binary on `PATH`).
/// 3. Reading the repository metadata directly: `$GIT_DIR` if set, else
///    `.git` in the working directory, else `../.git` (bench targets run
///    from `rust/`, one level below the repo root). Handles detached
///    heads, loose refs, packed refs and `gitdir:` worktree indirection.
/// 4. `"unknown"` — benches must not fail over provenance metadata.
///
/// The filesystem fallback matters in minimal containers: the CI
/// snapshot check flags all-`null` measurement rows, and a snapshot
/// that can't name its commit is almost as useless as one with no
/// numbers.
fn git_sha() -> String {
    for var in ["GITHUB_SHA", "GIT_COMMIT"] {
        if let Ok(s) = std::env::var(var) {
            let s = s.trim().to_string();
            if !s.is_empty() {
                return s;
            }
        }
    }
    if let Some(s) = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
    {
        return s;
    }
    let candidates: Vec<PathBuf> = std::env::var("GIT_DIR")
        .ok()
        .map(PathBuf::from)
        .into_iter()
        .chain([PathBuf::from(".git"), PathBuf::from("../.git")])
        .collect();
    for cand in candidates {
        if let Some(dir) = resolve_git_dir(&cand) {
            if let Some(sha) = sha_from_git_dir(&dir) {
                return sha;
            }
        }
    }
    "unknown".to_string()
}

/// Resolve a `.git` path to the actual git directory. A worktree's
/// `.git` is a *file* containing `gitdir: <path>`; follow one level of
/// that indirection (relative paths resolve against the gitfile's
/// parent).
fn resolve_git_dir(path: &Path) -> Option<PathBuf> {
    if path.is_dir() {
        return Some(path.to_path_buf());
    }
    if path.is_file() {
        let body = std::fs::read_to_string(path).ok()?;
        let target = body.strip_prefix("gitdir:")?.trim();
        let target = Path::new(target);
        let dir = if target.is_absolute() {
            target.to_path_buf()
        } else {
            path.parent()?.join(target)
        };
        return dir.is_dir().then_some(dir);
    }
    None
}

/// Read `HEAD` out of a resolved git directory: a detached HEAD is the
/// sha itself; a `ref: <name>` line is followed through the loose ref
/// file, then `packed-refs` (skipping `#` comments and `^` peel lines).
fn sha_from_git_dir(dir: &Path) -> Option<String> {
    let head = std::fs::read_to_string(dir.join("HEAD")).ok()?;
    let head = head.trim();
    if let Some(refname) = head.strip_prefix("ref:") {
        let refname = refname.trim();
        if let Ok(s) = std::fs::read_to_string(dir.join(refname)) {
            let s = s.trim().to_string();
            if looks_like_sha(&s) {
                return Some(s);
            }
        }
        let packed = std::fs::read_to_string(dir.join("packed-refs")).ok()?;
        for line in packed.lines() {
            if line.starts_with('#') || line.starts_with('^') {
                continue;
            }
            if let Some((sha, name)) = line.split_once(' ') {
                if name.trim() == refname && looks_like_sha(sha.trim()) {
                    return Some(sha.trim().to_string());
                }
            }
        }
        return None;
    }
    looks_like_sha(head).then(|| head.to_string())
}

/// 40+ hex chars (SHA-1 now, SHA-256 repos later).
fn looks_like_sha(s: &str) -> bool {
    s.len() >= 40 && s.chars().all(|c| c.is_ascii_hexdigit())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher::new(3, 1);
        let mut count = 0u64;
        let r = b.run("noop", 100.0, || {
            count += 1;
            std::hint::black_box(());
        });
        assert_eq!(count, 4); // warmup + samples
        assert!(r.throughput() > 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn snapshot_writes_wellformed_json() {
        let b = Bencher::new(1, 0);
        b.run("row \"one\"", 10.0, || std::hint::black_box(()));
        b.run("row/two", 0.0, || std::hint::black_box(()));
        let dir = std::env::temp_dir().join(format!("envpool_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = b.write_snapshot_to("testtable", &dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_testtable.json");
        let body = std::fs::read_to_string(&path).unwrap();
        // Minimal structural checks (no JSON parser in the crate set):
        // balanced braces/brackets, escaped quote survives, all keys on.
        assert_eq!(body.matches('{').count(), body.matches('}').count());
        assert_eq!(body.matches('[').count(), body.matches(']').count());
        for key in ["\"table\": \"testtable\"", "\"git_sha\"", "\"lane_width\"", "\"quick\"", "\"rows\""] {
            assert!(body.contains(key), "missing {key} in {body}");
        }
        assert!(body.contains("row \\\"one\\\""), "quote not escaped: {body}");
        assert!(body.contains("\"throughput_per_s\""));
        // units=0 row: throughput is defined as 0, still a finite number.
        assert!(body.contains("\"units\": 0"));
        std::fs::remove_file(&path).unwrap();
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null() {
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_num(1.5), "1.5");
    }

    #[test]
    fn sha_shape_check() {
        assert!(looks_like_sha("0123456789abcdef0123456789abcdef01234567"));
        assert!(looks_like_sha(&"a".repeat(64))); // SHA-256 repo format
        assert!(!looks_like_sha("deadbeef")); // too short
        assert!(!looks_like_sha(&"g".repeat(40))); // not hex
        assert!(!looks_like_sha("ref: refs/heads/main"));
    }

    /// Build a throwaway fake `.git` directory for the fallback tests.
    fn fake_git_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("envpool_gitsha_{}_{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("refs/heads")).unwrap();
        dir
    }

    #[test]
    fn detached_head_resolves_directly() {
        let sha = "1111111111111111111111111111111111111111";
        let dir = fake_git_dir("detached");
        std::fs::write(dir.join("HEAD"), format!("{sha}\n")).unwrap();
        assert_eq!(sha_from_git_dir(&dir).as_deref(), Some(sha));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn loose_ref_resolves_through_head() {
        let sha = "2222222222222222222222222222222222222222";
        let dir = fake_git_dir("loose");
        std::fs::write(dir.join("HEAD"), "ref: refs/heads/main\n").unwrap();
        std::fs::write(dir.join("refs/heads/main"), format!("{sha}\n")).unwrap();
        assert_eq!(sha_from_git_dir(&dir).as_deref(), Some(sha));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn packed_ref_resolves_when_loose_ref_missing() {
        let sha = "3333333333333333333333333333333333333333";
        let peel = "4444444444444444444444444444444444444444";
        let dir = fake_git_dir("packed");
        std::fs::write(dir.join("HEAD"), "ref: refs/heads/main\n").unwrap();
        std::fs::write(
            dir.join("packed-refs"),
            format!(
                "# pack-refs with: peeled fully-peeled sorted\n\
                 {sha} refs/heads/main\n^{peel}\n\
                 5555555555555555555555555555555555555555 refs/heads/other\n"
            ),
        )
        .unwrap();
        assert_eq!(sha_from_git_dir(&dir).as_deref(), Some(sha));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gitfile_indirection_resolves_relative_target() {
        let sha = "6666666666666666666666666666666666666666";
        let real = fake_git_dir("worktree_real");
        std::fs::write(real.join("HEAD"), format!("{sha}\n")).unwrap();
        // A worktree checkout: `.git` is a file pointing at the real dir.
        let wt = std::env::temp_dir()
            .join(format!("envpool_gitsha_worktree_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&wt);
        std::fs::create_dir_all(&wt).unwrap();
        let gitfile = wt.join(".git");
        std::fs::write(&gitfile, format!("gitdir: {}\n", real.display())).unwrap();
        let resolved = resolve_git_dir(&gitfile).expect("gitfile should resolve");
        assert_eq!(sha_from_git_dir(&resolved).as_deref(), Some(sha));
        // Missing / bogus paths resolve to None, never panic.
        assert!(resolve_git_dir(&wt.join("nope")).is_none());
        std::fs::write(wt.join("bogus"), "not a gitfile").unwrap();
        assert!(resolve_git_dir(&wt.join("bogus")).is_none());
        std::fs::remove_dir_all(&wt).unwrap();
        std::fs::remove_dir_all(&real).unwrap();
    }

    #[test]
    fn truncated_git_dir_yields_none() {
        let dir = fake_git_dir("broken");
        // No HEAD at all.
        assert!(sha_from_git_dir(&dir).is_none());
        // HEAD points at a ref that exists nowhere (no loose, no packed).
        std::fs::write(dir.join("HEAD"), "ref: refs/heads/gone\n").unwrap();
        assert!(sha_from_git_dir(&dir).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
