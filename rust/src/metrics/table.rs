//! Markdown table writer used by the benchmark harnesses to print
//! paper-style rows (Table 1, Table 2, Figure 3 series).

/// A simple column-aligned markdown table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(r.len(), self.header.len(), "row arity mismatch");
        self.rows.push(r);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as aligned GitHub markdown.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.header);
        let sep: Vec<String> = w.iter().map(|&n| "-".repeat(n)).collect();
        out.push_str(&line(&sep));
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

/// Format frames-per-second with thousands separators (paper style).
pub fn fmt_fps(fps: f64) -> String {
    let n = fps.round() as i64;
    let s = n.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 && ch != '-' {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(["Method", "Atari", "MuJoCo"]);
        t.row(["For-loop", "4,893", "12,861"]);
        t.row(["EnvPool (async)", "49,439", "105,126"]);
        let r = t.render();
        assert!(r.contains("| Method"));
        assert!(r.lines().count() == 4);
        assert!(r.contains("EnvPool (async)"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn fps_thousands() {
        assert_eq!(fmt_fps(4893.4), "4,893");
        assert_eq!(fmt_fps(1_069_922.0), "1,069,922");
        assert_eq!(fmt_fps(12.0), "12");
    }
}
