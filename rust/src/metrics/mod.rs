//! Metrics: streaming statistics, scoped timers, throughput counters, and
//! paper-style markdown table output.

pub mod stats;
pub mod table;
pub mod timer;

pub use stats::{Percentiles, Streaming};
pub use table::Table;
pub use timer::{Stopwatch, TimeBreakdown};
