//! Streaming statistics (Welford) and percentile summaries.

/// Online mean/variance/min/max accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    pub fn new() -> Self {
        Streaming { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile summary over a stored sample (used for step-latency tails —
/// the quantity that drives the paper's sync-vs-async gap).
#[derive(Debug, Clone)]
pub struct Percentiles {
    sorted: Vec<f64>,
}

impl Percentiles {
    pub fn from(mut xs: Vec<f64>) -> Self {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles { sorted: xs }
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn p(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    pub fn median(&self) -> f64 {
        self.p(50.0)
    }
    pub fn len(&self) -> usize {
        self.sorted.len()
    }
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Streaming::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Streaming::new();
        let mut b = Streaming::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.var() - whole.var()).abs() < 1e-9);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn percentiles_basic() {
        let p = Percentiles::from((1..=100).map(|i| i as f64).collect());
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.p(0.0) - 1.0).abs() < 1e-9);
        assert!((p.p(100.0) - 100.0).abs() < 1e-9);
        assert!(p.p(99.0) > 98.0);
    }

    #[test]
    fn empty_percentiles_nan() {
        assert!(Percentiles::from(vec![]).median().is_nan());
    }
}
