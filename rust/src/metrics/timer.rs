//! Scoped timers and the Figure-4 time breakdown
//! (environment step / inference / training / other).

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Category of time in the per-iteration profile, mirroring the paper's
/// Figure 4 decomposition of CleanRL's PPO.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Time in `env.step` / `send`+`recv`.
    EnvStep,
    /// Policy forward pass (action/logp/value).
    Inference,
    /// PPO minibatch updates (fwd+bwd+opt).
    Training,
    /// Blocked in `recv` waiting on the async pool — the decoupled
    /// loop's idle time; small when learner work overlaps env stepping.
    RecvWait,
    /// Everything else (storage, batching, metrics...).
    Other,
}

impl Category {
    pub const ALL: [Category; 5] = [
        Category::EnvStep,
        Category::Inference,
        Category::Training,
        Category::RecvWait,
        Category::Other,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::EnvStep => "env_step",
            Category::Inference => "inference",
            Category::Training => "training",
            Category::RecvWait => "recv_wait",
            Category::Other => "other",
        }
    }
}

/// Accumulated wall time per category (the Figure-4 bars).
#[derive(Debug, Clone, Default)]
pub struct TimeBreakdown {
    totals: [Duration; 5],
    iterations: u64,
}

impl TimeBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    fn idx(c: Category) -> usize {
        match c {
            Category::EnvStep => 0,
            Category::Inference => 1,
            Category::Training => 2,
            Category::RecvWait => 3,
            Category::Other => 4,
        }
    }

    /// Add elapsed time to one category.
    pub fn add(&mut self, c: Category, d: Duration) {
        self.totals[Self::idx(c)] += d;
    }

    /// Time a closure, attributing it to `c`.
    pub fn time<T>(&mut self, c: Category, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(c, t.elapsed());
        out
    }

    pub fn bump_iteration(&mut self) {
        self.iterations += 1;
    }

    pub fn total(&self, c: Category) -> Duration {
        self.totals[Self::idx(c)]
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.iter().sum()
    }

    /// Fraction of total time per category.
    pub fn fraction(&self, c: Category) -> f64 {
        let g = self.grand_total().as_secs_f64();
        if g == 0.0 { 0.0 } else { self.total(c).as_secs_f64() / g }
    }

    /// Per-iteration mean milliseconds for a category.
    pub fn per_iter_ms(&self, c: Category) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.total(c).as_secs_f64() * 1e3 / self.iterations as f64
        }
    }

    /// Render the Figure-4-style summary block.
    pub fn render(&self, label: &str) -> String {
        let mut s = format!("== time breakdown: {label} ({} iters) ==\n", self.iterations);
        for c in Category::ALL {
            s.push_str(&format!(
                "  {:<10} {:>9.3}s  {:>5.1}%  ({:.3} ms/iter)\n",
                c.name(),
                self.total(c).as_secs_f64(),
                100.0 * self.fraction(c),
                self.per_iter_ms(c),
            ));
        }
        s.push_str(&format!("  {:<10} {:>9.3}s\n", "total", self.grand_total().as_secs_f64()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = TimeBreakdown::new();
        b.add(Category::EnvStep, Duration::from_millis(30));
        b.add(Category::Inference, Duration::from_millis(10));
        b.add(Category::EnvStep, Duration::from_millis(30));
        b.bump_iteration();
        b.bump_iteration();
        assert_eq!(b.total(Category::EnvStep), Duration::from_millis(60));
        assert!((b.fraction(Category::EnvStep) - 60.0 / 70.0).abs() < 1e-9);
        assert!((b.per_iter_ms(Category::Inference) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut b = TimeBreakdown::new();
        let v = b.time(Category::Training, || 41 + 1);
        assert_eq!(v, 42);
        assert!(b.total(Category::Training) > Duration::ZERO);
    }

    #[test]
    fn render_contains_categories() {
        let b = TimeBreakdown::new();
        let r = b.render("x");
        for c in Category::ALL {
            assert!(r.contains(c.name()));
        }
    }
}
