//! Action sampling on the Rust side: the policy executable returns
//! distribution parameters; sampling + log-prob happen here so the AOT
//! artifact stays RNG-free (deterministic, seedable from L3).

use crate::rng::Pcg32;

/// Sample categorical actions from row-major logits `[B, A]`.
/// Returns (actions as f32 ids, log-probs).
pub fn categorical(logits: &[f32], batch: usize, n_act: usize, rng: &mut Pcg32) -> (Vec<f32>, Vec<f32>) {
    let mut actions = Vec::with_capacity(batch);
    let mut logps = Vec::with_capacity(batch);
    for b in 0..batch {
        let row = &logits[b * n_act..(b + 1) * n_act];
        // Gumbel-max: argmax(logit + g) ~ Categorical(softmax(logits))
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (a, &l) in row.iter().enumerate() {
            let u = rng.uniform().max(1e-10);
            let g = -(-(u.ln())).ln();
            if l + g > best_v {
                best_v = l + g;
                best = a;
            }
        }
        actions.push(best as f32);
        logps.push(log_prob_categorical(row, best));
    }
    (actions, logps)
}

/// log P(a) under softmax(logits).
pub fn log_prob_categorical(logits_row: &[f32], action: usize) -> f32 {
    let max = logits_row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = max + logits_row.iter().map(|l| (l - max).exp()).sum::<f32>().ln();
    logits_row[action] - lse
}

/// Entropy of `softmax(logits_row)` — the reference the native
/// backend's in-loss entropy is cross-checked against.
pub fn categorical_entropy(logits_row: &[f32]) -> f32 {
    let max = logits_row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = max + logits_row.iter().map(|l| (l - max).exp()).sum::<f32>().ln();
    -logits_row.iter().map(|l| (l - lse).exp() * (l - lse)).sum::<f32>()
}

/// Entropy of a diagonal Gaussian with per-dimension `log_std`:
/// `sum_k (log_std_k + 0.5 (1 + ln 2π))`.
pub fn gaussian_entropy(log_std_row: &[f32]) -> f32 {
    let c = 0.5 * (1.0 + (2.0 * std::f32::consts::PI).ln());
    log_std_row.iter().map(|ls| ls + c).sum()
}

/// Greedy (argmax) actions for evaluation.
pub fn greedy(logits: &[f32], batch: usize, n_act: usize) -> Vec<f32> {
    (0..batch)
        .map(|b| {
            let row = &logits[b * n_act..(b + 1) * n_act];
            row.iter()
                .enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                .unwrap()
                .0 as f32
        })
        .collect()
}

/// Sample Gaussian actions from `mu`/`log_std` (both `[B, A]`).
/// Returns (actions, log-probs).
pub fn gaussian(
    mu: &[f32],
    log_std: &[f32],
    batch: usize,
    act_dim: usize,
    rng: &mut Pcg32,
) -> (Vec<f32>, Vec<f32>) {
    let mut actions = vec![0.0f32; batch * act_dim];
    let mut logps = vec![0.0f32; batch];
    for b in 0..batch {
        let mut lp = 0.0f32;
        for k in 0..act_dim {
            let i = b * act_dim + k;
            let std = log_std[i].exp();
            let eps = rng.normal();
            let a = mu[i] + std * eps;
            actions[i] = a;
            lp += gaussian_logp_1d(a, mu[i], log_std[i]);
        }
        logps[b] = lp;
    }
    (actions, logps)
}

/// One-dimensional Gaussian log-density.
#[inline]
pub fn gaussian_logp_1d(a: f32, mu: f32, log_std: f32) -> f32 {
    let std = log_std.exp();
    let z = (a - mu) / std;
    -0.5 * z * z - log_std - 0.5 * (2.0 * std::f32::consts::PI).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categorical_respects_probabilities() {
        let mut rng = Pcg32::new(0, 0);
        // logits [0, ln(3)]: p = [0.25, 0.75]
        let logits: Vec<f32> = (0..1000).flat_map(|_| [0.0f32, 3.0f32.ln()]).collect();
        let (acts, logps) = categorical(&logits, 1000, 2, &mut rng);
        let ones = acts.iter().filter(|&&a| a == 1.0).count();
        assert!((650..850).contains(&ones), "P(1)=0.75, got {ones}/1000");
        for (a, lp) in acts.iter().zip(&logps) {
            let want = if *a == 1.0 { 0.75f32.ln() } else { 0.25f32.ln() };
            assert!((lp - want).abs() < 1e-5);
        }
    }

    #[test]
    fn entropy_references() {
        // uniform over 4: H = ln 4
        assert!((categorical_entropy(&[0.5; 4]) - 4.0f32.ln()).abs() < 1e-5);
        // near-deterministic: H ≈ 0
        assert!(categorical_entropy(&[100.0, 0.0]) < 1e-3);
        // unit Gaussian: 0.5 (1 + ln 2π) ≈ 1.4189
        assert!((gaussian_entropy(&[0.0]) - 1.4189385).abs() < 1e-4);
        // entropy rises with log_std
        assert!(gaussian_entropy(&[1.0, 1.0]) > gaussian_entropy(&[0.0, 0.0]));
    }

    #[test]
    fn greedy_picks_argmax() {
        let logits = [0.1, 0.9, -1.0, 5.0, 2.0, 3.0];
        assert_eq!(greedy(&logits, 2, 3), vec![1.0, 0.0]);
    }

    #[test]
    fn gaussian_moments_and_logp() {
        let mut rng = Pcg32::new(7, 0);
        let b = 4000;
        let mu = vec![1.0f32; b];
        let log_std = vec![0.0f32; b]; // std = 1
        let (acts, logps) = gaussian(&mu, &log_std, b, 1, &mut rng);
        let mean: f32 = acts.iter().sum::<f32>() / b as f32;
        let var: f32 = acts.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / b as f32;
        assert!((mean - 1.0).abs() < 0.06, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
        // at the mean the density is highest: -0.5 ln(2π)
        let lp_at_mu = gaussian_logp_1d(1.0, 1.0, 0.0);
        assert!(logps.iter().all(|&lp| lp <= lp_at_mu + 1e-6));
    }
}
