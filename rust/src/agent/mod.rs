//! Agent-side components living in Rust: the parameter store, rollout
//! buffer, action samplers, and a reference GAE used to cross-check the
//! AOT kernel.

pub mod params;
pub mod rollout;
pub mod sampler;
pub mod gae;
pub mod traj;

pub use params::{actor_critic_meta, ParamStore};
pub use rollout::RolloutBuffer;
pub use traj::TrajStore;
