//! Rollout-resident trajectory store for the decoupled actor–learner
//! loop: the async pool's recv/send driver writes transitions in place,
//! per env, the way `StateBufferQueue` blocks are written in place by
//! workers — and the learner consumes the finished `[T, N, ...]` arrays
//! zero-copy through [`TrajStore::buf`].
//!
//! Unlike [`RolloutBuffer::store`], which takes one synchronized time
//! slice for all N envs, a `TrajStore` accepts transitions **per env in
//! any arrival order**: under the async protocol a `recv` batch holds an
//! arbitrary subset of envs, so env 3 may be writing row `t = 7` while
//! env 0 is still on `t = 2`. Each env advances its own write cursor.
//!
//! A transition is split across the two halves of the async protocol:
//! [`begin`](TrajStore::begin) records everything known at action time
//! (obs, action, log-prob, value, and the *policy version* the action
//! was sampled under), and [`complete`](TrajStore::complete) fills in
//! the outcome (reward/done/trunc) when the env's next state comes back.
//! The per-transition version is what makes policy lag a measured
//! quantity instead of a hope: [`lag_stats`](TrajStore::lag_stats)
//! reports how stale the behaviour policy was relative to the learner.

use super::rollout::RolloutBuffer;

/// Policy-lag summary over one finished rollout: `mean`/`max` of
/// `current_version - version(t, e)` across all T·N transitions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LagStats {
    pub mean: f32,
    pub max: u32,
}

/// Per-env-cursor trajectory store over a `[T, N, ...]` rollout buffer.
#[derive(Debug, Clone)]
pub struct TrajStore {
    /// The underlying time-major storage, handed to GAE/minibatching
    /// unchanged once the store is full.
    pub buf: RolloutBuffer,
    /// `[T, N]` — minibatch-update counter at the moment each action was
    /// sampled (see `global_updates` in the async loop).
    pub versions: Vec<u32>,
    /// Next row each env writes (`cursor[e]` = number of *begun*
    /// transitions for env `e`).
    cursor: Vec<usize>,
    /// `pending[e]`: env `e` has a begun-but-incomplete transition (its
    /// action is in flight in the pool).
    pending: Vec<bool>,
    /// V(s_T) per env: the bootstrap values for GAE, written when the
    /// observation *after* each env's last stored transition arrives.
    pub last_values: Vec<f32>,
    /// Completed transitions so far (full at T·N).
    complete: usize,
}

impl TrajStore {
    pub fn new(t_len: usize, n: usize, obs_dim: usize, act_dim: usize) -> Self {
        TrajStore {
            buf: RolloutBuffer::new(t_len, n, obs_dim, act_dim),
            versions: vec![0; t_len * n],
            cursor: vec![0; n],
            pending: vec![false; n],
            last_values: vec![0.0; n],
            complete: 0,
        }
    }

    /// Recycle the store for the next rollout round. Storage is reused;
    /// only the cursors reset (stale rows are fully overwritten before
    /// the store reports full again).
    pub fn reset(&mut self) {
        self.cursor.fill(0);
        self.pending.fill(false);
        self.complete = 0;
    }

    /// Number of begun transitions for env `e` (its write cursor).
    pub fn cursor(&self, e: usize) -> usize {
        self.cursor[e]
    }

    /// Whether env `e` has an in-flight (begun, not completed)
    /// transition.
    pub fn pending(&self, e: usize) -> bool {
        self.pending[e]
    }

    /// Env `e` has begun all `T` of its transitions for this round.
    pub fn env_done(&self, e: usize) -> bool {
        self.cursor[e] >= self.buf.t_len
    }

    /// All T·N transitions completed: the buffer is a finished rollout.
    pub fn is_full(&self) -> bool {
        self.complete == self.buf.rows()
    }

    /// Record the action-time half of env `e`'s next transition at row
    /// `(cursor[e], e)` and advance the cursor. Panics (debug) if the
    /// env is already pending or past `T` — both are driver bugs.
    pub fn begin(
        &mut self,
        e: usize,
        obs_row: &[f32],
        act_row: &[f32],
        logp: f32,
        value: f32,
        version: u32,
    ) {
        debug_assert!(!self.pending[e], "env {e} already has an action in flight");
        let t = self.cursor[e];
        debug_assert!(t < self.buf.t_len, "env {e} past rollout horizon");
        let n = self.buf.n;
        let row = t * n + e;
        let od = self.buf.obs_dim;
        let ad = self.buf.act_dim;
        self.buf.obs[row * od..(row + 1) * od].copy_from_slice(obs_row);
        self.buf.actions[row * ad..(row + 1) * ad].copy_from_slice(act_row);
        self.buf.logp[row] = logp;
        self.buf.values[row] = value;
        self.versions[row] = version;
        self.cursor[e] = t + 1;
        self.pending[e] = true;
    }

    /// Record the outcome half of env `e`'s in-flight transition.
    pub fn complete(&mut self, e: usize, rew: f32, done: bool, trunc: bool) {
        debug_assert!(self.pending[e], "env {e} has no action in flight");
        let t = self.cursor[e] - 1;
        let row = t * self.buf.n + e;
        self.buf.rewards[row] = rew;
        self.buf.dones[row] = done as u32 as f32;
        self.buf.truncs[row] = trunc as u32 as f32;
        self.pending[e] = false;
        self.complete += 1;
    }

    /// Store env `e`'s bootstrap value V(s_T) (from the observation
    /// following its last stored transition).
    pub fn set_last_value(&mut self, e: usize, v: f32) {
        self.last_values[e] = v;
    }

    /// Policy-lag statistics for a finished rollout, in minibatch-update
    /// units, relative to the learner's `current_version`.
    pub fn lag_stats(&self, current_version: u32) -> LagStats {
        let mut sum = 0u64;
        let mut max = 0u32;
        for &v in &self.versions {
            let lag = current_version.saturating_sub(v);
            sum += lag as u64;
            max = max.max(lag);
        }
        LagStats { mean: sum as f32 / self.versions.len().max(1) as f32, max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_order_envs_land_in_time_major_rows() {
        // env 1 runs two transitions before env 0 begins its first: rows
        // must still come out time-major per env, exactly where
        // RolloutBuffer::store would have put a synchronized slice.
        let mut s = TrajStore::new(2, 2, 2, 1);
        s.begin(1, &[10.0, 11.0], &[1.0], -0.5, 0.9, 0);
        s.complete(1, 1.0, false, false);
        s.begin(1, &[12.0, 13.0], &[0.0], -0.6, 0.8, 1);
        s.complete(1, 0.5, true, false);
        assert!(s.env_done(1) && !s.env_done(0));
        assert!(!s.is_full());
        s.begin(0, &[1.0, 2.0], &[1.0], -0.1, 0.5, 2);
        s.complete(0, 2.0, false, true);
        s.begin(0, &[3.0, 4.0], &[0.0], -0.2, 0.4, 2);
        s.complete(0, 3.0, false, false);
        assert!(s.is_full());
        // row (t, e) = t*n + e; obs layout [T, N, obs_dim]
        assert_eq!(&s.buf.obs[0..4], &[1.0, 2.0, 10.0, 11.0]);
        assert_eq!(&s.buf.obs[4..8], &[3.0, 4.0, 12.0, 13.0]);
        assert_eq!(s.buf.rewards, vec![2.0, 1.0, 3.0, 0.5]);
        assert_eq!(s.buf.dones, vec![0.0, 0.0, 0.0, 1.0]);
        assert_eq!(s.buf.truncs, vec![1.0, 0.0, 0.0, 0.0]);
        assert_eq!(s.versions, vec![2, 0, 2, 1]);
    }

    #[test]
    fn lag_stats_measure_staleness_against_learner_version() {
        let mut s = TrajStore::new(2, 1, 1, 1);
        s.begin(0, &[0.0], &[0.0], 0.0, 0.0, 3);
        s.complete(0, 0.0, false, false);
        s.begin(0, &[0.0], &[0.0], 0.0, 0.0, 5);
        s.complete(0, 0.0, false, false);
        let lag = s.lag_stats(5);
        assert_eq!(lag.max, 2);
        assert!((lag.mean - 1.0).abs() < 1e-6);
        // versions newer than current saturate to zero lag
        assert_eq!(s.lag_stats(0).max, 0);
    }

    #[test]
    fn reset_recycles_cursors_and_fill_state() {
        let mut s = TrajStore::new(1, 2, 1, 1);
        s.begin(0, &[1.0], &[0.0], 0.0, 0.0, 0);
        s.complete(0, 1.0, false, false);
        s.begin(1, &[2.0], &[0.0], 0.0, 0.0, 0);
        s.complete(1, 1.0, false, false);
        assert!(s.is_full());
        s.set_last_value(0, 7.0);
        s.reset();
        assert!(!s.is_full());
        assert_eq!(s.cursor(0), 0);
        assert!(!s.pending(1));
        // last_values persist until overwritten; GAE reads them only
        // after a full round writes all N.
        assert_eq!(s.last_values[0], 7.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_begin_is_a_driver_bug() {
        let mut s = TrajStore::new(2, 1, 1, 1);
        s.begin(0, &[0.0], &[0.0], 0.0, 0.0, 0);
        s.begin(0, &[0.0], &[0.0], 0.0, 0.0, 0);
    }
}
