//! Parameter store: the ordered flat set of policy/optimizer tensors the
//! AOT executables consume and produce. Host vectors are the source of
//! truth; literals are materialized per call (cheap at policy-MLP sizes
//! — see EXPERIMENTS.md §Perf for the measurement).

use crate::rng::Pcg32;
use crate::runtime::artifact::{ArtifactConfig, Manifest, ParamMeta};
use crate::runtime::literal::tensor_f32;
use crate::Result;

/// Shape metadata of the standard MLP actor-critic, in the order both
/// compute backends use: `w1 [obs,h], b1 [h], w2 [h,h], b2 [h],
/// wp [h,act], bp [act], [log_std [act],] wv [h,1], bv [1]` — the same
/// naming convention `python/compile/aot.py` exports, so native-backend
/// checkpoints and artifact params are directly comparable.
pub fn actor_critic_meta(
    obs_dim: usize,
    act_dim: usize,
    hidden: usize,
    continuous: bool,
) -> Vec<ParamMeta> {
    let mut meta = vec![
        ParamMeta { name: "w1".into(), shape: vec![obs_dim, hidden] },
        ParamMeta { name: "b1".into(), shape: vec![hidden] },
        ParamMeta { name: "w2".into(), shape: vec![hidden, hidden] },
        ParamMeta { name: "b2".into(), shape: vec![hidden] },
        ParamMeta { name: "wp".into(), shape: vec![hidden, act_dim] },
        ParamMeta { name: "bp".into(), shape: vec![act_dim] },
    ];
    if continuous {
        meta.push(ParamMeta { name: "log_std".into(), shape: vec![act_dim] });
    }
    meta.push(ParamMeta { name: "wv".into(), shape: vec![hidden, 1] });
    meta.push(ParamMeta { name: "bv".into(), shape: vec![1] });
    meta
}

/// Ordered parameter tensors (+ shapes).
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub meta: Vec<ParamMeta>,
    pub values: Vec<Vec<f32>>,
}

impl ParamStore {
    /// Load the initial parameters exported by aot.py.
    pub fn load(manifest: &Manifest, cfg: &ArtifactConfig) -> Result<ParamStore> {
        Ok(ParamStore { meta: cfg.params.clone(), values: manifest.load_params(cfg)? })
    }

    /// Deterministic `Pcg32`-seeded initialization of the standard MLP
    /// actor-critic (the native backend's init source). Weights are
    /// scaled Gaussians, `std = gain / sqrt(fan_in)`, with CleanRL's
    /// orthogonal-init gains — `sqrt(2)` for the Tanh trunk, `0.01` for
    /// the policy head (near-uniform initial policy), `1.0` for the
    /// value head; biases and `log_std` start at zero.
    pub fn init_actor_critic(
        obs_dim: usize,
        act_dim: usize,
        hidden: usize,
        continuous: bool,
        seed: u64,
    ) -> ParamStore {
        let meta = actor_critic_meta(obs_dim, act_dim, hidden, continuous);
        let mut rng = Pcg32::new(seed, 0x6e61_7469_7665); // "native" stream
        let values = meta
            .iter()
            .map(|m| {
                let gain: f32 = match m.name.as_str() {
                    "w1" | "w2" => std::f32::consts::SQRT_2,
                    "wp" => 0.01,
                    "wv" => 1.0,
                    _ => return vec![0.0; m.numel()], // biases, log_std
                };
                let std = gain / (m.shape[0] as f32).sqrt();
                (0..m.numel()).map(|_| rng.normal() * std).collect()
            })
            .collect();
        ParamStore { meta, values }
    }

    /// Zero tensors with the same shapes (Adam m/v init).
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            meta: self.meta.clone(),
            values: self.meta.iter().map(|m| vec![0.0; m.numel()]).collect(),
        }
    }

    /// Materialize XLA literals in spec order.
    pub fn literals(&self) -> Result<Vec<xla::Literal>> {
        self.meta
            .iter()
            .zip(&self.values)
            .map(|(m, v)| tensor_f32(v, &m.shape))
            .collect()
    }

    /// Upload to device buffers in spec order (the hot-path transport —
    /// see EXPERIMENTS.md §Perf on why buffers, not literals).
    pub fn buffers(&self, rt: &crate::runtime::Runtime) -> Result<Vec<xla::PjRtBuffer>> {
        self.meta
            .iter()
            .zip(&self.values)
            .map(|(m, v)| rt.buf_f32(v, &m.shape))
            .collect()
    }

    /// Replace values from executable outputs (same order).
    pub fn update_from(&mut self, outs: &[xla::Literal]) -> Result<()> {
        debug_assert_eq!(outs.len(), self.values.len());
        for (v, l) in self.values.iter_mut().zip(outs) {
            *v = crate::runtime::literal::to_vec_f32(l)?;
        }
        Ok(())
    }

    /// Total parameter count (reporting).
    pub fn numel(&self) -> usize {
        self.meta.iter().map(|m| m.numel()).sum()
    }

    /// L2 norm over all tensors (divergence tripwire in the trainer).
    pub fn global_norm(&self) -> f32 {
        self.values
            .iter()
            .flat_map(|v| v.iter())
            .map(|x| x * x)
            .sum::<f32>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_critic_init_is_deterministic_and_shaped() {
        let p = ParamStore::init_actor_critic(4, 2, 64, false, 7);
        let q = ParamStore::init_actor_critic(4, 2, 64, false, 7);
        assert_eq!(p.values, q.values, "same seed must reproduce the init");
        assert_ne!(
            p.values,
            ParamStore::init_actor_critic(4, 2, 64, false, 8).values,
            "different seeds must differ"
        );
        assert_eq!(p.meta.len(), 8);
        assert_eq!(p.numel(), 4 * 64 + 64 + 64 * 64 + 64 + 64 * 2 + 2 + 64 + 1);
        // biases zero, weights nonzero, policy head much smaller than trunk
        assert!(p.values[1].iter().all(|&x| x == 0.0), "b1 zero");
        assert!(p.values[0].iter().any(|&x| x != 0.0), "w1 nonzero");
        let rms = |v: &[f32]| (v.iter().map(|x| x * x).sum::<f32>() / v.len() as f32).sqrt();
        assert!(rms(&p.values[4]) < 0.1 * rms(&p.values[2]), "wp gain 0.01 << trunk");

        let c = ParamStore::init_actor_critic(3, 2, 16, true, 1);
        assert_eq!(c.meta.len(), 9);
        assert_eq!(c.meta[6].name, "log_std");
        assert!(c.values[6].iter().all(|&x| x == 0.0), "log_std starts at 0");
    }

    #[test]
    fn load_zeros_and_norm() {
        let dir = crate::runtime::artifact::testsupport::synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let p = ParamStore::load(&m, cfg).unwrap();
        assert!(p.numel() > 4 * 64);
        assert!(p.global_norm() > 0.0);
        let z = p.zeros_like();
        assert_eq!(z.numel(), p.numel());
        assert_eq!(z.global_norm(), 0.0);
        assert_eq!(p.literals().unwrap().len(), p.meta.len());
    }
}
