//! Parameter store: the ordered flat set of policy/optimizer tensors the
//! AOT executables consume and produce. Host vectors are the source of
//! truth; literals are materialized per call (cheap at policy-MLP sizes
//! — see EXPERIMENTS.md §Perf for the measurement).

use crate::runtime::artifact::{ArtifactConfig, Manifest, ParamMeta};
use crate::runtime::literal::tensor_f32;
use crate::Result;

/// Ordered parameter tensors (+ shapes).
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub meta: Vec<ParamMeta>,
    pub values: Vec<Vec<f32>>,
}

impl ParamStore {
    /// Load the initial parameters exported by aot.py.
    pub fn load(manifest: &Manifest, cfg: &ArtifactConfig) -> Result<ParamStore> {
        Ok(ParamStore { meta: cfg.params.clone(), values: manifest.load_params(cfg)? })
    }

    /// Zero tensors with the same shapes (Adam m/v init).
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            meta: self.meta.clone(),
            values: self.meta.iter().map(|m| vec![0.0; m.numel()]).collect(),
        }
    }

    /// Materialize XLA literals in spec order.
    pub fn literals(&self) -> Result<Vec<xla::Literal>> {
        self.meta
            .iter()
            .zip(&self.values)
            .map(|(m, v)| tensor_f32(v, &m.shape))
            .collect()
    }

    /// Upload to device buffers in spec order (the hot-path transport —
    /// see EXPERIMENTS.md §Perf on why buffers, not literals).
    pub fn buffers(&self, rt: &crate::runtime::Runtime) -> Result<Vec<xla::PjRtBuffer>> {
        self.meta
            .iter()
            .zip(&self.values)
            .map(|(m, v)| rt.buf_f32(v, &m.shape))
            .collect()
    }

    /// Replace values from executable outputs (same order).
    pub fn update_from(&mut self, outs: &[xla::Literal]) -> Result<()> {
        debug_assert_eq!(outs.len(), self.values.len());
        for (v, l) in self.values.iter_mut().zip(outs) {
            *v = crate::runtime::literal::to_vec_f32(l)?;
        }
        Ok(())
    }

    /// Total parameter count (reporting).
    pub fn numel(&self) -> usize {
        self.meta.iter().map(|m| m.numel()).sum()
    }

    /// L2 norm over all tensors (divergence tripwire in the trainer).
    pub fn global_norm(&self) -> f32 {
        self.values
            .iter()
            .flat_map(|v| v.iter())
            .map(|x| x * x)
            .sum::<f32>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_zeros_and_norm() {
        let dir = crate::runtime::artifact::testsupport::synth_artifacts_dir();
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let p = ParamStore::load(&m, cfg).unwrap();
        assert!(p.numel() > 4 * 64);
        assert!(p.global_norm() > 0.0);
        let z = p.zeros_like();
        assert_eq!(z.numel(), p.numel());
        assert_eq!(z.global_norm(), 0.0);
        assert_eq!(p.literals().unwrap().len(), p.meta.len());
    }
}
