//! Rust reference GAE — cross-checks the AOT kernel and serves the
//! ablation bench (HLO scan vs native loop).

/// GAE over time-major `[T, N]` arrays. Same contract as the Python
/// `ref.gae` / the Pallas kernel: `dones` kills the bootstrap, `truncs`
/// stops advantage propagation but keeps the value bootstrap.
#[allow(clippy::too_many_arguments)]
pub fn gae_ref(
    rewards: &[f32],
    values: &[f32],
    last_value: &[f32],
    dones: &[f32],
    truncs: &[f32],
    t_len: usize,
    n: usize,
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut adv = vec![0.0f32; t_len * n];
    let mut ret = vec![0.0f32; t_len * n];
    for b in 0..n {
        let mut adv_next = 0.0f32;
        let mut v_next = last_value[b];
        for t in (0..t_len).rev() {
            let i = t * n + b;
            let nonterminal = 1.0 - dones[i];
            let nonboundary = nonterminal * (1.0 - truncs[i]);
            let delta = rewards[i] + gamma * v_next * nonterminal - values[i];
            adv[i] = delta + gamma * lam * nonboundary * adv_next;
            ret[i] = adv[i] + values[i];
            adv_next = adv[i];
            v_next = values[i];
        }
    }
    (adv, ret)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_case() {
        // T=2, N=1, gamma=lam=0.5, no dones (mirrors the python test).
        let (adv, ret) = gae_ref(
            &[1.0, 1.0],
            &[0.0, 0.0],
            &[2.0],
            &[0.0, 0.0],
            &[0.0, 0.0],
            2,
            1,
            0.5,
            0.5,
        );
        assert!((adv[1] - 2.0).abs() < 1e-6);
        assert!((adv[0] - 1.5).abs() < 1e-6);
        assert_eq!(adv, ret);
    }

    #[test]
    fn done_cuts_bootstrap() {
        let (adv, _) = gae_ref(
            &[1.0, 1.0],
            &[5.0, 5.0],
            &[100.0],
            &[0.0, 1.0],
            &[0.0, 0.0],
            2,
            1,
            0.99,
            0.95,
        );
        assert!((adv[1] - (1.0 - 5.0)).abs() < 1e-5);
    }

    #[test]
    fn trunc_keeps_value_bootstrap_but_cuts_advantage() {
        let make = |trunc1: f32| {
            gae_ref(
                &[0.0, 0.0, 10.0],
                &[1.0, 1.0, 1.0],
                &[0.0],
                &[0.0, 0.0, 0.0],
                &[0.0, trunc1, 0.0],
                3,
                1,
                1.0,
                1.0,
            )
            .0
        };
        let with_trunc = make(1.0);
        let without = make(0.0);
        // advantage at t<=1 must not see the big t=2 reward through the
        // truncation boundary at t=1...
        assert!(with_trunc[0] < without[0]);
        assert!(with_trunc[1] < without[1]);
        // ...but the t=1 delta itself still bootstraps the next value:
        // delta_1 = 0 + 1*v_2 - v_1 = 0 with these numbers
        assert_eq!(with_trunc[1], 0.0);
    }
}
