//! Time-major rollout storage for PPO: `[T, N, ...]` arrays matching the
//! GAE executable's layout, plus minibatch gathering for the train step.

use crate::rng::Pcg32;

/// Fixed-size rollout buffer.
#[derive(Debug, Clone)]
pub struct RolloutBuffer {
    pub t_len: usize,
    pub n: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    /// `[T, N, obs_dim]`
    pub obs: Vec<f32>,
    /// `[T, N, act_dim]`
    pub actions: Vec<f32>,
    /// `[T, N]`
    pub logp: Vec<f32>,
    /// `[T, N]`
    pub rewards: Vec<f32>,
    /// `[T, N]` — 1.0 where the transition ended an episode (terminal)
    pub dones: Vec<f32>,
    /// `[T, N]` — 1.0 where it was truncated
    pub truncs: Vec<f32>,
    /// `[T, N]` — V(s_t) under the behaviour policy
    pub values: Vec<f32>,
}

impl RolloutBuffer {
    pub fn new(t_len: usize, n: usize, obs_dim: usize, act_dim: usize) -> Self {
        RolloutBuffer {
            t_len,
            n,
            obs_dim,
            act_dim,
            obs: vec![0.0; t_len * n * obs_dim],
            actions: vec![0.0; t_len * n * act_dim],
            logp: vec![0.0; t_len * n],
            rewards: vec![0.0; t_len * n],
            dones: vec![0.0; t_len * n],
            truncs: vec![0.0; t_len * n],
            values: vec![0.0; t_len * n],
        }
    }

    /// Store one time slice (all N envs) at step `t`.
    #[allow(clippy::too_many_arguments)]
    pub fn store(
        &mut self,
        t: usize,
        obs: &[f32],
        actions: &[f32],
        logp: &[f32],
        values: &[f32],
        rewards: &[f32],
        dones: &[u8],
        truncs: &[u8],
    ) {
        debug_assert!(t < self.t_len);
        let n = self.n;
        self.obs[t * n * self.obs_dim..(t + 1) * n * self.obs_dim].copy_from_slice(obs);
        self.actions[t * n * self.act_dim..(t + 1) * n * self.act_dim].copy_from_slice(actions);
        self.logp[t * n..(t + 1) * n].copy_from_slice(logp);
        self.values[t * n..(t + 1) * n].copy_from_slice(values);
        self.rewards[t * n..(t + 1) * n].copy_from_slice(rewards);
        for i in 0..n {
            self.dones[t * n + i] = dones[i] as f32;
            self.truncs[t * n + i] = truncs[i] as f32;
        }
    }

    /// Total rows (T·N).
    pub fn rows(&self) -> usize {
        self.t_len * self.n
    }

    /// Gather a minibatch by flat row indices into the provided buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn gather(
        &self,
        idx: &[usize],
        adv: &[f32],
        ret: &[f32],
        mb_obs: &mut Vec<f32>,
        mb_actions: &mut Vec<f32>,
        mb_logp: &mut Vec<f32>,
        mb_adv: &mut Vec<f32>,
        mb_ret: &mut Vec<f32>,
    ) {
        mb_obs.clear();
        mb_actions.clear();
        mb_logp.clear();
        mb_adv.clear();
        mb_ret.clear();
        for &i in idx {
            mb_obs.extend_from_slice(&self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            mb_actions.extend_from_slice(&self.actions[i * self.act_dim..(i + 1) * self.act_dim]);
            mb_logp.push(self.logp[i]);
            mb_adv.push(adv[i]);
            mb_ret.push(ret[i]);
        }
    }

    /// A shuffled permutation of row indices (one per epoch).
    pub fn shuffled_indices(&self, rng: &mut Pcg32) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.rows()).collect();
        for i in (1..idx.len()).rev() {
            let j = rng.below((i + 1) as u32) as usize;
            idx.swap(i, j);
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_and_gather_roundtrip() {
        let mut buf = RolloutBuffer::new(2, 3, 2, 1);
        for t in 0..2 {
            let obs: Vec<f32> = (0..6).map(|i| (t * 10 + i) as f32).collect();
            let act = [0.0, 1.0, 2.0];
            let logp = [-0.1, -0.2, -0.3];
            let val = [1.0, 2.0, 3.0];
            let rew = [0.5; 3];
            buf.store(t, &obs, &act, &logp, &val, &rew, &[0, 1, 0], &[0, 0, 1]);
        }
        assert_eq!(buf.dones[1 * 3 + 1], 1.0);
        assert_eq!(buf.truncs[3 + 2], 1.0);

        let adv: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let ret: Vec<f32> = (0..6).map(|i| i as f32 * 2.0).collect();
        let (mut o, mut a, mut l, mut ad, mut r) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        buf.gather(&[4, 1], &adv, &ret, &mut o, &mut a, &mut l, &mut ad, &mut r);
        // row 4 = t1,env1: obs [12,13]
        assert_eq!(o, vec![12.0, 13.0, 2.0, 3.0]);
        assert_eq!(a, vec![1.0, 1.0]);
        assert_eq!(ad, vec![4.0, 1.0]);
        assert_eq!(r, vec![8.0, 2.0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let buf = RolloutBuffer::new(4, 4, 1, 1);
        let mut rng = Pcg32::new(3, 3);
        let idx = buf.shuffled_indices(&mut rng);
        let mut sorted = idx.clone();
        sorted.sort();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>());
    }
}
