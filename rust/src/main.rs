//! envpool-rs CLI — leader entrypoint.
//!
//! Subcommands:
//! - `envpool info`                      — list tasks and specs
//! - `envpool bench ...`                 — pure env-simulation throughput;
//!                                         `--scenario file.scn` benches a
//!                                         heterogeneous mixed-task pool
//! - `envpool train ...`                 — PPO training; `--backend
//!                                         {auto,pjrt,native}` selects the
//!                                         compute tier (native is pure
//!                                         Rust, needs no artifacts),
//!                                         `--precision {f64,f32}` picks the
//!                                         native arithmetic (f32 = SIMD
//!                                         fast path, f64 master weights),
//!                                         `--lane-width {1,4,8,auto}` the
//!                                         env-kernel SIMD width,
//!                                         `--eval-episodes N` runs greedy
//!                                         evaluation after training,
//!                                         `--curve out.csv` dumps the
//!                                         learning curve,
//!                                         `--target-return R` stops early,
//!                                         `--async-train` runs the
//!                                         decoupled actor–learner loop on
//!                                         an async executor
//!                                         (envpool-async[-vec]) and
//!                                         `--max-policy-lag L` bounds its
//!                                         mid-update sampling staleness
//! - `envpool profile ...`               — Figure-4 time breakdown
//! - `envpool serve ...`                 — own a pool and lease env ranges
//!                                         to other processes over a Unix
//!                                         socket + shared-memory rings
//!                                         (`--env --socket --max-clients
//!                                         --lease-size --ring-slots
//!                                         --heartbeat-ms --max-seconds`)
//! - `envpool attach ...`                — attach to a running server,
//!                                         step a leased env range with a
//!                                         fixed policy, report fps
//!                                         (`--socket --num-envs --steps`)
//! - `envpool worker --task T --seed S --env-id I`
//!                                       — subprocess-executor worker
//!                                         (internal; speaks IPC on stdio)

use envpool::cli::Args;
use envpool::config::TrainConfig;
use envpool::envs::registry;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    let args = Args::parse(argv);
    let code = match sub.as_str() {
        "worker" => cmd_worker(&args),
        "info" => cmd_info(),
        "bench" => cmd_bench(&args),
        "train" => cmd_train(&args),
        "profile" => cmd_profile(&args),
        "serve" => cmd_serve(&args),
        "attach" => cmd_attach(&args),
        _ => {
            eprintln!(
                "usage: envpool <worker|info|bench|train|profile|serve|attach> [--key value ...]\n\
                 see README.md for the full flag reference"
            );
            2
        }
    };
    std::process::exit(code);
}

/// Subprocess-executor worker: serve one env over stdio.
fn cmd_worker(args: &Args) -> i32 {
    let task = args.get("task", "CartPole-v1").to_string();
    let seed: u64 = args.parse_or("seed", 0);
    let env_id: u64 = args.parse_or("env-id", 0);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut r = stdin.lock();
    let mut w = std::io::BufWriter::new(stdout.lock());
    match envpool::executors::ipc::worker_serve(&task, seed, env_id, &mut r, &mut w) {
        Ok(()) => 0,
        Err(e) => {
            // Parent closing the pipe mid-read is a normal shutdown path.
            eprintln!("worker exit: {e}");
            0
        }
    }
}

fn cmd_info() -> i32 {
    println!("envpool-rs — registered tasks:");
    for &t in registry::ALL_TASKS {
        let s = registry::spec_for(t).unwrap();
        println!(
            "  {:<16} obs {:?}  actions {:?}  max_steps {}",
            t, s.obs_shape, s.action_space, s.max_episode_steps
        );
    }
    0
}

/// Pure env-simulation throughput (the Table-1 measurement, one cell).
fn cmd_bench(args: &Args) -> i32 {
    let task = args.get("env", "Pong-v5").to_string();
    let executor = args.get("executor", "envpool-async").to_string();
    let num_envs: usize = args.parse_or("num-envs", 8);
    let batch_size: usize = args.parse_or("batch-size", num_envs.div_ceil(2));
    let threads: usize = args.parse_or("num-threads", 4);
    let steps: u64 = args.parse_or("steps", 10_000);
    let seed: u64 = args.parse_or("seed", 0);
    let lane_pass: envpool::simd::LanePass = match args.get("lane-width", "auto").parse() {
        Ok(lp) => lp,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    // `--scenario <file>` benches a heterogeneous mixed-task pool
    // instead of a single `--env`.
    if let Some(path) = args.opt("scenario") {
        let sc = match envpool::config::ScenarioConfig::load(path) {
            Ok(sc) => sc,
            Err(e) => {
                eprintln!("cannot load scenario {path}: {e}");
                return 2;
            }
        };
        return match envpool::coordinator::throughput::run_throughput_scenario(
            &sc, &executor, threads, steps, seed, lane_pass,
        ) {
            Ok(fps) => {
                println!(
                    "scenario={path} executor={executor} num_envs={} threads={threads} \
                     lane_width={} steps={steps} fps={fps:.0}",
                    sc.num_envs(),
                    lane_pass.width()
                );
                0
            }
            Err(e) => {
                eprintln!("bench failed: {e}");
                1
            }
        };
    }
    match envpool::coordinator::throughput::run_throughput_lanes(
        &task, &executor, num_envs, batch_size, threads, steps, seed, lane_pass,
    ) {
        Ok(fps) => {
            println!(
                "env={task} executor={executor} num_envs={num_envs} batch_size={batch_size} \
                 threads={threads} lane_width={} steps={steps} fps={fps:.0}",
                lane_pass.width()
            );
            0
        }
        Err(e) => {
            eprintln!("bench failed: {e}");
            1
        }
    }
}

fn cmd_train(args: &Args) -> i32 {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.opt("config") {
        match envpool::config::KvFile::load(path) {
            Ok(f) => {
                if let Err(e) = cfg.apply_file(&f) {
                    eprintln!("config error: {e}");
                    return 2;
                }
            }
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                return 2;
            }
        }
    }
    if let Err(e) = cfg.apply_args(args) {
        eprintln!("config error: {e}");
        return 2;
    }
    match envpool::coordinator::ppo::train(&cfg) {
        Ok(summary) => {
            println!("{}", summary.render());
            if let Some(path) = args.opt("curve") {
                if let Err(e) = summary.write_curve_csv(path) {
                    eprintln!("cannot write learning curve: {e}");
                    return 1;
                }
                println!("learning curve -> {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            1
        }
    }
}

/// Own a pool and serve it to other processes (`envpool serve`).
fn cmd_serve(args: &Args) -> i32 {
    let task = args.get("env", "CartPole-v1").to_string();
    let socket = args.get("socket", "/tmp/envpool.sock").to_string();
    let mut cfg = envpool::config::ServeConfig::new(&task, socket)
        .max_clients(args.parse_or("max-clients", 2))
        .lease_size(args.parse_or("lease-size", 8))
        .seed(args.parse_or("seed", 0))
        .ring_slots(args.parse_or("ring-slots", 4));
    let threads: usize = args.parse_or("num-threads", 0);
    if threads > 0 {
        cfg = cfg.num_threads(threads);
    }
    if let Some(d) = args.opt("slab-dir") {
        cfg = cfg.slab_dir(d);
    }
    let hb_ms: u64 = args.parse_or("heartbeat-ms", 0);
    if hb_ms > 0 {
        cfg = cfg.heartbeat_timeout(Some(std::time::Duration::from_millis(hb_ms)));
    }
    // `--max-seconds` lets CI run a self-terminating server; 0 = forever.
    let max_seconds: u64 = args.parse_or("max-seconds", 0);
    let max = if max_seconds > 0 { Some(max_seconds) } else { None };
    match envpool::executors::serve::serve_blocking(cfg, max) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

/// Attach to a running pool server and step the lease (`envpool attach`).
fn cmd_attach(args: &Args) -> i32 {
    use envpool::executors::{ShmClient, VectorEnv};
    let socket = args.get("socket", "/tmp/envpool.sock").to_string();
    let num_envs: usize = args.parse_or("num-envs", 8);
    let steps: u64 = args.parse_or("steps", 10_000);
    let mut client = match ShmClient::attach(std::path::Path::new(&socket), num_envs) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("attach failed: {e}");
            return 1;
        }
    };
    println!(
        "attached lease {} (envs {}..{}) via {socket}",
        client.lease(),
        client.first_env(),
        client.first_env() + num_envs as u32
    );
    let act_dim = client.spec().action_space.dim();
    let mut out = client.make_output();
    if let Err(e) = client.reset(&mut out) {
        eprintln!("reset failed: {e}");
        return 1;
    }
    let mut acts = vec![0.0f32; num_envs * act_dim];
    let t0 = std::time::Instant::now();
    for t in 0..steps {
        for i in 0..num_envs {
            for d in 0..act_dim {
                acts[i * act_dim + d] = ((t as usize + i) % 2) as f32;
            }
        }
        if let Err(e) = client.step(&acts, &mut out) {
            eprintln!("step {t} failed: {e}");
            return 1;
        }
    }
    let fps = (steps * num_envs as u64) as f64 / t0.elapsed().as_secs_f64();
    println!("attach: num_envs={num_envs} steps={steps} fps={fps:.0}");
    if let Err(e) = client.detach() {
        eprintln!("detach failed: {e}");
        return 1;
    }
    0
}

fn cmd_profile(args: &Args) -> i32 {
    let mut cfg = TrainConfig::default();
    if let Err(e) = cfg.apply_args(args) {
        eprintln!("config error: {e}");
        return 2;
    }
    match envpool::coordinator::ppo::train_profiled(&cfg) {
        Ok((summary, breakdown)) => {
            println!("{}", summary.render());
            println!("{}", breakdown.render(&format!("{} / {}", cfg.env_id, cfg.executor)));
            0
        }
        Err(e) => {
            eprintln!("profile failed: {e}");
            1
        }
    }
}
