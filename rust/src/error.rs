//! Crate-wide error type.

/// Unified error type for envpool-rs.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Unknown environment id passed to `envs::registry::make`.
    #[error("unknown environment task id: {0}")]
    UnknownEnv(String),

    /// Invalid pool / executor configuration.
    #[error("invalid configuration: {0}")]
    Config(String),

    /// An action batch referenced an env id outside the pool.
    #[error("env id {id} out of range (num_envs = {num_envs})")]
    BadEnvId { id: usize, num_envs: usize },

    /// Action batch shape does not match the env ids given.
    #[error("action batch length {actions} != env id count {ids}")]
    ActionShape { actions: usize, ids: usize },

    /// The pool was already closed (threads joined).
    #[error("pool is closed")]
    Closed,

    /// XLA / PJRT error from the runtime layer.
    #[error("xla: {0}")]
    Xla(String),

    /// Artifact (HLO / manifest) loading problems.
    #[error("artifact: {0}")]
    Artifact(String),

    /// IPC framing error in the subprocess executor.
    #[error("ipc: {0}")]
    Ipc(String),

    /// Underlying I/O error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
