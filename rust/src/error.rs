//! Crate-wide error type. Hand-rolled `Display`/`Error` impls — the
//! vendored crate set has no `thiserror`.

/// Unified error type for envpool-rs.
#[derive(Debug)]
pub enum Error {
    /// Unknown environment id passed to `envs::registry::make`.
    UnknownEnv(String),

    /// Invalid pool / executor configuration.
    Config(String),

    /// An action batch referenced an env id outside the pool.
    BadEnvId { id: usize, num_envs: usize },

    /// Action batch shape does not match the env ids given.
    ActionShape { actions: usize, ids: usize },

    /// The pool was already closed (threads joined).
    Closed,

    /// XLA / PJRT error from the runtime layer.
    Xla(String),

    /// Artifact (HLO / manifest) loading problems.
    Artifact(String),

    /// IPC framing error in the subprocess executor.
    Ipc(String),

    /// Attach handshake to a pool server was refused (socket level).
    Attach(String),

    /// Lease protocol violation on an attached client (backpressure
    /// exceeded, wrong wave size, lease exhausted, ...).
    Lease(String),

    /// Underlying I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::UnknownEnv(id) => write!(f, "unknown environment task id: {id}"),
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::BadEnvId { id, num_envs } => {
                write!(f, "env id {id} out of range (num_envs = {num_envs})")
            }
            Error::ActionShape { actions, ids } => {
                write!(f, "action batch length {actions} != env id count {ids}")
            }
            Error::Closed => write!(f, "pool is closed"),
            Error::Xla(msg) => write!(f, "xla: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact: {msg}"),
            Error::Ipc(msg) => write!(f, "ipc: {msg}"),
            Error::Attach(msg) => write!(f, "attach refused: {msg}"),
            Error::Lease(msg) => write!(f, "lease: {msg}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_stable() {
        assert_eq!(Error::UnknownEnv("X-v0".into()).to_string(), "unknown environment task id: X-v0");
        assert_eq!(
            Error::BadEnvId { id: 9, num_envs: 4 }.to_string(),
            "env id 9 out of range (num_envs = 4)"
        );
        assert_eq!(
            Error::ActionShape { actions: 2, ids: 1 }.to_string(),
            "action batch length 2 != env id count 1"
        );
        assert_eq!(Error::Closed.to_string(), "pool is closed");
        assert_eq!(Error::Attach("full".into()).to_string(), "attach refused: full");
        assert_eq!(Error::Lease("overrun".into()).to_string(), "lease: overrun");
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.source().is_some());
        assert!(e.to_string().starts_with("io: "));
    }
}
