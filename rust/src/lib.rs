//! # envpool-rs — EnvPool (NeurIPS 2022) reproduction in Rust
//!
//! A highly parallel reinforcement-learning environment execution engine,
//! reproducing Weng et al., *EnvPool: A Highly Parallel Reinforcement
//! Learning Environment Execution Engine* (NeurIPS 2022), as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution: an asynchronous,
//!   threadpool-based environment executor built from three components:
//!   a lock-free [`pool::ActionBufferQueue`], a pinned
//!   [`pool::ThreadPool`], and a pre-allocated, block-structured
//!   [`pool::StateBufferQueue`]. Plus every substrate the paper evaluates
//!   on: Atari-like ([`envs::atari`]), MuJoCo-like ([`envs::mujoco`]),
//!   dm_control-like ([`envs::dmc`]) and classic-control environments,
//!   and the baseline executors it compares against ([`executors`]).
//! - **L2 (JAX, build-time)** — actor-critic forward/backward + PPO update,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! - **L1 (Pallas, build-time)** — the fused linear and GAE kernels inside
//!   the L2 graph, verified against pure-jnp oracles.
//!
//! The AOT artifacts are executed from Rust through PJRT ([`runtime`]);
//! Python never runs on the request path. When PJRT/artifacts are absent
//! the pure-Rust `native` compute backend ([`runtime::native`]) replaces
//! L1/L2 entirely, so `envpool train` works in every checkout.
//!
//! ## Quickstart
//!
//! ```no_run
//! use envpool::pool::{EnvPool, PoolConfig};
//!
//! // Asynchronous mode: num_envs > batch_size (paper §3.2).
//! let cfg = PoolConfig::new("CartPole-v1").num_envs(12).batch_size(8).num_threads(4);
//! let mut pool = EnvPool::make(cfg).unwrap();
//! pool.async_reset();
//! for _ in 0..100 {
//!     let batch = pool.recv().unwrap();
//!     let actions = vec![0.0f32; batch.len()];
//!     pool.send(&actions, &batch.env_ids).unwrap();
//! }
//! ```
//!
//! Synchronous mode is the special case `num_envs == batch_size`; the
//! [`pool::EnvPool::step_into`] convenience wraps `send`+`recv`. For
//! cheap environments, `PoolConfig::exec_mode(ExecMode::Vectorized)`
//! switches the workers to chunked struct-of-arrays execution
//! ([`envs::vector`]), amortizing per-step dispatch overhead.
//!
//! ## ExecMode / kernel support matrix
//!
//! Vectorized execution is the engine's primary abstraction: every
//! registered env family has a real batch kernel, the wrapper stack
//! ([`envs::wrappers`]) composes identically in both modes, and every
//! pool flavor (including NUMA shards) accepts either `ExecMode`. On
//! top of the SoA layout, kernels with a **SIMD lane pass** step whole
//! lane groups of envs per instruction ([`simd`]; width selected by
//! `PoolConfig::lane_pass` / `--lane-width {1,4,8,auto}`, width 1 = the
//! scalar reference loop). The classic-control kernels — instances of
//! one generic SoA driver ([`envs::vector::SoaKernel`]) — are
//! **bitwise identical at every width**: the shared trig twins
//! ([`simd::math`]) and lane-group dynamics apply the same operations
//! in the same order as the scalar code (`tests/simd_parity.rs`
//! asserts 0 ULP per step, including masked tails and mid-batch
//! resets). The MuJoCo walkers are **batch-resident**: body state,
//! joint warm starts and contact caches live in **body-major**
//! (`[body * lanes + lane]`) SoA lanes inside
//! [`envs::mujoco::WorldBatch`], so every lane-group load/store in the
//! lane-grouped sequential-impulse solver is one contiguous slice read
//! instead of a strided gather; width 1 is bitwise with the pre-batch
//! scalar path (the scalar env *is* a width-1 view), widths 4/8 follow
//! a **documented, asserted tolerance budget**
//! (`tests/mujoco_batch_parity.rs`). The Atari path batches the
//! **emulator itself** on top of its slab-resident pixel state:
//! [`envs::vector::AtariVec`] holds per-game SoA lane state
//! ([`envs::vector::atari_emulate`]) and runs the frameskip loop as
//! **masked lane-group tick passes** (branches become selects that
//! apply the identical scalar operation per lane; RNG draws stay
//! per-lane in lane order), then packs all lanes' native frames and
//! stack rings contiguously and runs the pure pixel math (2-frame
//! max-pool, 2×2 downsample, stack push, readout) as a lane-streaming
//! SoA pass — bitwise identical to the per-env path **at every lane
//! width** (shared `PreprocCore`; `tests/atari_emulate_parity.rs`).
//!
//! | env family | `ExecMode::Scalar` | SoA kernel | SIMD lane pass | parity |
//! |---|---|---|---|---|
//! | classic control (4 tasks) | per-env tasks | `CartPoleVec`, ... (shared `SoaKernel` driver) | full dynamics (incl. RK4 / trig) | bitwise at every width |
//! | MuJoCo walkers (`Hopper/HalfCheetah/Ant-v4`) | per-env tasks (each a width-1 `WorldBatch` view) | `WalkerVec` over batch-resident, body-major `WorldBatch` (contiguous body/joint/contact lane groups) | full constraint solver (masked lane groups) + batch task pass | bitwise at width 1; asserted tolerance budget at 4/8 |
//! | Atari (`Pong/Breakout-v5`) | per-env tasks | `AtariVec` (SoA game state + contiguous pixel slab, SoA preproc pass, shared `PreprocCore`) | masked lane-group emulator tick passes (`atari_emulate`) | bitwise at every width |
//! | dm_control (`cheetah_run`) | per-env tasks (width-1 view) | `CheetahRunVec` (shaping over `WalkerVec`) | inherits `WalkerVec` | bitwise at width 1; tolerance budget at 4/8 |
//! | wrappers (`TimeLimit`/`RewardClip`/`NormalizeObs`) | one-lane adapters | batch-wise `VecWrapper` layer (forwards `set_lane_pass`) | — | bitwise (shared cores) |
//!
//! Executors: `forloop`/`subprocess` are scalar by construction;
//! `forloop-vec` and `sample-factory-vec` drive the same kernels
//! synchronously; `envpool-{sync,async}[-vec]` select the pool engine;
//! `envpool-numa-async[-vec]` shards either engine across logical NUMA
//! nodes ([`pool::NumaPool`]). Out-of-registry envs can still opt into
//! chunked dispatch via [`envs::vector::ScalarVec`] explicitly.
//!
//! Training support per executor: the synchronous PPO trainer drives
//! `forloop[-vec]`, `subprocess`, and `envpool-sync[-vec]`;
//! `envpool-async[-vec]` additionally drives the **decoupled
//! actor–learner loop** (`--async-train`, [`coordinator::async_ppo`]):
//! pool workers step envs continuously into a double-buffered
//! rollout-resident [`agent::TrajStore`] while the learner updates on
//! the previous round, with per-transition policy-version tracking
//! (staleness reported in the train summary, bounded by
//! `--max-policy-lag`). The remaining kinds
//! (`envpool-numa-async[-vec]`, `sample-factory[-vec]`) are
//! benchmark-only.
//!
//! Wrapper knobs per `ExecMode`: per-lane `NormalizeObs` is available in
//! both modes (bitwise identical); pooled `normalize_obs_shared` (gym
//! `VecNormalize`-style, one statistic across a chunk's lanes) exists
//! only on the vectorized surface and is rejected by the scalar one.
//!
//! ## Heterogeneous scenario pools
//!
//! A [`config::ScenarioConfig`] (dependency-free `.scn` text, exact
//! `parse`/`to_text` round-trip — see `examples/scenarios/mixed.scn`)
//! describes an ordered list of **lane groups**: a task, a lane count,
//! a per-group wrapper stack, an optional seed, fixed `param.*`
//! physics overrides and seeded `jitter.*` per-lane ranges. One
//! pool then executes the mix: `PoolConfig::scenario` (CLI:
//! `envpool bench --scenario file.scn`, `envpool train --scenario`)
//! builds one full-width kernel per group and composes them behind
//! [`pool::GroupedVecEnv`] — a stable global `env_id → (group, lane)`
//! map, per-group obs arenas over group-offset rows (union-width rows,
//! zero-padded tails; chunking never splits a group), and per-group
//! action re-striding from the union action layout. Group kernels are
//! seeded with the **group seed** and group-local env ids, so each
//! group's per-env episodes are **bitwise identical** to a homogeneous
//! pool with the same task/seed/wrappers (`tests/scenario.rs` pins the
//! 3-group classic trio at widths 1/4/8 and a classic+walker+Atari mix
//! at width 1 across both `ExecMode`s, under mid-run auto-resets).
//! Domain randomization is first-class: every classic/walker kernel
//! takes **per-lane parameter lanes** (SoA, broadcast constants by
//! default — bitwise-unchanged when no override is set), and jitters
//! are drawn at construction from a dedicated `Pcg32` stream keyed by
//! `(group seed ^ JITTER_SALT, parameter index)` — independent of
//! exec mode, threads and chunking, so a scenario file + pool seed is
//! exactly replayable. The Table 2h bench
//! (`benches/table2h_hetero.rs`) gates the composition overhead: the
//! mixed pool must hold ≥ 0.9× the aggregate throughput of the same
//! groups run as separate homogeneous pools.
//!
//! | surface | heterogeneous (scenario) support |
//! |---|---|
//! | `EnvPool` sync, `ExecMode::Scalar` | ✓ per-lane `VecLaneEnv` views (group-seeded, width-1 kernels) |
//! | `EnvPool` sync, `ExecMode::Vectorized` | ✓ one chunk per group, full-width group kernels |
//! | async pools / `NumaPool` | ✗ rejected at config validation (sharding would split groups) |
//! | pool-level `PoolConfig::wrappers` | ✗ rejected — wrappers live on each group |
//! | `EnvSpec` | union spec (max obs/action dims, zero-padded) + per-group [`envs::spec::GroupView`]s |
//! | PPO trainer (`--scenario`) | ✓ on `envpool-sync[-vec]` for uniform-spec scenarios (single policy head) |
//! | physics params (`param.*` / `jitter.*`) | classic + walker families ([`envs::registry::supported_params`]); Acrobot/Atari: none |
//!
//! ## Serving the pool across processes
//!
//! `envpool serve` moves the pool out of the trainer's process: a
//! [`executors::serve::PoolServer`] owns one asynchronous scalar
//! [`pool::EnvPool`] (`max_clients × lease_size` envs, batch size
//! `lease_size`) and leases disjoint env ranges to clients. A
//! [`executors::ShmClient`] (`envpool attach`, or in-process via
//! [`executors::serve::PoolServer::start`] + `ShmClient::attach`) is a
//! full [`executors::VectorEnv`] whose envs live in the server. Data
//! rides per-lease shared-memory rings ([`executors::shm`]) with a
//! two-phase commit — positioned slab write, then a tiny sequence-number
//! frame on the Unix control socket — mirroring the in-process state
//! queue's `slot_obs_mut`/`commit` split; control frames reuse the
//! [`executors::ipc`] length-prefixed framing with hostile-input bounds.
//! Clients may pipeline up to `ring_slots - 1` waves (checked on both
//! sides). A dead client (socket EOF or missed `--heartbeat-ms` window)
//! has its lease drained, its envs reset, and the fresh initial batch
//! parked for the next attach, so served trajectories stay reproducible:
//! each env is seeded `(seed, env_id)` exactly as in-process, and every
//! attach begins with exactly one reset of the lease's envs
//! (`tests/serve.rs` pins two attached clients against an in-process
//! pool, episode-for-episode).
//!
//! | surface | served (`serve`/`attach`) behavior |
//! |---|---|
//! | exec mode | `ExecMode::Scalar` only (lease reclaim resets individual env ids; chunked kernels reset whole groups) |
//! | batching | full waves per lease (`lease_size` actions per `Step`), async across leases |
//! | clients | `--max-clients` leases; attach refused beyond capacity; re-attach after reclaim |
//! | backpressure | ring credits (`ring_slots - 1` outstanding waves) enforced client- and server-side |
//! | client death | EOF/heartbeat → drain in-flight wave, reset lease envs, park initial batch, log `lease N reclaimed` |
//! | determinism | per-env `(seed, env_id)` streams; one reset per attach; matches in-process pool per env id |
//! | transport | tmpfs-backed slabs + positioned I/O (no `mmap`: std-only, see [`executors::shm`] docs) |
//!
//! ## Compute-tier backend matrix
//!
//! `envpool train` / `envpool profile` drive a
//! [`runtime::ComputeBackend`] (`--backend {auto,pjrt,native}`;
//! `auto`, the default, picks PJRT when present and falls back to
//! native, so the trainer never degrades to "skip"). The native
//! backend has two precisions (`--precision {f64,f32}`): `f64` is the
//! scalar reference (finite-difference-provable), `f32` the SIMD fast
//! path — f32 compute weights mirrored from **f64 master weights**
//! (plus transposed GEMM layouts), re-demoted after every Adam step,
//! with the PPO head math still in f64 so both precisions share every
//! branch decision. The f32 forward runs the cache-blocked
//! transposed-weights GEMM ([`simd::gemm_bt_f32`], per-element
//! reassociation budget vs the sequential GEMV) and the deterministic
//! `tanh` twin ([`simd::math::tanh_f32`], ≤ 2 ULP vs demoted f64
//! libm). Documented f32-vs-f64 budget (asserted by `runtime::native`
//! tests): loss/entropy within 1e-4 relative, per-element gradients
//! within `1e-4 + 1e-2·|g|` on identical minibatches; FD gradient
//! checks re-run under f32; reruns are bit-exact.
//!
//! | capability | `pjrt` (AOT artifacts) | `native` `--precision f64` | `native` `--precision f32` |
//! |---|---|---|---|
//! | policy forward (logits / mu+log_std, value) | compiled HLO via PJRT | f64 MLP, 2×Tanh trunk ([`runtime::NativeNet`]) | f32 blocked transposed-weights GEMM + `tanh` lane twin |
//! | PPO update (clip + value + entropy) | compiled train step | analytic backprop + grad-norm clip + Adam | f32 blocked-GEMM fwd / SIMD bwd, f64 head + Adam on master weights |
//! | GAE | compiled scan kernel (Pallas-lowerable) | [`agent::gae::gae_ref`] | [`agent::gae::gae_ref`] |
//! | requirements | real `xla` bindings + `make artifacts` | none — the crate alone | none — the crate alone |
//! | shapes/schedule source | artifact manifest | [`config::TrainConfig`] | [`config::TrainConfig`] |
//! | determinism | per artifact | exact (`Pcg32`-seeded init, f64 math) | exact rerun (fixed lane dispatch) |

pub mod error;
pub mod rng;
pub mod simd;
pub mod cli;
pub mod prop;
pub mod config;
pub mod envs;
pub mod pool;
pub mod executors;
pub mod runtime;
pub mod agent;
pub mod coordinator;
pub mod metrics;
pub mod bench_util;

pub use error::{Error, Result};
