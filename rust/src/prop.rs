//! Mini property-testing framework (the vendored crate set has no
//! `proptest`). Seeded generators + a `forall` driver with shrinking-free
//! but reproducible counterexample reporting: every failure prints the
//! case index and seed so it can be replayed exactly.

use crate::rng::Pcg32;

/// Number of cases per property, overridable via `ENVPOOL_PROP_CASES`.
pub fn num_cases() -> usize {
    std::env::var("ENVPOOL_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// A generation context handed to properties.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg32,
}

impl<'a> Gen<'a> {
    /// usize uniform in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    /// f32 uniform in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    /// Random boolean.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Vector of given length generated per-element.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'s, T>(&mut self, xs: &'s [T]) -> &'s T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// A permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.usize_in(0, i);
            v.swap(i, j);
        }
        v
    }
}

/// Run `prop` against `num_cases()` generated inputs. The property
/// returns `Err(msg)` to signal failure; panics with seed + case index.
pub fn forall<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed: u64 = std::env::var("ENVPOOL_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_CAFE);
    for case in 0..num_cases() {
        let mut rng = Pcg32::new(base_seed, case as u64);
        let mut g = Gen { rng: &mut rng };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: ENVPOOL_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_is_permutation() {
        forall("perm", |g| {
            let n = g.usize_in(1, 50);
            let p = g.permutation(n);
            let mut seen = vec![false; n];
            for &x in &p {
                prop_assert!(x < n, "out of range {x}");
                prop_assert!(!seen[x], "duplicate {x}");
                seen[x] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn usize_in_bounds() {
        forall("bounds", |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let x = g.usize_in(lo, hi);
            prop_assert!(x >= lo && x <= hi, "{x} not in [{lo},{hi}]");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failure_reports() {
        forall("always-fails", |_| Err("nope".into()));
    }
}
