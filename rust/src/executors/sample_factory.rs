//! Sample-Factory-style executor (Petrenko et al. 2020): each worker
//! thread owns a fixed set of environments and steps them continuously
//! in a double-buffered fashion — while the consumer holds buffer A, the
//! worker fills buffer B. There is no global per-step barrier, but —
//! unlike EnvPool — batches are per-worker (fixed membership), and the
//! consumer must poll workers round-robin.

use crate::envs::env::Step;
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::envs::vector::{ScalarVec, SliceArena, VecEnv};
use crate::pool::batch::BatchedTransition;
use crate::pool::sem::Semaphore;
use crate::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One worker's shared double buffer.
struct WorkerShared {
    /// Buffer the worker fills next (swapped with the consumer's).
    ready: Mutex<BatchedTransition>,
    /// Actions for the worker's envs (set by the consumer before release).
    actions: Mutex<Vec<f32>>,
    /// Worker may start the next rollout step.
    go: Semaphore,
    /// A filled buffer is available.
    done: Semaphore,
    stop: AtomicBool,
}

/// Double-buffered asynchronous sampler.
pub struct SampleFactoryExecutor {
    spec: EnvSpec,
    shared: Vec<Arc<WorkerShared>>,
    handles: Vec<JoinHandle<()>>,
    envs_per_worker: usize,
    /// Which worker to poll next (round-robin fairness).
    cursor: usize,
}

impl SampleFactoryExecutor {
    /// `num_envs` split evenly over `num_workers` threads, stepped
    /// per-env (each worker wraps its set in a [`ScalarVec`]).
    pub fn new(task_id: &str, num_envs: usize, num_workers: usize, seed: u64) -> Result<Self> {
        Self::with_backend(task_id, num_envs, num_workers, seed, None)
    }

    /// Like [`Self::new`] but each worker steps its env set through the
    /// task's struct-of-arrays kernel ([`crate::envs::vector`]) — the
    /// fair double-buffered baseline against `ExecMode::Vectorized`.
    pub fn new_vectorized(
        task_id: &str,
        num_envs: usize,
        num_workers: usize,
        seed: u64,
    ) -> Result<Self> {
        Self::with_backend(task_id, num_envs, num_workers, seed, Some(crate::simd::LanePass::Auto))
    }

    /// [`Self::new_vectorized`] with an explicit SIMD lane width for the
    /// workers' kernels (bitwise identical at every width; the
    /// throughput driver pins widths through this).
    pub fn new_vectorized_with_lanes(
        task_id: &str,
        num_envs: usize,
        num_workers: usize,
        seed: u64,
        lane_pass: crate::simd::LanePass,
    ) -> Result<Self> {
        Self::with_backend(task_id, num_envs, num_workers, seed, Some(lane_pass))
    }

    fn with_backend(
        task_id: &str,
        num_envs: usize,
        num_workers: usize,
        seed: u64,
        vectorized: Option<crate::simd::LanePass>,
    ) -> Result<Self> {
        if num_workers == 0 || num_envs % num_workers != 0 {
            return Err(crate::Error::Config(format!(
                "num_envs {num_envs} must divide over {num_workers} workers"
            )));
        }
        let spec = registry::spec_for(task_id)?;
        let per = num_envs / num_workers;
        let dim = spec.obs_dim();
        let adim = spec.action_space.dim();
        let mut shared = Vec::new();
        let mut handles = Vec::new();
        for w in 0..num_workers {
            let sh = Arc::new(WorkerShared {
                ready: Mutex::new(BatchedTransition::with_capacity(per, dim)),
                actions: Mutex::new(vec![0.0; per * adim]),
                go: Semaphore::new(0),
                done: Semaphore::new(0),
                stop: AtomicBool::new(false),
            });
            shared.push(sh.clone());
            let task = task_id.to_string();
            handles.push(std::thread::spawn(move || {
                // Per-env semantics and RNG streams are identical either
                // way (the SoA kernels are bitwise-equal to the scalar
                // envs); `vectorized` only changes the stepping engine.
                let first = (w * per) as u64;
                let mut envs: Box<dyn VecEnv> = if let Some(lp) = vectorized {
                    let mut k = registry::make_vec_env(&task, seed, first, per).unwrap();
                    k.set_lane_pass(lp);
                    k
                } else {
                    Box::new(ScalarVec::new(&task, seed, first, per).unwrap())
                };
                let mut needs_reset = vec![0u8; per];
                let mut results = vec![Step::default(); per];
                let mut local = BatchedTransition::with_capacity(per, dim);
                // Reused across steps: cloning the action vector out of
                // the mutex every step put an allocation on the hot path
                // of every worker (N/num_workers × act_dim floats per
                // step); copy into this fixed buffer under the lock
                // instead.
                let mut action_buf = vec![0.0f32; per * adim];
                // initial reset fills the first buffer
                for i in 0..per {
                    envs.reset_lane(i, &mut local.obs[i * dim..(i + 1) * dim]);
                    local.env_ids[i] = (w * per + i) as u32;
                }
                loop {
                    // publish `local`, wait for actions, fill again
                    {
                        let mut slot = sh.ready.lock().unwrap();
                        std::mem::swap(&mut *slot, &mut local);
                    }
                    sh.done.post();
                    sh.go.wait();
                    if sh.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    action_buf.copy_from_slice(&sh.actions.lock().unwrap());
                    {
                        let mut arena = SliceArena::new(&mut local.obs, dim);
                        envs.step_batch(&action_buf, &needs_reset, &mut arena, &mut results);
                    }
                    for (i, s) in results.iter().enumerate() {
                        local.rew[i] = s.reward;
                        local.done[i] = s.done as u8;
                        local.trunc[i] = s.truncated as u8;
                        needs_reset[i] = s.finished() as u8;
                        local.env_ids[i] = (w * per + i) as u32;
                    }
                }
            }));
        }
        Ok(SampleFactoryExecutor { spec, shared, handles, envs_per_worker: per, cursor: 0 })
    }

    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    pub fn envs_per_worker(&self) -> usize {
        self.envs_per_worker
    }

    pub fn num_workers(&self) -> usize {
        self.shared.len()
    }

    /// Receive the next available per-worker batch (round-robin). The
    /// returned ids tell you whose actions to provide in [`Self::send`].
    pub fn recv_into(&mut self, out: &mut BatchedTransition) -> usize {
        let w = self.cursor;
        self.cursor = (self.cursor + 1) % self.shared.len();
        let sh = &self.shared[w];
        sh.done.wait();
        let mut slot = sh.ready.lock().unwrap();
        std::mem::swap(&mut *slot, out);
        w
    }

    /// Provide actions for worker `w`'s envs and release it for its next
    /// step (double-buffer handoff).
    pub fn send(&self, w: usize, actions: &[f32]) {
        let sh = &self.shared[w];
        sh.actions.lock().unwrap().copy_from_slice(actions);
        sh.go.post();
    }

    /// A per-worker-sized output buffer.
    pub fn make_output(&self) -> BatchedTransition {
        BatchedTransition::with_capacity(self.envs_per_worker, self.spec.obs_dim())
    }
}

impl Drop for SampleFactoryExecutor {
    fn drop(&mut self) {
        for sh in &self.shared {
            sh.stop.store(true, Ordering::Relaxed);
            sh.go.post();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_serves_all_workers() {
        let mut ex = SampleFactoryExecutor::new("CartPole-v1", 8, 2, 3).unwrap();
        let mut out = ex.make_output();
        let mut seen = vec![0u32; 8];
        for _ in 0..40 {
            let w = ex.recv_into(&mut out);
            for &id in &out.env_ids {
                seen[id as usize] += 1;
            }
            let actions = vec![1.0f32; out.len()];
            ex.send(w, &actions);
        }
        assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
    }

    #[test]
    fn uneven_split_rejected() {
        assert!(SampleFactoryExecutor::new("CartPole-v1", 7, 2, 0).is_err());
        assert!(SampleFactoryExecutor::new_vectorized("CartPole-v1", 7, 2, 0).is_err());
    }

    #[test]
    fn vectorized_backend_matches_scalar_backend() {
        // Round-robin polling is deterministic, so the full transition
        // stream must be identical between stepping engines.
        let run = |vectorized: bool| -> (Vec<f32>, Vec<u8>) {
            let mut ex = if vectorized {
                SampleFactoryExecutor::new_vectorized("CartPole-v1", 4, 2, 9).unwrap()
            } else {
                SampleFactoryExecutor::new("CartPole-v1", 4, 2, 9).unwrap()
            };
            let mut out = ex.make_output();
            let mut rew = Vec::new();
            let mut done = Vec::new();
            for step in 0..100 {
                let w = ex.recv_into(&mut out);
                rew.extend_from_slice(&out.rew);
                done.extend_from_slice(&out.done);
                let actions: Vec<f32> =
                    out.env_ids.iter().map(|&id| ((step + id as usize) % 2) as f32).collect();
                ex.send(w, &actions);
            }
            (rew, done)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn reused_action_buffer_applies_freshest_actions() {
        // Regression guard for the send→step handoff: the worker reuses
        // one preallocated action buffer across steps (it used to clone
        // the vector out of the mutex every step), so a stale or
        // misrouted copy would replay old actions. Drive each worker
        // with a step-varying action pattern and check the transition
        // stream against a directly-stepped ScalarVec reference.
        let per = 2usize;
        let mut ex = SampleFactoryExecutor::new("CartPole-v1", 4, 2, 21).unwrap();
        let mut refs: Vec<ScalarVec> =
            (0..2).map(|w| ScalarVec::new("CartPole-v1", 21, (w * per) as u64, per).unwrap()).collect();
        let dim = ex.spec().obs_dim();
        let mut ref_obs = vec![vec![0.0f32; per * dim]; 2];
        let mut ref_reset = vec![vec![0u8; per]; 2];
        for w in 0..2 {
            // mirror the worker's initial per-lane reset
            for i in 0..per {
                refs[w].reset_lane(i, &mut ref_obs[w][i * dim..(i + 1) * dim]);
            }
        }
        let mut ref_results = vec![Step::default(); per];
        let mut steps_seen = vec![0usize; 2];
        let mut out = ex.make_output();
        for _ in 0..60 {
            let w = ex.recv_into(&mut out);
            let k = steps_seen[w];
            if k > 0 {
                // compare against the reference worker's k-th step
                let actions: Vec<f32> = (0..per)
                    .map(|i| (((k - 1) + w * per + i) % 2) as f32)
                    .collect();
                {
                    let mut arena = SliceArena::new(&mut ref_obs[w], dim);
                    refs[w].step_batch(&actions, &ref_reset[w], &mut arena, &mut ref_results);
                }
                for i in 0..per {
                    ref_reset[w][i] = ref_results[i].finished() as u8;
                    assert_eq!(out.rew[i], ref_results[i].reward, "worker {w} step {k}");
                    assert_eq!(out.done[i], ref_results[i].done as u8);
                }
                assert_eq!(out.obs, ref_obs[w], "worker {w} step {k} obs diverged");
            }
            let actions: Vec<f32> =
                out.env_ids.iter().map(|&id| ((k + id as usize) % 2) as f32).collect();
            ex.send(w, &actions);
            steps_seen[w] += 1;
        }
        assert!(steps_seen.iter().all(|&s| s > 10));
    }

    #[test]
    fn episodes_roll_over() {
        let mut ex = SampleFactoryExecutor::new("CartPole-v1", 4, 1, 5).unwrap();
        let mut out = ex.make_output();
        let mut dones = 0;
        for step in 0..400 {
            let w = ex.recv_into(&mut out);
            dones += out.done.iter().filter(|&&d| d != 0).count();
            let actions: Vec<f32> = (0..out.len()).map(|k| ((step + k) % 2) as f32).collect();
            ex.send(w, &actions);
        }
        assert!(dones > 3, "cartpole must terminate under alternating actions, saw {dones}");
    }
}
