//! For-loop baseline: all environments stepped sequentially in the
//! calling thread — the paper's slowest comparison point, and the
//! semantic reference the other executors are tested against.

use super::traits::VectorEnv;
use crate::envs::env::Env;
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::pool::batch::BatchedTransition;
use crate::Result;

/// Sequential vectorized executor.
pub struct ForLoopExecutor {
    spec: EnvSpec,
    envs: Vec<Box<dyn Env>>,
    needs_reset: Vec<bool>,
}

impl ForLoopExecutor {
    pub fn new(task_id: &str, num_envs: usize, seed: u64) -> Result<Self> {
        let spec = registry::spec_for(task_id)?;
        let envs = (0..num_envs)
            .map(|i| registry::make_env(task_id, seed, i as u64))
            .collect::<Result<Vec<_>>>()?;
        Ok(ForLoopExecutor { spec, needs_reset: vec![false; num_envs], envs })
    }
}

impl VectorEnv for ForLoopExecutor {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.envs.len()
    }

    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()> {
        let dim = self.spec.obs_dim();
        out.obs_dim = dim;
        for (i, env) in self.envs.iter_mut().enumerate() {
            env.reset(&mut out.obs[i * dim..(i + 1) * dim]);
            out.rew[i] = 0.0;
            out.done[i] = 0;
            out.trunc[i] = 0;
            out.env_ids[i] = i as u32;
            self.needs_reset[i] = false;
        }
        Ok(())
    }

    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()> {
        let dim = self.spec.obs_dim();
        let adim = self.spec.action_space.dim();
        for (i, env) in self.envs.iter_mut().enumerate() {
            let obs = &mut out.obs[i * dim..(i + 1) * dim];
            if self.needs_reset[i] {
                self.needs_reset[i] = false;
                env.reset(obs);
                out.rew[i] = 0.0;
                out.done[i] = 0;
                out.trunc[i] = 0;
            } else {
                let s = env.step(&actions[i * adim..(i + 1) * adim], obs);
                out.rew[i] = s.reward;
                out.done[i] = s.done as u8;
                out.trunc[i] = s.truncated as u8;
                if s.finished() {
                    self.needs_reset[i] = true;
                }
            }
            out.env_ids[i] = i as u32;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_lifecycle() {
        let mut v = ForLoopExecutor::new("CartPole-v1", 3, 0).unwrap();
        let mut out = v.make_output();
        v.reset(&mut out).unwrap();
        assert_eq!(out.env_ids, vec![0, 1, 2]);
        for _ in 0..300 {
            let actions = vec![1.0f32; 3];
            v.step(&actions, &mut out).unwrap();
        }
        // constant-push cartpole must have terminated & auto-reset by now
    }

    #[test]
    fn agrees_with_pool_sync_mode() {
        // The semantic parity test behind Table 1: same seeds, same
        // actions => identical trajectories between the for-loop baseline
        // and EnvPool in sync mode.
        use crate::executors::traits::PoolVectorEnv;
        use crate::pool::envpool::{EnvPool, PoolConfig};

        let mut a = ForLoopExecutor::new("CartPole-v1", 4, 42).unwrap();
        let pool = EnvPool::make(
            PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(42),
        )
        .unwrap();
        let mut b = PoolVectorEnv::new(pool).unwrap();

        let mut oa = a.make_output();
        let mut ob = b.make_output();
        a.reset(&mut oa).unwrap();
        b.reset(&mut ob).unwrap();
        assert_eq!(oa.obs, ob.obs, "reset observations must match");
        for step in 0..200 {
            let actions: Vec<f32> = (0..4).map(|k| ((step + k) % 2) as f32).collect();
            a.step(&actions, &mut oa).unwrap();
            b.step(&actions, &mut ob).unwrap();
            assert_eq!(oa.rew, ob.rew, "step {step} rewards diverge");
            assert_eq!(oa.done, ob.done, "step {step} dones diverge");
            assert_eq!(oa.obs, ob.obs, "step {step} obs diverge");
        }
    }
}
