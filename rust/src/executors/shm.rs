//! Shared-memory slabs for `envpool serve`: per-lease observation and
//! action rings backed by files in `/dev/shm` (tmpfs), written and read
//! with positioned I/O (`pwrite`/`pread` via `std::os::unix::fs::FileExt`).
//!
//! The protocol mirrors the two-phase commit of
//! [`crate::pool::state_queue::StateBufferQueue`]'s `slot_obs_mut` /
//! `commit`: phase one writes the payload into a ring slot nobody is
//! reading (the control channel's credit scheme guarantees it — a client
//! pipelines at most `ring_slots - 1` waves); phase two is a tiny frame
//! on the Unix control socket (`Batch{seq}` / `Step{seq}`) that makes the
//! slot visible. The socket round-trip provides the happens-before edge:
//! both peers touch the slab through the same kernel page cache, so a
//! reader that has seen the commit frame sees the payload.
//!
//! Honest deviation from the "map once" ideal: the vendored crate set has
//! no `libc`, so instead of `mmap` the slabs use one `pwrite`/`pread`
//! syscall per *wave* (not per element or per env — the batching copy
//! stays amortized). Swapping in a real `mmap` later is a change local to
//! this module; layout, commit protocol and headers stay identical.
//!
//! Each slot carries a 16-byte header — magic, row count, wave sequence
//! number — validated on every read, so a torn or stale slot surfaces as
//! [`Error::Ipc`] instead of silent garbage.

use crate::pool::batch::BatchedTransition;
use crate::{Error, Result};
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;

/// `"EPSH"` little-endian — envpool shared-memory header.
const MAGIC: u32 = 0x4850_5345;
const HDR_BYTES: usize = 16;

/// Shape of one lease's rings; both peers must agree (the server sends
/// the numbers in the `Attached` handshake reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlabSpec {
    /// Envs per lease (rows per wave).
    pub lease_size: usize,
    /// Observation dim per env.
    pub obs_dim: usize,
    /// Action dim per env.
    pub act_dim: usize,
    /// Slots in the ring; wave `seq` lives in slot `seq % ring_slots`.
    pub ring_slots: usize,
}

fn round64(n: usize) -> usize {
    n.div_ceil(64) * 64
}

impl SlabSpec {
    /// Bytes of one obs slot: header + per-env `[obs f32 x dim, rew f32,
    /// done u8, trunc u8]` stored SoA, padded to a cache line.
    pub fn obs_slot_bytes(&self) -> usize {
        round64(HDR_BYTES + self.lease_size * (self.obs_dim * 4 + 4 + 1 + 1))
    }

    /// Bytes of one action slot: header + `lease_size * act_dim` f32s.
    pub fn act_slot_bytes(&self) -> usize {
        round64(HDR_BYTES + self.lease_size * self.act_dim * 4)
    }

    pub fn obs_file_bytes(&self) -> u64 {
        (self.obs_slot_bytes() * self.ring_slots) as u64
    }

    pub fn act_file_bytes(&self) -> u64 {
        (self.act_slot_bytes() * self.ring_slots) as u64
    }
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(bytes: &[u8], out: &mut [f32]) {
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
}

fn check_header(bytes: &[u8], expect_rows: usize, expect_seq: u64, what: &str) -> Result<()> {
    let magic = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    let rows = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    let seq = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    if magic != MAGIC {
        return Err(Error::Ipc(format!("{what} slab slot has bad magic {magic:#x}")));
    }
    if rows != expect_rows || seq != expect_seq {
        return Err(Error::Ipc(format!(
            "{what} slab slot holds wave seq {seq} of {rows} rows (expected seq \
             {expect_seq} of {expect_rows}) — commit protocol violated"
        )));
    }
    Ok(())
}

fn header(rows: usize, seq: u64) -> [u8; HDR_BYTES] {
    let mut h = [0u8; HDR_BYTES];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4..8].copy_from_slice(&(rows as u32).to_le_bytes());
    h[8..16].copy_from_slice(&seq.to_le_bytes());
    h
}

fn create_slab(path: &Path, bytes: u64) -> Result<File> {
    let f = OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
    f.set_len(bytes)?;
    Ok(f)
}

fn open_slab(path: &Path, bytes: u64, write: bool) -> Result<File> {
    let f = OpenOptions::new().read(true).write(write).open(path)?;
    let actual = f.metadata()?.len();
    if actual != bytes {
        return Err(Error::Attach(format!(
            "slab {} is {actual} bytes, expected {bytes} — client/server shape mismatch",
            path.display()
        )));
    }
    Ok(f)
}

/// One lease's observation ring (server publishes, client consumes).
pub struct ObsSlab {
    file: File,
    spec: SlabSpec,
    buf: Vec<u8>,
}

impl ObsSlab {
    /// Server side: create (or truncate) and size the backing file.
    pub fn create(path: &Path, spec: SlabSpec) -> Result<ObsSlab> {
        let file = create_slab(path, spec.obs_file_bytes())?;
        Ok(ObsSlab { file, spec, buf: Vec::with_capacity(spec.obs_slot_bytes()) })
    }

    /// Client side: open the file the `Attached` reply named.
    pub fn open(path: &Path, spec: SlabSpec) -> Result<ObsSlab> {
        let file = open_slab(path, spec.obs_file_bytes(), false)?;
        Ok(ObsSlab { file, spec, buf: vec![0; spec.obs_slot_bytes()] })
    }

    /// Phase one of the commit: write wave `seq` into its ring slot. The
    /// caller sends the `Batch{seq}` control frame afterwards (phase two).
    pub fn publish(
        &mut self,
        seq: u64,
        obs: &[f32],
        rew: &[f32],
        done: &[u8],
        trunc: &[u8],
    ) -> Result<()> {
        let k = self.spec.lease_size;
        debug_assert_eq!(obs.len(), k * self.spec.obs_dim);
        debug_assert_eq!(rew.len(), k);
        self.buf.clear();
        self.buf.extend_from_slice(&header(k, seq));
        put_f32s(&mut self.buf, obs);
        put_f32s(&mut self.buf, rew);
        self.buf.extend_from_slice(done);
        self.buf.extend_from_slice(trunc);
        let slot = (seq as usize % self.spec.ring_slots) as u64;
        self.file.write_at(&self.buf, slot * self.spec.obs_slot_bytes() as u64)?;
        Ok(())
    }

    /// Consume wave `seq` after its commit frame arrived, filling `out`
    /// in lease-local order with global env ids `first_env + i`.
    pub fn consume(&mut self, seq: u64, first_env: u32, out: &mut BatchedTransition) -> Result<()> {
        let k = self.spec.lease_size;
        let d = self.spec.obs_dim;
        let slot = (seq as usize % self.spec.ring_slots) as u64;
        let used = HDR_BYTES + k * (d * 4 + 4 + 1 + 1);
        self.buf.resize(self.spec.obs_slot_bytes(), 0);
        self.file.read_exact_at(&mut self.buf[..used], slot * self.spec.obs_slot_bytes() as u64)?;
        check_header(&self.buf, k, seq, "obs")?;
        out.obs_dim = d;
        out.obs.resize(k * d, 0.0);
        out.rew.resize(k, 0.0);
        out.done.resize(k, 0);
        out.trunc.resize(k, 0);
        out.env_ids.resize(k, 0);
        let mut at = HDR_BYTES;
        get_f32s(&self.buf[at..at + k * d * 4], &mut out.obs);
        at += k * d * 4;
        get_f32s(&self.buf[at..at + k * 4], &mut out.rew);
        at += k * 4;
        out.done.copy_from_slice(&self.buf[at..at + k]);
        at += k;
        out.trunc.copy_from_slice(&self.buf[at..at + k]);
        for (i, id) in out.env_ids.iter_mut().enumerate() {
            *id = first_env + i as u32;
        }
        Ok(())
    }
}

/// One lease's action ring (client publishes, server consumes).
pub struct ActSlab {
    file: File,
    spec: SlabSpec,
    buf: Vec<u8>,
}

impl ActSlab {
    /// Server side: create and size the backing file (the server owns
    /// every slab file's lifetime; the client only opens them).
    pub fn create(path: &Path, spec: SlabSpec) -> Result<ActSlab> {
        let file = create_slab(path, spec.act_file_bytes())?;
        Ok(ActSlab { file, spec, buf: vec![0; spec.act_slot_bytes()] })
    }

    /// Client side: open for writing actions.
    pub fn open(path: &Path, spec: SlabSpec) -> Result<ActSlab> {
        let file = open_slab(path, spec.act_file_bytes(), true)?;
        Ok(ActSlab { file, spec, buf: Vec::with_capacity(spec.act_slot_bytes()) })
    }

    /// Phase one on the client: write the action wave that will produce
    /// result `seq`; the `Step{seq}` control frame is the commit.
    pub fn publish(&mut self, seq: u64, actions: &[f32]) -> Result<()> {
        debug_assert_eq!(actions.len(), self.spec.lease_size * self.spec.act_dim);
        self.buf.clear();
        self.buf.extend_from_slice(&header(self.spec.lease_size, seq));
        put_f32s(&mut self.buf, actions);
        let slot = (seq as usize % self.spec.ring_slots) as u64;
        self.file.write_at(&self.buf, slot * self.spec.act_slot_bytes() as u64)?;
        Ok(())
    }

    /// Consume the action wave for result `seq` on the server.
    pub fn consume(&mut self, seq: u64, out: &mut Vec<f32>) -> Result<()> {
        let k = self.spec.lease_size;
        let n = k * self.spec.act_dim;
        let slot = (seq as usize % self.spec.ring_slots) as u64;
        let used = HDR_BYTES + n * 4;
        self.buf.resize(self.spec.act_slot_bytes(), 0);
        self.file.read_exact_at(&mut self.buf[..used], slot * self.spec.act_slot_bytes() as u64)?;
        check_header(&self.buf, k, seq, "act")?;
        out.resize(n, 0.0);
        get_f32s(&self.buf[HDR_BYTES..used], out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SlabSpec {
        SlabSpec { lease_size: 3, obs_dim: 4, act_dim: 2, ring_slots: 4 }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("envpool-shm-test-{}-{name}", std::process::id()))
    }

    #[test]
    fn obs_wave_roundtrip_and_ring_wrap() {
        let path = tmp("obs");
        let mut server = ObsSlab::create(&path, spec()).unwrap();
        let mut client = ObsSlab::open(&path, spec()).unwrap();
        let mut out = BatchedTransition::with_capacity(3, 4);
        for seq in 0..9u64 {
            let obs: Vec<f32> = (0..12).map(|i| seq as f32 + i as f32 * 0.5).collect();
            let rew = [seq as f32; 3];
            server.publish(seq, &obs, &rew, &[0, 1, 0], &[1, 0, 0]).unwrap();
            client.consume(seq, 10, &mut out).unwrap();
            assert_eq!(out.obs, obs, "seq {seq}");
            assert_eq!(out.rew, rew);
            assert_eq!(out.done, [0, 1, 0]);
            assert_eq!(out.trunc, [1, 0, 0]);
            assert_eq!(out.env_ids, [10, 11, 12]);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn act_wave_roundtrip() {
        let path = tmp("act");
        let mut client = ActSlab::create(&path, spec()).unwrap();
        let mut server = ActSlab::open(&path, spec()).unwrap();
        let mut out = Vec::new();
        client.publish(5, &[1.0, -2.0, 3.0, -4.0, 5.0, -6.0]).unwrap();
        server.consume(5, &mut out).unwrap();
        assert_eq!(out, [1.0, -2.0, 3.0, -4.0, 5.0, -6.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_or_torn_slot_is_rejected() {
        let path = tmp("stale");
        let mut server = ObsSlab::create(&path, spec()).unwrap();
        let mut client = ObsSlab::open(&path, spec()).unwrap();
        let mut out = BatchedTransition::with_capacity(3, 4);
        // Nothing published yet: all-zero header fails the magic check.
        let err = client.consume(0, 0, &mut out).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "got {err}");
        // Publish seq 0, then ask for seq 4 (same ring slot, stale wave).
        server.publish(0, &[0.0; 12], &[0.0; 3], &[0; 3], &[0; 3]).unwrap();
        let err = client.consume(4, 0, &mut out).unwrap_err();
        assert!(err.to_string().contains("commit protocol"), "got {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shape_mismatch_refused_at_open() {
        let path = tmp("shape");
        let _server = ObsSlab::create(&path, spec()).unwrap();
        let bigger = SlabSpec { lease_size: 64, ..spec() };
        let err = ObsSlab::open(&path, bigger).unwrap_err();
        assert!(matches!(err, Error::Attach(_)), "got {err}");
        let _ = std::fs::remove_file(&path);
    }
}
